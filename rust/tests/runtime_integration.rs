//! Integration: manifest + PJRT execution of real AOT artifacts.
//!
//! Requires `make artifacts` (skipped with a clear message otherwise).

use iso::runtime::{Arg, Manifest, Tensor, WorkerRuntime};

fn manifest() -> Option<Manifest> {
    match Manifest::load("artifacts") {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e}");
            None
        }
    }
}

#[test]
fn manifest_covers_engine_needs() {
    let Some(m) = manifest() else { return };
    assert_eq!(m.config.d_model, 128);
    assert_eq!(m.config.n_layers, 4);
    assert!(m.tp_degrees.contains(&2));
    for tp in &m.tp_degrees {
        for t in &m.chunk_lens {
            assert!(m.module(&format!("attn_tp{tp}_t{t}")).is_ok());
            assert!(m.module(&format!("mlp_tp{tp}_t{t}")).is_ok());
        }
    }
    for t in &m.chunk_lens {
        assert!(m.module(&format!("embed_t{t}")).is_ok());
        assert!(m.module(&format!("logits_t{t}")).is_ok());
    }
}

#[test]
fn weights_load_with_declared_shapes() {
    let Some(m) = manifest() else { return };
    let rt = WorkerRuntime::new(m).unwrap();
    let emb = rt.load_weight(2, "emb").unwrap();
    assert_eq!(emb.shape, vec![512, 128]);
    let wq = rt.load_weight(2, "layer0.rank1.wq").unwrap();
    assert_eq!(wq.shape, vec![128, 4 * 16]); // hq/tp=4 heads × hd=16
    let down = rt.load_weight(4, "layer3.rank3.w_down").unwrap();
    assert_eq!(down.shape, vec![512 / 4, 128]);
}

#[test]
fn embed_stage_is_a_table_lookup() {
    let Some(m) = manifest() else { return };
    let rt = WorkerRuntime::new(m).unwrap();
    let exe = rt.compile("embed_t16").unwrap();
    let emb = rt.load_weight(1, "emb").unwrap();
    let tokens: Vec<i32> = (0..16).collect();
    let out = exe.run(&[Arg::I32(&tokens), Arg::F32(&emb)]).unwrap();
    assert_eq!(out[0].shape, vec![16, 128]);
    // row i of output == row tokens[i] of emb
    for i in 0..16 {
        let got = &out[0].data[i * 128..(i + 1) * 128];
        let want = &emb.data[(tokens[i] as usize) * 128..(tokens[i] as usize + 1) * 128];
        assert_eq!(got, want, "row {i}");
    }
}

#[test]
fn attn_stage_writes_kv_at_offset() {
    let Some(m) = manifest() else { return };
    let rt = WorkerRuntime::new(m).unwrap();
    let exe = rt.compile("attn_tp2_t16").unwrap();
    let w = |n: &str| rt.load_weight(2, &format!("layer0.rank0.{n}")).unwrap();
    let x = Tensor::new(vec![16, 128], (0..16 * 128).map(|i| (i % 7) as f32 * 0.01).collect());
    let kc = Tensor::zeros(vec![2, 256, 16]);
    let vc = Tensor::zeros(vec![2, 256, 16]);
    let offset = 32;
    let out = exe
        .run(&[
            Arg::F32(&x),
            Arg::F32(&w("ln1")),
            Arg::F32(&w("wq")),
            Arg::F32(&w("wk")),
            Arg::F32(&w("wv")),
            Arg::F32(&w("wo")),
            Arg::F32(&kc),
            Arg::F32(&vc),
            Arg::Scalar(offset),
        ])
        .unwrap();
    assert_eq!(out.len(), 3);
    assert_eq!(out[0].shape, vec![16, 128]);
    let new_k = &out[1];
    // positions [32, 48) must be written, everything else still zero
    for h in 0..2 {
        for pos in 0..256 {
            let row = &new_k.data[(h * 256 + pos) * 16..(h * 256 + pos + 1) * 16];
            let nonzero = row.iter().any(|&v| v != 0.0);
            let expect = (32..48).contains(&pos);
            assert_eq!(nonzero, expect, "h={h} pos={pos}");
        }
    }
}

#[test]
fn tp_partials_sum_matches_tp1() {
    // sum over ranks of attn partials (tp=2) == the tp=1 partial.
    let Some(m) = manifest() else { return };
    let rt = WorkerRuntime::new(m).unwrap();
    let x = Tensor::new(vec![16, 128], (0..16 * 128).map(|i| ((i % 13) as f32 - 6.0) * 0.02).collect());

    let exe1 = rt.compile("attn_tp1_t16").unwrap();
    let w1 = |n: &str| rt.load_weight(1, &format!("layer0.rank0.{n}")).unwrap();
    let full = exe1
        .run(&[
            Arg::F32(&x),
            Arg::F32(&w1("ln1")),
            Arg::F32(&w1("wq")),
            Arg::F32(&w1("wk")),
            Arg::F32(&w1("wv")),
            Arg::F32(&w1("wo")),
            Arg::F32(&Tensor::zeros(vec![4, 256, 16])),
            Arg::F32(&Tensor::zeros(vec![4, 256, 16])),
            Arg::Scalar(0),
        ])
        .unwrap();

    let exe2 = rt.compile("attn_tp2_t16").unwrap();
    let mut acc = vec![0.0f32; 16 * 128];
    for rank in 0..2 {
        let w = |n: &str| rt.load_weight(2, &format!("layer0.rank{rank}.{n}")).unwrap();
        let part = exe2
            .run(&[
                Arg::F32(&x),
                Arg::F32(&w("ln1")),
                Arg::F32(&w("wq")),
                Arg::F32(&w("wk")),
                Arg::F32(&w("wv")),
                Arg::F32(&w("wo")),
                Arg::F32(&Tensor::zeros(vec![2, 256, 16])),
                Arg::F32(&Tensor::zeros(vec![2, 256, 16])),
                Arg::Scalar(0),
            ])
            .unwrap();
        for (a, b) in acc.iter_mut().zip(&part[0].data) {
            *a += b;
        }
    }
    for (i, (a, b)) in acc.iter().zip(&full[0].data).enumerate() {
        assert!((a - b).abs() < 1e-3, "idx {i}: {a} vs {b}");
    }
}

#[test]
fn corrupt_manifest_rejected() {
    // Failure injection: a syntactically-broken manifest and a manifest
    // whose weights lie about their sizes must both fail loudly.
    let dir = std::env::temp_dir().join("iso_corrupt_manifest");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), "{ not json").unwrap();
    assert!(Manifest::load(&dir).is_err());

    std::fs::write(
        dir.join("manifest.json"),
        r#"{"format_version":1,"config":{"vocab":8,"d_model":0,"n_layers":0,
            "n_heads":1,"n_kv_heads":1,"head_dim":1,"d_ff":1,"max_seq":8},
            "modules":[],"weights":{},"chunk_lens":[],"tp_degrees":[],
            "golden":{"tokens_file":"t","logits_file":"l","prompt_len":0,
            "logits_shape":[0,0]}}"#,
    )
    .unwrap();
    let err = Manifest::load(&dir).unwrap_err();
    assert!(err.to_string().contains("incomplete"), "{err}");
}

#[test]
fn truncated_weight_file_detected() {
    let Some(m) = manifest() else { return };
    // Copy the artifacts manifest but point at a truncated weight file.
    let dir = std::env::temp_dir().join("iso_truncated_weight");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::copy(m.dir.join("manifest.json"), dir.join("manifest.json")).unwrap();
    let m2 = Manifest::load(&dir).unwrap();
    // read_f32 with a non-multiple-of-4 file must error, not mis-parse
    std::fs::create_dir_all(dir.join("weights_tp2")).unwrap();
    std::fs::write(dir.join("weights_tp2/emb.f32"), [0u8; 7]).unwrap();
    assert!(m2.read_f32("weights_tp2/emb.f32").is_err());
    // and a missing file is a clean error
    assert!(m2.read_f32("weights_tp2/nope.f32").is_err());
}

#[test]
fn engine_rejects_missing_chunk_artifacts() {
    // An engine config demanding a tp degree the artifacts don't have
    // must fail at start, not at first request.
    use iso::config::EngineConfig;
    use iso::coordinator::Engine;
    if manifest().is_none() {
        return;
    }
    let mut cfg = EngineConfig::default();
    cfg.tp = 8; // artifacts ship tp ∈ {1,2,4}
    assert!(Engine::start(cfg).is_err());
}

#[test]
fn golden_data_consistent() {
    let Some(m) = manifest() else { return };
    let (tokens, logits, shape) = m.golden_data().unwrap();
    assert_eq!(tokens.len(), m.golden.prompt_len);
    assert_eq!(shape, vec![m.golden.prompt_len, m.config.vocab]);
    assert_eq!(logits.len(), shape[0] * shape[1]);
    assert!(tokens.iter().all(|&t| (t as usize) < m.config.vocab));
    assert!(logits.iter().all(|v| v.is_finite()));
}
