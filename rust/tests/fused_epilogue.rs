//! Fused-epilogue contracts (PR-5 tentpole acceptance): folding the
//! per-segment epilogue — residual add, and optionally the next op's
//! RMSNorm and row-sliced prologue GEMM — into the collective's segment
//! callbacks (`allreduce_seg_fused`, DESIGN.md §12) is **bit-identical**
//! to running the collective first and applying the epilogue once, across
//! segment counts × rank counts × wire formats × the engine's scheduler
//! shapes (sequential / mixed / spec / pp).
//!
//! The engine-level twin (fused vs unfused logits bit-equality through
//! the real coordinator) lives in `engine_e2e::fused_epilogue_engine_bit_identical`
//! and is artifact-gated; these tests are pure rust and always run.

use iso::collective::{run_on_ring, FusedEpilogue, Prologue};
use iso::config::CommQuant;
use iso::util::{Prop, Rng};

/// Deterministic per-rank partial for collective number `step` given the
/// current residual: rank-dependent scale plus a step offset, so any
/// bitwise divergence compounds through the schedule and gets caught.
fn partial_of(res: &[f32], rank: usize, step: usize) -> Vec<f32> {
    res.iter()
        .map(|&v| 0.25 * v * (rank as f32 + 1.0) + step as f32 * 0.01)
        .collect()
}

/// One scheduler shape: a sequence of collectives over named tensors.
/// `Seg(tensor, rows)` is a segment-streamed chunk collective (the
/// prefill path); `Lane(tensor, rows)` is a rank-ordered fused-rows lane
/// collective (the decode/verify path).
#[derive(Clone, Copy)]
enum Coll {
    Seg(usize, usize),
    Lane(usize, usize),
}

/// The four engine scheduler shapes, as the comm thread sees them
/// (tensor id, rows). `cols` is fixed by the caller.
fn shape(name: &str) -> (Vec<Coll>, Vec<usize>) {
    // Returns (collective sequence per "layer" ×2 layers, tensor rows).
    let (per_layer, tensors): (Vec<Coll>, Vec<usize>) = match name {
        // One chunk, attn + mlp collectives per layer.
        "sequential" => (vec![Coll::Seg(0, 12)], vec![12]),
        // Two prefill chunks + a fused decode lane per layer
        // ([P_attn×2, D], DESIGN.md §9 wire order).
        "mixed" => (
            vec![Coll::Seg(0, 8), Coll::Seg(1, 5), Coll::Lane(2, 3)],
            vec![8, 5, 3],
        ),
        // One wide verify lane (B·(k+1) rows, DESIGN.md §10).
        "spec" => (vec![Coll::Lane(0, 9)], vec![9]),
        // Two pipeline stages' slices of the same chunk back-to-back
        // (the p2p handoff is bit-exact by construction, DESIGN.md §11).
        "pp" => (vec![Coll::Seg(0, 7), Coll::Seg(0, 7)], vec![7]),
        other => panic!("unknown shape {other}"),
    };
    let mut seq = Vec::new();
    for _layer in 0..2 {
        // attn-reduce then mlp-reduce per tensor, per layer.
        seq.extend(per_layer.iter().copied());
        seq.extend(per_layer.iter().copied());
    }
    (seq, tensors)
}

/// Run a shape's collective stream on every rank; `fused` routes the
/// segment-streamed collectives through `allreduce_seg_fused` (comm-side
/// epilogue), `!fused` through `allreduce_seg` + a monolithic apply.
/// Returns each rank's final tensors.
fn run_shape(
    name: &str,
    n: usize,
    cols: usize,
    segments: usize,
    quant: CommQuant,
    fused: bool,
    seed: u64,
) -> Vec<Vec<Vec<f32>>> {
    let (seq, tensor_rows) = shape(name);
    let mut rng = Rng::new(seed);
    let init: Vec<Vec<f32>> =
        tensor_rows.iter().map(|&r| rng.normal_vec(r * cols, 1.0)).collect();
    run_on_ring(n, |rank, h| {
        let mut tensors: Vec<Vec<f32>> = init.clone();
        for (step, c) in seq.iter().enumerate() {
            match *c {
                Coll::Seg(t, rows) => {
                    let mut d = partial_of(&tensors[t], rank, step);
                    if fused {
                        let mut ep = FusedEpilogue::residual_only(&mut tensors[t], cols);
                        h.allreduce_seg_fused(&mut d, rows, cols, quant, segments, &mut ep);
                    } else {
                        h.allreduce_seg(&mut d, rows, cols, quant, segments);
                        for (o, v) in tensors[t].iter_mut().zip(&d) {
                            *o += *v;
                        }
                    }
                }
                Coll::Lane(t, rows) => {
                    // The lane collective is rank-ordered and un-segmented
                    // in both modes; only where the residual-add runs
                    // differs in the engine (comm vs compute thread) —
                    // the arithmetic is identical by construction.
                    let mut d = partial_of(&tensors[t], rank, step);
                    h.allreduce_rows_fused(&mut d, rows, cols, quant);
                    for (o, v) in tensors[t].iter_mut().zip(&d) {
                        *o += *v;
                    }
                }
            }
        }
        tensors
    })
}

#[test]
fn fused_epilogue_bit_identical_across_schedulers_and_segments() {
    // The acceptance pin: for every scheduler shape, rank count, wire
    // format and segment count, the fused-epilogue stream produces
    // bit-identical tensors to the unfused reference.
    for name in ["sequential", "mixed", "spec", "pp"] {
        for quant in [CommQuant::F32, CommQuant::Int8] {
            for n in [1usize, 2, 4] {
                let gold = run_shape(name, n, 6, 1, quant, false, 77);
                for segments in [1usize, 2, 3, 8] {
                    for fused in [false, true] {
                        let got = run_shape(name, n, 6, segments, quant, fused, 77);
                        assert_eq!(
                            gold, got,
                            "shape={name} quant={quant:?} n={n} segments={segments} \
                             fused={fused}: schedule diverged bitwise"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn full_epilogue_with_norm_and_prologue_bit_identical() {
    // The full TokenWeave-style epilogue (residual + RMSNorm + prologue
    // GEMM) fused per segment equals reduce-then-apply-once, bitwise.
    let (rows, cols, n_out) = (10usize, 8usize, 3usize);
    let n = 3;
    let mut rng = Rng::new(13);
    let parts: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(rows * cols, 1.0)).collect();
    let res0 = rng.normal_vec(rows * cols, 1.0);
    let gamma = rng.normal_vec(cols, 1.0);
    let w = rng.normal_vec(cols * n_out, 1.0);
    let gold = run_on_ring(n, |r, h| {
        let mut d = parts[r].clone();
        h.allreduce_seg(&mut d, rows, cols, CommQuant::F32, 1);
        let mut res = res0.clone();
        let mut normed = vec![0.0f32; rows * cols];
        let mut out = vec![0.0f32; rows * n_out];
        let mut ep = FusedEpilogue {
            residual: &mut res,
            cols,
            norm: Some((&gamma, 1e-5)),
            normed: Some(&mut normed),
            prologue: Some(Prologue { weight: &w, n: n_out, out: &mut out }),
        };
        ep.apply(0, rows, &d);
        (res, normed, out)
    });
    for segments in [2usize, 4, 7] {
        let got = run_on_ring(n, |r, h| {
            let mut d = parts[r].clone();
            let mut res = res0.clone();
            let mut normed = vec![0.0f32; rows * cols];
            let mut out = vec![0.0f32; rows * n_out];
            let mut ep = FusedEpilogue {
                residual: &mut res,
                cols,
                norm: Some((&gamma, 1e-5)),
                normed: Some(&mut normed),
                prologue: Some(Prologue { weight: &w, n: n_out, out: &mut out }),
            };
            h.allreduce_seg_fused(&mut d, rows, cols, CommQuant::F32, segments, &mut ep);
            (res, normed, out)
        });
        assert_eq!(gold, got, "segments={segments}: full epilogue diverged");
    }
}

#[test]
fn prop_fused_epilogue_bit_identical() {
    // Randomized sweep over shapes the grid test does not enumerate.
    Prop::new(29).cases(40).run("fused epilogue bitwise", |rng| {
        let n = rng.range(1, 5);
        let rows = rng.range(1, 24);
        let cols = rng.range(1, 12);
        let segments = rng.range(1, 10);
        let quant = if rng.f64() < 0.5 { CommQuant::F32 } else { CommQuant::Int8 };
        let mut seeder = Rng::new(1000 + rows as u64 * 31 + cols as u64);
        let parts: Vec<Vec<f32>> =
            (0..n).map(|_| seeder.normal_vec(rows * cols, 1.5)).collect();
        let res0 = seeder.normal_vec(rows * cols, 1.5);
        let gold = run_on_ring(n, |r, h| {
            let mut d = parts[r].clone();
            h.allreduce_seg(&mut d, rows, cols, quant, 1);
            let mut res = res0.clone();
            for (o, v) in res.iter_mut().zip(&d) {
                *o += *v;
            }
            res
        });
        let got = run_on_ring(n, |r, h| {
            let mut d = parts[r].clone();
            let mut res = res0.clone();
            let mut ep = FusedEpilogue::residual_only(&mut res, cols);
            h.allreduce_seg_fused(&mut d, rows, cols, quant, segments, &mut ep);
            res
        });
        if gold != got {
            return Err(format!(
                "n={n} rows={rows} cols={cols} segments={segments} quant={quant:?}"
            ));
        }
        Ok(())
    });
}
