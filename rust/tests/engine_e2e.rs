//! End-to-end engine tests: the full three-layer stack — rust coordinator
//! executing AOT JAX/Pallas artifacts over real ring collectives — checked
//! against the python full-model golden logits.
//!
//! Requires `make artifacts`.

use iso::batch::DecodeSlot;
use iso::config::{CommQuant, EngineConfig, SplitPolicy, Strategy};
use iso::coordinator::Engine;
use iso::runtime::Manifest;

fn have_artifacts() -> bool {
    match Manifest::load("artifacts") {
        Ok(_) => true,
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e}");
            false
        }
    }
}

fn cfg(strategy: Strategy, tp: usize) -> EngineConfig {
    EngineConfig {
        strategy,
        split: SplitPolicy::Even,
        comm_quant: CommQuant::F32,
        gemm_segments: 1,
        tp,
        max_chunk: 64,
        max_batch: 4,
        decode_steps: 0,
        artifacts_dir: "artifacts".into(),
        ..Default::default()
    }
}

/// Cosine similarity guard for logits vectors.
fn assert_close(got: &[f32], want: &[f32], tol: f32, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    let mut max_err = 0.0f32;
    for (g, w) in got.iter().zip(want) {
        max_err = max_err.max((g - w).abs());
    }
    assert!(max_err < tol, "{what}: max |err| = {max_err} >= {tol}");
}

#[test]
fn serial_engine_matches_golden_logits() {
    if !have_artifacts() {
        return;
    }
    let m = Manifest::load("artifacts").unwrap();
    let (tokens, golden, shape) = m.golden_data().unwrap();
    let mut e = Engine::start(cfg(Strategy::Serial, 2)).unwrap();
    let out = e.prefill(&tokens).unwrap();
    let vocab = shape[1];
    let want = &golden[(tokens.len() - 1) * vocab..tokens.len() * vocab];
    assert_close(&out.logits, want, 2e-3, "serial tp=2 last-row logits");
    e.shutdown().unwrap();
}

#[test]
fn iso_engine_matches_golden_logits() {
    // The ISO invariant end-to-end: the pipelined two-chunk schedule over
    // real collectives is numerically identical (to fp tolerance) to the
    // one-shot python reference.
    if !have_artifacts() {
        return;
    }
    let m = Manifest::load("artifacts").unwrap();
    let (tokens, golden, shape) = m.golden_data().unwrap();
    for tp in [1usize, 2, 4] {
        let mut e = Engine::start(cfg(Strategy::Iso, tp)).unwrap();
        let out = e.prefill(&tokens).unwrap();
        let vocab = shape[1];
        let want = &golden[(tokens.len() - 1) * vocab..tokens.len() * vocab];
        assert_close(&out.logits, want, 2e-3, &format!("iso tp={tp} last-row logits"));
        e.shutdown().unwrap();
    }
}

#[test]
fn iso_equals_serial_numerics() {
    if !have_artifacts() {
        return;
    }
    let prompt: Vec<i32> = (0..96).map(|i| (i * 37 % 512) as i32).collect();
    let mut serial = Engine::start(cfg(Strategy::Serial, 2)).unwrap();
    let a = serial.prefill(&prompt).unwrap();
    serial.shutdown().unwrap();
    let mut iso = Engine::start(cfg(Strategy::Iso, 2)).unwrap();
    let b = iso.prefill(&prompt).unwrap();
    iso.shutdown().unwrap();
    assert_close(&a.logits, &b.logits, 1e-4, "iso vs serial logits");
    assert_eq!(a.first_token, b.first_token);
}

#[test]
fn int8_wire_close_to_f32() {
    if !have_artifacts() {
        return;
    }
    let prompt: Vec<i32> = (0..64).map(|i| (i * 13 % 512) as i32).collect();
    let mut f32e = Engine::start(cfg(Strategy::Iso, 2)).unwrap();
    let a = f32e.prefill(&prompt).unwrap();
    f32e.shutdown().unwrap();

    let mut c = cfg(Strategy::Iso, 2);
    c.comm_quant = CommQuant::Int8;
    let mut int8e = Engine::start(c).unwrap();
    let b = int8e.prefill(&prompt).unwrap();
    let report = int8e.shutdown().unwrap();

    // int8 wire must (a) agree closely on logits, (b) move ~4x fewer bytes.
    let denom: f32 = a.logits.iter().map(|v| v * v).sum::<f32>().sqrt();
    let num: f32 = a
        .logits
        .iter()
        .zip(&b.logits)
        .map(|(x, y)| (x - y) * (x - y))
        .sum::<f32>()
        .sqrt();
    assert!(num / denom < 0.05, "relative logits error {}", num / denom);
    assert!(report.metrics.comm_bytes > 0);
}

#[test]
fn uneven_split_same_numerics() {
    // Paper §6: the split ratio is a scheduling knob, not a numerics knob.
    if !have_artifacts() {
        return;
    }
    let prompt: Vec<i32> = (0..128).map(|i| (i * 7 % 512) as i32).collect();
    let mut even = Engine::start(cfg(Strategy::Iso, 2)).unwrap();
    let a = even.prefill(&prompt).unwrap();
    even.shutdown().unwrap();

    let mut c = cfg(Strategy::Iso, 2);
    c.split = SplitPolicy::Ratio(0.75);
    let mut uneven = Engine::start(c).unwrap();
    let b = uneven.prefill(&prompt).unwrap();
    uneven.shutdown().unwrap();
    assert_close(&a.logits, &b.logits, 1e-4, "even vs 75/25 split");
}

#[test]
fn generate_decodes_greedily_and_consistently() {
    if !have_artifacts() {
        return;
    }
    let prompt: Vec<i32> = (0..32).map(|i| (i * 11 % 512) as i32).collect();
    let mut e1 = Engine::start(cfg(Strategy::Serial, 2)).unwrap();
    let g1 = e1.generate(&prompt, 4).unwrap();
    e1.shutdown().unwrap();
    let mut e2 = Engine::start(cfg(Strategy::Iso, 2)).unwrap();
    let g2 = e2.generate(&prompt, 4).unwrap();
    e2.shutdown().unwrap();
    assert_eq!(g1.tokens.len(), 5); // first + 4 decode steps
    assert_eq!(g1.tokens, g2.tokens, "serial and ISO must decode identically");
}

#[test]
fn engine_reuses_slots_across_requests() {
    if !have_artifacts() {
        return;
    }
    let mut e = Engine::start(cfg(Strategy::Iso, 2)).unwrap();
    let prompt: Vec<i32> = (0..48).map(|i| i as i32 % 512).collect();
    let a = e.prefill(&prompt).unwrap();
    for _ in 0..5 {
        let b = e.prefill(&prompt).unwrap();
        assert_eq!(a.first_token, b.first_token, "slot reuse changed results");
    }
    let report = e.shutdown().unwrap();
    assert_eq!(report.metrics.ttft_ms.len(), 6);
    assert!(report.workers.iter().all(|w| w.allreduces > 0));
}

#[test]
fn rejects_overlong_prompts_and_bad_tp() {
    if !have_artifacts() {
        return;
    }
    let mut e = Engine::start(cfg(Strategy::Iso, 2)).unwrap();
    let too_long: Vec<i32> = vec![0; 300]; // max_seq = 256
    assert!(e.prefill(&too_long).is_err());
    // engine must still work after a rejected request
    let ok: Vec<i32> = vec![1; 32];
    assert!(e.prefill(&ok).is_ok());
    e.shutdown().unwrap();

    let mut bad = cfg(Strategy::Iso, 3);
    bad.tp = 3;
    assert!(Engine::start(bad).is_err());
}

#[test]
fn serve_trace_continuous_batching() {
    // Admission-capped continuous batching over a paced arrival trace:
    // every request completes, decode interleaves across live sequences,
    // and queueing shows up in arrival-relative TTFT.
    if !have_artifacts() {
        return;
    }
    use iso::workload::{LenDist, TraceGen};
    let mut c = cfg(Strategy::Iso, 2);
    c.max_batch = 2; // force queueing with more requests than slots
    let mut e = Engine::start(c).unwrap();
    let mut gen = TraceGen::new(11, 512, LenDist::Uniform(20, 60)).decode_steps(3).rate(50.0);
    let reqs = gen.generate(6);
    let trace = e.serve_trace(&reqs).unwrap();
    assert_eq!(trace.completed, 6);
    assert_eq!(trace.ttft_ms.len(), 6);
    assert_eq!(trace.e2e_ms.len(), 6);
    // 1 first token + 3 decode steps each
    assert_eq!(trace.generated, 6 * 4);
    assert!(trace.throughput_tok_s() > 0.0);
    let report = e.shutdown().unwrap();
    assert!(report.metrics.generated_tokens >= 18);
}

#[test]
fn serve_trace_respects_decode_budget_and_max_seq() {
    if !have_artifacts() {
        return;
    }
    use iso::workload::Request;
    let mut e = Engine::start(cfg(Strategy::Serial, 2)).unwrap();
    // 250-token prompt (pads to 256 = max_seq): no decode room at all.
    let reqs = vec![Request {
        id: 0,
        arrival_s: 0.0,
        prompt: vec![1; 240],
        decode_steps: 50,
    }];
    let trace = e.serve_trace(&reqs).unwrap();
    assert_eq!(trace.completed, 1);
    // decode stops at max_seq even though 50 steps were requested
    assert!(trace.generated <= 1 + (256 - 240) as u64);
    e.shutdown().unwrap();
}

#[test]
fn comm_segments_bit_identical_logits() {
    // The tentpole invariant end-to-end: segment-streamed collectives
    // change scheduling granularity, never numerics — the engine's f32
    // logits are bit-identical across comm_segments.
    if !have_artifacts() {
        return;
    }
    let prompt: Vec<i32> = (0..96).map(|i| (i * 17 % 512) as i32).collect();
    let mut base = Engine::start(cfg(Strategy::Iso, 2)).unwrap();
    let a = base.prefill(&prompt).unwrap();
    base.shutdown().unwrap();
    for segments in [2usize, 4] {
        // Legacy ack streaming (fused_epilogue off): per-segment acks
        // flow back to the compute thread.
        let mut c = cfg(Strategy::Iso, 2);
        c.comm_segments = segments;
        c.fused_epilogue = false;
        let mut e = Engine::start(c).unwrap();
        let b = e.prefill(&prompt).unwrap();
        let report = e.shutdown().unwrap();
        assert_eq!(a.logits, b.logits, "comm_segments={segments} changed numerics");
        assert_eq!(a.first_token, b.first_token);
        // Per-segment acks actually streamed (more acks than collectives).
        assert!(
            report.metrics.seg_acks > report.metrics.allreduces,
            "segments={segments}: seg_acks {} <= allreduces {}",
            report.metrics.seg_acks,
            report.metrics.allreduces
        );
        assert!(report.metrics.comm_msgs > 0);
    }
}

#[test]
fn fused_epilogue_engine_bit_identical() {
    // The PR-5 tentpole invariant end-to-end: folding the residual
    // epilogue into the collective's segment callbacks (comm-side) never
    // changes a bit of the logits, at any segment count — and the fused
    // path really runs (rows counted, one ack per collective).
    if !have_artifacts() {
        return;
    }
    let prompt: Vec<i32> = (0..96).map(|i| (i * 19 % 512) as i32).collect();
    let mut base_cfg = cfg(Strategy::Iso, 2);
    base_cfg.fused_epilogue = false;
    let mut base = Engine::start(base_cfg).unwrap();
    let a = base.prefill(&prompt).unwrap();
    base.shutdown().unwrap();
    for segments in [1usize, 2, 4] {
        let mut c = cfg(Strategy::Iso, 2);
        c.comm_segments = segments;
        c.fused_epilogue = true;
        let mut e = Engine::start(c).unwrap();
        let b = e.prefill(&prompt).unwrap();
        let report = e.shutdown().unwrap();
        assert_eq!(
            a.logits, b.logits,
            "fused epilogue changed numerics at segments={segments}"
        );
        assert_eq!(a.first_token, b.first_token);
        assert!(
            report.metrics.fused_epilogue_rows > 0,
            "segments={segments}: fused epilogue never ran"
        );
        // One ack per collective: the exposed epilogue collapsed.
        assert_eq!(
            report.metrics.seg_acks, report.metrics.allreduces,
            "segments={segments}: fused path should ack once per collective"
        );
    }
}

#[test]
fn fused_epilogue_decode_and_trace_identical() {
    // The fused epilogue covers the decode/verify lanes and the serving
    // loop too: fused-off and fused-on engines emit identical tokens.
    if !have_artifacts() {
        return;
    }
    use iso::workload::{LenDist, TraceGen};
    let reqs = TraceGen::new(33, 512, LenDist::Uniform(20, 60))
        .decode_steps(4)
        .rate(100.0)
        .generate(4);
    let mut completions = Vec::new();
    for fused in [false, true] {
        let mut c = cfg(Strategy::Iso, 2);
        c.max_batch = 3;
        c.decode_batch = 2;
        c.fused_epilogue = fused;
        let mut e = Engine::start(c).unwrap();
        let trace = e.serve_trace(&reqs).unwrap();
        e.shutdown().unwrap();
        let mut sorted = trace.completions.clone();
        sorted.sort_by_key(|(id, _)| *id);
        completions.push(sorted);
    }
    assert_eq!(
        completions[0], completions[1],
        "fused epilogue changed served tokens"
    );
}

#[test]
fn ladder_residual_runs_and_decodes_consistently() {
    // Ladder residual is numerics-changing by design, so there is no
    // bit-exact pin — but it must serve correctly (every request
    // completes), be self-consistent across runs, and its decode chain
    // must match its own prefill+generate path.
    if !have_artifacts() {
        return;
    }
    let prompt: Vec<i32> = (0..48).map(|i| (i * 23 % 512) as i32).collect();
    let mut c = cfg(Strategy::Serial, 2);
    c.ladder_residual = true;
    let mut e1 = Engine::start(c.clone()).unwrap();
    let g1 = e1.generate(&prompt, 4).unwrap();
    e1.shutdown().unwrap();
    let mut e2 = Engine::start(c).unwrap();
    let g2 = e2.generate(&prompt, 4).unwrap();
    e2.shutdown().unwrap();
    assert_eq!(g1.tokens.len(), 5);
    assert_eq!(g1.tokens, g2.tokens, "ladder mode must be deterministic");
}

#[test]
fn decode_works_with_comm_segments() {
    // Decode chunks are single rows; the segment knob must degrade to
    // one sub-message without deadlock or numeric drift.
    if !have_artifacts() {
        return;
    }
    let prompt: Vec<i32> = (0..32).map(|i| (i * 11 % 512) as i32).collect();
    let mut e1 = Engine::start(cfg(Strategy::Iso, 2)).unwrap();
    let g1 = e1.generate(&prompt, 4).unwrap();
    e1.shutdown().unwrap();
    let mut c = cfg(Strategy::Iso, 2);
    c.comm_segments = 4;
    let mut e2 = Engine::start(c).unwrap();
    let g2 = e2.generate(&prompt, 4).unwrap();
    e2.shutdown().unwrap();
    assert_eq!(g1.tokens, g2.tokens, "segmented decode diverged");
}

/// Drive `steps` decode rounds over `prompts` on two engines — per-sequence
/// `decode_one` vs the fused lane — asserting bit-identical logits at every
/// round for every sequence.
fn assert_fused_decode_equivalence(prompts: &[Vec<i32>], steps: usize) {
    let b = prompts.len();
    let mut c = cfg(Strategy::Iso, 2);
    c.max_batch = b;
    c.decode_batch = b;

    let mut seq_eng = Engine::start(c.clone()).unwrap();
    let mut lane_eng = Engine::start(c).unwrap();

    // Prefill every sequence on both engines (same path on both).
    let mut seq_state = Vec::new(); // (slot, token, offset) on seq_eng
    let mut lane = Vec::new();
    for p in prompts {
        let slot_a = seq_eng.alloc_slot().unwrap();
        let a = seq_eng.step_decode(Some((slot_a, p)), &[]).unwrap().prefill.unwrap();
        let slot_b = lane_eng.alloc_slot().unwrap();
        let bout = lane_eng.step_decode(Some((slot_b, p)), &[]).unwrap().prefill.unwrap();
        assert_eq!(a.logits, bout.logits, "prefill logits diverged before decode");
        seq_state.push((slot_a, a.first_token, p.len()));
        lane.push(DecodeSlot { slot: slot_b, token: bout.first_token, offset: p.len() });
    }

    for round in 0..steps {
        let out = lane_eng.step_decode(None, &lane).unwrap();
        assert_eq!(out.decode_logits.len(), b);
        for j in 0..b {
            let (slot, token, offset) = seq_state[j];
            let logits = seq_eng.decode_one(slot, token, offset).unwrap();
            assert_eq!(
                logits, out.decode_logits[j],
                "round {round} seq {j}: fused lane logits != per-sequence decode"
            );
            seq_state[j] = (slot, out.decode_tokens[j], offset + 1);
            lane[j].token = out.decode_tokens[j];
            lane[j].offset += 1;
        }
    }
    let rep = lane_eng.shutdown().unwrap();
    // One fused collective per layer-stage per iteration, on every rank.
    assert!(
        rep.metrics.fused_allreduces > 0,
        "fused path never exercised the fused collective"
    );
    seq_eng.shutdown().unwrap();
}

#[test]
fn fused_decode_bit_identical_to_per_sequence() {
    // B=3: no compiled t=3 MLP stage, so the lane takes the per-row MLP
    // path while still fusing the collectives.
    if !have_artifacts() {
        return;
    }
    let prompts: Vec<Vec<i32>> = (0..3)
        .map(|s| (0..32).map(|i| ((i * 7 + s * 13) % 512) as i32).collect())
        .collect();
    assert_fused_decode_equivalence(&prompts, 4);
}

#[test]
fn fused_decode_gemm_path_bit_identical() {
    // B=16 matches a compiled chunk width, so the lane MLP runs as one
    // 16-row GEMM; the tiny prompts also exercise the short-prompt
    // single-lane ISO fallback end-to-end.
    if !have_artifacts() {
        return;
    }
    let prompts: Vec<Vec<i32>> = (0..16)
        .map(|s| (0..16).map(|i| ((i * 11 + s * 3) % 512) as i32).collect())
        .collect();
    assert_fused_decode_equivalence(&prompts, 2);
}

#[test]
fn mixed_trace_matches_sequential_tokens() {
    // The tentpole scheduling change must not change a single token:
    // the same trace served mixed and sequentially completes with
    // identical per-request token streams.
    if !have_artifacts() {
        return;
    }
    use iso::workload::{LenDist, TraceGen};
    let mut c = cfg(Strategy::Iso, 2);
    c.max_batch = 3;
    c.decode_batch = 2;
    c.mixed_iterations = true;
    let mut cs = c.clone();
    cs.mixed_iterations = false;

    let reqs = TraceGen::new(21, 512, LenDist::Uniform(20, 60))
        .decode_steps(4)
        .rate(100.0)
        .generate(6);

    let mut mixed = Engine::start(c).unwrap();
    let tm = mixed.serve_trace(&reqs).unwrap();
    mixed.shutdown().unwrap();
    let mut seq = Engine::start(cs).unwrap();
    let ts = seq.serve_trace(&reqs).unwrap();
    seq.shutdown().unwrap();

    assert_eq!(tm.completed, 6);
    assert_eq!(ts.completed, 6);
    let sort = |mut v: Vec<(u64, Vec<i32>)>| {
        v.sort_by_key(|(id, _)| *id);
        v
    };
    assert_eq!(
        sort(tm.completions.clone()),
        sort(ts.completions.clone()),
        "mixed scheduling changed emitted tokens"
    );
    // Mixed-iteration accounting is live.
    assert!(tm.iterations > 0);
    assert!(!tm.occupancy.is_empty());
    assert!(!tm.tbt_ms.is_empty());
    assert_eq!(tm.generated, 6 * 5); // first token + 4 decode steps each
}

#[test]
fn short_prompt_iso_prefill_matches_serial() {
    // Regression for the round_to_tiles panic: a prompt shorter than two
    // tiles prefills via the single-lane fallback and matches serial.
    if !have_artifacts() {
        return;
    }
    let prompt: Vec<i32> = (0..9).map(|i| (i * 29 % 512) as i32).collect();
    let mut iso = Engine::start(cfg(Strategy::Iso, 2)).unwrap();
    let a = iso.prefill(&prompt).unwrap();
    iso.shutdown().unwrap();
    let mut ser = Engine::start(cfg(Strategy::Serial, 2)).unwrap();
    let b = ser.prefill(&prompt).unwrap();
    ser.shutdown().unwrap();
    assert_eq!(a.logits, b.logits, "short-prompt fallback must equal serial");
}

#[test]
fn kill_rank_recovery_token_identical_across_shapes() {
    // The PR-6 acceptance criterion end-to-end: a seeded kill-rank fault
    // under every scheduler shape is detected, the mesh respawns, the
    // live sequences replay from their prompts (checkpoint-free), and
    // the served tokens are bit-identical to the fault-free run — with
    // zero dropped sequences.
    if !have_artifacts() {
        return;
    }
    use iso::workload::{LenDist, TraceGen};
    // (name, mixed iterations, spec_k, pp_stages)
    let shapes = [
        ("sequential", false, 0usize, 1usize),
        ("mixed", true, 0, 1),
        ("spec", true, 2, 1),
        ("pp2xtp2", true, 0, 2),
    ];
    for (name, mixed, spec_k, pp) in shapes {
        let reqs = TraceGen::new(17, 512, LenDist::Fixed(24)).decode_steps(6).generate(3);
        let mut base_cfg = cfg(Strategy::Iso, 2);
        base_cfg.mixed_iterations = mixed;
        base_cfg.spec_k = spec_k;
        base_cfg.pp_stages = pp;
        base_cfg.decode_batch = 2;

        let mut base = Engine::start(base_cfg.clone()).unwrap();
        let clean = base.serve_trace(&reqs).unwrap();
        let clean_rep = base.shutdown().unwrap();
        assert_eq!(clean.completed, 3, "{name}: fault-free run incomplete");
        assert_eq!(clean_rep.metrics.recoveries, 0, "{name}: fault-free run recovered");

        let mut c = base_cfg;
        c.fault_plan = Some("kill:rank=1:iter=3".into());
        let mut e = Engine::start(c).unwrap();
        let faulted = e.serve_trace(&reqs).unwrap();
        let rep = e.shutdown().unwrap();
        assert_eq!(faulted.completed, 3, "{name}: dropped sequences under fault");
        assert!(rep.metrics.faults_detected >= 1, "{name}: kill went undetected");
        assert!(rep.metrics.recoveries >= 1, "{name}: kill did not trigger recovery");
        assert!(!rep.metrics.recovery_ms.is_empty(), "{name}: recovery latency unrecorded");
        let sort = |mut v: Vec<(u64, Vec<i32>)>| {
            v.sort_by_key(|(id, _)| *id);
            v
        };
        assert_eq!(
            sort(clean.completions.clone()),
            sort(faulted.completions.clone()),
            "{name}: recovery changed served tokens"
        );
    }
}

#[test]
fn shutdown_after_fault_terminates() {
    // Shutdown-hang regression (PR-6 satellite): after a mid-trace kill
    // and recovery, both `shutdown` and `Drop` must terminate promptly —
    // the sender-drop cascade, not a blocking join on a dead rank.
    if !have_artifacts() {
        return;
    }
    use iso::workload::{LenDist, TraceGen};
    use std::time::{Duration, Instant};
    let reqs = TraceGen::new(29, 512, LenDist::Fixed(24)).decode_steps(4).generate(2);
    for explicit_shutdown in [true, false] {
        let mut c = cfg(Strategy::Iso, 2);
        c.decode_batch = 2;
        c.fault_plan = Some("kill:rank=0:iter=2".into());
        let mut e = Engine::start(c).unwrap();
        let trace = e.serve_trace(&reqs).unwrap();
        assert_eq!(trace.completed, 2);
        let clock = Instant::now();
        if explicit_shutdown {
            let rep = e.shutdown().unwrap();
            assert!(rep.metrics.recoveries >= 1);
        } else {
            drop(e); // Engine::drop must also terminate the mesh
        }
        assert!(
            clock.elapsed() < Duration::from_secs(30),
            "engine teardown hung after fault (explicit_shutdown={explicit_shutdown})"
        );
    }
}

#[test]
fn fault_free_paths_report_zero_recovery() {
    // Fault machinery off by default: no plan → the supervision layer is
    // pure bookkeeping, and every recovery counter reports zero.
    if !have_artifacts() {
        return;
    }
    let prompt: Vec<i32> = (0..48).map(|i| (i * 31 % 512) as i32).collect();
    let mut e = Engine::start(cfg(Strategy::Iso, 2)).unwrap();
    e.generate(&prompt, 3).unwrap();
    let rep = e.shutdown().unwrap();
    assert_eq!(rep.metrics.faults_detected, 0);
    assert_eq!(rep.metrics.recoveries, 0);
    assert_eq!(rep.metrics.replayed_seqs, 0);
    assert_eq!(rep.metrics.replayed_tokens, 0);
    assert!(rep.metrics.recovery_ms.is_empty());
}

#[test]
fn iso_overlap_is_real() {
    // The point of the paper: the comm stream's time must be (partially)
    // hidden behind compute under ISO, and visibly less hidden in serial.
    if !have_artifacts() {
        return;
    }
    let prompt: Vec<i32> = (0..128).map(|i| (i * 3 % 512) as i32).collect();

    let mut iso = Engine::start(cfg(Strategy::Iso, 2)).unwrap();
    for _ in 0..3 {
        iso.prefill(&prompt).unwrap();
    }
    let iso_rep = iso.shutdown().unwrap();

    let mut ser = Engine::start(cfg(Strategy::Serial, 2)).unwrap();
    for _ in 0..3 {
        ser.prefill(&prompt).unwrap();
    }
    let ser_rep = ser.shutdown().unwrap();

    let iso_eff: f64 = iso_rep.workers.iter().map(|w| w.overlap_efficiency()).sum::<f64>()
        / iso_rep.workers.len() as f64;
    let ser_eff: f64 = ser_rep.workers.iter().map(|w| w.overlap_efficiency()).sum::<f64>()
        / ser_rep.workers.len() as f64;
    eprintln!("overlap efficiency: iso={iso_eff:.3} serial={ser_eff:.3}");
    assert!(
        iso_eff > ser_eff,
        "ISO should hide more comm than serial: {iso_eff} vs {ser_eff}"
    );
}
