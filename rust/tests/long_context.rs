//! 3D-parallel (context × pipeline × tensor) engine tests (DESIGN.md §17).
//!
//! The load-bearing invariants:
//!   * ring context parallelism at the same per-group TP width is
//!     **bit-exact** — the KV prefix crosses the shard ring verbatim, so
//!     `cp=2,tp=2` logits equal `cp=1,tp=2` logits bit for bit;
//!   * `cp=2×tp=2` serving is **token-identical** to the flat `tp=4`
//!     baseline at equal world size across all three schedulers
//!     (sequential, mixed, speculative) — the PR-9 acceptance bar;
//!   * shard-ring accounting (`cp_shard_bytes`/`cp_shard_msgs`/
//!     `cp_stall_ms`) is live exactly when `cp > 1`, and only non-last
//!     groups send;
//!   * the config surface rejects `cp = 0` and the unsupported
//!     `cp > 1` + bounded-chunked-prefill combination with typed errors
//!     before any artifact is touched.
//!
//! The cold-KV offload twin (1M-token prompt completes under offload
//! where the resident-only pool fails typed) is pure Rust and lives in
//! `kv::tier_tests`; it runs unconditionally. Engine tests here require
//! `make artifacts` and skip (like the rest of the e2e suite) when the
//! artifacts are absent.

use iso::config::{CommQuant, EngineConfig, SplitPolicy, Strategy, Topology};
use iso::coordinator::Engine;
use iso::runtime::Manifest;
use iso::workload::{LenDist, TraceGen};

fn have_artifacts() -> bool {
    match Manifest::load("artifacts") {
        Ok(_) => true,
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e}");
            false
        }
    }
}

fn cfg(strategy: Strategy, cp: usize, pp: usize, tp: usize) -> EngineConfig {
    EngineConfig {
        strategy,
        split: SplitPolicy::Even,
        comm_quant: CommQuant::F32,
        gemm_segments: 1,
        tp,
        pp_stages: pp,
        cp,
        max_chunk: 64,
        max_batch: 4,
        artifacts_dir: "artifacts".into(),
        ..Default::default()
    }
}

#[test]
fn cp_rejects_invalid_configs_without_artifacts() {
    // Typed validation fires before the manifest loads, so these run
    // everywhere: the zero axis and the unsupported cp × bounded-prefill
    // combination must both fail to start.
    let err = Engine::start(cfg(Strategy::Iso, 0, 1, 1)).unwrap_err();
    assert!(err.to_string().contains("cp must be >= 1"), "got: {err}");
    let mut c = cfg(Strategy::Iso, 2, 1, 1);
    c.tbt_budget_ms = 5.0;
    let err = Engine::start(c).unwrap_err();
    assert!(err.to_string().contains("tbt_budget_ms requires cp = 1"), "got: {err}");
}

#[test]
fn topology_flag_spells_the_cp_grid() {
    // The canonical `--topology` spelling round-trips through the grid
    // the engine tests below exercise.
    let t: Topology = "pp1.tp2.cp2".parse().unwrap();
    assert_eq!((t.pp, t.tp, t.cp), (1, 2, 2));
    assert_eq!(t.world(), 4);
    assert_eq!(t.to_string(), "pp1.tp2.cp2");
    let c = cfg(Strategy::Iso, 2, 1, 2);
    assert_eq!(c.topology(), t);
}

#[test]
fn cp_prefill_bit_exact_vs_single_group() {
    // Same per-group TP width AND same chunk plan ⇒ identical layer
    // arithmetic; the shard ring moves f32 KV rows verbatim, so context
    // sharding must not change a single bit of the logits. The 96-token
    // prompt tiles identically for both engines (ISO: 4 chunks, serial:
    // 2 — both ≥ the cp=2 micro-batch floor), so group 1 computes the
    // back half on a streamed-in prefix that is byte-equal to what the
    // flat engine computed in place.
    if !have_artifacts() {
        return;
    }
    let prompt: Vec<i32> = (0..96).map(|i| (i * 19 % 512) as i32).collect();
    for strategy in [Strategy::Iso, Strategy::Serial] {
        let mut flat = Engine::start(cfg(strategy, 1, 1, 2)).unwrap();
        let a = flat.prefill(&prompt).unwrap();
        flat.shutdown().unwrap();
        let mut ring = Engine::start(cfg(strategy, 2, 1, 2)).unwrap();
        let b = ring.prefill(&prompt).unwrap();
        ring.shutdown().unwrap();
        assert_eq!(a.logits, b.logits, "{strategy:?}: context sharding changed the bits");
        assert_eq!(a.first_token, b.first_token);
    }
}

#[test]
fn cp_composes_with_pipeline_stages() {
    // The full 3D grid: cp=2 × pp=2 × tp=1 against the flat tp=1
    // baseline. The deeper grid re-tiles the prompt finer (micro-batch
    // floor = pipeline depth × cp), which changes kernel shapes but —
    // like the pp4 case — must not change the greedy outcome.
    if !have_artifacts() {
        return;
    }
    let prompt: Vec<i32> = (0..96).map(|i| (i * 19 % 512) as i32).collect();
    let mut flat = Engine::start(cfg(Strategy::Iso, 1, 1, 1)).unwrap();
    let a = flat.prefill(&prompt).unwrap();
    flat.shutdown().unwrap();
    let mut grid = Engine::start(cfg(Strategy::Iso, 2, 2, 1)).unwrap();
    let b = grid.prefill(&prompt).unwrap();
    grid.shutdown().unwrap();
    assert_eq!(a.first_token, b.first_token, "3D grid changed the token");
}

#[test]
fn cp_generate_matches_single_group_tokens() {
    // Decode is not sequence-parallel (DESIGN.md §17): the last group
    // holds the full prefix after prefill and serves every decode step,
    // so tokens must match the flat engine exactly.
    if !have_artifacts() {
        return;
    }
    let prompt: Vec<i32> = (0..32).map(|i| (i * 13 % 512) as i32).collect();
    let mut flat = Engine::start(cfg(Strategy::Iso, 1, 1, 2)).unwrap();
    let a = flat.generate(&prompt, 4).unwrap();
    flat.shutdown().unwrap();
    let mut ring = Engine::start(cfg(Strategy::Iso, 2, 1, 2)).unwrap();
    let b = ring.generate(&prompt, 4).unwrap();
    ring.shutdown().unwrap();
    assert_eq!(a.tokens, b.tokens, "context-parallel decode diverged from flat");
}

/// Serve one paced trace on two engine configs and assert identical
/// per-request token streams.
fn assert_token_identical_serving(mut a: EngineConfig, mut b: EngineConfig, seed: u64) {
    a.max_batch = 3;
    b.max_batch = 3;
    let reqs = TraceGen::new(seed, 512, LenDist::Uniform(20, 60))
        .decode_steps(4)
        .rate(100.0)
        .generate(5);
    let mut ea = Engine::start(a).unwrap();
    let ta = ea.serve_trace(&reqs).unwrap();
    ea.shutdown().unwrap();
    let mut eb = Engine::start(b).unwrap();
    let tb = eb.serve_trace(&reqs).unwrap();
    eb.shutdown().unwrap();
    assert_eq!(ta.completed, 5);
    assert_eq!(tb.completed, 5);
    let sort = |mut v: Vec<(u64, Vec<i32>)>| {
        v.sort_by_key(|(id, _)| *id);
        v
    };
    assert_eq!(
        sort(ta.completions),
        sort(tb.completions),
        "context parallelism changed emitted tokens"
    );
}

#[test]
fn cp2_tp2_tokens_match_tp4_sequential_scheduler() {
    // PR-9 acceptance: cp=2×tp=2 serves token-identical streams to the
    // flat tp=4 baseline at equal world size — legacy sequential loop.
    if !have_artifacts() {
        return;
    }
    let mut a = cfg(Strategy::Iso, 2, 1, 2);
    let mut b = cfg(Strategy::Iso, 1, 1, 4);
    a.mixed_iterations = false;
    b.mixed_iterations = false;
    assert_token_identical_serving(a, b, 41);
}

#[test]
fn cp2_tp2_tokens_match_tp4_mixed_scheduler() {
    // Same bar under the iteration-level mixed scheduler: non-last
    // groups run their prefill slice, the last group carries the fused
    // decode lane.
    if !have_artifacts() {
        return;
    }
    let mut a = cfg(Strategy::Iso, 2, 1, 2);
    let mut b = cfg(Strategy::Iso, 1, 1, 4);
    a.decode_batch = 2;
    b.decode_batch = 2;
    assert_token_identical_serving(a, b, 43);
}

#[test]
fn cp2_tp2_tokens_match_tp4_spec_scheduler() {
    // Same bar with speculative verify lanes (decode stays gathered on
    // the last group; greedy acceptance keeps the stream identical).
    if !have_artifacts() {
        return;
    }
    let mut a = cfg(Strategy::Iso, 2, 1, 2);
    let mut b = cfg(Strategy::Iso, 1, 1, 4);
    for c in [&mut a, &mut b] {
        c.decode_batch = 2;
        c.spec_k = 2;
    }
    assert_token_identical_serving(a, b, 45);
}

#[test]
fn cp_engine_reports_shard_metrics() {
    if !have_artifacts() {
        return;
    }
    let prompt: Vec<i32> = (0..64).map(|i| (i * 7 % 512) as i32).collect();
    let mut e = Engine::start(cfg(Strategy::Iso, 2, 1, 1)).unwrap();
    e.prefill(&prompt).unwrap();
    let report = e.shutdown().unwrap();
    assert_eq!((report.pp_stages, report.tp, report.cp), (1, 1, 2));
    let m = &report.metrics;
    assert!(m.cp_shard_msgs > 0, "shard ring ran but no messages recorded");
    assert!(m.cp_shard_bytes > 0);
    // Only non-last groups forward KV along the ring (world layout:
    // rank 0 = group 0, rank 1 = group 1).
    assert!(report.workers[0].cp_shard_bytes > 0);
    assert_eq!(report.workers[1].cp_shard_bytes, 0, "last group must not forward");
    assert!(report.workers[1].cp_stall_ms >= 0.0);
    // The opt-in report block surfaces the counters.
    let text = report.metrics.report();
    assert!(text.contains("cp_shard_bytes="), "report must carry cp counters");
}

#[test]
fn cp1_reports_no_shard_metrics() {
    // cp = 1 must look exactly like the pre-CP engine: zero shard
    // traffic and no cp lines in the rendered report.
    if !have_artifacts() {
        return;
    }
    let prompt: Vec<i32> = (0..32).map(|i| (i * 3 % 512) as i32).collect();
    let mut e = Engine::start(cfg(Strategy::Iso, 1, 1, 2)).unwrap();
    e.prefill(&prompt).unwrap();
    let report = e.shutdown().unwrap();
    assert_eq!(report.metrics.cp_shard_msgs, 0);
    assert_eq!(report.metrics.cp_shard_bytes, 0);
    assert!(!report.metrics.report().contains("cp_shard_bytes="));
}
