//! End-to-end speculative decoding (DESIGN.md §10): the verify lane over
//! real AOT stages and ring collectives must be row-for-row bit-identical
//! to single-token decode, and the spec serving path must emit exactly
//! the greedy baseline's tokens at every k.
//!
//! Requires `make artifacts`; every test self-skips without them.

use iso::batch::{DraftProposer, NGramProposer, SpecSlot};
use iso::config::{CommQuant, EngineConfig, SplitPolicy, Strategy};
use iso::coordinator::Engine;
use iso::runtime::Manifest;
use iso::workload::{LenDist, TraceGen};

fn have_artifacts() -> bool {
    match Manifest::load("artifacts") {
        Ok(_) => true,
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e}");
            false
        }
    }
}

fn cfg(strategy: Strategy, tp: usize) -> EngineConfig {
    EngineConfig {
        strategy,
        split: SplitPolicy::Even,
        comm_quant: CommQuant::F32,
        gemm_segments: 1,
        tp,
        max_chunk: 64,
        max_batch: 4,
        artifacts_dir: "artifacts".into(),
        ..Default::default()
    }
}

#[test]
fn verify_rows_bit_identical_to_decode_chain() {
    // The invariant the whole subsystem rests on: row j of a verify
    // window equals a single-token decode of the same token at the same
    // offset, given identical KV history — drafts included, accepted or
    // not.
    if !have_artifacts() {
        return;
    }
    let prompt: Vec<i32> = (0..32).map(|i| (i * 13 % 512) as i32).collect();

    let mut spec_eng = Engine::start(cfg(Strategy::Iso, 2)).unwrap();
    let mut chain_eng = Engine::start(cfg(Strategy::Iso, 2)).unwrap();

    let slot_s = spec_eng.alloc_slot().unwrap();
    let a = spec_eng.step_decode(Some((slot_s, &prompt)), &[]).unwrap().prefill.unwrap();
    let slot_c = chain_eng.alloc_slot().unwrap();
    let b = chain_eng.step_decode(Some((slot_c, &prompt)), &[]).unwrap().prefill.unwrap();
    assert_eq!(a.logits, b.logits, "prefill diverged before any speculation");

    // Window: last emitted token + 3 arbitrary drafts (almost certainly
    // rejected) — the rows must match the chain fed the same tokens,
    // whatever the acceptance turns out to be.
    let tokens = vec![a.first_token, 7, 8, 9];
    let offset = prompt.len();
    let window = SpecSlot { slot: slot_s, tokens: tokens.clone(), offset };
    let out = spec_eng.step_spec(None, std::slice::from_ref(&window)).unwrap();
    assert_eq!(out.row_logits.len(), 1);
    assert_eq!(out.row_logits[0].len(), tokens.len());
    for (j, &tok) in tokens.iter().enumerate() {
        let chain = chain_eng.decode_one(slot_c, tok, offset + j).unwrap();
        assert_eq!(
            out.row_logits[0][j], chain,
            "row {j}: verify lane logits != single-token decode"
        );
    }
    // Acceptance bookkeeping is internally consistent: emits the greedy
    // rows up to and including the first rejection.
    let acc = out.accepted[0];
    assert_eq!(out.emitted[0].len(), acc + 1);
    assert_eq!(out.emitted[0], out.row_tokens[0][..acc + 1].to_vec());

    // Second window from the post-rollback state: stale KV beyond the
    // accepted prefix must be invisible. The chain engine's KV matches by
    // construction (it was fed the identical window tokens above), so
    // one more decode on both sides must agree bit-for-bit.
    let take = out.emitted[0].len();
    let off2 = offset + take;
    let tok1 = *out.emitted[0].last().unwrap();
    let c1 = chain_eng.decode_one(slot_c, tok1, off2).unwrap();
    let w2 = SpecSlot { slot: slot_s, tokens: vec![tok1], offset: off2 };
    let out2 = spec_eng.step_spec(None, &[w2]).unwrap();
    assert_eq!(
        out2.row_logits[0][0], c1,
        "post-rollback verify row reads stale rejected KV"
    );

    let rep = spec_eng.shutdown().unwrap();
    assert!(rep.metrics.spec_windows >= 2);
    assert!(rep.metrics.spec_drafted >= 3);
    assert!(rep.workers.iter().all(|w| w.fused_rows >= w.fused_allreduces));
    chain_eng.shutdown().unwrap();
}

#[test]
fn accepted_drafts_fast_forward_the_sequence() {
    // Feed the model its own greedy continuation as drafts: everything
    // must be accepted and the window emits k+1 tokens identical to the
    // one-at-a-time chain.
    if !have_artifacts() {
        return;
    }
    let prompt: Vec<i32> = (0..48).map(|i| (i * 7 % 512) as i32).collect();

    // Reference greedy chain.
    let mut chain_eng = Engine::start(cfg(Strategy::Iso, 2)).unwrap();
    let g = chain_eng.generate(&prompt, 4).unwrap();
    chain_eng.shutdown().unwrap();
    assert_eq!(g.tokens.len(), 5);

    let mut eng = Engine::start(cfg(Strategy::Iso, 2)).unwrap();
    let slot = eng.alloc_slot().unwrap();
    let pre = eng.step_decode(Some((slot, &prompt)), &[]).unwrap().prefill.unwrap();
    assert_eq!(pre.first_token, g.tokens[0]);
    // Window = first token + the chain's next 3 tokens as drafts.
    let window = SpecSlot {
        slot,
        tokens: vec![g.tokens[0], g.tokens[1], g.tokens[2], g.tokens[3]],
        offset: prompt.len(),
    };
    let out = eng.step_spec(None, &[window]).unwrap();
    assert_eq!(out.accepted, vec![3], "perfect drafts must all be accepted");
    assert_eq!(out.emitted[0], &g.tokens[1..5], "fast-forward must emit the chain");
    let rep = eng.shutdown().unwrap();
    assert_eq!(rep.metrics.spec_accepted, 3);
    assert_eq!(rep.metrics.generated_tokens, 1 + 4);
}

#[test]
fn spec_trace_tokens_identical_to_baseline_all_k() {
    // The acceptance gate: serve one trace sequentially, mixed without
    // speculation, and mixed with spec_k ∈ {1, 3} — four schedulers, one
    // token stream.
    if !have_artifacts() {
        return;
    }
    let reqs = TraceGen::new(21, 512, LenDist::Uniform(20, 60))
        .decode_steps(6)
        .rate(100.0)
        .generate(6);

    let run = |mixed: bool, spec_k: usize| {
        let mut c = cfg(Strategy::Iso, 2);
        c.max_batch = 3;
        c.decode_batch = 2;
        c.mixed_iterations = mixed;
        c.spec_k = spec_k;
        let mut e = Engine::start(c).unwrap();
        let t = e.serve_trace(&reqs).unwrap();
        let rep = e.shutdown().unwrap();
        let mut done = t.completions.clone();
        done.sort_by_key(|(id, _)| *id);
        (done, t, rep)
    };

    let (base, bt, _) = run(false, 0);
    assert_eq!(bt.completed, 6);
    let (mixed, ..) = run(true, 0);
    assert_eq!(mixed, base, "mixed scheduling changed tokens");
    for k in [1usize, 3] {
        let (spec, st, rep) = run(true, k);
        assert_eq!(spec, base, "spec_k={k} changed emitted tokens");
        assert_eq!(st.completed, 6);
        // Speculation really ran: windows executed, drafts proposed, and
        // the engine produced the same tokens in no more iterations than
        // the non-speculative mixed run needed decode tokens.
        assert!(rep.metrics.spec_windows > 0, "k={k}: no verify windows ran");
        assert!(rep.metrics.spec_drafted > 0, "k={k}: nothing drafted");
        assert_eq!(
            rep.metrics.spec_accept_hist.len() as u64,
            rep.metrics.spec_windows
        );
        assert!(rep.metrics.acceptance_rate() >= 0.0);
        // Queue/saturation satellite wiring is live in the spec path too.
        assert!(!rep.metrics.queue_depth.is_empty());
    }
}

#[test]
fn spec_serving_respects_budget_and_max_seq() {
    // A near-max_seq prompt with a big decode ask: the planner must clamp
    // verify windows at the KV boundary and the decode budget, and still
    // match the sequential engine's output.
    if !have_artifacts() {
        return;
    }
    use iso::workload::Request;
    let reqs = vec![
        Request { id: 0, arrival_s: 0.0, prompt: vec![1; 240], decode_steps: 50 },
        Request { id: 1, arrival_s: 0.0, prompt: vec![2; 24], decode_steps: 9 },
    ];
    let run = |mixed: bool, spec_k: usize| {
        let mut c = cfg(Strategy::Iso, 2);
        c.mixed_iterations = mixed;
        c.spec_k = spec_k;
        let mut e = Engine::start(c).unwrap();
        let t = e.serve_trace(&reqs).unwrap();
        e.shutdown().unwrap();
        let mut done = t.completions.clone();
        done.sort_by_key(|(id, _)| *id);
        done
    };
    let base = run(false, 0);
    let spec = run(true, 4);
    assert_eq!(spec, base, "clamped spec serving diverged from baseline");
    // Request 1's budget (9 decode tokens) must be exact, not overshot by
    // a wide window.
    assert_eq!(spec[1].1.len(), 10); // first token + 9 decodes
}

#[test]
fn step_spec_validates_windows() {
    if !have_artifacts() {
        return;
    }
    let mut e = Engine::start(cfg(Strategy::Iso, 2)).unwrap();
    let slot = e.alloc_slot().unwrap();
    let prompt: Vec<i32> = (0..16).map(|i| i as i32).collect();
    e.step_decode(Some((slot, &prompt)), &[]).unwrap();
    // Empty window.
    let bad = SpecSlot { slot, tokens: vec![], offset: 16 };
    assert!(e.step_spec(None, &[bad]).is_err());
    // Window past max_seq (max_seq = 256).
    let bad = SpecSlot { slot, tokens: vec![1; 8], offset: 250 };
    assert!(e.step_spec(None, &[bad]).is_err());
    // Duplicate slot.
    let w = SpecSlot { slot, tokens: vec![1], offset: 16 };
    assert!(e.step_spec(None, &[w.clone(), w.clone()]).is_err());
    // Engine still serves after rejections.
    let ok = e.step_spec(None, &[w]).unwrap();
    assert_eq!(ok.emitted.len(), 1);
    e.shutdown().unwrap();
}

#[test]
fn ngram_proposer_drafts_stay_in_vocab_under_serving() {
    // The built-in self-draft proposer can only emit history tokens, so
    // no draft can index outside the embedding table. Exercise it the
    // way serve_trace does.
    let mut p = NGramProposer::new(2);
    let history: Vec<i32> = (0..200).map(|i| (i * 31 % 512) as i32).collect();
    for k in 0..8 {
        let d = p.propose(&history, k);
        assert_eq!(d.len(), k);
        assert!(d.iter().all(|&t| (0..512).contains(&t)));
    }
}
