//! AUTO-TUNE — the PR-10 rank-agreement harness (DESIGN.md §18). Pins
//! the calibrate → plan → verify loop at four levels, all pure rust (no
//! model artifacts needed for the tier-1 set):
//!
//! * **rank agreement** — Kendall τ between the planner's predicted
//!   ordering and the discrete-event sim-measured ordering over the
//!   top-5 configs stays ≥ 0.8 for every GPU preset × workload mix;
//! * **never worse than hand-tuned** — the planner's #1 pick never
//!   *measures* worse than the TUNING.md hand-tuned default on the
//!   mixes where the flat and factored cost models are commensurable
//!   (the one documented exception is recorded in EXPERIMENTS.md, not
//!   silently excluded here);
//! * **planner totality** — every ranked [`EngineConfig`] validates,
//!   plans are deterministic and sorted, and seeded fuzz over
//!   degenerate profiles (zero-bandwidth links, one-card nodes, zero
//!   peak FLOPS) never panics and never ranks a NaN;
//! * **calibration** — the analytic probe round-trips the hand-coded
//!   preset constants, and the `--profile-cache` file round-trips
//!   bit-exactly through disk.
//!
//! The engine-measured variant at the bottom is artifact-gated
//! (`make artifacts`) and self-skips in CI, like `engine_e2e.rs`.

use iso::coordinator::Engine;
use iso::hw::NodeProfile;
use iso::model::ModelSpec;
use iso::runtime::Manifest;
use iso::tune::{
    calibrate, hand_tuned_default, kendall_tau, plan, sim_measured_request_s, AnalyticProbe,
    MeasuredProfile, Workload,
};
use iso::util::prop::Prop;

/// The two GPU presets the paper calibrates (comm-dominated 4090,
/// compute-dominated A800), at the 4-card ring both sweeps use.
fn gpu_profiles() -> Vec<(&'static str, NodeProfile)> {
    vec![("4090-4", NodeProfile::rtx4090(4)), ("a800-4", NodeProfile::a800(4))]
}

fn workloads() -> Vec<Workload> {
    vec![Workload::prefill_heavy(), Workload::mixed(), Workload::decode_heavy()]
}

// ----------------------------------------------------------- agreement --

/// The headline pin: over the top-5 ranked configs of every profile ×
/// workload cell, the predicted ordering and the sim-measured ordering
/// (event-sim mixed iteration + epilogue exposure for flat topologies,
/// wavefront models for pp/cp) agree at Kendall τ ≥ 0.8.
#[test]
fn predicted_vs_sim_measured_rank_agreement_top5() {
    let model = ModelSpec::mha_30b();
    for (tag, node) in gpu_profiles() {
        for w in workloads() {
            let p = plan(&node, &model, &w);
            assert!(p.ranked.len() >= 5, "{tag} × {}: only {} candidates", w.name, p.ranked.len());
            let top = &p.ranked[..5];
            let pred: Vec<f64> = top.iter().map(|pc| pc.predicted_s).collect();
            let meas: Vec<f64> =
                top.iter().map(|pc| sim_measured_request_s(&node, &model, &w, &pc.cfg)).collect();
            for (pc, &m) in top.iter().zip(&meas) {
                assert!(
                    m.is_finite() && m > 0.0,
                    "{tag} × {}: {} measured {m}",
                    w.name,
                    pc.summary
                );
            }
            let tau = kendall_tau(&pred, &meas);
            eprintln!(
                "{tag} × {:<13}: tau {tau:+.3} over top-5 (#1 {} pred {:.2} ms meas {:.2} ms)",
                w.name,
                top[0].summary,
                pred[0] * 1e3,
                meas[0] * 1e3
            );
            assert!(tau >= 0.8, "{tag} × {}: kendall tau {tau:.3} < 0.8", w.name);
        }
    }
}

/// The planner's #1 pick never sim-measures worse than the hand-tuned
/// TUNING.md default (flat TP over every card, seg 1, lane 8, no spec,
/// profile-default wire rung). Pinned on every cell where the winner and
/// the baseline run through commensurable measurement models. The one
/// exception — 4090 × prefill-heavy, where the blocking flat closed form
/// overestimates the flat path so the planner prefers cp4 which then
/// event-sim-measures ~10% behind flat — is a documented cost-model
/// bias (EXPERIMENTS.md, PR-10), not silently skipped.
#[test]
fn planner_winner_never_measures_worse_than_hand_tuned() {
    let model = ModelSpec::mha_30b();
    let cells: Vec<(&str, NodeProfile, Vec<Workload>)> = vec![
        (
            "4090-4",
            NodeProfile::rtx4090(4),
            vec![Workload::mixed(), Workload::decode_heavy()],
        ),
        ("a800-4", NodeProfile::a800(4), workloads()),
    ];
    for (tag, node, ws) in cells {
        for w in ws {
            let p = plan(&node, &model, &w);
            let best = p.best().expect("ranked plan is non-empty");
            let best_meas = sim_measured_request_s(&node, &model, &w, &best.cfg);
            let ht = hand_tuned_default(&node, &w);
            let ht_meas = sim_measured_request_s(&node, &model, &w, &ht);
            eprintln!(
                "{tag} × {:<13}: #1 {} measures {:.2} ms, hand-tuned {:.2} ms",
                w.name,
                best.summary,
                best_meas * 1e3,
                ht_meas * 1e3
            );
            assert!(
                best_meas <= ht_meas * (1.0 + 1e-12),
                "{tag} × {}: planner #1 ({}) measures {:.3} ms, worse than the hand-tuned \
                 default's {:.3} ms",
                w.name,
                best.summary,
                best_meas * 1e3,
                ht_meas * 1e3
            );
        }
    }
}

// ------------------------------------------------------------ totality --

/// Every ranked config validates, plans are deterministic (bit-equal
/// predictions on a re-run), the ranking is monotone non-decreasing,
/// and nothing scored goes missing between `evaluated` and `ranked`.
#[test]
fn plans_validate_deterministically_and_stay_sorted() {
    let mut cells: Vec<(NodeProfile, ModelSpec, Workload)> = Vec::new();
    for (_, node) in gpu_profiles() {
        for w in workloads() {
            cells.push((node.clone(), ModelSpec::mha_30b(), w));
        }
    }
    cells.push((
        NodeProfile::cpu_engine(2, Some(64.0), 120.0),
        ModelSpec::tiny_gqa(),
        Workload { prompt_len: 64, decode_steps: 16, decode_ctx: 64, ..Workload::mixed() },
    ));
    for (node, model, w) in cells {
        let a = plan(&node, &model, &w);
        let b = plan(&node, &model, &w);
        assert_eq!(a.ranked.len(), a.evaluated, "{} × {}: scored configs went missing",
            node.device.name, w.name);
        assert!(a.best().is_some());
        assert_eq!(a.ranked.len(), b.ranked.len());
        for (x, y) in a.ranked.iter().zip(&b.ranked) {
            assert_eq!(x.summary, y.summary, "plan order changed between runs");
            assert_eq!(
                x.predicted_s.to_bits(),
                y.predicted_s.to_bits(),
                "{}: prediction changed between runs",
                x.summary
            );
        }
        for pair in a.ranked.windows(2) {
            assert!(
                pair[0].predicted_s.total_cmp(&pair[1].predicted_s).is_le(),
                "{} ranked above {} despite a worse prediction",
                pair[0].summary,
                pair[1].summary
            );
        }
        for pc in &a.ranked {
            pc.cfg.validate().unwrap_or_else(|e| {
                panic!("{} × {}: ranked config {} fails validation: {e}",
                    node.device.name, w.name, pc.summary)
            });
        }
    }
}

/// Degenerate profiles must plan totally, not panic: a zero-bandwidth
/// link (every collective probe is infinite — calibration records the
/// degeneracy as a `(0, 0)` link) and a one-card node (no collectives
/// at all).
#[test]
fn degenerate_profiles_plan_totally() {
    let model = ModelSpec::tiny_gqa();
    let w = Workload { prompt_len: 64, decode_steps: 16, decode_ctx: 64, ..Workload::mixed() };

    let zero_bw = NodeProfile::cpu_engine(2, Some(0.0), 120.0);
    let m = calibrate(&AnalyticProbe::new(zero_bw));
    assert_eq!(m.node.link.link_bytes_per_s, 0.0, "degenerate link must calibrate to zero");
    assert_eq!(m.node.link.alpha_s, 0.0);
    let p = plan(&m.node, &model, &w);
    assert!(!p.ranked.is_empty());
    for pc in &p.ranked {
        assert!(!pc.predicted_s.is_nan(), "{}: NaN prediction on a zero-bandwidth link",
            pc.summary);
    }
    for pair in p.ranked.windows(2) {
        assert!(pair[0].predicted_s.total_cmp(&pair[1].predicted_s).is_le());
    }

    let one_card = NodeProfile::cpu_engine(1, None, 120.0);
    let p1 = plan(&one_card, &model, &w);
    assert!(p1.best().is_some(), "a one-card node must still rank the trivial topology");
    assert!(p1.ranked.iter().all(|pc| pc.cfg.topology().world() == 1));
    for pc in &p1.ranked {
        assert!(pc.predicted_s.is_finite(), "{}: one-card prediction not finite", pc.summary);
    }
}

/// Seeded fuzz over random (often degenerate) profiles and workloads:
/// `plan` never panics, never ranks a NaN, keeps the ranking sorted,
/// and every surviving config validates.
#[test]
fn fuzz_random_profiles_never_panic_and_stay_ranked() {
    let model = ModelSpec::tiny_gqa();
    Prop::new(0x7A11_5EED).cases(24).run("plan over random profiles", |rng| {
        let cards = rng.range(1, 5);
        let mut node = NodeProfile::cpu_engine(cards, None, 50.0);
        node.device.peak_flops = if rng.range(0, 4) == 0 { 0.0 } else { rng.f64() * 1.0e13 };
        node.device.m_half = rng.f64() * 256.0;
        node.device.launch_s = rng.f64() * 1e-4;
        node.link.link_bytes_per_s = if rng.range(0, 4) == 0 { 0.0 } else { rng.f64() * 2.0e10 };
        node.link.alpha_s = rng.f64() * 1e-4;
        node.int8_wire_default = rng.range(0, 2) == 1;
        let w = Workload {
            prompt_len: rng.range(2, 512),
            decode_steps: if rng.range(0, 2) == 0 { 0 } else { rng.range(1, 64) },
            decode_ctx: rng.range(1, 2048),
            accept: rng.f64(),
            ..Workload::mixed()
        };
        let p = plan(&node, &model, &w);
        if p.ranked.len() != p.evaluated {
            return Err(format!("{} ranked vs {} evaluated", p.ranked.len(), p.evaluated));
        }
        for pair in p.ranked.windows(2) {
            if pair[0].predicted_s.total_cmp(&pair[1].predicted_s).is_gt() {
                return Err(format!(
                    "{} ({}) ranked above {} ({})",
                    pair[0].summary, pair[0].predicted_s, pair[1].summary, pair[1].predicted_s
                ));
            }
        }
        for pc in &p.ranked {
            if pc.predicted_s.is_nan() {
                return Err(format!("NaN prediction for {}", pc.summary));
            }
            pc.cfg.validate().map_err(|e| format!("{}: {e}", pc.summary))?;
        }
        Ok(())
    });
}

// --------------------------------------------------------- calibration --

/// Calibration through the analytic probe recovers the hand-coded
/// preset constants (the cpu-engine testbed included), and the
/// `--profile-cache` file round-trips bit-exactly: calibrate+write,
/// then read back, are the same profile.
#[test]
fn calibration_recovers_presets_and_cache_round_trips() {
    let presets = [
        ("4090-4", NodeProfile::rtx4090(4)),
        ("a800-4", NodeProfile::a800(4)),
        ("cpu-2", NodeProfile::cpu_engine(2, Some(64.0), 120.0)),
    ];
    for (tag, node) in presets {
        let probe = AnalyticProbe::new(node.clone());
        let fresh = calibrate(&probe);
        let close = |got: f64, want: f64| (got - want).abs() <= 1e-6 * want.abs().max(1e-12);
        assert!(close(fresh.node.device.peak_flops, node.device.peak_flops), "{tag} peak");
        assert!(close(fresh.node.device.launch_s, node.device.launch_s), "{tag} launch");
        assert!(close(fresh.node.link.alpha_s, node.link.alpha_s), "{tag} alpha");
        assert!(
            close(fresh.node.link.link_bytes_per_s, node.link.link_bytes_per_s),
            "{tag} bandwidth"
        );
        assert!(fresh.fit_err < 1e-9, "{tag}: fit_err {}", fresh.fit_err);

        let path = std::env::temp_dir()
            .join(format!("iso_tune_cache_{tag}_{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let (first, from_cache) =
            MeasuredProfile::load_or_calibrate(&path, &probe).expect("calibrate and write");
        assert!(!from_cache, "{tag}: first load must calibrate (no cache file yet)");
        assert_eq!(first, fresh, "{tag}: cached calibration differs from a direct one");
        let (second, from_cache) =
            MeasuredProfile::load_or_calibrate(&path, &probe).expect("read the cache back");
        assert!(from_cache, "{tag}: second load must hit the cache");
        assert_eq!(second, first, "{tag}: disk round-trip changed the profile");
        std::fs::remove_file(&path).expect("cleanup");
    }
}

/// Closing the loop: planning on a *calibrated* profile picks the same
/// winner (at the same predicted time, to fit tolerance) as planning on
/// the preset it was calibrated from — the planner is probe-driven, not
/// preset-driven.
#[test]
fn plan_on_calibrated_profile_matches_preset_plan() {
    let model = ModelSpec::mha_30b();
    let w = Workload::mixed();
    for (tag, node) in gpu_profiles() {
        let m = calibrate(&AnalyticProbe::new(node.clone()));
        let preset_best = plan(&node, &model, &w);
        let fitted_best = plan(&m.node, &model, &w);
        let pb = preset_best.best().unwrap();
        let fb = fitted_best.best().unwrap();
        assert_eq!(pb.summary, fb.summary, "{tag}: calibrated plan picked a different winner");
        let rel = (pb.predicted_s - fb.predicted_s).abs() / pb.predicted_s;
        assert!(rel < 1e-3, "{tag}: calibrated prediction drifted {rel:.2e}");
    }
}

// -------------------------------------------- engine-measured (gated) --

fn have_artifacts() -> bool {
    match Manifest::load("artifacts") {
        Ok(_) => true,
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e}");
            false
        }
    }
}

/// Artifact-gated engine variant of the agreement harness: host the
/// planner's top flat tp=2 candidates on the real engine (tiny model,
/// real ring collectives at the planned wire rung / segment count /
/// epilogue fusion) and report predicted-vs-wall-clock rank agreement.
/// Wall time on shared CI runners is noisy, so the hard pins here are
/// completion and sanity (finite positive wall, τ well-formed); the τ
/// value itself is reported for the bench trail rather than gated.
#[test]
fn engine_measured_rank_agreement_artifact_gated() {
    if !have_artifacts() {
        return;
    }
    let node = NodeProfile::cpu_engine(2, Some(64.0), 120.0);
    let model = ModelSpec::tiny_gqa();
    let w = Workload { prompt_len: 96, decode_steps: 0, decode_ctx: 96, ..Workload::prefill_heavy() };
    let p = plan(&node, &model, &w);
    let flat: Vec<_> = p
        .ranked
        .iter()
        .filter(|pc| {
            let t = pc.cfg.topology();
            t.pp == 1 && t.cp == 1 && t.tp == 2
        })
        .take(3)
        .collect();
    assert!(flat.len() >= 2, "need at least two engine-hostable flat candidates");
    let prompt: Vec<i32> = (0..96).map(|i| (i * 37 % 512) as i32).collect();
    let (mut pred, mut meas) = (Vec::new(), Vec::new());
    for pc in &flat {
        let mut c = pc.cfg.clone();
        c.artifacts_dir = "artifacts".into();
        let mut e = Engine::start(c).expect("engine start");
        e.prefill(&prompt).expect("warmup prefill");
        let clock = std::time::Instant::now();
        for _ in 0..3 {
            e.prefill(&prompt).expect("measured prefill");
        }
        let wall = clock.elapsed().as_secs_f64() / 3.0;
        e.shutdown().expect("shutdown");
        assert!(wall.is_finite() && wall > 0.0, "{}: bad wall time {wall}", pc.summary);
        eprintln!(
            "engine-measured {}: predicted {:.3} ms wall {:.3} ms",
            pc.summary,
            pc.predicted_s * 1e3,
            wall * 1e3
        );
        pred.push(pc.predicted_s);
        meas.push(wall);
    }
    let tau = kendall_tau(&pred, &meas);
    eprintln!("engine-measured rank agreement over {} candidates: tau {tau:+.3}", flat.len());
    assert!((-1.0..=1.0).contains(&tau), "tau out of range: {tau}");
}
