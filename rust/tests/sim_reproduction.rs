//! Simulator-level reproduction checks: the *shape* of every paper claim.
//!
//! These encode Table 1 and the §4.2 findings as assertions, so a
//! calibration regression that flips a paper conclusion fails CI.

use iso::config::{SimExperiment, SplitPolicy, Strategy};
use iso::hw::NodeProfile;
use iso::model::ModelSpec;
use iso::report::{table1, table1_lens};
use iso::sched::{prefill_s, reduction_vs_serial};
use iso::split::choose_split;

fn exp(gpu: &str, cards: usize, model: &str, len: usize, strategy: Strategy) -> SimExperiment {
    let mut e = SimExperiment::new(
        NodeProfile::by_name(gpu, cards).unwrap(),
        ModelSpec::by_name(model).unwrap(),
        len,
        strategy,
    );
    e.gemm_segments = if gpu == "a800" { 4 } else { 1 };
    e
}

#[test]
fn table1_iso_always_wins_at_4k_plus() {
    // Paper: "our main focus is on prompt lengths that exceed 4k" — every
    // populated >=4k cell in Table 1 is positive.
    for (gpu, cards) in [("4090", 4), ("4090", 8), ("a800", 4), ("a800", 8)] {
        for model in ["30b", "70b"] {
            for len in table1_lens(gpu, cards) {
                if len < 4096 {
                    continue;
                }
                let red = reduction_vs_serial(&exp(gpu, cards, model, len, Strategy::Iso));
                assert!(
                    red > 0.0,
                    "{gpu}-{cards} {model} {len}: ISO reduction {red} <= 0"
                );
            }
        }
    }
}

#[test]
fn table1_4090_average_band() {
    // Paper: ≈35% average on 4090 (≥4k cells).
    let mut sum = 0.0;
    let mut n = 0;
    for cards in [4usize, 8] {
        for model in ["30b", "70b"] {
            for len in table1_lens("4090", cards) {
                if len < 4096 {
                    continue;
                }
                sum += reduction_vs_serial(&exp("4090", cards, model, len, Strategy::Iso));
                n += 1;
            }
        }
    }
    let avg = sum / n as f64;
    assert!((0.25..0.50).contains(&avg), "4090 average reduction {avg}, paper ≈0.35");
}

#[test]
fn table1_a800_average_band() {
    // Paper: ≈15% average on A800 (≥4k cells).
    let mut sum = 0.0;
    let mut n = 0;
    for cards in [4usize, 8] {
        for model in ["30b", "70b"] {
            for len in table1_lens("a800", cards) {
                if len < 4096 {
                    continue;
                }
                sum += reduction_vs_serial(&exp("a800", cards, model, len, Strategy::Iso));
                n += 1;
            }
        }
    }
    let avg = sum / n as f64;
    assert!((0.08..0.30).contains(&avg), "a800 average reduction {avg}, paper ≈0.15");
}

#[test]
fn gains_4090_exceed_a800() {
    // The paper's headline contrast: ~35% vs ~15%.
    for model in ["30b", "70b"] {
        for len in [4096usize, 16384] {
            let g4090 = reduction_vs_serial(&exp("4090", 4, model, len, Strategy::Iso));
            let a800 = reduction_vs_serial(&exp("a800", 4, model, len, Strategy::Iso));
            assert!(
                g4090 > a800,
                "{model} {len}: 4090 {g4090} !> a800 {a800}"
            );
        }
    }
}

#[test]
fn short_prompts_gain_least_on_a800() {
    // Paper Table 1: A800 1k cells are ~0% (even −6%); gains peak mid-range.
    let short = reduction_vs_serial(&exp("a800", 4, "70b", 1024, Strategy::Iso));
    let mid = reduction_vs_serial(&exp("a800", 4, "70b", 8192, Strategy::Iso));
    assert!(short < mid, "1k ({short}) should gain less than 8k ({mid})");
    assert!(short < 0.12, "1k gain {short} should be small");
}

#[test]
fn gains_rise_with_length_on_4090_8c() {
    // Paper: 4090-8 goes 11% → 36% as prompts grow (comm amortizes).
    let r1k = reduction_vs_serial(&exp("4090", 8, "30b", 1024, Strategy::Iso));
    let r64k = reduction_vs_serial(&exp("4090", 8, "30b", 65536, Strategy::Iso));
    assert!(r64k > r1k + 0.10, "64k ({r64k}) should clearly beat 1k ({r1k})");
}

#[test]
fn gemm_overlap_marginal_on_a800_and_worse_than_iso_everywhere() {
    // Paper §4.2: "overlapping communication and matrix computations on
    // the A800 yields marginal gains of 2%–5% and even negative gains on
    // the 4090. In all tested scenarios, ISO surpasses this approach."
    for (gpu, cards, model, len) in [
        ("a800", 4, "70b", 8192usize),
        ("a800", 8, "30b", 8192),
        ("4090", 4, "30b", 4096),
        ("4090", 8, "70b", 16384),
    ] {
        let gemm = reduction_vs_serial(&exp(gpu, cards, model, len, Strategy::GemmOverlap));
        let iso = reduction_vs_serial(&exp(gpu, cards, model, len, Strategy::Iso));
        assert!(iso > gemm, "{gpu}-{cards} {model} {len}: iso {iso} !> gemm {gemm}");
        if gpu == "a800" {
            assert!((-0.02..0.12).contains(&gemm), "a800 gemm-overlap {gemm}");
        } else {
            assert!(gemm < 0.05, "4090 gemm-overlap should be ~<=0, got {gemm}");
        }
    }
}

#[test]
fn request_overlap_needs_two_requests_and_inflates_latency() {
    // Paper §1: request overlap "results in increased latency for
    // individual requests" while raising throughput.
    let e = exp("4090", 4, "30b", 4096, Strategy::RequestOverlap);
    let serial_solo = prefill_s(&exp("4090", 4, "30b", 4096, Strategy::Serial));
    let both = prefill_s(&e);
    assert!(both > serial_solo, "per-request latency must inflate");
    assert!(reduction_vs_serial(&e) > 0.0, "but throughput improves");
    // and ISO gets comparable-or-better throughput gains with ONE request
    let iso = reduction_vs_serial(&exp("4090", 4, "30b", 4096, Strategy::Iso));
    assert!(iso >= reduction_vs_serial(&e) - 0.05);
}

#[test]
fn adaptive_split_helps_when_comm_between_attn_and_mlp() {
    // Paper §6/Fig 3: when comm lies between attention and MLP times,
    // smarter splits beat 50/50.
    let node = NodeProfile::rtx4090(4);
    let model = ModelSpec::gqa_70b();
    let mut even = SimExperiment::new(node.clone(), model.clone(), 16384, Strategy::Iso);
    even.split = SplitPolicy::Even;
    let mut bal = even.clone();
    bal.split = SplitPolicy::AttnBalanced;
    let te = prefill_s(&even);
    let tb = prefill_s(&bal);
    assert!(tb <= te * 1.002, "balanced ({tb}) should not lose to even ({te})");
}

#[test]
fn full_table_renders_without_panic_and_matches_lens() {
    let rows = table1(Strategy::Iso);
    assert_eq!(rows.len(), 8); // 4 platforms × 2 models
    for r in &rows {
        assert_eq!(r.cells.len(), table1_lens(&r.gpu, r.cards).len());
        for (len, red) in &r.cells {
            assert!(red.is_finite(), "{} {}c {} {len}", r.gpu, r.cards, r.model);
            assert!(*red > -0.25 && *red < 0.60);
        }
    }
}

#[test]
fn shipped_config_files_parse() {
    // The configs/ presets documented in the README must stay valid.
    use iso::config::{parse_config_file, EngineConfig};
    use std::path::Path;
    for f in ["configs/engine-iso.conf", "configs/engine-serial-baseline.conf"] {
        let map = parse_config_file(Path::new(f)).unwrap();
        let cfg = EngineConfig::from_map(&map).unwrap();
        assert!(cfg.tp >= 1, "{f}");
    }
    let map = parse_config_file(Path::new("configs/hardware-h800ish.conf")).unwrap();
    let node = NodeProfile::from_map(&map).unwrap();
    assert_eq!(node.device.name, "h800ish");
    assert_eq!(node.cards, 8);
}

#[test]
fn newer_chip_between_extremes_gains_positive() {
    // Paper §6: "newer chips may lie somewhere in between, generally
    // yielding positive gains from ISO" — check with the shipped h800ish
    // profile.
    use iso::config::parse_config_file;
    let map = parse_config_file(std::path::Path::new("configs/hardware-h800ish.conf")).unwrap();
    let node = NodeProfile::from_map(&map).unwrap();
    for len in [4096usize, 16384, 65536] {
        let e = SimExperiment::new(node.clone(), ModelSpec::gqa_70b(), len, Strategy::Iso);
        let red = reduction_vs_serial(&e);
        assert!(red > 0.05, "h800ish {len}: {red}");
    }
}

#[test]
fn split_policies_agree_between_sim_and_engine_planner() {
    // The simulator's balanced split and the engine's cheap 0.55 heuristic
    // must point the same direction (first chunk ≥ half).
    let node = NodeProfile::a800(4);
    let model = ModelSpec::gqa_70b();
    for t in [4096usize, 16384, 65536] {
        let s = choose_split(SplitPolicy::AttnBalanced, &node, &model, t);
        assert!(s.t0 >= t / 2, "t={t}: balanced t0 {} < half", s.t0);
    }
}
