//! 2D-parallel (pipeline × tensor) engine tests (DESIGN.md §11).
//!
//! The load-bearing invariants:
//!   * splitting layers across stages at the same per-stage TP width is
//!     **bit-exact** — activations cross stages verbatim, so `pp=2,tp=2`
//!     logits equal `pp=1,tp=2` logits bit for bit;
//!   * `pp=2×tp=2` serving is **token-identical** to the flat `tp=4`
//!     baseline across all three schedulers (sequential, mixed,
//!     speculative) — the PR-4 acceptance bar;
//!   * pipeline accounting (p2p bytes/messages, bubble and stage
//!     histograms) is live exactly when `pp_stages > 1`.
//!
//! Engine tests require `make artifacts`; they skip (like the rest of the
//! e2e suite) when the artifacts are absent.

use iso::config::{CommQuant, EngineConfig, SplitPolicy, Strategy};
use iso::coordinator::{stage_layer_range, Engine};
use iso::runtime::Manifest;
use iso::workload::{LenDist, TraceGen};

fn have_artifacts() -> bool {
    match Manifest::load("artifacts") {
        Ok(_) => true,
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e}");
            false
        }
    }
}

fn cfg(strategy: Strategy, pp: usize, tp: usize) -> EngineConfig {
    EngineConfig {
        strategy,
        split: SplitPolicy::Even,
        comm_quant: CommQuant::F32,
        gemm_segments: 1,
        tp,
        pp_stages: pp,
        max_chunk: 64,
        max_batch: 4,
        artifacts_dir: "artifacts".into(),
        ..Default::default()
    }
}

#[test]
fn pp_layer_assignment_is_balanced_for_the_tiny_model() {
    // Pure-rust sanity for the assignment the engine tests exercise:
    // 4 layers over 2 stages = [0,2) + [2,4); over 4 stages = one each.
    assert_eq!(stage_layer_range(4, 2, 0), (0, 2));
    assert_eq!(stage_layer_range(4, 2, 1), (2, 4));
    for s in 0..4 {
        assert_eq!(stage_layer_range(4, 4, s), (s, s + 1));
    }
}

#[test]
fn pp_prefill_bit_exact_vs_single_stage() {
    // Same per-stage TP width AND same chunk plan ⇒ identical layer
    // arithmetic; the p2p handoff moves f32 activations verbatim, so
    // stage-splitting must not change a single bit of the logits. The
    // 96-token prompt yields the same chunk plan at pp=1 and pp=2 for
    // both strategies (ISO: 4 chunks ≥ the 2×pp depth; serial: 2 chunks
    // ≥ the pp depth), so the engines run byte-identical chunk sets
    // (deeper pipelines re-tile finer and are covered by the
    // token-identity tests below).
    if !have_artifacts() {
        return;
    }
    let prompt: Vec<i32> = (0..96).map(|i| (i * 19 % 512) as i32).collect();
    for strategy in [Strategy::Iso, Strategy::Serial] {
        let mut flat = Engine::start(cfg(strategy, 1, 2)).unwrap();
        let a = flat.prefill(&prompt).unwrap();
        flat.shutdown().unwrap();
        let mut deep = Engine::start(cfg(strategy, 2, 2)).unwrap();
        let b = deep.prefill(&prompt).unwrap();
        deep.shutdown().unwrap();
        assert_eq!(a.logits, b.logits, "{strategy:?}: stage split changed the bits");
        assert_eq!(a.first_token, b.first_token);
    }
}

#[test]
fn pp4_prefill_token_identical_despite_finer_tiling() {
    // A 4-deep pipeline re-tiles the same prompt into more micro-batch
    // chunks (2 per stage), which changes kernel shapes but must not
    // change the greedy outcome — the same cross-chunking guarantee the
    // serial-vs-ISO suite already relies on.
    if !have_artifacts() {
        return;
    }
    let prompt: Vec<i32> = (0..96).map(|i| (i * 19 % 512) as i32).collect();
    let mut flat = Engine::start(cfg(Strategy::Iso, 1, 2)).unwrap();
    let a = flat.prefill(&prompt).unwrap();
    flat.shutdown().unwrap();
    let mut deep = Engine::start(cfg(Strategy::Iso, 4, 2)).unwrap();
    let b = deep.prefill(&prompt).unwrap();
    deep.shutdown().unwrap();
    assert_eq!(a.first_token, b.first_token, "finer pp tiling changed the token");
}

#[test]
fn pp_generate_matches_single_stage_tokens() {
    // The legacy per-sequence decode path flows single rows through the
    // stages; tokens must match the flat engine exactly.
    if !have_artifacts() {
        return;
    }
    let prompt: Vec<i32> = (0..32).map(|i| (i * 13 % 512) as i32).collect();
    let mut flat = Engine::start(cfg(Strategy::Iso, 1, 2)).unwrap();
    let a = flat.generate(&prompt, 4).unwrap();
    flat.shutdown().unwrap();
    let mut deep = Engine::start(cfg(Strategy::Iso, 2, 2)).unwrap();
    let b = deep.generate(&prompt, 4).unwrap();
    deep.shutdown().unwrap();
    assert_eq!(a.tokens, b.tokens, "pipeline decode diverged from flat TP");
}

/// Serve one paced trace on two engine configs and assert identical
/// per-request token streams.
fn assert_token_identical_serving(mut a: EngineConfig, mut b: EngineConfig, seed: u64) {
    a.max_batch = 3;
    b.max_batch = 3;
    let reqs = TraceGen::new(seed, 512, LenDist::Uniform(20, 60))
        .decode_steps(4)
        .rate(100.0)
        .generate(5);
    let mut ea = Engine::start(a).unwrap();
    let ta = ea.serve_trace(&reqs).unwrap();
    ea.shutdown().unwrap();
    let mut eb = Engine::start(b).unwrap();
    let tb = eb.serve_trace(&reqs).unwrap();
    eb.shutdown().unwrap();
    assert_eq!(ta.completed, 5);
    assert_eq!(tb.completed, 5);
    let sort = |mut v: Vec<(u64, Vec<i32>)>| {
        v.sort_by_key(|(id, _)| *id);
        v
    };
    assert_eq!(
        sort(ta.completions),
        sort(tb.completions),
        "2D parallelism changed emitted tokens"
    );
}

#[test]
fn pp2_tp2_tokens_match_tp4_sequential_scheduler() {
    // PR-4 acceptance: PP=2×TP=2 serves bit-identical tokens to the flat
    // TP=4 baseline — legacy sequential loop.
    if !have_artifacts() {
        return;
    }
    let mut a = cfg(Strategy::Iso, 2, 2);
    let mut b = cfg(Strategy::Iso, 1, 4);
    a.mixed_iterations = false;
    b.mixed_iterations = false;
    assert_token_identical_serving(a, b, 31);
}

#[test]
fn pp2_tp2_tokens_match_tp4_mixed_scheduler() {
    // Same bar under the iteration-level mixed scheduler (prefill chunks
    // + fused decode lane flowing through the stages).
    if !have_artifacts() {
        return;
    }
    let mut a = cfg(Strategy::Iso, 2, 2);
    let mut b = cfg(Strategy::Iso, 1, 4);
    a.decode_batch = 2;
    b.decode_batch = 2;
    assert_token_identical_serving(a, b, 33);
}

#[test]
fn pp2_tp2_tokens_match_tp4_spec_scheduler() {
    // Same bar with speculative verify lanes (greedy acceptance keeps the
    // stream identical regardless of the parallel topology).
    if !have_artifacts() {
        return;
    }
    let mut a = cfg(Strategy::Iso, 2, 2);
    let mut b = cfg(Strategy::Iso, 1, 4);
    for c in [&mut a, &mut b] {
        c.decode_batch = 2;
        c.spec_k = 2;
    }
    assert_token_identical_serving(a, b, 35);
}

#[test]
fn pp_engine_reports_pipeline_metrics() {
    if !have_artifacts() {
        return;
    }
    let prompt: Vec<i32> = (0..64).map(|i| (i * 7 % 512) as i32).collect();
    let mut e = Engine::start(cfg(Strategy::Iso, 2, 1)).unwrap();
    e.prefill(&prompt).unwrap();
    let report = e.shutdown().unwrap();
    assert_eq!((report.pp_stages, report.tp), (2, 1));
    let m = &report.metrics;
    assert!(m.p2p_msgs > 0, "pipeline ran but no p2p messages recorded");
    assert!(m.p2p_bytes > 0);
    assert_eq!(m.pp_bubble_ms.len(), 2, "one bubble sample per rank");
    assert_eq!(m.stage_compute_ms.len(), 2, "one occupancy sample per stage");
    // Only the non-last stage forwards activations.
    let stage0 = report.workers.iter().find(|w| w.stage == 0).unwrap();
    let stage1 = report.workers.iter().find(|w| w.stage == 1).unwrap();
    assert!(stage0.p2p_bytes > 0);
    assert_eq!(stage1.p2p_bytes, 0, "last stage must not forward");
    assert!(stage1.p2p_stall_ms >= 0.0);
}

#[test]
fn pp_single_stage_reports_no_pipeline_metrics() {
    // pp = 1 must look exactly like the pre-PP engine: zero p2p traffic,
    // empty pipeline histograms.
    if !have_artifacts() {
        return;
    }
    let prompt: Vec<i32> = (0..32).map(|i| (i * 3 % 512) as i32).collect();
    let mut e = Engine::start(cfg(Strategy::Iso, 1, 2)).unwrap();
    e.prefill(&prompt).unwrap();
    let report = e.shutdown().unwrap();
    assert_eq!(report.metrics.p2p_msgs, 0);
    assert_eq!(report.metrics.p2p_bytes, 0);
    assert!(report.metrics.pp_bubble_ms.is_empty());
    assert!(report.metrics.stage_compute_ms.is_empty());
}

#[test]
fn pp_rejects_more_stages_than_layers() {
    if !have_artifacts() {
        return;
    }
    // The tiny model has 4 layers; a 5-stage pipeline would starve one.
    assert!(Engine::start(cfg(Strategy::Iso, 5, 1)).is_err());
    // pp_stages = n_layers (one layer per stage) must still start.
    let mut e = Engine::start(cfg(Strategy::Iso, 4, 1)).unwrap();
    let prompt: Vec<i32> = (0..32).map(|i| (i * 5 % 512) as i32).collect();
    let out = e.prefill(&prompt).unwrap();
    assert_eq!(out.logits.len(), 512);
    e.shutdown().unwrap();
}
