//! OVERLOAD — artifact-free closed-loop soak (PR-7): the admission
//! gate, the budget-bounded mixed planner, and the paged KV manager
//! driven together by a saturating heavy-tailed Poisson trace, with
//! KV-pressure preemption in the loop. CI runs this under a hard
//! timeout (the `overload` job); the properties:
//!
//! * the bounded queue never exceeds its bound and every rejection is
//!   the typed [`EngineError::Overloaded`] — backpressure, not a crash;
//! * allocator invariants hold through every preempt/restore cycle and
//!   the pool drains to empty at the end (no leaked blocks);
//! * every admitted sequence completes its full decode budget —
//!   preempted sequences included (checkpoint-free resume from the
//!   committed prefix);
//! * the loop terminates well inside a wall-clock watchdog: preemption
//!   never evicts the last runnable sequence and per-sequence caps
//!   bound the preempt/restore ping-pong (anti-livelock, DESIGN.md §15).

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use iso::batch::{Admission, LaneSeq, MixedPlanner, Priority};
use iso::config::{SplitPolicy, Strategy};
use iso::fault::EngineError;
use iso::kv::KvManager;
use iso::workload::{pad_to_chunk, LenDist, TraceGen};

const N_REQS: usize = 40;
const MAX_LIVE: usize = 4;
const QUEUE_BOUND: usize = 6;
const DECODE_STEPS: usize = 16;
const BLOCK: usize = 16;
const MAX_SEQ: usize = 256;
const ITER_S: f64 = 0.05;
const MAX_PREEMPTIONS: usize = 2;

/// One live sequence in the soak loop: scheduler lane state plus the
/// bookkeeping the serve loop keeps alongside it.
struct Live {
    id: u64,
    lane: LaneSeq,
    preemptions: usize,
}

/// A preempted sequence waiting for a free slot: everything needed to
/// resume from the committed prefix.
struct Preempted {
    id: u64,
    prompt_len: usize,
    committed: usize,
    decode_left: usize,
    preemptions: usize,
}

#[test]
fn saturating_trace_sheds_preempts_and_completes() {
    let reqs = TraceGen::new(23, 512, LenDist::Lognormal { mu: 3.5, sigma: 1.0, cap: 192 })
        .rate(40.0)
        .decode_steps(DECODE_STEPS)
        .generate(N_REQS);
    let mut adm = Admission::new(MAX_LIVE)
        .with_bound(QUEUE_BOUND)
        .with_ttft_deadline_s(1.0);
    let mut planner =
        MixedPlanner::new(Strategy::Iso, SplitPolicy::Even, vec![16, 32, 64], 2, MAX_SEQ)
            .with_prefill_budget(32);
    // 4 slots × 256 positions of paged KV; the high-water mark sits at
    // 60%, low enough that the trace's lognormal tail crosses it.
    let mut kvm = KvManager::new(MAX_LIVE * MAX_SEQ, BLOCK);
    let high_water = (kvm.total_blocks() as f64 * 0.6) as usize;
    let mut free_slots: Vec<usize> = (0..MAX_LIVE).rev().collect();

    let mut live: Vec<Live> = Vec::new();
    let mut preempted: VecDeque<Preempted> = VecDeque::new();
    let mut next = 0usize;
    let mut now_s = 0.0f64;
    let (mut completed, mut shed, mut rejected, mut preemptions) = (0usize, 0usize, 0usize, 0u64);
    let watchdog = Instant::now();
    let mut iters = 0usize;

    while next < reqs.len() || adm.pending() > 0 || !live.is_empty() || !preempted.is_empty() {
        iters += 1;
        assert!(iters < 20_000, "soak loop did not converge (livelock?)");
        assert!(
            watchdog.elapsed() < Duration::from_secs(60),
            "soak loop blew its wall-clock watchdog"
        );
        now_s += ITER_S;

        // Arrivals: bounded queue, typed rejection.
        while next < reqs.len() && reqs[next].arrival_s <= now_s {
            let prio = match reqs[next].id % 3 {
                0 => Priority::Interactive,
                1 => Priority::Batch,
                _ => Priority::BestEffort,
            };
            let tenant = reqs[next].id % 2;
            match adm.submit_classed(reqs[next].clone(), prio, tenant) {
                Ok(()) => {}
                Err(EngineError::Overloaded { bound, .. }) => {
                    assert_eq!(bound, QUEUE_BOUND);
                    rejected += 1;
                }
                Err(e) => panic!("unexpected admission error: {e}"),
            }
            next += 1;
        }
        assert!(adm.queue_depth() <= QUEUE_BOUND, "queue grew past its bound");

        // Deadline-based TTFT shedding.
        shed += adm.shed_stale(now_s).len();

        // Restore preempted sequences before admitting fresh arrivals.
        while !preempted.is_empty() && !free_slots.is_empty() {
            let slot = free_slots.pop().expect("checked non-empty");
            let p = preempted.pop_front().expect("checked non-empty");
            kvm.add_seq(slot as u64);
            let start = kvm.append(slot as u64, p.committed).expect("sized by release");
            assert_eq!(start, 0, "restore must rebuild from position 0");
            live.push(Live {
                id: p.id,
                lane: LaneSeq {
                    slot,
                    prompt_len: p.prompt_len,
                    prefilled: true,
                    prefill_done: p.prompt_len,
                    last_token: 1,
                    offset: p.committed,
                    decode_left: p.decode_left,
                },
                preemptions: p.preemptions,
            });
        }

        // Admission into free slots.
        for r in adm.admit() {
            let slot = free_slots.pop().expect("admission cap == slot count");
            let prompt_len = pad_to_chunk(r.prompt.len(), BLOCK);
            kvm.add_seq(slot as u64);
            live.push(Live {
                id: r.id,
                lane: LaneSeq {
                    slot,
                    prompt_len,
                    prefilled: false,
                    prefill_done: 0,
                    last_token: 0,
                    offset: 0,
                    decode_left: r.decode_steps,
                },
                preemptions: 0,
            });
        }
        if live.is_empty() {
            continue;
        }

        // One planner iteration: a budget-bounded prefill slice plus the
        // fused decode lane.
        let lanes: Vec<LaneSeq> = live.iter().map(|l| l.lane.clone()).collect();
        let plan = planner.plan(&lanes, None);
        if let Some(pf) = &plan.prefill {
            let last = pf.chunks.last().expect("budget slice is never empty");
            let slice_end = last.offset + last.len;
            assert!(slice_end <= pf.prompt_len, "slice overran the prompt");
            let l = live
                .iter_mut()
                .find(|l| l.lane.slot == pf.slot)
                .expect("planned slot is live");
            if slice_end >= pf.prompt_len {
                kvm.append(pf.slot as u64, pf.prompt_len).expect("capacity sized for max_live");
                l.lane.prefilled = true;
                l.lane.prefill_done = pf.prompt_len;
                l.lane.offset = pf.prompt_len;
            } else {
                l.lane.prefill_done = slice_end;
            }
        }
        for d in &plan.decode {
            let l = live
                .iter_mut()
                .find(|l| l.lane.slot == d.slot)
                .expect("decode slot is live");
            kvm.append(d.slot as u64, 1).expect("capacity sized for max_live");
            l.lane.offset += 1;
            l.lane.decode_left -= 1;
            l.lane.last_token = (l.lane.offset % 50) as i32;
        }

        // Retire finished sequences.
        let mut i = 0;
        while i < live.len() {
            if live[i].lane.prefilled && live[i].lane.decode_left == 0 {
                let l = live.remove(i);
                kvm.release(l.lane.slot as u64).expect("retiring seq owns its slot");
                free_slots.push(l.lane.slot);
                adm.complete();
                completed += 1;
            } else {
                i += 1;
            }
        }

        // KV-pressure preemption: evict the youngest prefilled sequence
        // until usage falls to the high-water mark, never the last one,
        // never a sequence past its preemption cap.
        while kvm.total_blocks() - kvm.free_blocks() > high_water {
            if live.iter().filter(|l| l.lane.prefilled).count() <= 1 {
                break;
            }
            let Some(vi) = live
                .iter()
                .rposition(|l| l.lane.prefilled && l.preemptions < MAX_PREEMPTIONS)
            else {
                break;
            };
            let v = live.remove(vi);
            kvm.release(v.lane.slot as u64).expect("victim owns its slot");
            free_slots.push(v.lane.slot);
            preemptions += 1;
            preempted.push_back(Preempted {
                id: v.id,
                prompt_len: v.lane.prompt_len,
                committed: v.lane.offset,
                decode_left: v.lane.decode_left,
                preemptions: v.preemptions + 1,
            });
        }
        kvm.check_invariants().expect("allocator invariants");
    }

    assert_eq!(
        completed + shed + rejected,
        N_REQS,
        "every request must complete, shed, or be rejected (none dropped)"
    );
    assert!(completed > 0, "soak completed nothing");
    assert!(shed + rejected > 0, "trace was not saturating: nothing shed or rejected");
    assert_eq!(kvm.free_blocks(), kvm.total_blocks(), "drained pool leaked KV blocks");
    assert_eq!(kvm.live_seqs(), 0);
    kvm.check_invariants().expect("final allocator invariants");
    let _ = preemptions; // may be 0 on a tail-free prefix; the guard test below pins the motion
}

#[test]
fn preemption_guard_never_evicts_last_runnable() {
    // The preemption while-loop's anti-livelock guard, pinned
    // deterministically: two prefilled sequences sit past a 50%
    // high-water mark; the youngest is evicted, the loop then refuses
    // to evict the survivor even though usage may still sit above the
    // mark, and the restore path rebuilds the evicted prefix from
    // position 0.
    let mut kvm = KvManager::new(256, BLOCK);
    kvm.add_seq(0);
    kvm.append(0, 96).unwrap();
    kvm.add_seq(1);
    kvm.append(1, 96).unwrap();
    let high_water = (kvm.total_blocks() as f64 * 0.5) as usize;
    assert!(kvm.total_blocks() - kvm.free_blocks() > high_water);

    let mut live: Vec<u64> = vec![0, 1];
    let mut evicted: Vec<(u64, usize)> = Vec::new();
    let mut caps = [0usize; 2];
    while kvm.total_blocks() - kvm.free_blocks() > high_water {
        if live.len() <= 1 {
            break;
        }
        let Some(vi) = live.iter().rposition(|&s| caps[s as usize] < MAX_PREEMPTIONS) else {
            break;
        };
        let s = live.remove(vi);
        let committed = kvm.seq_len(s).unwrap();
        kvm.release(s).unwrap();
        caps[s as usize] += 1;
        evicted.push((s, committed));
    }
    assert_eq!(live, vec![0], "guard must keep the oldest sequence live");
    assert_eq!(evicted, vec![(1, 96)], "youngest evicted exactly once");
    kvm.check_invariants().unwrap();

    for (s, committed) in evicted {
        kvm.add_seq(s);
        assert_eq!(kvm.append(s, committed).unwrap(), 0);
        assert_eq!(kvm.seq_len(s), Some(96));
    }
    kvm.check_invariants().unwrap();
}
