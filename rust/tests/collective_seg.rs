//! Segmented-collective contracts (PR-1 tentpole acceptance):
//!
//! * `allreduce_seg` is **bit-identical** to the unsegmented path for
//!   `CommQuant::F32` across rank counts and segment counts — the ring's
//!   chunk↔rank mapping (and so the per-element accumulation order) does
//!   not depend on sub-message granularity;
//! * the int8 wire keeps its per-row round-trip accuracy bound under
//!   segmentation, and is itself bit-identical across segment counts;
//! * `seg_range` partitions rows exactly (rows < n and rows ≫ n);
//! * `allreduce_seg_with` streams final row-ranges that cover the result
//!   exactly once with values matching the converged buffer;
//! * wire-buffer pooling reaches an allocation-free steady state.

use iso::collective::{ring, run_on_ring, seg_range, Throttle};
use iso::config::CommQuant;
use iso::quant::quantize_rows;
use iso::util::Rng;

fn gold_sum(parts: &[Vec<f32>]) -> Vec<f32> {
    let mut out = vec![0.0f32; parts[0].len()];
    for p in parts {
        for (o, x) in out.iter_mut().zip(p) {
            *o += x;
        }
    }
    out
}

fn parts_for(n: usize, rows: usize, cols: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal_vec(rows * cols, 1.5)).collect()
}

fn allreduce_all_ranks(
    parts: &[Vec<f32>],
    rows: usize,
    cols: usize,
    quant: CommQuant,
    segments: usize,
) -> Vec<Vec<f32>> {
    run_on_ring(parts.len(), |r, h| {
        let mut d = parts[r].clone();
        h.allreduce_seg(&mut d, rows, cols, quant, segments);
        d
    })
}

#[test]
fn segmented_f32_bit_identical_to_unsegmented() {
    // The acceptance criterion: for F32 wire the segmented result equals
    // the serial (segments=1) all-reduce bit-for-bit, for every rank
    // count and segment count, including rows not divisible by either.
    for n in [1usize, 2, 3, 4] {
        for (rows, cols) in [(13usize, 7usize), (1, 16), (64, 8)] {
            let parts = parts_for(n, rows, cols, 42 + n as u64);
            let baseline = allreduce_all_ranks(&parts, rows, cols, CommQuant::F32, 1);
            for segments in [1usize, 3, 8] {
                let seg = allreduce_all_ranks(&parts, rows, cols, CommQuant::F32, segments);
                for r in 0..n {
                    let a: Vec<u32> = baseline[r].iter().map(|v| v.to_bits()).collect();
                    let b: Vec<u32> = seg[r].iter().map(|v| v.to_bits()).collect();
                    assert_eq!(
                        a, b,
                        "n={n} rows={rows} cols={cols} segments={segments} rank={r}: \
                         segmented result differs bitwise"
                    );
                }
            }
        }
    }
}

#[test]
fn segmented_int8_bit_identical_and_accurate() {
    // Per-row scales make int8 quantization independent of how rows are
    // grouped into wire messages, so even the lossy path is bit-stable
    // under segmentation — and stays within the round-trip error bound.
    let n = 4;
    let (rows, cols) = (19, 24);
    let parts = parts_for(n, rows, cols, 7);
    let want = gold_sum(&parts);
    let baseline = allreduce_all_ranks(&parts, rows, cols, CommQuant::Int8, 1);
    for segments in [1usize, 3, 8] {
        let seg = allreduce_all_ranks(&parts, rows, cols, CommQuant::Int8, segments);
        assert_eq!(baseline, seg, "int8 wire changed under segments={segments}");
        // Accuracy: ~2(n-1) quantized hops; bound loosely like the
        // paper's wire-compression error budget.
        let amax = want.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        let tol = amax * 0.05;
        for got in &seg {
            for (g, w) in got.iter().zip(&want) {
                assert!(
                    (g - w).abs() <= tol,
                    "segments={segments}: {g} vs {w} (tol {tol})"
                );
            }
        }
    }
}

#[test]
fn int8_roundtrip_error_bound_is_per_row_under_segmentation() {
    // One quantize/dequantize round trip of a segment obeys the same
    // half-step-per-row bound as quantizing the whole payload: the wire
    // codec's accuracy does not degrade when payloads are split.
    let mut rng = Rng::new(11);
    let (rows, cols) = (16, 32);
    let x = rng.normal_vec(rows * cols, 2.0);
    let whole = quantize_rows(&x, rows, cols);
    for split in [1usize, 5, 8, 15] {
        let head = quantize_rows(&x[..split * cols], split, cols);
        let tail = quantize_rows(&x[split * cols..], rows - split, cols);
        for r in 0..rows {
            let (seg_scale, seg_data) = if r < split {
                (head.scales[r], &head.data[r * cols..(r + 1) * cols])
            } else {
                let rr = r - split;
                (tail.scales[rr], &tail.data[rr * cols..(rr + 1) * cols])
            };
            assert_eq!(seg_scale, whole.scales[r], "split={split} row={r}: scale");
            assert_eq!(
                seg_data,
                &whole.data[r * cols..(r + 1) * cols],
                "split={split} row={r}: payload"
            );
            let bound = seg_scale * 0.5 + 1e-6;
            for c in 0..cols {
                let back = seg_data[c] as f32 * seg_scale;
                let err = (x[r * cols + c] - back).abs();
                assert!(err <= bound, "split={split} r={r} c={c}: err {err} > {bound}");
            }
        }
    }
}

#[test]
fn seg_range_partitions_rows_exactly() {
    // rows < n (trailing empties), rows == n, rows ≫ n.
    for (rows, n) in [(3usize, 8usize), (8, 8), (1000, 7), (0, 4), (17, 4)] {
        let mut covered = 0;
        for i in 0..n {
            let (a, b) = seg_range(rows, n, i);
            assert_eq!(a, covered, "rows={rows} n={n} i={i}: gap/overlap");
            assert!(b >= a);
            covered = b;
        }
        assert_eq!(covered, rows, "rows={rows} n={n}: not a partition");
    }
}

#[test]
fn streamed_ranges_cover_result_exactly_once() {
    for n in [1usize, 2, 4] {
        for segments in [1usize, 3, 8] {
            let (rows, cols) = (14, 6);
            let parts = parts_for(n, rows, cols, 99);
            let outs = run_on_ring(n, |r, h| {
                let mut d = parts[r].clone();
                let mut hits = vec![0u32; rows];
                let mut streamed = vec![0.0f32; rows * cols];
                h.allreduce_seg_with(&mut d, rows, cols, CommQuant::F32, segments, |a, b, v| {
                    assert!(b > a && b <= rows, "bad range ({a},{b})");
                    assert_eq!(v.len(), (b - a) * cols);
                    for hit in &mut hits[a..b] {
                        *hit += 1;
                    }
                    streamed[a * cols..b * cols].copy_from_slice(v);
                });
                (d, hits, streamed)
            });
            for (d, hits, streamed) in outs {
                assert!(hits.iter().all(|&h| h == 1), "n={n} seg={segments}: {hits:?}");
                assert_eq!(d, streamed, "streamed values must equal the final result");
            }
        }
    }
}

#[test]
fn throttled_segmented_allreduce_matches_unthrottled() {
    // The virtual-time link model changes pacing, never values or bytes.
    let n = 3;
    let (rows, cols) = (12, 8);
    let parts = parts_for(n, rows, cols, 5);
    let plain = allreduce_all_ranks(&parts, rows, cols, CommQuant::F32, 4);
    let throttled = run_on_ring(n, |r, h| {
        // Generous bandwidth so the test stays fast; tiny α.
        h.throttle = Some(Throttle { alpha_s: 1e-6, bytes_per_s: 500e6 });
        let mut d = parts[r].clone();
        let bytes = h.allreduce_seg(&mut d, rows, cols, CommQuant::F32, 4);
        (d, bytes)
    });
    let plain_bytes = run_on_ring(n, |r, h| {
        let mut d = parts[r].clone();
        h.allreduce_seg(&mut d, rows, cols, CommQuant::F32, 4)
    });
    for (r, (d, bytes)) in throttled.iter().enumerate() {
        assert_eq!(d, &plain[r], "throttle changed values");
        assert_eq!(*bytes, plain_bytes[r], "throttle changed byte accounting");
    }
}

#[test]
fn pool_stops_allocating_in_steady_state() {
    let n = 4;
    let (rows, cols) = (32, 16);
    let stats = run_on_ring(n, |r, h| {
        let mut d = vec![(r + 1) as f32; rows * cols];
        // Warmup laps let buffers circulate the ring into every pool.
        for _ in 0..3 {
            h.allreduce_seg(&mut d, rows, cols, CommQuant::F32, 4);
        }
        let (allocs_warm, _) = h.pool_stats();
        for _ in 0..10 {
            h.allreduce_seg(&mut d, rows, cols, CommQuant::F32, 4);
        }
        let (allocs, reuses) = h.pool_stats();
        (allocs_warm, allocs, reuses)
    });
    for (allocs_warm, allocs, reuses) in stats {
        assert!(reuses > 0, "pool never reused a buffer");
        assert!(
            allocs - allocs_warm <= allocs_warm,
            "steady state still allocating: warm={allocs_warm} after={allocs}"
        );
    }
}

#[test]
fn single_rank_streams_whole_payload_immediately() {
    let mut h = ring(1).pop().unwrap();
    let mut d = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
    let mut calls = Vec::new();
    let sent = h.allreduce_seg_with(&mut d, 3, 2, CommQuant::F32, 4, |a, b, v| {
        calls.push((a, b, v.to_vec()));
    });
    assert_eq!(sent, 0);
    assert_eq!(calls.len(), 1);
    assert_eq!(calls[0].0, 0);
    assert_eq!(calls[0].1, 3);
    assert_eq!(calls[0].2, d);
}
