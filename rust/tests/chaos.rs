//! CHAOS — seeded fault plans swept across scheduler shapes (PR-6
//! satellite): a miniature supervised mesh built from the production
//! fabric (`collective::ring` / `collective::stage_grid`, the `try_*`
//! supervised collectives, and the real `FaultPlan` / `FaultInjector`)
//! exercises the same detect → teardown → respawn → replay protocol the
//! engine runs, without needing model artifacts. Properties asserted
//! per (shape × plan):
//!
//! * **no hang** — every run finishes under a wall-clock bound, and
//!   teardown mid-iteration terminates (the sender-drop cascade of
//!   DESIGN.md §14);
//! * **zero dropped sequences** — every sequence reaches its target
//!   length despite kills, stalls, and poisoned wire segments;
//! * **token identity** — token streams are bit-identical to the
//!   fault-free run of the same shape (tokens commit only on a
//!   successful reply, so replaying the uncommitted iteration is
//!   checkpoint-free and exact);
//! * **determinism** — the same seeded plan spec reproduces the same
//!   outcome.
//!
//! PR-8 extends the sweep down the wire-precision ladder: poisoned
//! fp8/int4 ring segments must surface as the same typed
//! [`EngineError::WireCorrupt`] and replay to token identity at the
//! same rung (the quantized codecs are deterministic, so replay stays
//! checkpoint-free and exact — DESIGN.md §16).

use std::collections::BTreeSet;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use iso::collective::{ring, stage_grid, RingHandle, StagePort};
use iso::config::CommQuant;
use iso::fault::{EngineError, FaultInjector, FaultPlan, SupervisionEvent};

/// Sequences per run; every one must reach `TARGET` tokens (zero-drop).
const N_SEQS: usize = 3;
/// Tokens each sequence must complete.
const TARGET: usize = 6;
/// Columns per activation row.
const COLS: usize = 4;
/// Leader-side detection deadline; generous, since supervision events
/// and the sender-drop cascade detect real faults in milliseconds.
const DEADLINE: Duration = Duration::from_secs(5);

/// A scheduler shape in miniature: how the mesh is factored and how
/// many rows each iteration carries.
#[derive(Clone, Copy)]
struct Shape {
    name: &'static str,
    pp: usize,
    tp: usize,
    /// Sequences advanced per iteration (1 = sequential admission,
    /// >1 = fused decode lane).
    lane: usize,
    /// Tokens per sequence per iteration (speculative drafts).
    k: usize,
}

const SHAPES: [Shape; 4] = [
    Shape { name: "sequential", pp: 1, tp: 2, lane: 1, k: 1 },
    Shape { name: "mixed", pp: 1, tp: 2, lane: 3, k: 1 },
    Shape { name: "spec", pp: 1, tp: 2, lane: 3, k: 2 },
    Shape { name: "pp2xtp2", pp: 2, tp: 2, lane: 3, k: 1 },
];

/// One leader→worker step: `rows × cols` of activation input.
struct StepJob {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

/// One mini-mesh rank: real ring handle + stage port + shared injector.
struct Worker {
    rank: usize,
    tp_rank: usize,
    ring: RingHandle,
    port: StagePort,
    inj: Arc<FaultInjector>,
    /// Wire rung for this worker's ring collectives (PR-8: the chaos
    /// protocol must hold on the quantized rungs too).
    rung: CommQuant,
}

impl Worker {
    /// Two toy "layers", each an injector poll + deterministic scale +
    /// supervised ring all-reduce, with stage chaining over the real
    /// port — the same poll points as the engine: layer boundaries
    /// (kill/stall), ring and stage sends (poison).
    fn step(&mut self, job: StepJob) -> Result<Option<Vec<i32>>, EngineError> {
        let (rows, cols, mut data) = if self.port.has_prev() {
            self.port.try_recv_prev()?
        } else {
            (job.rows, job.cols, job.data)
        };
        for layer in 0..2usize {
            self.inj.poll_compute(self.rank, layer)?;
            for v in data.iter_mut() {
                *v = (*v + layer as f32 * 0.125) * (self.tp_rank as f32 + 1.0) * 0.25;
            }
            if self.inj.poll_wire(self.rank, false) {
                self.ring.poison_next_send();
            }
            self.ring.try_allreduce(&mut data, rows, cols, self.rung)?;
        }
        if self.port.has_next() {
            if self.inj.poll_wire(self.rank, true) {
                self.port.poison_next_send();
            }
            self.port.try_send_next(data, rows, cols)?;
            return Ok(None);
        }
        if self.tp_rank != 0 {
            return Ok(None);
        }
        let tokens: Vec<i32> =
            data.chunks_exact(cols).map(|row| (row.iter().sum::<f32>() * 64.0) as i32).collect();
        Ok(Some(tokens))
    }

    /// Worker loop: exits when the leader drops the job sender, or on
    /// the first fault — which it reports as a supervision event before
    /// dropping its fabric ends (unblocking its peers).
    fn run(
        mut self,
        jobs: Receiver<StepJob>,
        reply: Sender<Vec<i32>>,
        events: Sender<SupervisionEvent>,
    ) {
        while let Ok(job) = jobs.recv() {
            match self.step(job) {
                Ok(Some(tokens)) => {
                    reply.send(tokens).ok();
                }
                Ok(None) => {}
                Err(error) => {
                    events.send(SupervisionEvent { rank: self.rank, error }).ok();
                    return;
                }
            }
        }
    }
}

/// Leader-side mesh handle: job fan-out, reply, supervision queue.
struct MiniMesh {
    job_txs: Vec<Sender<StepJob>>,
    reply_rx: Receiver<Vec<i32>>,
    event_rx: Receiver<SupervisionEvent>,
    joins: Vec<JoinHandle<()>>,
}

impl MiniMesh {
    /// Spawn a `pp × tp` grid of workers over fresh per-stage rings and
    /// stage-chained ports, all sharing one injector; every ring
    /// collective runs at `rung`.
    fn spawn(shape: Shape, injector: &Arc<FaultInjector>, rung: CommQuant) -> MiniMesh {
        let (reply_tx, reply_rx) = channel();
        let (event_tx, event_rx) = channel();
        let mut job_txs = Vec::new();
        let mut joins = Vec::new();
        for (s, ports) in stage_grid(shape.pp, shape.tp).into_iter().enumerate() {
            for (r, (port, handle)) in ports.into_iter().zip(ring(shape.tp)).enumerate() {
                let worker = Worker {
                    rank: s * shape.tp + r,
                    tp_rank: r,
                    ring: handle,
                    port,
                    inj: Arc::clone(injector),
                    rung,
                };
                let (tx, rx) = channel();
                let (reply, events) = (reply_tx.clone(), event_tx.clone());
                job_txs.push(tx);
                joins.push(std::thread::spawn(move || worker.run(rx, reply, events)));
            }
        }
        MiniMesh { job_txs, reply_rx, event_rx, joins }
    }

    /// Fan one step out to every rank; a dead rank surfaces as
    /// `RankDead` on the job link.
    fn broadcast(&self, rows: usize, cols: usize, data: &[f32]) -> Result<(), EngineError> {
        for (rank, tx) in self.job_txs.iter().enumerate() {
            tx.send(StepJob { rows, cols, data: data.to_vec() })
                .map_err(|_| EngineError::RankDead { rank, link: "job" })?;
        }
        Ok(())
    }

    /// Drain one queued supervision event, if any.
    fn first_event(&self) -> Option<EngineError> {
        self.event_rx.try_recv().ok().map(|ev| ev.error)
    }

    /// Await the iteration's reply, preferring a worker's attributed
    /// supervision event over the bare disconnect/timeout when one is
    /// queued (the engine's leader does the same).
    fn await_reply(&self, iteration: u64) -> Result<Vec<i32>, EngineError> {
        let start = Instant::now();
        loop {
            match self.reply_rx.recv_timeout(Duration::from_millis(10)) {
                Ok(tokens) => return Ok(tokens),
                Err(RecvTimeoutError::Disconnected) => {
                    let dead = EngineError::RankDead { rank: 0, link: "reply" };
                    return Err(self.first_event().unwrap_or(dead));
                }
                Err(RecvTimeoutError::Timeout) => {
                    if let Some(error) = self.first_event() {
                        return Err(error);
                    }
                    if start.elapsed() >= DEADLINE {
                        return Err(EngineError::CollectiveTimeout {
                            iteration,
                            deadline_ms: DEADLINE.as_secs_f64() * 1e3,
                        });
                    }
                }
            }
        }
    }

    /// Tear the mesh down: dropping every job sender unblocks all idle
    /// workers, exiting workers drop their ring/port ends, and that
    /// cascade unblocks any peer still inside a collective — so the
    /// joins below are bounded (DESIGN.md §14).
    fn teardown(mut self) {
        self.job_txs.clear();
        drop(self.reply_rx);
        drop(self.event_rx);
        for j in self.joins {
            let _ = j.join();
        }
    }
}

/// What a run produced: per-sequence token streams, how many mesh
/// respawns it took to get there, and the typed error behind each one
/// (in detection order — PR-8 asserts poisoned quantized segments
/// surface as `WireCorrupt`, not as a generic disconnect).
struct RunOutcome {
    seqs: Vec<Vec<i32>>,
    recoveries: usize,
    errors: Vec<EngineError>,
}

/// Serve `N_SEQS` sequences to `TARGET` tokens each through the mini
/// mesh, recovering from injected faults by respawn + replay of the
/// uncommitted iteration.
fn run_shape(shape: Shape, plan: FaultPlan) -> RunOutcome {
    run_shape_at(shape, plan, CommQuant::F32)
}

/// [`run_shape`] with an explicit wire rung for every ring collective
/// (PR-8: the recovery protocol is rung-agnostic; replay determinism
/// must hold even when the wire rounds).
fn run_shape_at(shape: Shape, plan: FaultPlan, rung: CommQuant) -> RunOutcome {
    run_shape_preempting_at(shape, plan, 0, rung)
}

/// Like [`run_shape`], but every `preempt_period` iterations the
/// least-advanced unfinished sequence is evicted from the packing set
/// for that iteration — the KV-pressure preemption motion (DESIGN.md
/// §15) in miniature. The victim re-enters on the next iteration from
/// its committed length, checkpoint-free; at least one sequence always
/// stays packed (the serve loop's anti-livelock guard). `0` disables
/// preemption.
fn run_shape_preempting(shape: Shape, plan: FaultPlan, preempt_period: usize) -> RunOutcome {
    run_shape_preempting_at(shape, plan, preempt_period, CommQuant::F32)
}

/// [`run_shape_preempting`] with an explicit wire rung.
fn run_shape_preempting_at(
    shape: Shape,
    plan: FaultPlan,
    preempt_period: usize,
    rung: CommQuant,
) -> RunOutcome {
    let max_recoveries = plan.events.len() + 2;
    let injector = Arc::new(FaultInjector::new(plan));
    let mut mesh = MiniMesh::spawn(shape, &injector, rung);
    let mut seqs: Vec<Vec<i32>> = vec![Vec::new(); N_SEQS];
    let mut recoveries = 0usize;
    let mut errors = Vec::new();
    let mut tick = 0usize;
    while seqs.iter().any(|s| s.len() < TARGET) {
        tick += 1;
        let victim = if preempt_period > 0 && tick % preempt_period == 0 {
            let unfinished: Vec<usize> = (0..N_SEQS).filter(|&i| seqs[i].len() < TARGET).collect();
            if unfinished.len() > 1 {
                unfinished
                    .into_iter()
                    .min_by_key(|&i| (seqs[i].len(), std::cmp::Reverse(i)))
            } else {
                None
            }
        } else {
            None
        };
        // Pack this iteration's rows: up to `lane` unfinished sequences,
        // `k` positions each — a pure function of committed state, which
        // is what makes replay bit-exact.
        let mut owners = Vec::new();
        let mut data = Vec::new();
        let mut picked = 0usize;
        for (id, s) in seqs.iter().enumerate() {
            if s.len() >= TARGET || Some(id) == victim {
                continue;
            }
            if picked == shape.lane {
                break;
            }
            picked += 1;
            for d in 0..shape.k.min(TARGET - s.len()) {
                let pos = s.len() + d;
                owners.push(id);
                data.extend((0..COLS).map(|c| ((id * 31 + pos * 7 + c * 3) % 13) as f32 / 13.0));
            }
        }
        let iteration = injector.begin_iteration();
        let outcome =
            mesh.broadcast(owners.len(), COLS, &data).and_then(|()| mesh.await_reply(iteration));
        match outcome {
            Ok(tokens) => {
                assert_eq!(tokens.len(), owners.len(), "reply row count mismatch");
                for (id, tok) in owners.iter().zip(&tokens) {
                    seqs[*id].push(*tok);
                }
            }
            Err(error) => {
                recoveries += 1;
                assert!(
                    recoveries <= max_recoveries,
                    "{}: recovery limit exhausted after {error}",
                    shape.name
                );
                // Checkpoint-free recovery in miniature: tear down,
                // respawn, re-run the uncommitted iteration. Consumed
                // fault events never re-fire (atomic claim), so the
                // retry loop always converges.
                errors.push(error);
                mesh.teardown();
                mesh = MiniMesh::spawn(shape, &injector, rung);
            }
        }
    }
    mesh.teardown();
    RunOutcome { seqs, recoveries, errors }
}

#[test]
fn chaos_sweep_zero_drops_and_token_identity() {
    for shape in SHAPES {
        let baseline = run_shape(shape, FaultPlan::empty());
        assert_eq!(baseline.recoveries, 0, "{}: fault-free run recovered", shape.name);
        let distinct: BTreeSet<i32> = baseline.seqs.iter().flatten().copied().collect();
        assert!(distinct.len() > 1, "{}: degenerate token stream", shape.name);
        let world = shape.pp * shape.tp;
        let mut plans = vec![
            "kill:rank=0:iter=2".to_string(),
            format!("kill:rank={}:iter=3", world - 1),
            "kill:rank=1:iter=2;kill:rank=0:iter=4".to_string(),
            "stall:rank=1:iter=2:ms=3".to_string(),
            "poison:rank=0:iter=2".to_string(),
        ];
        if shape.pp > 1 {
            plans.push("poison:rank=0:iter=2:p2p".to_string());
        }
        for seed in 1..=4u64 {
            plans.push(format!("seed={seed}:n=2:ranks={world}:iters=6"));
        }
        for spec in &plans {
            let plan = FaultPlan::parse(spec).expect("sweep specs are valid");
            let clock = Instant::now();
            let out = run_shape(shape, plan);
            assert!(
                clock.elapsed() < Duration::from_secs(60),
                "{} × {spec:?}: wall-clock bound blown",
                shape.name
            );
            for (id, s) in out.seqs.iter().enumerate() {
                assert_eq!(s.len(), TARGET, "{} × {spec:?}: seq {id} dropped tokens", shape.name);
            }
            assert_eq!(out.seqs, baseline.seqs, "{} × {spec:?}: tokens diverged", shape.name);
            if spec.starts_with("kill:") {
                assert!(out.recoveries >= 1, "{} × {spec:?}: kill did not recover", shape.name);
            }
            if spec.starts_with("stall:") {
                assert_eq!(out.recoveries, 0, "{} × {spec:?}: stall forced respawn", shape.name);
            }
        }
    }
}

#[test]
fn preemption_under_overload_with_kills_zero_drops() {
    // PR-7 satellite: preemption-heavy overload combined with kill-rank
    // plans. Every other iteration evicts the least-advanced live
    // sequence from the packing set; it resumes from its committed
    // length the next iteration. Because tokens commit only on a
    // successful reply and each row is a pure function of (id, pos),
    // preempted sequences must still finish with streams bit-identical
    // to the undisturbed fault-free run — zero drops, including the
    // sequences that were mid-eviction when a rank died.
    let shape = SHAPES[1]; // mixed: the lane-3 fused decode shape
    let baseline = run_shape(shape, FaultPlan::empty());
    for spec in ["", "kill:rank=1:iter=2", "kill:rank=0:iter=3;kill:rank=1:iter=5"] {
        let plan = if spec.is_empty() {
            FaultPlan::empty()
        } else {
            FaultPlan::parse(spec).expect("sweep specs are valid")
        };
        let clock = Instant::now();
        let out = run_shape_preempting(shape, plan, 2);
        assert!(
            clock.elapsed() < Duration::from_secs(60),
            "preempting × {spec:?}: wall-clock bound blown"
        );
        for (id, s) in out.seqs.iter().enumerate() {
            assert_eq!(s.len(), TARGET, "preempting × {spec:?}: seq {id} dropped tokens");
        }
        assert_eq!(out.seqs, baseline.seqs, "preempting × {spec:?}: tokens diverged");
        if spec.starts_with("kill:") {
            assert!(out.recoveries >= 1, "preempting × {spec:?}: kill did not recover");
        }
    }
}

#[test]
fn seeded_chaos_run_is_reproducible() {
    let shape = SHAPES[1]; // mixed
    let spec = "seed=9:n=3:ranks=2:iters=5";
    let a = run_shape(shape, FaultPlan::parse(spec).unwrap());
    let b = run_shape(shape, FaultPlan::parse(spec).unwrap());
    assert_eq!(a.seqs, b.seqs, "same seeded plan must reproduce the same tokens");
}

#[test]
fn poisoned_quantized_segments_typed_corrupt_and_token_identity() {
    // PR-8 satellite: the sub-int8 wire rungs (fp8 e5m2, packed int4)
    // ride the same supervised frames as f32, so a poisoned segment at
    // those rungs must (a) surface as a *typed* `WireCorrupt`, not a
    // generic disconnect, (b) cost zero sequences, and (c) replay to
    // token streams bit-identical to the fault-free run at the *same*
    // rung. Identity across rungs is not expected — lower rungs round
    // the wire (rust/tests/wire_precision.rs pins that drift) — so the
    // fault-free baseline is re-run per rung.
    for shape in [SHAPES[1], SHAPES[3]] {
        let world = shape.pp * shape.tp;
        for rung in [CommQuant::Fp8, CommQuant::Int4] {
            let baseline = run_shape_at(shape, FaultPlan::empty(), rung);
            assert_eq!(
                baseline.recoveries,
                0,
                "{} @ {}: fault-free run recovered",
                shape.name,
                rung.label()
            );
            let mut plans = vec![
                "poison:rank=0:iter=2".to_string(),
                format!("poison:rank={}:iter=3", world - 1),
            ];
            if shape.pp > 1 {
                plans.push("poison:rank=0:iter=2:p2p".to_string());
            }
            plans.push(format!("seed=11:n=2:ranks={world}:iters=6"));
            for spec in &plans {
                let plan = FaultPlan::parse(spec).expect("sweep specs are valid");
                let clock = Instant::now();
                let out = run_shape_at(shape, plan, rung);
                assert!(
                    clock.elapsed() < Duration::from_secs(60),
                    "{} @ {} × {spec:?}: wall-clock bound blown",
                    shape.name,
                    rung.label()
                );
                for (id, s) in out.seqs.iter().enumerate() {
                    assert_eq!(
                        s.len(),
                        TARGET,
                        "{} @ {} × {spec:?}: seq {id} dropped tokens",
                        shape.name,
                        rung.label()
                    );
                }
                assert_eq!(
                    out.seqs, baseline.seqs,
                    "{} @ {} × {spec:?}: tokens diverged from the fault-free run at this rung",
                    shape.name,
                    rung.label()
                );
                if spec.starts_with("poison:") {
                    assert!(
                        out.recoveries >= 1,
                        "{} @ {} × {spec:?}: poison did not force a recovery",
                        shape.name,
                        rung.label()
                    );
                    assert!(
                        out.errors.iter().any(|e| matches!(e, EngineError::WireCorrupt { .. })),
                        "{} @ {} × {spec:?}: poison surfaced as {:?}, not WireCorrupt",
                        shape.name,
                        rung.label(),
                        out.errors
                    );
                }
            }
        }
    }
}

#[test]
fn teardown_mid_iteration_terminates() {
    // Shutdown-hang regression in miniature: tear the mesh down while
    // an iteration (with a stalled rank) is still in flight. The
    // sender-drop cascade must unblock every thread; a hang here trips
    // the chaos CI job's hard timeout.
    let shape = SHAPES[1];
    let plan = FaultPlan::parse("stall:rank=1:iter=1:ms=50").unwrap();
    let injector = Arc::new(FaultInjector::new(plan));
    let mesh = MiniMesh::spawn(shape, &injector, CommQuant::F32);
    injector.begin_iteration();
    let data = vec![0.5f32; 2 * COLS];
    mesh.broadcast(2, COLS, &data).expect("fresh mesh accepts jobs");
    let clock = Instant::now();
    mesh.teardown();
    assert!(clock.elapsed() < Duration::from_secs(5), "teardown did not terminate promptly");
}

#[test]
fn auto_tuned_shape_runs_chaos_clean_at_planned_rung() {
    // PR-10 satellite: derive the mesh shape from the auto-tuner instead
    // of hand-picking it — plan for the 4-thread CPU testbed, take the
    // best candidate the mini mesh can host (pp×tp only; it has no cp
    // fabric), and run the chaos protocol at the *planned* wire rungs.
    // The recovery contract (zero drops, token identity vs the
    // fault-free baseline at the same rung) must hold for whatever
    // config the planner picks, not just the hand-enumerated SHAPES.
    use iso::hw::NodeProfile;
    use iso::model::ModelSpec;
    use iso::tune::{plan, Workload};

    let node = NodeProfile::cpu_engine(4, Some(64.0), 120.0);
    let model = ModelSpec::tiny_gqa();
    let w = Workload { prompt_len: 64, decode_steps: 16, decode_ctx: 64, ..Workload::mixed() };
    let p = plan(&node, &model, &w);
    let pc = p
        .ranked
        .iter()
        .find(|pc| {
            let t = pc.cfg.topology();
            t.cp == 1 && t.tp >= 2
        })
        .expect("a pp×tp candidate survives pruning on a 4-card node");
    let topo = pc.cfg.topology();
    let shape = Shape {
        name: "auto-tuned",
        pp: topo.pp,
        tp: topo.tp,
        lane: pc.cfg.decode_batch.clamp(1, N_SEQS),
        k: pc.cfg.spec_k.max(1),
    };
    let world = shape.pp * shape.tp;
    let prec = pc.cfg.precision();
    let mut rungs = vec![prec.prefill];
    if prec.decode != prec.prefill {
        rungs.push(prec.decode);
    }
    eprintln!(
        "auto-tuned chaos shape: {} → pp{}×tp{} lane {} k {}",
        pc.summary, shape.pp, shape.tp, shape.lane, shape.k
    );
    for rung in rungs {
        let baseline = run_shape_at(shape, FaultPlan::empty(), rung);
        assert_eq!(
            baseline.recoveries,
            0,
            "auto-tuned @ {}: fault-free run recovered",
            rung.label()
        );
        for spec in
            [format!("kill:rank={}:iter=2", world - 1), format!("seed=23:n=2:ranks={world}:iters=6")]
        {
            let fault_plan = FaultPlan::parse(&spec).expect("sweep specs are valid");
            let clock = Instant::now();
            let out = run_shape_at(shape, fault_plan, rung);
            assert!(
                clock.elapsed() < Duration::from_secs(60),
                "auto-tuned @ {} × {spec}: wall-clock bound blown",
                rung.label()
            );
            for (id, s) in out.seqs.iter().enumerate() {
                assert_eq!(
                    s.len(),
                    TARGET,
                    "auto-tuned @ {} × {spec}: seq {id} dropped tokens",
                    rung.label()
                );
            }
            assert_eq!(
                out.seqs, baseline.seqs,
                "auto-tuned @ {} × {spec}: tokens diverged from the fault-free run",
                rung.label()
            );
            if spec.starts_with("kill:") {
                assert!(
                    out.recoveries >= 1,
                    "auto-tuned @ {} × {spec}: kill did not force a recovery",
                    rung.label()
                );
            }
        }
    }
}
