//! WIRE-PRECISION LADDER — the PR-8 property harness. Pins the error
//! contract of every rung of the wire ladder (DESIGN.md §16) at three
//! levels, all pure rust (no model artifacts):
//!
//! * **codec round trips** — per-rung quantize→dequantize error bounds
//!   over random segment widths, rank counts, and adversarial
//!   magnitudes (denormals, zeros, ±inf, values past the fp8 saturation
//!   point), plus bit-exactness under row segmentation — the property
//!   that makes segment-streamed collectives byte-identical to
//!   monolithic ones;
//! * **ring reduction** — measured error of the segment-streamed and
//!   fused-rows all-reduces against an f64 golden stays under an
//!   analytic bound that is explicit in the world size (each of the
//!   ≤ 2(R−1) encode/decode events contributes at most one half-step at
//!   the partial-sum magnitude);
//! * **end to end** — a miniature pp×tp mesh (the chaos-harness shape
//!   set: sequential / mixed / spec / pp2×tp2) runs greedy decoding
//!   under a per-phase [`PrecisionPolicy`]: lossless rungs are
//!   bit-identical to the f32 baseline, int8 keeps token identity, and
//!   the sub-int8 rungs stay inside pinned drift bounds and are
//!   deterministic run to run.

use iso::collective::{ring, run_on_ring, stage_grid, RingHandle, StagePort};
use iso::config::{CommQuant, PrecisionPolicy};
use iso::quant;
use iso::util::prop::Prop;
use iso::util::rng::Rng;

// ------------------------------------------------------------- codecs --

/// A row magnitude from an adversarial exponent range: denormal-scale
/// through overflow-scale, plus exact zero.
fn adversarial_magnitude(rng: &mut Rng) -> f32 {
    match rng.range(0, 6) {
        0 => 0.0,
        1 => 1e-38,                          // denormal-scale rows
        2 => quant::FP8_MIN_NORMAL * 0.5,    // below the fp8 normal range
        3 => rng.f32_range(0.5, 2.0),        // activation scale
        4 => rng.f32_range(1e3, 6e4),        // near fp8 saturation
        _ => 1e30,                           // far past fp8 saturation
    }
}

fn fill_row(rng: &mut Rng, mag: f32, cols: usize) -> Vec<f32> {
    (0..cols).map(|_| rng.f32_range(-1.0, 1.0) * mag).collect()
}

#[test]
fn int8_roundtrip_half_step_per_row_any_magnitude() {
    Prop::new(0x81).cases(200).run("int8 round trip", |rng| {
        let (rows, cols) = (rng.range(1, 6), rng.range(1, 48));
        let mut x = Vec::new();
        for _ in 0..rows {
            let mag = adversarial_magnitude(rng);
            x.extend(fill_row(rng, mag, cols));
        }
        let q = quant::quantize_rows(&x, rows, cols);
        let y = quant::dequantize_rows(&q);
        for r in 0..rows {
            // Half a step per row, plus f32 slop proportional to the
            // row magnitude (v·inv and code·scale each round once).
            // The 1e-36 term covers the degenerate-scale contract: a
            // denormal row scale encodes the row as exact zeros
            // (`quant::row_scale`), leaving |v| ≤ ~4e-37 of error.
            let bound = q.scales[r] * 0.5 * 1.001 + q.scales[r] * 127.0 * 1e-5 + 1e-36;
            for c in 0..cols {
                let (v, d) = (x[r * cols + c], y[r * cols + c]);
                if !v.is_finite() {
                    continue; // ±inf clamps to full scale by contract
                }
                if (d - v).abs() > bound {
                    return Err(format!("row {r}: |{d} - {v}| > {bound}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn int4_roundtrip_half_step_per_row_any_magnitude() {
    Prop::new(0x41).cases(200).run("int4 round trip", |rng| {
        let (rows, cols) = (rng.range(1, 6), rng.range(1, 48));
        let mut x = Vec::new();
        for _ in 0..rows {
            let mag = adversarial_magnitude(rng);
            x.extend(fill_row(rng, mag, cols));
        }
        let q = quant::quantize4_rows(&x, rows, cols);
        let y = quant::dequantize4_rows(&q);
        let err = quant::max_roundtrip_error4(&q);
        for (i, (&v, &d)) in x.iter().zip(y.iter()).enumerate() {
            if !v.is_finite() {
                continue;
            }
            let bound = err * 1.001 + v.abs() * 1e-5 + 1e-36;
            if (d - v).abs() > bound {
                return Err(format!("elem {i}: |{d} - {v}| > {bound}"));
            }
        }
        Ok(())
    });
}

#[test]
fn fp8_roundtrip_format_bound_and_saturation() {
    Prop::new(0xF8).cases(400).run("fp8 round trip", |rng| {
        let mag = adversarial_magnitude(rng);
        let v = rng.f32_range(-1.0, 1.0) * mag;
        let d = quant::fp8_to_f32(quant::fp8_from_f32(v));
        let a = v.abs();
        if a > quant::FP8_MAX {
            // Saturating encode: adversarial magnitudes stay finite and
            // sign-correct on the wire.
            if d.abs() != quant::FP8_MAX || d.signum() != v.signum() {
                return Err(format!("{v} must saturate to ±FP8_MAX, got {d}"));
            }
        } else {
            let bound = (a * quant::FP8_REL_ERR).max(quant::FP8_ABS_ERR);
            if (d - v).abs() > bound {
                return Err(format!("|{d} - {v}| > {bound}"));
            }
        }
        Ok(())
    });
}

/// Row-local encodings are the property the segmented collectives rely
/// on: encoding a payload segment-by-segment, at any split, is
/// bit-identical to encoding it whole. Pinned for both scaled rungs
/// (fp8 is elementwise, so it holds trivially).
#[test]
fn segmentation_bit_exactness_any_split() {
    Prop::new(0x5E6).cases(100).run("segmented encode ==", |rng| {
        let (rows, cols) = (rng.range(2, 9), rng.range(1, 33));
        let mut x = Vec::new();
        for _ in 0..rows {
            let mag = adversarial_magnitude(rng);
            x.extend(fill_row(rng, mag, cols));
        }
        let cut = rng.range(1, rows);
        let whole8 = quant::quantize_rows(&x, rows, cols);
        let lo8 = quant::quantize_rows(&x[..cut * cols], cut, cols);
        let hi8 = quant::quantize_rows(&x[cut * cols..], rows - cut, cols);
        if [&lo8.data[..], &hi8.data[..]].concat() != whole8.data
            || [&lo8.scales[..], &hi8.scales[..]].concat() != whole8.scales
        {
            return Err(format!("int8 split at {cut} not bit-identical"));
        }
        let whole4 = quant::quantize4_rows(&x, rows, cols);
        let lo4 = quant::quantize4_rows(&x[..cut * cols], cut, cols);
        let hi4 = quant::quantize4_rows(&x[cut * cols..], rows - cut, cols);
        if [&lo4.data[..], &hi4.data[..]].concat() != whole4.data
            || [&lo4.scales[..], &hi4.scales[..]].concat() != whole4.scales
        {
            return Err(format!("int4 split at {cut} not bit-identical (nibble restart)"));
        }
        Ok(())
    });
}

// --------------------------------------------------------------- ring --

/// Elementwise error of one encode/decode event at partial-sum
/// magnitude `m`, per rung. Lossless rungs get the f32-accumulation
/// term only.
fn event_error(q: CommQuant, m: f32) -> f32 {
    match q {
        CommQuant::F32 | CommQuant::Fp16 => 0.0,
        CommQuant::Int8 => m / 127.0 * 0.5,
        CommQuant::Fp8 => (m * quant::FP8_REL_ERR).max(quant::FP8_ABS_ERR),
        CommQuant::Int4 => m / 7.0 * 0.5,
    }
}

/// Analytic ring bound: ≤ 2(R−1) encode/decode events (reduce-scatter
/// hops plus all-gather re-encodes), each at most one event error at
/// the largest partial-sum magnitude (R·pmax, with 1.5× slack for error
/// feedback into later scales), plus f32 accumulation slop.
fn ring_bound(q: CommQuant, n: usize, pmax: f32) -> f32 {
    let events = 2.0 * (n as f32 - 1.0);
    events * event_error(q, 1.5 * n as f32 * pmax) + n as f32 * pmax * 1e-5
}

fn rank_parts(n: usize, rows: usize, cols: usize, seed: u64) -> Vec<Vec<f32>> {
    (0..n)
        .map(|r| {
            let mut rng = Rng::new(seed ^ (r as u64 + 1).wrapping_mul(0x9E37));
            (0..rows * cols).map(|_| rng.f32_range(-1.0, 1.0)).collect()
        })
        .collect()
}

fn golden_sum(parts: &[Vec<f32>]) -> Vec<f64> {
    (0..parts[0].len())
        .map(|i| parts.iter().map(|p| p[i] as f64).sum::<f64>())
        .collect()
}

#[test]
fn segmented_ring_error_within_analytic_bound_in_world_size() {
    for n in [2usize, 4, 8] {
        let (rows, cols) = (8usize, 16usize);
        let parts = rank_parts(n, rows, cols, 0x517E);
        let golden = golden_sum(&parts);
        let pmax = parts.iter().flatten().fold(0.0f32, |a, &v| a.max(v.abs()));
        for q in CommQuant::LADDER {
            let segments = if n <= 4 { 2 } else { 1 };
            let results = run_on_ring(n, |r, h| {
                let mut data = parts[r].clone();
                h.allreduce_seg(&mut data, rows, cols, q, segments);
                data
            });
            let bound = ring_bound(q, n, pmax);
            for (rank, out) in results.iter().enumerate() {
                let err = out
                    .iter()
                    .zip(golden.iter())
                    .fold(0.0f32, |m, (&a, &g)| m.max((a as f64 - g).abs() as f32));
                assert!(
                    err <= bound,
                    "{}: rank {rank}/{n} seg ring err {err} > analytic {bound}",
                    q.label()
                );
            }
        }
    }
}

#[test]
fn fused_ring_error_within_analytic_bound_in_world_size() {
    for n in [2usize, 3, 4, 8] {
        let (rows, cols) = (5usize, 9usize); // deliberately ragged
        let parts = rank_parts(n, rows, cols, 0xF05E);
        let golden = golden_sum(&parts);
        let pmax = parts.iter().flatten().fold(0.0f32, |a, &v| a.max(v.abs()));
        for q in CommQuant::LADDER {
            let results = run_on_ring(n, |r, h| {
                let mut data = parts[r].clone();
                h.allreduce_rows_fused(&mut data, rows, cols, q);
                data
            });
            let bound = ring_bound(q, n, pmax);
            for (rank, out) in results.iter().enumerate() {
                let err = out
                    .iter()
                    .zip(golden.iter())
                    .fold(0.0f32, |m, (&a, &g)| m.max((a as f64 - g).abs() as f32));
                assert!(
                    err <= bound,
                    "{}: rank {rank}/{n} fused ring err {err} > analytic {bound}",
                    q.label()
                );
            }
        }
    }
}

// ---------------------------------------------------------- mini mesh --

const COLS: usize = 8;
const ITERS: usize = 4;

#[derive(Clone, Copy)]
struct Shape {
    name: &'static str,
    pp: usize,
    tp: usize,
    lane: usize,
    k: usize,
}

/// The chaos-harness shape set: every scheduler the coordinator runs,
/// in miniature.
const SHAPES: [Shape; 4] = [
    Shape { name: "sequential", pp: 1, tp: 2, lane: 1, k: 1 },
    Shape { name: "mixed", pp: 1, tp: 2, lane: 3, k: 1 },
    Shape { name: "spec", pp: 1, tp: 2, lane: 3, k: 2 },
    Shape { name: "pp2xtp2", pp: 2, tp: 2, lane: 3, k: 1 },
];

/// Deterministic "activation" input for one iteration. The 7/16 offset
/// and mod-19 grid keep every greedy row sum at least ~0.05 token
/// quanta away from a rounding boundary in the f32 run — an order of
/// magnitude more than the int8 rung can drift it, so the int8
/// token-identity assertion has real margin rather than luck.
fn mesh_input(iter: usize, rows: usize) -> Vec<f32> {
    (0..rows * COLS)
        .map(|i| 0.4375 + ((i * 31 + iter * 13) % 19) as f32 / 19.0)
        .collect()
}

/// Run the mini mesh under a wire-precision policy and return the
/// concatenated last-stage logits and greedy tokens. The step mirrors
/// the coordinator's split: per-layer collectives ride
/// `policy.prefill` through the segment-streamed path; the final
/// lane-fused collective rides `policy.decode` through
/// `allreduce_rows_fused` (DESIGN.md §16).
fn run_mesh(shape: Shape, policy: PrecisionPolicy) -> (Vec<f32>, Vec<i32>) {
    let rows = shape.lane * shape.k;
    let mut rings: Vec<Vec<RingHandle>> = (0..shape.pp).map(|_| ring(shape.tp)).collect();
    let mut grid: Vec<Vec<StagePort>> = stage_grid(shape.pp, shape.tp);
    let mut workers = Vec::new();
    for s in (0..shape.pp).rev() {
        for t in (0..shape.tp).rev() {
            workers.push((s, t, rings[s].pop().unwrap(), grid[s].pop().unwrap()));
        }
    }
    let mut result = None;
    std::thread::scope(|scope| {
        let mut join = Vec::new();
        for (s, t, mut rh, mut port) in workers {
            join.push(scope.spawn(move || {
                let mut logits = Vec::new();
                let mut tokens = Vec::new();
                for iter in 0..ITERS {
                    let mut data = if port.has_prev() {
                        port.recv_prev().2
                    } else {
                        mesh_input(iter, rows)
                    };
                    for layer in 0..2usize {
                        for v in data.iter_mut() {
                            *v = (*v + layer as f32 * 0.125) * (t as f32 + 1.0) * 0.25;
                        }
                        rh.allreduce_seg(&mut data, rows, COLS, policy.prefill, 2);
                    }
                    for v in data.iter_mut() {
                        *v *= 0.5;
                    }
                    rh.allreduce_rows_fused(&mut data, rows, COLS, policy.decode);
                    if port.has_next() {
                        port.send_next(data, rows, COLS);
                    } else if t == 0 {
                        tokens.extend(
                            data.chunks_exact(COLS)
                                .map(|row| (row.iter().sum::<f32>() / 8.0).round() as i32),
                        );
                        logits.extend_from_slice(&data);
                    }
                }
                (s, t, logits, tokens)
            }));
        }
        for j in join {
            let (s, t, logits, tokens) = j.join().expect("mesh rank panicked");
            if s == shape.pp - 1 && t == 0 {
                result = Some((logits, tokens));
            }
        }
    });
    result.expect("last stage produced output")
}

fn uniform(q: CommQuant) -> PrecisionPolicy {
    PrecisionPolicy { prefill: q, decode: q }
}

#[test]
fn e2e_lossless_rungs_bit_identical_to_f32() {
    for shape in SHAPES {
        let (gold_logits, gold_tokens) = run_mesh(shape, uniform(CommQuant::F32));
        let (fp16_logits, fp16_tokens) = run_mesh(shape, uniform(CommQuant::Fp16));
        // fp16 moves raw f32 on the CPU wire (DESIGN.md §16), so it is
        // a rung of the *cost* ladder only — numerics are identical.
        assert_eq!(gold_tokens, fp16_tokens, "{}", shape.name);
        assert_eq!(
            gold_logits.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            fp16_logits.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "{}: fp16 logits must be bit-identical",
            shape.name
        );
        assert_eq!(gold_tokens.len(), ITERS * shape.lane * shape.k, "{}", shape.name);
    }
}

#[test]
fn e2e_int8_token_identity_and_pinned_drift() {
    for shape in SHAPES {
        let (gold_logits, gold_tokens) = run_mesh(shape, uniform(CommQuant::F32));
        let (logits, tokens) = run_mesh(shape, uniform(CommQuant::Int8));
        let gmax = gold_logits.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        let drift = logits
            .iter()
            .zip(gold_logits.iter())
            .fold(0.0f32, |m, (&a, &g)| m.max((a - g).abs()));
        // ≤ 6 encode/decode events on this 2-rank mesh, each a half
        // int8 step of the running magnitude — far under the 8.0 token
        // quantum, so greedy tokens must survive the rung exactly.
        assert!(drift <= 0.30 * gmax.max(1.0), "{}: int8 drift {drift}", shape.name);
        assert_eq!(gold_tokens, tokens, "{}: int8 must keep token identity", shape.name);
    }
}

#[test]
fn e2e_sub_int8_rungs_pinned_drift_and_deterministic() {
    for shape in SHAPES {
        let (gold_logits, _) = run_mesh(shape, uniform(CommQuant::F32));
        let gmax = gold_logits.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        for q in [CommQuant::Fp8, CommQuant::Int4] {
            let (logits, tokens) = run_mesh(shape, uniform(q));
            let (logits2, tokens2) = run_mesh(shape, uniform(q));
            assert_eq!(tokens, tokens2, "{} {}: rung must be deterministic", shape.name, q.label());
            assert_eq!(
                logits.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                logits2.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "{} {}: reruns must be bit-identical",
                shape.name,
                q.label()
            );
            let drift = logits
                .iter()
                .zip(gold_logits.iter())
                .fold(0.0f32, |m, (&a, &g)| m.max((a - g).abs()));
            assert!(
                logits.iter().all(|v| v.is_finite()),
                "{} {}: non-finite logit",
                shape.name,
                q.label()
            );
            assert!(
                drift <= 1.5 * gmax.max(1.0),
                "{} {}: drift {drift} past pinned bound",
                shape.name,
                q.label()
            );
            assert_eq!(tokens.len(), ITERS * shape.lane * shape.k);
        }
    }
}

#[test]
fn e2e_mixed_policy_decode_rung_only_bounds_drift_tighter() {
    // Per-phase policy: prefill stays on the exact f32 rung, only the
    // fused decode collective drops down the ladder — the drift must be
    // no worse than running the whole mesh at the low rung.
    for shape in SHAPES {
        let (gold_logits, _) = run_mesh(shape, uniform(CommQuant::F32));
        let gmax = gold_logits.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        for q in [CommQuant::Int8, CommQuant::Fp8, CommQuant::Int4] {
            let mixed = PrecisionPolicy { prefill: CommQuant::F32, decode: q };
            let (logits, _) = run_mesh(shape, mixed);
            let (uni_logits, _) = run_mesh(shape, uniform(q));
            let drift = |xs: &[f32]| {
                xs.iter()
                    .zip(gold_logits.iter())
                    .fold(0.0f32, |m, (&a, &g)| m.max((a - g).abs()))
            };
            let (d_mixed, d_uni) = (drift(&logits), drift(&uni_logits));
            assert!(
                d_mixed <= d_uni + 0.25 * gmax.max(1.0),
                "{} {}: mixed-policy drift {d_mixed} worse than uniform {d_uni}",
                shape.name,
                q.label()
            );
        }
    }
}
