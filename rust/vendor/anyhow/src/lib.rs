//! Vendored minimal `anyhow` (DESIGN.md §5: the offline build pulls no
//! registry crates). Implements exactly the subset the `iso` crate uses —
//! `Error`, `Result`, `anyhow!`, `bail!`, and the `Context` extension
//! trait — with the same observable formatting contract as the real
//! crate: `{}` prints the outermost context, `{:#}` prints the whole
//! chain joined by `": "`, and `{:?}` prints a `Caused by:` list.
//!
//! Drop-in: replace the `[dependencies] anyhow` path entry with the
//! registry crate and nothing in `iso` changes.

use std::fmt;

/// An error chain: context messages wrapped around a root cause.
/// Stored innermost-first; the last entry is the outermost context.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message (the `anyhow!` macro).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Wrap this error in an outer context message.
    pub fn context<C: fmt::Display>(mut self, c: C) -> Error {
        self.chain.push(c.to_string());
        self
    }

    fn from_std<E: std::error::Error>(e: E) -> Error {
        // Flatten the source chain so `{:#}` shows root causes.
        let mut outermost_first = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            outermost_first.push(s.to_string());
            src = s.source();
        }
        outermost_first.reverse();
        Error { chain: outermost_first }
    }

    /// The innermost message of the chain.
    pub fn root_cause(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // "{:#}": outermost context first, then causes, one line.
            for (i, c) in self.chain.iter().rev().enumerate() {
                if i > 0 {
                    write!(f, ": ")?;
                }
                write!(f, "{c}")?;
            }
            Ok(())
        } else {
            write!(f, "{}", self.chain.last().expect("non-empty chain"))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.last().expect("non-empty chain"))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for c in self.chain.iter().rev().skip(1) {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

// Like the real anyhow: a blanket From over std errors. `Error` itself
// deliberately does not implement `std::error::Error`, which keeps this
// impl coherent with core's reflexive `From`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::from_std(e)
    }
}

/// `anyhow::Result<T>` — `Result` with `Error` as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

#[doc(hidden)]
pub mod ext {
    use super::Error;

    /// Sealed adapter: anything that can become an `Error`. The blanket
    /// impl covers std errors; the specific impl covers `Error` itself
    /// (coherent because `Error` is local and not a `std::error::Error`).
    pub trait IntoAnyhow: Sized {
        fn into_anyhow(self) -> Error;
    }

    impl<E> IntoAnyhow for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn into_anyhow(self) -> Error {
            Error::from_std(self)
        }
    }

    impl IntoAnyhow for Error {
        fn into_anyhow(self) -> Error {
            self
        }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` (any error convertible to `Error`) and `Option`.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: ext::IntoAnyhow,
{
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.map_err(|e| e.into_anyhow().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into_anyhow().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any `Display` value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn display_shows_outermost_context() {
        let e: Error = Err::<(), _>(io_err())
            .with_context(|| "reading manifest".to_string())
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
    }

    #[test]
    fn alternate_display_shows_chain() {
        let e: Error = Err::<(), _>(io_err()).context("reading manifest").unwrap_err();
        let s = format!("{e:#}");
        assert!(s.contains("reading manifest"), "{s}");
        assert!(s.contains("no such file"), "{s}");
    }

    #[test]
    fn context_on_anyhow_result_stacks() {
        let base: Result<()> = Err(Error::msg("inner"));
        let e = base.context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
    }

    #[test]
    fn macros_format_and_bail() {
        fn f(x: usize) -> Result<()> {
            if x > 2 {
                bail!("x too big: {x}");
            }
            Err(anyhow!("plain {}", "arg"))
        }
        assert_eq!(format!("{}", f(3).unwrap_err()), "x too big: 3");
        assert_eq!(format!("{}", f(1).unwrap_err()), "plain arg");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(format!("{e}"), "missing");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/here")?;
            Ok(s)
        }
        assert!(f().is_err());
    }
}
