//! Stub of the `xla-rs` PJRT API surface used by `iso::runtime`
//! (DESIGN.md §5). The offline build has no `xla_extension` shared
//! library, so this crate provides the same types and signatures with
//! host-side `Literal` arithmetic implemented for real and every
//! PJRT entry point returning a descriptive error.
//!
//! The engine degrades gracefully: `Manifest::load` fails before any
//! PJRT call when no artifacts are present, so the simulator, the
//! collective layer, and every pure-rust test run unmodified. To run
//! the real engine, point the `xla` dependency in `rust/Cargo.toml`
//! at an `xla-rs` checkout with `xla_extension` installed — the call
//! sites compile against either.

use std::fmt;

/// Stub error: carries which PJRT entry point was reached.
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn stub(what: &str) -> Error {
        Error(format!(
            "{what}: iso was built with the stub `xla` backend (no xla_extension); \
             point rust/Cargo.toml's `xla` dependency at xla-rs to run the real engine"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

#[doc(hidden)]
#[derive(Clone, Debug)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Element types a `Literal` can hold (f32 and i32 are all iso uses).
pub trait Element: Copy {
    #[doc(hidden)]
    fn wrap(v: Vec<Self>) -> Data;
    #[doc(hidden)]
    fn unwrap(d: &Data) -> Option<&[Self]>;
}

impl Element for f32 {
    fn wrap(v: Vec<f32>) -> Data {
        Data::F32(v)
    }
    fn unwrap(d: &Data) -> Option<&[f32]> {
        match d {
            Data::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl Element for i32 {
    fn wrap(v: Vec<i32>) -> Data {
        Data::I32(v)
    }
    fn unwrap(d: &Data) -> Option<&[i32]> {
        match d {
            Data::I32(v) => Some(v),
            _ => None,
        }
    }
}

/// Host-side literal: dims + typed payload. Fully functional.
#[derive(Clone, Debug)]
pub struct Literal {
    dims: Vec<i64>,
    data: Data,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: Element>(v: &[T]) -> Literal {
        Literal { dims: vec![v.len() as i64], data: T::wrap(v.to_vec()) }
    }

    /// Rank-0 literal.
    pub fn scalar<T: Element>(x: T) -> Literal {
        Literal { dims: Vec::new(), data: T::wrap(vec![x]) }
    }

    fn len(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Tuple(v) => v.len(),
        }
    }

    /// Reinterpret with new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, Error> {
        let n: i64 = dims.iter().product();
        if n as usize != self.len() {
            return Err(Error(format!(
                "reshape: {} elements into dims {dims:?}",
                self.len()
            )));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    /// Copy the payload out as a typed vec.
    pub fn to_vec<T: Element>(&self) -> Result<Vec<T>, Error> {
        T::unwrap(&self.data)
            .map(|v| v.to_vec())
            .ok_or_else(|| Error("to_vec: element type mismatch".into()))
    }

    /// Decompose a tuple literal into its parts.
    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        match self.data {
            Data::Tuple(v) => Ok(v),
            _ => Err(Error("to_tuple: not a tuple literal".into())),
        }
    }

    /// The literal's dimensions.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module (stub: loading always fails).
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(Error::stub("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation (stub).
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// PJRT CPU client (stub: construction always fails).
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(Error::stub("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error::stub("PjRtClient::compile"))
    }
}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error::stub("PjRtLoadedExecutable::execute"))
    }
}

/// Device buffer (stub).
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error::stub("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.dims(), &[2, 2]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn reshape_checks_element_count() {
        assert!(Literal::vec1(&[1i32, 2, 3]).reshape(&[2, 2]).is_err());
    }

    #[test]
    fn scalar_is_rank0() {
        let l = Literal::scalar(7i32);
        assert!(l.dims().is_empty());
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![7]);
    }

    #[test]
    fn pjrt_stubs_report_missing_backend() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("stub `xla` backend"));
    }
}
