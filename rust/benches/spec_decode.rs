//! BENCH — §6 extension: speculative-decode verify steps under ISO.
//!
//! The paper conjectures speculative sampling (k draft tokens per decode
//! step) makes overlap profitable in decode on the comm-heavy 4090-4.
//! Sweep k and context length on both platforms.

use iso::config::{SimExperiment, Strategy};
use iso::hw::NodeProfile;
use iso::model::ModelSpec;
use iso::sched::{spec_decode, Coster};
use iso::util::bench::section;

fn main() {
    for (gpu, cards, model) in [("4090", 4usize, "30b"), ("a800", 4, "70b")] {
        let e = SimExperiment::new(
            NodeProfile::by_name(gpu, cards).unwrap(),
            ModelSpec::by_name(model).unwrap(),
            4096,
            Strategy::Iso,
        );
        let contention = e.node.device.contention;
        let c = Coster::new(&e);
        section(&format!("speculative verify step — {model} on {gpu}-{cards}"));
        println!(
            "{:>6} {:>8} {:>12} {:>12} {:>10}",
            "k", "ctx", "serial/step", "iso/step", "gain"
        );
        for ctx in [4096usize, 16384] {
            for k in [1usize, 4, 16, 64, 128, 256, 512] {
                let (s, i) = spec_decode::verify_step_times(&c, k, ctx, contention);
                println!(
                    "{:>6} {:>7}k {:>10.3}ms {:>10.3}ms {:>9.1}%",
                    k,
                    ctx / 1024,
                    s * 1e3,
                    i * 1e3,
                    (s - i) / s * 100.0
                );
            }
            println!();
        }
    }
    println!("paper §6: decode-step overlap only pays once speculative k raises the");
    println!("per-step token count — and earlier on the comm-heavy 4090 than the A800.");
}
