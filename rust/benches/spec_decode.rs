//! BENCH — §6 extension: speculative decoding under ISO.
//!
//! Two halves, snapshotted to `BENCH_PR3.json` (override with
//! `ISO_PERF_SNAPSHOT_PR3`):
//!
//! * **Simulator k-sweep** (always runs): the paper-§6 verify-step
//!   overlap study, plus the PR-3 engine-matching fused-lane model —
//!   predicted accepted-token throughput of the real engine's verify
//!   lane across `k` and acceptance rates.
//! * **Engine k-sweep** (requires `make artifacts`): `serve_trace` with
//!   `spec_k ∈ {0, 1, 2, 4}` on a repetitive (draftable) trace —
//!   measured accepted-token throughput and acceptance rate next to the
//!   prediction.

use iso::config::{CommQuant, EngineConfig, SimExperiment, SplitPolicy, Strategy};
use iso::coordinator::Engine;
use iso::hw::NodeProfile;
use iso::model::ModelSpec;
use iso::report::{append_perf_records, PerfRecord};
use iso::runtime::Manifest;
use iso::sched::{spec_decode, Coster};
use iso::util::bench::section;
use iso::workload::Request;

fn snapshot_path() -> String {
    std::env::var("ISO_PERF_SNAPSHOT_PR3").unwrap_or_else(|_| "../BENCH_PR3.json".into())
}

/// Paper-§6 verify-step table (ISO vs serial inside one verify step).
fn sim_verify_overlap() {
    for (gpu, cards, model) in [("4090", 4usize, "30b"), ("a800", 4, "70b")] {
        let e = SimExperiment::new(
            NodeProfile::by_name(gpu, cards).unwrap(),
            ModelSpec::by_name(model).unwrap(),
            4096,
            Strategy::Iso,
        );
        let contention = e.node.device.contention;
        let c = Coster::new(&e);
        section(&format!("speculative verify step — {model} on {gpu}-{cards}"));
        println!(
            "{:>6} {:>8} {:>12} {:>12} {:>10}",
            "k", "ctx", "serial/step", "iso/step", "gain"
        );
        for ctx in [4096usize, 16384] {
            for k in [1usize, 4, 16, 64, 128, 256, 512] {
                let (s, i) = spec_decode::verify_step_times(&c, k, ctx, contention);
                println!(
                    "{:>6} {:>7}k {:>10.3}ms {:>10.3}ms {:>9.1}%",
                    k,
                    ctx / 1024,
                    s * 1e3,
                    i * 1e3,
                    (s - i) / s * 100.0
                );
            }
            println!();
        }
    }
    println!("paper §6: decode-step overlap only pays once speculative k raises the");
    println!("per-step token count — and earlier on the comm-heavy 4090 than the A800.");
}

/// PR-3 prediction: the engine-matching fused-lane model's k-sweep.
fn sim_lane_sweep(path: &str) {
    let e = SimExperiment::new(
        NodeProfile::rtx4090(4),
        ModelSpec::mha_30b(),
        4096,
        Strategy::Iso,
    );
    let c = Coster::new(&e);
    let (b, ctx) = (8usize, 2048usize);
    section("simulator: fused verify lane tokens/s vs k (4090-4, 30b, b=8, ctx=2048)");
    let mut records = Vec::new();
    for k in [0usize, 1, 2, 4, 8] {
        let iter_ms = spec_decode::fused_verify_iteration_s(&c, b, k + 1, ctx) * 1e3;
        print!("  k={k}: iter {iter_ms:.3}ms;");
        let mut rec = PerfRecord::new(&format!("sim lane k{k}"), iter_ms, iter_ms, iter_ms)
            .with("spec_k", k as f64);
        for accept in [0.0f64, 0.5, 0.8, 0.95] {
            let tok_s = spec_decode::spec_lane_tokens_per_s(&c, b, k, ctx, accept);
            print!("  α={accept}: {tok_s:.0} tok/s");
            rec = rec.with(&format!("tok_s_accept{}", (accept * 100.0) as usize), tok_s);
        }
        println!();
        records.push(rec);
    }
    if let Err(e) = append_perf_records(path, "sim_spec_lane", &records) {
        eprintln!("could not write {path}: {e}");
    }
}

/// Engine measurement: accepted-token throughput across spec_k on a
/// repetitive trace the n-gram proposer can actually draft.
fn engine_spec_sweep(path: &str) -> anyhow::Result<()> {
    if Manifest::load("artifacts").is_err() {
        eprintln!("SKIP engine spec sweep: run `make artifacts` first");
        return Ok(());
    }
    // Period-4 prompts make self-drafting productive even on the tiny
    // random-weight model (the continuation after any bigram repeats).
    let reqs: Vec<Request> = (0..6)
        .map(|i| Request {
            id: i,
            arrival_s: 0.0,
            prompt: (0..48).map(|j| ((j % 4) + 10 * (i as usize % 3)) as i32).collect(),
            decode_steps: 24,
        })
        .collect();

    section("engine: serve_trace accepted-token throughput vs spec_k (tp=2, pcie-emu)");
    let mut records = Vec::new();
    for spec_k in [0usize, 1, 2, 4] {
        let mut c = EngineConfig {
            strategy: Strategy::Iso,
            split: SplitPolicy::Even,
            comm_quant: CommQuant::F32,
            tp: 2,
            max_chunk: 64,
            max_batch: 8,
            link_mbps: Some(40.0),
            ..Default::default()
        };
        c.link_alpha_us = 5.0;
        c.spec_k = spec_k;
        let mut engine = Engine::start(c)?;
        let trace = engine.serve_trace(&reqs)?;
        let report = engine.shutdown()?;
        let m = report.metrics;
        let tok_s = trace.throughput_tok_s();
        println!(
            "  spec_k={spec_k}: {tok_s:>7.1} tok/s  iterations={}  windows={}  \
             accept_rate={:.3}  fused_rows={}",
            trace.iterations,
            m.spec_windows,
            m.acceptance_rate(),
            report.workers.iter().map(|w| w.fused_rows).sum::<u64>()
        );
        records.push(
            PerfRecord::new(
                &format!("engine spec_k{spec_k}"),
                trace.wall_s * 1e3,
                trace.wall_s * 1e3,
                trace.wall_s * 1e3,
            )
            .with("spec_k", spec_k as f64)
            .with("tok_s", tok_s)
            .with("iterations", trace.iterations as f64)
            .with("spec_windows", m.spec_windows as f64)
            .with("accept_rate", m.acceptance_rate()),
        );
    }
    if let Err(e) = append_perf_records(path, "e2e_engine_spec", &records) {
        eprintln!("could not write {path}: {e}");
    } else {
        println!("  wrote spec-decode sweep to {path}");
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let path = snapshot_path();
    sim_verify_overlap();
    sim_lane_sweep(&path);
    engine_spec_sweep(&path)?;
    Ok(())
}
