//! BENCH — Figure 3 / §6: adaptive attention/MLP imbalance splitting.
//!
//! Sweeps split policies across platforms and context lengths; verifies
//! that (i) the balanced policies shrink the chunk-time imbalance the
//! paper describes, and (ii) they never lose to the 50/50 split.

use iso::config::{SimExperiment, SplitPolicy, Strategy};
use iso::hw::NodeProfile;
use iso::model::ModelSpec;
use iso::sched::prefill_s;
use iso::split::{attn_imbalance, choose_split, imbalance};
use iso::util::bench::section;

fn main() {
    let policies = [
        ("even", SplitPolicy::Even),
        ("ratio:0.55", SplitPolicy::Ratio(0.55)),
        ("ratio:0.6", SplitPolicy::Ratio(0.6)),
        ("attn-balanced", SplitPolicy::AttnBalanced),
        ("adaptive(fig3)", SplitPolicy::AdaptiveAttnMlp),
    ];

    for (gpu, cards, model_name) in
        [("4090", 4usize, "30b"), ("4090", 4, "70b"), ("a800", 8, "70b")]
    {
        let node = NodeProfile::by_name(gpu, cards).unwrap();
        let model = ModelSpec::by_name(model_name).unwrap();
        section(&format!("Fig 3 — {model_name} on {gpu}-{cards}"));
        println!(
            "{:<16} {:>8} {:>9} {:>12} {:>12} {:>12}",
            "policy", "len", "t0 frac", "chunk imbal", "attn imbal", "prefill"
        );
        for len in [4096usize, 16384, 65536] {
            let mut best = f64::INFINITY;
            let mut even_t = 0.0;
            for (name, p) in policies {
                let s = choose_split(p, &node, &model, len);
                let mut e =
                    SimExperiment::new(node.clone(), model.clone(), len, Strategy::Iso);
                e.split = p;
                e.gemm_segments = if gpu == "a800" { 4 } else { 1 };
                let t = prefill_s(&e);
                if p == SplitPolicy::Even {
                    even_t = t;
                }
                best = best.min(t);
                println!(
                    "{:<16} {:>7}k {:>9.2} {:>11.1}% {:>11.1}% {:>10.1}ms",
                    name,
                    len / 1024,
                    s.t0 as f64 / len as f64,
                    imbalance(&node, &model, &s) * 100.0,
                    attn_imbalance(&node, &model, &s) * 100.0,
                    t * 1e3
                );
            }
            println!(
                "{:<16} {:>7}k best saves {:.1}% vs even\n",
                "→",
                len / 1024,
                (even_t - best) / even_t * 100.0
            );
            assert!(best <= even_t * 1.001, "a balanced policy lost to even");
        }
    }
}
