//! BENCH — the real engine end-to-end: serial vs ISO TTFT on the tiny
//! model executed through PJRT + ring collectives, plus decode latency
//! and the PR-1 segment-streaming sweep. This is the L3 hot-path
//! benchmark the §Perf pass optimizes.
//!
//! Appends machine-readable sections to `BENCH_PR1.json` (override with
//! `ISO_PERF_SNAPSHOT`): the engine's measured segments ∈ {1,2,4,8}
//! sweep next to the simulator's `ar_s(t, segments)` pipelined-tile
//! prediction, so the sim-vs-engine trend direction is recorded per PR.
//!
//! Requires `make artifacts`.

use iso::config::{CommQuant, EngineConfig, SimExperiment, SplitPolicy, Strategy};
use iso::coordinator::Engine;
use iso::hw::NodeProfile;
use iso::model::ModelSpec;
use iso::report::{append_perf_records, PerfRecord};
use iso::runtime::Manifest;
use iso::sched::Coster;
use iso::util::bench::{bench, section};

fn cfg(strategy: Strategy, tp: usize, quant: CommQuant, link_mbps: Option<f64>) -> EngineConfig {
    EngineConfig {
        strategy,
        split: SplitPolicy::Even,
        comm_quant: quant,
        tp,
        max_chunk: 64,
        link_mbps,
        ..Default::default()
    }
}

fn snapshot_path() -> String {
    std::env::var("ISO_PERF_SNAPSHOT").unwrap_or_else(|_| "../BENCH_PR1.json".into())
}

/// Simulator prediction for the exposed (un-hidden) time of one
/// segment-streamed all-reduce: the first comm tile is always exposed;
/// each later tile hides up to one compute tile behind it (paper §3.2,
/// Fig 1b — the same pipelined-tile model `sched::build_gemm_overlap`
/// lowers). Strictly decreasing in `segments` while compute tiles are
/// nonzero, which is the direction the engine sweep must reproduce.
fn sim_exposed_ar_s(c: &Coster, t: usize, segments: usize) -> f64 {
    let ar_tile = c.ar_s(t, segments);
    let gemm_tile = c.o_proj_seg_s(t, segments);
    ar_tile + (segments as f64 - 1.0) * (ar_tile - gemm_tile).max(0.0)
}

fn main() -> anyhow::Result<()> {
    let path = snapshot_path();

    // --- simulator side of the segment sweep (no artifacts needed).
    let sim_exp = SimExperiment::new(
        NodeProfile::rtx4090(4),
        ModelSpec::mha_30b(),
        4096,
        Strategy::Iso,
    );
    let coster = Coster::new(&sim_exp);
    let mut sim_records = Vec::new();
    section("simulator: predicted exposed AR time vs segments (4090-4, 30b, t=4096)");
    for segments in [1usize, 2, 4, 8] {
        let exposed_ms = sim_exposed_ar_s(&coster, 4096, segments) * 1e3;
        println!("  segments={segments}: exposed {exposed_ms:.3}ms");
        let case = format!("sim 4090-4 30b t4096 seg{segments}");
        sim_records.push(
            PerfRecord::new(&case, exposed_ms, exposed_ms, exposed_ms)
                .with("segments", segments as f64)
                .with("exposed_ms", exposed_ms),
        );
    }
    if let Err(e) = append_perf_records(&path, "sim_segments", &sim_records) {
        eprintln!("could not write {path}: {e}");
    }

    if Manifest::load("artifacts").is_err() {
        eprintln!("SKIP e2e_engine bench: run `make artifacts` first");
        return Ok(());
    }
    let prompt: Vec<i32> = (0..128).map(|i| ((i * 31) % 512) as i32).collect();

    for tp in [2usize, 4] {
        section(&format!("prefill TTFT, tp={tp} (128-token prompt)"));
        let mut results = Vec::new();
        for (name, strat, quant, link) in [
            ("serial/f32 native", Strategy::Serial, CommQuant::F32, None),
            ("iso/f32 native", Strategy::Iso, CommQuant::F32, None),
            ("serial/f32 pcie-emu", Strategy::Serial, CommQuant::F32, Some(40.0)),
            ("iso/f32 pcie-emu", Strategy::Iso, CommQuant::F32, Some(40.0)),
            ("iso/int8 pcie-emu", Strategy::Iso, CommQuant::Int8, Some(40.0)),
        ] {
            let mut engine = Engine::start(cfg(strat, tp, quant, link))?;
            engine.prefill(&prompt)?; // warmup
            let r = bench(&format!("tp{tp} {name}"), 1, 8, || {
                engine.prefill(&prompt).unwrap();
            });
            let report = engine.shutdown()?;
            let eff = report.workers.iter().map(|w| w.overlap_efficiency()).sum::<f64>()
                / report.workers.len() as f64;
            println!("    overlap efficiency {eff:.2}");
            results.push((name, r.mean_ms));
        }
        let native = (results[0].1 - results[1].1) / results[0].1;
        let pcie = (results[2].1 - results[3].1) / results[2].1;
        println!("  → ISO reduction: native {:.1}%, pcie-emulated {:.1}%", native * 100.0, pcie * 100.0);
    }

    // --- PR-1 tentpole: comm_segments sweep on the throttled (4090 PCIe
    // calibration) link. Wall time and exposed comm should trend down
    // from segments=1 to 4, matching the simulator's direction above.
    section("engine: ISO prefill vs comm_segments (tp=2, pcie-emu 40 MB/s, α=5µs)");
    let mut eng_records = Vec::new();
    let mut prev_exposed = f64::INFINITY;
    for segments in [1usize, 2, 4, 8] {
        let mut c = cfg(Strategy::Iso, 2, CommQuant::F32, Some(40.0));
        c.link_alpha_us = 5.0;
        c.comm_segments = segments;
        let mut engine = Engine::start(c)?;
        engine.prefill(&prompt)?; // warmup
        let r = bench(&format!("tp2 iso pcie-emu segments={segments}"), 1, 6, || {
            engine.prefill(&prompt).unwrap();
        });
        let report = engine.shutdown()?;
        let m = report.metrics;
        println!(
            "    exposed {:.2}ms overlapped {:.2}ms wire_msgs {} seg_acks {}",
            m.exposed_ms, m.overlapped_ms, m.comm_msgs, m.seg_acks
        );
        if segments <= 4 {
            if m.exposed_ms > prev_exposed {
                println!("    (warning: exposed comm did not decrease at segments={segments})");
            }
            prev_exposed = m.exposed_ms;
        }
        let case = format!("tp2 iso pcie-emu seg{segments}");
        eng_records.push(
            PerfRecord::new(&case, r.mean_ms, r.p50_ms, r.p95_ms)
                .with("segments", segments as f64)
                .with("exposed_ms", m.exposed_ms)
                .with("overlapped_ms", m.overlapped_ms)
                .with("wire_msgs", m.comm_msgs as f64)
                .with("seg_acks", m.seg_acks as f64),
        );
    }
    if let Err(e) = append_perf_records(&path, "e2e_engine_segments", &eng_records) {
        eprintln!("could not write {path}: {e}");
    } else {
        println!("  wrote engine segment sweep to {path}");
    }

    section("decode step latency (t=1 chunks, blocking — overlap unprofitable per paper)");
    let mut engine = Engine::start(cfg(Strategy::Iso, 2, CommQuant::F32, None))?;
    let short: Vec<i32> = (0..32).map(|i| i as i32).collect();
    engine.generate(&short, 2)?; // warmup
    bench("tp2 decode 8 steps", 1, 5, || {
        engine.generate(&short, 8).unwrap();
    });
    engine.shutdown()?;

    Ok(())
}
