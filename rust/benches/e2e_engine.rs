//! BENCH — the real engine end-to-end: serial vs ISO TTFT on the tiny
//! model executed through PJRT + ring collectives, plus decode latency.
//! This is the L3 hot-path benchmark the §Perf pass optimizes.
//!
//! Requires `make artifacts`.

use iso::config::{CommQuant, EngineConfig, SplitPolicy, Strategy};
use iso::coordinator::Engine;
use iso::runtime::Manifest;
use iso::util::bench::{bench, section};

fn cfg(strategy: Strategy, tp: usize, quant: CommQuant, link_mbps: Option<f64>) -> EngineConfig {
    EngineConfig {
        strategy,
        split: SplitPolicy::Even,
        comm_quant: quant,
        tp,
        max_chunk: 64,
        link_mbps,
        ..Default::default()
    }
}

fn main() -> anyhow::Result<()> {
    if Manifest::load("artifacts").is_err() {
        eprintln!("SKIP e2e_engine bench: run `make artifacts` first");
        return Ok(());
    }
    let prompt: Vec<i32> = (0..128).map(|i| ((i * 31) % 512) as i32).collect();

    for tp in [2usize, 4] {
        section(&format!("prefill TTFT, tp={tp} (128-token prompt)"));
        let mut results = Vec::new();
        for (name, strat, quant, link) in [
            ("serial/f32 native", Strategy::Serial, CommQuant::F32, None),
            ("iso/f32 native", Strategy::Iso, CommQuant::F32, None),
            ("serial/f32 pcie-emu", Strategy::Serial, CommQuant::F32, Some(40.0)),
            ("iso/f32 pcie-emu", Strategy::Iso, CommQuant::F32, Some(40.0)),
            ("iso/int8 pcie-emu", Strategy::Iso, CommQuant::Int8, Some(40.0)),
        ] {
            let mut engine = Engine::start(cfg(strat, tp, quant, link))?;
            engine.prefill(&prompt)?; // warmup
            let r = bench(&format!("tp{tp} {name}"), 1, 8, || {
                engine.prefill(&prompt).unwrap();
            });
            let report = engine.shutdown()?;
            let eff = report.workers.iter().map(|w| w.overlap_efficiency()).sum::<f64>()
                / report.workers.len() as f64;
            println!("    overlap efficiency {eff:.2}");
            results.push((name, r.mean_ms));
        }
        let native = (results[0].1 - results[1].1) / results[0].1;
        let pcie = (results[2].1 - results[3].1) / results[2].1;
        println!("  → ISO reduction: native {:.1}%, pcie-emulated {:.1}%", native * 100.0, pcie * 100.0);
    }

    section("decode step latency (t=1 chunks, blocking — overlap unprofitable per paper)");
    let mut engine = Engine::start(cfg(Strategy::Iso, 2, CommQuant::F32, None))?;
    let short: Vec<i32> = (0..32).map(|i| i as i32).collect();
    engine.generate(&short, 2)?; // warmup
    bench("tp2 decode 8 steps", 1, 5, || {
        engine.generate(&short, 8).unwrap();
    });
    engine.shutdown()?;

    Ok(())
}
