//! BENCH — the real engine end-to-end: serial vs ISO TTFT on the tiny
//! model executed through PJRT + ring collectives, plus decode latency,
//! the PR-1 segment-streaming sweep, and the PR-2 mixed-batching sweep
//! (decode-batch width × prefill:decode mix). This is the L3 hot-path
//! benchmark the §Perf pass optimizes.
//!
//! Appends machine-readable sections to `BENCH_PR1.json` (override with
//! `ISO_PERF_SNAPSHOT`), `BENCH_PR2.json` (`ISO_PERF_SNAPSHOT_PR2`),
//! `BENCH_PR4.json` (`ISO_PERF_SNAPSHOT_PR4`, the PP×TP sweep CI gates
//! against `BENCH_BASELINE.json`), `BENCH_PR5.json`
//! (`ISO_PERF_SNAPSHOT_PR5`, the fused-epilogue sweep, also CI-gated),
//! `BENCH_PR6.json` (`ISO_PERF_SNAPSHOT_PR6`, the fault-rate ×
//! recovery-overhead sweep, also CI-gated), `BENCH_SLO.json`
//! (`ISO_PERF_SNAPSHOT_SLO`, the PR-7 offered-load SLO frontier, also
//! CI-gated), `BENCH_PRECISION.json` (`ISO_PERF_SNAPSHOT_PRECISION`,
//! the PR-8 wire-precision ladder, also CI-gated), and `BENCH_CP.json`
//! (`ISO_PERF_SNAPSHOT_CP`, the PR-9 context-parallel factorization
//! sweep, also CI-gated): each engine sweep is recorded next to the
//! simulator's prediction, so the sim-vs-engine trend direction is
//! recorded per PR.
//!
//! Requires `make artifacts` for the engine sections; the simulator
//! sections always run.

use iso::config::{CommQuant, EngineConfig, SimExperiment, SplitPolicy, Strategy};
use iso::coordinator::Engine;
use iso::hw::NodeProfile;
use iso::model::ModelSpec;
use iso::report::{append_perf_records, PerfRecord};
use iso::runtime::Manifest;
use iso::sched::{
    bounded_tbt_s, cp_best_config, cp_iteration_s, epilogue_exposed_s, epilogue_s,
    expected_overhead_frac, fused_epilogue_iteration_s, iteration_deadline_s, mixed_iteration_s,
    pp_best_config, pp_bubble_fraction, pp_iteration_s, recovery_s, slo_admitted_frac, slo_ttft_s,
    Coster, MixedIteration,
};
use iso::tune::{self, Workload};
use iso::util::bench::{bench, section};
use iso::workload::{LenDist, TraceGen};

fn cfg(strategy: Strategy, tp: usize, quant: CommQuant, link_mbps: Option<f64>) -> EngineConfig {
    EngineConfig {
        strategy,
        split: SplitPolicy::Even,
        comm_quant: quant,
        tp,
        max_chunk: 64,
        link_mbps,
        ..Default::default()
    }
}

fn snapshot_path() -> String {
    std::env::var("ISO_PERF_SNAPSHOT").unwrap_or_else(|_| "../BENCH_PR1.json".into())
}

fn pr2_snapshot_path() -> String {
    std::env::var("ISO_PERF_SNAPSHOT_PR2").unwrap_or_else(|_| "../BENCH_PR2.json".into())
}

fn pr4_snapshot_path() -> String {
    std::env::var("ISO_PERF_SNAPSHOT_PR4").unwrap_or_else(|_| "../BENCH_PR4.json".into())
}

fn pr5_snapshot_path() -> String {
    std::env::var("ISO_PERF_SNAPSHOT_PR5").unwrap_or_else(|_| "../BENCH_PR5.json".into())
}

fn pr6_snapshot_path() -> String {
    std::env::var("ISO_PERF_SNAPSHOT_PR6").unwrap_or_else(|_| "../BENCH_PR6.json".into())
}

fn slo_snapshot_path() -> String {
    std::env::var("ISO_PERF_SNAPSHOT_SLO").unwrap_or_else(|_| "../BENCH_SLO.json".into())
}

fn precision_snapshot_path() -> String {
    std::env::var("ISO_PERF_SNAPSHOT_PRECISION")
        .unwrap_or_else(|_| "../BENCH_PRECISION.json".into())
}

fn cp_snapshot_path() -> String {
    std::env::var("ISO_PERF_SNAPSHOT_CP").unwrap_or_else(|_| "../BENCH_CP.json".into())
}

fn tune_snapshot_path() -> String {
    std::env::var("ISO_PERF_SNAPSHOT_TUNE").unwrap_or_else(|_| "../BENCH_TUNE.json".into())
}

/// The PP×TP factorizations of a 4-device node that the deterministic
/// (CI-gated) simulator sweep exercises.
const PP_CONFIGS: [(usize, usize); 3] = [(1, 4), (2, 2), (4, 1)];

/// The engine sweep's candidate set: the 4-device factorizations plus
/// the cheaper 2-device ones, so the measured sweep also covers the
/// small-world regime. The predicted-vs-measured comparison runs over
/// exactly this list.
const ENGINE_PP_CONFIGS: [(usize, usize); 5] = [(1, 2), (2, 1), (1, 4), (2, 2), (4, 1)];

/// Simulator side of the PR-4 sweep (no artifacts needed, fully
/// deterministic — this section is what `scripts/check_bench_regression.py`
/// gates against `BENCH_BASELINE.json` in CI): predicted prefill time of
/// a 4096-token prompt in 4 micro-batch chunks on a modeled 4-card 4090
/// node, factored as 1×4 / 2×2 / 4×1 (pp × tp). Deeper pipelines shrink
/// every all-reduce ring but pay fill/drain bubbles and p2p hops — the
/// recorded `pred_prefill_tok_s` / `pred_exposed_ms_per_tok` directions
/// are the ones the engine sweep below must reproduce.
fn sim_pp_sweep(path: &str) {
    let node = NodeProfile::rtx4090(4);
    let model = ModelSpec::mha_30b();
    let (prompt, chunks) = (4096usize, 4usize);
    let p2p = node.link;
    section("simulator: PP×TP factorization of a 4-card 4090 (30b, t=4096, 4 chunks)");
    let mut records = Vec::new();
    for (pp, tp) in PP_CONFIGS {
        let s = pp_iteration_s(&node, &model, prompt, chunks, pp, tp, &p2p, true);
        // Blocking model: every ring all-reduce is exposed; per-token
        // exposure falls as the per-stage ring shrinks.
        let t = prompt / chunks;
        let wire = (t * model.d_model * model.act_bytes) as f64 * iso::hw::INT8_WIRE_FACTOR;
        let ar_layer = 2.0 * node.link.ring_allreduce_s(wire, tp);
        let exposed_ms_per_tok = model.n_layers as f64 * ar_layer / t as f64 * 1e3;
        let pred_ms = s * 1e3;
        println!(
            "  pp{pp}×tp{tp}: {pred_ms:9.2}ms  {:8.0} tok/s  exposed {:.4}ms/tok  bubble {:.2}",
            prompt as f64 / s,
            exposed_ms_per_tok,
            pp_bubble_fraction(pp, chunks)
        );
        records.push(
            PerfRecord::new(&format!("sim pp{pp} tp{tp}"), pred_ms, pred_ms, pred_ms)
                .with("pp", pp as f64)
                .with("tp", tp as f64)
                .with("pred_prefill_tok_s", prompt as f64 / s)
                .with("pred_exposed_ms_per_tok", exposed_ms_per_tok)
                .with("bubble_frac", pp_bubble_fraction(pp, chunks)),
        );
    }
    let best = pp_best_config(&node, &model, prompt, chunks, &PP_CONFIGS, &p2p, true);
    println!("  → predicted fastest factorization: pp{}×tp{}", best.0, best.1);
    if let Err(e) = append_perf_records(path, "sim_pp", &records) {
        eprintln!("could not write {path}: {e}");
    }
}

/// Engine side of the PR-4 sweep: measured prefill across PP×TP
/// factorizations on the throttled link, recorded next to the cost
/// model's predicted fastest config so the sweep direction is pinned per
/// PR (EXPERIMENTS.md).
fn engine_pp_sweep(path: &str) -> anyhow::Result<()> {
    let prompt: Vec<i32> = (0..128).map(|i| ((i * 31) % 512) as i32).collect();
    section("engine: prefill PP×TP sweep (tiny model, pcie-emu 40 MB/s, α=5µs)");
    let mut records = Vec::new();
    let mut measured_best: Option<(f64, (usize, usize))> = None;
    for (pp, tp) in ENGINE_PP_CONFIGS {
        let mut c = cfg(Strategy::Iso, tp, CommQuant::F32, Some(40.0));
        c.link_alpha_us = 5.0;
        c.pp_stages = pp;
        let mut engine = Engine::start(c)?;
        engine.prefill(&prompt)?; // warmup
        let r = bench(&format!("pp{pp}×tp{tp} iso pcie-emu"), 1, 6, || {
            engine.prefill(&prompt).unwrap();
        });
        let report = engine.shutdown()?;
        let m = report.metrics;
        let tok_s = 128.0 / (r.mean_ms / 1e3);
        println!(
            "    {tok_s:7.0} tok/s  exposed {:.4}ms/tok  p2p {}B in {} msgs",
            m.exposed_ms_per_token(),
            m.p2p_bytes,
            m.p2p_msgs
        );
        records.push(
            PerfRecord::new(&format!("engine pp{pp} tp{tp}"), r.mean_ms, r.p50_ms, r.p95_ms)
                .with("pp", pp as f64)
                .with("tp", tp as f64)
                .with("prefill_tok_s", tok_s)
                .with("exposed_ms_per_tok", m.exposed_ms_per_token())
                .with("p2p_bytes", m.p2p_bytes as f64),
        );
        let improved = match measured_best {
            None => true,
            Some((best_ms, _)) => r.mean_ms < best_ms,
        };
        if improved {
            measured_best = Some((r.mean_ms, (pp, tp)));
        }
    }
    // Predicted direction from the engine's own calibrated profile, the
    // exact layer-to-stage assignment, the chunk plan each config
    // actually runs (`plan_prefill_pp` with that config's micro-batch
    // depth), and ISO's pair-granular forwarding: the engine wavefronts
    // chunk *pairs* between stages (DESIGN.md §11), so the model's
    // micro-batch count is ceil(chunks / 2).
    let node = NodeProfile::cpu_engine(1, Some(40.0), 5.0);
    let model = ModelSpec::tiny_gqa();
    let p2p = node.link;
    let predict = |pp: usize, tp: usize| {
        // The engine's own depth rule: an ISO pipeline asks for two
        // chunks per stage (pairs are the wavefront unit).
        let depth = if pp > 1 { 2 * pp } else { 1 };
        let chunks = iso::batch::plan_prefill_pp(
            0,
            128,
            Strategy::Iso,
            SplitPolicy::Even,
            &[16, 32, 64],
            None,
            depth,
        )
        .len();
        let units = chunks.div_ceil(2).max(1);
        pp_iteration_s(&node, &model, 128, units, pp, tp, &p2p, false)
    };
    let pred = *ENGINE_PP_CONFIGS
        .iter()
        .min_by(|a, b| predict(a.0, a.1).partial_cmp(&predict(b.0, b.1)).unwrap())
        .unwrap();
    let meas = measured_best.unwrap().1;
    println!(
        "  → predicted fastest pp{}×tp{}, measured fastest pp{}×tp{}{}",
        pred.0,
        pred.1,
        meas.0,
        meas.1,
        if pred == meas { " (directions agree)" } else { " (DIVERGED — investigate)" }
    );
    if let Err(e) = append_perf_records(path, "e2e_engine_pp", &records) {
        eprintln!("could not write {path}: {e}");
    } else {
        println!("  wrote PP×TP sweep to {path}");
    }
    Ok(())
}

/// Simulator side of the PR-2 sweep: per-token mixed-iteration time vs
/// decode-batch width, fused vs per-sequence, decode-only and composed
/// with a prefill. The recorded direction — per-token time falling as the
/// lane widens, fused beating per-sequence — is what the engine sweep
/// below must reproduce.
fn sim_mixed_sweep(path: &str) {
    let node = NodeProfile::rtx4090(4);
    let model = ModelSpec::mha_30b();
    section("simulator: mixed iteration vs decode_batch (4090-4, 30b, ctx=2048)");
    let mut records = Vec::new();
    for b in [1usize, 2, 4, 8, 16] {
        let mk = |prefill: usize, fused: bool| MixedIteration {
            prefill_tokens: prefill,
            decode_batch: b,
            decode_ctx: 2048,
            fused,
        };
        let s = |m: &MixedIteration| {
            mixed_iteration_s(&node, &model, SplitPolicy::AttnBalanced, m, 1, true)
        };
        let fused_ms = s(&mk(0, true)) * 1e3;
        let unfused_ms = s(&mk(0, false)) * 1e3;
        let mixed_ms = s(&mk(4096, true)) * 1e3;
        println!(
            "  b={b}: decode-only fused {:.3}ms ({:.3}/tok) per-seq {:.3}ms, \
             + 4k prefill {:.3}ms",
            fused_ms,
            fused_ms / b as f64,
            unfused_ms,
            mixed_ms
        );
        records.push(
            PerfRecord::new(&format!("sim mixed b{b}"), mixed_ms, mixed_ms, mixed_ms)
                .with("decode_batch", b as f64)
                .with("fused_per_tok_ms", fused_ms / b as f64)
                .with("unfused_per_tok_ms", unfused_ms / b as f64)
                .with("mixed_iter_ms", mixed_ms),
        );
    }
    if let Err(e) = append_perf_records(path, "sim_mixed", &records) {
        eprintln!("could not write {path}: {e}");
    }
}

/// Engine side of the PR-2 sweep: `serve_trace` throughput and exposed
/// comm per decoded token across decode-batch widths and two
/// prefill:decode mixes, plus the legacy sequential loop as baseline.
fn engine_mixed_sweep(path: &str) -> anyhow::Result<()> {
    let mut records = Vec::new();
    for (mix_name, n_req, prompt_len, decode_steps) in
        [("pf-heavy", 6usize, 96usize, 4usize), ("dec-heavy", 8, 32, 16)]
    {
        section(&format!(
            "engine: serve_trace {mix_name} ({n_req} reqs, prompt {prompt_len}, decode {decode_steps}; tp=2 pcie-emu)"
        ));
        // decode_batch = 0 encodes the sequential (mixed-off) baseline.
        for db in [0usize, 1, 2, 4, 8] {
            let mut c = cfg(Strategy::Iso, 2, CommQuant::F32, Some(40.0));
            c.link_alpha_us = 5.0;
            c.max_batch = 8;
            c.mixed_iterations = db > 0;
            c.decode_batch = db.max(1);
            let mut engine = Engine::start(c)?;
            let reqs = TraceGen::new(5, 512, LenDist::Fixed(prompt_len))
                .decode_steps(decode_steps)
                .generate(n_req);
            let mut trace = engine.serve_trace(&reqs)?;
            let report = engine.shutdown()?;
            let m = report.metrics;
            let label = if db == 0 { "sequential".into() } else { format!("mixed db{db}") };
            let tok_s = trace.throughput_tok_s();
            let tbt_p50 = if trace.tbt_ms.is_empty() { 0.0 } else { trace.tbt_ms.p50() };
            let occ = if trace.occupancy.is_empty() { 0.0 } else { trace.occupancy.mean() };
            println!(
                "  {label:<12} {tok_s:>7.1} tok/s  exposed {:.4}ms/tok  tbt p50 {tbt_p50:.2}ms  \
                 occupancy mean {occ:.1}  fused_ars {}",
                m.exposed_ms_per_token(),
                m.fused_allreduces
            );
            records.push(
                PerfRecord::new(
                    &format!("{mix_name} {label}"),
                    trace.wall_s * 1e3,
                    trace.wall_s * 1e3,
                    trace.wall_s * 1e3,
                )
                .with("decode_batch", db as f64)
                .with("tok_s", tok_s)
                .with("exposed_ms_per_tok", m.exposed_ms_per_token())
                .with("tbt_p50_ms", tbt_p50)
                .with("fused_allreduces", m.fused_allreduces as f64),
            );
        }
    }
    if let Err(e) = append_perf_records(path, "e2e_engine_mixed", &records) {
        eprintln!("could not write {path}: {e}");
    } else {
        println!("  wrote mixed-batching sweep to {path}");
    }
    Ok(())
}

/// Simulator side of the PR-5 sweep (no artifacts needed, fully
/// deterministic — gated against `BENCH_BASELINE.json` by
/// `scripts/check_bench_regression.py` in CI): one blocking layer-stage
/// iteration over a 4096-token chunk on the modeled 4-card 4090 with the
/// post-collective epilogue serial vs fused into the segment stream
/// (TokenWeave-style, DESIGN.md §12). The direction the engine sweep
/// below must reproduce: fused exposure falls as `comm_segments` grows;
/// unfused exposure does not.
fn sim_fused_epilogue_sweep(path: &str) {
    let node = NodeProfile::rtx4090(4);
    let model = ModelSpec::mha_30b();
    let t = 4096usize;
    section("simulator: fused-epilogue iteration vs comm_segments (4090-4, 30b, t=4096)");
    let mut records = Vec::new();
    for segments in [1usize, 2, 4, 8] {
        let fused_s = fused_epilogue_iteration_s(&node, &model, t, segments, true, true);
        let unfused_s = fused_epilogue_iteration_s(&node, &model, t, segments, false, true);
        let c = Coster {
            node: node.clone(),
            model: model.clone(),
            int8_wire: true,
        };
        let epi = epilogue_s(&node, &model, t);
        let exposed_epi_ms = model.n_layers as f64
            * 2.0
            * epilogue_exposed_s(c.ar_s(t, 1), epi, segments, true)
            * 1e3;
        println!(
            "  segments={segments}: fused {:.2}ms unfused {:.2}ms exposed-epilogue {:.4}ms",
            fused_s * 1e3,
            unfused_s * 1e3,
            exposed_epi_ms
        );
        records.push(
            PerfRecord::new(
                &format!("sim fused-epi seg{segments}"),
                fused_s * 1e3,
                fused_s * 1e3,
                fused_s * 1e3,
            )
            .with("segments", segments as f64)
            .with("fused_iter_ms", fused_s * 1e3)
            .with("unfused_iter_ms", unfused_s * 1e3)
            .with("exposed_epilogue_ms", exposed_epi_ms),
        );
    }
    if let Err(e) = append_perf_records(path, "sim_fused_epilogue", &records) {
        eprintln!("could not write {path}: {e}");
    }
}

/// Engine side of the PR-5 sweep: measured prefill wall time and
/// epilogue exposure across `comm_segments` × fused/unfused, plus the
/// numerics-changing ladder-residual rider on the serial baseline.
fn engine_fused_epilogue_sweep(path: &str) -> anyhow::Result<()> {
    let prompt: Vec<i32> = (0..128).map(|i| ((i * 31) % 512) as i32).collect();
    section("engine: fused-epilogue × comm_segments (tp=2, pcie-emu 40 MB/s, α=5µs)");
    let mut records = Vec::new();
    for fused in [false, true] {
        for segments in [1usize, 2, 4] {
            let mut c = cfg(Strategy::Iso, 2, CommQuant::F32, Some(40.0));
            c.link_alpha_us = 5.0;
            c.comm_segments = segments;
            c.fused_epilogue = fused;
            let mut engine = Engine::start(c)?;
            engine.prefill(&prompt)?; // warmup
            let label = format!("{} seg{segments}", if fused { "fused-epi" } else { "unfused" });
            let r = bench(&format!("tp2 iso {label}"), 1, 6, || {
                engine.prefill(&prompt).unwrap();
            });
            let report = engine.shutdown()?;
            let m = report.metrics;
            println!(
                "    exposed {:.2}ms exposed-epilogue {:.3}ms fused_epi_rows {} seg_acks {}",
                m.exposed_ms, m.exposed_epilogue_ms, m.fused_epilogue_rows, m.seg_acks
            );
            records.push(
                PerfRecord::new(&format!("engine {label}"), r.mean_ms, r.p50_ms, r.p95_ms)
                    .with("segments", segments as f64)
                    .with("fused", if fused { 1.0 } else { 0.0 })
                    .with("exposed_ms", m.exposed_ms)
                    .with("exposed_epilogue_ms", m.exposed_epilogue_ms)
                    .with("fused_epilogue_rows", m.fused_epilogue_rows as f64),
            );
        }
    }
    // Ladder-residual rider: numerics-changing, so it sweeps the serial
    // baseline (where the exposed window it attacks lives) and records
    // wall time only — no bit-exact claims.
    for ladder in [false, true] {
        let mut c = cfg(Strategy::Serial, 2, CommQuant::F32, Some(40.0));
        c.link_alpha_us = 5.0;
        c.ladder_residual = ladder;
        let mut engine = Engine::start(c)?;
        engine.prefill(&prompt)?; // warmup
        let label = if ladder { "serial ladder" } else { "serial baseline" };
        let r = bench(&format!("tp2 {label}"), 1, 6, || {
            engine.prefill(&prompt).unwrap();
        });
        let report = engine.shutdown()?;
        records.push(
            PerfRecord::new(&format!("engine {label}"), r.mean_ms, r.p50_ms, r.p95_ms)
                .with("ladder", if ladder { 1.0 } else { 0.0 })
                .with("exposed_ms", report.metrics.exposed_ms),
        );
    }
    if let Err(e) = append_perf_records(path, "e2e_engine_fused_epilogue", &records) {
        eprintln!("could not write {path}: {e}");
    } else {
        println!("  wrote fused-epilogue sweep to {path}");
    }
    Ok(())
}

/// Simulator side of the PR-6 sweep (no artifacts needed, fully
/// deterministic — gated against `BENCH_BASELINE.json` by
/// `scripts/check_bench_regression.py` in CI): the pinned recovery cost
/// model (DESIGN.md §14) over fault rate × live context. One recovery
/// costs a worst-case detection deadline + mesh respawn + checkpoint-
/// free replay of the live context; goodput is the fused decode lane's
/// throughput scaled by the expected recovery-overhead share. The
/// directions the gate pins: recovery cost grows with context, goodput
/// falls as the fault rate rises.
fn sim_fault_sweep(path: &str) {
    // Modeled serving point: a 30 ms mixed iteration advancing an
    // 8-wide fused decode lane, deadline slack 4, 2 s mesh respawn,
    // 20k tok/s re-prefill throughput.
    let (iter_s, slack, respawn_s, prefill_tok_s) = (0.03f64, 4.0f64, 2.0f64, 20_000.0f64);
    let lane_tok_s = 8.0 / iter_s;
    let deadline_s = iteration_deadline_s(iter_s, slack);
    section("simulator: fault rate × recovery overhead (8-lane 30ms iterations)");
    let mut records = Vec::new();
    for ctx in [512usize, 4096] {
        let rec_s = recovery_s(deadline_s, respawn_s, ctx, prefill_tok_s);
        for rate in [1e-5f64, 1e-4, 1e-3] {
            let frac = expected_overhead_frac(rate, iter_s, rec_s);
            let goodput = lane_tok_s * (1.0 - frac);
            println!(
                "  ctx={ctx:<4} rate={rate:.0e}: recovery {:7.1}ms overhead {:.5} \
                 goodput {goodput:7.2} tok/s",
                rec_s * 1e3,
                frac
            );
            records.push(
                PerfRecord::new(
                    &format!("sim fault ctx{ctx} rate{rate:.0e}"),
                    rec_s * 1e3,
                    rec_s * 1e3,
                    rec_s * 1e3,
                )
                .with("ctx", ctx as f64)
                .with("fault_rate", rate)
                .with("pred_recovery_ms", rec_s * 1e3)
                .with("pred_goodput_tok_s", goodput)
                .with("pred_overhead_frac", frac),
            );
        }
    }
    if let Err(e) = append_perf_records(path, "sim_fault", &records) {
        eprintln!("could not write {path}: {e}");
    }
}

/// Engine side of the PR-6 sweep (artifact-gated, not in the baseline):
/// serve a fixed trace with seeded kill-rank plans of increasing event
/// count and record measured recovery latency and goodput next to the
/// fault-free run. Zero dropped sequences is asserted here too — a
/// bench that silently lost work would be measuring the wrong engine.
fn engine_fault_sweep(path: &str) -> anyhow::Result<()> {
    section("engine: seeded kill-rank faults during serve_trace (tp=2, mixed)");
    let mut records = Vec::new();
    for (label, plan) in [
        ("fault-free", None),
        ("kill x1", Some("kill:rank=1:iter=4")),
        ("kill x2", Some("kill:rank=1:iter=4;kill:rank=0:iter=9")),
    ] {
        let mut c = cfg(Strategy::Iso, 2, CommQuant::F32, None);
        c.decode_batch = 4;
        c.fault_plan = plan.map(str::to_string);
        c.fault_slack = 64.0;
        let mut engine = Engine::start(c)?;
        let reqs = TraceGen::new(11, 512, LenDist::Fixed(32)).decode_steps(8).generate(4);
        let clock = std::time::Instant::now();
        let trace = engine.serve_trace(&reqs)?;
        let wall_ms = clock.elapsed().as_secs_f64() * 1e3;
        let report = engine.shutdown()?;
        assert_eq!(trace.completed, 4, "dropped sequences in {label}");
        let recoveries = report.metrics.recoveries;
        let rec_ms = if report.metrics.recovery_ms.is_empty() {
            0.0
        } else {
            report.metrics.recovery_ms.mean()
        };
        println!(
            "  {label:<10} wall {wall_ms:8.1}ms  recoveries {recoveries}  \
             recovery mean {rec_ms:.1}ms  {:7.1} tok/s",
            trace.throughput_tok_s()
        );
        records.push(
            PerfRecord::new(&format!("engine fault {label}"), wall_ms, wall_ms, wall_ms)
                .with("recoveries", recoveries as f64)
                .with("recovery_mean_ms", rec_ms)
                .with("tok_s", trace.throughput_tok_s())
                .with("replayed_tokens", report.metrics.replayed_tokens as f64),
        );
    }
    if let Err(e) = append_perf_records(path, "e2e_engine_fault", &records) {
        eprintln!("could not write {path}: {e}");
    } else {
        println!("  wrote fault sweep to {path}");
    }
    Ok(())
}

/// Simulator side of the PR-7 sweep (no artifacts needed, fully
/// deterministic — gated against `BENCH_BASELINE.json` by
/// `scripts/check_bench_regression.py` in CI): the pinned overload model
/// (DESIGN.md §15) over offered load. Admission clamps utilization at
/// `rho_max` and sheds the excess, queueing delay follows the M/D/1
/// waiting time, and the bounded-prefill TBT is the unbounded mixed
/// iteration clamped to the budget. The directions the gate pins: TTFT
/// saturates (instead of diverging) past the knee, goodput plateaus at
/// the admitted ceiling, and the p99 TBT stays pinned at the budget even
/// with a 4096-token prompt in flight.
fn sim_slo_sweep(path: &str) {
    // Modeled serving point: 30 ms decode iterations over an 8-wide
    // fused lane (knee at 8/0.03 tok/s), a 50 ms TBT budget, admission
    // ceiling rho_max = 0.9, 20k tok/s prefill, 4096-token worst prompt.
    let (iter_s, budget_ms, decode_batch, rho_max) = (0.03f64, 50.0f64, 8usize, 0.9f64);
    let capacity = decode_batch as f64 / iter_s;
    let prefill_tok_s = 20_000.0f64;
    let unbounded_s = 4096.0 / prefill_tok_s + iter_s;
    section("simulator: SLO frontier vs offered load (8-lane 30ms iterations, 50ms budget)");
    let mut records = Vec::new();
    for (label, m) in [("0.5", 0.5f64), ("0.9", 0.9), ("1.0", 1.0), ("2.0", 2.0)] {
        let rho = m;
        let admitted = slo_admitted_frac(rho, rho_max);
        let ttft_ms = slo_ttft_s(iter_s, rho, rho_max) * 1e3;
        let p99_tbt_ms = bounded_tbt_s(iter_s, unbounded_s, budget_ms / 1e3) * 1e3;
        let goodput = m * capacity * admitted;
        let shed_frac = 1.0 - admitted;
        println!(
            "  load {label}x: ttft {ttft_ms:6.1}ms  p99 tbt {p99_tbt_ms:5.1}ms  \
             goodput {goodput:6.1} tok/s  shed {shed_frac:.2}"
        );
        records.push(
            PerfRecord::new(&format!("sim slo load{label}"), ttft_ms, ttft_ms, ttft_ms)
                .with("rho", rho)
                .with("pred_ttft_ms", ttft_ms)
                .with("pred_p99_tbt_ms", p99_tbt_ms)
                .with("pred_goodput_tok_s", goodput)
                .with("shed_frac", shed_frac),
        );
    }
    if let Err(e) = append_perf_records(path, "sim_slo", &records) {
        eprintln!("could not write {path}: {e}");
    }
}

/// Engine side of the PR-7 sweep (artifact-gated, not in the baseline):
/// serve a heavy-tailed lognormal burst at roughly twice the fused
/// lane's knee with every overload knob armed, next to the same trace
/// with the knobs off. The frontier table (EXPERIMENTS.md) records the
/// shape this sweep must keep: the armed engine finishes everything it
/// admits and sheds, rejects, or preempts the excess instead of letting
/// a giant prompt stall the decode lane.
fn engine_overload_sweep(path: &str) -> anyhow::Result<()> {
    section("engine: overload knobs on a heavy-tailed burst (tp=2, mixed db4)");
    let mut records = Vec::new();
    for (label, armed) in [("open-loop", false), ("slo-armed", true)] {
        let mut c = cfg(Strategy::Iso, 2, CommQuant::F32, None);
        c.decode_batch = 4;
        c.max_batch = 8;
        if armed {
            c.tbt_budget_ms = 50.0;
            c.kv_high_water = 0.75;
            c.queue_bound = 8;
            c.ttft_deadline_ms = 2_000.0;
        }
        let mut engine = Engine::start(c)?;
        let reqs = TraceGen::new(17, 512, LenDist::Lognormal { mu: 3.2, sigma: 0.8, cap: 96 })
            .rate(200.0)
            .decode_steps(8)
            .generate(12);
        let clock = std::time::Instant::now();
        let mut trace = engine.serve_trace(&reqs)?;
        let wall_ms = clock.elapsed().as_secs_f64() * 1e3;
        let report = engine.shutdown()?;
        let accounted = trace.completed as u64 + trace.shed + trace.rejected;
        assert_eq!(accounted, 12, "dropped sequences in {label}");
        let tbt_p50 = if trace.tbt_ms.is_empty() { 0.0 } else { trace.tbt_ms.p50() };
        println!(
            "  {label:<10} wall {wall_ms:8.1}ms  completed {} shed {} rejected {} \
             preemptions {}  tbt p50 {tbt_p50:.2}ms",
            trace.completed, trace.shed, trace.rejected, trace.preemptions
        );
        records.push(
            PerfRecord::new(&format!("engine overload {label}"), wall_ms, wall_ms, wall_ms)
                .with("completed", trace.completed as f64)
                .with("preemptions", trace.preemptions as f64)
                .with("shed", trace.shed as f64)
                .with("rejected", trace.rejected as f64)
                .with("tok_s", trace.throughput_tok_s())
                .with("preempted_tokens", report.metrics.preempted_tokens as f64),
        );
    }
    if let Err(e) = append_perf_records(path, "e2e_engine_overload", &records) {
        eprintln!("could not write {path}: {e}");
    } else {
        println!("  wrote overload sweep to {path}");
    }
    Ok(())
}

/// One rung's wire round-trip, exactly as `collective::send_segment` /
/// `recv_apply` encode and decode it (f32 and fp16 move raw f32 on the
/// CPU wire, so they are lossless here).
fn rung_roundtrip(q: CommQuant, x: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    match q {
        CommQuant::F32 | CommQuant::Fp16 => x.to_vec(),
        CommQuant::Int8 => iso::quant::dequantize_rows(&iso::quant::quantize_rows(x, rows, cols)),
        CommQuant::Fp8 => iso::quant::fp8_decode_rows(&iso::quant::fp8_encode_rows(x, rows, cols)),
        CommQuant::Int4 => {
            iso::quant::dequantize4_rows(&iso::quant::quantize4_rows(x, rows, cols))
        }
    }
}

/// Simulator side of the PR-8 sweep (no artifacts needed, fully
/// deterministic — gated against `BENCH_BASELINE.json` by
/// `scripts/check_bench_regression.py` in CI): the wire-precision
/// ladder's three axes on the modeled 4-card 4090 (DESIGN.md §16). Per
/// rung: engine-exact bytes per collective
/// (`sched::wire_bytes_per_collective`), measured logit drift of a
/// 4-rank rank-ordered ring reduce vs the f32 golden (seeded inputs;
/// ungated — pinned by `tests/wire_precision.rs`, recorded here for the
/// EXPERIMENTS.md table), and the predicted blocking-iteration
/// throughput (`sched::ladder_iteration_s`, gated: tok/s must not fall,
/// iteration ms must not rise).
fn sim_precision_sweep(path: &str) {
    let node = NodeProfile::rtx4090(4);
    let model = ModelSpec::mha_30b();
    let t = 4096usize;
    let (ranks, rows, cols) = (4usize, 8usize, model.d_model);
    // Seeded activation-scale parts; each rank contributes rows×cols.
    let parts: Vec<Vec<f32>> = (0..ranks)
        .map(|r| iso::util::rng::Rng::new(0x9c0 + r as u64).normal_vec(rows * cols, 1.0))
        .collect();
    let golden: Vec<f32> = (0..rows * cols)
        .map(|i| parts.iter().map(|p| p[i] as f64).sum::<f64>() as f32)
        .collect();
    let gmax = golden.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
    section("simulator: wire-precision ladder (4090-4, 30b, t=4096; drift on 4-rank ring)");
    let mut records = Vec::new();
    for q in CommQuant::LADDER {
        // Rank-ordered fused reduce: every hop re-encodes the running
        // partial sum; the broadcast re-encodes the final sum once more.
        let mut acc = parts[0].clone();
        for part in parts.iter().skip(1) {
            acc = rung_roundtrip(q, &acc, rows, cols);
            for (a, &p) in acc.iter_mut().zip(part.iter()) {
                *a += p;
            }
        }
        acc = rung_roundtrip(q, &acc, rows, cols);
        let drift = acc
            .iter()
            .zip(golden.iter())
            .fold(0.0f32, |m, (&a, &g)| m.max((a - g).abs()));
        let bytes = iso::sched::wire_bytes_per_collective(&model, t, q);
        let iter_s = iso::sched::ladder_iteration_s(&node, &model, t, q);
        let pred_ms = iter_s * 1e3;
        let tok_s = t as f64 / iter_s;
        println!(
            "  {:>4}: {bytes:>9} B/ar  iter {pred_ms:8.2}ms  {tok_s:7.0} tok/s  \
             max drift {drift:.3e} ({:.2e} rel)",
            q.label(),
            drift / gmax
        );
        records.push(
            PerfRecord::new(&format!("sim precision {}", q.label()), pred_ms, pred_ms, pred_ms)
                .with("wire_bytes_per_ar", bytes as f64)
                .with("pred_prefill_tok_s", tok_s)
                .with("max_abs_drift", drift as f64)
                .with("rel_drift", (drift / gmax) as f64),
        );
    }
    if let Err(e) = append_perf_records(path, "sim_precision", &records) {
        eprintln!("could not write {path}: {e}");
    }
}

/// Simulator prediction for the exposed (un-hidden) time of one
/// segment-streamed all-reduce: the first comm tile is always exposed;
/// each later tile hides up to one compute tile behind it (paper §3.2,
/// Fig 1b — the same pipelined-tile model `sched::build_gemm_overlap`
/// lowers). Strictly decreasing in `segments` while compute tiles are
/// nonzero, which is the direction the engine sweep must reproduce.
fn sim_exposed_ar_s(c: &Coster, t: usize, segments: usize) -> f64 {
    let ar_tile = c.ar_s(t, segments);
    let gemm_tile = c.o_proj_seg_s(t, segments);
    ar_tile + (segments as f64 - 1.0) * (ar_tile - gemm_tile).max(0.0)
}

/// Engine side of the PR-8 sweep (artifact-gated, not in the baseline):
/// measured ISO prefill on the throttled link at every rung of
/// `--wire-precision`, recording wall time and the per-rung wire-byte
/// counters so the measured byte ratios sit next to the simulator's
/// predicted ladder.
fn engine_precision_sweep(path: &str) -> anyhow::Result<()> {
    let prompt: Vec<i32> = (0..128).map(|i| ((i * 31) % 512) as i32).collect();
    section("engine: prefill vs --wire-precision (tp=2, pcie-emu 40 MB/s, α=5µs)");
    let mut records = Vec::new();
    for q in CommQuant::LADDER {
        let mut c = cfg(Strategy::Iso, 2, CommQuant::F32, Some(40.0));
        c.link_alpha_us = 5.0;
        c.wire_precision = Some(q);
        let mut engine = Engine::start(c)?;
        engine.prefill(&prompt)?; // warmup
        let r = bench(&format!("tp2 iso wire={}", q.label()), 1, 6, || {
            engine.prefill(&prompt).unwrap();
        });
        let report = engine.shutdown()?;
        let m = report.metrics;
        println!(
            "    comm_bytes {}  rung[{}] {}",
            m.comm_bytes,
            q.label(),
            m.comm_bytes_by_rung[q.index()]
        );
        records.push(
            PerfRecord::new(&format!("engine wire {}", q.label()), r.mean_ms, r.p50_ms, r.p95_ms)
                .with("comm_bytes", m.comm_bytes as f64)
                .with("rung_bytes", m.comm_bytes_by_rung[q.index()] as f64),
        );
    }
    if let Err(e) = append_perf_records(path, "e2e_engine_precision", &records) {
        eprintln!("could not write {path}: {e}");
    } else {
        println!("  wrote wire-precision sweep to {path}");
    }
    Ok(())
}

/// The CP×TP factorizations of a 4-device node that the deterministic
/// (CI-gated) simulator sweep exercises.
const CP_CONFIGS: [(usize, usize); 3] = [(1, 4), (2, 2), (4, 1)];

/// Simulator side of the PR-9 sweep (no artifacts needed, fully
/// deterministic — gated against `BENCH_BASELINE.json` by
/// `scripts/check_bench_regression.py` in CI): predicted prefill time of
/// the third parallelism axis (`sched::cp_iteration_s`, DESIGN.md §17)
/// across the CP×TP factorizations of a 4-device node, on both modeled
/// platforms and three prompt lengths up to 1M tokens. The directions
/// the gate pins: in the comm-bound regime (short and medium prompts on
/// the PCIe 4090) the context-sharded configs beat the wide flat ring,
/// while compute-dominated points (the NVLink A800 past ~64k, and the
/// quadratic-attention-heavy 1M case on both platforms) favor flat TP,
/// which divides every FLOP instead of sharding rows — the pp-vs-tp
/// crossover one axis over.
fn sim_cp_sweep(path: &str) {
    let model = ModelSpec::mha_30b();
    section("simulator: CP×TP factorization vs prompt length (30b, 4 devices)");
    let mut records = Vec::new();
    for (node_name, node) in [("4090-4", NodeProfile::rtx4090(4)), ("a800-4", NodeProfile::a800(4))]
    {
        let p2p = node.link;
        let int8 = node.int8_wire_default;
        for prompt in [4096usize, 65536, 1_048_576] {
            for (cp, tp) in CP_CONFIGS {
                let s = cp_iteration_s(&node, &model, prompt, cp, tp, &p2p, int8);
                let pred_ms = s * 1e3;
                let tok_s = prompt as f64 / s;
                println!(
                    "  {node_name} t={prompt:>7} cp{cp}×tp{tp}: {pred_ms:10.2}ms  {tok_s:8.0} tok/s"
                );
                records.push(
                    PerfRecord::new(
                        &format!("sim cp{cp} tp{tp} {node_name} t{prompt}"),
                        pred_ms,
                        pred_ms,
                        pred_ms,
                    )
                    .with("cp", cp as f64)
                    .with("tp", tp as f64)
                    .with("prompt", prompt as f64)
                    .with("pred_prefill_tok_s", tok_s),
                );
            }
            let best = cp_best_config(&node, &model, prompt, &CP_CONFIGS, &p2p, int8);
            println!(
                "  → {node_name} t={prompt}: predicted fastest factorization cp{}×tp{}",
                best.0, best.1
            );
        }
    }
    if let Err(e) = append_perf_records(path, "sim_cp", &records) {
        eprintln!("could not write {path}: {e}");
    }
}

/// Engine side of the PR-9 sweep (artifact-gated, not in the baseline):
/// measured prefill across CP×TP factorizations on the throttled link,
/// with the shard-ring byte/stall counters recorded next to the wall
/// time so the prefix-forward cost of each factorization is visible in
/// the snapshot.
fn engine_cp_sweep(path: &str) -> anyhow::Result<()> {
    let prompt: Vec<i32> = (0..128).map(|i| ((i * 31) % 512) as i32).collect();
    section("engine: prefill CP×TP sweep (tiny model, pcie-emu 40 MB/s, α=5µs)");
    let mut records = Vec::new();
    for (cp, tp) in [(1usize, 2usize), (2, 1), (2, 2)] {
        let mut c = cfg(Strategy::Iso, tp, CommQuant::F32, Some(40.0));
        c.link_alpha_us = 5.0;
        c.cp = cp;
        let mut engine = Engine::start(c)?;
        engine.prefill(&prompt)?; // warmup
        let r = bench(&format!("cp{cp}×tp{tp} iso pcie-emu"), 1, 6, || {
            engine.prefill(&prompt).unwrap();
        });
        let report = engine.shutdown()?;
        let m = report.metrics;
        let tok_s = 128.0 / (r.mean_ms / 1e3);
        println!(
            "    {tok_s:7.0} tok/s  cp_shard {}B in {} msgs  cp_stall {:.2}ms",
            m.cp_shard_bytes, m.cp_shard_msgs, m.cp_stall_ms
        );
        records.push(
            PerfRecord::new(&format!("engine cp{cp} tp{tp}"), r.mean_ms, r.p50_ms, r.p95_ms)
                .with("cp", cp as f64)
                .with("tp", tp as f64)
                .with("prefill_tok_s", tok_s)
                .with("cp_shard_bytes", m.cp_shard_bytes as f64)
                .with("cp_stall_ms", m.cp_stall_ms),
        );
    }
    if let Err(e) = append_perf_records(path, "e2e_engine_cp", &records) {
        eprintln!("could not write {path}: {e}");
    } else {
        println!("  wrote CP×TP sweep to {path}");
    }
    Ok(())
}

/// Simulator side of the PR-10 auto-tune sweep (no artifacts needed,
/// fully deterministic — gated against `BENCH_BASELINE.json` in CI):
/// for each GPU preset × workload mix, plan the joint knob space, then
/// re-price the top-5 through the event-sim "measured" twin
/// (`tune::sim_measured_request_s`). Each rank's predicted/measured
/// milliseconds are recorded, plus one agreement record per cell with
/// the Kendall τ and the hand-tuned default's measured time — the same
/// quantities `rust/tests/auto_tune.rs` pins, kept here so regressions
/// show up as numbers, not just pass/fail.
fn sim_tune_sweep(path: &str) {
    let model = ModelSpec::mha_30b();
    section("simulator: auto-tune predicted vs sim-measured, top-5 (30b, 4 devices)");
    let mut records = Vec::new();
    for (tag, node) in [("4090-4", NodeProfile::rtx4090(4)), ("a800-4", NodeProfile::a800(4))] {
        for w in [Workload::prefill_heavy(), Workload::mixed(), Workload::decode_heavy()] {
            let p = tune::plan(&node, &model, &w);
            let top = &p.ranked[..5.min(p.ranked.len())];
            let pred: Vec<f64> = top.iter().map(|pc| pc.predicted_s).collect();
            let meas: Vec<f64> = top
                .iter()
                .map(|pc| tune::sim_measured_request_s(&node, &model, &w, &pc.cfg))
                .collect();
            for (i, pc) in top.iter().enumerate() {
                let pred_ms = pred[i] * 1e3;
                let meas_ms = meas[i] * 1e3;
                println!(
                    "  {tag} {:<13} rank{} {:<44} pred {pred_ms:9.3}ms meas {meas_ms:9.3}ms",
                    w.name,
                    i + 1,
                    pc.summary
                );
                records.push(
                    PerfRecord::new(
                        &format!("sim tune {tag} {} rank{}", w.name, i + 1),
                        pred_ms,
                        pred_ms,
                        pred_ms,
                    )
                    .with("rank", (i + 1) as f64)
                    .with("predicted_ms", pred_ms)
                    .with("measured_ms", meas_ms),
                );
            }
            let tau = tune::kendall_tau(&pred, &meas);
            let ht = tune::hand_tuned_default(&node, &w);
            let ht_ms = tune::sim_measured_request_s(&node, &model, &w, &ht) * 1e3;
            let best_ms = meas[0] * 1e3;
            println!(
                "  → {tag} {:<13} tau {tau:+.3}  best-measured {best_ms:9.3}ms  \
                 hand-tuned {ht_ms:9.3}ms",
                w.name
            );
            records.push(
                PerfRecord::new(
                    &format!("sim tune {tag} {} agreement", w.name),
                    best_ms,
                    best_ms,
                    best_ms,
                )
                .with("tau", tau)
                .with("best_measured_ms", best_ms)
                .with("hand_tuned_ms", ht_ms),
            );
        }
    }
    if let Err(e) = append_perf_records(path, "sim_tune", &records) {
        eprintln!("could not write {path}: {e}");
    }
}

fn main() -> anyhow::Result<()> {
    let path = snapshot_path();
    let pr2_path = pr2_snapshot_path();
    let pr4_path = pr4_snapshot_path();
    let pr5_path = pr5_snapshot_path();
    let pr6_path = pr6_snapshot_path();
    let slo_path = slo_snapshot_path();
    let precision_path = precision_snapshot_path();
    let cp_path = cp_snapshot_path();

    // --- PR-2: simulator-predicted mixed-batching direction (no
    // artifacts needed).
    sim_mixed_sweep(&pr2_path);

    // --- PR-4: simulator-predicted PP×TP factorization direction (no
    // artifacts needed; gated against BENCH_BASELINE.json in CI).
    sim_pp_sweep(&pr4_path);

    // --- PR-5: simulator-predicted fused-epilogue direction (no
    // artifacts needed; gated against BENCH_BASELINE.json in CI).
    sim_fused_epilogue_sweep(&pr5_path);

    // --- PR-6: pinned recovery cost model over fault rate × context
    // (no artifacts needed; gated against BENCH_BASELINE.json in CI).
    sim_fault_sweep(&pr6_path);

    // --- PR-7: pinned overload/SLO frontier over offered load (no
    // artifacts needed; gated against BENCH_BASELINE.json in CI).
    sim_slo_sweep(&slo_path);

    // --- PR-8: wire-precision ladder — bytes × drift × predicted tok/s
    // (no artifacts needed; gated against BENCH_BASELINE.json in CI).
    sim_precision_sweep(&precision_path);

    // --- PR-9: CP×TP factorization × prompt length on both modeled
    // platforms (no artifacts needed; gated against BENCH_BASELINE.json
    // in CI).
    sim_cp_sweep(&cp_path);

    // --- PR-10: auto-tune rank agreement — top-5 predicted vs
    // sim-measured per profile × workload (no artifacts needed; gated
    // against BENCH_BASELINE.json in CI).
    sim_tune_sweep(&tune_snapshot_path());

    // --- simulator side of the segment sweep (no artifacts needed).
    let sim_exp = SimExperiment::new(
        NodeProfile::rtx4090(4),
        ModelSpec::mha_30b(),
        4096,
        Strategy::Iso,
    );
    let coster = Coster::new(&sim_exp);
    let mut sim_records = Vec::new();
    section("simulator: predicted exposed AR time vs segments (4090-4, 30b, t=4096)");
    for segments in [1usize, 2, 4, 8] {
        let exposed_ms = sim_exposed_ar_s(&coster, 4096, segments) * 1e3;
        println!("  segments={segments}: exposed {exposed_ms:.3}ms");
        let case = format!("sim 4090-4 30b t4096 seg{segments}");
        sim_records.push(
            PerfRecord::new(&case, exposed_ms, exposed_ms, exposed_ms)
                .with("segments", segments as f64)
                .with("exposed_ms", exposed_ms),
        );
    }
    if let Err(e) = append_perf_records(&path, "sim_segments", &sim_records) {
        eprintln!("could not write {path}: {e}");
    }

    if Manifest::load("artifacts").is_err() {
        eprintln!("SKIP e2e_engine bench: run `make artifacts` first");
        return Ok(());
    }
    let prompt: Vec<i32> = (0..128).map(|i| ((i * 31) % 512) as i32).collect();

    for tp in [2usize, 4] {
        section(&format!("prefill TTFT, tp={tp} (128-token prompt)"));
        let mut results = Vec::new();
        for (name, strat, quant, link) in [
            ("serial/f32 native", Strategy::Serial, CommQuant::F32, None),
            ("iso/f32 native", Strategy::Iso, CommQuant::F32, None),
            ("serial/f32 pcie-emu", Strategy::Serial, CommQuant::F32, Some(40.0)),
            ("iso/f32 pcie-emu", Strategy::Iso, CommQuant::F32, Some(40.0)),
            ("iso/int8 pcie-emu", Strategy::Iso, CommQuant::Int8, Some(40.0)),
        ] {
            let mut engine = Engine::start(cfg(strat, tp, quant, link))?;
            engine.prefill(&prompt)?; // warmup
            let r = bench(&format!("tp{tp} {name}"), 1, 8, || {
                engine.prefill(&prompt).unwrap();
            });
            let report = engine.shutdown()?;
            let eff = report.workers.iter().map(|w| w.overlap_efficiency()).sum::<f64>()
                / report.workers.len() as f64;
            println!("    overlap efficiency {eff:.2}");
            results.push((name, r.mean_ms));
        }
        let native = (results[0].1 - results[1].1) / results[0].1;
        let pcie = (results[2].1 - results[3].1) / results[2].1;
        println!("  → ISO reduction: native {:.1}%, pcie-emulated {:.1}%", native * 100.0, pcie * 100.0);
    }

    // --- PR-1 tentpole: comm_segments sweep on the throttled (4090 PCIe
    // calibration) link. Wall time and exposed comm should trend down
    // from segments=1 to 4, matching the simulator's direction above.
    section("engine: ISO prefill vs comm_segments (tp=2, pcie-emu 40 MB/s, α=5µs)");
    let mut eng_records = Vec::new();
    let mut prev_exposed = f64::INFINITY;
    for segments in [1usize, 2, 4, 8] {
        let mut c = cfg(Strategy::Iso, 2, CommQuant::F32, Some(40.0));
        c.link_alpha_us = 5.0;
        c.comm_segments = segments;
        // The PR-1 sweep measures the legacy streamed-ack path so its
        // rows stay comparable with earlier BENCH_PR1.json snapshots;
        // the fused-epilogue path has its own PR-5 sweep below.
        c.fused_epilogue = false;
        let mut engine = Engine::start(c)?;
        engine.prefill(&prompt)?; // warmup
        let r = bench(&format!("tp2 iso pcie-emu segments={segments}"), 1, 6, || {
            engine.prefill(&prompt).unwrap();
        });
        let report = engine.shutdown()?;
        let m = report.metrics;
        println!(
            "    exposed {:.2}ms overlapped {:.2}ms wire_msgs {} seg_acks {}",
            m.exposed_ms, m.overlapped_ms, m.comm_msgs, m.seg_acks
        );
        if segments <= 4 {
            if m.exposed_ms > prev_exposed {
                println!("    (warning: exposed comm did not decrease at segments={segments})");
            }
            prev_exposed = m.exposed_ms;
        }
        let case = format!("tp2 iso pcie-emu seg{segments}");
        eng_records.push(
            PerfRecord::new(&case, r.mean_ms, r.p50_ms, r.p95_ms)
                .with("segments", segments as f64)
                .with("exposed_ms", m.exposed_ms)
                .with("overlapped_ms", m.overlapped_ms)
                .with("wire_msgs", m.comm_msgs as f64)
                .with("seg_acks", m.seg_acks as f64),
        );
    }
    if let Err(e) = append_perf_records(&path, "e2e_engine_segments", &eng_records) {
        eprintln!("could not write {path}: {e}");
    } else {
        println!("  wrote engine segment sweep to {path}");
    }

    section("decode step latency (t=1 chunks, blocking — overlap unprofitable per paper)");
    let mut engine = Engine::start(cfg(Strategy::Iso, 2, CommQuant::F32, None))?;
    let short: Vec<i32> = (0..32).map(|i| i as i32).collect();
    engine.generate(&short, 2)?; // warmup
    bench("tp2 decode 8 steps", 1, 5, || {
        engine.generate(&short, 8).unwrap();
    });
    engine.shutdown()?;

    // --- PR-2 tentpole: mixed-batching sweep (decode-batch width ×
    // prefill:decode mix), sequential loop as baseline.
    engine_mixed_sweep(&pr2_path)?;

    // --- PR-4 tentpole: PP×TP factorization sweep on the real engine.
    engine_pp_sweep(&pr4_path)?;

    // --- PR-5 tentpole: fused-epilogue × segments sweep on the real
    // engine, plus the ladder-residual rider.
    engine_fused_epilogue_sweep(&pr5_path)?;

    // --- PR-6 tentpole: seeded kill-rank faults on the real engine —
    // measured detection + respawn + replay latency vs fault-free.
    engine_fault_sweep(&pr6_path)?;

    // --- PR-7 tentpole: overload knobs on the real engine — bounded
    // queue, KV-pressure preemption, and TBT-budgeted prefill under a
    // heavy-tailed burst past the knee.
    engine_overload_sweep(&slo_path)?;

    // --- PR-8 tentpole: every rung of --wire-precision on the real
    // engine next to the simulator's predicted ladder.
    engine_precision_sweep(&precision_path)?;

    // --- PR-9 tentpole: CP×TP factorizations on the real engine with
    // the shard-ring counters next to the simulator's predicted sweep.
    engine_cp_sweep(&cp_path)?;

    Ok(())
}
