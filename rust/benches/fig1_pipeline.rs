//! BENCH — Figure 1: the four pipeline schematics ((a) serial, (b) gemm
//! overlap, (c) request overlap, (d) ISO) regenerated as simulator
//! timelines + ASCII Gantt charts, with busy/overlap accounting.

use iso::config::{SimExperiment, Strategy};
use iso::hw::NodeProfile;
use iso::model::ModelSpec;
use iso::report::{gantt, timeline_json};
use iso::sched::run;
use iso::sim::OpKind;
use iso::util::bench::{bench, section};

fn main() {
    let node = NodeProfile::rtx4090(4);
    let model = ModelSpec::mha_30b();
    let len = 8192;

    std::fs::create_dir_all("target/bench-out").ok();
    for strat in Strategy::all() {
        let e = SimExperiment::new(node.clone(), model.clone(), len, strat);
        let tl = run(&e);
        section(&format!("Figure 1 ({strat}) — 30b, 4090-4, 8k prompt"));
        let per_layer = tl.makespan_s / model.n_layers as f64;
        print!("{}", gantt(&tl, 110, per_layer * 3.0));
        let compute = tl.busy_s(OpKind::Compute);
        let comm = tl.busy_s(OpKind::Comm);
        println!(
            "makespan {:>7.1}ms | compute busy {:>7.1}ms | comm busy {:>7.1}ms | overlapped {:>7.1}ms ({:.0}% of comm)",
            tl.makespan_s * 1e3,
            compute * 1e3,
            comm * 1e3,
            tl.overlap_s() * 1e3,
            tl.overlap_s() / comm * 100.0
        );
        std::fs::write(
            format!("target/bench-out/fig1_{strat}.json"),
            timeline_json(&tl).to_string(),
        )
        .ok();
    }

    section("figure ordering (paper: ISO (d) is the shortest pipeline)");
    let mut spans: Vec<(Strategy, f64)> = Strategy::all()
        .into_iter()
        .map(|s| {
            let e = SimExperiment::new(node.clone(), model.clone(), len, s);
            // request-overlap runs two requests; normalize per request
            let norm = if s == Strategy::RequestOverlap { 2.0 } else { 1.0 };
            (s, run(&e).makespan_s / norm)
        })
        .collect();
    spans.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    for (s, t) in &spans {
        println!("{:<16} {:>8.1} ms/request", s.to_string(), t * 1e3);
    }
    // ISO must beat serial and gemm-overlap outright. Request-overlap gets
    // per-request parity here only because the two simulated requests are
    // *perfectly* balanced — and it still needs two concurrent requests and
    // inflates each request's latency (paper §1); ISO needs one request.
    let t = |strat: Strategy| spans.iter().find(|(s, _)| *s == strat).unwrap().1;
    assert!(t(Strategy::Iso) < t(Strategy::Serial));
    assert!(t(Strategy::Iso) < t(Strategy::GemmOverlap));
    assert!(t(Strategy::Iso) < t(Strategy::RequestOverlap) * 1.10);

    section("timing");
    bench("lower+simulate ISO graph (60 layers)", 2, 20, || {
        let e = SimExperiment::new(node.clone(), model.clone(), len, Strategy::Iso);
        std::hint::black_box(run(&e));
    });
}
