//! BENCH — Table 1: regenerate the paper's headline grid (% decrease of
//! prefill duration, ISO vs serial) over {4090,A800}×{4,8}×{30b,70b}×
//! {1k..128k}, plus the §4.2 strategy-comparison rows, and time the
//! simulator itself.
//!
//! Paper reference values (Table 1):
//!   4090-4 30b: 38 42 43 44 47 48 · 70b: 43 44 45 46 47 46
//!   4090-8 30b: 11 10 18 21 30 33 36 · 70b: 14 19 22 23 35 42 39
//!   A800-4 30b:  0  8 18 11 12  9 10  5 · 70b: -6  2  8 10  9  8  8  3
//!   A800-8 30b:  8 24 22 20 16 25 11 10 · 70b:  3  9 14 15 16 15 14  7

use iso::config::Strategy;
use iso::report::{render_table1, table1, table1_csv};
use iso::util::bench::{bench, section};

fn main() {
    section("Table 1 — ISO (simulated)");
    let rows = table1(Strategy::Iso);
    print!("{}", render_table1(&rows, ""));

    section("Table 1 rows — gemm-overlap baseline (paper §4.2)");
    let gemm = table1(Strategy::GemmOverlap);
    print!("{}", render_table1(&gemm, ""));

    section("Table 1 rows — request-overlap baseline (throughput-normalized)");
    let req = table1(Strategy::RequestOverlap);
    print!("{}", render_table1(&req, ""));

    section("summary vs paper");
    let avg = |rows: &[iso::report::Table1Row], gpu: &str| {
        let (mut s, mut n) = (0.0, 0);
        for r in rows.iter().filter(|r| r.gpu == gpu) {
            for (len, red) in &r.cells {
                if *len >= 4096 {
                    s += red;
                    n += 1;
                }
            }
        }
        s / n as f64
    };
    println!(
        "4090 average (>=4k): measured {:>4.0}%   paper ~35%",
        avg(&rows, "4090") * 100.0
    );
    println!(
        "a800 average (>=4k): measured {:>4.0}%   paper ~15%",
        avg(&rows, "a800") * 100.0
    );

    section("simulator throughput");
    bench("full Table-1 grid (60 cells × 2 runs)", 1, 5, || {
        std::hint::black_box(table1(Strategy::Iso));
    });

    std::fs::create_dir_all("target/bench-out").ok();
    std::fs::write("target/bench-out/table1.csv", table1_csv(&rows)).ok();
    println!("\nwrote target/bench-out/table1.csv");
}
