//! BENCH — ring all-reduce microbenchmark: payload sweep × rank count ×
//! wire format. The collective is ISO's overlapped resource; its cost
//! model (bytes moved, quantization overhead) feeds the simulator
//! calibration.

use iso::collective::run_on_ring;
use iso::config::CommQuant;
use iso::util::bench::{bench, section};

fn main() {
    for n in [2usize, 4] {
        section(&format!("ring all-reduce, {n} ranks"));
        for (rows, cols) in [(64usize, 128usize), (192, 128), (512, 512)] {
            let elems = rows * cols;
            let mb = (elems * 4) as f64 / (1 << 20) as f64;
            for quant in [CommQuant::F32, CommQuant::Int8] {
                let label = format!(
                    "{n}r {rows}x{cols} ({mb:.1}MiB) {}",
                    if quant == CommQuant::Int8 { "int8" } else { "f32" }
                );
                let data: Vec<f32> = (0..elems).map(|i| (i % 97) as f32 * 0.01).collect();
                let r = bench(&label, 2, 10, || {
                    let d = &data;
                    run_on_ring(n, move |_, h| {
                        let mut x = d.clone();
                        h.allreduce(&mut x, rows, cols, quant);
                    });
                });
                // effective algorithm bandwidth (per rank payload / time)
                let algbw = mb / (r.mean_ms / 1e3) / 1024.0; // GiB/s
                println!("    algbw {algbw:.2} GiB/s");
            }
        }
    }

    section("quantize/dequantize kernel (wire codec)");
    let data: Vec<f32> = (0..192 * 128).map(|i| ((i * 7) % 255) as f32 * 0.01 - 1.0).collect();
    bench("quantize_rows 192x128", 5, 50, || {
        std::hint::black_box(iso::quant::quantize_rows(&data, 192, 128));
    });
    let q = iso::quant::quantize_rows(&data, 192, 128);
    bench("dequantize_rows 192x128", 5, 50, || {
        std::hint::black_box(iso::quant::dequantize_rows(&q));
    });
    let mut acc = vec![0.0f32; 192 * 128];
    bench("dequantize_add 192x128", 5, 50, || {
        iso::quant::dequantize_add(&q, &mut acc);
    });
}
