//! BENCH — ring all-reduce microbenchmark: payload sweep × rank count ×
//! wire format × segment streaming. The collective is ISO's overlapped
//! resource; its cost model (bytes moved, quantization overhead, segment
//! pipelining) feeds the simulator calibration.
//!
//! Appends a machine-readable section to `BENCH_PR1.json` (override the
//! path with `ISO_PERF_SNAPSHOT`) so the segment-sweep trend can be
//! compared against the simulator's prediction across PRs.

use iso::collective::{run_on_ring, Throttle};
use iso::config::CommQuant;
use iso::report::{append_perf_records, PerfRecord};
use iso::util::bench::{bench, section};

/// The repo's scaled-down 4090 PCIe calibration (DESIGN.md §2): the CPU
/// testbed throttles each ring hop to α + bytes/B so compute:comm ratios
/// match the paper's node, not the memory bus.
const PCIE_MBPS: f64 = 40.0;
const PCIE_ALPHA_S: f64 = 5e-6;

fn snapshot_path() -> String {
    std::env::var("ISO_PERF_SNAPSHOT").unwrap_or_else(|_| "../BENCH_PR1.json".into())
}

fn main() {
    let mut records = Vec::new();

    for n in [2usize, 4] {
        section(&format!("ring all-reduce, {n} ranks"));
        for (rows, cols) in [(64usize, 128usize), (192, 128), (512, 512)] {
            let elems = rows * cols;
            let mb = (elems * 4) as f64 / (1 << 20) as f64;
            for quant in [CommQuant::F32, CommQuant::Int8] {
                let label = format!(
                    "{n}r {rows}x{cols} ({mb:.1}MiB) {}",
                    if quant == CommQuant::Int8 { "int8" } else { "f32" }
                );
                let data: Vec<f32> = (0..elems).map(|i| (i % 97) as f32 * 0.01).collect();
                let r = bench(&label, 2, 10, || {
                    let d = &data;
                    run_on_ring(n, move |_, h| {
                        let mut x = d.clone();
                        h.allreduce(&mut x, rows, cols, quant);
                    });
                });
                // effective algorithm bandwidth (per rank payload / time)
                let algbw = mb / (r.mean_ms / 1e3) / 1024.0; // GiB/s
                println!("    algbw {algbw:.2} GiB/s");
            }
        }
    }

    // --- segment streaming sweep (the PR-1 tentpole): double-buffered
    // sub-messages hide reduction/quantization behind wire time on a
    // throttled link; more segments also means more per-message α.
    let n = 4;
    let (rows, cols) = (192usize, 128usize);
    for (link, link_label) in [
        (None, "native"),
        (Some(Throttle { alpha_s: PCIE_ALPHA_S, bytes_per_s: PCIE_MBPS * 1e6 }), "pcie-emu"),
    ] {
        section(&format!("segmented all-reduce sweep, {n} ranks {rows}x{cols}, {link_label}"));
        for quant in [CommQuant::F32, CommQuant::Int8] {
            for segments in [1usize, 2, 4, 8] {
                let qname = if quant == CommQuant::Int8 { "int8" } else { "f32" };
                let label = format!("{link_label} {qname} segments={segments}");
                let data: Vec<f32> = (0..rows * cols).map(|i| (i % 89) as f32 * 0.01).collect();
                let samples = if link.is_some() { 5 } else { 10 };
                let r = bench(&label, 1, samples, || {
                    let d = &data;
                    run_on_ring(n, move |_, h| {
                        h.throttle = link;
                        let mut x = d.clone();
                        h.allreduce_seg(&mut x, rows, cols, quant, segments);
                    });
                });
                records.push(
                    PerfRecord::new(
                        &format!("{n}r {rows}x{cols} {label}"),
                        r.mean_ms,
                        r.p50_ms,
                        r.p95_ms,
                    )
                    .with("segments", segments as f64)
                    .with("throttled", if link.is_some() { 1.0 } else { 0.0 }),
                );
            }
        }
    }

    section("quantize/dequantize kernel (wire codec)");
    let data: Vec<f32> = (0..192 * 128).map(|i| ((i * 7) % 255) as f32 * 0.01 - 1.0).collect();
    bench("quantize_rows 192x128", 5, 50, || {
        std::hint::black_box(iso::quant::quantize_rows(&data, 192, 128));
    });
    let q = iso::quant::quantize_rows(&data, 192, 128);
    bench("dequantize_rows 192x128", 5, 50, || {
        std::hint::black_box(iso::quant::dequantize_rows(&q));
    });
    let mut acc = vec![0.0f32; 192 * 128];
    bench("dequantize_add 192x128", 5, 50, || {
        iso::quant::dequantize_add(&q, &mut acc);
    });

    let path = snapshot_path();
    match append_perf_records(&path, "collective", &records) {
        Ok(()) => println!("\nwrote {} collective records to {path}", records.len()),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
}
