//! BENCH — Figure 2: the two asymmetric regimes and their mitigations.
//!
//! (a) Communication dominates (4090): int8 wire quantization drops the
//!     comm share from ~75% to ~50% and unlocks the ISO gain.
//! (b) Computation dominates (A800): NCCL SM contention inflates
//!     overlapped GEMMs 15–20%; segmenting the GEMM into multiple kernel
//!     launches reclaims the SMs the moment comm ends.

use iso::config::{SimExperiment, Strategy};
use iso::hw::NodeProfile;
use iso::model::ModelSpec;
use iso::sched::{prefill_s, reduction_vs_serial, Coster};
use iso::util::bench::section;

fn main() {
    // ---- (a) communication dominates ------------------------------------
    section("Fig 2a — 4090-4, 30b: wire format vs comm share and ISO gain");
    println!(
        "{:<8} {:>10} {:>12} {:>12} {:>10}",
        "len", "wire", "comm share", "ISO gain", "Δ vs fp16"
    );
    for len in [2048usize, 4096, 8192, 16384] {
        let node = NodeProfile::rtx4090(4);
        let model = ModelSpec::mha_30b();
        let mut fp16 = SimExperiment::new(node.clone(), model.clone(), len, Strategy::Iso);
        fp16.int8_wire = false;
        let mut int8 = fp16.clone();
        int8.int8_wire = true;

        let share = |e: &SimExperiment| {
            let c = Coster::new(e);
            let compute = c.attn_block_s(len, 0) + c.mlp_block_s(len);
            let comm = 2.0 * c.ar_s(len, 1);
            comm / (comm + compute)
        };
        let g_fp16 = reduction_vs_serial(&fp16);
        let g_int8 = reduction_vs_serial(&int8);
        println!(
            "{:>6}k {:>10} {:>11.0}% {:>11.1}% {:>10}",
            len / 1024,
            "fp16",
            share(&fp16) * 100.0,
            g_fp16 * 100.0,
            "-"
        );
        println!(
            "{:>6}k {:>10} {:>11.0}% {:>11.1}% {:>+9.1}%",
            len / 1024,
            "int8",
            share(&int8) * 100.0,
            g_int8 * 100.0,
            (g_int8 - g_fp16) * 100.0
        );
    }
    println!("paper: int8 wire reduces the 4090 comm share from ~75% to ~50%");

    // ---- (b) computation dominates ---------------------------------------
    section("Fig 2b — A800, 70b: GEMM segmentation vs SM contention");
    println!(
        "{:<10} {:<8} {:>10} {:>12} {:>12}",
        "platform", "len", "segments", "prefill", "ISO gain"
    );
    for cards in [4usize, 8] {
        for len in [8192usize, 16384] {
            for segments in [1usize, 2, 4, 8] {
                let mut e = SimExperiment::new(
                    NodeProfile::a800(cards),
                    ModelSpec::gqa_70b(),
                    len,
                    Strategy::Iso,
                );
                e.gemm_segments = segments;
                println!(
                    "{:<10} {:>6}k {:>10} {:>10.1}ms {:>11.1}%",
                    format!("a800-{cards}"),
                    len / 1024,
                    segments,
                    prefill_s(&e) * 1e3,
                    reduction_vs_serial(&e) * 100.0
                );
            }
            println!();
        }
    }
    println!("paper: contention costs 15–20% on A800, negligible on 4090;");
    println!("multiple kernel launches let compute reclaim the GPU after comm ends.");

    // sanity: segmentation must help on a800, and contention must be the reason
    let mut seg1 = SimExperiment::new(NodeProfile::a800(8), ModelSpec::gqa_70b(), 16384, Strategy::Iso);
    seg1.gemm_segments = 1;
    let mut seg4 = seg1.clone();
    seg4.gemm_segments = 4;
    assert!(prefill_s(&seg4) < prefill_s(&seg1));
}
