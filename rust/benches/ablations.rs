//! BENCH — design-choice ablations (DESIGN.md §8): which parts of the
//! calibrated model actually drive Table 1's shape?
//!
//! Each ablation zeroes one mechanism and reports the 4090/A800 average
//! ISO reductions (≥4k prompts), so reviewers can see which conclusions
//! depend on which modeling assumptions.

use iso::config::{SimExperiment, Strategy};
use iso::hw::NodeProfile;
use iso::model::ModelSpec;
use iso::report::table1_lens;
use iso::sched::reduction_vs_serial;
use iso::util::bench::section;

fn averages(mutate: impl Fn(&mut SimExperiment)) -> (f64, f64) {
    let mut sums = [0.0f64; 2];
    let mut counts = [0usize; 2];
    for (idx, gpu) in ["4090", "a800"].iter().enumerate() {
        for cards in [4usize, 8] {
            for model in ["30b", "70b"] {
                for len in table1_lens(gpu, cards) {
                    if len < 4096 {
                        continue;
                    }
                    let mut e = SimExperiment::new(
                        NodeProfile::by_name(gpu, cards).unwrap(),
                        ModelSpec::by_name(model).unwrap(),
                        len,
                        Strategy::Iso,
                    );
                    e.gemm_segments = if *gpu == "a800" { 4 } else { 1 };
                    mutate(&mut e);
                    sums[idx] += reduction_vs_serial(&e);
                    counts[idx] += 1;
                }
            }
        }
    }
    (sums[0] / counts[0] as f64, sums[1] / counts[1] as f64)
}

fn main() {
    section("ablations — average ISO reduction (>=4k cells)");
    println!("{:<44} {:>10} {:>10}", "configuration", "4090 avg", "a800 avg");

    let (g0, a0) = averages(|_| {});
    println!("{:<44} {:>9.0}% {:>9.0}%", "full model (paper setup)", g0 * 100.0, a0 * 100.0);

    let (g, a) = averages(|e| e.int8_wire = false);
    println!(
        "{:<44} {:>9.0}% {:>9.0}%",
        "− int8 wire on 4090 (fp16 comm everywhere)", g * 100.0, a * 100.0
    );

    let (g, a) = averages(|e| e.node.device.contention = 1.0);
    println!(
        "{:<44} {:>9.0}% {:>9.0}%",
        "− NCCL SM contention (factor = 1.0)", g * 100.0, a * 100.0
    );

    let (g, a) = averages(|e| e.gemm_segments = 1);
    println!(
        "{:<44} {:>9.0}% {:>9.0}%",
        "− GEMM segmentation (monolithic launches)", g * 100.0, a * 100.0
    );

    let (g, a) = averages(|e| {
        e.node.device.m_half = 0.0; // perfect small-m efficiency
    });
    println!(
        "{:<44} {:>9.0}% {:>9.0}%",
        "− small-m GEMM efficiency cliff (m_half = 0)", g * 100.0, a * 100.0
    );

    let (g, a) = averages(|e| e.node.link.alpha_s = 0.0);
    println!(
        "{:<44} {:>9.0}% {:>9.0}%",
        "− collective latency term (alpha = 0)", g * 100.0, a * 100.0
    );

    let (g, a) = averages(|e| e.split = iso::config::SplitPolicy::Even);
    println!(
        "{:<44} {:>9.0}% {:>9.0}%",
        "even 50/50 split instead of attn-balanced", g * 100.0, a * 100.0
    );

    println!();
    println!("readings: int8 wire drives the 4090 numbers; contention + segmentation");
    println!("shape the A800 numbers; the efficiency cliff is what makes short");
    println!("prompts lose (Table 1's 1k column).");
}
