//! Continuous batching: request queue, admission, and the chunked-prefill
//! batch composer that feeds the coordinator.
//!
//! The scheduler follows SARATHI-style chunked prefill (paper §2.1): every
//! engine iteration executes one *chunk* of one or more sequences. Under
//! the ISO strategy the composer emits the two intra-sequence micro-chunks
//! of the *same* sequence so the coordinator can ping-pong their
//! compute/communication (paper §3.1); under the serial strategy it emits
//! one chunk at a time.

use std::collections::VecDeque;

use crate::config::{SplitPolicy, Strategy};
use crate::workload::Request;

/// Scheduler state of one live sequence.
#[derive(Clone, Debug)]
pub struct SeqState {
    pub id: u64,
    pub prompt: Vec<i32>,
    /// Tokens already prefixed into the KV cache.
    pub done: usize,
    pub decode_steps: usize,
    pub decoded: usize,
    pub arrival_s: f64,
}

impl SeqState {
    pub fn new(r: &Request) -> Self {
        SeqState {
            id: r.id,
            prompt: r.prompt.clone(),
            done: 0,
            decode_steps: r.decode_steps,
            decoded: 0,
            arrival_s: r.arrival_s,
        }
    }

    pub fn prefill_remaining(&self) -> usize {
        self.prompt.len().saturating_sub(self.done)
    }

    pub fn in_decode(&self) -> bool {
        self.prefill_remaining() == 0 && self.decoded < self.decode_steps
    }

    pub fn finished(&self) -> bool {
        self.prefill_remaining() == 0 && self.decoded >= self.decode_steps
    }
}

/// One schedulable unit of work: a chunk of a sequence's prefill.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ChunkJob {
    pub seq: u64,
    /// Index of the first token of the chunk within the sequence.
    pub offset: usize,
    /// Chunk length (must match a compiled artifact chunk size).
    pub len: usize,
    /// Micro-batch lane for ISO ping-pong (0 or 1).
    pub lane: usize,
    /// True if this chunk completes the sequence's prefill.
    pub last: bool,
}

/// The prefill plan for one sequence under a strategy: a list of chunk
/// jobs whose lengths tile the prompt with compiled chunk sizes.
pub fn plan_prefill(
    seq: u64,
    prompt_len: usize,
    strategy: Strategy,
    split: SplitPolicy,
    chunk_sizes: &[usize],
) -> Vec<ChunkJob> {
    assert!(!chunk_sizes.is_empty());
    let mut sizes: Vec<usize> = chunk_sizes.to_vec();
    sizes.sort_unstable();

    match strategy {
        Strategy::Iso => {
            // Split the sequence into two micro-batches (lanes), then tile
            // each lane with compiled chunk sizes. Lane 1 may only start a
            // given layer after lane 0 — enforced by the coordinator; here
            // we fix lane membership and offsets.
            let t0 = match split {
                SplitPolicy::Even => prompt_len / 2,
                SplitPolicy::Ratio(r) => {
                    ((prompt_len as f64 * r).round() as usize).clamp(1, prompt_len - 1)
                }
                // Engine-side balanced split: causal attention makes the
                // tail heavier, so give the head slightly more tokens
                // (cheap closed-form of split::choose_split's bisection:
                // t0 s.t. t0^2/2 == t^2/2 - t0^2/2 ... i.e. t0 = t/sqrt2
                // on the attention term; temper toward even for the
                // position-free GEMM share).
                SplitPolicy::AttnBalanced | SplitPolicy::AdaptiveAttnMlp => {
                    (prompt_len as f64 * 0.55).round() as usize
                }
            };
            let t0 = round_to_tiles(t0.clamp(1, prompt_len - 1), &sizes, prompt_len);
            let mut jobs = tile(seq, 0, t0, 0, &sizes);
            jobs.extend(tile(seq, t0, prompt_len - t0, 1, &sizes));
            if let Some(j) = jobs.last_mut() {
                j.last = true;
            }
            jobs
        }
        _ => {
            let mut jobs = tile(seq, 0, prompt_len, 0, &sizes);
            if let Some(j) = jobs.last_mut() {
                j.last = true;
            }
            jobs
        }
    }
}

/// Tile `len` tokens starting at `offset` with the largest chunks first.
fn tile(seq: u64, offset: usize, len: usize, lane: usize, sizes: &[usize]) -> Vec<ChunkJob> {
    let mut out = Vec::new();
    let mut pos = 0;
    while pos < len {
        let remaining = len - pos;
        // Largest compiled size that fits; fall back to the smallest size
        // (callers pad prompts to a multiple of the smallest size).
        let size = sizes
            .iter()
            .rev()
            .find(|&&s| s <= remaining)
            .copied()
            .unwrap_or_else(|| panic!("remaining {remaining} below smallest chunk {sizes:?}"));
        out.push(ChunkJob { seq, offset: offset + pos, len: size, lane, last: false });
        pos += size;
    }
    out
}

/// Round `t0` to something exactly tileable, keeping it in (0, total).
fn round_to_tiles(t0: usize, sizes: &[usize], total: usize) -> usize {
    let g = sizes[0]; // smallest compiled chunk
    let rounded = ((t0 + g / 2) / g * g).clamp(g, total - g);
    rounded
}

/// FIFO admission queue with a live-sequence cap.
#[derive(Debug)]
pub struct Admission {
    queue: VecDeque<Request>,
    pub max_live: usize,
    pub live: usize,
}

impl Admission {
    pub fn new(max_live: usize) -> Self {
        Admission { queue: VecDeque::new(), max_live, live: 0 }
    }

    pub fn submit(&mut self, r: Request) {
        self.queue.push_back(r);
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Admit as many requests as capacity allows.
    pub fn admit(&mut self) -> Vec<Request> {
        let mut out = Vec::new();
        while self.live < self.max_live {
            match self.queue.pop_front() {
                Some(r) => {
                    self.live += 1;
                    out.push(r);
                }
                None => break,
            }
        }
        out
    }

    pub fn complete(&mut self) {
        assert!(self.live > 0, "complete() without a live sequence");
        self.live -= 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prop;

    const SIZES: &[usize] = &[16, 32, 64];

    #[test]
    fn serial_plan_tiles_whole_prompt() {
        let jobs = plan_prefill(1, 96, Strategy::Serial, SplitPolicy::Even, SIZES);
        let total: usize = jobs.iter().map(|j| j.len).sum();
        assert_eq!(total, 96);
        assert_eq!(jobs[0].offset, 0);
        assert!(jobs.last().unwrap().last);
        assert!(jobs.iter().all(|j| j.lane == 0));
        // offsets are contiguous
        let mut pos = 0;
        for j in &jobs {
            assert_eq!(j.offset, pos);
            pos += j.len;
        }
    }

    #[test]
    fn iso_plan_has_two_lanes_contiguous() {
        let jobs = plan_prefill(1, 128, Strategy::Iso, SplitPolicy::Even, SIZES);
        let lane0: usize = jobs.iter().filter(|j| j.lane == 0).map(|j| j.len).sum();
        let lane1: usize = jobs.iter().filter(|j| j.lane == 1).map(|j| j.len).sum();
        assert_eq!(lane0 + lane1, 128);
        assert_eq!(lane0, 64);
        // lane 1 starts exactly where lane 0 ends
        let first1 = jobs.iter().find(|j| j.lane == 1).unwrap();
        assert_eq!(first1.offset, lane0);
    }

    #[test]
    fn iso_balanced_gives_head_more_tokens() {
        let jobs = plan_prefill(1, 128, Strategy::Iso, SplitPolicy::AttnBalanced, SIZES);
        let lane0: usize = jobs.iter().filter(|j| j.lane == 0).map(|j| j.len).sum();
        assert!(lane0 > 48 && lane0 < 128, "lane0 = {lane0}");
    }

    #[test]
    fn ratio_split_respects_tiles() {
        let jobs = plan_prefill(1, 128, Strategy::Iso, SplitPolicy::Ratio(0.6), SIZES);
        let lane0: usize = jobs.iter().filter(|j| j.lane == 0).map(|j| j.len).sum();
        assert_eq!(lane0 % 16, 0);
        assert!(lane0 >= 16 && lane0 <= 112);
    }

    #[test]
    fn prop_plan_tiles_exactly_with_compiled_sizes() {
        Prop::new(57).cases(200).run("prefill plan tiles prompt", |rng| {
            let len = rng.range(2, 40) * 16; // padded prompts
            let strat = if rng.f64() < 0.5 { Strategy::Iso } else { Strategy::Serial };
            let jobs = plan_prefill(7, len, strat, SplitPolicy::Even, SIZES);
            let total: usize = jobs.iter().map(|j| j.len).sum();
            if total != len {
                return Err(format!("tiled {total} != {len}"));
            }
            for j in &jobs {
                if !SIZES.contains(&j.len) {
                    return Err(format!("chunk size {} not compiled", j.len));
                }
            }
            // offsets contiguous within each lane, lane1 after lane0
            let mut pos = 0;
            for j in jobs.iter().filter(|j| j.lane == 0) {
                if j.offset != pos {
                    return Err(format!("lane0 gap at {pos}"));
                }
                pos += j.len;
            }
            for j in jobs.iter().filter(|j| j.lane == 1) {
                if j.offset != pos {
                    return Err(format!("lane1 gap at {pos}"));
                }
                pos += j.len;
            }
            // exactly one `last`
            if jobs.iter().filter(|j| j.last).count() != 1 {
                return Err("need exactly one last chunk".into());
            }
            Ok(())
        });
    }

    #[test]
    fn seq_state_lifecycle() {
        let r = Request { id: 1, arrival_s: 0.0, prompt: vec![0; 32], decode_steps: 2 };
        let mut s = SeqState::new(&r);
        assert_eq!(s.prefill_remaining(), 32);
        assert!(!s.in_decode() && !s.finished());
        s.done = 32;
        assert!(s.in_decode());
        s.decoded = 2;
        assert!(s.finished());
    }

    #[test]
    fn admission_respects_cap() {
        let mut a = Admission::new(2);
        for i in 0..5 {
            a.submit(Request { id: i, arrival_s: 0.0, prompt: vec![0; 4], decode_steps: 0 });
        }
        assert_eq!(a.admit().len(), 2);
        assert_eq!(a.pending(), 3);
        assert!(a.admit().is_empty());
        a.complete();
        assert_eq!(a.admit().len(), 1);
    }

    #[test]
    #[should_panic]
    fn complete_without_live_panics() {
        Admission::new(1).complete();
    }
}
