//! Continuous batching: request queue, admission, the chunked-prefill
//! planner, and the iteration-level mixed-batch composer that feeds the
//! coordinator.
//!
//! The scheduler follows SARATHI-style chunked prefill (paper §2.1): every
//! engine iteration executes one *chunk* of one or more sequences. Under
//! the ISO strategy the composer emits the two intra-sequence micro-chunks
//! of the *same* sequence so the coordinator can ping-pong their
//! compute/communication (paper §3.1); under the serial strategy it emits
//! one chunk at a time.
//!
//! Mixed iterations (DESIGN.md §9): [`MixedPlanner`] composes each engine
//! iteration from (a) the ISO chunk set of the head-of-line sequence
//! still needing prefill and (b) a **fused decode lane** — one decode
//! token for up to `decode_batch` live sequences, rotated for fairness —
//! so decode collectives batch into one B-row all-reduce per layer-stage
//! and decode compute slides into the prefill's communication windows
//! (paper Fig 1c composed with Fig 1d).

use std::collections::VecDeque;

use crate::config::{SplitPolicy, Strategy};
use crate::split::SplitContext;
use crate::workload::Request;

/// Scheduler state of one live sequence.
#[derive(Clone, Debug)]
pub struct SeqState {
    pub id: u64,
    pub prompt: Vec<i32>,
    /// Tokens already prefixed into the KV cache.
    pub done: usize,
    pub decode_steps: usize,
    pub decoded: usize,
    pub arrival_s: f64,
}

impl SeqState {
    pub fn new(r: &Request) -> Self {
        SeqState {
            id: r.id,
            prompt: r.prompt.clone(),
            done: 0,
            decode_steps: r.decode_steps,
            decoded: 0,
            arrival_s: r.arrival_s,
        }
    }

    pub fn prefill_remaining(&self) -> usize {
        self.prompt.len().saturating_sub(self.done)
    }

    pub fn in_decode(&self) -> bool {
        self.prefill_remaining() == 0 && self.decoded < self.decode_steps
    }

    pub fn finished(&self) -> bool {
        self.prefill_remaining() == 0 && self.decoded >= self.decode_steps
    }
}

/// One schedulable unit of work: a chunk of a sequence's prefill.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ChunkJob {
    pub seq: u64,
    /// Index of the first token of the chunk within the sequence.
    pub offset: usize,
    /// Chunk length (must match a compiled artifact chunk size).
    pub len: usize,
    /// Micro-batch lane for ISO ping-pong (0 or 1).
    pub lane: usize,
    /// True if this chunk completes the sequence's prefill.
    pub last: bool,
}

/// The prefill plan for one sequence under a strategy: a list of chunk
/// jobs whose lengths tile the prompt with compiled chunk sizes.
///
/// When a calibrated [`SplitContext`] is supplied, the balanced policies
/// solve `split::choose_split` against it — the same bisection the
/// simulator and benches use — so all three agree on the split point.
/// Without one, the old closed-form 0.55 head fraction stands in.
pub fn plan_prefill(
    seq: u64,
    prompt_len: usize,
    strategy: Strategy,
    split: SplitPolicy,
    chunk_sizes: &[usize],
    ctx: Option<&SplitContext>,
) -> Vec<ChunkJob> {
    assert!(!chunk_sizes.is_empty());
    let mut sizes: Vec<usize> = chunk_sizes.to_vec();
    sizes.sort_unstable();

    // Prompts shorter than two tiles cannot form two lanes — the old
    // rounding would clamp into an inverted range and panic. Serial
    // single-lane fallback (one lane ⇒ nothing to overlap anyway).
    let splittable = prompt_len >= 2 * sizes[0];

    match strategy {
        Strategy::Iso if splittable => {
            // Split the sequence into two micro-batches (lanes), then tile
            // each lane with compiled chunk sizes. Lane 1 may only start a
            // given layer after lane 0 — enforced by the coordinator; here
            // we fix lane membership and offsets.
            let t0 = match split {
                SplitPolicy::Even => prompt_len / 2,
                SplitPolicy::Ratio(r) => {
                    ((prompt_len as f64 * r).round() as usize).clamp(1, prompt_len - 1)
                }
                SplitPolicy::AttnBalanced | SplitPolicy::AdaptiveAttnMlp => match ctx {
                    Some(c) => {
                        crate::split::choose_split(split, &c.node, &c.model, prompt_len).t0
                    }
                    None => (prompt_len as f64 * 0.55).round() as usize,
                },
            };
            let t0 = round_to_tiles(t0.clamp(1, prompt_len - 1), &sizes, prompt_len);
            let mut jobs = tile(seq, 0, t0, 0, &sizes);
            jobs.extend(tile(seq, t0, prompt_len - t0, 1, &sizes));
            if let Some(j) = jobs.last_mut() {
                j.last = true;
            }
            jobs
        }
        _ => {
            let mut jobs = tile(seq, 0, prompt_len, 0, &sizes);
            if let Some(j) = jobs.last_mut() {
                j.last = true;
            }
            jobs
        }
    }
}

/// Tile `len` tokens starting at `offset` with the largest chunks first.
fn tile(seq: u64, offset: usize, len: usize, lane: usize, sizes: &[usize]) -> Vec<ChunkJob> {
    let mut out = Vec::new();
    let mut pos = 0;
    while pos < len {
        let remaining = len - pos;
        // Largest compiled size that fits; fall back to the smallest size
        // (callers pad prompts to a multiple of the smallest size).
        let size = sizes
            .iter()
            .rev()
            .find(|&&s| s <= remaining)
            .copied()
            .unwrap_or_else(|| panic!("remaining {remaining} below smallest chunk {sizes:?}"));
        out.push(ChunkJob { seq, offset: offset + pos, len: size, lane, last: false });
        pos += size;
    }
    out
}

/// Round `t0` to something exactly tileable, keeping it in (0, total).
fn round_to_tiles(t0: usize, sizes: &[usize], total: usize) -> usize {
    let g = sizes[0]; // smallest compiled chunk
    let rounded = ((t0 + g / 2) / g * g).clamp(g, total - g);
    rounded
}

/// One decode-lane entry of a mixed iteration: feed `token` (the
/// sequence's latest emission) to the slot's KV state at absolute
/// position `offset`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DecodeSlot {
    pub slot: usize,
    pub token: i32,
    pub offset: usize,
}

/// The prefill half of a [`StepPlan`].
#[derive(Clone, Debug)]
pub struct PrefillPlan {
    pub slot: usize,
    /// Padded prompt length the chunks tile exactly.
    pub prompt_len: usize,
    pub chunks: Vec<ChunkJob>,
}

/// One engine iteration under the mixed scheduler: at most one
/// head-of-line prefill's ISO chunk set plus a fused decode micro-batch.
#[derive(Clone, Debug, Default)]
pub struct StepPlan {
    pub prefill: Option<PrefillPlan>,
    pub decode: Vec<DecodeSlot>,
}

impl StepPlan {
    pub fn is_empty(&self) -> bool {
        self.prefill.is_none() && self.decode.is_empty()
    }

    /// Tokens this iteration advances (prefill tokens + decode lane rows).
    pub fn tokens(&self) -> usize {
        self.prefill.as_ref().map_or(0, |p| p.prompt_len) + self.decode.len()
    }
}

/// Scheduler-visible state of one live sequence, as the leader loop
/// tracks it between iterations.
#[derive(Clone, Debug)]
pub struct LaneSeq {
    pub slot: usize,
    /// Padded prompt length (tiles exactly into compiled chunk sizes).
    pub prompt_len: usize,
    pub prefilled: bool,
    /// Latest emitted token (valid once `prefilled`).
    pub last_token: i32,
    /// Absolute position `last_token` will occupy — the next decode
    /// attention offset.
    pub offset: usize,
    /// Decode steps still owed; 0 retires the sequence from the lane.
    pub decode_left: usize,
}

impl LaneSeq {
    /// Eligible for the decode lane this iteration.
    pub fn decoding(&self, max_seq: usize) -> bool {
        self.prefilled && self.decode_left > 0 && self.offset < max_seq
    }
}

/// Iteration-level mixed-batch composer (DESIGN.md §9). Each `plan` call
/// emits one [`StepPlan`]: the first un-prefilled sequence's chunk set
/// (one prefill per iteration keeps TTFT bounded while the lane streams)
/// plus up to `decode_batch` decode rows, selected round-robin so a lane
/// wider than the cap shares iterations fairly.
#[derive(Clone, Debug)]
pub struct MixedPlanner {
    pub strategy: Strategy,
    pub split: SplitPolicy,
    pub chunk_sizes: Vec<usize>,
    pub decode_batch: usize,
    pub max_seq: usize,
    cursor: usize,
}

impl MixedPlanner {
    pub fn new(
        strategy: Strategy,
        split: SplitPolicy,
        chunk_sizes: Vec<usize>,
        decode_batch: usize,
        max_seq: usize,
    ) -> Self {
        assert!(decode_batch >= 1, "decode_batch must be >= 1");
        assert!(!chunk_sizes.is_empty());
        MixedPlanner { strategy, split, chunk_sizes, decode_batch, max_seq, cursor: 0 }
    }

    /// Compose the next iteration from the live set.
    pub fn plan(&mut self, live: &[LaneSeq], ctx: Option<&SplitContext>) -> StepPlan {
        let prefill = live.iter().find(|s| !s.prefilled).map(|s| PrefillPlan {
            slot: s.slot,
            prompt_len: s.prompt_len,
            chunks: plan_prefill(
                s.slot as u64,
                s.prompt_len,
                self.strategy,
                self.split,
                &self.chunk_sizes,
                ctx,
            ),
        });
        let eligible: Vec<&LaneSeq> =
            live.iter().filter(|s| s.decoding(self.max_seq)).collect();
        let width = eligible.len().min(self.decode_batch);
        let mut decode = Vec::with_capacity(width);
        if width > 0 {
            let start = self.cursor % eligible.len();
            for j in 0..width {
                let s = eligible[(start + j) % eligible.len()];
                decode.push(DecodeSlot { slot: s.slot, token: s.last_token, offset: s.offset });
            }
            self.cursor = self.cursor.wrapping_add(width);
        }
        StepPlan { prefill, decode }
    }
}

/// FIFO admission queue with a live-sequence cap.
#[derive(Debug)]
pub struct Admission {
    queue: VecDeque<Request>,
    pub max_live: usize,
    pub live: usize,
}

impl Admission {
    pub fn new(max_live: usize) -> Self {
        Admission { queue: VecDeque::new(), max_live, live: 0 }
    }

    pub fn submit(&mut self, r: Request) {
        self.queue.push_back(r);
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Admit as many requests as capacity allows.
    pub fn admit(&mut self) -> Vec<Request> {
        let mut out = Vec::new();
        while self.live < self.max_live {
            match self.queue.pop_front() {
                Some(r) => {
                    self.live += 1;
                    out.push(r);
                }
                None => break,
            }
        }
        out
    }

    pub fn complete(&mut self) {
        assert!(self.live > 0, "complete() without a live sequence");
        self.live -= 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prop;

    const SIZES: &[usize] = &[16, 32, 64];

    #[test]
    fn serial_plan_tiles_whole_prompt() {
        let jobs = plan_prefill(1, 96, Strategy::Serial, SplitPolicy::Even, SIZES, None);
        let total: usize = jobs.iter().map(|j| j.len).sum();
        assert_eq!(total, 96);
        assert_eq!(jobs[0].offset, 0);
        assert!(jobs.last().unwrap().last);
        assert!(jobs.iter().all(|j| j.lane == 0));
        // offsets are contiguous
        let mut pos = 0;
        for j in &jobs {
            assert_eq!(j.offset, pos);
            pos += j.len;
        }
    }

    #[test]
    fn iso_plan_has_two_lanes_contiguous() {
        let jobs = plan_prefill(1, 128, Strategy::Iso, SplitPolicy::Even, SIZES, None);
        let lane0: usize = jobs.iter().filter(|j| j.lane == 0).map(|j| j.len).sum();
        let lane1: usize = jobs.iter().filter(|j| j.lane == 1).map(|j| j.len).sum();
        assert_eq!(lane0 + lane1, 128);
        assert_eq!(lane0, 64);
        // lane 1 starts exactly where lane 0 ends
        let first1 = jobs.iter().find(|j| j.lane == 1).unwrap();
        assert_eq!(first1.offset, lane0);
    }

    #[test]
    fn iso_balanced_gives_head_more_tokens() {
        let jobs = plan_prefill(1, 128, Strategy::Iso, SplitPolicy::AttnBalanced, SIZES, None);
        let lane0: usize = jobs.iter().filter(|j| j.lane == 0).map(|j| j.len).sum();
        assert!(lane0 > 48 && lane0 < 128, "lane0 = {lane0}");
    }

    #[test]
    fn ratio_split_respects_tiles() {
        let jobs = plan_prefill(1, 128, Strategy::Iso, SplitPolicy::Ratio(0.6), SIZES, None);
        let lane0: usize = jobs.iter().filter(|j| j.lane == 0).map(|j| j.len).sum();
        assert_eq!(lane0 % 16, 0);
        assert!(lane0 >= 16 && lane0 <= 112);
    }

    #[test]
    fn prop_plan_tiles_exactly_with_compiled_sizes() {
        Prop::new(57).cases(200).run("prefill plan tiles prompt", |rng| {
            let len = rng.range(2, 40) * 16; // padded prompts
            let strat = if rng.f64() < 0.5 { Strategy::Iso } else { Strategy::Serial };
            let jobs = plan_prefill(7, len, strat, SplitPolicy::Even, SIZES, None);
            let total: usize = jobs.iter().map(|j| j.len).sum();
            if total != len {
                return Err(format!("tiled {total} != {len}"));
            }
            for j in &jobs {
                if !SIZES.contains(&j.len) {
                    return Err(format!("chunk size {} not compiled", j.len));
                }
            }
            // offsets contiguous within each lane, lane1 after lane0
            let mut pos = 0;
            for j in jobs.iter().filter(|j| j.lane == 0) {
                if j.offset != pos {
                    return Err(format!("lane0 gap at {pos}"));
                }
                pos += j.len;
            }
            for j in jobs.iter().filter(|j| j.lane == 1) {
                if j.offset != pos {
                    return Err(format!("lane1 gap at {pos}"));
                }
                pos += j.len;
            }
            // exactly one `last`
            if jobs.iter().filter(|j| j.last).count() != 1 {
                return Err("need exactly one last chunk".into());
            }
            Ok(())
        });
    }

    #[test]
    fn seq_state_lifecycle() {
        let r = Request { id: 1, arrival_s: 0.0, prompt: vec![0; 32], decode_steps: 2 };
        let mut s = SeqState::new(&r);
        assert_eq!(s.prefill_remaining(), 32);
        assert!(!s.in_decode() && !s.finished());
        s.done = 32;
        assert!(s.in_decode());
        s.decoded = 2;
        assert!(s.finished());
    }

    #[test]
    fn admission_respects_cap() {
        let mut a = Admission::new(2);
        for i in 0..5 {
            a.submit(Request { id: i, arrival_s: 0.0, prompt: vec![0; 4], decode_steps: 0 });
        }
        assert_eq!(a.admit().len(), 2);
        assert_eq!(a.pending(), 3);
        assert!(a.admit().is_empty());
        a.complete();
        assert_eq!(a.admit().len(), 1);
    }

    #[test]
    #[should_panic]
    fn complete_without_live_panics() {
        Admission::new(1).complete();
    }

    #[test]
    fn iso_short_prompt_falls_back_to_single_lane() {
        // Regression: prompt_len < 2 × smallest chunk used to hit
        // `clamp(g, total - g)` with an inverted range and panic.
        let jobs = plan_prefill(1, 16, Strategy::Iso, SplitPolicy::Even, SIZES, None);
        assert_eq!(jobs.iter().map(|j| j.len).sum::<usize>(), 16);
        assert!(jobs.iter().all(|j| j.lane == 0), "short prompt must be single-lane");
        assert_eq!(jobs.iter().filter(|j| j.last).count(), 1);
        for policy in [
            SplitPolicy::Even,
            SplitPolicy::Ratio(0.9),
            SplitPolicy::AttnBalanced,
            SplitPolicy::AdaptiveAttnMlp,
        ] {
            let jobs = plan_prefill(1, 16, Strategy::Iso, policy, SIZES, None);
            assert_eq!(jobs.iter().map(|j| j.len).sum::<usize>(), 16, "{policy:?}");
        }
    }

    #[test]
    fn balanced_split_agrees_with_cost_model_when_ctx_given() {
        // Satellite: no more hardcoded 0.55 — with a calibrated context
        // the engine-side plan lands on choose_split's t0 (tile-rounded).
        use crate::hw::NodeProfile;
        use crate::model::ModelSpec;
        use crate::split::{choose_split, SplitContext};
        let ctx = SplitContext::new(NodeProfile::a800(4), ModelSpec::gqa_70b());
        for len in [128usize, 512, 4096] {
            let jobs =
                plan_prefill(1, len, Strategy::Iso, SplitPolicy::AttnBalanced, SIZES, Some(&ctx));
            let lane0: usize = jobs.iter().filter(|j| j.lane == 0).map(|j| j.len).sum();
            let want = choose_split(SplitPolicy::AttnBalanced, &ctx.node, &ctx.model, len).t0;
            let g = SIZES[0];
            let want_rounded = ((want + g / 2) / g * g).clamp(g, len - g);
            assert_eq!(lane0, want_rounded, "len={len}");
        }
    }

    #[test]
    fn prop_iso_never_panics_on_padded_prompts() {
        Prop::new(91).cases(300).run("iso plan total lengths", |rng| {
            // Anything the engine can pad to: multiples of the smallest
            // chunk, including a single tile.
            let len = rng.range(1, 30) * 16;
            for policy in [SplitPolicy::Even, SplitPolicy::AttnBalanced] {
                let jobs = plan_prefill(3, len, Strategy::Iso, policy, SIZES, None);
                let total: usize = jobs.iter().map(|j| j.len).sum();
                if total != len {
                    return Err(format!("len={len}: tiled {total}"));
                }
                if jobs.iter().filter(|j| j.last).count() != 1 {
                    return Err(format!("len={len}: last count"));
                }
            }
            Ok(())
        });
    }

    fn lane_seq(slot: usize, prefilled: bool, offset: usize, left: usize) -> LaneSeq {
        LaneSeq {
            slot,
            prompt_len: 64,
            prefilled,
            last_token: slot as i32 + 100,
            offset,
            decode_left: left,
        }
    }

    #[test]
    fn planner_composes_head_of_line_prefill_and_lane() {
        let mut p = MixedPlanner::new(Strategy::Iso, SplitPolicy::Even, SIZES.to_vec(), 8, 256);
        let live = vec![
            lane_seq(0, true, 64, 3),
            lane_seq(1, false, 0, 3),
            lane_seq(2, true, 70, 1),
            lane_seq(3, false, 0, 3), // second un-prefilled seq must wait
        ];
        let plan = p.plan(&live, None);
        let pf = plan.prefill.expect("head-of-line prefill");
        assert_eq!(pf.slot, 1);
        assert_eq!(pf.chunks.iter().map(|c| c.len).sum::<usize>(), 64);
        assert_eq!(plan.decode.len(), 2);
        let slots: Vec<usize> = plan.decode.iter().map(|d| d.slot).collect();
        assert!(slots.contains(&0) && slots.contains(&2));
        // lane offsets come straight from sequence state
        for d in &plan.decode {
            let s = live.iter().find(|s| s.slot == d.slot).unwrap();
            assert_eq!(d.offset, s.offset);
            assert_eq!(d.token, s.last_token);
        }
        // a prefilling sequence is never also in the lane
        assert!(plan.decode.iter().all(|d| d.slot != pf.slot));
    }

    #[test]
    fn planner_caps_and_rotates_lane() {
        let mut p = MixedPlanner::new(Strategy::Iso, SplitPolicy::Even, SIZES.to_vec(), 2, 256);
        let live: Vec<LaneSeq> = (0..5).map(|s| lane_seq(s, true, 64, 10)).collect();
        let mut seen = [0usize; 5];
        for _ in 0..10 {
            let plan = p.plan(&live, None);
            assert!(plan.prefill.is_none());
            assert_eq!(plan.decode.len(), 2, "lane must be capped at decode_batch");
            for d in &plan.decode {
                seen[d.slot] += 1;
            }
        }
        // Rotation shares the 20 lane rows across all 5 sequences.
        assert_eq!(seen.iter().sum::<usize>(), 20);
        assert!(seen.iter().all(|&c| c == 4), "unfair rotation: {seen:?}");
    }

    #[test]
    fn planner_skips_finished_and_overlong_sequences() {
        let mut p = MixedPlanner::new(Strategy::Iso, SplitPolicy::Even, SIZES.to_vec(), 8, 128);
        let live = vec![
            lane_seq(0, true, 64, 0),   // out of decode budget
            lane_seq(1, true, 128, 5),  // at max_seq
            lane_seq(2, true, 100, 5),  // eligible
        ];
        let plan = p.plan(&live, None);
        assert_eq!(plan.decode.len(), 1);
        assert_eq!(plan.decode[0].slot, 2);
        assert!(!plan.is_empty());
        let empty = p.plan(&[], None);
        assert!(empty.is_empty());
        assert_eq!(empty.tokens(), 0);
    }

    #[test]
    fn prop_step_plan_conserves_tokens_and_kv_order() {
        // Satellite: every StepPlan conserves tokens (the prefill chunk
        // set tiles the padded prompt exactly; the lane advances exactly
        // one token per entry) and respects the KV ordering constraint
        // (chunk offsets contiguous, lane 1 strictly after lane 0, decode
        // offsets taken verbatim from sequence state, no slot in both
        // halves of the iteration).
        Prop::new(97).cases(200).run("step plan invariants", |rng| {
            let mut planner = MixedPlanner::new(
                Strategy::Iso,
                SplitPolicy::Even,
                SIZES.to_vec(),
                rng.range(1, 6),
                256,
            );
            let n = rng.range(1, 10);
            let live: Vec<LaneSeq> = (0..n)
                .map(|s| LaneSeq {
                    slot: s,
                    prompt_len: rng.range(1, 12) * 16,
                    prefilled: rng.f64() < 0.7,
                    last_token: rng.range(0, 512) as i32,
                    offset: rng.range(1, 256),
                    decode_left: rng.range(0, 5),
                })
                .collect();
            let plan = planner.plan(&live, None);
            if plan.decode.len() > planner.decode_batch {
                return Err(format!("lane {} over cap", plan.decode.len()));
            }
            if let Some(pf) = &plan.prefill {
                let total: usize = pf.chunks.iter().map(|c| c.len).sum();
                if total != pf.prompt_len {
                    return Err(format!("prefill tiles {total} != {}", pf.prompt_len));
                }
                // KV order: lane-0 chunks contiguous from 0, lane-1 after.
                let mut pos = 0;
                for lane in [0usize, 1] {
                    for c in pf.chunks.iter().filter(|c| c.lane == lane) {
                        if c.offset != pos {
                            return Err(format!("lane{lane} gap at {pos}"));
                        }
                        pos += c.len;
                    }
                }
                if plan.decode.iter().any(|d| d.slot == pf.slot) {
                    return Err("slot both prefilling and decoding".into());
                }
                if live.iter().find(|s| s.slot == pf.slot).map(|s| s.prefilled) != Some(false)
                {
                    return Err("prefill picked an already-prefilled seq".into());
                }
            }
            let mut lane_slots = Vec::new();
            for d in &plan.decode {
                let s = live.iter().find(|s| s.slot == d.slot).ok_or("unknown lane slot")?;
                if !s.decoding(planner.max_seq) {
                    return Err(format!("ineligible slot {} in lane", d.slot));
                }
                if d.offset != s.offset || d.token != s.last_token {
                    return Err(format!("lane entry desynced from seq state: {d:?}"));
                }
                lane_slots.push(d.slot);
            }
            lane_slots.sort_unstable();
            lane_slots.dedup();
            if lane_slots.len() != plan.decode.len() {
                return Err("duplicate slot in lane".into());
            }
            if plan.tokens()
                != plan.prefill.as_ref().map_or(0, |p| p.prompt_len) + plan.decode.len()
            {
                return Err("token accounting".into());
            }
            Ok(())
        });
    }
}
