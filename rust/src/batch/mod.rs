//! Continuous batching: request queue, admission, the chunked-prefill
//! planner, and the iteration-level mixed-batch composer that feeds the
//! coordinator.
//!
//! The scheduler follows SARATHI-style chunked prefill (paper §2.1): every
//! engine iteration executes one *chunk* of one or more sequences. Under
//! the ISO strategy the composer emits the two intra-sequence micro-chunks
//! of the *same* sequence so the coordinator can ping-pong their
//! compute/communication (paper §3.1); under the serial strategy it emits
//! one chunk at a time.
//!
//! Mixed iterations (DESIGN.md §9): [`MixedPlanner`] composes each engine
//! iteration from (a) the ISO chunk set of the head-of-line sequence
//! still needing prefill and (b) a **fused decode lane** — one decode
//! token for up to `decode_batch` live sequences, rotated for fairness —
//! so decode collectives batch into one B-row all-reduce per layer-stage
//! and decode compute slides into the prefill's communication windows
//! (paper Fig 1c composed with Fig 1d).
//!
//! Speculative decoding (DESIGN.md §10): with `spec_k > 0` every decode
//! lane entry widens into a *verify window* ([`SpecSlot`]) — the
//! sequence's last emitted token plus up to `k` draft tokens from a
//! [`DraftProposer`] — so each iteration advances a sequence by up to
//! `k + 1` tokens while the lane's collectives stay fused into one
//! `B·(k+1)`-row all-reduce per layer-stage. Greedy acceptance
//! ([`accept_count`]) keeps the emitted stream identical to the
//! non-speculative baseline.

use std::collections::VecDeque;

use crate::config::{SplitPolicy, Strategy};
use crate::split::SplitContext;
use crate::workload::Request;

/// Scheduler state of one live sequence.
#[derive(Clone, Debug)]
pub struct SeqState {
    /// Request id.
    pub id: u64,
    /// Prompt token ids.
    pub prompt: Vec<i32>,
    /// Tokens already prefixed into the KV cache.
    pub done: usize,
    /// Decode steps the request asked for.
    pub decode_steps: usize,
    /// Decode steps already taken.
    pub decoded: usize,
    /// Arrival time (seconds from trace start).
    pub arrival_s: f64,
}

impl SeqState {
    /// Scheduler state for a fresh request (nothing prefilled yet).
    pub fn new(r: &Request) -> Self {
        SeqState {
            id: r.id,
            prompt: r.prompt.clone(),
            done: 0,
            decode_steps: r.decode_steps,
            decoded: 0,
            arrival_s: r.arrival_s,
        }
    }

    /// Prompt tokens not yet prefixed into the KV cache.
    pub fn prefill_remaining(&self) -> usize {
        self.prompt.len().saturating_sub(self.done)
    }

    /// Prefill done but decode budget left.
    pub fn in_decode(&self) -> bool {
        self.prefill_remaining() == 0 && self.decoded < self.decode_steps
    }

    /// Both prefill and decode complete.
    pub fn finished(&self) -> bool {
        self.prefill_remaining() == 0 && self.decoded >= self.decode_steps
    }
}

/// One schedulable unit of work: a chunk of a sequence's prefill.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ChunkJob {
    /// Owning sequence id.
    pub seq: u64,
    /// Index of the first token of the chunk within the sequence.
    pub offset: usize,
    /// Chunk length (must match a compiled artifact chunk size).
    pub len: usize,
    /// Micro-batch lane for ISO ping-pong (0 or 1).
    pub lane: usize,
    /// True if this chunk completes the sequence's prefill.
    pub last: bool,
}

/// The prefill plan for one sequence under a strategy: a list of chunk
/// jobs whose lengths tile the prompt with compiled chunk sizes.
///
/// When a calibrated [`SplitContext`] is supplied, the balanced policies
/// solve `split::choose_split` against it — the same bisection the
/// simulator and benches use — so all three agree on the split point.
/// Without one, the old closed-form 0.55 head fraction stands in.
pub fn plan_prefill(
    seq: u64,
    prompt_len: usize,
    strategy: Strategy,
    split: SplitPolicy,
    chunk_sizes: &[usize],
    ctx: Option<&SplitContext>,
) -> Vec<ChunkJob> {
    plan_prefill_pp(seq, prompt_len, strategy, split, chunk_sizes, ctx, 1)
}

/// [`plan_prefill`] with the chunk count coupled to the pipeline's
/// micro-batch depth (DESIGN.md §11): the chunk set is the unit that
/// fills pipeline bubbles, so a `pp_stages`-deep engine wants at least
/// `min_chunks` chunks in flight. When the default (largest-tile-first)
/// tiling yields fewer, the planner drops the largest compiled sizes and
/// re-tiles finer until the plan reaches `min_chunks` chunks or bottoms
/// out at the smallest compiled tile. Token totals, lane contiguity, and
/// the single `last` marker are preserved in every branch (same tiling
/// code, restricted size set).
pub fn plan_prefill_pp(
    seq: u64,
    prompt_len: usize,
    strategy: Strategy,
    split: SplitPolicy,
    chunk_sizes: &[usize],
    ctx: Option<&SplitContext>,
    min_chunks: usize,
) -> Vec<ChunkJob> {
    assert!(!chunk_sizes.is_empty());
    let mut sizes: Vec<usize> = chunk_sizes.to_vec();
    sizes.sort_unstable();
    let min_chunks = min_chunks.max(1);
    loop {
        let jobs = plan_prefill_sized(seq, prompt_len, strategy, split, &sizes, ctx);
        if jobs.len() >= min_chunks || sizes.len() == 1 {
            return jobs;
        }
        sizes.pop(); // drop the largest tile, re-tile finer
    }
}

/// One CP group's assignment under [`cp_shard_spans`]: a contiguous
/// run of chunk indices and the token span those chunks cover.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CpShardSpan {
    /// First chunk index of the group's slice (inclusive).
    pub chunk_lo: usize,
    /// One past the last chunk index of the group's slice.
    pub chunk_hi: usize,
    /// First token of the group's shard within the padded prompt.
    pub tok_lo: usize,
    /// One past the last token of the group's shard.
    pub tok_hi: usize,
}

/// Shard a chunk plan across `cp` ring context-parallel groups
/// (DESIGN.md §17): group `c` owns the contiguous chunk slice
/// `seg_range(chunks.len(), cp, c)` and therefore the token span between
/// that slice's chunk boundaries. Chunks are never split mid-chunk — the
/// shard cut always lands on a chunk boundary, so every group's slice
/// runs through the unchanged prefill machinery. The spans partition the
/// plan exactly (chunk and token ranges are gap-free and disjoint, the
/// last group ends at the padded prompt length); when `cp` exceeds the
/// chunk count the trailing groups hold empty slices and merely relay
/// the full KV prefix along the shard ring. This is the leader-
/// side mirror of the worker's slicing, so plans, workers, and the
/// `sched::cp_iteration_s` cost model agree on shard boundaries.
pub fn cp_shard_spans(chunks: &[ChunkJob], cp: usize) -> Vec<CpShardSpan> {
    let cp = cp.max(1);
    let k = chunks.len();
    let total = chunks.last().map_or(0, |c| c.offset + c.len);
    let tok = |i: usize| if i < k { chunks[i].offset } else { total };
    (0..cp)
        .map(|c| {
            let (lo, hi) = crate::collective::seg_range(k, cp, c);
            CpShardSpan { chunk_lo: lo, chunk_hi: hi, tok_lo: tok(lo), tok_hi: tok(hi) }
        })
        .collect()
}

/// The tiling body shared by [`plan_prefill`]/[`plan_prefill_pp`];
/// `sizes` must be sorted ascending.
fn plan_prefill_sized(
    seq: u64,
    prompt_len: usize,
    strategy: Strategy,
    split: SplitPolicy,
    sizes: &[usize],
    ctx: Option<&SplitContext>,
) -> Vec<ChunkJob> {
    // Prompts shorter than two tiles cannot form two lanes — the old
    // rounding would clamp into an inverted range and panic. Serial
    // single-lane fallback (one lane ⇒ nothing to overlap anyway).
    let splittable = prompt_len >= 2 * sizes[0];

    match strategy {
        Strategy::Iso if splittable => {
            // Split the sequence into two micro-batches (lanes), then tile
            // each lane with compiled chunk sizes. Lane 1 may only start a
            // given layer after lane 0 — enforced by the coordinator; here
            // we fix lane membership and offsets.
            let t0 = match split {
                SplitPolicy::Even => prompt_len / 2,
                SplitPolicy::Ratio(r) => {
                    ((prompt_len as f64 * r).round() as usize).clamp(1, prompt_len - 1)
                }
                SplitPolicy::AttnBalanced | SplitPolicy::AdaptiveAttnMlp => match ctx {
                    Some(c) => {
                        crate::split::choose_split(split, &c.node, &c.model, prompt_len).t0
                    }
                    None => (prompt_len as f64 * 0.55).round() as usize,
                },
            };
            let t0 = round_to_tiles(t0.clamp(1, prompt_len - 1), sizes, prompt_len);
            let mut jobs = tile(seq, 0, t0, 0, sizes);
            jobs.extend(tile(seq, t0, prompt_len - t0, 1, sizes));
            if let Some(j) = jobs.last_mut() {
                j.last = true;
            }
            jobs
        }
        _ => {
            let mut jobs = tile(seq, 0, prompt_len, 0, sizes);
            if let Some(j) = jobs.last_mut() {
                j.last = true;
            }
            jobs
        }
    }
}

/// Tile `len` tokens starting at `offset` with the largest chunks first.
fn tile(seq: u64, offset: usize, len: usize, lane: usize, sizes: &[usize]) -> Vec<ChunkJob> {
    let mut out = Vec::new();
    let mut pos = 0;
    while pos < len {
        let remaining = len - pos;
        // Largest compiled size that fits; fall back to the smallest size
        // (callers pad prompts to a multiple of the smallest size).
        let size = sizes
            .iter()
            .rev()
            .find(|&&s| s <= remaining)
            .copied()
            .unwrap_or_else(|| panic!("remaining {remaining} below smallest chunk {sizes:?}"));
        out.push(ChunkJob { seq, offset: offset + pos, len: size, lane, last: false });
        pos += size;
    }
    out
}

/// Round `t0` to something exactly tileable, keeping it in (0, total).
fn round_to_tiles(t0: usize, sizes: &[usize], total: usize) -> usize {
    let g = sizes[0]; // smallest compiled chunk
    let rounded = ((t0 + g / 2) / g * g).clamp(g, total - g);
    rounded
}

/// One decode-lane entry of a mixed iteration: feed `token` (the
/// sequence's latest emission) to the slot's KV state at absolute
/// position `offset`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DecodeSlot {
    /// Engine slot whose KV caches the step advances.
    pub slot: usize,
    /// The sequence's latest emitted token.
    pub token: i32,
    /// Absolute position `token` will occupy.
    pub offset: usize,
}

/// One verify window of the speculative decode lane (DESIGN.md §10): the
/// sequence's last emitted token followed by draft tokens, run as
/// `tokens.len()` rows at consecutive KV offsets starting at `offset`.
/// Row `j`'s greedy argmax is the model's next token after consuming
/// `tokens[..=j]`; [`accept_count`] turns the row argmaxes into the
/// accepted prefix.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SpecSlot {
    /// Engine slot whose KV caches the window advances.
    pub slot: usize,
    /// Window inputs: the last emitted token, then the proposer's drafts.
    pub tokens: Vec<i32>,
    /// Absolute position of `tokens[0]`.
    pub offset: usize,
}

impl SpecSlot {
    /// Rows the window contributes to the verify micro-batch.
    pub fn width(&self) -> usize {
        self.tokens.len()
    }

    /// The draft tokens under verification (everything after the first).
    pub fn drafts(&self) -> &[i32] {
        &self.tokens[1..]
    }
}

/// Proposes draft tokens for speculative decoding (DESIGN.md §10).
///
/// Implementations must be cheap relative to a model step — the point is
/// to trade a little wasted verify compute for wider, better-overlapping
/// decode batches. Drafts never change emitted tokens (greedy
/// verification discards bad ones); they only change how many tokens each
/// verify step advances.
pub trait DraftProposer: Send {
    /// Up to `k` candidate next tokens given the sequence's token history
    /// (prompt followed by emissions, oldest first). May return fewer.
    fn propose(&mut self, history: &[i32], k: usize) -> Vec<i32>;
}

/// Self-drafting n-gram proposer: find the most recent earlier occurrence
/// of the history's final `n`-gram and propose the tokens that followed
/// it (prompt-lookup decoding). Falls back to repeating the last token,
/// so every proposed token is drawn from the history and is therefore a
/// valid vocab id.
#[derive(Clone, Debug)]
pub struct NGramProposer {
    /// N-gram order to match (≥ 1).
    pub n: usize,
}

impl NGramProposer {
    /// A proposer matching on the trailing `n`-gram.
    pub fn new(n: usize) -> Self {
        assert!(n >= 1, "n-gram order must be >= 1");
        NGramProposer { n }
    }
}

impl DraftProposer for NGramProposer {
    fn propose(&mut self, history: &[i32], k: usize) -> Vec<i32> {
        if history.is_empty() || k == 0 {
            return Vec::new();
        }
        let n = self.n.min(history.len());
        let pat = &history[history.len() - n..];
        let mut out = Vec::with_capacity(k);
        // Most recent earlier occurrence of the trailing n-gram.
        for start in (0..history.len() - n).rev() {
            if &history[start..start + n] == pat {
                let mut j = start + n;
                while out.len() < k && j < history.len() {
                    out.push(history[j]);
                    j += 1;
                }
                break;
            }
        }
        let last = *history.last().unwrap();
        while out.len() < k {
            out.push(last);
        }
        out
    }
}

/// Greedy speculative acceptance: `rows[j]` is the model's greedy token
/// after consuming the window's `tokens[..=j]`, `drafts` are
/// `tokens[1..]` (`rows.len() == drafts.len() + 1`). Returns the length
/// `a` of the longest prefix with `drafts[j] == rows[j]`; the window then
/// emits `rows[..=a]` — exactly the tokens the non-speculative greedy
/// chain would have produced one step at a time.
pub fn accept_count(drafts: &[i32], rows: &[i32]) -> usize {
    assert_eq!(rows.len(), drafts.len() + 1, "one row per window token");
    let mut a = 0;
    while a < drafts.len() && drafts[a] == rows[a] {
        a += 1;
    }
    a
}

/// The prefill half of a [`StepPlan`].
#[derive(Clone, Debug)]
pub struct PrefillPlan {
    /// Engine slot being prefilled.
    pub slot: usize,
    /// Padded prompt length of the whole sequence.
    pub prompt_len: usize,
    /// The ISO chunk set this iteration executes. Without a prefill
    /// budget it tiles the padded prompt exactly; under `tbt_budget_ms`
    /// bounding it is a contiguous resumable slice of that tiling
    /// (DESIGN.md §15), and the rest streams in later iterations.
    pub chunks: Vec<ChunkJob>,
}

/// One engine iteration under the mixed scheduler: at most one
/// head-of-line prefill's ISO chunk set plus a fused decode micro-batch —
/// either one-token [`DecodeSlot`] rows or speculative [`SpecSlot`]
/// verify windows, never both.
#[derive(Clone, Debug, Default)]
pub struct StepPlan {
    /// Head-of-line prefill, if any sequence still needs one.
    pub prefill: Option<PrefillPlan>,
    /// One-token decode lane (`spec_k = 0`).
    pub decode: Vec<DecodeSlot>,
    /// Speculative verify lane (`spec_k > 0`); mutually exclusive with
    /// `decode`.
    pub spec: Vec<SpecSlot>,
}

impl StepPlan {
    /// True when the iteration carries no work at all.
    pub fn is_empty(&self) -> bool {
        self.prefill.is_none() && self.decode.is_empty() && self.spec.is_empty()
    }

    /// Tokens this iteration advances (prefill chunk tokens + decode
    /// lane rows + verify window rows). Counts the chunks actually
    /// scheduled, so a budget-bounded partial prefill is priced at its
    /// slice, not the whole prompt.
    pub fn tokens(&self) -> usize {
        self.prefill.as_ref().map_or(0, |p| p.chunks.iter().map(|c| c.len).sum())
            + self.decode.len()
            + self.spec.iter().map(SpecSlot::width).sum::<usize>()
    }
}

/// Scheduler-visible state of one live sequence, as the leader loop
/// tracks it between iterations.
#[derive(Clone, Debug)]
pub struct LaneSeq {
    /// Engine slot the sequence occupies.
    pub slot: usize,
    /// Padded prompt length (tiles exactly into compiled chunk sizes).
    pub prompt_len: usize,
    /// Whether the prefill has completed.
    pub prefilled: bool,
    /// Prompt tokens already written into the worker KV by earlier
    /// bounded-prefill iterations (DESIGN.md §15); equals `prompt_len`
    /// once `prefilled`. Always 0 when `tbt_budget_ms` is off.
    pub prefill_done: usize,
    /// Latest emitted token (valid once `prefilled`).
    pub last_token: i32,
    /// Absolute position `last_token` will occupy — the next decode
    /// attention offset.
    pub offset: usize,
    /// Decode steps still owed; 0 retires the sequence from the lane.
    pub decode_left: usize,
}

impl LaneSeq {
    /// Eligible for the decode lane this iteration.
    pub fn decoding(&self, max_seq: usize) -> bool {
        self.prefilled && self.decode_left > 0 && self.offset < max_seq
    }
}

/// Iteration-level mixed-batch composer (DESIGN.md §9). Each `plan` call
/// emits one [`StepPlan`]: the first un-prefilled sequence's chunk set
/// (one prefill per iteration keeps TTFT bounded while the lane streams)
/// plus up to `decode_batch` decode rows, selected round-robin so a lane
/// wider than the cap shares iterations fairly.
///
/// # Examples
///
/// ```
/// use iso::batch::{LaneSeq, MixedPlanner};
/// use iso::config::{SplitPolicy, Strategy};
///
/// let mut planner = MixedPlanner::new(
///     Strategy::Iso,
///     SplitPolicy::Even,
///     vec![16, 32, 64], // compiled chunk sizes
///     4,                // decode lane cap
///     256,              // max_seq
/// );
/// let live = vec![
///     // Slot 0 still needs its prefill; slot 1 is decoding.
///     LaneSeq { slot: 0, prompt_len: 64, prefilled: false, prefill_done: 0, last_token: 0, offset: 0, decode_left: 4 },
///     LaneSeq { slot: 1, prompt_len: 64, prefilled: true, prefill_done: 64, last_token: 7, offset: 64, decode_left: 4 },
/// ];
/// let plan = planner.plan(&live, None);
/// let prefill = plan.prefill.expect("head-of-line prefill");
/// assert_eq!(prefill.slot, 0);
/// assert_eq!(prefill.chunks.iter().map(|c| c.len).sum::<usize>(), 64);
/// assert_eq!(plan.decode.len(), 1); // slot 1 rides the fused lane
/// assert_eq!(plan.decode[0].slot, 1);
/// ```
#[derive(Clone, Debug)]
pub struct MixedPlanner {
    /// Overlap strategy the prefill chunk sets follow.
    pub strategy: Strategy,
    /// Split policy for the ISO two-lane prefill plan.
    pub split: SplitPolicy,
    /// Compiled prefill chunk sizes.
    pub chunk_sizes: Vec<usize>,
    /// Width cap of the fused decode lane.
    pub decode_batch: usize,
    /// KV capacity per sequence; lanes retire at this offset.
    pub max_seq: usize,
    /// Minimum prefill chunks per plan (pipeline micro-batch depth,
    /// DESIGN.md §11); 1 = the single-stage default.
    pub min_chunks: usize,
    /// Per-iteration prefill token cap derived from `tbt_budget_ms`
    /// (DESIGN.md §15). 0 = unbounded: whole prompts prefill in one
    /// iteration, exactly the pre-overload behavior. Non-zero plans
    /// carry a resumable slice of the chunk set, always at least one
    /// chunk (anti-starvation: prefill never stalls outright).
    pub prefill_token_budget: usize,
    cursor: usize,
}

impl MixedPlanner {
    /// A planner over the given strategy, split policy and compiled sizes.
    pub fn new(
        strategy: Strategy,
        split: SplitPolicy,
        chunk_sizes: Vec<usize>,
        decode_batch: usize,
        max_seq: usize,
    ) -> Self {
        assert!(decode_batch >= 1, "decode_batch must be >= 1");
        assert!(!chunk_sizes.is_empty());
        MixedPlanner {
            strategy,
            split,
            chunk_sizes,
            decode_batch,
            max_seq,
            min_chunks: 1,
            prefill_token_budget: 0,
            cursor: 0,
        }
    }

    /// Couple the chunk count to the pipeline's micro-batch depth
    /// (builder style): prefill plans will carry at least `min_chunks`
    /// chunks when the prompt allows, so a `pp_stages`-deep engine keeps
    /// every stage fed (DESIGN.md §11).
    pub fn with_min_chunks(mut self, min_chunks: usize) -> Self {
        self.min_chunks = min_chunks.max(1);
        self
    }

    /// Cap prefill work per iteration at `tokens` (builder style); the
    /// engine derives the cap from `tbt_budget_ms` via the cost model
    /// (`sched::budgeted_prefill_tokens`). 0 = unbounded.
    pub fn with_prefill_budget(mut self, tokens: usize) -> Self {
        self.prefill_token_budget = tokens;
        self
    }

    /// Compose the next iteration from the live set.
    pub fn plan(&mut self, live: &[LaneSeq], ctx: Option<&SplitContext>) -> StepPlan {
        self.plan_spec(live, ctx, 0, &mut |_, _| Vec::new())
    }

    /// Like [`MixedPlanner::plan`], but with speculative decoding: each
    /// chosen lane sequence becomes a [`SpecSlot`] verify window of its
    /// last emitted token plus up to `spec_k` drafts from `drafts(slot,
    /// k_eff)`. `k_eff` is clamped so the window fits the KV capacity
    /// (`offset + k_eff < max_seq`) and never verifies past the
    /// sequence's decode budget (a window emits at most `k_eff + 1`
    /// tokens). `spec_k = 0` degrades to the plain one-token lane.
    pub fn plan_spec(
        &mut self,
        live: &[LaneSeq],
        ctx: Option<&SplitContext>,
        spec_k: usize,
        drafts: &mut dyn FnMut(usize, usize) -> Vec<i32>,
    ) -> StepPlan {
        let prefill = live.iter().find(|s| !s.prefilled).map(|s| {
            let chunks = plan_prefill_pp(
                s.slot as u64,
                s.prompt_len,
                self.strategy,
                self.split,
                &self.chunk_sizes,
                ctx,
                self.min_chunks,
            );
            PrefillPlan {
                slot: s.slot,
                prompt_len: s.prompt_len,
                chunks: self.budget_slice(chunks, s.prefill_done),
            }
        });
        let eligible: Vec<&LaneSeq> =
            live.iter().filter(|s| s.decoding(self.max_seq)).collect();
        let width = eligible.len().min(self.decode_batch);
        let mut chosen = Vec::with_capacity(width);
        if width > 0 {
            let start = self.cursor % eligible.len();
            for j in 0..width {
                chosen.push(eligible[(start + j) % eligible.len()]);
            }
            self.cursor = self.cursor.wrapping_add(width);
        }
        let mut plan = StepPlan { prefill, ..Default::default() };
        if spec_k == 0 {
            plan.decode = chosen
                .iter()
                .map(|s| DecodeSlot { slot: s.slot, token: s.last_token, offset: s.offset })
                .collect();
        } else {
            plan.spec = chosen
                .iter()
                .map(|s| {
                    // `decoding()` guarantees offset < max_seq, so both
                    // clamps are in range.
                    let k_eff = spec_k
                        .min(self.max_seq - 1 - s.offset)
                        .min(s.decode_left.saturating_sub(1));
                    let mut tokens = Vec::with_capacity(k_eff + 1);
                    tokens.push(s.last_token);
                    let mut d = drafts(s.slot, k_eff);
                    d.truncate(k_eff);
                    tokens.extend(d);
                    SpecSlot { slot: s.slot, tokens, offset: s.offset }
                })
                .collect();
        }
        plan
    }

    /// Bounded chunked prefill (DESIGN.md §15): drop the chunks already
    /// executed by earlier iterations (`offset + len <= done`; chunks
    /// are taken whole, so `done` always lands on a chunk boundary) and
    /// keep whole chunks while the slice fits `prefill_token_budget` —
    /// always at least one, so prefill never starves. The slice's final
    /// chunk is re-marked `last` so the worker computes a logits row for
    /// the iteration; the coordinator treats that row as the first
    /// emission only when the slice completes the prompt.
    fn budget_slice(&self, chunks: Vec<ChunkJob>, done: usize) -> Vec<ChunkJob> {
        if self.prefill_token_budget == 0 && done == 0 {
            return chunks; // bounding off: byte-identical plans
        }
        let mut out: Vec<ChunkJob> = Vec::new();
        let mut taken = 0usize;
        for mut c in chunks {
            if c.offset + c.len <= done {
                continue; // prefilled by an earlier iteration
            }
            if self.prefill_token_budget > 0
                && !out.is_empty()
                && taken + c.len > self.prefill_token_budget
            {
                break;
            }
            taken += c.len;
            c.last = false;
            out.push(c);
        }
        if let Some(c) = out.last_mut() {
            c.last = true;
        }
        out
    }
}

/// Priority class of a request (DESIGN.md §15). Classes drain strictly
/// in order: no batch request is admitted while an interactive one
/// waits, and best-effort traffic is the first shed under pressure.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Priority {
    /// Latency-sensitive traffic (chat turns); admitted first.
    Interactive,
    /// The default class: throughput-oriented traffic.
    Batch,
    /// Background traffic; admitted last, shed first.
    BestEffort,
}

impl Priority {
    /// All classes, highest priority first (queue drain order).
    pub const ALL: [Priority; 3] = [Priority::Interactive, Priority::Batch, Priority::BestEffort];
}

/// One queued request with its admission metadata.
#[derive(Clone, Debug)]
struct Queued {
    req: Request,
    tenant: u64,
}

/// SLO admission gate (DESIGN.md §15): a priority-classed queue with a
/// live-sequence cap, an optional queue bound (backpressure — submits
/// beyond it are rejected with [`EngineError::Overloaded`] instead of
/// queueing without limit), per-tenant token-rate fairness inside each
/// class, and optional deadline-based shedding of requests that can no
/// longer meet their TTFT target.
///
/// With `bound = 0` and `ttft_deadline_s = 0.0` (the defaults) it
/// behaves exactly like the old unbounded FIFO queue.
///
/// [`EngineError::Overloaded`]: crate::fault::EngineError::Overloaded
#[derive(Debug)]
pub struct Admission {
    /// One FIFO queue per priority class, drained in class order.
    queues: [VecDeque<Queued>; 3],
    /// Live-sequence cap.
    pub max_live: usize,
    /// Sequences currently admitted and not yet completed.
    pub live: usize,
    /// Queue bound across all classes; 0 = unbounded.
    pub bound: usize,
    /// TTFT deadline (seconds); queued requests that have waited longer
    /// are shed by [`Admission::shed_stale`]. 0.0 = shedding off.
    pub ttft_deadline_s: f64,
    /// Submits rejected for backpressure since construction.
    pub rejected: u64,
    /// Requests shed for a blown TTFT deadline since construction.
    pub shed: u64,
    /// Prompt tokens admitted per tenant — the fairness ledger.
    tenant_tokens: std::collections::BTreeMap<u64, u64>,
}

impl Admission {
    /// An empty gate admitting at most `max_live` concurrent sequences,
    /// with an unbounded queue and shedding off.
    pub fn new(max_live: usize) -> Self {
        Admission {
            queues: [VecDeque::new(), VecDeque::new(), VecDeque::new()],
            max_live,
            live: 0,
            bound: 0,
            ttft_deadline_s: 0.0,
            rejected: 0,
            shed: 0,
            tenant_tokens: std::collections::BTreeMap::new(),
        }
    }

    /// Bound the total queue depth (builder style); 0 = unbounded.
    pub fn with_bound(mut self, bound: usize) -> Self {
        self.bound = bound;
        self
    }

    /// Shed queued requests older than `deadline_s` (builder style);
    /// 0.0 = shedding off.
    pub fn with_ttft_deadline_s(mut self, deadline_s: f64) -> Self {
        self.ttft_deadline_s = deadline_s;
        self
    }

    /// Enqueue a request in the default [`Priority::Batch`] class under
    /// tenant 0. Fails with [`EngineError::Overloaded`] when the queue
    /// bound is hit.
    ///
    /// [`EngineError::Overloaded`]: crate::fault::EngineError::Overloaded
    pub fn submit(&mut self, r: Request) -> Result<(), crate::fault::EngineError> {
        self.submit_classed(r, Priority::Batch, 0)
    }

    /// Enqueue a request under an explicit priority class and tenant id.
    /// Rejects with [`EngineError::Overloaded`] — backpressure, not
    /// failure — when `bound > 0` and the queue is already full.
    ///
    /// [`EngineError::Overloaded`]: crate::fault::EngineError::Overloaded
    pub fn submit_classed(
        &mut self,
        r: Request,
        prio: Priority,
        tenant: u64,
    ) -> Result<(), crate::fault::EngineError> {
        let queued = self.pending();
        if self.bound > 0 && queued >= self.bound {
            self.rejected += 1;
            return Err(crate::fault::EngineError::Overloaded { queued, bound: self.bound });
        }
        self.queues[prio as usize].push_back(Queued { req: r, tenant });
        Ok(())
    }

    /// Requests queued but not yet admitted, across all classes.
    pub fn pending(&self) -> usize {
        self.queues.iter().map(VecDeque::len).sum()
    }

    /// Requests queued but not yet admitted — the saturation signal
    /// (alias of [`Admission::pending`], named for the dashboard
    /// counter). The serving loop records the same arrived-but-unadmitted
    /// count into `metrics.queue_depth` every iteration.
    pub fn queue_depth(&self) -> usize {
        self.pending()
    }

    /// How long (seconds) the *oldest* queued request has been waiting at
    /// engine clock `now_s`, or `None` when the queue is empty. Grows
    /// without bound when the live cap is saturated — the head-of-line
    /// companion to [`Admission::queue_depth`].
    pub fn oldest_wait_s(&self, now_s: f64) -> Option<f64> {
        self.queues
            .iter()
            .filter_map(|q| q.front().map(|e| (now_s - e.req.arrival_s).max(0.0)))
            .fold(None, |acc: Option<f64>, w| Some(acc.map_or(w, |a| a.max(w))))
    }

    /// Shed every queued request that has already waited past the TTFT
    /// deadline at engine clock `now_s` — serving it would blow its SLO
    /// anyway, and shedding it early frees queue space for requests that
    /// can still make theirs. Returns the shed requests (best-effort
    /// classes shed like any other; a request already admitted is never
    /// shed). No-op when shedding is off.
    pub fn shed_stale(&mut self, now_s: f64) -> Vec<Request> {
        if self.ttft_deadline_s <= 0.0 {
            return Vec::new();
        }
        let mut out = Vec::new();
        for q in &mut self.queues {
            let mut keep = VecDeque::with_capacity(q.len());
            for e in q.drain(..) {
                if now_s - e.req.arrival_s > self.ttft_deadline_s {
                    out.push(e.req);
                } else {
                    keep.push_back(e);
                }
            }
            *q = keep;
        }
        self.shed += out.len() as u64;
        out
    }

    /// Admit as many requests as capacity allows: classes drain in
    /// priority order; within a class the request whose tenant has been
    /// admitted the fewest prompt tokens goes first (FIFO among equals),
    /// so one chatty tenant cannot starve the rest of its class.
    pub fn admit(&mut self) -> Vec<Request> {
        let mut out = Vec::new();
        while self.live < self.max_live {
            let Some(qi) = (0..self.queues.len()).find(|&i| !self.queues[i].is_empty())
            else {
                break;
            };
            let ledger = &self.tenant_tokens;
            let pick = self.queues[qi]
                .iter()
                .enumerate()
                .min_by_key(|(i, e)| (ledger.get(&e.tenant).copied().unwrap_or(0), *i))
                .map(|(i, _)| i)
                .expect("non-empty queue");
            let e = self.queues[qi].remove(pick).expect("picked index in range");
            *self.tenant_tokens.entry(e.tenant).or_insert(0) += e.req.prompt.len() as u64;
            self.live += 1;
            out.push(e.req);
        }
        out
    }

    /// Mark one live sequence as finished, freeing admission capacity.
    pub fn complete(&mut self) {
        assert!(self.live > 0, "complete() without a live sequence");
        self.live -= 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prop;

    const SIZES: &[usize] = &[16, 32, 64];

    #[test]
    fn serial_plan_tiles_whole_prompt() {
        let jobs = plan_prefill(1, 96, Strategy::Serial, SplitPolicy::Even, SIZES, None);
        let total: usize = jobs.iter().map(|j| j.len).sum();
        assert_eq!(total, 96);
        assert_eq!(jobs[0].offset, 0);
        assert!(jobs.last().unwrap().last);
        assert!(jobs.iter().all(|j| j.lane == 0));
        // offsets are contiguous
        let mut pos = 0;
        for j in &jobs {
            assert_eq!(j.offset, pos);
            pos += j.len;
        }
    }

    #[test]
    fn iso_plan_has_two_lanes_contiguous() {
        let jobs = plan_prefill(1, 128, Strategy::Iso, SplitPolicy::Even, SIZES, None);
        let lane0: usize = jobs.iter().filter(|j| j.lane == 0).map(|j| j.len).sum();
        let lane1: usize = jobs.iter().filter(|j| j.lane == 1).map(|j| j.len).sum();
        assert_eq!(lane0 + lane1, 128);
        assert_eq!(lane0, 64);
        // lane 1 starts exactly where lane 0 ends
        let first1 = jobs.iter().find(|j| j.lane == 1).unwrap();
        assert_eq!(first1.offset, lane0);
    }

    #[test]
    fn iso_balanced_gives_head_more_tokens() {
        let jobs = plan_prefill(1, 128, Strategy::Iso, SplitPolicy::AttnBalanced, SIZES, None);
        let lane0: usize = jobs.iter().filter(|j| j.lane == 0).map(|j| j.len).sum();
        assert!(lane0 > 48 && lane0 < 128, "lane0 = {lane0}");
    }

    #[test]
    fn ratio_split_respects_tiles() {
        let jobs = plan_prefill(1, 128, Strategy::Iso, SplitPolicy::Ratio(0.6), SIZES, None);
        let lane0: usize = jobs.iter().filter(|j| j.lane == 0).map(|j| j.len).sum();
        assert_eq!(lane0 % 16, 0);
        assert!(lane0 >= 16 && lane0 <= 112);
    }

    #[test]
    fn prop_plan_tiles_exactly_with_compiled_sizes() {
        Prop::new(57).cases(200).run("prefill plan tiles prompt", |rng| {
            let len = rng.range(2, 40) * 16; // padded prompts
            let strat = if rng.f64() < 0.5 { Strategy::Iso } else { Strategy::Serial };
            let jobs = plan_prefill(7, len, strat, SplitPolicy::Even, SIZES, None);
            let total: usize = jobs.iter().map(|j| j.len).sum();
            if total != len {
                return Err(format!("tiled {total} != {len}"));
            }
            for j in &jobs {
                if !SIZES.contains(&j.len) {
                    return Err(format!("chunk size {} not compiled", j.len));
                }
            }
            // offsets contiguous within each lane, lane1 after lane0
            let mut pos = 0;
            for j in jobs.iter().filter(|j| j.lane == 0) {
                if j.offset != pos {
                    return Err(format!("lane0 gap at {pos}"));
                }
                pos += j.len;
            }
            for j in jobs.iter().filter(|j| j.lane == 1) {
                if j.offset != pos {
                    return Err(format!("lane1 gap at {pos}"));
                }
                pos += j.len;
            }
            // exactly one `last`
            if jobs.iter().filter(|j| j.last).count() != 1 {
                return Err("need exactly one last chunk".into());
            }
            Ok(())
        });
    }

    #[test]
    fn seq_state_lifecycle() {
        let r = Request { id: 1, arrival_s: 0.0, prompt: vec![0; 32], decode_steps: 2 };
        let mut s = SeqState::new(&r);
        assert_eq!(s.prefill_remaining(), 32);
        assert!(!s.in_decode() && !s.finished());
        s.done = 32;
        assert!(s.in_decode());
        s.decoded = 2;
        assert!(s.finished());
    }

    #[test]
    fn admission_respects_cap() {
        let mut a = Admission::new(2);
        for i in 0..5 {
            a.submit(Request { id: i, arrival_s: 0.0, prompt: vec![0; 4], decode_steps: 0 })
                .unwrap();
        }
        assert_eq!(a.admit().len(), 2);
        assert_eq!(a.pending(), 3);
        assert!(a.admit().is_empty());
        a.complete();
        assert_eq!(a.admit().len(), 1);
    }

    #[test]
    #[should_panic]
    fn complete_without_live_panics() {
        Admission::new(1).complete();
    }

    fn req(id: u64, len: usize) -> Request {
        Request { id, arrival_s: 0.0, prompt: vec![0; len], decode_steps: 0 }
    }

    #[test]
    fn admission_bound_rejects_with_overloaded() {
        use crate::fault::EngineError;
        let mut a = Admission::new(1).with_bound(2);
        a.submit(req(0, 4)).unwrap();
        a.submit(req(1, 4)).unwrap();
        let err = a.submit(req(2, 4)).unwrap_err();
        assert_eq!(err, EngineError::Overloaded { queued: 2, bound: 2 });
        assert_eq!(a.rejected, 1);
        assert_eq!(a.pending(), 2, "rejected request never entered the queue");
        // Draining the queue reopens admission.
        assert_eq!(a.admit().len(), 1);
        a.submit(req(3, 4)).unwrap();
    }

    #[test]
    fn admission_drains_classes_in_priority_order() {
        let mut a = Admission::new(3);
        a.submit_classed(req(0, 4), Priority::BestEffort, 0).unwrap();
        a.submit_classed(req(1, 4), Priority::Batch, 0).unwrap();
        a.submit_classed(req(2, 4), Priority::Interactive, 0).unwrap();
        a.submit_classed(req(3, 4), Priority::Interactive, 0).unwrap();
        let ids: Vec<u64> = a.admit().iter().map(|r| r.id).collect();
        // Interactive first (FIFO within class), then batch; best-effort
        // is still queued when the cap bites.
        assert_eq!(ids, vec![2, 3, 1]);
        assert_eq!(a.pending(), 1);
    }

    #[test]
    fn admission_balances_tenant_tokens_within_class() {
        let mut a = Admission::new(1);
        // Tenant 7 floods the queue with big prompts; tenant 8 trickles
        // small ones in behind it.
        for i in 0..3 {
            a.submit_classed(req(i, 64), Priority::Batch, 7).unwrap();
        }
        a.submit_classed(req(10, 8), Priority::Batch, 8).unwrap();
        a.submit_classed(req(11, 8), Priority::Batch, 8).unwrap();
        let mut order = Vec::new();
        for _ in 0..5 {
            let got = a.admit();
            assert_eq!(got.len(), 1);
            order.push(got[0].id);
            a.complete();
        }
        // The ledger alternates tenants instead of serving 7's backlog
        // first: 7 (ties broken FIFO), then 8 twice (8 tokens < 64),
        // then the rest of 7.
        assert_eq!(order, vec![0, 10, 11, 1, 2]);
    }

    #[test]
    fn admission_sheds_stale_requests() {
        let mut a = Admission::new(1).with_ttft_deadline_s(2.0);
        a.submit(Request { id: 0, arrival_s: 0.0, prompt: vec![0; 4], decode_steps: 0 })
            .unwrap();
        a.submit(Request { id: 1, arrival_s: 3.5, prompt: vec![0; 4], decode_steps: 0 })
            .unwrap();
        assert!(a.shed_stale(1.0).is_empty(), "nothing stale yet");
        let shed = a.shed_stale(4.0); // id 0 has waited 4s > 2s deadline
        assert_eq!(shed.len(), 1);
        assert_eq!(shed[0].id, 0);
        assert_eq!(a.shed, 1);
        assert_eq!(a.pending(), 1);
        // Shedding off (deadline 0) is a no-op regardless of age.
        let mut b = Admission::new(1);
        b.submit(req(0, 4)).unwrap();
        assert!(b.shed_stale(1e9).is_empty());
    }

    #[test]
    fn budget_slices_resume_and_cover_prompt_exactly() {
        // Bounded chunked prefill: iterating plan() with prefill_done
        // advanced by each slice walks the whole prompt — whole chunks,
        // contiguous, exactly one `last` per slice, final slice ends at
        // prompt_len.
        for budget in [16usize, 32, 48, 64, 100] {
            let mut p =
                MixedPlanner::new(Strategy::Iso, SplitPolicy::Even, SIZES.to_vec(), 8, 512)
                    .with_prefill_budget(budget);
            let mut seq = lane_seq_unprefilled(0, 192);
            let mut iterations = 0;
            while seq.prefill_done < 192 {
                iterations += 1;
                assert!(iterations <= 192 / 16 + 1, "budget slicing must terminate");
                let plan = p.plan(std::slice::from_ref(&seq), None);
                let pf = plan.prefill.expect("prefill until done");
                assert!(!pf.chunks.is_empty(), "anti-starvation: at least one chunk");
                assert_eq!(pf.chunks.iter().filter(|c| c.last).count(), 1);
                assert!(pf.chunks.last().unwrap().last, "last marks the slice tail");
                let tokens: usize = pf.chunks.iter().map(|c| c.len).sum();
                assert_eq!(plan.tokens(), tokens, "tokens() prices the slice");
                // Over budget only when a single chunk alone exceeds it.
                assert!(tokens <= budget.max(pf.chunks[0].len));
                // The slice resumes exactly where the last one stopped.
                assert_eq!(pf.chunks[0].offset, seq.prefill_done);
                let mut pos = seq.prefill_done;
                for c in &pf.chunks {
                    assert_eq!(c.offset, pos, "slice must stay contiguous");
                    pos += c.len;
                }
                seq.prefill_done = pos;
            }
            assert_eq!(seq.prefill_done, 192, "slices cover the prompt exactly");
        }
    }

    #[test]
    fn zero_budget_plans_are_identical() {
        // Budget off ⇒ plans byte-identical to a budget-less planner.
        let mut plain =
            MixedPlanner::new(Strategy::Iso, SplitPolicy::Even, SIZES.to_vec(), 4, 256);
        let mut budgeted = plain.clone().with_prefill_budget(0);
        let live = vec![lane_seq_unprefilled(0, 128), lane_seq(1, true, 70, 3)];
        for _ in 0..4 {
            let a = plain.plan(&live, None);
            let b = budgeted.plan(&live, None);
            assert_eq!(a.prefill.as_ref().unwrap().chunks, b.prefill.as_ref().unwrap().chunks);
            assert_eq!(a.decode, b.decode);
        }
    }

    #[test]
    fn admission_exposes_depth_and_oldest_wait() {
        // Satellite: saturation is observable — depth counts the queue,
        // oldest-wait tracks the head-of-line request's age.
        let mut a = Admission::new(1);
        assert_eq!(a.queue_depth(), 0);
        assert_eq!(a.oldest_wait_s(5.0), None);
        for (i, arr) in [(0u64, 1.0f64), (1, 2.0), (2, 3.0)] {
            a.submit(Request { id: i, arrival_s: arr, prompt: vec![0; 4], decode_steps: 0 })
                .unwrap();
        }
        assert_eq!(a.queue_depth(), 3);
        assert_eq!(a.oldest_wait_s(4.0), Some(3.0)); // head arrived at t=1
        assert_eq!(a.admit().len(), 1); // cap 1
        assert_eq!(a.queue_depth(), 2);
        assert_eq!(a.oldest_wait_s(4.0), Some(2.0)); // head is now t=2
        // Clock before arrival clamps to zero rather than going negative.
        assert_eq!(a.oldest_wait_s(0.0), Some(0.0));
    }

    #[test]
    fn ngram_proposer_copies_continuation() {
        let mut p = NGramProposer::new(2);
        // history ends in [3, 4]; its earlier occurrence is followed by 5, 6.
        let h = vec![1, 2, 3, 4, 5, 6, 9, 3, 4];
        assert_eq!(p.propose(&h, 2), vec![5, 6]);
        // Asking for more than the continuation pads with the last token.
        assert_eq!(p.propose(&h, 4), vec![5, 6, 4, 4]);
        // No earlier occurrence: repeat the last token.
        let h2 = vec![7, 8];
        assert_eq!(p.propose(&h2, 3), vec![8, 8, 8]);
        // Degenerate inputs.
        assert_eq!(p.propose(&[], 3), Vec::<i32>::new());
        assert_eq!(p.propose(&h, 0), Vec::<i32>::new());
    }

    #[test]
    fn ngram_proposer_only_emits_history_tokens() {
        use crate::util::Rng;
        let mut rng = Rng::new(3);
        let mut p = NGramProposer::new(3);
        for _ in 0..100 {
            let h: Vec<i32> =
                (0..rng.range(1, 60)).map(|_| rng.range(0, 8) as i32).collect();
            let k = rng.range(0, 9);
            let d = p.propose(&h, k);
            assert_eq!(d.len(), k);
            assert!(d.iter().all(|t| h.contains(t)), "draft outside history");
        }
    }

    #[test]
    fn accept_count_longest_matching_prefix() {
        assert_eq!(accept_count(&[], &[9]), 0); // no drafts: emit 1 token
        assert_eq!(accept_count(&[5], &[5, 7]), 1);
        assert_eq!(accept_count(&[5], &[6, 7]), 0);
        assert_eq!(accept_count(&[5, 6, 8], &[5, 6, 7, 1]), 2); // stops at first miss
        assert_eq!(accept_count(&[5, 6, 7], &[5, 6, 7, 1]), 3); // all accepted
        // A later match after a miss must NOT count.
        assert_eq!(accept_count(&[1, 2], &[9, 2, 3]), 0);
    }

    #[test]
    fn plan_spec_builds_clamped_windows() {
        let mut p = MixedPlanner::new(Strategy::Iso, SplitPolicy::Even, SIZES.to_vec(), 8, 128);
        let live = vec![
            lane_seq(0, true, 64, 10),  // room for a full window
            lane_seq(1, true, 125, 10), // KV clamp: only 2 drafts fit
            lane_seq(2, true, 64, 2),   // budget clamp: only 1 draft useful
            lane_seq(3, false, 0, 5),   // prefilling: not in the lane
        ];
        let plan = p.plan_spec(&live, None, 4, &mut |slot, k| {
            vec![slot as i32 + 50; k + 3] // over-proposes; planner truncates
        });
        assert!(plan.prefill.is_some());
        assert!(plan.decode.is_empty(), "spec lane replaces the decode lane");
        assert_eq!(plan.spec.len(), 3);
        for w in &plan.spec {
            let s = live.iter().find(|s| s.slot == w.slot).unwrap();
            assert_eq!(w.tokens[0], s.last_token);
            assert_eq!(w.offset, s.offset);
            // Window fits the KV capacity and never outruns the budget.
            assert!(w.offset + w.width() <= 128);
            assert!(w.width() <= s.decode_left.saturating_sub(1) + 1);
            assert_eq!(w.drafts().len() + 1, w.width());
        }
        let by_slot =
            |s: usize| plan.spec.iter().find(|w| w.slot == s).unwrap().width();
        assert_eq!(by_slot(0), 5); // full k=4 window
        assert_eq!(by_slot(1), 3); // clamped by max_seq: 125 + 2 = 127
        assert_eq!(by_slot(2), 2); // clamped by decode budget
        // Token accounting covers the window rows.
        assert_eq!(plan.tokens(), 64 + 5 + 3 + 2);
    }

    #[test]
    fn plan_spec_zero_k_equals_plain_plan() {
        let live: Vec<LaneSeq> = (0..4).map(|s| lane_seq(s, true, 64, 10)).collect();
        let mut a = MixedPlanner::new(Strategy::Iso, SplitPolicy::Even, SIZES.to_vec(), 2, 256);
        let mut b = a.clone();
        for _ in 0..6 {
            let pa = a.plan(&live, None);
            let pb = b.plan_spec(&live, None, 0, &mut |_, _| vec![1, 2, 3]);
            assert_eq!(pa.decode, pb.decode, "k=0 must match the plain lane");
            assert!(pb.spec.is_empty());
        }
    }

    #[test]
    fn plan_prefill_pp_meets_micro_batch_depth() {
        // Satellite (PR 4): with pp stages the chunk set is the pipeline
        // micro-batch unit, so the planner re-tiles finer until at least
        // `min_chunks` chunks are in flight (or the smallest tile caps it).
        for strategy in [Strategy::Iso, Strategy::Serial] {
            let one =
                plan_prefill_pp(1, 128, strategy, SplitPolicy::Even, SIZES, None, 1);
            for min_chunks in [2usize, 3, 4, 6] {
                let jobs = plan_prefill_pp(
                    1,
                    128,
                    strategy,
                    SplitPolicy::Even,
                    SIZES,
                    None,
                    min_chunks,
                );
                assert!(
                    jobs.len() >= min_chunks.min(128 / 16),
                    "{strategy:?} min_chunks={min_chunks}: got {} chunks",
                    jobs.len()
                );
                assert!(jobs.len() >= one.len(), "finer tiling cannot shrink the plan");
                // All invariants of the base planner hold.
                assert_eq!(jobs.iter().map(|j| j.len).sum::<usize>(), 128);
                assert_eq!(jobs.iter().filter(|j| j.last).count(), 1);
                let mut pos = 0;
                for lane in [0usize, 1] {
                    for j in jobs.iter().filter(|j| j.lane == lane) {
                        assert_eq!(j.offset, pos, "{strategy:?} lane{lane} gap");
                        pos += j.len;
                    }
                }
            }
        }
        // Depth beyond what the smallest tile allows caps gracefully.
        let jobs =
            plan_prefill_pp(1, 32, Strategy::Iso, SplitPolicy::Even, SIZES, None, 99);
        assert_eq!(jobs.len(), 2); // 32 tokens / 16-token smallest tile
        assert_eq!(jobs.iter().map(|j| j.len).sum::<usize>(), 32);
    }

    #[test]
    fn cp_shard_spans_partition_chunks_and_tokens() {
        // Tentpole (PR 9): the leader-side shard map must tile the chunk
        // plan exactly — chunk ranges gap-free and disjoint, token spans
        // meeting at chunk boundaries, last group ending at the padded
        // prompt length — for every strategy and any cp, including
        // cp > chunk count (leading groups empty, relay-only).
        for strategy in [Strategy::Iso, Strategy::Serial] {
            for prompt_len in [16usize, 96, 128, 131] {
                let jobs = plan_prefill(1, prompt_len, strategy, SplitPolicy::Even, SIZES, None);
                let total: usize = jobs.last().map_or(0, |c| c.offset + c.len);
                for cp in [1usize, 2, 3, 4, 7, 16] {
                    let spans = cp_shard_spans(&jobs, cp);
                    assert_eq!(spans.len(), cp);
                    assert_eq!(spans[0].chunk_lo, 0);
                    assert_eq!(spans[0].tok_lo, 0);
                    assert_eq!(spans[cp - 1].chunk_hi, jobs.len());
                    assert_eq!(spans[cp - 1].tok_hi, total);
                    for w in spans.windows(2) {
                        assert_eq!(w[0].chunk_hi, w[1].chunk_lo, "{strategy:?} chunk gap");
                        assert_eq!(w[0].tok_hi, w[1].tok_lo, "{strategy:?} token gap");
                    }
                    for s in &spans {
                        // Shard cuts land on chunk boundaries: a non-empty
                        // slice starts exactly at its first chunk's offset.
                        if s.chunk_lo < s.chunk_hi {
                            assert_eq!(s.tok_lo, jobs[s.chunk_lo].offset);
                        } else {
                            assert_eq!(s.tok_lo, s.tok_hi, "empty slice must span 0 tokens");
                        }
                    }
                    // With at least one chunk per group nobody idles; when
                    // cp exceeds the chunk count the empty slices are the
                    // trailing groups (`seg_range` front-loads extras) —
                    // they still hold the full relayed prefix, so decode
                    // on the last group stays correct (DESIGN.md §17).
                    if jobs.len() >= cp {
                        for s in &spans {
                            assert!(s.chunk_lo < s.chunk_hi);
                        }
                    }
                }
            }
        }
        // Degenerate: no chunks at all.
        assert_eq!(
            cp_shard_spans(&[], 3),
            vec![
                CpShardSpan { chunk_lo: 0, chunk_hi: 0, tok_lo: 0, tok_hi: 0 };
                3
            ]
        );
    }

    #[test]
    fn planner_min_chunks_threads_into_plans() {
        let mut p = MixedPlanner::new(Strategy::Iso, SplitPolicy::Even, SIZES.to_vec(), 8, 256)
            .with_min_chunks(4);
        assert_eq!(p.min_chunks, 4);
        let live = vec![lane_seq_unprefilled(0, 128)];
        let plan = p.plan(&live, None);
        let pf = plan.prefill.expect("prefill planned");
        assert!(pf.chunks.len() >= 4, "pipeline depth ignored: {}", pf.chunks.len());
        assert_eq!(pf.chunks.iter().map(|c| c.len).sum::<usize>(), 128);
    }

    fn lane_seq_unprefilled(slot: usize, prompt_len: usize) -> LaneSeq {
        LaneSeq {
            slot,
            prompt_len,
            prefilled: false,
            prefill_done: 0,
            last_token: 0,
            offset: 0,
            decode_left: 4,
        }
    }

    #[test]
    fn iso_short_prompt_falls_back_to_single_lane() {
        // Regression: prompt_len < 2 × smallest chunk used to hit
        // `clamp(g, total - g)` with an inverted range and panic.
        let jobs = plan_prefill(1, 16, Strategy::Iso, SplitPolicy::Even, SIZES, None);
        assert_eq!(jobs.iter().map(|j| j.len).sum::<usize>(), 16);
        assert!(jobs.iter().all(|j| j.lane == 0), "short prompt must be single-lane");
        assert_eq!(jobs.iter().filter(|j| j.last).count(), 1);
        for policy in [
            SplitPolicy::Even,
            SplitPolicy::Ratio(0.9),
            SplitPolicy::AttnBalanced,
            SplitPolicy::AdaptiveAttnMlp,
        ] {
            let jobs = plan_prefill(1, 16, Strategy::Iso, policy, SIZES, None);
            assert_eq!(jobs.iter().map(|j| j.len).sum::<usize>(), 16, "{policy:?}");
        }
    }

    #[test]
    fn balanced_split_agrees_with_cost_model_when_ctx_given() {
        // Satellite: no more hardcoded 0.55 — with a calibrated context
        // the engine-side plan lands on choose_split's t0 (tile-rounded).
        use crate::hw::NodeProfile;
        use crate::model::ModelSpec;
        use crate::split::{choose_split, SplitContext};
        let ctx = SplitContext::new(NodeProfile::a800(4), ModelSpec::gqa_70b());
        for len in [128usize, 512, 4096] {
            let jobs =
                plan_prefill(1, len, Strategy::Iso, SplitPolicy::AttnBalanced, SIZES, Some(&ctx));
            let lane0: usize = jobs.iter().filter(|j| j.lane == 0).map(|j| j.len).sum();
            let want = choose_split(SplitPolicy::AttnBalanced, &ctx.node, &ctx.model, len).t0;
            let g = SIZES[0];
            let want_rounded = ((want + g / 2) / g * g).clamp(g, len - g);
            assert_eq!(lane0, want_rounded, "len={len}");
        }
    }

    #[test]
    fn prop_iso_never_panics_on_padded_prompts() {
        Prop::new(91).cases(300).run("iso plan total lengths", |rng| {
            // Anything the engine can pad to: multiples of the smallest
            // chunk, including a single tile.
            let len = rng.range(1, 30) * 16;
            for policy in [SplitPolicy::Even, SplitPolicy::AttnBalanced] {
                let jobs = plan_prefill(3, len, Strategy::Iso, policy, SIZES, None);
                let total: usize = jobs.iter().map(|j| j.len).sum();
                if total != len {
                    return Err(format!("len={len}: tiled {total}"));
                }
                if jobs.iter().filter(|j| j.last).count() != 1 {
                    return Err(format!("len={len}: last count"));
                }
            }
            Ok(())
        });
    }

    fn lane_seq(slot: usize, prefilled: bool, offset: usize, left: usize) -> LaneSeq {
        LaneSeq {
            slot,
            prompt_len: 64,
            prefilled,
            prefill_done: if prefilled { 64 } else { 0 },
            last_token: slot as i32 + 100,
            offset,
            decode_left: left,
        }
    }

    #[test]
    fn planner_composes_head_of_line_prefill_and_lane() {
        let mut p = MixedPlanner::new(Strategy::Iso, SplitPolicy::Even, SIZES.to_vec(), 8, 256);
        let live = vec![
            lane_seq(0, true, 64, 3),
            lane_seq(1, false, 0, 3),
            lane_seq(2, true, 70, 1),
            lane_seq(3, false, 0, 3), // second un-prefilled seq must wait
        ];
        let plan = p.plan(&live, None);
        let pf = plan.prefill.expect("head-of-line prefill");
        assert_eq!(pf.slot, 1);
        assert_eq!(pf.chunks.iter().map(|c| c.len).sum::<usize>(), 64);
        assert_eq!(plan.decode.len(), 2);
        let slots: Vec<usize> = plan.decode.iter().map(|d| d.slot).collect();
        assert!(slots.contains(&0) && slots.contains(&2));
        // lane offsets come straight from sequence state
        for d in &plan.decode {
            let s = live.iter().find(|s| s.slot == d.slot).unwrap();
            assert_eq!(d.offset, s.offset);
            assert_eq!(d.token, s.last_token);
        }
        // a prefilling sequence is never also in the lane
        assert!(plan.decode.iter().all(|d| d.slot != pf.slot));
    }

    #[test]
    fn planner_caps_and_rotates_lane() {
        let mut p = MixedPlanner::new(Strategy::Iso, SplitPolicy::Even, SIZES.to_vec(), 2, 256);
        let live: Vec<LaneSeq> = (0..5).map(|s| lane_seq(s, true, 64, 10)).collect();
        let mut seen = [0usize; 5];
        for _ in 0..10 {
            let plan = p.plan(&live, None);
            assert!(plan.prefill.is_none());
            assert_eq!(plan.decode.len(), 2, "lane must be capped at decode_batch");
            for d in &plan.decode {
                seen[d.slot] += 1;
            }
        }
        // Rotation shares the 20 lane rows across all 5 sequences.
        assert_eq!(seen.iter().sum::<usize>(), 20);
        assert!(seen.iter().all(|&c| c == 4), "unfair rotation: {seen:?}");
    }

    #[test]
    fn planner_skips_finished_and_overlong_sequences() {
        let mut p = MixedPlanner::new(Strategy::Iso, SplitPolicy::Even, SIZES.to_vec(), 8, 128);
        let live = vec![
            lane_seq(0, true, 64, 0),   // out of decode budget
            lane_seq(1, true, 128, 5),  // at max_seq
            lane_seq(2, true, 100, 5),  // eligible
        ];
        let plan = p.plan(&live, None);
        assert_eq!(plan.decode.len(), 1);
        assert_eq!(plan.decode[0].slot, 2);
        assert!(!plan.is_empty());
        let empty = p.plan(&[], None);
        assert!(empty.is_empty());
        assert_eq!(empty.tokens(), 0);
    }

    #[test]
    fn prop_step_plan_conserves_tokens_and_kv_order() {
        // Satellite: every StepPlan conserves tokens (the prefill chunk
        // set tiles the padded prompt exactly; the lane advances exactly
        // one token per entry) and respects the KV ordering constraint
        // (chunk offsets contiguous, lane 1 strictly after lane 0, decode
        // offsets taken verbatim from sequence state, no slot in both
        // halves of the iteration).
        Prop::new(97).cases(200).run("step plan invariants", |rng| {
            let mut planner = MixedPlanner::new(
                Strategy::Iso,
                SplitPolicy::Even,
                SIZES.to_vec(),
                rng.range(1, 6),
                256,
            );
            let n = rng.range(1, 10);
            let live: Vec<LaneSeq> = (0..n)
                .map(|s| LaneSeq {
                    slot: s,
                    prompt_len: rng.range(1, 12) * 16,
                    prefilled: rng.f64() < 0.7,
                    prefill_done: 0,
                    last_token: rng.range(0, 512) as i32,
                    offset: rng.range(1, 256),
                    decode_left: rng.range(0, 5),
                })
                .collect();
            let plan = planner.plan(&live, None);
            if plan.decode.len() > planner.decode_batch {
                return Err(format!("lane {} over cap", plan.decode.len()));
            }
            if let Some(pf) = &plan.prefill {
                let total: usize = pf.chunks.iter().map(|c| c.len).sum();
                if total != pf.prompt_len {
                    return Err(format!("prefill tiles {total} != {}", pf.prompt_len));
                }
                // KV order: lane-0 chunks contiguous from 0, lane-1 after.
                let mut pos = 0;
                for lane in [0usize, 1] {
                    for c in pf.chunks.iter().filter(|c| c.lane == lane) {
                        if c.offset != pos {
                            return Err(format!("lane{lane} gap at {pos}"));
                        }
                        pos += c.len;
                    }
                }
                if plan.decode.iter().any(|d| d.slot == pf.slot) {
                    return Err("slot both prefilling and decoding".into());
                }
                if live.iter().find(|s| s.slot == pf.slot).map(|s| s.prefilled) != Some(false)
                {
                    return Err("prefill picked an already-prefilled seq".into());
                }
            }
            let mut lane_slots = Vec::new();
            for d in &plan.decode {
                let s = live.iter().find(|s| s.slot == d.slot).ok_or("unknown lane slot")?;
                if !s.decoding(planner.max_seq) {
                    return Err(format!("ineligible slot {} in lane", d.slot));
                }
                if d.offset != s.offset || d.token != s.last_token {
                    return Err(format!("lane entry desynced from seq state: {d:?}"));
                }
                lane_slots.push(d.slot);
            }
            lane_slots.sort_unstable();
            lane_slots.dedup();
            if lane_slots.len() != plan.decode.len() {
                return Err("duplicate slot in lane".into());
            }
            if plan.tokens()
                != plan.prefill.as_ref().map_or(0, |p| p.prompt_len) + plan.decode.len()
            {
                return Err("token accounting".into());
            }
            Ok(())
        });
    }
}
