//! # iso — Intra-Sequence Overlap of computation and communication
//!
//! A production-shaped reproduction of *"ISO: Overlap of Computation and
//! Communication within Sequence For LLM Inference"* (Bin Xiao, Lei Su;
//! Baichuan Inc., 2024).
//!
//! The paper overlaps the tensor-parallel all-reduces of LLM prefill with
//! compute by splitting each sequence into two intra-sequence micro-batches
//! (chunked-prefill style) and ping-ponging compute/communication between
//! them, preserving only the causal attention ordering between chunks.
//!
//! This crate provides:
//! * a **real serving engine** (`coordinator`, `runtime`, `collective`,
//!   `kv`, `batch`): N tensor-parallel worker threads executing AOT-lowered
//!   JAX/Pallas artifacts via PJRT, a real ring all-reduce (fp32 or int8
//!   wire), a paged KV cache, continuous batching, and the ISO pipelined
//!   scheduler — python never runs at serving time;
//! * a **calibrated simulator** (`sim`, `sched`, `hw`, `model`, `split`)
//!   reproducing every table and figure of the paper's evaluation on
//!   modeled 4090/A800 nodes;
//! * a **profile-driven auto-tuner** (`tune`): a calibration pass that
//!   fits the `hw` constants from micro-benchmarks, a planner that ranks
//!   the joint knob space against the `sched` cost models, and the
//!   predicted-vs-measured rank-agreement harness that keeps the two
//!   honest (`serve --auto-tune`, DESIGN.md §18);
//! * shared substrates: `config`, `quant`, `metrics`, `workload`,
//!   `report`, `util`.
//!
//! See DESIGN.md for the architecture and EXPERIMENTS.md for the
//! paper-vs-measured results.

// Docs are a first-class artifact of this crate: every public item must
// say what it is. CI runs `cargo doc --no-deps` with `-D warnings`, so a
// missing doc fails the build there.
#![warn(missing_docs)]

pub mod batch;
pub mod cli;
pub mod collective;
pub mod config;
pub mod coordinator;
pub mod fault;
pub mod hw;
pub mod kv;
pub mod metrics;
pub mod model;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod split;
pub mod tune;
pub mod util;
pub mod workload;
