//! Hand-rolled CLI (no clap offline — DESIGN.md §5): subcommand + flags.

use std::collections::BTreeMap;

/// Parsed command line: subcommand, `--key value` flags, bare positionals.
#[derive(Clone, Debug, Default)]
pub struct Cli {
    /// The subcommand (first argument).
    pub command: String,
    /// `--key value` / `--key=value` flags; bare `--flag` stores `"true"`.
    pub flags: BTreeMap<String, String>,
    /// Non-flag arguments, in order.
    pub positional: Vec<String>,
}

impl Cli {
    /// Parse from arbitrary args (first is the subcommand). `--flag` with
    /// no value is stored as "true".
    pub fn parse(args: &[String]) -> Result<Cli, String> {
        let mut cli = Cli::default();
        let mut it = args.iter().peekable();
        if let Some(cmd) = it.next() {
            cli.command = cmd.clone();
        }
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    return Err("bare `--` not supported".into());
                }
                if let Some((k, v)) = name.split_once('=') {
                    cli.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    cli.flags.insert(name.to_string(), it.next().unwrap().clone());
                } else {
                    cli.flags.insert(name.to_string(), "true".to_string());
                }
            } else {
                cli.positional.push(a.clone());
            }
        }
        Ok(cli)
    }

    /// Parse the process arguments (skipping argv\[0\]).
    pub fn from_env() -> Result<Cli, String> {
        let args: Vec<String> = std::env::args().skip(1).collect();
        Cli::parse(&args)
    }

    /// The value of `--key`, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    /// The value of `--key`, or `default`.
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// `--key` parsed as usize, or `default` when absent.
    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: bad number {v:?}")),
        }
    }

    /// Whether `--key` was given at all.
    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

/// Top-level help text (`iso-serve help`).
pub const USAGE: &str = "\
iso-serve — ISO (Intra-Sequence Overlap) LLM serving engine + paper-eval simulator

USAGE:
  iso-serve <command> [flags]

COMMANDS:
  serve       run the real engine on a synthetic trace
              --topology ppP.tpT.cpC (the rank grid in one flag, e.g.
                pp2.tp2.cp1; axes may be omitted — tp4 = pp1.tp4.cp1.
                pp: pipeline stages, layers split contiguously, stages
                chained by bit-exact p2p activation handoffs; tp: tensor-
                parallel width per stage; cp: ring context-parallel
                groups — each owns a contiguous KV shard of every
                sequence during prefill, decode runs on the last group)
              --tp N / --pp-stages N (deprecated aliases for the tp/pp
                axes; --topology wins when both are given)
              --kv-offload true|false (cold-KV tier: spill least-recently-
                needed KV pages to host memory, prefetch ahead of the
                decode cursor; opens prompts past the resident pool)
              --kv-resident-tokens N (device-resident KV pool cap in
                tokens; 0 = unbounded, the all-resident default)
              --kv-prefetch-pages N (pages fetched ahead of the decode
                cursor; default 2)
              --strategy iso|serial --requests N --prompt-len N
              --decode N --comm-quant f32|int8 --split even|ratio:X|balanced
              --wire-precision f32|fp16|int8|fp8|int4 (NUMERICS-CHANGING:
                wire rung for every collective; overrides --comm-quant;
                see DESIGN.md §16)
              --decode-wire-precision f32|fp16|int8|fp8|int4 (wire rung
                for the fused decode/verify lane only; prefill keeps the
                base rung; default: same as the base rung)
              --rate R (req/s Poisson arrivals → continuous batching)
              --decode-batch N (fused decode lane width per iteration)
              --mixed true|false (iteration-level mixed batching; default on)
              --spec-k N (speculative decoding: drafts verified per lane
                sequence per iteration; 0 = off)
              --spec-ngram N (self-draft n-gram order; default 2)
              --comm-segments N (row-segments per streamed collective)
              --fused-epilogue true|false (fold the residual epilogue into
                the collective's segment callbacks, TokenWeave-style;
                bit-exact, default on)
              --ladder-residual true|false (NUMERICS-CHANGING: the
                serial prefill / per-sequence decode loops compute the
                MLP from the pre-attention residual so both collectives
                overlap it; fused lanes unaffected; default off)
              --fault-plan SPEC (deterministic fault injection, e.g.
                kill:rank=1:iter=3 or seed=7:ranks=4:iters=20;
                see DESIGN.md §14; default off)
              --fault-slack X (detection deadline = X × iteration EMA)
              --max-recoveries N (mesh respawns before giving up)
              --tbt-budget-ms X (bounded chunked prefill: cap each
                iteration's prefill work so decode TBT stays under X ms;
                giant prompts stream across iterations; 0 = off)
              --kv-high-water F (KV-pressure preemption: past this
                fraction of KV blocks, evict the youngest sequence and
                re-prefill it later, checkpoint-free; 1.0 = off)
              --queue-bound N (bounded admission queue; requests past N
                are rejected with a typed overload error; 0 = unbounded)
              --max-preemptions N (per-sequence eviction cap; anti-
                livelock, default 2)
              --ttft-deadline-ms X (shed queued requests whose wait
                exceeds X ms before they start; 0 = off)
              --auto-tune (calibrate a hardware profile, rank the joint
                knob space against the cost models, and adopt the
                winner's topology/overlap/wire knobs; DESIGN.md §18)
              --auto-tune=dry-run (print the ranked plan and the pruned-
                axis ledger, then exit without starting the engine)
              --tune-profile 4090|a800 (plan against a built-in preset
                instead of the CPU engine testbed; --tune-cards N sets
                its ring size, default 4)
              --tune-model 30b|70b|tiny (model geometry the planner
                prices; default tiny for the CPU testbed, 30b for
                presets)
              --profile-cache FILE (persist the calibrated profile as
                JSON; reused on the next run instead of recalibrating —
                delete the file to invalidate, see TUNING.md)
              --config FILE (e.g. configs/engine-iso.conf; flags override)
              --verbose (deprecation notes for alias flags, stderr only)
  table1      print the paper's Table 1 from the calibrated simulator
              --strategy iso|gemm-overlap|request-overlap  --csv FILE
  timeline    ASCII Gantt of one prefill (Figure 1)
              --gpu 4090|a800 --cards N --model 30b|70b --len N
              --strategy ... --layers N
  sweep       reduction vs prompt length for one platform
              --gpu ... --cards N --model ... --strategy ...
              --hw-file FILE (custom [hardware] profile, e.g.
                configs/hardware-h800ish.conf)
  help        this text
";

#[cfg(test)]
mod tests {
    use super::*;

    fn v(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_command_flags_positional() {
        // valued flags take the next token greedily; trailing bare flags
        // become booleans
        let c = Cli::parse(&v(&["serve", "--tp", "4", "extra", "--verbose"])).unwrap();
        assert_eq!(c.command, "serve");
        assert_eq!(c.get("tp"), Some("4"));
        assert_eq!(c.get("verbose"), Some("true"));
        assert_eq!(c.positional, vec!["extra"]);
    }

    #[test]
    fn parses_equals_form() {
        let c = Cli::parse(&v(&["table1", "--strategy=iso", "--csv=out.csv"])).unwrap();
        assert_eq!(c.get("strategy"), Some("iso"));
        assert_eq!(c.get("csv"), Some("out.csv"));
    }

    #[test]
    fn usize_parsing() {
        let c = Cli::parse(&v(&["serve", "--tp", "8"])).unwrap();
        assert_eq!(c.usize_or("tp", 2).unwrap(), 8);
        assert_eq!(c.usize_or("missing", 7).unwrap(), 7);
        let bad = Cli::parse(&v(&["serve", "--tp", "x"])).unwrap();
        assert!(bad.usize_or("tp", 2).is_err());
    }

    #[test]
    fn empty_args_ok() {
        let c = Cli::parse(&[]).unwrap();
        assert_eq!(c.command, "");
    }
}
