//! Bench harness (criterion is unavailable offline — DESIGN.md §5):
//! warmup + timed samples, mean/p50/p95 reporting, and paper-table
//! formatting shared by every `cargo bench` target.

use crate::metrics::Histogram;
use std::time::Instant;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Case label.
    pub name: String,
    /// Timed iterations.
    pub samples: usize,
    /// Mean wall time (ms).
    pub mean_ms: f64,
    /// Median wall time (ms).
    pub p50_ms: f64,
    /// 95th-percentile wall time (ms).
    pub p95_ms: f64,
}

/// Time `f` for `samples` iterations after `warmup` untimed runs.
pub fn bench(name: &str, warmup: usize, samples: usize, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut h = Histogram::new();
    for _ in 0..samples {
        let t = Instant::now();
        f();
        h.record(t.elapsed().as_secs_f64() * 1e3);
    }
    let r = BenchResult {
        name: name.to_string(),
        samples,
        mean_ms: h.mean(),
        p50_ms: h.p50(),
        p95_ms: h.p95(),
    };
    println!(
        "{:<44} n={:<4} mean={:>9.3}ms p50={:>9.3}ms p95={:>9.3}ms",
        r.name, r.samples, r.mean_ms, r.p50_ms, r.p95_ms
    );
    r
}

/// Section header for bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_positive_times() {
        let r = bench("noop-ish", 1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(r.samples, 5);
        assert!(r.mean_ms >= 0.0 && r.p95_ms >= r.p50_ms * 0.5);
    }
}
