//! Minimal JSON: a writer for reports and a recursive-descent parser for
//! the artifact manifest. No serde in the offline build (DESIGN.md §5).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. `Object` uses a BTreeMap so output is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (always stored as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys → deterministic output).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// An empty object.
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert `key = val` (panics on non-objects); chainable.
    pub fn set(&mut self, key: &str, val: impl Into<Json>) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val.into());
        } else {
            panic!("set() on non-object Json");
        }
        self
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]`-style path lookup.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    /// This value as a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// This value as a non-negative integer.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    /// This value as a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// This value as an array slice.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Parse a JSON document. Supports the full grammar minus `\uXXXX`
    /// surrogate pairs beyond the BMP (not present in our manifests).
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl From<Vec<Json>> for Json {
    fn from(x: Vec<Json>) -> Json {
        Json::Arr(x)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.b.get(self.i + 1..self.i + 5).ok_or("bad \\u")?,
                            )
                            .map_err(|_| "bad \\u")?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u")?;
                            out.push(char::from_u32(cp).ok_or("bad codepoint")?);
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let mut j = Json::obj();
        j.set("name", "iso").set("n", 42usize).set("pi", 3.5);
        let s = j.to_string();
        assert_eq!(Json::parse(&s).unwrap(), j);
    }

    #[test]
    fn parse_manifest_like() {
        let doc = r#"{"config": {"d_model": 128, "eps": 1e-05},
                      "modules": [{"name": "attn_tp2_t16", "inputs": [{"shape": [16, 128], "dtype": "f32"}]}],
                      "ok": true, "none": null}"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.path(&["config", "d_model"]).unwrap().as_usize(), Some(128));
        assert_eq!(
            j.path(&["modules"]).unwrap().as_arr().unwrap()[0]
                .get("name")
                .unwrap()
                .as_str(),
            Some("attn_tp2_t16")
        );
        assert!((j.path(&["config", "eps"]).unwrap().as_f64().unwrap() - 1e-5).abs() < 1e-12);
    }

    #[test]
    fn parse_nested_arrays_and_escapes() {
        let j = Json::parse(r#"[[1,2],[3,[4]], "a\"b\nc", -2.5e3]"#).unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[2].as_str(), Some("a\"b\nc"));
        assert_eq!(a[3].as_f64(), Some(-2500.0));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{,}").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":1} x").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn display_escapes_control_chars() {
        let s = Json::Str("a\nb\"".into()).to_string();
        assert_eq!(s, "\"a\\nb\\\"\"");
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(2.5).to_string(), "2.5");
    }
}
