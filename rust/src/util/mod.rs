//! Small shared utilities: deterministic RNG, JSON writer, property-test
//! driver. The offline build has no `rand`/`serde`/`proptest`, so these are
//! hand-rolled (DESIGN.md §5).

pub mod bench;
pub mod json;
pub mod prop;
pub mod rng;

pub use json::Json;
pub use prop::Prop;
pub use rng::Rng;
