//! SplitMix64 — tiny, deterministic, splittable RNG.
//!
//! Used by the workload generator, the property-test driver, and the tests
//! that must agree with python's fixed-seed weight generation *structure*
//! (not values — cross-language numeric parity is established through the
//! exported weight files, never through RNG replication).

/// SplitMix64 PRNG (Steele, Lea & Flood 2014). Passes BigCrush for the
/// purposes we need; 2^64 period; every seed valid.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// A generator seeded with `seed` (every seed is valid).
    pub fn new(seed: u64) -> Self {
        Rng { state: seed }
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift rejection-free
    /// mapping (bias < 2^-64*n, negligible for our n).
    pub fn below(&mut self, n: u64) -> u64 {
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`; panics if lo >= hi.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range {lo}..{hi}");
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of entropy.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.f64() as f32) * (hi - lo)
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-12 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos();
            }
        }
    }

    /// Exponential with rate `lambda` (Poisson inter-arrival times).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        -(1.0 - self.f64()).ln() / lambda
    }

    /// Split off an independent child stream.
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Fill a float vec with N(0, scale) values.
    pub fn normal_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32 * scale).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn f64_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let mean = (0..n).map(|_| r.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn split_streams_independent() {
        let mut parent = Rng::new(5);
        let mut c1 = parent.split();
        let mut c2 = parent.split();
        assert_ne!(c1.next_u64(), c2.next_u64());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
