//! Hand-rolled property-test driver (no proptest in the offline build).
//!
//! Runs a closure over many RNG-derived cases; on failure it panics with
//! the failing case index and seed so the case is reproducible with
//! `Prop::new(seed).run_from(index, ..)`.

use super::rng::Rng;

/// A property-test run: a seed and a case count.
pub struct Prop {
    seed: u64,
    cases: usize,
}

impl Prop {
    /// A 256-case property run derived from `seed`.
    pub fn new(seed: u64) -> Self {
        Prop { seed, cases: 256 }
    }

    /// Override the number of cases.
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }

    /// Run `f` over `cases` independent RNG streams; `f` returns
    /// `Err(String)` (or panics) to fail.
    pub fn run<F>(&self, name: &str, mut f: F)
    where
        F: FnMut(&mut Rng) -> Result<(), String>,
    {
        self.run_from(0, name, &mut f);
    }

    /// Re-run starting from a specific failing case index.
    pub fn run_from<F>(&self, start: usize, name: &str, f: &mut F)
    where
        F: FnMut(&mut Rng) -> Result<(), String>,
    {
        for case in start..self.cases {
            let mut rng = Rng::new(self.seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15));
            if let Err(msg) = f(&mut rng) {
                panic!(
                    "property '{name}' failed at case {case} (seed {}): {msg}\n\
                     reproduce with Prop::new({}).run_from({case}, ..)",
                    self.seed, self.seed
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        Prop::new(1).cases(64).run("u64 below bound", |rng| {
            let n = rng.range(1, 1000) as u64;
            let x = rng.below(n);
            if x < n {
                Ok(())
            } else {
                Err(format!("{x} >= {n}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn reports_failure_with_case() {
        Prop::new(2).cases(8).run("always fails", |_| Err("nope".into()));
    }
}
