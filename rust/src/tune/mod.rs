//! Profile-driven auto-tuner: calibrate → plan → verify (DESIGN.md §18).
//!
//! TUNING.md documents ~10 interacting knobs; every one already has a
//! tier-1-pinned cost model in [`crate::sched`]. This module closes the
//! loop (ROADMAP item 5):
//!
//! * **calibrate** — [`calibrate`] runs short micro-benchmarks through a
//!   [`Probe`] (GEMM wall time at the engine's row counts, ring
//!   all-reduce α/β per wire rung, p2p stage-port latency) and fits a
//!   [`MeasuredProfile`] that slots in exactly where the hand-coded
//!   [`NodeProfile`] constants sit today. The deterministic
//!   [`AnalyticProbe`] answers from a profile's closed forms (what the
//!   stub backend's modeled kernels report), so tests can pin that the
//!   fit recovers `NodeProfile::{rtx4090,a800}` to within float noise;
//!   a live backend supplies its own `Probe` with real timers.
//! * **plan** — [`plan`] enumerates the joint config space (topology
//!   grid pp×tp×cp × comm_segments × decode_batch × spec_k × precision
//!   policy × fused_epilogue) against the `sched::*` cost models, prunes
//!   with the validity rules [`EngineConfig`] already enforces (every
//!   pruned axis keeps a one-line "why"), and returns a ranked
//!   [`Plan`].
//! * **verify** — [`sim_measured_request_s`] re-prices a planned config
//!   through the discrete-event engine twin ([`crate::sim::simulate`]
//!   over the ISO mixed iteration), and [`kendall_tau`] quantifies rank
//!   agreement between the planner's predictions and measurements —
//!   pinned ≥ 0.8 in `rust/tests/auto_tune.rs` (pure sim tier-1, real
//!   engine artifact-gated).

use std::collections::BTreeMap;
use std::path::Path;

use crate::config::{CommQuant, EngineConfig, OverlapCfg, SplitPolicy, Topology, WireCfg};
use crate::hw::{wire_factor, LinkProfile, NodeProfile};
use crate::model::ModelSpec;
use crate::sched::{self, spec_decode, Coster, MixedIteration};
use crate::util::json::Json;

// ---------------------------------------------------------------------------
// Probe: the micro-benchmark surface
// ---------------------------------------------------------------------------

/// The micro-benchmark surface [`calibrate`] measures through: one GEMM,
/// one ring all-reduce, one p2p send — each returning wall seconds. A
/// live backend implements this with real timers; [`AnalyticProbe`]
/// answers deterministically from a [`NodeProfile`]'s closed forms.
pub trait Probe {
    /// Human-readable probe/backend name (lands in
    /// [`MeasuredProfile::source`]).
    fn name(&self) -> String;
    /// Ring size the collectives run over.
    fn cards(&self) -> usize;
    /// The device's advertised peak FLOP/s (spec sheet / device query).
    /// Timing alone only identifies `peak_flops × eff(m)`; the hint
    /// splits the product the same way the hand-coded constants do.
    fn peak_flops_hint(&self) -> f64;
    /// Compute slowdown while a collective is in flight, as reported by
    /// the backend's overlap micro-benchmark.
    fn contention_hint(&self) -> f64;
    /// Whether this backend quantizes the wire to int8 by default.
    fn int8_wire_default(&self) -> bool;
    /// Wall seconds of one GEMM of `flops` at `m` rows.
    fn gemm_s(&self, flops: f64, m: usize) -> f64;
    /// Wall seconds of one ring all-reduce of `fp16_bytes` at rung `q`.
    fn allreduce_s(&self, fp16_bytes: usize, q: CommQuant) -> f64;
    /// Wall seconds of one p2p transfer of `bytes`.
    fn p2p_s(&self, bytes: f64) -> f64;
}

/// The deterministic probe: answers every micro-benchmark from a
/// [`NodeProfile`]'s closed-form models — exactly what the stub backend's
/// modeled kernels report. [`calibrate`] against it must reproduce the
/// profile's constants (the round-trip the tier-1 harness pins).
#[derive(Clone, Debug)]
pub struct AnalyticProbe {
    node: NodeProfile,
}

impl AnalyticProbe {
    /// A probe over `node`'s closed forms.
    pub fn new(node: NodeProfile) -> Self {
        AnalyticProbe { node }
    }
}

impl Probe for AnalyticProbe {
    fn name(&self) -> String {
        format!("analytic:{}", self.node.device.name)
    }
    fn cards(&self) -> usize {
        self.node.cards
    }
    fn peak_flops_hint(&self) -> f64 {
        self.node.device.peak_flops
    }
    fn contention_hint(&self) -> f64 {
        self.node.device.contention
    }
    fn int8_wire_default(&self) -> bool {
        self.node.int8_wire_default
    }
    fn gemm_s(&self, flops: f64, m: usize) -> f64 {
        self.node.device.gemm_s(flops, m)
    }
    fn allreduce_s(&self, fp16_bytes: usize, q: CommQuant) -> f64 {
        self.node.allreduce_rung_s(fp16_bytes, q)
    }
    fn p2p_s(&self, bytes: f64) -> f64 {
        self.node.link.p2p_s(bytes)
    }
}

// ---------------------------------------------------------------------------
// MeasuredProfile: the calibration product
// ---------------------------------------------------------------------------

/// A calibrated hardware profile: the fitted [`NodeProfile`] (drop-in for
/// the hand-coded constants) plus provenance. Serializes to the on-disk
/// cache behind `serve --profile-cache`.
#[derive(Clone, Debug, PartialEq)]
pub struct MeasuredProfile {
    /// The fitted constants, in the exact shape every cost model takes.
    pub node: NodeProfile,
    /// Which probe produced it (e.g. `analytic:rtx4090`).
    pub source: String,
    /// Micro-benchmark samples the fit consumed.
    pub samples: usize,
    /// Max relative residual of the fitted model over a held-out
    /// validation grid — how well the closed forms explain the probe.
    pub fit_err: f64,
    /// Measured per-rung wire factor (time ratio vs the fp16 rung after
    /// removing the α term), ladder order. Empty on one-card nodes.
    pub wire_factors: Vec<(String, f64)>,
}

impl MeasuredProfile {
    /// The profile as a JSON document (deterministic key order).
    pub fn to_json(&self) -> Json {
        let n = &self.node;
        let mut hw = Json::obj();
        hw.set("name", n.device.name.as_str())
            .set("cards", n.cards)
            .set("peak_flops", n.device.peak_flops)
            .set("peak_eff", n.device.peak_eff)
            .set("m_half", n.device.m_half)
            .set("launch_s", n.device.launch_s)
            .set("contention", n.device.contention)
            .set("link_alpha_s", n.link.alpha_s)
            .set("link_bytes_per_s", n.link.link_bytes_per_s)
            .set("int8_wire", n.int8_wire_default);
        let mut wf = Json::obj();
        for (label, factor) in &self.wire_factors {
            wf.set(label, *factor);
        }
        let mut j = Json::obj();
        j.set("source", self.source.as_str())
            .set("samples", self.samples)
            .set("fit_err", self.fit_err)
            .set("hardware", hw)
            .set("wire_factors", wf);
        j
    }

    /// Parse a profile previously written by [`MeasuredProfile::to_json`].
    pub fn from_json(j: &Json) -> Result<MeasuredProfile, String> {
        let f = |keys: &[&str]| -> Result<f64, String> {
            j.path(keys)
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("profile cache: missing number {}", keys.join(".")))
        };
        let hw_str = |key: &str| -> Result<String, String> {
            j.path(&["hardware", key])
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| format!("profile cache: missing hardware.{key}"))
        };
        let hw_bool = |key: &str| -> Result<bool, String> {
            match j.path(&["hardware", key]) {
                Some(Json::Bool(b)) => Ok(*b),
                _ => Err(format!("profile cache: missing hardware.{key}")),
            }
        };
        let mut node = NodeProfile::a800(1);
        node.device.name = hw_str("name")?;
        node.cards = f(&["hardware", "cards"])? as usize;
        if node.cards == 0 {
            return Err("profile cache: cards must be >= 1".into());
        }
        node.device.peak_flops = f(&["hardware", "peak_flops"])?;
        node.device.peak_eff = f(&["hardware", "peak_eff"])?;
        node.device.m_half = f(&["hardware", "m_half"])?;
        node.device.launch_s = f(&["hardware", "launch_s"])?;
        node.device.contention = f(&["hardware", "contention"])?;
        node.link.alpha_s = f(&["hardware", "link_alpha_s"])?;
        node.link.link_bytes_per_s = f(&["hardware", "link_bytes_per_s"])?;
        node.int8_wire_default = hw_bool("int8_wire")?;
        let source = j
            .get("source")
            .and_then(Json::as_str)
            .ok_or("profile cache: missing source")?
            .to_string();
        let samples = f(&["samples"])? as usize;
        let fit_err = f(&["fit_err"])?;
        // Rebuild wire factors in ladder order (objects sort by key).
        let mut wire_factors = Vec::new();
        for q in CommQuant::LADDER {
            if let Some(x) = j.path(&["wire_factors", q.label()]).and_then(Json::as_f64) {
                wire_factors.push((q.label().to_string(), x));
            }
        }
        Ok(MeasuredProfile { node, source, samples, fit_err, wire_factors })
    }

    /// Write the profile to `path` (the `--profile-cache` file).
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))
    }

    /// Read a profile back from `path`.
    pub fn load(path: &Path) -> Result<MeasuredProfile, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path:?}: {e}"))?;
        MeasuredProfile::from_json(&Json::parse(&text)?)
    }

    /// Load the cached profile at `path` if present, else [`calibrate`]
    /// through `probe` and write the cache. Returns the profile and
    /// whether it came from the cache (so the CLI can say so).
    pub fn load_or_calibrate(
        path: &Path,
        probe: &dyn Probe,
    ) -> Result<(MeasuredProfile, bool), String> {
        if path.exists() {
            return MeasuredProfile::load(path).map(|p| (p, true));
        }
        let p = calibrate(probe);
        p.save(path).map_err(|e| format!("{path:?}: {e}"))?;
        Ok((p, false))
    }

    /// The fitted constants as `[hardware]` config keys
    /// ([`NodeProfile::to_map`]) — feedable back through `--hw-file`.
    pub fn hw_map(&self) -> BTreeMap<String, String> {
        self.node.to_map()
    }
}

/// Ordinary-least-squares fit `y = a + b·x`; returns `(a, b)`.
fn linfit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert!(xs.len() == ys.len() && xs.len() >= 2);
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        sxx += (x - mx) * (x - mx);
        sxy += (x - mx) * (y - my);
    }
    if sxx == 0.0 {
        return (my, 0.0);
    }
    let b = sxy / sxx;
    (my - b * mx, b)
}

/// Relative error of `got` vs `want`, tolerant of zero/non-finite
/// references (degenerate probes must not poison the fit-error metric).
fn rel_err(got: f64, want: f64) -> f64 {
    if !got.is_finite() || !want.is_finite() {
        return if got == want { 0.0 } else { f64::INFINITY };
    }
    (got - want).abs() / want.abs().max(1e-30)
}

/// Calibrate a [`MeasuredProfile`] from micro-benchmarks through `probe`
/// (DESIGN.md §18).
///
/// The fit is exact for probes that obey the closed forms: GEMM pairs at
/// fixed `m` isolate `launch_s` and the per-flop slope `1/(peak·eff(m))`;
/// regressing that slope on `1/m` recovers `m_half` and
/// `peak_flops × peak_eff` (split via [`Probe::peak_flops_hint`]); two
/// all-reduce sizes at the fp16 rung recover ring α and link bandwidth;
/// per-rung repeats recover the measured wire factors. One-card nodes
/// fall back to the p2p port for α/β (they run no collectives).
/// Degenerate links (zero bandwidth → infinite probe times) yield a
/// zero-bandwidth profile rather than NaN, so planning stays total.
pub fn calibrate(probe: &dyn Probe) -> MeasuredProfile {
    let mut samples = 0usize;

    // --- GEMM: two flop counts per row count.
    let row_counts = [64usize, 256, 1024, 8192];
    let (f1, f2) = (1.0e12, 4.0e12);
    let mut launch_sum = 0.0;
    let mut inv_m = Vec::new();
    let mut per_flop = Vec::new();
    for &m in &row_counts {
        let t1 = probe.gemm_s(f1, m);
        let t2 = probe.gemm_s(f2, m);
        samples += 2;
        let slope = (t2 - t1) / (f2 - f1);
        launch_sum += t1 - f1 * slope;
        inv_m.push(1.0 / m as f64);
        per_flop.push(slope);
    }
    let launch_s = (launch_sum / row_counts.len() as f64).max(0.0);
    // per_flop(m) = (1 + m_half/m) / (peak·peak_eff): linear in 1/m.
    let (a, b) = linfit(&inv_m, &per_flop);
    let peak_flops = probe.peak_flops_hint();
    let (peak_eff, m_half) = if a > 0.0 && peak_flops > 0.0 && a.is_finite() {
        ((1.0 / a) / peak_flops, (b / a).max(0.0))
    } else {
        (1.0, 0.0)
    };

    // --- Link: α/β from the ring (or the p2p port on one-card nodes),
    // then the per-rung wire factors from slope ratios.
    let r = probe.cards();
    let (bytes1, bytes2) = (1usize << 20, 64usize << 20);
    let mut wire_factors = Vec::new();
    let (alpha_s, link_bytes_per_s) = if r > 1 {
        let t1 = probe.allreduce_s(bytes1, CommQuant::Fp16);
        let t2 = probe.allreduce_s(bytes2, CommQuant::Fp16);
        samples += 2;
        if t1.is_finite() && t2.is_finite() {
            let k = 2.0 * (r as f64 - 1.0);
            let slope = (t2 - t1) / (bytes2 - bytes1) as f64;
            let alpha = ((t1 - slope * bytes1 as f64) / k).max(0.0);
            let bw = if slope > 0.0 { k / (r as f64 * slope) } else { 1e18 };
            let fp16_wire = t2 - k * alpha;
            for q in CommQuant::LADDER {
                let tq = probe.allreduce_s(bytes2, q);
                samples += 1;
                let factor = if fp16_wire > 0.0 && tq.is_finite() {
                    (tq - k * alpha) / fp16_wire
                } else {
                    wire_factor(q)
                };
                wire_factors.push((q.label().to_string(), factor));
            }
            (alpha, bw)
        } else {
            // Zero-bandwidth link: every sample is infinite. Record the
            // degeneracy honestly instead of NaN.
            (0.0, 0.0)
        }
    } else {
        let t1 = probe.p2p_s(bytes1 as f64);
        let t2 = probe.p2p_s(bytes2 as f64);
        samples += 2;
        if t1.is_finite() && t2.is_finite() {
            let slope = (t2 - t1) / (bytes2 - bytes1) as f64;
            let alpha = (t1 - slope * bytes1 as f64).max(0.0);
            let bw = if slope > 0.0 { 1.0 / slope } else { 1e18 };
            (alpha, bw)
        } else {
            (0.0, 0.0)
        }
    };

    let mut node = NodeProfile::a800(1);
    node.device.name = probe.name();
    node.device.peak_flops = peak_flops;
    node.device.peak_eff = peak_eff;
    node.device.m_half = m_half;
    node.device.launch_s = launch_s;
    node.device.contention = probe.contention_hint().max(1.0);
    node.link = LinkProfile { alpha_s, link_bytes_per_s };
    node.cards = r;
    node.int8_wire_default = probe.int8_wire_default();

    // --- Held-out validation grid: how well the fit explains the probe.
    let mut fit_err = 0.0f64;
    for &(flops, m) in &[(5.0e11, 128usize), (2.0e12, 2048)] {
        samples += 1;
        fit_err = fit_err.max(rel_err(node.device.gemm_s(flops, m), probe.gemm_s(flops, m)));
    }
    if r > 1 && link_bytes_per_s > 0.0 {
        for &bytes in &[4usize << 20, 16 << 20] {
            samples += 1;
            fit_err = fit_err.max(rel_err(
                node.allreduce_rung_s(bytes, CommQuant::Fp16),
                probe.allreduce_s(bytes, CommQuant::Fp16),
            ));
        }
    }
    if !fit_err.is_finite() {
        fit_err = f64::MAX;
    }

    MeasuredProfile { node, source: probe.name(), samples, fit_err, wire_factors }
}

// ---------------------------------------------------------------------------
// Workload mixes
// ---------------------------------------------------------------------------

/// The serving mix a plan optimizes for: one representative request —
/// `prompt_len` prefill tokens, then `decode_steps` emitted tokens at KV
/// context `decode_ctx` — with the observed speculative acceptance rate.
#[derive(Clone, Debug, PartialEq)]
pub struct Workload {
    /// Mix label for reports/bench cases.
    pub name: String,
    /// Prefill tokens per request (≥ 2: a 1-token prefill cannot be
    /// ISO-split or costed).
    pub prompt_len: usize,
    /// Decode tokens emitted per request after prefill (0 = TTFT-only).
    pub decode_steps: usize,
    /// KV context the decode lane reads at.
    pub decode_ctx: usize,
    /// Per-draft speculative acceptance probability in `[0, 1]`.
    pub accept: f64,
}

impl Workload {
    /// Long-prompt, TTFT-dominated mix (summarization-style).
    pub fn prefill_heavy() -> Workload {
        Workload {
            name: "prefill-heavy".into(),
            prompt_len: 16384,
            decode_steps: 0,
            decode_ctx: 16384,
            accept: 0.8,
        }
    }

    /// Balanced chat-style mix.
    pub fn mixed() -> Workload {
        Workload {
            name: "mixed".into(),
            prompt_len: 4096,
            decode_steps: 256,
            decode_ctx: 4096,
            accept: 0.8,
        }
    }

    /// Short-prompt, long-generation mix (agentic/codegen-style).
    pub fn decode_heavy() -> Workload {
        Workload {
            name: "decode-heavy".into(),
            prompt_len: 512,
            decode_steps: 1024,
            decode_ctx: 1536,
            accept: 0.8,
        }
    }
}

// ---------------------------------------------------------------------------
// The planner
// ---------------------------------------------------------------------------

/// One ranked plan entry: a fully validated [`EngineConfig`] plus the
/// cost-model prediction that ranked it.
#[derive(Clone, Debug)]
pub struct PlannedConfig {
    /// The config, exactly as `Engine::start` would take it.
    pub cfg: EngineConfig,
    /// One-line human label (`pp1.tp4.cp1 seg4 b8 k4 int8/int4 fused`).
    pub summary: String,
    /// Predicted request time: `prefill_s + decode_s`.
    pub predicted_s: f64,
    /// Predicted prefill wall seconds for the workload's prompt.
    pub prefill_s: f64,
    /// Predicted decode device-seconds for the workload's emitted tokens.
    pub decode_s: f64,
}

/// A family of candidates the planner discarded, with the one-line "why"
/// (an [`EngineConfig::validate`] message or a cost-model validity rule).
#[derive(Clone, Debug, PartialEq)]
pub struct Pruned {
    /// The one-line reason.
    pub why: String,
    /// First candidate the rule fired on.
    pub example: String,
    /// Candidates discarded by this rule.
    pub count: usize,
}

/// The planner's output: candidates ranked by predicted request time
/// (ascending — best first), plus the pruned-axis ledger.
#[derive(Clone, Debug)]
pub struct Plan {
    /// Profile name the plan was computed against.
    pub profile: String,
    /// Model name.
    pub model: String,
    /// Workload mix the predictions price.
    pub workload: Workload,
    /// Candidates, best (lowest predicted time) first; ties broken by
    /// the summary string so the order is fully deterministic.
    pub ranked: Vec<PlannedConfig>,
    /// Discard ledger: one line per pruning rule that fired.
    pub pruned: Vec<Pruned>,
    /// Candidates that were actually scored.
    pub evaluated: usize,
}

impl Plan {
    /// The winning config, if any candidate survived pruning.
    pub fn best(&self) -> Option<&PlannedConfig> {
        self.ranked.first()
    }

    /// Render the plan for `serve --auto-tune=dry-run`: the top `top`
    /// rows, then the pruned-axis ledger.
    pub fn render(&self, top: usize) -> String {
        let w = &self.workload;
        let mut out = format!(
            "auto-tune plan: profile {} model {} workload {} \
             (prompt {}, decode {} @ ctx {}, accept {:.2})\n",
            self.profile, self.model, w.name, w.prompt_len, w.decode_steps, w.decode_ctx,
            w.accept
        );
        out.push_str(&format!(
            "{:>4}  {:<44} {:>12} {:>12} {:>12}\n",
            "rank", "config", "predicted", "prefill", "decode"
        ));
        for (i, pc) in self.ranked.iter().take(top).enumerate() {
            out.push_str(&format!(
                "{:>4}  {:<44} {:>9.2} ms {:>9.2} ms {:>9.2} ms\n",
                i + 1,
                pc.summary,
                pc.predicted_s * 1e3,
                pc.prefill_s * 1e3,
                pc.decode_s * 1e3,
            ));
        }
        out.push_str(&format!(
            "evaluated {} candidates, pruned {} ({} rules):\n",
            self.evaluated,
            self.pruned.iter().map(|p| p.count).sum::<usize>(),
            self.pruned.len()
        ));
        for p in &self.pruned {
            out.push_str(&format!(
                "  - {} [{} candidates, e.g. {}]\n",
                p.why, p.count, p.example
            ));
        }
        out
    }
}

/// One grid point before scoring.
#[derive(Clone, Copy, Debug)]
struct Candidate {
    topo: Topology,
    comm_segments: usize,
    decode_batch: usize,
    spec_k: usize,
    prefill_q: CommQuant,
    decode_q: CommQuant,
    fused_epilogue: bool,
}

impl Candidate {
    fn summary(&self) -> String {
        format!(
            "{} seg{} b{} k{} {}/{} {}",
            self.topo,
            self.comm_segments,
            self.decode_batch,
            self.spec_k,
            self.prefill_q,
            self.decode_q,
            if self.fused_epilogue { "fused" } else { "unfused" },
        )
    }
}

/// Every `(pp, tp, cp)` with `pp·tp·cp = cards`, deterministic order.
fn topologies(cards: usize) -> Vec<Topology> {
    let mut out = Vec::new();
    for pp in 1..=cards {
        if cards % pp != 0 {
            continue;
        }
        let rest = cards / pp;
        for tp in 1..=rest {
            if rest % tp != 0 {
                continue;
            }
            out.push(Topology { pp, tp, cp: rest / tp });
        }
    }
    out
}

fn record_prune(pruned: &mut Vec<Pruned>, why: &str, example: String) {
    if let Some(p) = pruned.iter_mut().find(|p| p.why == why) {
        p.count += 1;
    } else {
        pruned.push(Pruned { why: why.to_string(), example, count: 1 });
    }
}

/// `node` restricted to the `tp`-rank sub-ring that serves the decode
/// lane (cp gathers decode on its last group; pp's stages each run a
/// `tp`-wide ring).
fn lane_node(node: &NodeProfile, tp: usize) -> NodeProfile {
    let mut n = node.clone();
    n.cards = tp;
    n
}

/// Blocking flat-TP prefill with the epilogue exposure model, priced at
/// wire rung `q` — [`sched::fused_epilogue_iteration_s`] generalized over
/// the ladder (identical at the `Fp16`/`Int8` rungs).
fn flat_prefill_s(
    node: &NodeProfile,
    model: &ModelSpec,
    t: usize,
    segments: usize,
    fused: bool,
    q: CommQuant,
) -> f64 {
    let c = Coster { node: node.clone(), model: model.clone(), int8_wire: false };
    let bytes = t * model.d_model * model.act_bytes;
    let ar = node.allreduce_rung_s(bytes, q);
    let epi = sched::epilogue_s(node, model, t);
    let exposed = sched::epilogue_exposed_s(ar, epi, segments, fused);
    model.n_layers as f64 * (c.attn_block_s(t, 0) + c.mlp_block_s(t) + 2.0 * (ar + exposed))
}

/// Predicted decode device-seconds for the workload's emitted tokens:
/// the fused verify lane on the topology's `tp` sub-ring, windows of
/// `spec_k + 1` rows, plus the per-iteration pp stage hops.
fn decode_cost_s(node: &NodeProfile, model: &ModelSpec, w: &Workload, c: &Candidate) -> f64 {
    if w.decode_steps == 0 {
        return 0.0;
    }
    let lane = lane_node(node, c.topo.tp);
    let coster = Coster {
        node: lane,
        model: model.clone(),
        int8_wire: c.decode_q.is_quantized(),
    };
    let iter = spec_decode::fused_verify_iteration_s(
        &coster,
        c.decode_batch,
        c.spec_k + 1,
        w.decode_ctx,
    );
    let hop = if c.topo.pp > 1 {
        let bytes = c.decode_batch * (c.spec_k + 1) * model.d_model * model.act_bytes;
        (c.topo.pp - 1) as f64 * node.link.p2p_s(bytes as f64)
    } else {
        0.0
    };
    let emitted =
        c.decode_batch as f64 * spec_decode::expected_emitted(c.spec_k, w.accept);
    w.decode_steps as f64 * (iter + hop) / emitted
}

/// Predicted `(prefill_s, decode_s)` of one candidate — the planner's
/// closed-form score (the "predicted" side of the rank-agreement
/// harness).
fn predict_parts(
    node: &NodeProfile,
    model: &ModelSpec,
    w: &Workload,
    c: &Candidate,
) -> (f64, f64) {
    let t = c.topo;
    let prefill = if t.cp > 1 {
        sched::cp_iteration_rung_s(node, model, w.prompt_len, t.cp, t.tp, &node.link, c.prefill_q)
    } else if t.pp > 1 {
        let chunks = w.prompt_len.clamp(1, PP_CHUNKS);
        sched::pp_iteration_rung_s(
            node, model, w.prompt_len, chunks, t.pp, t.tp, &node.link, c.prefill_q,
        )
    } else {
        flat_prefill_s(node, model, w.prompt_len, c.comm_segments, c.fused_epilogue, c.prefill_q)
    };
    (prefill, decode_cost_s(node, model, w, c))
}

/// Micro-batches the planner assumes for pipeline candidates (matches
/// the `BENCH_PR4.json` sweep depth).
const PP_CHUNKS: usize = 4;

/// Enumerate, prune, score, and rank the joint knob space for `node` ×
/// `model` × `w` (DESIGN.md §18). Deterministic for fixed inputs; never
/// panics on degenerate profiles (zero-bandwidth links and one-card
/// nodes produce infinite/zero predictions, not NaN comparisons).
pub fn plan(node: &NodeProfile, model: &ModelSpec, w: &Workload) -> Plan {
    assert!(w.prompt_len >= 2, "a prompt of {} tokens cannot be planned", w.prompt_len);
    assert!((0.0..=1.0).contains(&w.accept), "accept must be in [0, 1]");

    let segment_grid: &[usize] = &[1, 2, 4, 8];
    let fused_grid: &[bool] = &[true, false];
    let batch_grid: &[usize] = if w.decode_steps > 0 { &[1, 4, 8, 16] } else { &[1] };
    let spec_grid: &[usize] = if w.decode_steps > 0 { &[0, 2, 4] } else { &[0] };
    let policy_grid: Vec<(CommQuant, CommQuant)> = if w.decode_steps > 0 {
        vec![
            (CommQuant::F32, CommQuant::F32),
            (CommQuant::Fp16, CommQuant::Fp16),
            (CommQuant::Fp16, CommQuant::Int8),
            (CommQuant::Int8, CommQuant::Int8),
            (CommQuant::Fp8, CommQuant::Fp8),
            (CommQuant::Int4, CommQuant::Int4),
            (CommQuant::Fp16, CommQuant::Int4),
        ]
    } else {
        CommQuant::LADDER.iter().map(|&q| (q, q)).collect()
    };

    let mut pruned: Vec<Pruned> = Vec::new();
    if w.decode_steps == 0 {
        record_prune(
            &mut pruned,
            "workload has no decode phase; decode_batch/spec_k/decode-rung axes collapsed",
            "b1 k0".into(),
        );
    }

    let mut ranked: Vec<PlannedConfig> = Vec::new();
    let mut evaluated = 0usize;
    for topo in topologies(node.cards) {
        let flat = topo.pp == 1 && topo.cp == 1;
        for &seg in segment_grid {
            for &fused in fused_grid {
                for &b in batch_grid {
                    for &k in spec_grid {
                        for &(pq, dq) in &policy_grid {
                            let cand = Candidate {
                                topo,
                                comm_segments: seg,
                                decode_batch: b,
                                spec_k: k,
                                prefill_q: pq,
                                decode_q: dq,
                                fused_epilogue: fused,
                            };
                            // Cost-model validity rules first (mirrors of
                            // the sched asserts), then EngineConfig's own.
                            if topo.pp > model.n_layers {
                                record_prune(
                                    &mut pruned,
                                    "more pipeline stages than layers",
                                    cand.summary(),
                                );
                                continue;
                            }
                            if topo.pp > 1 && topo.cp > 1 {
                                record_prune(
                                    &mut pruned,
                                    "no composed pp×cp cost model: the engine can run it \
                                     but the planner cannot rank it",
                                    cand.summary(),
                                );
                                continue;
                            }
                            if topo.cp > w.prompt_len {
                                record_prune(
                                    &mut pruned,
                                    "sub-token context shards: prompt shorter than cp",
                                    cand.summary(),
                                );
                                continue;
                            }
                            if !flat && seg != 1 {
                                record_prune(
                                    &mut pruned,
                                    "comm-segment streaming is priced on the flat path \
                                     only; collapsed to 1 for pp/cp topologies",
                                    cand.summary(),
                                );
                                continue;
                            }
                            if !flat && !fused {
                                record_prune(
                                    &mut pruned,
                                    "epilogue fusion is priced on the flat path only; \
                                     collapsed to the engine default for pp/cp topologies",
                                    cand.summary(),
                                );
                                continue;
                            }
                            let overlap = OverlapCfg {
                                comm_segments: seg,
                                decode_batch: b,
                                spec_k: k,
                                fused_epilogue: fused,
                                ..OverlapCfg::default()
                            };
                            let wire = WireCfg {
                                wire_precision: Some(pq),
                                decode_wire_precision: Some(dq),
                                ..WireCfg::default()
                            };
                            let cfg = match EngineConfig::builder()
                                .topology(topo)
                                .overlap(overlap)
                                .wire(wire)
                                .decode_steps(w.decode_steps)
                                .build()
                            {
                                Ok(cfg) => cfg,
                                Err(e) => {
                                    record_prune(&mut pruned, &e, cand.summary());
                                    continue;
                                }
                            };
                            evaluated += 1;
                            let (prefill_s, decode_s) = predict_parts(node, model, w, &cand);
                            ranked.push(PlannedConfig {
                                cfg,
                                summary: cand.summary(),
                                predicted_s: prefill_s + decode_s,
                                prefill_s,
                                decode_s,
                            });
                        }
                    }
                }
            }
        }
    }
    ranked.sort_by(|a, b| {
        a.predicted_s.total_cmp(&b.predicted_s).then_with(|| a.summary.cmp(&b.summary))
    });
    Plan {
        profile: node.device.name.clone(),
        model: model.name.clone(),
        workload: w.clone(),
        ranked,
        pruned,
        evaluated,
    }
}

/// The hand-tuned TUNING.md baseline for `node`: flat TP over every
/// card, unsegmented collectives, the default decode lane of 8, no
/// speculation, fused epilogue, and the profile's default wire rung
/// (int8 on comm-bound nodes, fp16 otherwise). The rank-agreement
/// harness pins that the planner's #1 pick never measures worse than
/// this.
pub fn hand_tuned_default(node: &NodeProfile, w: &Workload) -> EngineConfig {
    let q = if node.int8_wire_default { CommQuant::Int8 } else { CommQuant::Fp16 };
    EngineConfig::builder()
        .topology(Topology { pp: 1, tp: node.cards, cp: 1 })
        .overlap(OverlapCfg::default())
        .wire(WireCfg {
            wire_precision: Some(q),
            decode_wire_precision: Some(q),
            ..WireCfg::default()
        })
        .decode_steps(w.decode_steps)
        .build()
        .expect("the hand-tuned default must validate")
}

// ---------------------------------------------------------------------------
// The sim-measured side of the rank-agreement harness
// ---------------------------------------------------------------------------

/// `node` with the link bandwidth de-rated by rung `q`'s wire factor —
/// pricing `bytes × wire_factor(q)` through the unscaled models, so the
/// event-sim twin sees the same per-rung wire the planner priced.
fn rung_scaled(node: &NodeProfile, q: CommQuant) -> NodeProfile {
    let mut n = node.clone();
    n.link.link_bytes_per_s /= wire_factor(q);
    n
}

/// The "measured" side of the tier-1 rank-agreement harness: re-price a
/// planned config through the discrete-event engine twin. Flat
/// topologies run one ISO mixed iteration ([`sched::mixed_iteration_s`]:
/// two intra-sequence chunks ping-ponging compute/comm under stream
/// contention, the decode lane riding along) plus the per-chunk epilogue
/// exposure; pp/cp topologies run their wavefront models on the
/// rung-scaled link. The decode tail is priced by the same lane model
/// the planner uses (the lane graph is a serial chain, where the event
/// sim and the closed form agree by construction). The real
/// engine-measured counterpart lives in `rust/tests/auto_tune.rs` behind
/// the artifact gate.
pub fn sim_measured_request_s(
    node: &NodeProfile,
    model: &ModelSpec,
    w: &Workload,
    cfg: &EngineConfig,
) -> f64 {
    let topo = cfg.topology();
    let prec = cfg.precision();
    let prefill = if topo.cp > 1 {
        sched::cp_iteration_rung_s(
            node, model, w.prompt_len, topo.cp, topo.tp, &node.link, prec.prefill,
        )
    } else if topo.pp > 1 {
        let chunks = w.prompt_len.clamp(1, PP_CHUNKS);
        sched::pp_iteration_rung_s(
            node, model, w.prompt_len, chunks, topo.pp, topo.tp, &node.link, prec.prefill,
        )
    } else {
        let scaled = rung_scaled(node, prec.prefill);
        let lane_b = if w.decode_steps > 0 { cfg.decode_batch } else { 0 };
        let mix = MixedIteration {
            prefill_tokens: w.prompt_len,
            decode_batch: lane_b,
            decode_ctx: w.decode_ctx,
            fused: true,
        };
        let iso = sched::mixed_iteration_s(
            &scaled,
            model,
            SplitPolicy::Even,
            &mix,
            cfg.comm_segments,
            false,
        );
        // Per-chunk epilogue exposure, consumed in ack order on the comm
        // thread (the part ISO's cross-chunk overlap cannot hide).
        let mut exposure = 0.0;
        let t0 = w.prompt_len / 2;
        for t in [t0, w.prompt_len - t0] {
            if t == 0 {
                continue;
            }
            let bytes = t * model.d_model * model.act_bytes;
            let ar = scaled.allreduce_rung_s(bytes, CommQuant::Fp16);
            let epi = sched::epilogue_s(node, model, t);
            exposure += 2.0
                * model.n_layers as f64
                * sched::epilogue_exposed_s(ar, epi, cfg.comm_segments, cfg.fused_epilogue);
        }
        iso + exposure
    };
    let cand = Candidate {
        topo,
        comm_segments: cfg.comm_segments,
        decode_batch: cfg.decode_batch,
        spec_k: cfg.spec_k,
        prefill_q: prec.prefill,
        decode_q: prec.decode,
        fused_epilogue: cfg.fused_epilogue,
    };
    prefill + decode_cost_s(node, model, w, &cand)
}

// ---------------------------------------------------------------------------
// Rank agreement
// ---------------------------------------------------------------------------

/// Kendall rank correlation (τ-b, tie-corrected) between two paired
/// samples: `+1` = identical ordering, `−1` = reversed, `0` =
/// independent. Fully tied inputs return `+1` (vacuous agreement).
/// Comparisons use [`f64::total_cmp`], so NaN never panics.
pub fn kendall_tau(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "paired samples");
    let n = a.len();
    if n < 2 {
        return 1.0;
    }
    let (mut concordant, mut discordant) = (0i64, 0i64);
    let (mut ties_a, mut ties_b) = (0i64, 0i64);
    for i in 0..n {
        for j in i + 1..n {
            use std::cmp::Ordering::Equal;
            let oa = a[i].total_cmp(&a[j]);
            let ob = b[i].total_cmp(&b[j]);
            match (oa, ob) {
                (Equal, Equal) => {
                    ties_a += 1;
                    ties_b += 1;
                }
                (Equal, _) => ties_a += 1,
                (_, Equal) => ties_b += 1,
                _ if oa == ob => concordant += 1,
                _ => discordant += 1,
            }
        }
    }
    let n0 = (n * (n - 1) / 2) as f64;
    let denom = ((n0 - ties_a as f64) * (n0 - ties_b as f64)).sqrt();
    if denom == 0.0 {
        return 1.0;
    }
    (concordant - discordant) as f64 / denom
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kendall_tau_hand_cases() {
        assert_eq!(kendall_tau(&[1.0, 2.0, 3.0], &[10.0, 20.0, 30.0]), 1.0);
        assert_eq!(kendall_tau(&[1.0, 2.0, 3.0], &[30.0, 20.0, 10.0]), -1.0);
        // One swapped adjacent pair among 4: (C, D) = (5, 1) → 4/6.
        let tau = kendall_tau(&[1.0, 2.0, 3.0, 4.0], &[1.0, 3.0, 2.0, 4.0]);
        assert!((tau - 4.0 / 6.0).abs() < 1e-12, "{tau}");
        // Fully tied on one side: vacuous agreement, not a panic.
        assert_eq!(kendall_tau(&[1.0, 1.0, 1.0], &[1.0, 2.0, 3.0]), 1.0);
        // Ties dilute but don't flip: τ-b of a half-tied list stays
        // positive when the strict pairs agree.
        let tau = kendall_tau(&[1.0, 1.0, 2.0], &[5.0, 6.0, 7.0]);
        assert!(tau > 0.0 && tau < 1.0, "{tau}");
    }

    #[test]
    fn topology_grid_is_exact_factorization() {
        let t4 = topologies(4);
        assert_eq!(t4.len(), 6);
        assert!(t4.iter().all(|t| t.world() == 4));
        assert_eq!(topologies(1), vec![Topology { pp: 1, tp: 1, cp: 1 }]);
    }

    #[test]
    fn analytic_calibration_recovers_preset_constants() {
        for preset in [NodeProfile::rtx4090(4), NodeProfile::a800(4)] {
            let probe = AnalyticProbe::new(preset.clone());
            let m = calibrate(&probe);
            let close =
                |got: f64, want: f64| (got - want).abs() <= 1e-6 * want.abs().max(1e-12);
            assert!(close(m.node.device.peak_flops, preset.device.peak_flops));
            assert!(close(m.node.device.peak_eff, preset.device.peak_eff), "{m:?}");
            assert!(close(m.node.device.m_half, preset.device.m_half), "{m:?}");
            assert!(close(m.node.device.launch_s, preset.device.launch_s), "{m:?}");
            assert!(close(m.node.link.alpha_s, preset.link.alpha_s), "{m:?}");
            assert!(
                close(m.node.link.link_bytes_per_s, preset.link.link_bytes_per_s),
                "{m:?}"
            );
            assert!(m.fit_err < 1e-9, "fit_err {}", m.fit_err);
            // Measured wire factors match the ladder constants.
            for (label, factor) in &m.wire_factors {
                let q = CommQuant::parse(label).unwrap();
                assert!(close(*factor, wire_factor(q)), "{label}: {factor}");
            }
        }
    }

    #[test]
    fn measured_profile_json_round_trips() {
        let m = calibrate(&AnalyticProbe::new(NodeProfile::rtx4090(4)));
        let back = MeasuredProfile::from_json(&Json::parse(&m.to_json().to_string()).unwrap())
            .unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn plan_is_deterministic_and_sorted() {
        let node = NodeProfile::cpu_engine(2, Some(64.0), 120.0);
        let model = ModelSpec::tiny_gqa();
        let w = Workload { prompt_len: 64, decode_steps: 16, decode_ctx: 64, ..Workload::mixed() };
        let a = plan(&node, &model, &w);
        let b = plan(&node, &model, &w);
        assert!(!a.ranked.is_empty());
        assert_eq!(a.evaluated, b.evaluated);
        let sa: Vec<&str> = a.ranked.iter().map(|p| p.summary.as_str()).collect();
        let sb: Vec<&str> = b.ranked.iter().map(|p| p.summary.as_str()).collect();
        assert_eq!(sa, sb);
        for pair in a.ranked.windows(2) {
            assert!(pair[0].predicted_s <= pair[1].predicted_s);
        }
    }

    #[test]
    fn plan_prunes_with_reasons() {
        let node = NodeProfile::rtx4090(4);
        let model = ModelSpec::mha_30b();
        let p = plan(&node, &model, &Workload::mixed());
        // The pp×cp composition rule must have fired on a 4-card grid
        // (pp2.tp1.cp2 exists) and kept a one-line why.
        assert!(p.pruned.iter().any(|pr| pr.why.contains("pp×cp")), "{:?}", p.pruned);
        assert!(p.pruned.iter().all(|pr| pr.count >= 1 && !pr.why.contains('\n')));
        assert!(p.evaluated > 0 && p.ranked.len() == p.evaluated);
    }

    #[test]
    fn render_names_the_winner() {
        let node = NodeProfile::cpu_engine(2, Some(64.0), 120.0);
        let model = ModelSpec::tiny_gqa();
        let w = Workload { prompt_len: 64, decode_steps: 16, decode_ctx: 64, ..Workload::mixed() };
        let p = plan(&node, &model, &w);
        let text = p.render(5);
        assert!(text.contains("auto-tune plan"));
        assert!(text.contains(&p.best().unwrap().summary));
    }
}
