//! Speculative-decode overlap study (paper §6, "Benefits for the Decode
//! Stage").
//!
//! Plain decode moves one token per step — far too little compute and
//! communication to overlap profitably (the paper's and our engine's
//! finding). Speculative sampling verifies `k` draft tokens per step,
//! which turns each decode step into a k-token chunk — a miniature
//! prefill. The paper conjectures this makes ISO profitable on the
//! 4090-4 (comm-heavy) platform; this module models exactly that:
//! a verify step of `k` tokens at context offset `ctx`, run serially or
//! ISO-split into two sub-chunks.

use crate::sim::{simulate, OpGraph, OpKind, Timeline};

use super::Coster;

/// Build the op graph of ONE speculative verify step over all layers.
/// `k` draft tokens at context length `ctx`; `iso` splits them k/2 + k/2.
pub fn build_verify_step(c: &Coster, k: usize, ctx: usize, iso: bool) -> OpGraph {
    let mut g = OpGraph::new();
    if !iso || k < 2 {
        let mut prev: Vec<usize> = vec![];
        for l in 0..c.model.n_layers {
            let attn = g.push(
                format!("L{l}.verify_attn"),
                OpKind::Compute,
                c.attn_block_s(k, ctx),
                &prev,
                0,
            );
            let ar0 = g.push(format!("L{l}.ar0"), OpKind::Comm, c.ar_s(k, 1), &[attn], 0);
            let mlp = g.push(
                format!("L{l}.verify_mlp"),
                OpKind::Compute,
                c.mlp_block_s(k),
                &[ar0],
                0,
            );
            let ar1 = g.push(format!("L{l}.ar1"), OpKind::Comm, c.ar_s(k, 1), &[mlp], 0);
            prev = vec![ar1];
        }
        return g;
    }

    let k0 = k / 2;
    let k1 = k - k0;
    let mut prev0: Vec<usize> = vec![];
    let mut prev1: Vec<usize> = vec![];
    for l in 0..c.model.n_layers {
        let a0 = g.push(
            format!("L{l}.attn0"),
            OpKind::Compute,
            c.attn_block_s(k0, ctx),
            &prev0,
            0,
        );
        let ar_a0 = g.push(format!("L{l}.ar_a0"), OpKind::Comm, c.ar_s(k0, 1), &[a0], 0);
        // draft chunk 1 attends over chunk 0's freshly-written KV
        let mut deps1 = prev1.clone();
        deps1.push(a0);
        let a1 = g.push(
            format!("L{l}.attn1"),
            OpKind::Compute,
            c.attn_block_s(k1, ctx + k0),
            &deps1,
            1,
        );
        let ar_a1 = g.push(format!("L{l}.ar_a1"), OpKind::Comm, c.ar_s(k1, 1), &[a1], 1);
        let m0 = g.push(
            format!("L{l}.mlp0"),
            OpKind::Compute,
            c.mlp_block_s(k0),
            &[ar_a0],
            0,
        );
        let ar_m0 = g.push(format!("L{l}.ar_m0"), OpKind::Comm, c.ar_s(k0, 1), &[m0], 0);
        let m1 = g.push(
            format!("L{l}.mlp1"),
            OpKind::Compute,
            c.mlp_block_s(k1),
            &[ar_a1],
            1,
        );
        let ar_m1 = g.push(format!("L{l}.ar_m1"), OpKind::Comm, c.ar_s(k1, 1), &[m1], 1);
        prev0 = vec![ar_m0];
        prev1 = vec![ar_m1];
    }
    g
}

/// Simulate one verify step; returns (serial_s, iso_s).
pub fn verify_step_times(c: &Coster, k: usize, ctx: usize, contention: f64) -> (f64, f64) {
    let serial = simulate(&build_verify_step(c, k, ctx, false), contention).makespan_s;
    let iso = simulate(&build_verify_step(c, k, ctx, true), contention).makespan_s;
    (serial, iso)
}

/// Timeline of one ISO verify step (for Gantt rendering).
pub fn verify_timeline(c: &Coster, k: usize, ctx: usize, contention: f64) -> Timeline {
    simulate(&build_verify_step(c, k, ctx, true), contention)
}

// ---------------------------------------------------------------------------
// Engine-matching fused-lane model (DESIGN.md §10)
// ---------------------------------------------------------------------------

/// One fused verify iteration costed exactly as the engine executes it
/// (`coordinator`'s `verify_fused`): `b` sequences × `w`-row windows, per
/// layer — per-row t=1 attention kernels (each row reads its own cache at
/// its own offset, so attention never batches), ONE rank-ordered fused
/// collective over all `b·w` rows, the position-free MLP as one
/// `b·w`-row GEMM, and a second fused collective. This is the curve the
/// `spec_decode` bench records next to the measured engine sweep so the
/// simulator predicts the same direction as `spec_k` grows.
pub fn fused_verify_iteration_s(c: &Coster, b: usize, w: usize, ctx: usize) -> f64 {
    if b == 0 || w == 0 {
        return 0.0;
    }
    let rows = b * w;
    let per_layer = rows as f64 * c.decode_attn_s(ctx)
        + c.mlp_block_s(rows)
        + 2.0 * c.fused_ar_s(rows);
    c.model.n_layers as f64 * per_layer
}

/// Expected tokens a `k`-draft verify window emits under an i.i.d.
/// per-draft acceptance probability `accept`: the window always emits the
/// first greedy token, plus draft `j` iff all drafts before it were
/// accepted — `1 + Σ_{j=1..k} accept^j`, saturating at `k + 1`.
pub fn expected_emitted(k: usize, accept: f64) -> f64 {
    let a = accept.clamp(0.0, 1.0);
    1.0 + (1..=k).map(|j| a.powi(j as i32)).sum::<f64>()
}

/// Predicted accepted-token throughput (tokens/second across the lane) of
/// the engine's fused spec-decode lane: `b` sequences verifying `k`
/// drafts per iteration at context `ctx`, with acceptance rate `accept`.
/// The k-sweep of this function against the measured engine throughput is
/// the PR-3 snapshot (`BENCH_PR3.json`): speculation pays where the extra
/// verify rows cost less than the tokens they admit — comm-heavy nodes
/// with α-bound decode collectives first (paper §6).
pub fn spec_lane_tokens_per_s(
    c: &Coster,
    b: usize,
    k: usize,
    ctx: usize,
    accept: f64,
) -> f64 {
    let iter_s = fused_verify_iteration_s(c, b, k + 1, ctx);
    if iter_s <= 0.0 {
        return 0.0;
    }
    b as f64 * expected_emitted(k, accept) / iter_s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{SimExperiment, Strategy};
    use crate::hw::NodeProfile;
    use crate::model::ModelSpec;

    fn coster(gpu: &str, cards: usize, model: &str) -> (Coster, f64) {
        let e = SimExperiment::new(
            NodeProfile::by_name(gpu, cards).unwrap(),
            ModelSpec::by_name(model).unwrap(),
            4096,
            Strategy::Iso,
        );
        let contention = e.node.device.contention;
        (Coster::new(&e), contention)
    }

    #[test]
    fn single_token_decode_gains_nothing() {
        // k=1 cannot split; ISO == serial (paper: decode overlap
        // unprofitable).
        let (c, f) = coster("4090", 4, "30b");
        let (serial, iso) = verify_step_times(&c, 1, 4096, f);
        assert!((serial - iso).abs() / serial < 1e-9);
    }

    #[test]
    fn speculative_k_unlocks_overlap_on_4090() {
        // Paper §6: "speculative sampling could potentially offer benefits
        // on the 4090 with 4 cards ... a greater number of input tokens".
        // Our α/β collective model adds a quantitative rider: splitting
        // doubles the number of (latency-bound) collectives, so the gain
        // only turns positive once k is large enough for the bandwidth
        // term to dominate — k ≳ 128 drafted tokens on 4090-4.
        let (c, f) = coster("4090", 4, "30b");
        let gain = |k: usize| {
            let (s, i) = verify_step_times(&c, k, 4096, f);
            (s - i) / s
        };
        assert!(gain(32) > gain(8), "gain should grow with k");
        assert!(gain(256) > gain(32), "gain should keep growing with k");
        assert!(gain(256) > 0.10, "k=256 on 4090-4 should be clearly profitable: {}", gain(256));
        assert!(gain(8) < 0.0, "small-k splitting is latency-dominated");
    }

    #[test]
    fn small_k_on_a800_stays_marginal() {
        let (c, f) = coster("a800", 4, "70b");
        let (s, i) = verify_step_times(&c, 4, 4096, f);
        let gain = (s - i) / s;
        assert!(gain < 0.10, "A800 small-k gain should be marginal: {gain}");
    }

    #[test]
    fn verify_step_costs_scale_with_context() {
        // Longer context → heavier attention in the verify step.
        let (c, f) = coster("4090", 4, "30b");
        let (s_short, _) = verify_step_times(&c, 16, 1024, f);
        let (s_long, _) = verify_step_times(&c, 16, 65536, f);
        assert!(s_long > s_short);
    }

    #[test]
    fn expected_emitted_formula() {
        assert_eq!(expected_emitted(0, 0.9), 1.0); // no drafts: one token
        assert_eq!(expected_emitted(4, 0.0), 1.0); // nothing ever accepted
        assert!((expected_emitted(3, 1.0) - 4.0).abs() < 1e-12); // all accepted
        // Monotone in both k and accept.
        assert!(expected_emitted(4, 0.5) > expected_emitted(2, 0.5));
        assert!(expected_emitted(4, 0.8) > expected_emitted(4, 0.5));
        // Geometric sum: 1 + 0.5 + 0.25 = 1.75.
        assert!((expected_emitted(2, 0.5) - 1.75).abs() < 1e-12);
    }

    #[test]
    fn fused_verify_iteration_scales_with_rows() {
        let (c, _) = coster("4090", 4, "30b");
        let t1 = fused_verify_iteration_s(&c, 8, 1, 2048);
        let t5 = fused_verify_iteration_s(&c, 8, 5, 2048);
        assert!(t5 > t1, "wider windows must cost more wall time");
        // ...but much less than 5× — the α term amortizes across rows
        // and the fused MLP GEMM gains efficiency (that is the whole bet).
        assert!(t5 < 4.0 * t1, "t5={t5} t1={t1}");
        assert_eq!(fused_verify_iteration_s(&c, 0, 5, 2048), 0.0);
        assert_eq!(fused_verify_iteration_s(&c, 8, 0, 2048), 0.0);
    }

    #[test]
    fn spec_lane_throughput_pays_with_acceptance() {
        // The engine-matching prediction (DESIGN.md §10): verify
        // attention runs per row, so widening a window costs ~linear
        // attention but sublinear collectives/MLP — speculation pays only
        // once acceptance clears that cost ratio (≈0.83 on the modeled
        // 4090-4 at ctx 2048), and at acceptance 0 the extra rows are
        // pure waste.
        let (c, _) = coster("4090", 4, "30b");
        let tok_s = |k: usize, acc: f64| spec_lane_tokens_per_s(&c, 8, k, 2048, acc);
        let base = tok_s(0, 0.0);
        assert!(tok_s(4, 0.95) > base, "k=4 @ 95% must beat the one-token lane");
        assert!(tok_s(4, 0.0) < base, "k=4 @ 0% must lose to the one-token lane");
        // Higher acceptance monotonically raises throughput at fixed k.
        assert!(tok_s(4, 0.9) > tok_s(4, 0.5));
    }

    #[test]
    fn iso_graph_doubles_collectives() {
        let (c, _) = coster("4090", 4, "30b");
        let serial = build_verify_step(&c, 16, 1024, false);
        let iso = build_verify_step(&c, 16, 1024, true);
        let count = |g: &OpGraph| g.ops.iter().filter(|o| o.kind == OpKind::Comm).count();
        assert_eq!(count(&iso), 2 * count(&serial));
    }
}
