//! Strategy lowering: one prefill → an `OpGraph` per overlap strategy
//! (paper Fig 1 a–d), costed by the calibrated hardware model.
//!
//! * `serial`          — (a) compute → all-reduce → compute → all-reduce;
//! * `gemm_overlap`    — (b) tile o_proj/down into the collective
//!                       (CoCoNet/T3/Flux-like);
//! * `request_overlap` — (c) two requests ping-pong compute/comm (Liger);
//! * `iso`             — (d) two intra-sequence chunks, attention ordering
//!                       preserved (the paper's contribution).

pub mod spec_decode;

use crate::config::{CommQuant, SimExperiment, Strategy};
use crate::hw::NodeProfile;
use crate::model::ModelSpec;
use crate::sim::{simulate, OpGraph, OpKind, Timeline};
use crate::split::{choose_split, Split};

/// Per-op costing against a node profile. All compute times are one
/// device's share (total work / cards); collectives use the ring model.
#[derive(Clone, Debug)]
pub struct Coster {
    /// Node being modeled.
    pub node: NodeProfile,
    /// Transformer geometry being modeled.
    pub model: ModelSpec,
    /// Whether collectives quantize to int8 on the wire.
    pub int8_wire: bool,
}

impl Coster {
    /// The coster of one simulator experiment.
    pub fn new(exp: &SimExperiment) -> Self {
        Coster { node: exp.node.clone(), model: exp.model.clone(), int8_wire: exp.int8_wire }
    }

    fn r(&self) -> f64 {
        self.node.cards as f64
    }

    /// qkv projection for a chunk of `t` tokens.
    pub fn qkv_s(&self, t: usize) -> f64 {
        let m = &self.model;
        let flops = 2.0 * t as f64 * m.d_model as f64
            * (m.q_dim() as f64 + 2.0 * m.kv_dim() as f64)
            / self.r();
        self.node.device.gemm_s(flops, t)
    }

    /// attention core (scores + weighted values) for chunk `[off, off+t)`.
    pub fn attn_core_s(&self, t: usize, off: usize) -> f64 {
        let m = &self.model;
        let attended = t as f64 * off as f64 + t as f64 * (t as f64 + 1.0) / 2.0;
        let flops = 2.0 * 2.0 * attended * m.q_dim() as f64 / self.r();
        self.node.device.gemm_s(flops, t)
    }

    /// o_proj for a chunk of `t` tokens, executed in `segments` launches.
    /// Returns per-segment time (each segment covers t/segments rows).
    pub fn o_proj_seg_s(&self, t: usize, segments: usize) -> f64 {
        let m = &self.model;
        let flops = 2.0 * t as f64 * m.q_dim() as f64 * m.d_model as f64 / self.r()
            / segments as f64;
        let rows = (t / segments).max(1);
        self.node.device.gemm_s(flops, rows)
    }

    /// gate+up projections + activation for `t` tokens.
    pub fn gate_up_s(&self, t: usize) -> f64 {
        let m = &self.model;
        let flops = 2.0 * 2.0 * t as f64 * m.d_model as f64 * m.d_ff as f64 / self.r();
        self.node.device.gemm_s(flops, t)
    }

    /// down projection, per segment of `segments` launches.
    pub fn down_seg_s(&self, t: usize, segments: usize) -> f64 {
        let m = &self.model;
        let flops =
            2.0 * t as f64 * m.d_ff as f64 * m.d_model as f64 / self.r() / segments as f64;
        let rows = (t / segments).max(1);
        self.node.device.gemm_s(flops, rows)
    }

    /// One tensor-parallel all-reduce of `t` tokens of activations
    /// (optionally 1/segments of it).
    pub fn ar_s(&self, t: usize, segments: usize) -> f64 {
        let bytes = t * self.model.d_model * self.model.act_bytes / segments;
        self.node.allreduce_s(bytes, self.int8_wire)
    }

    /// Whole attention block (qkv + core + o_proj) as one kernel's time.
    pub fn attn_block_s(&self, t: usize, off: usize) -> f64 {
        self.qkv_s(t) + self.attn_core_s(t, off) + self.o_proj_seg_s(t, 1)
    }

    /// Whole MLP block.
    pub fn mlp_block_s(&self, t: usize) -> f64 {
        self.gate_up_s(t) + self.down_seg_s(t, 1)
    }

    /// One decode row's attention block over a KV context of `ctx`
    /// tokens. Decode attention is per-sequence in both the fused and
    /// per-sequence schedules — each row reads its own cache at its own
    /// offset — so the lane costs `batch ×` this.
    pub fn decode_attn_s(&self, ctx: usize) -> f64 {
        self.qkv_s(1) + self.attn_core_s(1, ctx) + self.o_proj_seg_s(1, 1)
    }

    /// The fused decode-lane collective, costed as the engine executes it
    /// (`collective::allreduce_rows_fused`): rank-ordered reduce +
    /// broadcast, 2(R−1) messages each carrying the **full** B-row
    /// payload — no 1/R chunking (that's the bit-identity trade). α
    /// amortizes B×; the bandwidth term does not shrink with R.
    pub fn fused_ar_s(&self, b: usize) -> f64 {
        let r = self.node.cards;
        if r <= 1 || b == 0 {
            return 0.0;
        }
        let bytes = (b * self.model.d_model * self.model.act_bytes) as f64;
        let wire =
            if self.int8_wire { bytes * crate::hw::INT8_WIRE_FACTOR } else { bytes };
        2.0 * (r as f64 - 1.0)
            * (self.node.link.alpha_s + wire / self.node.link.link_bytes_per_s)
    }
}

/// Post-quantization bytes one TP collective of `t` tokens puts on the
/// wire at rung `q` — exactly the engine's accounting
/// (`collective::Wire::bytes` via [`CommQuant::wire_bytes`], scale
/// vectors and nibble packing included), evaluated at the model's
/// `d_model`. The bytes axis of the `sim_precision` sweep
/// (BENCH_PRECISION.json): multiply by `2·n_layers·allreduces` for an
/// iteration's wire volume.
pub fn wire_bytes_per_collective(model: &ModelSpec, t: usize, q: CommQuant) -> usize {
    q.wire_bytes(t, model.d_model)
}

/// Predicted wall time of one blocking TP pass over a `t`-token prefill
/// chunk with both per-layer collectives priced at wire rung `q` — the
/// tok/s axis of the `sim_precision` sweep. The blocking skeleton (no
/// cross-chunk overlap) isolates the rung effect: walking down the
/// ladder changes only the `2·n_layers` collective terms, so the
/// iteration time is monotone down the ladder and the Fp16→Int8 gap
/// equals the legacy `int8_wire` gap exactly
/// ([`NodeProfile::allreduce_rung_s`]).
pub fn ladder_iteration_s(
    node: &NodeProfile,
    model: &ModelSpec,
    t: usize,
    q: CommQuant,
) -> f64 {
    assert!(t >= 1);
    let c = Coster { node: node.clone(), model: model.clone(), int8_wire: false };
    let bytes = t * model.d_model * model.act_bytes;
    let ar = node.allreduce_rung_s(bytes, q);
    let layer = c.attn_block_s(t, 0) + c.mlp_block_s(t) + 2.0 * ar;
    model.n_layers as f64 * layer
}

/// Push a compute block as `segments` chained launches; returns the id of
/// the last segment. Extra deps apply to the first segment.
fn push_segmented(
    g: &mut OpGraph,
    label: &str,
    per_seg_s: f64,
    segments: usize,
    deps: &[usize],
    chunk: usize,
) -> usize {
    let mut last: Option<usize> = None;
    for s in 0..segments {
        let seg_deps: Vec<usize> = match last {
            None => deps.to_vec(),
            Some(prev) => vec![prev],
        };
        let lbl =
            if segments == 1 { label.to_string() } else { format!("{label}.s{s}") };
        last = Some(g.push(lbl, OpKind::Compute, per_seg_s, &seg_deps, chunk));
    }
    last.expect("segments >= 1")
}

/// (a) Serial pipeline. One chunk = whole prompt; no overlap anywhere.
pub fn build_serial(c: &Coster, t: usize) -> OpGraph {
    let mut g = OpGraph::new();
    let mut prev: Vec<usize> = vec![];
    for l in 0..c.model.n_layers {
        let attn = g.push(
            format!("L{l}.attn"),
            OpKind::Compute,
            c.attn_block_s(t, 0),
            &prev,
            0,
        );
        let ar0 = g.push(format!("L{l}.ar_attn"), OpKind::Comm, c.ar_s(t, 1), &[attn], 0);
        let mlp =
            g.push(format!("L{l}.mlp"), OpKind::Compute, c.mlp_block_s(t), &[ar0], 0);
        let ar1 = g.push(format!("L{l}.ar_mlp"), OpKind::Comm, c.ar_s(t, 1), &[mlp], 0);
        prev = vec![ar1];
    }
    g
}

/// (b) GEMM overlap: o_proj/down are tiled into `tiles` launches and the
/// matching all-reduce is tiled alongside; tile i's collective depends on
/// tile i's GEMM and tile i-1's collective (a software pipeline).
pub fn build_gemm_overlap(c: &Coster, t: usize, tiles: usize) -> OpGraph {
    assert!(tiles >= 1);
    let mut g = OpGraph::new();
    let mut prev: Vec<usize> = vec![];
    for l in 0..c.model.n_layers {
        // qkv + attention core are not adjacent to the collective; they
        // stay monolithic.
        let pre = g.push(
            format!("L{l}.qkv+core"),
            OpKind::Compute,
            c.qkv_s(t) + c.attn_core_s(t, 0),
            &prev,
            0,
        );
        // o_proj tiles pipelined into AR tiles.
        let mut last_gemm = pre;
        let mut last_ar: Option<usize> = None;
        for i in 0..tiles {
            last_gemm = g.push(
                format!("L{l}.o.t{i}"),
                OpKind::Compute,
                c.o_proj_seg_s(t, tiles),
                &[last_gemm],
                0,
            );
            let mut deps = vec![last_gemm];
            if let Some(ar) = last_ar {
                deps.push(ar);
            }
            last_ar = Some(g.push(
                format!("L{l}.ar_attn.t{i}"),
                OpKind::Comm,
                c.ar_s(t, tiles),
                &deps,
                0,
            ));
        }
        let gate_up = g.push(
            format!("L{l}.gate_up"),
            OpKind::Compute,
            c.gate_up_s(t),
            &[last_ar.unwrap()],
            0,
        );
        let mut last_gemm = gate_up;
        let mut last_ar: Option<usize> = None;
        for i in 0..tiles {
            last_gemm = g.push(
                format!("L{l}.down.t{i}"),
                OpKind::Compute,
                c.down_seg_s(t, tiles),
                &[last_gemm],
                0,
            );
            let mut deps = vec![last_gemm];
            if let Some(ar) = last_ar {
                deps.push(ar);
            }
            last_ar = Some(g.push(
                format!("L{l}.ar_mlp.t{i}"),
                OpKind::Comm,
                c.ar_s(t, tiles),
                &deps,
                0,
            ));
        }
        prev = vec![last_ar.unwrap()];
    }
    g
}

/// (d) ISO: two intra-sequence chunks. Chunk 1's attention core waits for
/// chunk 0's qkv (its KV-cache write), preserving the paper's only
/// ordering constraint; everything else ping-pongs compute/comm.
pub fn build_iso(c: &Coster, split: &Split, segments: usize) -> OpGraph {
    build_two_chunk(c, split, segments, true)
}

/// (c) Request-level overlap: identical structure to ISO but the two
/// micro-batches are *independent requests* (both at offset 0, no KV
/// ordering constraint). `t` is each request's length.
pub fn build_request_overlap(c: &Coster, t: usize, segments: usize) -> OpGraph {
    let split = Split { t0: t, t1: t, mlp_t0: t, mlp_t1: t };
    build_two_chunk(c, &split, segments, false)
}

fn build_two_chunk(c: &Coster, split: &Split, segments: usize, intra_sequence: bool) -> OpGraph {
    assert!(segments >= 1);
    let (t0, t1) = (split.t0, split.t1);
    // Chunk offsets: ISO chunks share one sequence; request-overlap
    // chunks are separate sequences at offset 0.
    let off1 = if intra_sequence { t0 } else { 0 };
    let mut g = OpGraph::new();
    let mut prev0: Vec<usize> = vec![];
    let mut prev1: Vec<usize> = vec![];
    for l in 0..c.model.n_layers {
        // --- chunk 0 attention ---
        let qkv0 = push_segmented(
            &mut g,
            &format!("L{l}.qkv0"),
            c.qkv_s(t0) / segments as f64,
            segments,
            &prev0,
            0,
        );
        let core0 = push_segmented(
            &mut g,
            &format!("L{l}.attn0"),
            (c.attn_core_s(t0, 0) + c.o_proj_seg_s(t0, 1)) / segments as f64,
            segments,
            &[qkv0],
            0,
        );
        let ar_a0 =
            g.push(format!("L{l}.ar_attn0"), OpKind::Comm, c.ar_s(t0, 1), &[core0], 0);

        // --- chunk 1 attention ---
        // qkv1 only needs chunk 1's own input; the KV-order constraint
        // binds the attention *core*, which reads chunk 0's cache.
        let qkv1 = push_segmented(
            &mut g,
            &format!("L{l}.qkv1"),
            c.qkv_s(t1) / segments as f64,
            segments,
            &prev1,
            1,
        );
        let core_deps: Vec<usize> =
            if intra_sequence { vec![qkv1, qkv0] } else { vec![qkv1] };
        let core1 = push_segmented(
            &mut g,
            &format!("L{l}.attn1"),
            (c.attn_core_s(t1, off1) + c.o_proj_seg_s(t1, 1)) / segments as f64,
            segments,
            &core_deps,
            1,
        );
        let ar_a1 =
            g.push(format!("L{l}.ar_attn1"), OpKind::Comm, c.ar_s(t1, 1), &[core1], 1);

        // --- MLP micro-batches (may use the Fig-3 re-split) ---
        let (m0, m1) = (split.mlp_t0, split.mlp_t1);
        let mlp0 = push_segmented(
            &mut g,
            &format!("L{l}.mlp0"),
            (c.gate_up_s(m0) + c.down_seg_s(m0, 1)) / segments as f64,
            segments,
            &[ar_a0],
            0,
        );
        let ar_m0 =
            g.push(format!("L{l}.ar_mlp0"), OpKind::Comm, c.ar_s(m0, 1), &[mlp0], 0);
        let mlp1 = push_segmented(
            &mut g,
            &format!("L{l}.mlp1"),
            (c.gate_up_s(m1) + c.down_seg_s(m1, 1)) / segments as f64,
            segments,
            &[ar_a1],
            1,
        );
        let ar_m1 =
            g.push(format!("L{l}.ar_mlp1"), OpKind::Comm, c.ar_s(m1, 1), &[mlp1], 1);

        prev0 = vec![ar_m0];
        prev1 = vec![ar_m1];
    }
    g
}

/// One iteration of the mixed scheduler (DESIGN.md §9): the head-of-line
/// prefill's two ISO chunks composed with a decode micro-batch.
#[derive(Clone, Copy, Debug)]
pub struct MixedIteration {
    /// Prefill tokens carried this iteration (0 = decode-only).
    pub prefill_tokens: usize,
    /// Decode lane width: sequences decoding one token each.
    pub decode_batch: usize,
    /// KV context length each decode row attends over.
    pub decode_ctx: usize,
    /// `true`: the lane shares one B-row collective per layer-stage and
    /// its MLP runs as one B-row GEMM. `false`: the legacy per-sequence
    /// schedule — B blocking single-row collectives and t=1 GEMMs.
    pub fused: bool,
}

/// Lower one mixed iteration to an op graph. The decode lane (chunk tag
/// 2) is dependency-free of the prefill chunks, so the simulator lets
/// the lane's compute run inside the prefill's communication windows and
/// the lane's collectives hide behind prefill compute — the engine's
/// `step_mixed` interleave (Fig 1c composed with Fig 1d).
pub fn build_mixed(
    c: &Coster,
    split: Option<&Split>,
    mix: &MixedIteration,
    segments: usize,
) -> OpGraph {
    assert_ne!(
        mix.prefill_tokens, 1,
        "a 1-token prefill cannot be costed; use 0 (decode-only) or >= 2"
    );
    assert_eq!(
        split.is_some(),
        mix.prefill_tokens >= 2,
        "split must accompany a prefill of >= 2 tokens"
    );
    if let Some(s) = split {
        assert_eq!(s.total(), mix.prefill_tokens, "split must cover the prefill");
    }
    assert!(mix.decode_batch >= 1 || split.is_some(), "empty iteration");
    let mut g = OpGraph::new();
    let b = mix.decode_batch;
    let ctx = mix.decode_ctx;

    let mut prev0: Vec<usize> = vec![];
    let mut prev1: Vec<usize> = vec![];
    let mut prev_d: Vec<usize> = vec![];
    for l in 0..c.model.n_layers {
        // --- prefill: the same two-chunk ISO skeleton as build_iso.
        if let Some(split) = split {
            let (t0, t1) = (split.t0, split.t1);
            let qkv0 = push_segmented(
                &mut g,
                &format!("L{l}.qkv0"),
                c.qkv_s(t0) / segments as f64,
                segments,
                &prev0,
                0,
            );
            let core0 = push_segmented(
                &mut g,
                &format!("L{l}.attn0"),
                (c.attn_core_s(t0, 0) + c.o_proj_seg_s(t0, 1)) / segments as f64,
                segments,
                &[qkv0],
                0,
            );
            let ar_a0 =
                g.push(format!("L{l}.ar_attn0"), OpKind::Comm, c.ar_s(t0, 1), &[core0], 0);
            let qkv1 = push_segmented(
                &mut g,
                &format!("L{l}.qkv1"),
                c.qkv_s(t1) / segments as f64,
                segments,
                &prev1,
                1,
            );
            let core1 = push_segmented(
                &mut g,
                &format!("L{l}.attn1"),
                (c.attn_core_s(t1, t0) + c.o_proj_seg_s(t1, 1)) / segments as f64,
                segments,
                &[qkv1, qkv0],
                1,
            );
            let ar_a1 =
                g.push(format!("L{l}.ar_attn1"), OpKind::Comm, c.ar_s(t1, 1), &[core1], 1);
            let (m0, m1) = (split.mlp_t0, split.mlp_t1);
            let mlp0 = push_segmented(
                &mut g,
                &format!("L{l}.mlp0"),
                c.mlp_block_s(m0) / segments as f64,
                segments,
                &[ar_a0],
                0,
            );
            let ar_m0 =
                g.push(format!("L{l}.ar_mlp0"), OpKind::Comm, c.ar_s(m0, 1), &[mlp0], 0);
            let mlp1 = push_segmented(
                &mut g,
                &format!("L{l}.mlp1"),
                c.mlp_block_s(m1) / segments as f64,
                segments,
                &[ar_a1],
                1,
            );
            let ar_m1 =
                g.push(format!("L{l}.ar_mlp1"), OpKind::Comm, c.ar_s(m1, 1), &[mlp1], 1);
            prev0 = vec![ar_m0];
            prev1 = vec![ar_m1];
        }

        // --- decode lane.
        if b > 0 {
            if mix.fused {
                // Per-row attention compute, one B-row collective, one
                // B-row MLP GEMM (position-free), one more collective.
                let attn = g.push(
                    format!("L{l}.dec_attn"),
                    OpKind::Compute,
                    b as f64 * c.decode_attn_s(ctx),
                    &prev_d,
                    2,
                );
                let ar_a = g.push(
                    format!("L{l}.dec_ar_attn"),
                    OpKind::Comm,
                    c.fused_ar_s(b),
                    &[attn],
                    2,
                );
                let mlp = g.push(
                    format!("L{l}.dec_mlp"),
                    OpKind::Compute,
                    c.mlp_block_s(b),
                    &[ar_a],
                    2,
                );
                let ar_m = g.push(
                    format!("L{l}.dec_ar_mlp"),
                    OpKind::Comm,
                    c.fused_ar_s(b),
                    &[mlp],
                    2,
                );
                prev_d = vec![ar_m];
            } else {
                // Legacy round-robin: each sequence's layer is a blocking
                // attn → AR → mlp → AR chain, sequences back-to-back.
                for j in 0..b {
                    let attn = g.push(
                        format!("L{l}.dec{j}.attn"),
                        OpKind::Compute,
                        c.decode_attn_s(ctx),
                        &prev_d,
                        2,
                    );
                    let ar_a = g.push(
                        format!("L{l}.dec{j}.ar_attn"),
                        OpKind::Comm,
                        c.ar_s(1, 1),
                        &[attn],
                        2,
                    );
                    let mlp = g.push(
                        format!("L{l}.dec{j}.mlp"),
                        OpKind::Compute,
                        c.mlp_block_s(1),
                        &[ar_a],
                        2,
                    );
                    let ar_m = g.push(
                        format!("L{l}.dec{j}.ar_mlp"),
                        OpKind::Comm,
                        c.ar_s(1, 1),
                        &[mlp],
                        2,
                    );
                    prev_d = vec![ar_m];
                }
            }
        }
    }
    g
}

/// Makespan of one mixed iteration on a node — what the PR-2 bench
/// records next to the engine's measured sweep so both predict the same
/// direction as `decode_batch` grows.
pub fn mixed_iteration_s(
    node: &NodeProfile,
    model: &ModelSpec,
    policy: crate::config::SplitPolicy,
    mix: &MixedIteration,
    segments: usize,
    int8_wire: bool,
) -> f64 {
    let c = Coster { node: node.clone(), model: model.clone(), int8_wire };
    let split = if mix.prefill_tokens >= 2 {
        Some(choose_split(policy, node, model, mix.prefill_tokens))
    } else {
        None
    };
    let g = build_mixed(&c, split.as_ref(), mix, segments);
    simulate(&g, node.device.contention).makespan_s
}

/// Predicted wall time of one prefill through a `pp × tp` 2D-parallel
/// engine (DESIGN.md §11): the prompt is split into `chunks` equal
/// micro-batches, the model's layers into `pp` contiguous stage groups
/// (balanced via `seg_range`, exactly the engine's assignment), each
/// stage internally tensor-parallel over a `tp`-rank ring, stages
/// connected by a `p2p` link carrying one chunk's activations per hop.
///
/// Per chunk, per layer the model costs the blocking TP schedule —
/// compute (`1/tp` of the layer's FLOPs at the chunk's GEMM row count)
/// plus two ring all-reduces over the `tp`-rank ring — and feeds the
/// per-stage times into [`crate::sim::pipeline_makespan`]. The model
/// captures the 2D trade the engine realizes: deeper pipelines shrink
/// each all-reduce ring (fewer α-steps, less per-hop wire) at the price
/// of `(pp − 1)` fill/drain bubbles and inter-stage hops, so which
/// `(pp, tp)` wins depends on the link — the bench records the predicted
/// and measured direction side by side (`BENCH_PR4.json`).
#[allow(clippy::too_many_arguments)]
pub fn pp_iteration_s(
    node: &NodeProfile,
    model: &ModelSpec,
    prompt_len: usize,
    chunks: usize,
    pp: usize,
    tp: usize,
    p2p: &crate::hw::LinkProfile,
    int8_wire: bool,
) -> f64 {
    let q = if int8_wire { CommQuant::Int8 } else { CommQuant::Fp16 };
    pp_iteration_rung_s(node, model, prompt_len, chunks, pp, tp, p2p, q)
}

/// [`pp_iteration_s`] generalized over the full wire-precision ladder:
/// both per-layer TP collectives are priced at rung `q`
/// ([`crate::hw::wire_factor`]), so the auto-tuner can rank `(pp, tp)`
/// candidates jointly with the precision axis. `Fp16`/`Int8` reproduce
/// the legacy bool exactly (the bool entry point delegates here).
#[allow(clippy::too_many_arguments)]
pub fn pp_iteration_rung_s(
    node: &NodeProfile,
    model: &ModelSpec,
    prompt_len: usize,
    chunks: usize,
    pp: usize,
    tp: usize,
    p2p: &crate::hw::LinkProfile,
    q: CommQuant,
) -> f64 {
    assert!(pp >= 1 && tp >= 1 && chunks >= 1);
    assert!(pp <= model.n_layers, "more stages than layers");
    assert!(prompt_len >= chunks, "sub-token chunks");
    let t = prompt_len / chunks;
    // Mean per-chunk layer cost: layer costs are additive in tokens, so
    // the whole-prompt layer cost divided by the chunk count is exact.
    let full = model.layer_chunk_cost(prompt_len, 0);
    let flops_per_chunk =
        (full.gemm_flops_attn + full.gemm_flops_mlp + full.attn_flops) / chunks as f64;
    let compute_s = node.device.gemm_s(flops_per_chunk / tp as f64, t);
    let ar_bytes = (t * model.d_model * model.act_bytes) as f64;
    let wire = ar_bytes * crate::hw::wire_factor(q);
    let layer_s = compute_s + 2.0 * node.link.ring_allreduce_s(wire, tp);
    let stage_s: Vec<f64> = (0..pp)
        .map(|s| {
            let (lo, hi) = crate::collective::seg_range(model.n_layers, pp, s);
            (hi - lo) as f64 * layer_s
        })
        .collect();
    let hop_s = if pp > 1 {
        p2p.alpha_s + (t * model.d_model * model.act_bytes) as f64 / p2p.link_bytes_per_s
    } else {
        0.0
    };
    crate::sim::pipeline_makespan(&stage_s, hop_s, chunks)
}

/// Host-side cost of the per-layer post-collective epilogue over `t`
/// tokens (residual add + the next op's RMSNorm,
/// [`ModelSpec::epilogue_flops`]): elementwise work priced through the
/// device's GEMM-shaped throughput curve. Replicated per rank — every
/// rank applies its own copy — so there is no TP division.
pub fn epilogue_s(node: &NodeProfile, model: &ModelSpec, t: usize) -> f64 {
    node.device.gemm_s(model.epilogue_flops(t), t)
}

/// Exposed (serial) share of one collective's epilogue (DESIGN.md §12).
/// Unfused, the whole epilogue runs after the last segment lands —
/// `epi_s` regardless of `segments`. Fused (TokenWeave-style), segment
/// `k`'s slice applies while segments `k+1..` are still on the wire
/// ([`crate::sim::streamed_epilogue_exposed_s`]): wire-dominated
/// epilogues expose exactly `epi_s / segments`.
pub fn epilogue_exposed_s(ar_s: f64, epi_s: f64, segments: usize, fused: bool) -> f64 {
    assert!(segments >= 1, "segments must be >= 1");
    if !fused || segments == 1 {
        return epi_s;
    }
    let cover = vec![ar_s / segments as f64; segments];
    let work = vec![epi_s / segments as f64; segments];
    crate::sim::streamed_epilogue_exposed_s(&cover, &work)
}

/// Predicted wall time of one blocking TP layer-stage pass over a
/// `t`-token chunk with the post-collective epilogue either serial
/// (`fused = false`: the residual-add + norm wait for the whole
/// collective) or fused into the `segments`-streamed collective — the
/// cost model of the engine's `fused_epilogue` knob. The absolute level
/// prices the blocking skeleton (ISO's cross-chunk overlap hides comm,
/// not the epilogue, which is consumed in ack order either way); the
/// fused-vs-unfused *direction* is what `BENCH_PR5.json` records and the
/// CI bench gate pins against `BENCH_BASELINE.json`.
pub fn fused_epilogue_iteration_s(
    node: &NodeProfile,
    model: &ModelSpec,
    t: usize,
    segments: usize,
    fused: bool,
    int8_wire: bool,
) -> f64 {
    assert!(t >= 1 && segments >= 1);
    let c = Coster { node: node.clone(), model: model.clone(), int8_wire };
    let ar = c.ar_s(t, 1);
    let epi = epilogue_s(node, model, t);
    let exposed = epilogue_exposed_s(ar, epi, segments, fused);
    let layer = c.attn_block_s(t, 0) + c.mlp_block_s(t) + 2.0 * (ar + exposed);
    model.n_layers as f64 * layer
}

/// The pipeline's fill/drain bubble share for a `pp`-stage, `chunks`-deep
/// schedule: `(pp − 1) / (chunks + pp − 1)` of the iteration is spent
/// filling and draining — the quantity deeper chunk sets amortize away
/// (DESIGN.md §11).
pub fn pp_bubble_fraction(pp: usize, chunks: usize) -> f64 {
    assert!(pp >= 1 && chunks >= 1);
    (pp as f64 - 1.0) / (chunks as f64 + pp as f64 - 1.0)
}

/// The `(pp, tp)` candidate with the smallest predicted prefill time
/// under [`pp_iteration_s`] — what the `BENCH_PR4.json` sweep checks the
/// measured direction against.
pub fn pp_best_config(
    node: &NodeProfile,
    model: &ModelSpec,
    prompt_len: usize,
    chunks: usize,
    candidates: &[(usize, usize)],
    p2p: &crate::hw::LinkProfile,
    int8_wire: bool,
) -> (usize, usize) {
    assert!(!candidates.is_empty());
    *candidates
        .iter()
        .min_by(|a, b| {
            let ta = pp_iteration_s(node, model, prompt_len, chunks, a.0, a.1, p2p, int8_wire);
            let tb = pp_iteration_s(node, model, prompt_len, chunks, b.0, b.1, p2p, int8_wire);
            ta.partial_cmp(&tb).unwrap()
        })
        .unwrap()
}

/// Predicted wall time of one prefill through a `cp × tp` context-parallel
/// engine (DESIGN.md §17): the *tokens* are split into `cp` contiguous
/// shards (balanced via `seg_range`, exactly the engine's assignment),
/// each shard's group internally tensor-parallel over a `tp`-rank ring,
/// and per layer each group forwards the prefix K/V to its successor over
/// a `p2p` link so attention sees the exact causal history.
///
/// Per layer, group `c` costs its shard's compute (`1/tp` of the shard's
/// FLOPs, including the causally-imbalanced attention term —
/// [`ModelSpec::layer_chunk_cost`] at the shard's offset) plus two ring
/// all-reduces over the shard's `t_c` rows; the groups form a wavefront
/// over layers priced by [`crate::sim::pipeline_makespan`] with the mean
/// per-layer prefix-K/V forward as the hop. The model captures the third
/// axis's trade: CP shrinks each group's all-reduce payload and row count
/// (fewer bytes, fewer α-steps than one wide TP ring) at the price of
/// the layer wavefront's fill/drain and the shard chain's hops — so which
/// `(cp, tp)` wins at fixed world size depends on the link, mirroring the
/// pp-vs-tp crossover one axis over (`BENCH_CP.json` records the sweep).
pub fn cp_iteration_s(
    node: &NodeProfile,
    model: &ModelSpec,
    prompt_len: usize,
    cp: usize,
    tp: usize,
    p2p: &crate::hw::LinkProfile,
    int8_wire: bool,
) -> f64 {
    let q = if int8_wire { CommQuant::Int8 } else { CommQuant::Fp16 };
    cp_iteration_rung_s(node, model, prompt_len, cp, tp, p2p, q)
}

/// [`cp_iteration_s`] generalized over the full wire-precision ladder:
/// each group's two per-layer TP collectives are priced at rung `q`
/// ([`crate::hw::wire_factor`]); the prefix-K/V hop stays at the cache's
/// storage width (KV pages are not wire-quantized by the rung knob).
/// `Fp16`/`Int8` reproduce the legacy bool exactly (the bool entry point
/// delegates here) — this is the form the auto-tuner ranks `(cp, tp)`
/// candidates with.
pub fn cp_iteration_rung_s(
    node: &NodeProfile,
    model: &ModelSpec,
    prompt_len: usize,
    cp: usize,
    tp: usize,
    p2p: &crate::hw::LinkProfile,
    q: CommQuant,
) -> f64 {
    assert!(cp >= 1 && tp >= 1);
    assert!(prompt_len >= cp, "sub-token shards");
    let group_s: Vec<f64> = (0..cp)
        .map(|c| {
            let (lo, hi) = crate::collective::seg_range(prompt_len, cp, c);
            let t = hi - lo;
            let cost = model.layer_chunk_cost(t, lo);
            let flops = cost.gemm_flops_attn + cost.gemm_flops_mlp + cost.attn_flops;
            let compute_s = node.device.gemm_s(flops / tp as f64, t);
            let wire = cost.ar_bytes as f64 * crate::hw::wire_factor(q);
            compute_s + 2.0 * node.link.ring_allreduce_s(wire, tp)
        })
        .collect();
    let hop_s = if cp > 1 {
        // Mean prefix K/V payload a group forwards per layer (group c
        // sends rows [0, hi_c)), spread over the tp ranks that each own
        // a kv-head slice of the shard chain.
        let prefix_rows: usize = (0..cp - 1)
            .map(|c| crate::collective::seg_range(prompt_len, cp, c).1)
            .sum();
        let mean_rows = prefix_rows as f64 / (cp - 1) as f64;
        let bytes = mean_rows * (2 * model.kv_dim() * model.act_bytes) as f64 / tp as f64;
        p2p.p2p_s(bytes)
    } else {
        0.0
    };
    crate::sim::pipeline_makespan(&group_s, hop_s, model.n_layers)
}

/// The `(cp, tp)` candidate with the smallest predicted prefill time
/// under [`cp_iteration_s`] — what the `BENCH_CP.json` sweep checks the
/// crossover direction against.
pub fn cp_best_config(
    node: &NodeProfile,
    model: &ModelSpec,
    prompt_len: usize,
    candidates: &[(usize, usize)],
    p2p: &crate::hw::LinkProfile,
    int8_wire: bool,
) -> (usize, usize) {
    assert!(!candidates.is_empty());
    *candidates
        .iter()
        .min_by(|a, b| {
            let ta = cp_iteration_s(node, model, prompt_len, a.0, a.1, p2p, int8_wire);
            let tb = cp_iteration_s(node, model, prompt_len, b.0, b.1, p2p, int8_wire);
            ta.partial_cmp(&tb).unwrap()
        })
        .unwrap()
}

// ---------------------------------------------------------------------------
// Recovery cost model (DESIGN.md §14)
// ---------------------------------------------------------------------------

/// The leader's detection deadline for one iteration: `slack ×` the
/// modeled (or observed) iteration time. The engine uses the same form
/// over its measured EMA; the simulator uses it over the cost model, so
/// the two sides price detection latency identically.
pub fn iteration_deadline_s(iter_s: f64, slack: f64) -> f64 {
    assert!(iter_s >= 0.0 && slack >= 1.0);
    iter_s * slack
}

/// Modeled wall time of one recovery round (DESIGN.md §14): worst-case
/// detection (a full deadline), mesh respawn, then checkpoint-free
/// replay of `replay_tokens` at the node's prefill throughput. The
/// engine's `recovery_ms` histogram measures the real counterpart.
pub fn recovery_s(
    deadline_s: f64,
    respawn_s: f64,
    replay_tokens: usize,
    prefill_tok_s: f64,
) -> f64 {
    assert!(prefill_tok_s > 0.0);
    deadline_s + respawn_s + replay_tokens as f64 / prefill_tok_s
}

/// Expected fraction of wall time lost to recovery at a per-iteration
/// fault rate: each iteration costs `iter_s` and, with probability
/// `rate`, an extra `recovery_s` — so the overhead share is
/// `rate·R / (iter_s + rate·R)`. This is the checkpoint-free analogue
/// of the classic checkpoint-restart overhead formula: recompute cost
/// scales with live context, not with a checkpoint interval.
pub fn expected_overhead_frac(rate: f64, iter_s: f64, recovery_s: f64) -> f64 {
    assert!((0.0..=1.0).contains(&rate) && iter_s > 0.0 && recovery_s >= 0.0);
    let overhead = rate * recovery_s;
    overhead / (iter_s + overhead)
}

// ---------------------------------------------------------------------------
// Overload / SLO cost model (DESIGN.md §15)
// ---------------------------------------------------------------------------

/// Fraction of offered load an SLO-guarding gate admits at utilization
/// `rho` (offered / capacity) with an admission ceiling `rho_max`: all
/// of it below the ceiling, `rho_max / rho` above — the rest is shed or
/// rejected, which is what keeps the served tail latency finite past
/// saturation.
pub fn slo_admitted_frac(rho: f64, rho_max: f64) -> f64 {
    assert!(rho >= 0.0, "rho must be >= 0");
    assert!(rho_max > 0.0 && rho_max < 1.0, "rho_max must be in (0, 1)");
    if rho <= rho_max {
        1.0
    } else {
        rho_max / rho
    }
}

/// Predicted mean TTFT (seconds) at admitted utilization `rho`: one
/// scheduling iteration plus the M/D/1 mean wait `rho / (2·(1 − rho))`
/// iterations (Poisson arrivals, deterministic iteration-sized service).
/// Admission clamps utilization at `rho_max`, so the prediction stays
/// finite past saturation — the modeled payoff of shedding.
pub fn slo_ttft_s(iter_s: f64, rho: f64, rho_max: f64) -> f64 {
    assert!(iter_s > 0.0, "iter_s must be > 0");
    assert!(rho >= 0.0, "rho must be >= 0");
    assert!(rho_max > 0.0 && rho_max < 1.0, "rho_max must be in (0, 1)");
    let r = rho.min(rho_max);
    iter_s * (1.0 + r / (2.0 * (1.0 - r)))
}

/// Predicted worst-case decode TBT (seconds) under bounded chunked
/// prefill: without a budget the lane waits for the whole head-of-line
/// prefill (`unbounded_s`); with one, the iteration is capped at the
/// budget but can never drop below the decode-only floor
/// (`decode_only_s`) — the budget bounds prefill work, it does not
/// shrink the lane itself.
pub fn bounded_tbt_s(decode_only_s: f64, unbounded_s: f64, budget_s: f64) -> f64 {
    assert!(decode_only_s >= 0.0 && budget_s >= 0.0);
    assert!(
        unbounded_s >= decode_only_s,
        "adding prefill work cannot make an iteration faster"
    );
    if budget_s == 0.0 {
        unbounded_s
    } else {
        unbounded_s.min(budget_s.max(decode_only_s))
    }
}

/// The largest prefill chunk budget (tokens per iteration) whose mixed
/// iteration still fits `budget_s`, chosen from `candidates` (the
/// engine passes multiples of its smallest compiled chunk). Falls back
/// to the smallest candidate when none fit — the anti-starvation floor:
/// prefill always makes progress, even if that iteration runs over
/// budget. This is how `tbt_budget_ms` is lowered onto
/// [`MixedPlanner::with_prefill_budget`].
///
/// [`MixedPlanner::with_prefill_budget`]: crate::batch::MixedPlanner::with_prefill_budget
#[allow(clippy::too_many_arguments)]
pub fn budgeted_prefill_tokens(
    node: &NodeProfile,
    model: &ModelSpec,
    policy: crate::config::SplitPolicy,
    decode_batch: usize,
    decode_ctx: usize,
    segments: usize,
    int8_wire: bool,
    budget_s: f64,
    candidates: &[usize],
) -> usize {
    assert!(budget_s > 0.0, "budget_s must be > 0 (0 disables bounding upstream)");
    assert!(!candidates.is_empty());
    let mut sorted: Vec<usize> = candidates.to_vec();
    sorted.sort_unstable();
    assert!(sorted[0] >= 2, "a 1-token prefill cannot be costed");
    let fits = |tokens: usize| {
        let mix = MixedIteration {
            prefill_tokens: tokens,
            decode_batch,
            decode_ctx,
            fused: true,
        };
        mixed_iteration_s(node, model, policy, &mix, segments, int8_wire) <= budget_s
    };
    sorted
        .iter()
        .rev()
        .find(|&&t| fits(t))
        .copied()
        .unwrap_or(sorted[0])
}

/// Lower an experiment to its op graph.
pub fn build(exp: &SimExperiment) -> OpGraph {
    let c = Coster::new(exp);
    match exp.strategy {
        Strategy::Serial => build_serial(&c, exp.prompt_len),
        Strategy::GemmOverlap => build_gemm_overlap(&c, exp.prompt_len, exp.gemm_segments.max(2)),
        Strategy::RequestOverlap => {
            build_request_overlap(&c, exp.prompt_len, exp.gemm_segments)
        }
        Strategy::Iso => {
            let split = choose_split(exp.split, &exp.node, &exp.model, exp.prompt_len);
            build_iso(&c, &split, exp.gemm_segments)
        }
    }
}

/// Simulate an experiment end-to-end; returns the timeline.
pub fn run(exp: &SimExperiment) -> Timeline {
    let graph = build(exp);
    // Serial never overlaps, so contention never fires; still pass it for
    // uniformity.
    simulate(&graph, exp.node.device.contention)
}

/// Prefill wall time (seconds) for an experiment.
pub fn prefill_s(exp: &SimExperiment) -> f64 {
    run(exp).makespan_s
}

/// The paper's Table-1 metric: percentage decrease of the prefill
/// duration vs the serial baseline on identical settings.
pub fn reduction_vs_serial(exp: &SimExperiment) -> f64 {
    let mut serial = exp.clone();
    serial.strategy = Strategy::Serial;
    let t_serial = prefill_s(&serial);
    let t_strategy = prefill_s(exp);
    // Request overlap processes TWO requests per run; compare per-request
    // throughput-normalized time (serial does them back-to-back).
    let t_base = if exp.strategy == Strategy::RequestOverlap {
        2.0 * t_serial
    } else {
        t_serial
    };
    (t_base - t_strategy) / t_base
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{SimExperiment, Strategy};
    use crate::hw::NodeProfile;
    use crate::model::ModelSpec;
    use crate::sim::OpKind;

    fn exp(strategy: Strategy) -> SimExperiment {
        SimExperiment::new(NodeProfile::rtx4090(4), ModelSpec::mha_30b(), 4096, strategy)
    }

    #[test]
    fn serial_has_zero_overlap() {
        let tl = run(&exp(Strategy::Serial));
        assert!(tl.overlap_s() < 1e-9);
        assert!(tl.makespan_s > 0.0);
    }

    #[test]
    fn serial_makespan_is_sum_of_all_ops() {
        let e = exp(Strategy::Serial);
        let g = build(&e);
        let total = g.total_work(OpKind::Compute) + g.total_work(OpKind::Comm);
        let tl = run(&e);
        assert!((tl.makespan_s - total).abs() / total < 1e-9);
    }

    #[test]
    fn iso_overlaps_and_beats_serial_on_4090() {
        let e = exp(Strategy::Iso);
        let tl = run(&e);
        assert!(tl.overlap_s() > 0.1 * tl.makespan_s, "overlap too small");
        let red = reduction_vs_serial(&e);
        assert!(
            (0.30..0.55).contains(&red),
            "4090-4 30b 4k ISO reduction {red} outside paper band ~0.43"
        );
    }

    #[test]
    fn iso_on_a800_gains_modestly() {
        let e = SimExperiment::new(
            NodeProfile::a800(4),
            ModelSpec::gqa_70b(),
            8192,
            Strategy::Iso,
        );
        let red = reduction_vs_serial(&e);
        assert!((0.02..0.25).contains(&red), "A800-4 70b 8k reduction {red}, paper ~0.10");
    }

    #[test]
    fn gemm_overlap_small_gain_a800_negative_4090() {
        // Paper §4.2: 2–5% on A800, negative on 4090; ISO beats it everywhere.
        let a800 = SimExperiment::new(
            NodeProfile::a800(4),
            ModelSpec::gqa_70b(),
            8192,
            Strategy::GemmOverlap,
        );
        let red_a800 = reduction_vs_serial(&a800);
        assert!(
            (-0.02..0.12).contains(&red_a800),
            "gemm-overlap a800 reduction {red_a800}"
        );

        let r4090 = SimExperiment::new(
            NodeProfile::rtx4090(4),
            ModelSpec::mha_30b(),
            4096,
            Strategy::GemmOverlap,
        );
        let red_4090 = reduction_vs_serial(&r4090);
        let iso_4090 = reduction_vs_serial(&exp(Strategy::Iso));
        assert!(red_4090 < 0.10, "gemm-overlap on 4090 should be ~<=0: {red_4090}");
        assert!(iso_4090 > red_4090, "ISO must beat gemm overlap");

        let iso_a800 = reduction_vs_serial(&SimExperiment::new(
            NodeProfile::a800(4),
            ModelSpec::gqa_70b(),
            8192,
            Strategy::Iso,
        ));
        assert!(iso_a800 > red_a800, "ISO must beat gemm overlap on a800");
    }

    #[test]
    fn request_overlap_improves_throughput_but_inflates_latency() {
        let e = exp(Strategy::RequestOverlap);
        let red = reduction_vs_serial(&e); // throughput-normalized
        assert!(red > 0.0, "request overlap should raise throughput: {red}");
        // ...but each individual request takes longer than its solo serial run.
        let solo = prefill_s(&exp(Strategy::Serial));
        let both = prefill_s(&e);
        assert!(both > solo, "per-request latency must inflate: {both} vs {solo}");
    }

    #[test]
    fn iso_respects_attention_order() {
        // In the ISO graph, chunk 1's first attention core segment must
        // start at/after chunk 0's qkv completes, layer by layer.
        let e = exp(Strategy::Iso);
        let tl = run(&e);
        for l in 0..4 {
            let qkv0_end = tl
                .spans
                .iter()
                .filter(|s| s.label.starts_with(&format!("L{l}.qkv0")))
                .map(|s| s.end_s)
                .fold(0.0, f64::max);
            let attn1_start = tl
                .spans
                .iter()
                .filter(|s| s.label.starts_with(&format!("L{l}.attn1")))
                .map(|s| s.start_s)
                .fold(f64::INFINITY, f64::min);
            assert!(
                attn1_start >= qkv0_end - 1e-12,
                "L{l}: attn1 at {attn1_start} before qkv0 end {qkv0_end}"
            );
        }
    }

    #[test]
    fn segments_help_when_computation_dominates() {
        // Fig 2b: multiple kernel launches reclaim SMs after comm ends.
        let mut e = SimExperiment::new(
            NodeProfile::a800(8),
            ModelSpec::gqa_70b(),
            16384,
            Strategy::Iso,
        );
        e.gemm_segments = 1;
        let t1 = prefill_s(&e);
        e.gemm_segments = 4;
        let t4 = prefill_s(&e);
        assert!(t4 < t1, "segments=4 ({t4}) should beat segments=1 ({t1}) on A800");
    }

    #[test]
    fn int8_wire_helps_on_4090() {
        // Fig 2a: quantized comm cuts the dominating term.
        let mut e = exp(Strategy::Iso);
        e.int8_wire = false;
        let fp16 = reduction_vs_serial(&e);
        e.int8_wire = true;
        let int8 = reduction_vs_serial(&e);
        assert!(int8 > fp16, "int8 wire gain {int8} !> fp16 {fp16}");
    }

    fn wire_case(q: CommQuant) -> usize {
        wire_bytes_per_collective(&ModelSpec::tiny_gqa(), 7, q)
    }

    #[test]
    fn wire_bytes_per_collective_hand_arithmetic() {
        // tiny_gqa d_model = 128; t = 7 rows. Hand arithmetic per rung:
        // f32/fp16 raw f32 wire 7·128·4; int8 7 scales + 7·128 bytes;
        // fp8 7·128 bytes, no scales; int4 7 scales + 7·64 packed bytes.
        assert_eq!(wire_case(CommQuant::F32), 7 * 128 * 4);
        assert_eq!(wire_case(CommQuant::Fp16), 7 * 128 * 4);
        assert_eq!(wire_case(CommQuant::Int8), 7 * 4 + 7 * 128);
        assert_eq!(wire_case(CommQuant::Fp8), 7 * 128);
        assert_eq!(wire_case(CommQuant::Int4), 7 * 4 + 7 * 64);
        // Odd cols: packing rounds up per row.
        let mut m = ModelSpec::tiny_gqa();
        m.d_model = 129;
        assert_eq!(wire_bytes_per_collective(&m, 3, CommQuant::Int4), 3 * 4 + 3 * 65);
    }

    #[test]
    fn ladder_iteration_monotone_down_the_ladder() {
        // The sim_precision tok/s axis: on the comm-dominated 4090
        // profile every step down the ladder must strictly shrink the
        // iteration, and the Fp16→Int8 step reproduces the legacy
        // int8_wire gap exactly.
        let node = NodeProfile::rtx4090(4);
        let model = ModelSpec::mha_30b();
        let s: Vec<f64> =
            CommQuant::LADDER.iter().map(|&q| ladder_iteration_s(&node, &model, 4096, q)).collect();
        for (w, q) in s.windows(2).zip(CommQuant::LADDER.windows(2)) {
            assert!(w[1] < w[0], "{:?} -> {:?} did not shrink: {s:?}", q[0], q[1]);
        }
        let c = Coster { node: node.clone(), model: model.clone(), int8_wire: false };
        let legacy_gap = 2.0 * model.n_layers as f64 * (c.ar_s(4096, 1) - {
            let c8 = Coster { node: node.clone(), model: model.clone(), int8_wire: true };
            c8.ar_s(4096, 1)
        });
        let ladder_gap = ladder_iteration_s(&node, &model, 4096, CommQuant::Fp16)
            - ladder_iteration_s(&node, &model, 4096, CommQuant::Int8);
        assert!(
            (ladder_gap - legacy_gap).abs() <= 1e-9 * legacy_gap.max(1e-12),
            "ladder {ladder_gap} vs legacy {legacy_gap}"
        );
    }

    fn mix(prefill: usize, b: usize, fused: bool) -> MixedIteration {
        MixedIteration { prefill_tokens: prefill, decode_batch: b, decode_ctx: 2048, fused }
    }

    fn mixed_s(m: &MixedIteration) -> f64 {
        mixed_iteration_s(
            &NodeProfile::rtx4090(4),
            &ModelSpec::mha_30b(),
            crate::config::SplitPolicy::AttnBalanced,
            m,
            1,
            true,
        )
    }

    #[test]
    fn fused_decode_lane_beats_per_sequence() {
        // The batched-decode direction: one B-row collective per stage
        // and a B-row MLP GEMM beat B blocking single-row rounds.
        let fused = mixed_s(&mix(0, 8, true));
        let unfused = mixed_s(&mix(0, 8, false));
        assert!(
            fused < 0.6 * unfused,
            "fused lane {fused} should be well under per-seq {unfused}"
        );
    }

    #[test]
    fn fused_decode_per_token_improves_with_batch() {
        // α-amortization + the GEMM efficiency curve: per-token iteration
        // time must fall monotonically as the lane widens.
        let per_tok = |b: usize| mixed_s(&mix(0, b, true)) / b as f64;
        let (t1, t4, t16) = (per_tok(1), per_tok(4), per_tok(16));
        assert!(t4 < t1, "b=4 per-token {t4} !< b=1 {t1}");
        assert!(t16 < t4, "b=16 per-token {t16} !< b=4 {t4}");
    }

    #[test]
    fn mixed_iteration_hides_decode_comm_behind_prefill() {
        // Composing the lane with a prefill must beat running the two
        // iterations back-to-back: decode comm slides into prefill
        // compute windows and vice versa.
        let together = mixed_s(&mix(4096, 8, true));
        let apart = mixed_s(&mix(4096, 0, true)) + mixed_s(&mix(0, 8, true));
        assert!(
            together < apart,
            "mixed {together} should beat separate phases {apart}"
        );
    }

    #[test]
    fn mixed_graphs_execute_fully() {
        let node = NodeProfile::a800(4);
        let model = ModelSpec::gqa_70b();
        let c = Coster { node: node.clone(), model: model.clone(), int8_wire: false };
        for m in [mix(4096, 8, true), mix(4096, 0, true), mix(0, 3, false), mix(0, 1, true)] {
            let split = if m.prefill_tokens >= 2 {
                Some(choose_split(
                    crate::config::SplitPolicy::Even,
                    &node,
                    &model,
                    m.prefill_tokens,
                ))
            } else {
                None
            };
            let g = build_mixed(&c, split.as_ref(), &m, 2);
            let tl = simulate(&g, node.device.contention);
            assert_eq!(tl.spans.len(), g.ops.len(), "{m:?}");
            assert!(tl.makespan_s > 0.0);
        }
    }

    /// A 4-card node with hand-controllable compute and ring link and no
    /// launch overhead, so the pp model's crossover can be verified by
    /// hand arithmetic (launch_s = 0 makes per-chunk compute time exactly
    /// independent of the (pp, tp) factorization).
    fn pp_node(peak_flops: f64, alpha_s: f64, bw: f64) -> NodeProfile {
        NodeProfile {
            device: crate::hw::DeviceProfile {
                name: "pp-test".into(),
                peak_flops,
                peak_eff: 0.7,
                m_half: 96.0,
                launch_s: 0.0,
                contention: 1.0,
            },
            link: crate::hw::LinkProfile { alpha_s, link_bytes_per_s: bw },
            cards: 4,
            int8_wire_default: false,
        }
    }

    #[test]
    fn pp_model_comm_free_favors_flat_tp() {
        // With a free interconnect the factorizations do identical
        // compute per chunk (launch_s = 0), so 2×2 pays exactly one
        // chunk-slot of fill/drain bubble over 1×4 and must lose.
        let node = pp_node(1e12, 0.0, 1e18);
        let model = ModelSpec::mha_30b();
        let free = crate::hw::LinkProfile { alpha_s: 0.0, link_bytes_per_s: 1e18 };
        let flat = pp_iteration_s(&node, &model, 4096, 4, 1, 4, &free, false);
        let deep = pp_iteration_s(&node, &model, 4096, 4, 2, 2, &free, false);
        assert!(
            flat < deep,
            "comm-free: 1x4 ({flat}) must beat 2x2 ({deep}) by the bubble"
        );
        // And the bubble accounts for the whole gap: deep/flat = (k+pp-1)/k.
        assert!((deep / flat - 5.0 / 4.0).abs() < 1e-9, "ratio {}", deep / flat);
    }

    #[test]
    fn pp_model_alpha_bound_link_favors_deep_pipeline() {
        // On a latency-bound ring (α ≫ everything) the per-layer
        // all-reduce costs 2·2(R−1)·α: 12α at tp=4 vs 4α at tp=2. Halving
        // the ring more than pays for the bubble and the p2p hop, so 2×2
        // must win — the paper-adjacent "2D beats flat TP on weak links"
        // direction (arXiv:2507.14392).
        let node = pp_node(1e30, 1e-3, 1e18); // compute ~0, α-dominated ring
        let model = ModelSpec::mha_30b();
        let p2p = crate::hw::LinkProfile { alpha_s: 1e-3, link_bytes_per_s: 1e18 };
        let flat = pp_iteration_s(&node, &model, 4096, 4, 1, 4, &p2p, false);
        let deep = pp_iteration_s(&node, &model, 4096, 4, 2, 2, &p2p, false);
        assert!(
            deep < 0.5 * flat,
            "α-bound link: 2x2 ({deep}) should beat 1x4 ({flat}) decisively"
        );
        // The predictor agrees on both regimes.
        let cands = [(1usize, 4usize), (2, 2)];
        assert_eq!(pp_best_config(&node, &model, 4096, 4, &cands, &p2p, false), (2, 2));
        let fast = pp_node(1e12, 0.0, 1e18);
        let free = crate::hw::LinkProfile { alpha_s: 0.0, link_bytes_per_s: 1e18 };
        assert_eq!(pp_best_config(&fast, &model, 4096, 4, &cands, &free, false), (1, 4));
    }

    #[test]
    fn pp_bubble_fraction_amortizes_with_depth() {
        assert_eq!(pp_bubble_fraction(1, 4), 0.0);
        assert!((pp_bubble_fraction(2, 4) - 0.2).abs() < 1e-12);
        for pp in [2usize, 4] {
            for k in [1usize, 2, 8, 32] {
                assert!(
                    pp_bubble_fraction(pp, 4 * k) < pp_bubble_fraction(pp, k),
                    "pp={pp} k={k}: more chunks must shrink the bubble"
                );
            }
        }
    }

    #[test]
    fn pp_model_respects_uneven_stage_split() {
        // 5 layers over 2 stages → stages of 3 and 2 layers; the slower
        // 3-layer stage bottlenecks the pipeline (sim::pipeline_makespan
        // recurrence), so the makespan must exceed the even-split bound
        // chunks·(L/pp)·layer and the single-stage serial time divided by
        // nothing — pin the exact recurrence value instead: with layer
        // time τ, stages [3τ, 2τ], hop 0, k=4: fill 3τ then 4 chunks at
        // 3τ each through the bottleneck + trailing 2τ = 14τ.
        let node = pp_node(1e30, 1e-3, 1e18);
        let mut model = ModelSpec::mha_30b();
        model.n_layers = 5;
        let free = crate::hw::LinkProfile { alpha_s: 0.0, link_bytes_per_s: 1e18 };
        let got = pp_iteration_s(&node, &model, 4096, 4, 2, 2, &free, false);
        // layer τ = 2 ARs · 2(2−1)(α + b/2/bw) ≈ 4α (compute ~0, bw ~∞).
        let tau = 4.0 * 1e-3;
        assert!((got / tau - 14.0).abs() < 0.01, "got {} vs 14τ", got / tau);
    }

    #[test]
    fn cp_model_alpha_bound_link_favors_context_shards() {
        // Hand arithmetic (DESIGN.md §17): on a latency-bound ring the
        // per-layer all-reduce costs 2·2(R−1)·α — 12α at tp=4, 4α at
        // tp=2. Flat TP serializes L layers: L·12α. The cp=2 wavefront
        // over L layers with uniform 4α group-layers and one α hop is
        // (2 + L − 1)·4α + (2 − 1)·α. At fixed world size cp=2 must win
        // decisively on the weak link.
        let node = pp_node(1e30, 1e-3, 1e18); // compute ~0, α-dominated
        let model = ModelSpec::mha_30b();
        let p2p = crate::hw::LinkProfile { alpha_s: 1e-3, link_bytes_per_s: 1e18 };
        let l = model.n_layers as f64;
        let flat = cp_iteration_s(&node, &model, 4096, 1, 4, &p2p, false);
        let deep = cp_iteration_s(&node, &model, 4096, 2, 2, &p2p, false);
        assert!((flat / (12.0e-3 * l) - 1.0).abs() < 0.01, "flat {flat} vs {}", 12.0e-3 * l);
        let want = (l + 1.0) * 4.0e-3 + 1.0e-3;
        assert!((deep / want - 1.0).abs() < 0.01, "deep {deep} vs hand value {want}");
        assert!(deep < 0.5 * flat, "α-bound link: cp2×2 ({deep}) should rout 1×4 ({flat})");
        let cands = [(1usize, 4usize), (2, 2)];
        assert_eq!(cp_best_config(&node, &model, 4096, &cands, &p2p, false), (2, 2));
    }

    #[test]
    fn cp_model_comm_free_favors_flat_tp() {
        // With a free interconnect both factorizations do the same total
        // FLOPs per rank (the shards' layer costs sum exactly to the
        // whole-prompt layer cost, causal term included), but cp=2 pays
        // the layer wavefront's fill/drain, the causally-imbalanced
        // second shard, and the short-row efficiency cliff — flat TP
        // must win, the other side of the crossover.
        let node = pp_node(1e12, 0.0, 1e18);
        let model = ModelSpec::mha_30b();
        let free = crate::hw::LinkProfile { alpha_s: 0.0, link_bytes_per_s: 1e18 };
        let flat = cp_iteration_s(&node, &model, 4096, 1, 4, &free, false);
        let deep = cp_iteration_s(&node, &model, 4096, 2, 2, &free, false);
        assert!(flat < deep, "comm-free: 1×4 ({flat}) must beat cp2×2 ({deep})");
        let cands = [(1usize, 4usize), (2, 2)];
        assert_eq!(cp_best_config(&node, &model, 4096, &cands, &free, false), (1, 4));
    }

    #[test]
    fn epilogue_exposure_hand_arithmetic() {
        // Unfused or single-segment: the whole epilogue is exposed.
        assert_eq!(epilogue_exposed_s(1.0, 0.25, 1, true), 0.25);
        assert_eq!(epilogue_exposed_s(1.0, 0.25, 4, false), 0.25);
        // Wire-dominated (epi <= ar): only the last segment's slice is
        // exposed — epi / segments exactly.
        let e = epilogue_exposed_s(1.0, 0.25, 4, true);
        assert!((e - 0.0625).abs() < 1e-12, "{e}");
        // Epilogue-dominated: arrivals at 0.025·k, 0.25 work each —
        // finish 0.025 + 4·0.25 = 1.025, exposed 1.025 − 0.1 = 0.925.
        let e = epilogue_exposed_s(0.1, 1.0, 4, true);
        assert!((e - 0.925).abs() < 1e-12, "{e}");
    }

    #[test]
    fn fused_epilogue_iteration_direction() {
        // The PR-5 cost model, pinned: fusing the epilogue into the
        // segment stream wins exactly the hidden epilogue share and only
        // once there are in-flight segments to hide behind.
        let node = NodeProfile::rtx4090(4);
        let model = ModelSpec::mha_30b();
        let s =
            |seg, fused| fused_epilogue_iteration_s(&node, &model, 4096, seg, fused, true);
        // segments = 1: nothing in flight to hide behind — identical.
        assert_eq!(s(1, true), s(1, false));
        // Unfused time is segment-independent (the epilogue waits out the
        // whole collective either way).
        assert!((s(4, false) - s(1, false)).abs() < 1e-12);
        // Fusion wins at every segment count >= 2, monotonically.
        for seg in [2usize, 4, 8] {
            assert!(s(seg, true) < s(seg, false), "seg={seg}");
        }
        assert!(s(4, true) <= s(2, true) + 1e-15);
        // The win is exactly the hidden epilogue share, layer for layer:
        // 2 collectives × n_layers × (epi − exposed).
        let c = Coster { node: node.clone(), model: model.clone(), int8_wire: true };
        let epi = epilogue_s(&node, &model, 4096);
        let hidden = epi - epilogue_exposed_s(c.ar_s(4096, 1), epi, 4, true);
        let want = model.n_layers as f64 * 2.0 * hidden;
        let got = s(4, false) - s(4, true);
        assert!(
            (got - want).abs() <= 1e-9 * want.max(1e-12),
            "hidden share mismatch: got {got}, want {want}"
        );
    }

    #[test]
    fn all_strategies_produce_valid_graphs() {
        for strat in Strategy::all() {
            let tl = run(&exp(strat));
            assert!(tl.makespan_s.is_finite() && tl.makespan_s > 0.0, "{strat}");
            // Every op executed exactly once.
            let g = build(&exp(strat));
            assert_eq!(tl.spans.len(), g.ops.len(), "{strat}");
        }
    }

    #[test]
    fn recovery_model_pinned() {
        // The PR-6 recovery cost model, pinned (DESIGN.md §14): these
        // exact values feed the BENCH_PR6.json sim_fault section.
        assert_eq!(iteration_deadline_s(0.03, 4.0), 0.12);
        // deadline 0.12 s + respawn 2 s + 512 tokens @ 20k tok/s.
        let r = recovery_s(0.12, 2.0, 512, 20_000.0);
        assert!((r - 2.1456).abs() < 1e-12, "{r}");
        let r = recovery_s(0.12, 2.0, 4096, 20_000.0);
        assert!((r - 2.3248).abs() < 1e-12, "{r}");
        // Fault-free limit: zero rate, zero overhead.
        assert_eq!(expected_overhead_frac(0.0, 0.03, 2.1456), 0.0);
        // rate·R / (iter + rate·R), exact.
        let f = expected_overhead_frac(1e-3, 0.03, 2.1456);
        let want = 1e-3 * 2.1456 / (0.03 + 1e-3 * 2.1456);
        assert!((f - want).abs() < 1e-15);
        // Overhead grows with both rate and context (replay length).
        assert!(
            expected_overhead_frac(1e-4, 0.03, 2.1456)
                < expected_overhead_frac(1e-3, 0.03, 2.1456)
        );
        assert!(
            expected_overhead_frac(1e-3, 0.03, 2.1456)
                < expected_overhead_frac(1e-3, 0.03, 2.3248)
        );
    }

    #[test]
    fn slo_model_pinned() {
        // The PR-7 overload cost model, pinned (DESIGN.md §15): these
        // exact values feed the BENCH_SLO.json sim_slo section.
        assert_eq!(slo_admitted_frac(0.5, 0.9), 1.0);
        assert_eq!(slo_admitted_frac(0.9, 0.9), 1.0);
        assert_eq!(slo_admitted_frac(2.0, 0.9), 0.45);
        // M/D/1 wait: rho 0.5 → 1.5 iterations total; clamped at
        // rho_max past saturation so TTFT stays finite.
        assert_eq!(slo_ttft_s(0.03, 0.5, 0.9), 0.03 * 1.5);
        let sat = slo_ttft_s(0.03, 0.9, 0.9);
        assert!((sat - 0.03 * 5.5).abs() < 1e-12, "{sat}");
        assert_eq!(slo_ttft_s(0.03, 2.0, 0.9), sat, "clamped past saturation");
        // Bounded TBT: budget off passes the unbounded time through;
        // budget on clamps it but never below the decode-only floor.
        assert_eq!(bounded_tbt_s(0.03, 0.2348, 0.0), 0.2348);
        assert_eq!(bounded_tbt_s(0.03, 0.2348, 0.05), 0.05);
        assert_eq!(bounded_tbt_s(0.03, 0.2348, 0.01), 0.03);
        // A budget looser than the unbounded iteration changes nothing.
        assert_eq!(bounded_tbt_s(0.02, 0.025, 0.05), 0.025);
    }

    #[test]
    fn budgeted_prefill_tokens_monotone_and_floored() {
        let node = NodeProfile::cpu_engine(2, None, 50.0);
        let model = ModelSpec::tiny_gqa();
        let candidates: Vec<usize> = (1..=8).map(|i| i * 16).collect();
        let pick = |budget_s: f64| {
            budgeted_prefill_tokens(
                &node,
                &model,
                crate::config::SplitPolicy::AttnBalanced,
                4,
                64,
                1,
                false,
                budget_s,
                &candidates,
            )
        };
        // A huge budget admits the largest candidate; a tiny one floors
        // at the smallest (anti-starvation) rather than returning zero.
        assert_eq!(pick(1e6), 128);
        assert_eq!(pick(1e-12), 16);
        // Monotone: more budget never means fewer tokens.
        let budgets = [1e-12, 1e-6, 1e-3, 0.1, 10.0, 1e6];
        let picks: Vec<usize> = budgets.iter().map(|&b| pick(b)).collect();
        for w in picks.windows(2) {
            assert!(w[0] <= w[1], "non-monotone: {picks:?}");
        }
    }

    #[test]
    fn rung_generalizations_reproduce_legacy_bool_exactly() {
        // The auto-tuner ranks (pp, tp) / (cp, tp) jointly with the wire
        // rung; the legacy bool entry points must stay bit-identical so
        // every older pin (BENCH_PR4 / BENCH_CP) is untouched.
        let node = NodeProfile::rtx4090(4);
        let model = ModelSpec::mha_30b();
        let link = node.link;
        for (b, q) in [(false, CommQuant::Fp16), (true, CommQuant::Int8)] {
            assert_eq!(
                pp_iteration_s(&node, &model, 4096, 4, 2, 2, &link, b),
                pp_iteration_rung_s(&node, &model, 4096, 4, 2, 2, &link, q),
            );
            assert_eq!(
                cp_iteration_s(&node, &model, 4096, 2, 2, &link, b),
                cp_iteration_rung_s(&node, &model, 4096, 2, 2, &link, q),
            );
        }
        // Walking down the ladder only shrinks the wire terms, so both
        // models are monotone non-increasing in LADDER order.
        let pp_ladder: Vec<f64> = CommQuant::LADDER
            .iter()
            .map(|&q| pp_iteration_rung_s(&node, &model, 4096, 4, 2, 2, &link, q))
            .collect();
        let cp_ladder: Vec<f64> = CommQuant::LADDER
            .iter()
            .map(|&q| cp_iteration_rung_s(&node, &model, 4096, 2, 2, &link, q))
            .collect();
        for w in pp_ladder.windows(2).chain(cp_ladder.windows(2)) {
            assert!(w[0] >= w[1], "ladder not monotone: {w:?}");
        }
    }

    #[test]
    fn deadline_bounds_recovery_under_cp_topologies() {
        // Coverage gap (PR 10): when the auto-tuner picks a cp > 1
        // topology, the fault deadline is taken over the *cp* iteration
        // time — recovery must stay bounded by the closed form
        // (slack + 1)·iter + respawn when replaying one full prompt at
        // the same topology's prefill throughput.
        let node = NodeProfile::rtx4090(4);
        let model = ModelSpec::mha_30b();
        let (prompt, slack, respawn) = (4096usize, 4.0f64, 2.0f64);
        for cp in [2usize, 4] {
            let tp = node.cards / cp;
            let iter = cp_iteration_s(&node, &model, prompt, cp, tp, &node.link, true);
            assert!(iter.is_finite() && iter > 0.0);
            let deadline = iteration_deadline_s(iter, slack);
            assert!((deadline - slack * iter).abs() < 1e-15);
            // Replaying the whole prompt at this topology's throughput
            // costs exactly one more iteration.
            let tok_s = prompt as f64 / iter;
            let rec = recovery_s(deadline, respawn, prompt, tok_s);
            let bound = (slack + 1.0) * iter + respawn;
            assert!((rec - bound).abs() < 1e-9, "cp={cp}: {rec} vs {bound}");
            // The overhead share at a realistic fault rate stays small —
            // the planner can treat cp topologies as recoverable.
            let frac = expected_overhead_frac(1e-3, iter, rec);
            assert!(frac < 0.05, "cp={cp}: overhead {frac}");
        }
        // Deadline ordering follows the iteration-time ordering, so
        // whichever (cp, tp) the planner ranks faster also detects faster.
        let i21 = cp_iteration_s(&node, &model, prompt, 2, 2, &node.link, true);
        let i41 = cp_iteration_s(&node, &model, prompt, 4, 1, &node.link, true);
        assert_eq!(
            iteration_deadline_s(i21, slack) < iteration_deadline_s(i41, slack),
            i21 < i41
        );
    }
}
