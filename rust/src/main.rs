//! `iso-serve` — leader entrypoint.
//!
//! Subcommands (see `iso-serve help`):
//!   serve     real engine (PJRT + ring collectives) on a synthetic trace
//!   table1    the paper's Table 1 from the calibrated simulator
//!   timeline  Figure-1 Gantt of one prefill
//!   sweep     reduction vs prompt length

use anyhow::{anyhow, bail, Result};

use iso::cli::{Cli, USAGE};
use iso::config::{
    parse_config_file, CommQuant, EngineConfig, SimExperiment, SplitPolicy, Strategy, Topology,
};
use iso::coordinator::Engine;
use iso::hw::NodeProfile;
use iso::model::ModelSpec;
use iso::report::{gantt, render_table1, table1, table1_csv};
use iso::sched::{reduction_vs_serial, run};
use iso::tune::{AnalyticProbe, MeasuredProfile};
use iso::workload::{LenDist, TraceGen};

fn main() -> Result<()> {
    let cli = Cli::from_env().map_err(|e| anyhow!(e))?;
    match cli.command.as_str() {
        "serve" => serve(&cli),
        "table1" => cmd_table1(&cli),
        "timeline" => timeline(&cli),
        "sweep" => sweep(&cli),
        "" | "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => {
            eprint!("unknown command {other:?}\n\n{USAGE}");
            std::process::exit(2);
        }
    }
}

fn strategy_flag(cli: &Cli) -> Result<Strategy> {
    let s = cli.get_or("strategy", "iso");
    Strategy::parse(&s).ok_or_else(|| anyhow!("bad --strategy {s:?}"))
}

fn serve(cli: &Cli) -> Result<()> {
    let mut cfg = if let Some(path) = cli.get("config") {
        let map = parse_config_file(std::path::Path::new(path)).map_err(|e| anyhow!(e))?;
        EngineConfig::from_map(&map).map_err(|e| anyhow!(e))?
    } else {
        EngineConfig::default()
    };
    if cli.has("strategy") {
        cfg.strategy = strategy_flag(cli)?;
    }
    // Deprecated per-axis aliases (kept byte-compatible). The canonical
    // spelling is `--topology ppP.tpT.cpC` (DESIGN.md §17); the note is
    // stderr-only and gated on --verbose so scripted stdout never moves.
    if cli.has("tp") {
        cfg.tp = cli.usize_or("tp", cfg.tp).map_err(|e| anyhow!(e))?;
        if cli.has("verbose") {
            eprintln!("note: --tp is deprecated; use --topology ppP.tpT.cpC");
        }
    }
    if cli.has("pp-stages") {
        cfg.pp_stages = cli.usize_or("pp-stages", cfg.pp_stages).map_err(|e| anyhow!(e))?;
        if cli.has("verbose") {
            eprintln!("note: --pp-stages is deprecated; use --topology ppP.tpT.cpC");
        }
    }
    if let Some(t) = cli.get("topology") {
        // Canonical wins over the deprecated aliases when both are given.
        let t: Topology = t.parse().map_err(|e| anyhow!("bad --topology: {e}"))?;
        cfg.pp_stages = t.pp;
        cfg.tp = t.tp;
        cfg.cp = t.cp;
    }
    if let Some(q) = cli.get("comm-quant") {
        cfg.comm_quant = CommQuant::parse(q).ok_or_else(|| anyhow!("bad --comm-quant {q:?}"))?;
    }
    if let Some(q) = cli.get("wire-precision") {
        cfg.wire_precision =
            Some(CommQuant::parse(q).ok_or_else(|| anyhow!("bad --wire-precision {q:?}"))?);
    }
    if let Some(q) = cli.get("decode-wire-precision") {
        cfg.decode_wire_precision = Some(
            CommQuant::parse(q).ok_or_else(|| anyhow!("bad --decode-wire-precision {q:?}"))?,
        );
    }
    if let Some(s) = cli.get("split") {
        cfg.split = SplitPolicy::parse(s).ok_or_else(|| anyhow!("bad --split {s:?}"))?;
    }
    if cli.has("decode-batch") {
        cfg.decode_batch = cli.usize_or("decode-batch", cfg.decode_batch).map_err(|e| anyhow!(e))?;
    }
    if let Some(m) = cli.get("mixed") {
        cfg.mixed_iterations =
            iso::config::parse_bool(m, "--mixed").map_err(|e| anyhow!(e))?;
    }
    if cli.has("spec-k") {
        cfg.spec_k = cli.usize_or("spec-k", cfg.spec_k).map_err(|e| anyhow!(e))?;
    }
    if cli.has("spec-ngram") {
        cfg.spec_ngram = cli.usize_or("spec-ngram", cfg.spec_ngram).map_err(|e| anyhow!(e))?;
    }
    if cli.has("comm-segments") {
        cfg.comm_segments =
            cli.usize_or("comm-segments", cfg.comm_segments).map_err(|e| anyhow!(e))?;
    }
    if let Some(v) = cli.get("fused-epilogue") {
        cfg.fused_epilogue =
            iso::config::parse_bool(v, "--fused-epilogue").map_err(|e| anyhow!(e))?;
    }
    if let Some(v) = cli.get("ladder-residual") {
        cfg.ladder_residual =
            iso::config::parse_bool(v, "--ladder-residual").map_err(|e| anyhow!(e))?;
    }
    if let Some(plan) = cli.get("fault-plan") {
        // Validate eagerly so a typo'd plan fails before engine start.
        iso::fault::FaultPlan::parse(plan).map_err(|e| anyhow!("bad --fault-plan: {e}"))?;
        cfg.fault_plan = Some(plan.to_string());
    }
    if cli.has("fault-slack") {
        let v = cli.get("fault-slack").unwrap();
        cfg.fault_slack = v.parse().map_err(|_| anyhow!("bad --fault-slack {v:?}"))?;
    }
    if cli.has("max-recoveries") {
        cfg.max_recoveries =
            cli.usize_or("max-recoveries", cfg.max_recoveries).map_err(|e| anyhow!(e))?;
    }
    if cli.has("tbt-budget-ms") {
        let v = cli.get("tbt-budget-ms").unwrap();
        cfg.tbt_budget_ms = v.parse().map_err(|_| anyhow!("bad --tbt-budget-ms {v:?}"))?;
    }
    if cli.has("kv-high-water") {
        let v = cli.get("kv-high-water").unwrap();
        cfg.kv_high_water = v.parse().map_err(|_| anyhow!("bad --kv-high-water {v:?}"))?;
    }
    if cli.has("queue-bound") {
        cfg.queue_bound = cli.usize_or("queue-bound", cfg.queue_bound).map_err(|e| anyhow!(e))?;
    }
    if cli.has("max-preemptions") {
        cfg.max_preemptions =
            cli.usize_or("max-preemptions", cfg.max_preemptions).map_err(|e| anyhow!(e))?;
    }
    if cli.has("ttft-deadline-ms") {
        let v = cli.get("ttft-deadline-ms").unwrap();
        cfg.ttft_deadline_ms = v.parse().map_err(|_| anyhow!("bad --ttft-deadline-ms {v:?}"))?;
    }
    if let Some(v) = cli.get("kv-offload") {
        cfg.kv_offload = iso::config::parse_bool(v, "--kv-offload").map_err(|e| anyhow!(e))?;
    }
    if cli.has("kv-resident-tokens") {
        cfg.kv_resident_tokens = cli
            .usize_or("kv-resident-tokens", cfg.kv_resident_tokens)
            .map_err(|e| anyhow!(e))?;
    }
    if cli.has("kv-prefetch-pages") {
        cfg.kv_prefetch_pages =
            cli.usize_or("kv-prefetch-pages", cfg.kv_prefetch_pages).map_err(|e| anyhow!(e))?;
    }
    let n_requests = cli.usize_or("requests", 8).map_err(|e| anyhow!(e))?;
    let prompt_len = cli.usize_or("prompt-len", 128).map_err(|e| anyhow!(e))?;
    let decode = cli.usize_or("decode", 0).map_err(|e| anyhow!(e))?;

    // --- Auto-tune (DESIGN.md §18): calibrate → plan, then either print
    // the ranked plan and stop (dry-run) or adopt the winner's knobs
    // before engine start. Absent the flag, stdout is byte-identical to
    // the hand-tuned path.
    let mut tuned: Option<String> = None;
    if let Some(mode) = cli.get("auto-tune") {
        if mode != "true" && mode != "dry-run" {
            bail!("bad --auto-tune {mode:?} (bare flag, or --auto-tune=dry-run)");
        }
        let (profile, cached) = tune_profile(cli, &cfg)?;
        let model_name = cli.get_or(
            "tune-model",
            if profile.node.device.name.starts_with("cpu-engine") { "tiny" } else { "30b" },
        );
        let model = ModelSpec::by_name(&model_name)
            .ok_or_else(|| anyhow!("bad --tune-model {model_name:?}"))?;
        let w = iso::tune::Workload {
            name: "serve".into(),
            prompt_len,
            decode_steps: decode,
            decode_ctx: prompt_len + decode,
            accept: 0.8,
        };
        let plan = iso::tune::plan(&profile.node, &model, &w);
        if mode == "dry-run" {
            print!("{}", plan.render(10));
            return Ok(());
        }
        let best = plan
            .best()
            .ok_or_else(|| anyhow!("auto-tune: every candidate was pruned"))?;
        let summary = format!(
            "{} predicted={:.2}ms profile={} ({})",
            best.summary,
            best.predicted_s * 1e3,
            profile.source,
            if cached { "cached" } else { "calibrated" },
        );
        // Adopt the planner's knobs; run-level scalars (requests, decode
        // steps, artifacts) stay the operator's.
        let picked = &best.cfg;
        cfg.pp_stages = picked.pp_stages;
        cfg.tp = picked.tp;
        cfg.cp = picked.cp;
        cfg.comm_segments = picked.comm_segments;
        cfg.decode_batch = picked.decode_batch;
        cfg.spec_k = picked.spec_k;
        cfg.fused_epilogue = picked.fused_epilogue;
        cfg.wire_precision = picked.wire_precision;
        cfg.decode_wire_precision = picked.decode_wire_precision;
        println!("auto_tune: {summary}");
        tuned = Some(summary);
    }

    // Opt-in banner suffix: " cp=N" only when the third axis is in play,
    // so cp=1 invocations keep byte-identical stdout (DESIGN.md §17).
    let cp_tag = if cfg.cp > 1 { format!(" cp={}", cfg.cp) } else { String::new() };
    println!(
        "engine: pp={} tp={}{cp_tag} strategy={} comm_quant={:?} mixed={} decode_batch={} \
         spec_k={} comm_segments={} fused_epilogue={} ladder_residual={} artifacts={}",
        cfg.pp_stages,
        cfg.tp,
        cfg.strategy,
        cfg.comm_quant,
        cfg.mixed_iterations,
        cfg.decode_batch,
        cfg.spec_k,
        cfg.comm_segments,
        cfg.fused_epilogue,
        cfg.ladder_residual,
        cfg.artifacts_dir
    );
    // Opt-in banner line: absent unless a precision override is set, so
    // legacy invocations keep byte-identical stdout (DESIGN.md §16).
    if cfg.wire_precision.is_some() || cfg.decode_wire_precision.is_some() {
        let p = cfg.precision();
        println!("wire_precision: prefill={} decode={}", p.prefill.label(), p.decode.label());
    }
    // Same rule for the cold-KV tier (DESIGN.md §17): silent unless on.
    if cfg.kv_offload {
        println!(
            "kv_offload: resident_tokens={} prefetch_pages={}",
            cfg.kv_resident_tokens, cfg.kv_prefetch_pages
        );
    }
    let mut engine = Engine::start(cfg)?;
    let vocab = engine.manifest.config.vocab;
    let max_seq = engine.manifest.config.max_seq;
    if prompt_len + decode > max_seq {
        bail!("prompt-len {prompt_len} + decode {decode} exceeds max_seq {max_seq}");
    }
    let rate: f64 = cli
        .get("rate")
        .map(|v| v.parse().map_err(|_| anyhow!("bad --rate {v:?}")))
        .transpose()?
        .unwrap_or(0.0);
    let mut tracegen =
        TraceGen::new(7, vocab, LenDist::Fixed(prompt_len)).decode_steps(decode).rate(rate);
    let reqs = tracegen.generate(n_requests);
    if rate > 0.0 {
        // Continuous batching over a paced arrival trace.
        let trace = engine.serve_trace(&reqs)?;
        let mut t = trace.clone();
        t.tuned = tuned.clone();
        println!(
            "completed {} requests in {} iterations, {:.0} tok/s; {}",
            trace.completed,
            trace.iterations,
            trace.throughput_tok_s(),
            t.ttft_ms.summary("ttft_from_arrival_ms"),
        );
        println!("{}", t.e2e_ms.summary("e2e_ms"));
        if !t.tbt_ms.is_empty() {
            println!("{}", t.tbt_ms.summary("tbt_ms"));
        }
        if !t.occupancy.is_empty() {
            println!("{}", t.occupancy.summary("iter_occupancy"));
        }
        // Opt-in banner (DESIGN.md §18): absent unless --auto-tune picked
        // the config, so hand-tuned invocations keep byte-identical stdout.
        if let Some(s) = &t.tuned {
            println!("tuned: {s}");
        }
    } else {
        for r in &reqs {
            let out = engine.generate(&r.prompt, r.decode_steps)?;
            println!(
                "req {:>3}: ttft {:>8.1}ms  tokens {:?}",
                r.id,
                out.ttft_ms,
                &out.tokens[..out.tokens.len().min(8)]
            );
        }
    }
    let report = engine.shutdown()?;
    let mut m = report.metrics;
    println!("\n{}", m.report());
    // Topology-aware rollup: flat per-rank lines for pp=1 (byte-identical
    // to the legacy report), stage-grouped for pipeline engines.
    print!(
        "{}",
        iso::report::worker_rollup_cp(&report.workers, report.pp_stages, report.tp, report.cp)
    );
    Ok(())
}

/// Resolve the hardware profile `--auto-tune` plans against: a named
/// preset (`--tune-profile 4090|a800` with `--tune-cards N`), else the
/// CPU engine testbed the real engine runs on, sized to the configured
/// rank grid and emulated link. `--profile-cache FILE` persists the
/// calibration (`tune::MeasuredProfile` JSON) across runs; without it
/// every invocation recalibrates. Returns the profile and whether it
/// came from the cache.
fn tune_profile(cli: &Cli, cfg: &EngineConfig) -> Result<(MeasuredProfile, bool)> {
    let node = if let Some(name) = cli.get("tune-profile") {
        let cards = cli.usize_or("tune-cards", 4).map_err(|e| anyhow!(e))?;
        NodeProfile::by_name(name, cards)
            .ok_or_else(|| anyhow!("bad --tune-profile {name:?} (4090|a800)"))?
    } else {
        NodeProfile::cpu_engine(cfg.topology().world(), cfg.link_mbps, cfg.link_alpha_us)
    };
    let probe = AnalyticProbe::new(node);
    if let Some(path) = cli.get("profile-cache") {
        MeasuredProfile::load_or_calibrate(std::path::Path::new(path), &probe)
            .map_err(|e| anyhow!(e))
    } else {
        Ok((iso::tune::calibrate(&probe), false))
    }
}

fn cmd_table1(cli: &Cli) -> Result<()> {
    let strategy = strategy_flag(cli)?;
    let rows = table1(strategy);
    print!(
        "{}",
        render_table1(
            &rows,
            &format!("% decrease in prefill duration vs serial — {strategy} (simulated)"),
        )
    );
    if let Some(path) = cli.get("csv") {
        std::fs::write(path, table1_csv(&rows))?;
        println!("wrote {path}");
    }
    Ok(())
}

fn node_from_flags(cli: &Cli) -> Result<(NodeProfile, ModelSpec)> {
    let model_name = cli.get_or("model", "30b");
    let model =
        ModelSpec::by_name(&model_name).ok_or_else(|| anyhow!("bad --model {model_name:?}"))?;
    // --hw-file points at a [hardware] config (see configs/hardware-*.conf)
    // for custom platforms; otherwise --gpu/--cards select a preset.
    let node = if let Some(path) = cli.get("hw-file") {
        let map = parse_config_file(std::path::Path::new(path)).map_err(|e| anyhow!(e))?;
        NodeProfile::from_map(&map).map_err(|e| anyhow!(e))?
    } else {
        let gpu = cli.get_or("gpu", "4090");
        let cards = cli.usize_or("cards", 4).map_err(|e| anyhow!(e))?;
        NodeProfile::by_name(&gpu, cards).ok_or_else(|| anyhow!("bad --gpu {gpu:?}"))?
    };
    Ok((node, model))
}

fn timeline(cli: &Cli) -> Result<()> {
    let (node, model) = node_from_flags(cli)?;
    let len = cli.usize_or("len", 8192).map_err(|e| anyhow!(e))?;
    let layers = cli.usize_or("layers", 3).map_err(|e| anyhow!(e))?;
    let strategy = strategy_flag(cli)?;
    let e = SimExperiment::new(node, model.clone(), len, strategy);
    let tl = run(&e);
    println!(
        "{strategy} on {}·{} cards, {} len {}: makespan {:.1}ms",
        e.node.device.name,
        e.node.cards,
        model.name,
        len,
        tl.makespan_s * 1e3
    );
    let until = tl.makespan_s / model.n_layers as f64 * layers as f64;
    print!("{}", gantt(&tl, 110, until));
    Ok(())
}

fn sweep(cli: &Cli) -> Result<()> {
    let (node, model) = node_from_flags(cli)?;
    let strategy = strategy_flag(cli)?;
    println!("reduction vs serial — {} on {}-{}", model.name, node.device.name, node.cards);
    for i in 0..8 {
        let len = 1024usize << i;
        let mut e = SimExperiment::new(node.clone(), model.clone(), len, strategy);
        e.gemm_segments = if node.device.name == "a800" { 4 } else { 1 };
        println!("{:>7}k  {:>6.1}%", len / 1024, reduction_vs_serial(&e) * 100.0);
    }
    Ok(())
}
