//! Transformer geometry and exact per-op cost accounting.
//!
//! The simulator consumes `(flops, gemm_rows, bytes)` per op — never
//! weights — so the paper-scale models are pure specs. Geometry follows
//! the paper's evaluation: a ~30B dense MHA model and a ~70B dense GQA
//! model (§4.1), with int8 weights/KV/GEMM and fp16 activations.

/// Transformer geometry (single model replica; TP divides it by `cards`).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelSpec {
    /// Spec name (`30b-mha`, `70b-gqa`, `tiny-gqa`).
    pub name: String,
    /// Residual width.
    pub d_model: usize,
    /// Query heads.
    pub n_heads: usize,
    /// KV heads (GQA shrinks this).
    pub n_kv_heads: usize,
    /// Per-head feature dimension.
    pub head_dim: usize,
    /// MLP hidden width.
    pub d_ff: usize,
    /// Transformer layers.
    pub n_layers: usize,
    /// Vocabulary size.
    pub vocab: usize,
    /// bytes per activation element on the wire *before* any comm quant
    /// (fp16 = 2, matching the paper's activation dtype).
    pub act_bytes: usize,
}

impl ModelSpec {
    /// ~30B dense MHA — LLaMA-30B-like geometry (paper's "30b (MHA)").
    pub fn mha_30b() -> Self {
        ModelSpec {
            name: "30b-mha".into(),
            d_model: 6656,
            n_heads: 52,
            n_kv_heads: 52,
            head_dim: 128,
            d_ff: 17920,
            n_layers: 60,
            vocab: 64000,
            act_bytes: 2,
        }
    }

    /// ~70B dense GQA — LLaMA-70B-like geometry (paper's "70b (GQA)").
    pub fn gqa_70b() -> Self {
        ModelSpec {
            name: "70b-gqa".into(),
            d_model: 8192,
            n_heads: 64,
            n_kv_heads: 8,
            head_dim: 128,
            d_ff: 28672,
            n_layers: 80,
            vocab: 64000,
            act_bytes: 2,
        }
    }

    /// The tiny real model the CPU engine actually executes (must match
    /// `python/compile/model.py::GQA_TINY`).
    pub fn tiny_gqa() -> Self {
        ModelSpec {
            name: "tiny-gqa".into(),
            d_model: 128,
            n_heads: 8,
            n_kv_heads: 4,
            head_dim: 16,
            d_ff: 512,
            n_layers: 4,
            vocab: 512,
            act_bytes: 4, // CPU engine keeps f32 activations
        }
    }

    /// Spec lookup (`30b` / `70b` / `tiny`).
    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "30b" | "30b-mha" => Some(Self::mha_30b()),
            "70b" | "70b-gqa" => Some(Self::gqa_70b()),
            "tiny" | "tiny-gqa" => Some(Self::tiny_gqa()),
            _ => None,
        }
    }

    /// Query projection width (`n_heads × head_dim`).
    pub fn q_dim(&self) -> usize {
        self.n_heads * self.head_dim
    }

    /// KV projection width (`n_kv_heads × head_dim`).
    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }

    /// Total parameter count (sanity check for the spec tables).
    pub fn param_count(&self) -> usize {
        let per_layer = self.d_model * (self.q_dim() + 2 * self.kv_dim()) // qkv
            + self.q_dim() * self.d_model                                // o_proj
            + 3 * self.d_model * self.d_ff                               // gate/up/down
            + 2 * self.d_model;                                          // norms
        2 * self.vocab * self.d_model + self.n_layers * per_layer + self.d_model
    }

    /// KV-cache bytes per token (int8 KV per the paper's quant setup).
    pub fn kv_bytes_per_token(&self, kv_quant_bytes: usize) -> usize {
        2 * self.kv_dim() * kv_quant_bytes * self.n_layers
    }
}

/// FLOPs and shape metadata for the compute ops of one layer over a chunk
/// of `t` tokens whose first token sits at absolute position `offset`.
/// All values are *whole-replica*; divide FLOPs by the TP degree for
/// per-device work (the sim does).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LayerChunkCost {
    /// qkv + o_proj GEMM flops (2*m*n*k convention).
    pub gemm_flops_attn: f64,
    /// gate/up/down GEMM flops.
    pub gemm_flops_mlp: f64,
    /// attention score+value flops (quadratic part, causal).
    pub attn_flops: f64,
    /// rows (m) of the chunk GEMMs — drives the efficiency curve.
    pub gemm_rows: usize,
    /// bytes all-reduced after attention / after MLP (pre-quant, fp16).
    pub ar_bytes: usize,
}

impl ModelSpec {
    /// Costs of one transformer layer on a chunk `[offset, offset+t)`.
    ///
    /// Causal attention over the KV cache: each query row `i` attends to
    /// `offset + i + 1` keys, so total attended keys = t*offset + t(t+1)/2.
    pub fn layer_chunk_cost(&self, t: usize, offset: usize) -> LayerChunkCost {
        let d = self.d_model as f64;
        let tf = t as f64;
        let qd = self.q_dim() as f64;
        let kvd = self.kv_dim() as f64;
        let ff = self.d_ff as f64;

        let qkv = 2.0 * tf * d * (qd + 2.0 * kvd);
        let o = 2.0 * tf * qd * d;
        let mlp = 3.0 * 2.0 * tf * d * ff;

        let attended = tf * offset as f64 + tf * (tf + 1.0) / 2.0;
        // score (q·k) + weighted value (p·v), over n_heads*head_dim each.
        let attn = 2.0 * 2.0 * attended * qd;

        LayerChunkCost {
            gemm_flops_attn: qkv + o,
            gemm_flops_mlp: mlp,
            attn_flops: attn,
            gemm_rows: t,
            ar_bytes: t * self.d_model * self.act_bytes,
        }
    }

    /// Whole-prefill flops for a prompt of `len` tokens (all layers).
    pub fn prefill_flops(&self, len: usize) -> f64 {
        let c = self.layer_chunk_cost(len, 0);
        self.n_layers as f64 * (c.gemm_flops_attn + c.gemm_flops_mlp + c.attn_flops)
    }

    /// FLOPs of the per-layer post-collective epilogue over `t` tokens
    /// (DESIGN.md §12): the residual add (1 FLOP/element) plus the next
    /// op's RMSNorm (≈2 FLOP/element square-accumulate + 2 FLOP/element
    /// rescale) — 5 per element of the `t × d_model` activation.
    /// Replicated on every rank (each applies its own copy), so callers
    /// do **not** divide by the TP degree.
    pub fn epilogue_flops(&self, t: usize) -> f64 {
        5.0 * t as f64 * self.d_model as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_near_nominal() {
        let p30 = ModelSpec::mha_30b().param_count() as f64 / 1e9;
        let p70 = ModelSpec::gqa_70b().param_count() as f64 / 1e9;
        assert!((30.0..36.0).contains(&p30), "30b spec has {p30}B params");
        assert!((65.0..72.0).contains(&p70), "70b spec has {p70}B params");
    }

    #[test]
    fn gqa_shrinks_kv() {
        let mha = ModelSpec::mha_30b();
        let gqa = ModelSpec::gqa_70b();
        assert_eq!(mha.kv_dim(), mha.q_dim());
        assert!(gqa.kv_dim() * 8 == gqa.q_dim());
        assert!(gqa.kv_bytes_per_token(1) < mha.kv_bytes_per_token(1));
    }

    #[test]
    fn by_name_lookup() {
        assert_eq!(ModelSpec::by_name("30b").unwrap().name, "30b-mha");
        assert_eq!(ModelSpec::by_name("70b-gqa").unwrap().name, "70b-gqa");
        assert_eq!(ModelSpec::by_name("tiny").unwrap().name, "tiny-gqa");
        assert!(ModelSpec::by_name("13b").is_none());
    }

    #[test]
    fn chunk_costs_additive_in_tokens() {
        // Splitting [0, 2t) into [0, t) + [t, 2t) preserves total flops —
        // the ISO split is work-conserving (paper §3.1).
        let m = ModelSpec::gqa_70b();
        let t = 1024;
        let full = m.layer_chunk_cost(2 * t, 0);
        let a = m.layer_chunk_cost(t, 0);
        let b = m.layer_chunk_cost(t, t);
        let sum_attn = a.attn_flops + b.attn_flops;
        assert!((full.attn_flops - sum_attn).abs() / full.attn_flops < 1e-12);
        let sum_gemm = a.gemm_flops_attn + b.gemm_flops_attn;
        assert!((full.gemm_flops_attn - sum_gemm).abs() / full.gemm_flops_attn < 1e-12);
        assert_eq!(full.ar_bytes, a.ar_bytes + b.ar_bytes);
    }

    #[test]
    fn second_chunk_attention_heavier() {
        // Paper §6: the latter half of the sequence does markedly more
        // attention work — the motivation for uneven splits.
        let m = ModelSpec::mha_30b();
        let a = m.layer_chunk_cost(2048, 0);
        let b = m.layer_chunk_cost(2048, 2048);
        assert!(b.attn_flops > 2.0 * a.attn_flops);
        assert_eq!(a.gemm_flops_mlp, b.gemm_flops_mlp); // MLP is position-free
    }

    #[test]
    fn ar_bytes_are_fp16_activations() {
        let m = ModelSpec::gqa_70b();
        let c = m.layer_chunk_cost(4096, 0);
        assert_eq!(c.ar_bytes, 4096 * 8192 * 2);
    }

    #[test]
    fn prefill_flops_scale_superlinearly() {
        let m = ModelSpec::mha_30b();
        let f1 = m.prefill_flops(1024);
        let f2 = m.prefill_flops(2048);
        assert!(f2 > 2.0 * f1); // quadratic attention term
        assert!(f2 < 4.0 * f1);
    }

    #[test]
    fn epilogue_flops_linear_in_tokens() {
        // The epilogue is elementwise over t × d_model: additive in the
        // split (work-conserving, like the layer costs) and linear in d.
        let m = ModelSpec::mha_30b();
        assert_eq!(m.epilogue_flops(2048), 2.0 * m.epilogue_flops(1024));
        assert_eq!(m.epilogue_flops(1), 5.0 * m.d_model as f64);
        assert_eq!(m.epilogue_flops(0), 0.0);
    }

    #[test]
    fn tiny_matches_python_config() {
        // Must agree with python/compile/model.py::GQA_TINY.
        let t = ModelSpec::tiny_gqa();
        assert_eq!(
            (t.d_model, t.n_heads, t.n_kv_heads, t.head_dim, t.d_ff, t.n_layers, t.vocab),
            (128, 8, 4, 16, 512, 4, 512)
        );
    }
}
