//! Symmetric per-row int8 quantization — the paper's §3.2 wire format.
//!
//! On the 4090 the all-reduced activations are converted fp16→int8 before
//! hitting the ring, dropping the communication share from ~75% to ~50%
//! (paper Fig 2a). This module is the rust half of that path; it matches
//! `python/compile/kernels/quant.py` (and `ref.quantize_int8_ref`)
//! bit-for-bit under round-half-to-even.
//!
//! Layout of a quantized block of `rows × cols` f32: `rows` f32 scales
//! followed by `rows*cols` int8 payload — 1 byte/element + 4 bytes/row,
//! i.e. ~4× smaller than f32 and ~2× smaller than fp16 wire formats.

/// Quantized rows: `scales.len() == rows`, `data.len() == rows * cols`.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantizedRows {
    /// Row count.
    pub rows: usize,
    /// Column count.
    pub cols: usize,
    /// Per-row dequantization scales.
    pub scales: Vec<f32>,
    /// int8 payload, row-major.
    pub data: Vec<i8>,
}

impl QuantizedRows {
    /// Wire size in bytes (scales + payload).
    pub fn wire_bytes(&self) -> usize {
        self.scales.len() * 4 + self.data.len()
    }
}

/// Round-half-to-even, matching jnp.round / IEEE default.
#[inline]
fn round_ties_even(x: f32) -> f32 {
    // f32::round_ties_even is stable since 1.77.
    x.round_ties_even()
}

/// Quantize `rows × cols` row-major f32 into int8 with per-row scales.
pub fn quantize_rows(x: &[f32], rows: usize, cols: usize) -> QuantizedRows {
    let mut scales = Vec::new();
    let mut data = Vec::new();
    quantize_rows_into(x, rows, cols, &mut scales, &mut data);
    QuantizedRows { rows, cols, scales, data }
}

/// Quantize into caller-provided buffers (cleared first) — the wire hot
/// path (`collective::BufferPool`): no allocation once the buffers have
/// grown to the working-set size. Scales are per-row, so the result for a
/// row does not depend on how rows are grouped into calls — quantizing a
/// payload segment-by-segment is bit-identical to quantizing it whole.
pub fn quantize_rows_into(
    x: &[f32],
    rows: usize,
    cols: usize,
    scales: &mut Vec<f32>,
    data: &mut Vec<i8>,
) {
    assert_eq!(x.len(), rows * cols, "shape mismatch");
    scales.clear();
    scales.reserve(rows);
    data.clear();
    data.resize(rows * cols, 0);
    for r in 0..rows {
        let row = &x[r * cols..(r + 1) * cols];
        let amax = row.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        let scale = amax / 127.0;
        scales.push(scale);
        if scale > 0.0 {
            let inv = 1.0 / scale;
            for (d, &v) in data[r * cols..(r + 1) * cols].iter_mut().zip(row) {
                *d = round_ties_even(v * inv).clamp(-127.0, 127.0) as i8;
            }
        }
    }
}

/// Dequantize back to f32 (lossy inverse of `quantize_rows`).
pub fn dequantize_rows(q: &QuantizedRows) -> Vec<f32> {
    let mut out = vec![0.0f32; q.rows * q.cols];
    for r in 0..q.rows {
        let s = q.scales[r];
        for (o, &d) in out[r * q.cols..(r + 1) * q.cols]
            .iter_mut()
            .zip(&q.data[r * q.cols..(r + 1) * q.cols])
        {
            *o = d as f32 * s;
        }
    }
    out
}

/// Dequantize-and-accumulate: `acc[i] += dequant(q)[i]` without the
/// intermediate vec — the all-reduce hot path (collective::ring).
pub fn dequantize_add(q: &QuantizedRows, acc: &mut [f32]) {
    assert_eq!(acc.len(), q.rows * q.cols);
    for r in 0..q.rows {
        let s = q.scales[r];
        if s == 0.0 {
            continue;
        }
        for (o, &d) in acc[r * q.cols..(r + 1) * q.cols]
            .iter_mut()
            .zip(&q.data[r * q.cols..(r + 1) * q.cols])
        {
            *o += d as f32 * s;
        }
    }
}

/// Dequantize into an existing buffer (overwrite) — the all-gather hot
/// path (no allocation).
pub fn dequantize_into(q: &QuantizedRows, out: &mut [f32]) {
    assert_eq!(out.len(), q.rows * q.cols);
    for r in 0..q.rows {
        let s = q.scales[r];
        for (o, &d) in out[r * q.cols..(r + 1) * q.cols]
            .iter_mut()
            .zip(&q.data[r * q.cols..(r + 1) * q.cols])
        {
            *o = d as f32 * s;
        }
    }
}

/// Max absolute error bound of one quantize/dequantize round trip:
/// half a quantization step per row.
pub fn max_roundtrip_error(q: &QuantizedRows) -> f32 {
    q.scales.iter().fold(0.0f32, |m, &s| m.max(s * 0.5))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{Prop, Rng};

    #[test]
    fn roundtrip_within_half_step() {
        let mut rng = Rng::new(3);
        let (rows, cols) = (16, 64);
        let x = rng.normal_vec(rows * cols, 2.0);
        let q = quantize_rows(&x, rows, cols);
        let back = dequantize_rows(&q);
        for r in 0..rows {
            let bound = q.scales[r] * 0.5 + 1e-7;
            for c in 0..cols {
                let err = (x[r * cols + c] - back[r * cols + c]).abs();
                assert!(err <= bound, "row {r} col {c}: err {err} > {bound}");
            }
        }
    }

    #[test]
    fn zero_rows_stay_zero() {
        let q = quantize_rows(&[0.0; 32], 4, 8);
        assert!(q.scales.iter().all(|&s| s == 0.0));
        assert!(q.data.iter().all(|&d| d == 0));
        assert!(dequantize_rows(&q).iter().all(|&v| v == 0.0));
    }

    #[test]
    fn extremes_map_to_127() {
        let x = [1.0f32, -1.0, 0.5, 0.0];
        let q = quantize_rows(&x, 1, 4);
        assert_eq!(q.data[0], 127);
        assert_eq!(q.data[1], -127);
        assert_eq!(q.data[3], 0);
    }

    #[test]
    fn wire_bytes_are_quarter_of_f32() {
        let q = quantize_rows(&vec![1.0; 128 * 256], 128, 256);
        let f32_bytes = 128 * 256 * 4;
        assert_eq!(q.wire_bytes(), 128 * 4 + 128 * 256);
        assert!((q.wire_bytes() as f64) < 0.27 * f32_bytes as f64);
    }

    #[test]
    fn quantize_into_clears_stale_buffers_and_matches() {
        let mut rng = Rng::new(21);
        let x = rng.normal_vec(6 * 10, 1.5);
        let q = quantize_rows(&x, 6, 10);
        let mut scales = vec![9.0f32; 3]; // stale contents must be cleared
        let mut data = vec![5i8; 100];
        quantize_rows_into(&x, 6, 10, &mut scales, &mut data);
        assert_eq!(scales, q.scales);
        assert_eq!(data, q.data);
    }

    #[test]
    fn quantize_segmentwise_matches_whole() {
        // Per-row scales ⇒ grouping rows into segments cannot change the
        // wire bytes (the collective's bit-identity invariant).
        let mut rng = Rng::new(23);
        let (rows, cols) = (13, 8);
        let x = rng.normal_vec(rows * cols, 2.0);
        let whole = quantize_rows(&x, rows, cols);
        let split = 5; // uneven on purpose
        let head = quantize_rows(&x[..split * cols], split, cols);
        let tail = quantize_rows(&x[split * cols..], rows - split, cols);
        assert_eq!(&whole.scales[..split], &head.scales[..]);
        assert_eq!(&whole.scales[split..], &tail.scales[..]);
        assert_eq!(&whole.data[..split * cols], &head.data[..]);
        assert_eq!(&whole.data[split * cols..], &tail.data[..]);
    }

    #[test]
    fn dequantize_add_equals_dequant_then_add() {
        let mut rng = Rng::new(7);
        let x = rng.normal_vec(8 * 16, 1.0);
        let q = quantize_rows(&x, 8, 16);
        let mut acc = rng.normal_vec(8 * 16, 1.0);
        let expect: Vec<f32> = acc
            .iter()
            .zip(dequantize_rows(&q))
            .map(|(a, b)| a + b)
            .collect();
        dequantize_add(&q, &mut acc);
        assert_eq!(acc, expect);
    }

    #[test]
    fn prop_roundtrip_error_bound() {
        Prop::new(11).cases(128).run("quant roundtrip bound", |rng| {
            let rows = rng.range(1, 20);
            let cols = rng.range(1, 130);
            let scale = rng.f32_range(1e-3, 100.0);
            let x = rng.normal_vec(rows * cols, scale);
            let q = quantize_rows(&x, rows, cols);
            let back = dequantize_rows(&q);
            for r in 0..rows {
                let bound = q.scales[r] * 0.5 + scale * 1e-5;
                for c in 0..cols {
                    let err = (x[r * cols + c] - back[r * cols + c]).abs();
                    if err > bound {
                        return Err(format!("err {err} > bound {bound}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_quantize_idempotent_on_grid() {
        // Values already on the int8 grid survive a round trip exactly.
        Prop::new(13).cases(64).run("idempotent on grid", |rng| {
            let cols = rng.range(1, 64);
            let scale = rng.f32_range(1e-2, 10.0) / 127.0;
            let mut x: Vec<f32> = (0..cols)
                .map(|_| (rng.range(0, 255) as i32 - 127) as f32 * scale)
                .collect();
            // Anchor the row's amax so the re-derived scale matches the
            // generating grid (idempotence only holds on a fixed grid).
            let anchor = rng.range(0, cols);
            x[anchor] = 127.0 * scale;
            let q = quantize_rows(&x, 1, cols);
            let back = dequantize_rows(&q);
            for (a, b) in x.iter().zip(&back) {
                if (a - b).abs() > scale * 1e-3 {
                    return Err(format!("{a} != {b}"));
                }
            }
            Ok(())
        });
    }
}
