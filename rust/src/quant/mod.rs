//! Wire-precision ladder — the paper's §3.2 wire format, extended.
//!
//! On the 4090 the all-reduced activations are converted fp16→int8 before
//! hitting the ring, dropping the communication share from ~75% to ~50%
//! (paper Fig 2a). This module is the rust half of that path; the int8
//! rung matches `python/compile/kernels/quant.py` (and
//! `ref.quantize_int8_ref`) bit-for-bit under round-half-to-even.
//!
//! PR 8 extends the ladder downward (ROADMAP item 3, DESIGN.md §16):
//!
//! * **int8** — symmetric per-row scales, 1 byte/element + 4 bytes/row;
//! * **fp8 (e5m2)** — software-emulated 1-5-2 floats, elementwise (the
//!   exponent travels in every byte, no scale vector), 1 byte/element;
//! * **int4** — symmetric per-row scales, two's-complement nibbles packed
//!   two per byte *per row*: `ceil(cols/2)` bytes/row + 4 bytes/row.
//!
//! Every rung keeps the collective's bit-exact-under-segmentation
//! invariant: scales are per-row (fp8 needs none) and int4 packing
//! restarts at each row boundary, so encoding a payload segment-by-
//! segment is bit-identical to encoding it whole.

/// Quantized rows: `scales.len() == rows`, `data.len() == rows * cols`.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantizedRows {
    /// Row count.
    pub rows: usize,
    /// Column count.
    pub cols: usize,
    /// Per-row dequantization scales.
    pub scales: Vec<f32>,
    /// int8 payload, row-major.
    pub data: Vec<i8>,
}

impl QuantizedRows {
    /// Wire size in bytes (scales + payload).
    pub fn wire_bytes(&self) -> usize {
        self.scales.len() * 4 + self.data.len()
    }
}

/// fp8-e5m2 rows: `data.len() == rows * cols`, elementwise codes — no
/// scale vector (each byte carries its own 5-bit exponent).
#[derive(Clone, Debug, PartialEq)]
pub struct Fp8Rows {
    /// Row count.
    pub rows: usize,
    /// Column count.
    pub cols: usize,
    /// e5m2 payload, row-major.
    pub data: Vec<u8>,
}

impl Fp8Rows {
    /// Wire size in bytes (payload only; fp8 has no scales).
    pub fn wire_bytes(&self) -> usize {
        self.data.len()
    }
}

/// int4 rows: per-row scales, nibbles packed two per byte per row —
/// `data.len() == rows * cols.div_ceil(2)`. Each row starts a fresh
/// byte (odd `cols` leaves the last high nibble zero), so slicing a
/// payload into row segments re-packs to identical bytes.
#[derive(Clone, Debug, PartialEq)]
pub struct Quant4Rows {
    /// Row count.
    pub rows: usize,
    /// Column count.
    pub cols: usize,
    /// Per-row dequantization scales.
    pub scales: Vec<f32>,
    /// Packed two's-complement nibbles, row-major, low nibble first.
    pub data: Vec<u8>,
}

impl Quant4Rows {
    /// Wire size in bytes (scales + packed payload, `ceil(cols/2)`/row).
    pub fn wire_bytes(&self) -> usize {
        self.scales.len() * 4 + self.data.len()
    }
}

/// Round-half-to-even, matching jnp.round / IEEE default.
#[inline]
fn round_ties_even(x: f32) -> f32 {
    // f32::round_ties_even is stable since 1.77.
    x.round_ties_even()
}

/// 2^e as f32 (e in the normal-exponent range).
#[inline]
fn exp2i(e: i32) -> f32 {
    f32::from_bits(((e + 127) as u32) << 23)
}

/// Per-row symmetric scale for a `grid_max`-step integer grid, guarding
/// the degenerate rows: an all-zero row, a denormal amax whose
/// reciprocal overflows to inf, or ±inf elements must never put NaN on
/// either side of the wire. Returns `(scale, 1/scale)`; scale 0 means
/// the row encodes — and decodes — as exact zeros. A non-finite amax
/// saturates to `f32::MAX`, so ±inf elements clamp to full scale.
fn row_scale(row: &[f32], grid_max: f32) -> (f32, f32) {
    // f32::max ignores a NaN operand, so NaN elements never poison amax.
    let mut amax = row.iter().fold(0.0f32, |m, v| m.max(v.abs()));
    if !amax.is_finite() {
        amax = f32::MAX;
    }
    let scale = amax / grid_max;
    let inv = 1.0 / scale;
    if scale > 0.0 && inv.is_finite() {
        (scale, inv)
    } else {
        (0.0, 0.0)
    }
}

/// Quantize `rows × cols` row-major f32 into int8 with per-row scales.
pub fn quantize_rows(x: &[f32], rows: usize, cols: usize) -> QuantizedRows {
    let mut scales = Vec::new();
    let mut data = Vec::new();
    quantize_rows_into(x, rows, cols, &mut scales, &mut data);
    QuantizedRows { rows, cols, scales, data }
}

/// Quantize into caller-provided buffers (cleared first) — the wire hot
/// path (`collective::BufferPool`): no allocation once the buffers have
/// grown to the working-set size. Scales are per-row, so the result for a
/// row does not depend on how rows are grouped into calls — quantizing a
/// payload segment-by-segment is bit-identical to quantizing it whole.
pub fn quantize_rows_into(
    x: &[f32],
    rows: usize,
    cols: usize,
    scales: &mut Vec<f32>,
    data: &mut Vec<i8>,
) {
    assert_eq!(x.len(), rows * cols, "shape mismatch");
    scales.clear();
    scales.reserve(rows);
    data.clear();
    data.resize(rows * cols, 0);
    for r in 0..rows {
        let row = &x[r * cols..(r + 1) * cols];
        let (scale, inv) = row_scale(row, 127.0);
        scales.push(scale);
        if scale > 0.0 {
            for (d, &v) in data[r * cols..(r + 1) * cols].iter_mut().zip(row) {
                *d = round_ties_even(v * inv).clamp(-127.0, 127.0) as i8;
            }
        }
    }
}

/// Dequantize back to f32 (lossy inverse of `quantize_rows`).
pub fn dequantize_rows(q: &QuantizedRows) -> Vec<f32> {
    let mut out = vec![0.0f32; q.rows * q.cols];
    dequantize_into(q, &mut out);
    out
}

/// Dequantize-and-accumulate: `acc[i] += dequant(q)[i]` without the
/// intermediate vec — the all-reduce hot path (collective::ring).
pub fn dequantize_add(q: &QuantizedRows, acc: &mut [f32]) {
    assert_eq!(acc.len(), q.rows * q.cols);
    for r in 0..q.rows {
        let s = q.scales[r];
        if s == 0.0 {
            continue;
        }
        for (o, &d) in acc[r * q.cols..(r + 1) * q.cols]
            .iter_mut()
            .zip(&q.data[r * q.cols..(r + 1) * q.cols])
        {
            *o += d as f32 * s;
        }
    }
}

/// Dequantize into an existing buffer (overwrite) — the all-gather hot
/// path (no allocation).
pub fn dequantize_into(q: &QuantizedRows, out: &mut [f32]) {
    assert_eq!(out.len(), q.rows * q.cols);
    for r in 0..q.rows {
        let s = q.scales[r];
        for (o, &d) in out[r * q.cols..(r + 1) * q.cols]
            .iter_mut()
            .zip(&q.data[r * q.cols..(r + 1) * q.cols])
        {
            *o = d as f32 * s;
        }
    }
}

/// Max absolute error bound of one quantize/dequantize round trip:
/// half a quantization step per row.
pub fn max_roundtrip_error(q: &QuantizedRows) -> f32 {
    q.scales.iter().fold(0.0f32, |m, &s| m.max(s * 0.5))
}

// ---------------------------------------------------------------- fp8 --

/// Largest finite e5m2 magnitude: 1.75 · 2^15. The encoder saturates
/// here (inf included) so adversarial magnitudes stay finite on the
/// wire.
pub const FP8_MAX: f32 = 57344.0;

/// Smallest positive e5m2 normal: 2^-14.
pub const FP8_MIN_NORMAL: f32 = 6.103_515_6e-5;

/// Relative round-trip error bound for e5m2 in the normal range: half a
/// 2-bit-mantissa ulp, 2^-3.
pub const FP8_REL_ERR: f32 = 0.125;

/// Absolute round-trip error bound below [`FP8_MIN_NORMAL`]: half the
/// denormal step, 2^-17.
pub const FP8_ABS_ERR: f32 = 7.629_394_5e-6;

/// e5m2 code of +[`FP8_MAX`] (exponent 30, mantissa 0b11).
const FP8_MAX_CODE: u8 = 0x7b;

/// Encode one f32 as e5m2 (1 sign / 5 exponent / 2 mantissa), round to
/// nearest ties-to-even, **saturating**: ±inf and magnitudes at or past
/// [`FP8_MAX`] encode as ±[`FP8_MAX`]; NaN keeps a NaN code. The
/// conversion is elementwise — no scales — so any row/segment grouping
/// of a payload encodes bit-identically.
pub fn fp8_from_f32(v: f32) -> u8 {
    let sign = ((v.to_bits() >> 24) & 0x80) as u8;
    if v.is_nan() {
        return sign | 0x7f;
    }
    let a = v.abs();
    if a >= FP8_MAX {
        return sign | FP8_MAX_CODE;
    }
    if a < FP8_MIN_NORMAL {
        // Denormal grid m · 2^-16, m ∈ 0..=3; m = 4 is exactly the
        // smallest normal and its code continues the sequence.
        return sign | round_ties_even(a * 65536.0) as u8;
    }
    let e = ((a.to_bits() >> 23) as i32) - 127; // unbiased, in [-14, 15]
    let m = round_ties_even(a / exp2i(e) * 4.0) as u32; // in [4, 8]
    // m = 8 carries into the exponent field: the encoding is monotone.
    sign | ((((e + 15) as u32) << 2) + (m - 4)) as u8
}

/// Decode one e5m2 byte (exact: every e5m2 value is representable in
/// f32). Inf/NaN codes decode faithfully — our encoder never emits them,
/// but a poisoned wire byte must not map to a silently-wrong finite.
pub fn fp8_to_f32(b: u8) -> f32 {
    let sign = if b & 0x80 != 0 { -1.0f32 } else { 1.0 };
    let exp = ((b >> 2) & 0x1f) as i32;
    let man = (b & 0x03) as f32;
    if exp == 31 {
        return if man == 0.0 { sign * f32::INFINITY } else { f32::NAN };
    }
    if exp == 0 {
        return sign * man * FP8_MIN_NORMAL * 0.25; // man · 2^-16
    }
    sign * (4.0 + man) * exp2i(exp - 17) // (1 + man/4) · 2^(exp-15)
}

/// Encode `rows × cols` f32 as e5m2.
pub fn fp8_encode_rows(x: &[f32], rows: usize, cols: usize) -> Fp8Rows {
    let mut data = Vec::new();
    fp8_encode_rows_into(x, rows, cols, &mut data);
    Fp8Rows { rows, cols, data }
}

/// Encode into a caller-provided buffer (cleared first) — the wire hot
/// path. Elementwise, so trivially bit-exact under segmentation.
pub fn fp8_encode_rows_into(x: &[f32], rows: usize, cols: usize, data: &mut Vec<u8>) {
    assert_eq!(x.len(), rows * cols, "shape mismatch");
    data.clear();
    data.reserve(rows * cols);
    data.extend(x.iter().map(|&v| fp8_from_f32(v)));
}

/// Decode back to f32 (lossy inverse of `fp8_encode_rows`).
pub fn fp8_decode_rows(q: &Fp8Rows) -> Vec<f32> {
    let mut out = vec![0.0f32; q.rows * q.cols];
    fp8_decode_into(q, &mut out);
    out
}

/// Decode-and-accumulate — the all-reduce hot path.
pub fn fp8_decode_add(q: &Fp8Rows, acc: &mut [f32]) {
    assert_eq!(acc.len(), q.rows * q.cols);
    for (o, &b) in acc.iter_mut().zip(&q.data) {
        *o += fp8_to_f32(b);
    }
}

/// Decode into an existing buffer (overwrite) — the all-gather hot path.
pub fn fp8_decode_into(q: &Fp8Rows, out: &mut [f32]) {
    assert_eq!(out.len(), q.rows * q.cols);
    for (o, &b) in out.iter_mut().zip(&q.data) {
        *o = fp8_to_f32(b);
    }
}

// --------------------------------------------------------------- int4 --

/// Quantize `rows × cols` row-major f32 into packed int4 with per-row
/// scales.
pub fn quantize4_rows(x: &[f32], rows: usize, cols: usize) -> Quant4Rows {
    let mut scales = Vec::new();
    let mut data = Vec::new();
    quantize4_rows_into(x, rows, cols, &mut scales, &mut data);
    Quant4Rows { rows, cols, scales, data }
}

/// Quantize into caller-provided buffers (cleared first). The grid is
/// symmetric ±7 (the -8 code is unused, keeping negation exact) and
/// packing restarts at every row, so segment-by-segment encoding is
/// bit-identical to whole-payload encoding.
pub fn quantize4_rows_into(
    x: &[f32],
    rows: usize,
    cols: usize,
    scales: &mut Vec<f32>,
    data: &mut Vec<u8>,
) {
    assert_eq!(x.len(), rows * cols, "shape mismatch");
    let rb = cols.div_ceil(2);
    scales.clear();
    scales.reserve(rows);
    data.clear();
    data.resize(rows * rb, 0);
    for r in 0..rows {
        let row = &x[r * cols..(r + 1) * cols];
        let (scale, inv) = row_scale(row, 7.0);
        scales.push(scale);
        if scale == 0.0 {
            continue;
        }
        let packed = &mut data[r * rb..(r + 1) * rb];
        for (c, &v) in row.iter().enumerate() {
            let q = round_ties_even(v * inv).clamp(-7.0, 7.0) as i8;
            let nib = (q as u8) & 0x0f;
            if c % 2 == 0 {
                packed[c / 2] = nib;
            } else {
                packed[c / 2] |= nib << 4;
            }
        }
    }
}

/// Unpack one nibble (low first) and sign-extend it.
#[inline]
fn nib4(packed: &[u8], c: usize) -> i8 {
    let nib = (packed[c / 2] >> ((c % 2) * 4)) & 0x0f;
    ((nib << 4) as i8) >> 4
}

/// Dequantize back to f32 (lossy inverse of `quantize4_rows`).
pub fn dequantize4_rows(q: &Quant4Rows) -> Vec<f32> {
    let mut out = vec![0.0f32; q.rows * q.cols];
    dequantize4_into(q, &mut out);
    out
}

/// Dequantize-and-accumulate — the all-reduce hot path.
pub fn dequantize4_add(q: &Quant4Rows, acc: &mut [f32]) {
    assert_eq!(acc.len(), q.rows * q.cols);
    let rb = q.cols.div_ceil(2);
    for r in 0..q.rows {
        let s = q.scales[r];
        if s == 0.0 {
            continue;
        }
        let packed = &q.data[r * rb..(r + 1) * rb];
        for (c, o) in acc[r * q.cols..(r + 1) * q.cols].iter_mut().enumerate() {
            *o += nib4(packed, c) as f32 * s;
        }
    }
}

/// Dequantize into an existing buffer (overwrite) — the all-gather hot
/// path.
pub fn dequantize4_into(q: &Quant4Rows, out: &mut [f32]) {
    assert_eq!(out.len(), q.rows * q.cols);
    let rb = q.cols.div_ceil(2);
    for r in 0..q.rows {
        let s = q.scales[r];
        let packed = &q.data[r * rb..(r + 1) * rb];
        for (c, o) in out[r * q.cols..(r + 1) * q.cols].iter_mut().enumerate() {
            *o = nib4(packed, c) as f32 * s;
        }
    }
}

/// Max absolute error bound of one int4 round trip: half a step per row.
pub fn max_roundtrip_error4(q: &Quant4Rows) -> f32 {
    q.scales.iter().fold(0.0f32, |m, &s| m.max(s * 0.5))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{Prop, Rng};

    #[test]
    fn roundtrip_within_half_step() {
        let mut rng = Rng::new(3);
        let (rows, cols) = (16, 64);
        let x = rng.normal_vec(rows * cols, 2.0);
        let q = quantize_rows(&x, rows, cols);
        let back = dequantize_rows(&q);
        for r in 0..rows {
            let bound = q.scales[r] * 0.5 + 1e-7;
            for c in 0..cols {
                let err = (x[r * cols + c] - back[r * cols + c]).abs();
                assert!(err <= bound, "row {r} col {c}: err {err} > {bound}");
            }
        }
    }

    #[test]
    fn zero_rows_stay_zero() {
        let q = quantize_rows(&[0.0; 32], 4, 8);
        assert!(q.scales.iter().all(|&s| s == 0.0));
        assert!(q.data.iter().all(|&d| d == 0));
        assert!(dequantize_rows(&q).iter().all(|&v| v == 0.0));
    }

    #[test]
    fn extremes_map_to_127() {
        let x = [1.0f32, -1.0, 0.5, 0.0];
        let q = quantize_rows(&x, 1, 4);
        assert_eq!(q.data[0], 127);
        assert_eq!(q.data[1], -127);
        assert_eq!(q.data[3], 0);
    }

    #[test]
    fn wire_bytes_are_quarter_of_f32() {
        let q = quantize_rows(&vec![1.0; 128 * 256], 128, 256);
        let f32_bytes = 128 * 256 * 4;
        assert_eq!(q.wire_bytes(), 128 * 4 + 128 * 256);
        assert!((q.wire_bytes() as f64) < 0.27 * f32_bytes as f64);
    }

    #[test]
    fn quantize_into_clears_stale_buffers_and_matches() {
        let mut rng = Rng::new(21);
        let x = rng.normal_vec(6 * 10, 1.5);
        let q = quantize_rows(&x, 6, 10);
        let mut scales = vec![9.0f32; 3]; // stale contents must be cleared
        let mut data = vec![5i8; 100];
        quantize_rows_into(&x, 6, 10, &mut scales, &mut data);
        assert_eq!(scales, q.scales);
        assert_eq!(data, q.data);
    }

    #[test]
    fn quantize_segmentwise_matches_whole() {
        // Per-row scales ⇒ grouping rows into segments cannot change the
        // wire bytes (the collective's bit-identity invariant).
        let mut rng = Rng::new(23);
        let (rows, cols) = (13, 8);
        let x = rng.normal_vec(rows * cols, 2.0);
        let whole = quantize_rows(&x, rows, cols);
        let split = 5; // uneven on purpose
        let head = quantize_rows(&x[..split * cols], split, cols);
        let tail = quantize_rows(&x[split * cols..], rows - split, cols);
        assert_eq!(&whole.scales[..split], &head.scales[..]);
        assert_eq!(&whole.scales[split..], &tail.scales[..]);
        assert_eq!(&whole.data[..split * cols], &head.data[..]);
        assert_eq!(&whole.data[split * cols..], &tail.data[..]);
    }

    #[test]
    fn dequantize_add_equals_dequant_then_add() {
        let mut rng = Rng::new(7);
        let x = rng.normal_vec(8 * 16, 1.0);
        let q = quantize_rows(&x, 8, 16);
        let mut acc = rng.normal_vec(8 * 16, 1.0);
        let expect: Vec<f32> = acc
            .iter()
            .zip(dequantize_rows(&q))
            .map(|(a, b)| a + b)
            .collect();
        dequantize_add(&q, &mut acc);
        assert_eq!(acc, expect);
    }

    #[test]
    fn prop_roundtrip_error_bound() {
        Prop::new(11).cases(128).run("quant roundtrip bound", |rng| {
            let rows = rng.range(1, 20);
            let cols = rng.range(1, 130);
            let scale = rng.f32_range(1e-3, 100.0);
            let x = rng.normal_vec(rows * cols, scale);
            let q = quantize_rows(&x, rows, cols);
            let back = dequantize_rows(&q);
            for r in 0..rows {
                let bound = q.scales[r] * 0.5 + scale * 1e-5;
                for c in 0..cols {
                    let err = (x[r * cols + c] - back[r * cols + c]).abs();
                    if err > bound {
                        return Err(format!("err {err} > bound {bound}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_quantize_idempotent_on_grid() {
        // Values already on the int8 grid survive a round trip exactly.
        Prop::new(13).cases(64).run("idempotent on grid", |rng| {
            let cols = rng.range(1, 64);
            let scale = rng.f32_range(1e-2, 10.0) / 127.0;
            let mut x: Vec<f32> = (0..cols)
                .map(|_| (rng.range(0, 255) as i32 - 127) as f32 * scale)
                .collect();
            // Anchor the row's amax so the re-derived scale matches the
            // generating grid (idempotence only holds on a fixed grid).
            let anchor = rng.range(0, cols);
            x[anchor] = 127.0 * scale;
            let q = quantize_rows(&x, 1, cols);
            let back = dequantize_rows(&q);
            for (a, b) in x.iter().zip(&back) {
                if (a - b).abs() > scale * 1e-3 {
                    return Err(format!("{a} != {b}"));
                }
            }
            Ok(())
        });
    }

    // ---- degenerate-row regression (ISSUE-8 satellite) ----

    #[test]
    fn degenerate_rows_never_nan() {
        // Row 0: all zero. Row 1: single-ULP denormal amax — the scale
        // itself underflows to 0. Row 2: a denormal amax whose scale
        // stays positive but whose reciprocal overflows to inf — this
        // used to turn the row into NaN-saturated garbage. Row 3:
        // ±inf-adjacent (f32::MAX) must quantize normally. Row 4: actual
        // ±inf saturates to full scale instead of poisoning the scale.
        let tiny = f32::from_bits(1); // smallest positive denormal
        let denorm = f32::from_bits(1000); // scale > 0 but 1/scale = inf
        let x = [
            [0.0f32, 0.0, 0.0, 0.0],
            [tiny, -tiny, 0.0, tiny],
            [denorm, -denorm, 0.0, denorm],
            [f32::MAX, -f32::MAX, 0.5, 0.0],
            [f32::INFINITY, f32::NEG_INFINITY, 1.0, 0.0],
        ]
        .concat();
        let q = quantize_rows(&x, 5, 4);
        assert_eq!(q.scales[0], 0.0);
        assert_eq!(q.scales[1], 0.0, "underflowed scale must stay 0");
        assert_eq!(q.scales[2], 0.0, "denormal amax must collapse to scale 0");
        assert!(q.data[4..12].iter().all(|&d| d == 0));
        assert!(q.scales[3] > 0.0 && q.scales[3].is_finite());
        assert_eq!(q.data[12], 127);
        assert_eq!(q.data[13], -127);
        assert!(q.scales[4].is_finite(), "inf row must saturate, not poison");
        assert_eq!(q.data[16], 127);
        assert_eq!(q.data[17], -127);
        let back = dequantize_rows(&q);
        assert!(back.iter().all(|v| v.is_finite()), "NaN/inf leaked: {back:?}");

        let q4 = quantize4_rows(&x, 5, 4);
        assert_eq!(q4.scales[0], 0.0);
        assert_eq!(q4.scales[1], 0.0);
        assert_eq!(q4.scales[2], 0.0);
        assert!(q4.scales[4].is_finite());
        let back4 = dequantize4_rows(&q4);
        assert!(back4.iter().all(|v| v.is_finite()), "int4 NaN/inf leaked: {back4:?}");

        let q8f = fp8_encode_rows(&x, 5, 4);
        let backf = fp8_decode_rows(&q8f);
        assert!(backf.iter().all(|v| v.is_finite()), "fp8 NaN/inf leaked: {backf:?}");
        assert_eq!(backf[16], FP8_MAX, "fp8 saturates inf to max finite");
    }

    #[test]
    fn nan_input_rows_stay_finite() {
        // Garbage in, finite out: NaN elements encode as 0 on every rung
        // and never poison the row's scale.
        let x = [f32::NAN, 1.0, -1.0, f32::NAN];
        let q = quantize_rows(&x, 1, 4);
        assert!(q.scales[0].is_finite());
        assert!(dequantize_rows(&q).iter().all(|v| v.is_finite()));
        let q4 = quantize4_rows(&x, 1, 4);
        assert!(dequantize4_rows(&q4).iter().all(|v| v.is_finite()));
    }

    // ---- fp8 (e5m2) ----

    #[test]
    fn fp8_exact_on_representable_values() {
        // Every e5m2 code round-trips f32 → fp8 → f32 exactly (the
        // codec is a bijection on its own grid).
        for code in 0u16..=255 {
            let b = code as u8;
            let v = fp8_to_f32(b);
            if !v.is_finite() {
                continue; // inf/NaN codes are never emitted by encode
            }
            let back = fp8_to_f32(fp8_from_f32(v));
            assert_eq!(back.to_bits(), v.to_bits(), "code {b:#x}: {v} -> {back}");
        }
    }

    #[test]
    fn fp8_saturates_and_preserves_sign_and_zero() {
        assert_eq!(fp8_to_f32(fp8_from_f32(f32::INFINITY)), FP8_MAX);
        assert_eq!(fp8_to_f32(fp8_from_f32(f32::NEG_INFINITY)), -FP8_MAX);
        assert_eq!(fp8_to_f32(fp8_from_f32(1e9)), FP8_MAX);
        assert_eq!(fp8_to_f32(fp8_from_f32(0.0)), 0.0);
        assert_eq!(fp8_to_f32(fp8_from_f32(-0.0)), 0.0);
        assert!(fp8_to_f32(fp8_from_f32(f32::NAN)).is_nan());
        // Below half the smallest denormal flushes to zero.
        assert_eq!(fp8_to_f32(fp8_from_f32(1e-9)), 0.0);
    }

    #[test]
    fn fp8_rounds_ties_to_even() {
        // Midpoint between 1.0 (code exp=15,m=0) and 1.25 (m=1) is
        // 1.125 → ties to even mantissa (1.0). Midpoint 1.375 → 1.5.
        assert_eq!(fp8_to_f32(fp8_from_f32(1.125)), 1.0);
        assert_eq!(fp8_to_f32(fp8_from_f32(1.375)), 1.5);
        assert_eq!(fp8_to_f32(fp8_from_f32(-1.125)), -1.0);
    }

    #[test]
    fn prop_fp8_relative_error_bound() {
        Prop::new(17).cases(128).run("fp8 rel error", |rng| {
            let n = rng.range(1, 200);
            let scale = rng.f32_range(1e-3, 1000.0);
            let x = rng.normal_vec(n, scale);
            for &v in &x {
                let back = fp8_to_f32(fp8_from_f32(v));
                let bound = (v.abs() * FP8_REL_ERR).max(FP8_ABS_ERR);
                if (v - back).abs() > bound {
                    return Err(format!("{v} -> {back}, bound {bound}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_fp8_monotone() {
        // Encoding preserves order — a sanity property that catches
        // exponent/mantissa packing mistakes.
        Prop::new(19).cases(128).run("fp8 monotone", |rng| {
            let a = rng.f32_range(-60000.0, 60000.0);
            let b = rng.f32_range(-60000.0, 60000.0);
            let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
            let (dl, dh) = (fp8_to_f32(fp8_from_f32(lo)), fp8_to_f32(fp8_from_f32(hi)));
            if dl <= dh {
                Ok(())
            } else {
                Err(format!("{lo}->{dl} > {hi}->{dh}"))
            }
        });
    }

    #[test]
    fn fp8_segmentwise_matches_whole() {
        let mut rng = Rng::new(29);
        let (rows, cols) = (9, 12);
        let x = rng.normal_vec(rows * cols, 3.0);
        let whole = fp8_encode_rows(&x, rows, cols);
        let head = fp8_encode_rows(&x[..4 * cols], 4, cols);
        let tail = fp8_encode_rows(&x[4 * cols..], rows - 4, cols);
        assert_eq!(&whole.data[..4 * cols], &head.data[..]);
        assert_eq!(&whole.data[4 * cols..], &tail.data[..]);
    }

    // ---- int4 ----

    #[test]
    fn int4_extremes_and_zero() {
        let x = [1.0f32, -1.0, 0.5, 0.0];
        let q = quantize4_rows(&x, 1, 4);
        let back = dequantize4_rows(&q);
        assert_eq!(back[0], 1.0);
        assert_eq!(back[1], -1.0);
        assert_eq!(back[3], 0.0);
        assert_eq!(q.data.len(), 2); // 4 nibbles packed into 2 bytes
    }

    #[test]
    fn int4_odd_cols_pack_per_row() {
        // 3 cols → 2 bytes per row; the dangling high nibble stays 0, so
        // rows never share a byte and wire bytes are rows·ceil(cols/2).
        let x = [1.0f32, -1.0, 0.25, 2.0, 0.5, -2.0];
        let q = quantize4_rows(&x, 2, 3);
        assert_eq!(q.data.len(), 4);
        assert_eq!(q.wire_bytes(), 2 * 4 + 4);
        assert_eq!(q.data[1] >> 4, 0, "row 0 dangling nibble");
        assert_eq!(q.data[3] >> 4, 0, "row 1 dangling nibble");
    }

    #[test]
    fn int4_segmentwise_matches_whole() {
        let mut rng = Rng::new(31);
        let (rows, cols) = (11, 7); // odd cols on purpose
        let x = rng.normal_vec(rows * cols, 2.0);
        let whole = quantize4_rows(&x, rows, cols);
        let split = 4;
        let head = quantize4_rows(&x[..split * cols], split, cols);
        let tail = quantize4_rows(&x[split * cols..], rows - split, cols);
        assert_eq!(&whole.scales[..split], &head.scales[..]);
        assert_eq!(&whole.scales[split..], &tail.scales[..]);
        let rb = cols.div_ceil(2);
        assert_eq!(&whole.data[..split * rb], &head.data[..]);
        assert_eq!(&whole.data[split * rb..], &tail.data[..]);
    }

    #[test]
    fn int4_add_equals_decode_then_add() {
        let mut rng = Rng::new(37);
        let x = rng.normal_vec(5 * 9, 1.0);
        let q = quantize4_rows(&x, 5, 9);
        let mut acc = rng.normal_vec(5 * 9, 1.0);
        let expect: Vec<f32> =
            acc.iter().zip(dequantize4_rows(&q)).map(|(a, b)| a + b).collect();
        dequantize4_add(&q, &mut acc);
        assert_eq!(acc, expect);
    }

    #[test]
    fn prop_int4_roundtrip_error_bound() {
        Prop::new(41).cases(128).run("int4 roundtrip bound", |rng| {
            let rows = rng.range(1, 12);
            let cols = rng.range(1, 40);
            let scale = rng.f32_range(1e-3, 100.0);
            let x = rng.normal_vec(rows * cols, scale);
            let q = quantize4_rows(&x, rows, cols);
            let back = dequantize4_rows(&q);
            for r in 0..rows {
                let bound = q.scales[r] * 0.5 + scale * 1e-5;
                for c in 0..cols {
                    let err = (x[r * cols + c] - back[r * cols + c]).abs();
                    if err > bound {
                        return Err(format!("err {err} > bound {bound}"));
                    }
                }
            }
            Ok(())
        });
    }
}
