//! Workload generation: prompt-length distributions, Poisson arrivals,
//! and request traces for the engine examples and benches.
//!
//! The paper evaluates batch-size-1 prefill at fixed prompt lengths
//! (Table 1); the serving examples additionally exercise realistic mixed
//! traffic, for which we provide lognormal-ish length mixtures and
//! Poisson arrivals (the standard serving-benchmark setup).

use crate::util::Rng;

/// One inference request.
#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    /// Request id (unique within a trace).
    pub id: u64,
    /// Arrival time in seconds from trace start.
    pub arrival_s: f64,
    /// Prompt token ids.
    pub prompt: Vec<i32>,
    /// Decode steps requested after prefill.
    pub decode_steps: usize,
}

/// Prompt-length distribution.
#[derive(Clone, Copy, Debug)]
pub enum LenDist {
    /// Every prompt exactly n tokens (Table-1 style).
    Fixed(usize),
    /// Uniform in [lo, hi].
    Uniform(usize, usize),
    /// Mixture: short chats + long documents (serving-realistic).
    Bimodal { short: usize, long: usize, long_frac: f64 },
    /// Heavy-tailed lognormal: `exp(mu + sigma·N(0,1))`, clamped to
    /// `[2, cap]`. The overload sweep's long-prompt regime: most
    /// prompts are short, a deterministic seeded tail is huge.
    Lognormal { mu: f64, sigma: f64, cap: usize },
}

impl LenDist {
    /// Draw one prompt length.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        match *self {
            LenDist::Fixed(n) => n,
            LenDist::Uniform(lo, hi) => rng.range(lo, hi + 1),
            LenDist::Bimodal { short, long, long_frac } => {
                if rng.f64() < long_frac {
                    // jitter ±25% around the mode
                    let j = 0.75 + rng.f64() * 0.5;
                    ((long as f64 * j) as usize).max(2)
                } else {
                    let j = 0.75 + rng.f64() * 0.5;
                    ((short as f64 * j) as usize).max(2)
                }
            }
            LenDist::Lognormal { mu, sigma, cap } => {
                let len = (mu + sigma * rng.normal()).exp();
                (len as usize).clamp(2, cap.max(2))
            }
        }
    }
}

/// Trace generator.
#[derive(Clone, Debug)]
pub struct TraceGen {
    /// Deterministic source of lengths/tokens/arrivals.
    pub rng: Rng,
    /// Vocabulary to draw prompt tokens from.
    pub vocab: usize,
    /// Prompt-length distribution.
    pub lens: LenDist,
    /// Mean arrival rate (requests/second); 0 = all arrive at t=0.
    pub rate: f64,
    /// Decode steps attached to every request.
    pub decode_steps: usize,
}

impl TraceGen {
    /// A generator over `vocab` with the given length distribution.
    pub fn new(seed: u64, vocab: usize, lens: LenDist) -> Self {
        TraceGen { rng: Rng::new(seed), vocab, lens, rate: 0.0, decode_steps: 0 }
    }

    /// Set the Poisson arrival rate (builder style).
    pub fn rate(mut self, r: f64) -> Self {
        self.rate = r;
        self
    }

    /// Set decode steps per request (builder style).
    pub fn decode_steps(mut self, n: usize) -> Self {
        self.decode_steps = n;
        self
    }

    /// Generate `n` requests.
    pub fn generate(&mut self, n: usize) -> Vec<Request> {
        let mut t = 0.0;
        (0..n)
            .map(|i| {
                if self.rate > 0.0 {
                    t += self.rng.exponential(self.rate);
                }
                let len = self.lens.sample(&mut self.rng);
                let prompt =
                    (0..len).map(|_| self.rng.below(self.vocab as u64) as i32).collect();
                Request {
                    id: i as u64,
                    arrival_s: t,
                    prompt,
                    decode_steps: self.decode_steps,
                }
            })
            .collect()
    }
}

/// Round `len` up to a multiple of `chunk` (engine prompts must tile into
/// compiled chunk sizes).
pub fn pad_to_chunk(len: usize, chunk: usize) -> usize {
    len.div_ceil(chunk) * chunk
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_lengths() {
        let mut g = TraceGen::new(1, 512, LenDist::Fixed(96));
        let reqs = g.generate(10);
        assert!(reqs.iter().all(|r| r.prompt.len() == 96));
        assert!(reqs.iter().all(|r| r.arrival_s == 0.0));
    }

    #[test]
    fn uniform_lengths_in_range() {
        let mut g = TraceGen::new(2, 512, LenDist::Uniform(10, 20));
        for r in g.generate(200) {
            assert!((10..=20).contains(&r.prompt.len()));
        }
    }

    #[test]
    fn bimodal_mixes_modes() {
        let mut g = TraceGen::new(3, 512, LenDist::Bimodal { short: 32, long: 512, long_frac: 0.3 });
        let reqs = g.generate(500);
        let longs = reqs.iter().filter(|r| r.prompt.len() > 128).count();
        assert!((100..250).contains(&longs), "got {longs} long prompts");
    }

    #[test]
    fn lognormal_heavy_tail_clamped_and_deterministic() {
        let dist = LenDist::Lognormal { mu: 4.0, sigma: 1.2, cap: 4096 };
        let mut g = TraceGen::new(11, 512, dist);
        let reqs = g.generate(1000);
        assert!(reqs.iter().all(|r| (2..=4096).contains(&r.prompt.len())));
        // Heavy tail: median near exp(4)≈55, but a real fraction lands
        // far above it — the regime that stresses bounded prefill.
        let median_ish = reqs.iter().filter(|r| r.prompt.len() <= 64).count();
        let tail = reqs.iter().filter(|r| r.prompt.len() >= 512).count();
        assert!(median_ish > 400, "body too thin: {median_ish}");
        assert!(tail > 10, "tail too thin: {tail}");
        // Seeded: same seed, same trace.
        let again = TraceGen::new(11, 512, dist).generate(1000);
        assert_eq!(reqs, again);
    }

    #[test]
    fn poisson_arrivals_monotone_with_mean_rate() {
        let mut g = TraceGen::new(4, 512, LenDist::Fixed(8)).rate(10.0);
        let reqs = g.generate(2000);
        let mut last = 0.0;
        for r in &reqs {
            assert!(r.arrival_s >= last);
            last = r.arrival_s;
        }
        let span = reqs.last().unwrap().arrival_s;
        let rate = 2000.0 / span;
        assert!((8.0..12.0).contains(&rate), "empirical rate {rate}");
    }

    #[test]
    fn tokens_within_vocab() {
        let mut g = TraceGen::new(5, 100, LenDist::Fixed(50));
        for r in g.generate(20) {
            assert!(r.prompt.iter().all(|&t| (0..100).contains(&t)));
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = TraceGen::new(7, 512, LenDist::Uniform(5, 50)).generate(20);
        let b = TraceGen::new(7, 512, LenDist::Uniform(5, 50)).generate(20);
        assert_eq!(a, b);
    }

    #[test]
    fn pad_to_chunk_works() {
        assert_eq!(pad_to_chunk(96, 64), 128);
        assert_eq!(pad_to_chunk(64, 64), 64);
        assert_eq!(pad_to_chunk(1, 16), 16);
    }
}
