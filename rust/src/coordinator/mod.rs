//! The serving engine: leader + a `cp × pp_stages × tp` grid of worker
//! pairs.
//!
//! Topology (one process; `pp_stages = 1, cp = 1` is the paper's
//! one-node TP deployment, `pp_stages > 1` the 2D pipeline×tensor
//! deployment of DESIGN.md §11, `cp > 1` the ring context-parallel
//! third axis of DESIGN.md §17):
//!
//! ```text
//!   leader (Engine) ──jobs──▶ every rank        stage s, rank r:
//!        ▲                                        COMPUTE thread ─┐partials
//!        │ logits                                      ▲ p2p      ▼
//!        └── stage P−1, rank 0 ◀──               COMM thread (stage ring)
//!                                 stage s−1 ──────┘ activations
//! ```
//!
//! Every rank receives the identical job stream; each executes only its
//! stage's contiguous layer slice ([`stage_layer_range`]) and owns only
//! that slice's KV caches. Within a stage the TP ring synchronizes the
//! ranks; between stages, rank `r` hands the post-all-reduce (replicated,
//! therefore bit-exact) activation to stage `s + 1`'s rank `r` over a
//! point-to-point [`StagePort`] — ISO's sequence chunks double as the
//! pipeline micro-batches, so chunk *i* computes on stage *s* while chunk
//! *i − 1*'s activation is on the inter-stage wire and chunk *i + 1*'s
//! all-reduce drains on the stage ring. Logits come from the last stage's
//! rank 0, which holds the leader's reply channel.
//!
//! Each rank is a *pair* of threads — compute and communication —
//! the CPU analogue of a GPU's compute stream + NCCL stream. ISO's overlap
//! is real here: while the comm thread blocks in the ring all-reduce of
//! chunk 0's partials, the compute thread executes chunk 1's attention
//! (paper §3.1, Fig 1d). The serial baseline (`Strategy::Serial`) issues
//! the same work but blocks on every collective before continuing —
//! exactly pipeline (a).
//!
//! Segment streaming (DESIGN.md §§4,6): each `CommJob` carries the
//! config's `comm_segments` knob — the engine-side twin of the
//! simulator's `Coster::ar_s(t, segments)`. The comm thread streams the
//! collective at that granularity and acks each row-segment the moment
//! it is final, so the compute thread applies the residual for segment 0
//! while the tail of the collective is still on the ring. Ack payloads
//! are recycled back to the comm thread — the job path allocates nothing
//! in steady state.
//!
//! Fused epilogue (DESIGN.md §12, the default): under `fused_epilogue`
//! every collective — prefill chunks, the fused decode/verify lanes, and
//! every PP stage's slice — ships its residual tensor along with the
//! partial, and the comm thread folds each reduced row-segment into it
//! inside the collective's own segment callback
//! ([`crate::collective::FusedEpilogue`]). The residual-add of segment
//! `k` therefore overlaps the wire time of segments `k+1..`, and the one
//! returning ack hands the finished tensor back — the exposed epilogue
//! collapses from a per-layer serial window to a buffer swap. Bit-exact
//! to the unfused path (same f32 adds per element, same order). The
//! opt-in `ladder_residual` mode goes further and is numerics-changing:
//! the per-sequence blocking layer loops (serial prefill, legacy
//! decode) compute the MLP from the *pre-attention* residual so both
//! block collectives fly while it runs (Ladder-Residual style); it
//! never ships residuals (the tensor stays compute-side for the next
//! block) and is excluded from every bit-exact pin. The fused lanes and
//! the ISO/mixed schedules ignore it, so a serving configuration's lane
//! math never depends on iteration composition.
//!
//! Mixed iterations (DESIGN.md §9): `serve_trace` no longer runs one
//! request at a time. Each leader iteration broadcasts a `Job::Step`
//! composing the head-of-line prefill's ISO chunks with a **fused decode
//! lane** — one token for up to `decode_batch` live sequences. The lane's
//! attention runs per slot (offsets differ) but its partials concatenate
//! into one B-row `CommJob` per layer-stage (B× fewer collectives via
//! `RingHandle::allreduce_rows_fused`, bit-identical to per-sequence
//! decode), and its MLP runs as one B-row GEMM when that width is
//! compiled. The interleave puts lane compute in the windows where the
//! prefill's collectives are on the ring and vice versa (paper Fig 1c
//! composed with Fig 1d).
//!
//! Speculative decoding (DESIGN.md §10): with `spec_k > 0` the decode
//! half of each iteration becomes a **verify lane** — every lane sequence
//! contributes a `k+1`-row window (last emitted token + `k` self-drafted
//! candidates), attention runs per row at consecutive KV offsets (so the
//! window's causal chain is exact), and the whole lane's partials
//! concatenate into one `B·(k+1)`-row `allreduce_rows_fused` per
//! layer-stage. The leader accepts the longest matching greedy prefix
//! (`batch::accept_count`) and rolls the rejected suffix back by
//! `KvManager::truncate`, so the emitted stream is token-identical to the
//! non-speculative engine while each iteration advances up to `k + 1`
//! tokens per sequence.
//!
//! Context parallelism (DESIGN.md §17): with `cp > 1` the leader's
//! chunk tiling is sliced into `cp` contiguous spans and each span runs
//! on its own full `pp × tp` grid. Group `c > 0` first drains the
//! preceding groups' K/V prefix off a per-(stage, tp-rank)
//! [`RingPass`] ring — one shard message per stage-local layer — then
//! prefills its own span with the unchanged ISO machinery, then
//! forwards the grown prefix to group `c + 1`. The fold order is
//! pinned, so the computed KV and logits are bit-identical to the flat
//! engine's. Decode is *not* sequence-parallel (the paper's rule): the
//! last group, which ends prefill holding the full prefix, runs every
//! decode/verify lane and holds the leader's reply channel; earlier
//! groups idle through lane steps in job lockstep.
//!
//! Python is long gone by the time this runs: stages were AOT-lowered to
//! HLO text by `make artifacts` and are compiled per worker at startup.

use std::collections::{BTreeMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::batch::{
    accept_count, plan_prefill_pp, ChunkJob, DecodeSlot, DraftProposer, LaneSeq, MixedPlanner,
    NGramProposer, SpecSlot,
};
use crate::collective::{
    cp_ring, ring, seg_range, stage_grid, FusedEpilogue, RingHandle, RingPass, ShardMsg, StagePort,
};
use crate::config::{CommQuant, EngineConfig, PrecisionPolicy, Strategy};
use crate::fault::{EngineError, FaultInjector, FaultPlan, SupervisionEvent};
use crate::kv::TieredKv;
use crate::metrics::{EngineMetrics, Timer};
use crate::runtime::{Arg, DevTensor, Executable, Manifest, Tensor, WorkerRuntime};
use crate::split::SplitContext;

/// The prefill half of a `Job::Step` (leader-planned, `Arc`-shared).
#[derive(Debug)]
struct StepPrefill {
    slot: usize,
    /// Padded prompt the full chunk tiling covers.
    tokens: Vec<i32>,
    chunks: Vec<ChunkJob>,
    /// True-last-token row within the final chunk (the slice tail's
    /// last row for a budget-bounded partial slice, whose logits the
    /// leader discards).
    logits_row: usize,
    /// Whether this chunk set finishes the sequence's prefill. `false`
    /// only under bounded chunked prefill (DESIGN.md §15), when the
    /// slice stops short and the rest streams in later iterations.
    completes: bool,
}

/// Jobs broadcast from the leader to every rank (identical stream).
/// Bulky payloads are `Arc`-shared so the per-rank clone is a refcount
/// bump, not a buffer copy (§Perf).
#[derive(Clone, Debug)]
enum Job {
    /// One mixed iteration: at most one prefill plus a fused lane —
    /// either one-token decode rows or speculative verify windows, never
    /// both (not every half may be absent at once).
    Step {
        prefill: Option<Arc<StepPrefill>>,
        decode: Arc<Vec<DecodeSlot>>,
        spec: Arc<Vec<SpecSlot>>,
    },
    /// One legacy per-sequence decode step: token at absolute position
    /// `offset` (kept for `generate`, the sequential serving loop, and
    /// the fused-vs-per-sequence equivalence tests).
    Decode { slot: usize, token: i32, offset: usize },
    /// Free a slot's caches.
    Release { slot: usize },
    Shutdown,
}

/// Replies from rank 0 only.
#[derive(Clone, Debug)]
enum Reply {
    /// Mixed-iteration result: prefill logits row (if a prefill ran) and
    /// one logits vector per decode lane entry, in lane order.
    Step { prefill: Option<Vec<f32>>, decode: Vec<Vec<f32>> },
    Logits(Vec<f32>),
    Released,
}

/// Work handed from a compute thread to its comm thread: one partial to
/// all-reduce, streamed back as `segments`-granular acks. `fused` marks a
/// decode-lane batch reduced rank-ordered (`allreduce_rows_fused`) so the
/// result is bit-identical to per-row collectives. Under the fused
/// epilogue (DESIGN.md §12) `residual` carries the chunk's residual
/// tensor; the comm thread applies each reduced segment into it the
/// moment the segment finalizes and one ack returns the finished tensor.
struct CommJob {
    data: Vec<f32>,
    rows: usize,
    cols: usize,
    segments: usize,
    fused: bool,
    residual: Option<Vec<f32>>,
    /// Wire rung this collective runs at (DESIGN.md §16): the compute
    /// thread resolves the per-phase `PrecisionPolicy` — prefill rung
    /// for chunked prefill reduces, decode rung for the fused lane — so
    /// one rank can mix rungs job by job.
    quant: CommQuant,
}

/// Rank-0 logits produced by one worker-side step: the prefill's
/// true-last-token row (if any) and one vector per decode lane entry.
type StepLogits = (Option<Vec<f32>>, Option<Vec<Vec<f32>>>);

/// One finalized row-range of a reduced partial, streamed back from the
/// comm thread while the collective's tail is still in flight.
struct SegAck {
    row_start: usize,
    rows: usize,
    data: Vec<f32>,
    /// `true`: the comm thread already applied the epilogue
    /// (DESIGN.md §12) — `data` is the finished residual tensor to adopt,
    /// one ack per collective. `false`: `data` is a reduced row-segment
    /// the compute thread adds in place (legacy path).
    fused: bool,
    /// A spent submit payload riding back for buffer reuse, keeping the
    /// fused-epilogue path allocation-free in steady state.
    spent: Option<Vec<f32>>,
}

/// Contiguous layer range `[lo, hi)` owned by pipeline stage `stage` of
/// `pp_stages` (DESIGN.md §11): the balanced contiguous partition of
/// `seg_range` — the first `n_layers % pp_stages` stages take one extra
/// layer, so every stage owns at least one layer whenever
/// `pp_stages <= n_layers`. This single function is the engine's whole
/// layer-to-stage assignment; the cost model (`sched::pp_iteration_s`)
/// and the benches use it too, so predictions and execution agree.
pub fn stage_layer_range(n_layers: usize, pp_stages: usize, stage: usize) -> (usize, usize) {
    seg_range(n_layers, pp_stages, stage)
}

/// Per-worker performance counters (returned at shutdown).
#[derive(Clone, Debug, Default)]
pub struct WorkerStats {
    /// Global rank the counters belong to (`stage × tp + tp_rank`).
    pub rank: usize,
    /// Pipeline stage the rank belongs to (0 when `pp_stages = 1`).
    pub stage: usize,
    /// Time spent inside compiled stages.
    pub compute_ms: f64,
    /// Time the compute thread spent blocked waiting for reduced results
    /// — the *exposed* (un-overlapped) communication time.
    pub stall_ms: f64,
    /// Wall time the comm thread spent inside collectives.
    pub comm_ms: f64,
    /// Post-quantization bytes this rank put on the wire.
    pub wire_bytes: u64,
    /// Wire messages sent by the ring (grows with `comm_segments`).
    pub wire_msgs: u64,
    /// `wire_bytes` split by wire rung, indexed by
    /// [`CommQuant::index`] (f32, fp16, int8, fp8, int4) — the
    /// per-phase precision policy (DESIGN.md §16) mixes rungs on one
    /// rank, so a single total can't show where the bytes went.
    pub wire_bytes_by_rung: [u64; 5],
    /// All-reduce invocations.
    pub allreduces: u64,
    /// Fused B-row lane collectives (subset of `allreduces`).
    pub fused_allreduces: u64,
    /// Total rows through fused lane collectives — with
    /// `fused_allreduces` this gives the mean verify-window width the
    /// spec-decode lane actually achieved (DESIGN.md §10).
    pub fused_rows: u64,
    /// Per-segment acks exchanged between the comm and compute threads
    /// (one per collective under the fused epilogue).
    pub seg_acks: u64,
    /// Compute-thread time spent applying reduced rows into the residual
    /// — the *exposed* post-collective epilogue (DESIGN.md §12). Near
    /// zero under `fused_epilogue`, where the comm thread applies each
    /// segment while the collective's tail is still on the ring.
    pub epilogue_ms: f64,
    /// Rows whose residual epilogue ran comm-side, fused into the
    /// collective's segment callbacks.
    pub fused_epilogue_rows: u64,
    /// Comm-thread time inside the fused epilogue (hidden behind the
    /// in-flight wire segments, not behind compute).
    pub fused_epilogue_ms: f64,
    /// Activation bytes this rank sent to the next pipeline stage.
    pub p2p_bytes: u64,
    /// Activation messages this rank sent to the next pipeline stage.
    pub p2p_msgs: u64,
    /// Time the compute thread spent blocked waiting on the previous
    /// stage's activations — the rank's share of the pipeline bubble.
    pub p2p_stall_ms: f64,
    /// KV-shard bytes this rank forwarded around the context-parallel
    /// ring (DESIGN.md §17); zero when `cp = 1`.
    pub cp_shard_bytes: u64,
    /// KV-shard messages this rank forwarded around the CP ring.
    pub cp_shard_msgs: u64,
    /// Time the compute thread spent blocked waiting on the previous
    /// CP group's KV prefix — the shard ring's share of the wavefront.
    pub cp_stall_ms: f64,
}

impl WorkerStats {
    /// Comm time hidden behind compute (the achieved overlap).
    pub fn overlapped_ms(&self) -> f64 {
        (self.comm_ms - self.stall_ms).max(0.0)
    }

    /// Fraction of comm hidden (1.0 = perfectly overlapped).
    pub fn overlap_efficiency(&self) -> f64 {
        if self.comm_ms <= 0.0 {
            return 1.0;
        }
        self.overlapped_ms() / self.comm_ms
    }

    /// Copy the comm-thread half of a rank's counters into the
    /// compute-side record (the rank's two threads split the fields).
    fn fold_comm(&mut self, comm: &WorkerStats) {
        self.comm_ms = comm.comm_ms;
        self.allreduces = comm.allreduces;
        self.fused_allreduces = comm.fused_allreduces;
        self.fused_rows = comm.fused_rows;
        self.wire_bytes = comm.wire_bytes;
        self.wire_msgs = comm.wire_msgs;
        self.wire_bytes_by_rung = comm.wire_bytes_by_rung;
        self.fused_epilogue_rows = comm.fused_epilogue_rows;
        self.fused_epilogue_ms = comm.fused_epilogue_ms;
    }

    /// Add another mesh generation's counters for the same rank.
    /// Recovery (DESIGN.md §14) respawns the worker grid; the final
    /// report spans every generation, so an abandoned mesh's counters
    /// are folded into its successor's rather than dropped.
    fn absorb(&mut self, o: &WorkerStats) {
        self.compute_ms += o.compute_ms;
        self.stall_ms += o.stall_ms;
        self.comm_ms += o.comm_ms;
        self.wire_bytes += o.wire_bytes;
        self.wire_msgs += o.wire_msgs;
        for (a, b) in self.wire_bytes_by_rung.iter_mut().zip(&o.wire_bytes_by_rung) {
            *a += *b;
        }
        self.allreduces += o.allreduces;
        self.fused_allreduces += o.fused_allreduces;
        self.fused_rows += o.fused_rows;
        self.seg_acks += o.seg_acks;
        self.epilogue_ms += o.epilogue_ms;
        self.fused_epilogue_rows += o.fused_epilogue_rows;
        self.fused_epilogue_ms += o.fused_epilogue_ms;
        self.p2p_bytes += o.p2p_bytes;
        self.p2p_msgs += o.p2p_msgs;
        self.p2p_stall_ms += o.p2p_stall_ms;
        self.cp_shard_bytes += o.cp_shard_bytes;
        self.cp_shard_msgs += o.cp_shard_msgs;
        self.cp_stall_ms += o.cp_stall_ms;
    }
}

/// Result of one prefill.
#[derive(Clone, Debug)]
pub struct PrefillOut {
    /// Greedy first token.
    pub first_token: i32,
    /// Time to first token (engine-relative, ms).
    pub ttft_ms: f64,
    /// Full logits of the prompt's true last token.
    pub logits: Vec<f32>,
}

/// Result of a full generate call.
#[derive(Clone, Debug)]
pub struct GenOut {
    /// Emitted tokens (first token + decode steps).
    pub tokens: Vec<i32>,
    /// Time to first token (ms).
    pub ttft_ms: f64,
    /// Per-decode-step latency (ms).
    pub decode_ms: Vec<f64>,
}

/// Final engine report.
#[derive(Clone, Debug)]
pub struct EngineReport {
    /// Leader-side counters and histograms.
    pub metrics: EngineMetrics,
    /// Per-rank compute/comm counters, in global-rank order (stage-major).
    pub workers: Vec<WorkerStats>,
    /// Pipeline stages the engine ran with (1 = flat TP).
    pub pp_stages: usize,
    /// Tensor-parallel width per stage.
    pub tp: usize,
    /// Ring context-parallel group count (1 = no third axis).
    pub cp: usize,
}

/// Accounting from `Engine::serve_trace` (continuous batching).
#[derive(Clone, Debug, Default)]
pub struct TraceReport {
    /// TTFT measured from *arrival* (includes queueing).
    pub ttft_ms: crate::metrics::Histogram,
    /// Request completion latency from arrival.
    pub e2e_ms: crate::metrics::Histogram,
    /// Time between consecutive tokens of a sequence (ms per decoded
    /// token) — steady under the mixed scheduler, bursty round-robin
    /// under the legacy loop.
    pub tbt_ms: crate::metrics::Histogram,
    /// Per-iteration batch occupancy (prefill chunks + decode lane rows).
    pub occupancy: crate::metrics::Histogram,
    /// Engine iterations the trace took.
    pub iterations: u64,
    /// Requests completed.
    pub completed: u64,
    /// Tokens emitted across all requests.
    pub generated: u64,
    /// Trace wall time (seconds).
    pub wall_s: f64,
    /// `(request id, emitted tokens)` per completed request — lets tests
    /// and benches assert scheduling changes never change the tokens.
    pub completions: Vec<(u64, Vec<i32>)>,
    /// Sequences evicted by KV-pressure preemption and re-enqueued for
    /// checkpoint-free re-prefill (DESIGN.md §15); 0 with
    /// `kv_high_water = 1.0`.
    pub preemptions: u64,
    /// Queued requests shed for a blown TTFT deadline; 0 with
    /// `ttft_deadline_ms = 0`.
    pub shed: u64,
    /// Arrivals rejected at the bounded admission queue; 0 with
    /// `queue_bound = 0`.
    pub rejected: u64,
    /// One-line summary of the auto-tuned config this trace ran under
    /// (`serve --auto-tune`, DESIGN.md §18); `None` for hand-set configs,
    /// keeping legacy reports byte-identical.
    pub tuned: Option<String>,
}

impl TraceReport {
    /// Emitted tokens per second of trace wall time.
    pub fn throughput_tok_s(&self) -> f64 {
        if self.wall_s <= 0.0 {
            return 0.0;
        }
        self.generated as f64 / self.wall_s
    }
}

// ---------------------------------------------------------------------------
// Worker (compute + comm threads)
// ---------------------------------------------------------------------------

/// Everything a rank's compute thread owns.
struct ComputeWorker {
    /// Pipeline stage this rank belongs to (within its CP group).
    stage: usize,
    /// Total pipeline stages.
    stages: usize,
    /// Context-parallel group this rank belongs to (0 when `cp = 1`).
    cp_group: usize,
    /// Total context-parallel groups (config `topology.cp`).
    cp: usize,
    /// This rank holds the leader's reply channel (last CP group, last
    /// stage, TP rank 0) and is therefore the one that compiles and runs
    /// the logits stage.
    is_reply: bool,
    strategy: Strategy,
    /// Layers owned by this stage (the stage's contiguous slice; equals
    /// the whole model when `pp_stages = 1`). All layer indices below are
    /// stage-local.
    local_layers: usize,
    d_model: usize,
    /// Point-to-point activation port to the neighboring stages.
    port: StagePort,
    /// KV-shard port to the neighboring CP groups (DESIGN.md §17); the
    /// solo port when `cp = 1`.
    shard_ring: RingPass,
    /// Row-segments per collective (config `comm_segments`).
    comm_segments: usize,
    /// Resolved per-phase wire rungs (DESIGN.md §16): prefill reduces
    /// ride `precision.prefill`, the fused decode/verify lane rides
    /// `precision.decode`.
    precision: PrecisionPolicy,
    /// B-row lane-MLP GEMM fusion (config `lane_gemm`).
    lane_gemm: bool,
    /// Comm-side fused epilogue (config `fused_epilogue`, DESIGN.md §12):
    /// collectives carry their residual and come back fully applied.
    fused_epilogue: bool,
    /// Ladder-residual reordering (config `ladder_residual`,
    /// numerics-changing, DESIGN.md §12).
    ladder: bool,
    // compiled stages keyed by chunk length
    embed: BTreeMap<usize, Executable>,
    attn: BTreeMap<usize, Executable>,
    mlp: BTreeMap<usize, Executable>,
    logits: BTreeMap<usize, Executable>,
    // weights: per layer, in stage argument order
    layer_w: Vec<LayerWeights>,
    emb: DevTensor,
    ln_f: DevTensor,
    head: DevTensor,
    // KV caches: slot → per-layer (k, v)
    caches: BTreeMap<usize, Vec<(Tensor, Tensor)>>,
    kv_shape: Vec<usize>,
    // comm plumbing
    to_comm: Sender<CommJob>,
    from_comm: Receiver<SegAck>,
    /// Returns spent ack buffers to the comm thread for reuse.
    recycle_tx: Sender<Vec<f32>>,
    /// Small compute-side buffer pool closing the fused-lane cycle
    /// (§Perf): a fused submit payload comes back as the ack payload, so
    /// the lane reuses buffers instead of allocating per layer-stage.
    scratch: Vec<Vec<f32>>,
    /// Engine-wide fault injector (DESIGN.md §14), polled at layer
    /// boundaries. Holds an empty plan unless a `FaultPlan` is set.
    injector: Arc<FaultInjector>,
    stats: WorkerStats,
}

struct LayerWeights {
    ln1: DevTensor,
    wq: DevTensor,
    wk: DevTensor,
    wv: DevTensor,
    wo: DevTensor,
    ln2: DevTensor,
    w_gate: DevTensor,
    w_up: DevTensor,
    w_down: DevTensor,
}

impl ComputeWorker {
    #[allow(clippy::too_many_arguments)]
    fn build(
        rank: usize,
        cfg: &EngineConfig,
        manifest: Manifest,
        port: StagePort,
        shard_ring: RingPass,
        to_comm: Sender<CommJob>,
        from_comm: Receiver<SegAck>,
        recycle_tx: Sender<Vec<f32>>,
        injector: Arc<FaultInjector>,
    ) -> Result<Self> {
        let tp = cfg.tp;
        let stages = cfg.pp_stages;
        let cp = cfg.cp.max(1);
        // World rank layout: `c × (pp × tp) + s × tp + r` — each CP
        // group is a full pp × tp grid (DESIGN.md §17).
        let group_rank = rank % (stages * tp);
        let cp_group = rank / (stages * tp);
        let stage = group_rank / tp;
        let tp_rank = group_rank % tp;
        let is_reply = cp_group == cp - 1 && stage == stages - 1 && tp_rank == 0;
        let rt = WorkerRuntime::new(manifest)?;
        let geo = rt.manifest.config;
        let (layer_lo, layer_hi) = stage_layer_range(geo.n_layers, stages, stage);
        let mut embed = BTreeMap::new();
        let mut attn = BTreeMap::new();
        let mut mlp = BTreeMap::new();
        let mut logits = BTreeMap::new();
        for &t in &rt.manifest.chunk_lens {
            if t > cfg.max_chunk && t != 1 {
                continue;
            }
            if stage == 0 {
                // Only the first stage embeds tokens; later stages adopt
                // the previous stage's activations over the p2p port.
                embed.insert(t, rt.compile(&format!("embed_t{t}"))?);
            }
            attn.insert(t, rt.compile(&format!("attn_tp{tp}_t{t}"))?);
            mlp.insert(t, rt.compile(&format!("mlp_tp{tp}_t{t}"))?);
            if is_reply {
                logits.insert(t, rt.compile(&format!("logits_t{t}"))?);
            }
        }
        if attn.is_empty() {
            bail!("no chunk sizes compiled (max_chunk {} too small?)", cfg.max_chunk);
        }
        // Prime XLA's lazy first-execution init at startup so the first
        // request doesn't pay it (§Perf: first TTFT was ~50x p50 before).
        for exe in embed
            .values()
            .chain(attn.values())
            .chain(mlp.values())
            .chain(logits.values())
        {
            exe.warmup()?;
        }

        // Per-stage weight ownership: only this stage's layer slice is
        // loaded (the point of pipeline sharding). Weight shards are
        // indexed by the within-stage TP rank.
        let mut layer_w = Vec::with_capacity(layer_hi - layer_lo);
        for l in layer_lo..layer_hi {
            let w = |n: &str| -> Result<DevTensor> {
                DevTensor::from_tensor(
                    &rt.load_weight(tp, &format!("layer{l}.rank{tp_rank}.{n}"))?,
                )
            };
            layer_w.push(LayerWeights {
                ln1: w("ln1")?,
                wq: w("wq")?,
                wk: w("wk")?,
                wv: w("wv")?,
                wo: w("wo")?,
                ln2: w("ln2")?,
                w_gate: w("w_gate")?,
                w_up: w("w_up")?,
                w_down: w("w_down")?,
            });
        }
        let emb = DevTensor::from_tensor(&rt.load_weight(tp, "emb")?)?;
        let ln_f = DevTensor::from_tensor(&rt.load_weight(tp, "ln_f")?)?;
        let head = DevTensor::from_tensor(&rt.load_weight(tp, "head")?)?;
        let kv_shape = vec![geo.n_kv_heads / tp, geo.max_seq, geo.head_dim];

        Ok(ComputeWorker {
            stage,
            stages,
            cp_group,
            cp,
            is_reply,
            strategy: cfg.strategy,
            local_layers: layer_hi - layer_lo,
            d_model: geo.d_model,
            port,
            shard_ring,
            comm_segments: cfg.comm_segments.max(1),
            precision: cfg.precision(),
            lane_gemm: cfg.lane_gemm,
            fused_epilogue: cfg.fused_epilogue,
            ladder: cfg.ladder_residual,
            embed,
            attn,
            mlp,
            logits,
            layer_w,
            emb,
            ln_f,
            head,
            caches: BTreeMap::new(),
            kv_shape,
            to_comm,
            from_comm,
            recycle_tx,
            scratch: Vec::new(),
            injector,
            stats: WorkerStats { rank, stage, ..Default::default() },
        })
    }

    /// Poll the fault injector at a layer boundary (DESIGN.md §14): a
    /// planned kill surfaces as a typed error the worker exits with, a
    /// stall sleeps in place, and a planned p2p poison arms the stage
    /// port so its next activation send is flagged corrupt.
    fn fault_check(&mut self, layer: usize) -> Result<()> {
        if self.injector.poll_wire(self.stats.rank, true) {
            self.port.poison_next_send();
        }
        self.injector.poll_compute(self.stats.rank, layer)?;
        Ok(())
    }

    /// Per-stage KV ownership (DESIGN.md §11): a slot's caches on this
    /// rank cover only the stage's own layer slice.
    fn ensure_slot(&mut self, slot: usize) {
        if !self.caches.contains_key(&slot) {
            let per_layer = (0..self.local_layers)
                .map(|_| {
                    (Tensor::zeros(self.kv_shape.clone()), Tensor::zeros(self.kv_shape.clone()))
                })
                .collect();
            self.caches.insert(slot, per_layer);
        }
    }

    /// Whether this rank sits on the pipeline's last stage (the stage
    /// that produces logits instead of forwarding activations).
    fn is_last_stage(&self) -> bool {
        self.stage == self.stages - 1
    }

    /// Blocking receive of the previous stage's next activation (FIFO
    /// order matches the upstream send order). The wait is the pipeline
    /// bubble this rank observes; it is accounted separately from
    /// all-reduce stalls.
    fn recv_stage(&mut self, rows: usize) -> Result<Tensor> {
        let t = Timer::start();
        let (r, c, data) = self.port.try_recv_prev()?;
        self.stats.p2p_stall_ms += t.elapsed_ms();
        if r != rows || c != self.d_model {
            bail!("stage handoff shape mismatch: got {r}x{c}, want {rows}x{}", self.d_model);
        }
        Ok(Tensor { shape: vec![r, c], data })
    }

    /// Hand a finalized activation to the next stage (zero-copy, bit
    /// exact; never blocks — the transfer overlaps this rank's next
    /// chunk). A dead downstream stage surfaces as a typed error.
    fn send_stage(&mut self, x: Tensor) -> Result<()> {
        let rows = x.shape[0];
        self.port.try_send_next(x.data, rows, self.d_model)?;
        Ok(())
    }

    /// A chunk's input activation: embedded on stage 0, received from the
    /// previous stage otherwise.
    fn chunk_in(&mut self, tokens: &[i32], c: &ChunkJob) -> Result<Tensor> {
        if self.stage == 0 {
            self.run_embed(&tokens[c.offset..c.offset + c.len])
        } else {
            self.recv_stage(c.len)
        }
    }

    /// Whether this rank's group runs the decode/verify lanes. Decode
    /// keeps sequence parallelism off (the paper's "SP is not allowed"
    /// rule, DESIGN.md §17): after prefill the last CP group holds every
    /// sequence's full KV prefix, so it alone serves decode; earlier
    /// groups contribute their prefill shard and idle through lane work.
    fn cp_owns_lane(&self) -> bool {
        self.cp_group == self.cp - 1
    }

    /// This group's slice of a leader-planned chunk tiling plus its
    /// shard's token boundaries `[prefix, end)` within the padded prompt
    /// (DESIGN.md §17): rows `[0, prefix)` must be KV-resident before
    /// the slice's first attention (they stream in from the previous
    /// group), and rows `[0, end)` are resident — and forwarded — once
    /// the slice completes. With `cp = 1` this is the whole tiling.
    fn cp_span<'a>(&self, chunks: &'a [ChunkJob]) -> (&'a [ChunkJob], usize, usize) {
        let k = chunks.len();
        let total = chunks.last().map_or(0, |c| c.offset + c.len);
        if self.cp == 1 {
            return (chunks, 0, total);
        }
        let (lo, hi) = seg_range(k, self.cp, self.cp_group);
        let tok = |i: usize| if i < k { chunks[i].offset } else { total };
        (&chunks[lo..hi], tok(lo), tok(hi))
    }

    /// Copy token rows `[row_start, row_start + rows)` of a cached K or V
    /// tensor (shape `[heads, max_seq, head_dim]`) into a dense wire
    /// buffer laid out `[heads, rows, head_dim]`.
    fn load_kv_rows(&self, cache: &Tensor, row_start: usize, rows: usize) -> Vec<f32> {
        let (heads, max_seq, hd) = (self.kv_shape[0], self.kv_shape[1], self.kv_shape[2]);
        let mut out = vec![0.0; heads * rows * hd];
        for h in 0..heads {
            for t in 0..rows {
                let src = (h * max_seq + row_start + t) * hd;
                let dst = (h * rows + t) * hd;
                out[dst..dst + hd].copy_from_slice(&cache.data[src..src + hd]);
            }
        }
        out
    }

    /// Scatter a dense `[heads, rows, head_dim]` wire buffer back into a
    /// cached tensor at token rows `[row_start, row_start + rows)`.
    fn store_kv_rows(&self, cache: &mut Tensor, data: &[f32], row_start: usize, rows: usize) {
        let (heads, max_seq, hd) = (self.kv_shape[0], self.kv_shape[1], self.kv_shape[2]);
        for h in 0..heads {
            for t in 0..rows {
                let src = (h * rows + t) * hd;
                let dst = (h * max_seq + row_start + t) * hd;
                cache.data[dst..dst + hd].copy_from_slice(&data[src..src + hd]);
            }
        }
    }

    /// Receive the prompt's prefix K/V rows `[0, rows)` for every local
    /// layer from the previous CP group and scatter them into this
    /// slot's caches (DESIGN.md §17). The wavefront is stage-granular:
    /// each stage exchanges only its own layer slice, one shard message
    /// per stage-local layer, in layer order on both ends.
    fn cp_recv_prefix(&mut self, slot: usize, rows: usize) -> Result<()> {
        if self.cp == 1 || self.cp_group == 0 || rows == 0 {
            return Ok(());
        }
        self.ensure_slot(slot);
        for l in 0..self.local_layers {
            let t = Timer::start();
            let msg = self.shard_ring.try_recv_prev()?;
            self.stats.cp_stall_ms += t.elapsed_ms();
            if msg.slot != slot || msg.layer != l || msg.row_start != 0 || msg.rows != rows {
                bail!(
                    "cp shard mismatch: got slot {} layer {} rows [{}, {}), \
                     want slot {slot} layer {l} rows [0, {rows})",
                    msg.slot,
                    msg.layer,
                    msg.row_start,
                    msg.row_start + msg.rows
                );
            }
            let caches =
                self.caches.get_mut(&slot).expect("invariant: slot cache allocated at spawn");
            let (mut k, mut v) = std::mem::take(&mut caches[l]);
            self.store_kv_rows(&mut k, &msg.k, 0, rows);
            self.store_kv_rows(&mut v, &msg.v, 0, rows);
            self.caches.get_mut(&slot).expect("invariant: slot cache allocated at spawn")[l] =
                (k, v);
        }
        Ok(())
    }

    /// Forward K/V rows `[0, rows)` — the received prefix plus this
    /// group's freshly computed shard — for every local layer to the
    /// next CP group. The last group owns the full prefix and sends
    /// nothing; a dead neighbor surfaces as a typed error.
    fn cp_send_prefix(&mut self, slot: usize, rows: usize) -> Result<()> {
        if self.cp == 1 || self.cp_group == self.cp - 1 || rows == 0 {
            return Ok(());
        }
        for l in 0..self.local_layers {
            let caches = self.caches.get(&slot).expect("invariant: slot cache allocated at spawn");
            let k = self.load_kv_rows(&caches[l].0, 0, rows);
            let v = self.load_kv_rows(&caches[l].1, 0, rows);
            self.shard_ring.try_send_next(ShardMsg { slot, layer: l, row_start: 0, rows, k, v })?;
        }
        Ok(())
    }

    /// Submit a partial for all-reduce; the reduced rows stream back as
    /// per-segment acks consumed by [`ComputeWorker::recv_reduced_apply`].
    /// Under the fused epilogue (DESIGN.md §12) the chunk's residual
    /// tensor `x` rides along: the comm thread folds each reduced segment
    /// into it the moment the segment finalizes, and the single returning
    /// ack carries the fully-updated tensor — the residual-add overlaps
    /// the collective's in-flight tail instead of running after it.
    fn submit(&mut self, data: Vec<f32>, rows: usize, x: &mut Tensor) -> Result<()> {
        let residual = self.take_residual(x, rows);
        self.submit_with(data, rows, self.comm_segments, false, residual, self.precision.prefill)
    }

    /// [`ComputeWorker::submit`] without the residual payload — the
    /// ladder-residual paths keep the tensor compute-side because the
    /// next block still reads it while the collective is in flight.
    fn submit_plain(&mut self, data: Vec<f32>, rows: usize) -> Result<()> {
        self.submit_with(data, rows, self.comm_segments, false, None, self.precision.prefill)
    }

    /// Submit a fused decode-lane batch: one rank-ordered B-row
    /// collective whose result is bit-identical to B per-row collectives.
    /// The lane's residual rides along under the fused epilogue. Rides
    /// the policy's decode rung (DESIGN.md §16), which may sit below the
    /// prefill rung — decode activations tolerate a coarser wire.
    fn submit_fused(&mut self, data: Vec<f32>, rows: usize, x: &mut Tensor) -> Result<()> {
        let residual = self.take_residual(x, rows);
        self.submit_with(data, rows, 1, true, residual, self.precision.decode)
    }

    /// Detach `x`'s buffer as the job's residual payload when the fused
    /// epilogue is on; `x` keeps its shape and readopts the (updated)
    /// buffer at the matching [`ComputeWorker::recv_reduced_apply`].
    fn take_residual(&mut self, x: &mut Tensor, rows: usize) -> Option<Vec<f32>> {
        if !self.fused_epilogue {
            return None;
        }
        debug_assert_eq!(x.data.len(), rows * self.d_model, "residual shape");
        Some(std::mem::take(&mut x.data))
    }

    fn submit_with(
        &mut self,
        data: Vec<f32>,
        rows: usize,
        segments: usize,
        fused: bool,
        residual: Option<Vec<f32>>,
        quant: CommQuant,
    ) -> Result<()> {
        let cols = self.d_model;
        self.stats.allreduces += 1;
        self.to_comm
            .send(CommJob { data, rows, cols, segments, fused, residual, quant })
            .map_err(|_| EngineError::RankDead { rank: self.stats.rank, link: "comm" })?;
        Ok(())
    }

    /// Consume the next reduced result (FIFO) and fold it into `x` — the
    /// residual connection. Legacy path: add row-segment by row-segment
    /// as acks land (segment 0 applies while the collective's tail is
    /// still on the ring). Fused-epilogue path (DESIGN.md §12): the comm
    /// thread already applied every segment into the shipped residual, so
    /// the single ack just hands the finished buffer back and the exposed
    /// epilogue collapses to a pointer swap. Only time actually blocked
    /// counts as stall (exposed comm). A comm thread that exited on a
    /// ring fault surfaces here as a typed [`EngineError::RankDead`].
    fn recv_reduced_apply(&mut self, x: &mut Tensor) -> Result<()> {
        let cols = self.d_model;
        let rows = x.shape.first().copied().unwrap_or(0);
        let mut got = 0;
        while got < rows {
            let t = Timer::start();
            let ack = self
                .from_comm
                .recv()
                .map_err(|_| EngineError::RankDead { rank: self.stats.rank, link: "comm" })?;
            self.stats.stall_ms += t.elapsed_ms();
            self.stats.seg_acks += 1;
            if let Some(buf) = ack.spent {
                // Spent submit payloads return for reuse (§Perf).
                if self.scratch.len() < 4 {
                    self.scratch.push(buf);
                } else {
                    self.recycle_tx.send(buf).ok();
                }
            }
            if ack.fused {
                debug_assert_eq!(ack.data.len(), rows * cols, "fused ack shape");
                x.data = ack.data;
                got = rows;
                continue;
            }
            let t_epi = Timer::start();
            let lo = ack.row_start * cols;
            let hi = lo + ack.rows * cols;
            debug_assert!(hi <= x.data.len(), "ack outside tensor");
            for (o, v) in x.data[lo..hi].iter_mut().zip(&ack.data) {
                *o += *v;
            }
            self.stats.epilogue_ms += t_epi.elapsed_ms();
            got += ack.rows;
            // Return the buffer for reuse: a few stay compute-side for
            // the fused lane's submits, the rest refill the comm thread's
            // ack pool. Ignore send failure at shutdown.
            if self.scratch.len() < 4 {
                self.scratch.push(ack.data);
            } else {
                self.recycle_tx.send(ack.data).ok();
            }
        }
        Ok(())
    }

    /// A zeroed `len`-element buffer from the scratch pool (or fresh).
    fn take_scratch(&mut self, len: usize) -> Vec<f32> {
        let mut v = self.scratch.pop().unwrap_or_default();
        v.clear();
        v.resize(len, 0.0);
        v
    }

    fn run_embed(&mut self, tokens: &[i32]) -> Result<Tensor> {
        let t = tokens.len();
        let exe = self.embed.get(&t).ok_or_else(|| anyhow!("no embed_t{t}"))?;
        let out = exe.run(&[Arg::I32(tokens), Arg::Dev(&self.emb)])?;
        Ok(out.into_iter().next().expect("invariant: embed module emits one output"))
    }

    /// One chunk's attention partial; updates the slot's KV cache.
    fn run_attn(&mut self, slot: usize, layer: usize, x: &Tensor, offset: usize) -> Result<Tensor> {
        let t = x.shape[0];
        let timer = Timer::start();
        let exe = self.attn.get(&t).ok_or_else(|| anyhow!("no attn_t{t}"))?;
        let w = &self.layer_w[layer];
        // Move the caches out instead of cloning them (§Perf): the stage
        // returns the updated caches, which we put back below. `take`
        // leaves an unallocated placeholder, not a zero-filled tensor.
        let caches = self.caches.get_mut(&slot).expect("invariant: slot cache allocated at spawn");
        let (k_cache, v_cache) = std::mem::take(&mut caches[layer]);
        let out = exe.run(&[
            Arg::F32(x),
            Arg::Dev(&w.ln1),
            Arg::Dev(&w.wq),
            Arg::Dev(&w.wk),
            Arg::Dev(&w.wv),
            Arg::Dev(&w.wo),
            Arg::F32(&k_cache),
            Arg::F32(&v_cache),
            Arg::Scalar(offset as i32),
        ])?;
        let mut it = out.into_iter();
        let arity = "invariant: attn module emits (partial, k, v)";
        let partial = it.next().expect(arity);
        let new_k = it.next().expect(arity);
        let new_v = it.next().expect(arity);
        self.caches.get_mut(&slot).expect("invariant: slot cache allocated at spawn")[layer] =
            (new_k, new_v);
        self.stats.compute_ms += timer.elapsed_ms();
        Ok(partial)
    }

    fn run_mlp(&mut self, layer: usize, x: &Tensor) -> Result<Tensor> {
        let t = x.shape[0];
        let timer = Timer::start();
        let exe = self.mlp.get(&t).ok_or_else(|| anyhow!("no mlp_t{t}"))?;
        let w = &self.layer_w[layer];
        let out = exe.run(&[
            Arg::F32(x),
            Arg::Dev(&w.ln2),
            Arg::Dev(&w.w_gate),
            Arg::Dev(&w.w_up),
            Arg::Dev(&w.w_down),
        ])?;
        self.stats.compute_ms += timer.elapsed_ms();
        Ok(out.into_iter().next().expect("invariant: mlp module emits one output"))
    }

    fn run_logits(&mut self, x: &Tensor) -> Result<Tensor> {
        let t = x.shape[0];
        let exe = self.logits.get(&t).ok_or_else(|| anyhow!("no logits_t{t}"))?;
        let out = exe.run(&[Arg::F32(x), Arg::Dev(&self.ln_f), Arg::Dev(&self.head)])?;
        Ok(out.into_iter().next().expect("invariant: logits module emits one output"))
    }

    /// Prefill one sequence with the ISO pipelined schedule (or blocking
    /// serial when `strategy != Iso`) over this rank's stage slice.
    /// Chunk activations arrive from the previous stage (or the embedding
    /// on stage 0) and stream to the next stage as each finalizes, so the
    /// chunks double as pipeline micro-batches (DESIGN.md §11). Returns
    /// last-chunk logits on the reply rank.
    fn prefill(
        &mut self,
        slot: usize,
        tokens: &[i32],
        chunks: &[ChunkJob],
        logits_row: usize,
    ) -> Result<Option<Vec<f32>>> {
        self.ensure_slot(slot);
        // Context parallelism (DESIGN.md §17): each group executes its
        // contiguous chunk slice after pulling the preceding groups' KV
        // prefix off the shard ring, then forwards the grown prefix on.
        let (my, prefix, end) = self.cp_span(chunks);
        self.cp_recv_prefix(slot, prefix)?;
        let xs = match self.strategy {
            Strategy::Iso => self.prefill_pipelined(slot, tokens, my)?,
            _ => self.prefill_blocking(slot, tokens, my)?,
        };
        self.cp_send_prefix(slot, end)?;
        if self.is_reply {
            let last_idx = my.iter().position(|c| c.last).expect("no last chunk");
            Ok(Some(self.logits_row_of(&xs[last_idx], logits_row)?))
        } else {
            Ok(None)
        }
    }

    /// Rank-0 logits for row `logits_row` of chunk activations `x`.
    fn logits_row_of(&mut self, x: &Tensor, logits_row: usize) -> Result<Vec<f32>> {
        let logits = self.run_logits(x)?;
        let vocab = logits.shape[1];
        // Extract the true-last-token row in place — truncate + drain
        // memmove within the existing allocation instead of `to_vec`
        // copying into a fresh one (§Perf).
        let mut row = logits.data;
        row.truncate((logits_row + 1) * vocab);
        row.drain(..logits_row * vocab);
        // Don't pin the whole chunk×vocab allocation inside the returned
        // PrefillOut for its lifetime.
        row.shrink_to_fit();
        Ok(row)
    }

    /// Fig 1(d) within the stage: per layer, compute every chunk's
    /// attention back-to-back while earlier chunks' collectives fly; MLPs
    /// interleave with the attention collectives; next layer starts as
    /// soon as *that chunk's* MLP collective lands. The KV ordering
    /// constraint is honored by construction: chunk i's attention
    /// executes after chunk i-1's within the same thread, and chunks are
    /// offset-ordered. Pipeline edges are lazy, streaming, and
    /// **pair-granular**: a single-stage engine keeps the whole chunk set
    /// in one ISO group (bit-for-bit the pre-PP schedule), while a
    /// pipeline stage processes the chunks in pairs — each pair runs the
    /// full layer-major ping-pong (so ISO's two-chunk overlap survives
    /// inside the pair) and is forwarded downstream the moment its final
    /// collectives land, before the next pair starts. Chunk *pairs* are
    /// therefore the wavefront unit: stage s+1 computes pair g while
    /// stage s computes pair g+1 and pair g+1's all-reduces drain. The
    /// causal KV constraint holds because pairs execute in chunk order
    /// within one thread. Returns the chunk activations — placeholders
    /// for entries already forwarded downstream.
    fn prefill_pipelined(
        &mut self,
        slot: usize,
        tokens: &[i32],
        chunks: &[ChunkJob],
    ) -> Result<Vec<Tensor>> {
        let k = chunks.len();
        let group = if self.stages > 1 { 2 } else { k.max(1) };
        let mut xs: Vec<Tensor> = Vec::with_capacity(k);
        let mut g0 = 0;
        while g0 < k {
            let g1 = (g0 + group).min(k);
            for l in 0..self.local_layers {
                self.fault_check(l)?;
                for i in g0..g1 {
                    if l == 0 {
                        let x = self.chunk_in(tokens, &chunks[i])?;
                        xs.push(x);
                    } else {
                        // consume chunk i's MLP all-reduce from layer l-1
                        self.recv_reduced_apply(&mut xs[i])?;
                    }
                    let partial = self.run_attn(slot, l, &xs[i], chunks[i].offset)?;
                    self.submit(partial.data, chunks[i].len, &mut xs[i])?;
                }
                for i in g0..g1 {
                    self.recv_reduced_apply(&mut xs[i])?;
                    let partial = self.run_mlp(l, &xs[i])?;
                    self.submit(partial.data, chunks[i].len, &mut xs[i])?;
                }
            }
            for i in g0..g1 {
                self.recv_reduced_apply(&mut xs[i])?;
                if !self.is_last_stage() {
                    self.send_stage(std::mem::take(&mut xs[i]))?;
                }
            }
            g0 = g1;
        }
        Ok(xs)
    }

    /// Fig 1(a): strict compute → comm → compute → comm, chunk-major.
    /// Under pipeline stages the chunk-major order forwards each chunk
    /// the moment its last layer lands, so even the serial baseline
    /// pipelines across stages (it just never overlaps within one).
    /// With `ladder_residual` (DESIGN.md §12, numerics-changing) the MLP
    /// reads the pre-attention residual so both block collectives are in
    /// flight while it computes.
    fn prefill_blocking(
        &mut self,
        slot: usize,
        tokens: &[i32],
        chunks: &[ChunkJob],
    ) -> Result<Vec<Tensor>> {
        let mut xs: Vec<Tensor> = Vec::with_capacity(chunks.len());
        for c in chunks {
            let mut x = self.chunk_in(tokens, c)?;
            for l in 0..self.local_layers {
                self.fault_check(l)?;
                if self.ladder {
                    let pa = self.run_attn(slot, l, &x, c.offset)?;
                    self.submit_plain(pa.data, c.len)?;
                    let pm = self.run_mlp(l, &x)?;
                    self.submit_plain(pm.data, c.len)?;
                    self.recv_reduced_apply(&mut x)?;
                    self.recv_reduced_apply(&mut x)?;
                } else {
                    let partial = self.run_attn(slot, l, &x, c.offset)?;
                    self.submit(partial.data, c.len, &mut x)?;
                    self.recv_reduced_apply(&mut x)?;
                    let partial = self.run_mlp(l, &x)?;
                    self.submit(partial.data, c.len, &mut x)?;
                    self.recv_reduced_apply(&mut x)?;
                }
            }
            if !self.is_last_stage() {
                self.send_stage(std::mem::take(&mut x))?;
            }
            xs.push(x);
        }
        Ok(xs)
    }

    /// One decode step (t = 1): blocking schedule — the paper finds
    /// overlap unprofitable in decode (§1, §6) and so do we. The single
    /// row flows through the stages like a one-chunk pipeline.
    fn decode(&mut self, slot: usize, token: i32, offset: usize) -> Result<Option<Vec<f32>>> {
        if self.cp > 1 && !self.cp_owns_lane() {
            // Decode is not sequence-parallel (DESIGN.md §17): only the
            // last CP group, which holds the full KV prefix, decodes.
            return Ok(None);
        }
        self.ensure_slot(slot);
        let mut x = if self.stage == 0 {
            self.run_embed(&[token])?
        } else {
            self.recv_stage(1)?
        };
        for l in 0..self.local_layers {
            self.fault_check(l)?;
            if self.ladder {
                let pa = self.run_attn(slot, l, &x, offset)?;
                self.submit_plain(pa.data, 1)?;
                let pm = self.run_mlp(l, &x)?;
                self.submit_plain(pm.data, 1)?;
                self.recv_reduced_apply(&mut x)?;
                self.recv_reduced_apply(&mut x)?;
            } else {
                let partial = self.run_attn(slot, l, &x, offset)?;
                self.submit(partial.data, 1, &mut x)?;
                self.recv_reduced_apply(&mut x)?;
                let partial = self.run_mlp(l, &x)?;
                self.submit(partial.data, 1, &mut x)?;
                self.recv_reduced_apply(&mut x)?;
            }
        }
        if !self.is_last_stage() {
            self.send_stage(x)?;
            return Ok(None);
        }
        if self.is_reply {
            Ok(Some(self.run_logits(&x)?.data))
        } else {
            Ok(None)
        }
    }

    /// Embed the decode lane's tokens into one `B × d_model` activation.
    fn embed_lane(&mut self, lane: &[DecodeSlot]) -> Result<Tensor> {
        let d = self.d_model;
        let mut x = Tensor::zeros(vec![lane.len(), d]);
        for (j, s) in lane.iter().enumerate() {
            self.ensure_slot(s.slot);
            let e = self.run_embed(&[s.token])?;
            x.data[j * d..(j + 1) * d].copy_from_slice(&e.data);
        }
        Ok(x)
    }

    /// Assemble the lane's per-slot t=1 attention partials (each row has
    /// its own cache and offset) into one B-row buffer ready for a fused
    /// collective. `row` is a reusable 1×d scratch tensor.
    fn lane_attn_partial(
        &mut self,
        layer: usize,
        lane: &[DecodeSlot],
        x_lane: &Tensor,
        row: &mut Tensor,
    ) -> Result<Vec<f32>> {
        let d = self.d_model;
        let mut fused = self.take_scratch(lane.len() * d);
        for (j, s) in lane.iter().enumerate() {
            self.ensure_slot(s.slot);
            row.data.copy_from_slice(&x_lane.data[j * d..(j + 1) * d]);
            let p = self.run_attn(s.slot, layer, &*row, s.offset)?;
            fused[j * d..(j + 1) * d].copy_from_slice(&p.data);
        }
        Ok(fused)
    }

    /// Lane attention for one layer: per-slot t=1 attention, partials
    /// concatenated into **one** fused B-row collective (the lane's
    /// residual rides along under the fused epilogue).
    fn lane_attn_submit(
        &mut self,
        layer: usize,
        lane: &[DecodeSlot],
        x_lane: &mut Tensor,
        row: &mut Tensor,
    ) -> Result<()> {
        let p = self.lane_attn_partial(layer, lane, &*x_lane, row)?;
        self.submit_fused(p, lane.len(), x_lane)
    }

    /// The lane's MLP partial for one layer: position-free, so it runs as
    /// **one B-row GEMM** when a stage of exactly that width is compiled;
    /// otherwise per-row launches.
    fn lane_mlp_partial(
        &mut self,
        layer: usize,
        x_lane: &Tensor,
        row: &mut Tensor,
    ) -> Result<Vec<f32>> {
        let d = self.d_model;
        let b = x_lane.shape[0];
        if b > 1 && self.lane_gemm && self.mlp.contains_key(&b) {
            Ok(self.run_mlp(layer, x_lane)?.data)
        } else {
            let mut fused = self.take_scratch(b * d);
            for j in 0..b {
                row.data.copy_from_slice(&x_lane.data[j * d..(j + 1) * d]);
                let p = self.run_mlp(layer, &*row)?;
                fused[j * d..(j + 1) * d].copy_from_slice(&p.data);
            }
            Ok(fused)
        }
    }

    /// Lane MLP for one layer; the partials go out as one fused
    /// collective (residual riding along under the fused epilogue).
    fn lane_mlp_submit(
        &mut self,
        layer: usize,
        x_lane: &mut Tensor,
        row: &mut Tensor,
    ) -> Result<()> {
        let b = x_lane.shape[0];
        let p = self.lane_mlp_partial(layer, &*x_lane, row)?;
        self.submit_fused(p, b, x_lane)
    }

    /// Rank-0 logits for every lane row.
    fn lane_logits(&mut self, x_lane: &Tensor, row: &mut Tensor) -> Result<Vec<Vec<f32>>> {
        let d = self.d_model;
        let b = x_lane.shape[0];
        let mut out = Vec::with_capacity(b);
        for j in 0..b {
            row.data.copy_from_slice(&x_lane.data[j * d..(j + 1) * d]);
            out.push(self.run_logits(&*row)?.data);
        }
        Ok(out)
    }

    /// Fused decode-only step: the whole lane advances one token with
    /// `2 × local_layers` collectives per stage instead of `B ×` that —
    /// bit-identical to B independent [`ComputeWorker::decode`] steps.
    /// The lane's single B-row activation flows through the stages.
    /// `ladder_residual` deliberately does **not** apply here: under the
    /// mixed scheduler a lane-only iteration and a prefill+lane
    /// iteration must use identical lane math, so the ladder reorder is
    /// confined to the per-sequence blocking paths (DESIGN.md §12).
    fn decode_fused(&mut self, lane: &[DecodeSlot]) -> Result<Option<Vec<Vec<f32>>>> {
        debug_assert!(!lane.is_empty());
        let mut x_lane = if self.stage == 0 {
            self.embed_lane(lane)?
        } else {
            self.recv_stage(lane.len())?
        };
        let mut row = Tensor::zeros(vec![1, self.d_model]);
        for l in 0..self.local_layers {
            self.fault_check(l)?;
            self.lane_attn_submit(l, lane, &mut x_lane, &mut row)?;
            self.recv_reduced_apply(&mut x_lane)?;
            self.lane_mlp_submit(l, &mut x_lane, &mut row)?;
            self.recv_reduced_apply(&mut x_lane)?;
        }
        if !self.is_last_stage() {
            self.send_stage(x_lane)?;
            return Ok(None);
        }
        if self.is_reply {
            Ok(Some(self.lane_logits(&x_lane, &mut row)?))
        } else {
            Ok(None)
        }
    }

    /// Embed a speculative verify lane into one `ΣW × d_model`
    /// activation, window rows in lane order.
    fn embed_spec(&mut self, lane: &[SpecSlot]) -> Result<Tensor> {
        let d = self.d_model;
        let rows: usize = lane.iter().map(SpecSlot::width).sum();
        let mut x = Tensor::zeros(vec![rows, d]);
        let mut r = 0;
        for w in lane {
            self.ensure_slot(w.slot);
            for &t in &w.tokens {
                let e = self.run_embed(&[t])?;
                x.data[r * d..(r + 1) * d].copy_from_slice(&e.data);
                r += 1;
            }
        }
        Ok(x)
    }

    /// Assemble the verify lane's attention partials for one layer: each
    /// window's rows run t=1 attention at consecutive offsets — row `j`
    /// writes its K/V at `offset + j` before attending, so within a
    /// window the causal chain over the draft tokens is exact.
    fn spec_attn_partial(
        &mut self,
        layer: usize,
        lane: &[SpecSlot],
        x_lane: &Tensor,
        row: &mut Tensor,
    ) -> Result<Vec<f32>> {
        let d = self.d_model;
        let rows = x_lane.shape[0];
        let mut fused = self.take_scratch(rows * d);
        let mut r = 0;
        for w in lane {
            self.ensure_slot(w.slot);
            for j in 0..w.tokens.len() {
                row.data.copy_from_slice(&x_lane.data[r * d..(r + 1) * d]);
                let p = self.run_attn(w.slot, layer, &*row, w.offset + j)?;
                fused[r * d..(r + 1) * d].copy_from_slice(&p.data);
                r += 1;
            }
        }
        Ok(fused)
    }

    /// Verify-lane attention for one layer: every row's partial
    /// concatenates into **one** fused `ΣW`-row collective, the wide-lane
    /// reuse of `allreduce_rows_fused` (DESIGN.md §10), with the lane's
    /// residual riding along under the fused epilogue.
    fn spec_attn_submit(
        &mut self,
        layer: usize,
        lane: &[SpecSlot],
        x_lane: &mut Tensor,
        row: &mut Tensor,
    ) -> Result<()> {
        let rows = x_lane.shape[0];
        let p = self.spec_attn_partial(layer, lane, &*x_lane, row)?;
        self.submit_fused(p, rows, x_lane)
    }

    /// Speculative verify step over the whole lane: `2 × n_layers` fused
    /// collectives total, each `ΣW` rows wide. Per-row execution makes
    /// every row's logits bit-identical to the chain of single-token
    /// [`ComputeWorker::decode`] steps over the same token prefix, which
    /// is what lets greedy acceptance guarantee baseline-identical
    /// emissions. Returns one logits vector per lane row (rank 0).
    fn verify_fused(&mut self, lane: &[SpecSlot]) -> Result<Option<Vec<Vec<f32>>>> {
        debug_assert!(!lane.is_empty());
        let rows: usize = lane.iter().map(SpecSlot::width).sum();
        let mut x_lane = if self.stage == 0 {
            self.embed_spec(lane)?
        } else {
            self.recv_stage(rows)?
        };
        let mut row = Tensor::zeros(vec![1, self.d_model]);
        for l in 0..self.local_layers {
            self.fault_check(l)?;
            self.spec_attn_submit(l, lane, &mut x_lane, &mut row)?;
            self.recv_reduced_apply(&mut x_lane)?;
            self.lane_mlp_submit(l, &mut x_lane, &mut row)?;
            self.recv_reduced_apply(&mut x_lane)?;
        }
        if !self.is_last_stage() {
            self.send_stage(x_lane)?;
            return Ok(None);
        }
        if self.is_reply {
            Ok(Some(self.lane_logits(&x_lane, &mut row)?))
        } else {
            Ok(None)
        }
    }

    /// The speculative mixed iteration: same interleave as
    /// [`ComputeWorker::step_mixed`] — prefill chunk attentions launch
    /// first so their collectives fly while the verify lane computes, and
    /// the lane's wide fused collectives hide behind prefill compute —
    /// with the decode lane replaced by verify windows. FIFO order per
    /// layer: `[P_attn×k, V_attn, P_mlp×k, V_mlp]`.
    fn step_mixed_spec(&mut self, p: &StepPrefill, lane: &[SpecSlot]) -> Result<StepLogits> {
        self.ensure_slot(p.slot);
        // Under cp > 1 only the last group reaches the mixed schedules
        // (earlier groups are lane-gated in `exec_step`), so the prefix
        // recv below is the whole shard-ring interaction: the last group
        // never forwards.
        let (chunks, prefix, _) = self.cp_span(&p.chunks);
        self.cp_recv_prefix(p.slot, prefix)?;
        let k = chunks.len();
        let lane_rows: usize = lane.iter().map(SpecSlot::width).sum();
        let mut xs: Vec<Tensor> = Vec::with_capacity(k);
        let mut x_lane =
            if self.stage == 0 { self.embed_spec(lane)? } else { Tensor::default() };
        let mut row = Tensor::zeros(vec![1, self.d_model]);

        for l in 0..self.local_layers {
            self.fault_check(l)?;
            for i in 0..k {
                if l == 0 {
                    let x = self.chunk_in(&p.tokens, &chunks[i])?;
                    xs.push(x);
                } else {
                    self.recv_reduced_apply(&mut xs[i])?;
                }
                let partial = self.run_attn(p.slot, l, &xs[i], chunks[i].offset)?;
                self.submit(partial.data, chunks[i].len, &mut xs[i])?;
            }
            if l == 0 && self.stage > 0 {
                // Wire order is [chunks…, lane]: the upstream stage
                // forwards its chunk set first, the lane last.
                x_lane = self.recv_stage(lane_rows)?;
            }
            if l > 0 {
                self.recv_reduced_apply(&mut x_lane)?;
            }
            self.spec_attn_submit(l, lane, &mut x_lane, &mut row)?;
            for i in 0..k {
                self.recv_reduced_apply(&mut xs[i])?;
                let partial = self.run_mlp(l, &xs[i])?;
                self.submit(partial.data, chunks[i].len, &mut xs[i])?;
            }
            self.recv_reduced_apply(&mut x_lane)?;
            self.lane_mlp_submit(l, &mut x_lane, &mut row)?;
        }
        for x in xs.iter_mut() {
            self.recv_reduced_apply(x)?;
            if !self.is_last_stage() {
                self.send_stage(std::mem::take(x))?;
            }
        }
        self.recv_reduced_apply(&mut x_lane)?;
        if !self.is_last_stage() {
            self.send_stage(x_lane)?;
            return Ok((None, None));
        }

        if self.is_reply {
            let last_idx = chunks.iter().position(|c| c.last).expect("no last chunk");
            let prefill_logits = self.logits_row_of(&xs[last_idx], p.logits_row)?;
            let lane_logits = self.lane_logits(&x_lane, &mut row)?;
            Ok((Some(prefill_logits), Some(lane_logits)))
        } else {
            Ok((None, None))
        }
    }

    /// The mixed iteration (Fig 1c ∘ 1d): the prefill chunks run the ISO
    /// pipeline while the decode lane's compute slides into the windows
    /// where the prefill's collectives are on the ring, and the lane's
    /// fused collectives fly under prefill compute. Submission and
    /// consumption orders are FIFO-matched per layer:
    /// `[P_attn×k, D_attn, P_mlp×k, D_mlp]`.
    fn step_mixed(
        &mut self,
        p: &StepPrefill,
        lane: &[DecodeSlot],
    ) -> Result<StepLogits> {
        self.ensure_slot(p.slot);
        // See `step_mixed_spec`: under cp > 1 only the last group runs
        // the mixed schedule, over its own chunk slice.
        let (chunks, prefix, _) = self.cp_span(&p.chunks);
        self.cp_recv_prefix(p.slot, prefix)?;
        let k = chunks.len();
        let mut xs: Vec<Tensor> = Vec::with_capacity(k);
        let mut x_lane =
            if self.stage == 0 { self.embed_lane(lane)? } else { Tensor::default() };
        let mut row = Tensor::zeros(vec![1, self.d_model]);

        for l in 0..self.local_layers {
            self.fault_check(l)?;
            // Prefill chunk attentions launch first so their collectives
            // are on the ring while the lane computes.
            for i in 0..k {
                if l == 0 {
                    let x = self.chunk_in(&p.tokens, &chunks[i])?;
                    xs.push(x);
                } else {
                    self.recv_reduced_apply(&mut xs[i])?;
                }
                let partial = self.run_attn(p.slot, l, &xs[i], chunks[i].offset)?;
                self.submit(partial.data, chunks[i].len, &mut xs[i])?;
            }
            if l == 0 && self.stage > 0 {
                // Wire order is [chunks…, lane]: the upstream stage
                // forwards its chunk set first, the lane last.
                x_lane = self.recv_stage(lane.len())?;
            }
            if l > 0 {
                self.recv_reduced_apply(&mut x_lane)?;
            }
            self.lane_attn_submit(l, lane, &mut x_lane, &mut row)?;
            for i in 0..k {
                self.recv_reduced_apply(&mut xs[i])?;
                let partial = self.run_mlp(l, &xs[i])?;
                self.submit(partial.data, chunks[i].len, &mut xs[i])?;
            }
            self.recv_reduced_apply(&mut x_lane)?;
            self.lane_mlp_submit(l, &mut x_lane, &mut row)?;
        }
        for x in xs.iter_mut() {
            self.recv_reduced_apply(x)?;
            if !self.is_last_stage() {
                self.send_stage(std::mem::take(x))?;
            }
        }
        self.recv_reduced_apply(&mut x_lane)?;
        if !self.is_last_stage() {
            self.send_stage(x_lane)?;
            return Ok((None, None));
        }

        if self.is_reply {
            let last_idx = chunks.iter().position(|c| c.last).expect("no last chunk");
            let prefill_logits = self.logits_row_of(&xs[last_idx], p.logits_row)?;
            let decode_logits = self.lane_logits(&x_lane, &mut row)?;
            Ok((Some(prefill_logits), Some(decode_logits)))
        } else {
            Ok((None, None))
        }
    }

    /// Dispatch one `Job::Step`. The decode and spec lanes are mutually
    /// exclusive (the leader never sends both).
    fn exec_step(
        &mut self,
        prefill: Option<&StepPrefill>,
        lane: &[DecodeSlot],
        spec: &[SpecSlot],
    ) -> Result<StepLogits> {
        if !lane.is_empty() && !spec.is_empty() {
            bail!("a step cannot carry both a decode lane and a verify lane");
        }
        if self.cp > 1 && !self.cp_owns_lane() {
            // Lane work is not sequence-parallel (DESIGN.md §17): groups
            // before the last contribute their prefill shard — pulling
            // and forwarding the KV prefix inside `prefill` — and idle
            // through lane-only steps, staying in job lockstep.
            return match prefill {
                Some(p) => {
                    let logits = self.prefill(p.slot, &p.tokens, &p.chunks, p.logits_row)?;
                    Ok((logits, None))
                }
                None => Ok((None, None)),
            };
        }
        if !spec.is_empty() {
            return match prefill {
                None => Ok((None, self.verify_fused(spec)?)),
                Some(p) if self.strategy == Strategy::Iso => self.step_mixed_spec(p, spec),
                Some(p) => {
                    // Serial baseline: prefill blocks, then the fused
                    // verify lane — wide collectives without overlap.
                    let logits = self.prefill(p.slot, &p.tokens, &p.chunks, p.logits_row)?;
                    Ok((logits, self.verify_fused(spec)?))
                }
            };
        }
        match (prefill, lane.is_empty()) {
            (Some(p), true) => {
                let logits = self.prefill(p.slot, &p.tokens, &p.chunks, p.logits_row)?;
                Ok((logits, if self.is_reply { Some(Vec::new()) } else { None }))
            }
            (None, false) => Ok((None, self.decode_fused(lane)?)),
            (Some(p), false) => {
                if self.strategy == Strategy::Iso {
                    self.step_mixed(p, lane)
                } else {
                    // Serial baseline: prefill blocks, then the fused lane
                    // — collective fusion without overlap.
                    let logits = self.prefill(p.slot, &p.tokens, &p.chunks, p.logits_row)?;
                    Ok((logits, self.decode_fused(lane)?))
                }
            }
            (None, true) => Ok((None, if self.is_reply { Some(Vec::new()) } else { None })),
        }
    }

    fn release(&mut self, slot: usize) {
        self.caches.remove(&slot);
    }
}

/// Run one all-reduce job through the ring, streaming acks back to the
/// compute thread. Returns the wire bytes the job sent; a typed error
/// means a ring peer is dead (or a segment arrived corrupt) and the
/// comm thread exits with it.
#[allow(clippy::too_many_arguments)]
fn comm_reduce(
    handle: &mut RingHandle,
    job: CommJob,
    stats: &mut WorkerStats,
    acks: &Sender<SegAck>,
    recycled: &Receiver<Vec<f32>>,
    ack_pool: &mut Vec<Vec<f32>>,
    hung_up: &mut bool,
) -> Result<u64, EngineError> {
    let CommJob { mut data, rows, cols, segments, fused, residual, quant } = job;
    if fused {
        // Decode lane: rank-ordered fused-rows reduce, bit-identical
        // to per-row collectives; one ack for the whole lane.
        let b = handle.try_allreduce_rows_fused(&mut data, rows, cols, quant)?;
        stats.fused_allreduces += 1;
        stats.fused_rows += rows as u64;
        match residual {
            // Fused epilogue (DESIGN.md §12): fold the lane's
            // residual-add into the comm thread so the compute thread
            // gets the finished tensor back in one ack.
            Some(mut res) => {
                let te = Timer::start();
                debug_assert_eq!(res.len(), data.len(), "lane residual shape");
                FusedEpilogue::residual_only(&mut res, cols).apply(0, rows, &data);
                stats.fused_epilogue_ms += te.elapsed_ms();
                stats.fused_epilogue_rows += rows as u64;
                let ack =
                    SegAck { row_start: 0, rows, data: res, fused: true, spent: Some(data) };
                *hung_up = acks.send(ack).is_err();
            }
            None => {
                let ack = SegAck { row_start: 0, rows, data, fused: false, spent: None };
                *hung_up = acks.send(ack).is_err();
            }
        }
        Ok(b)
    } else if let Some(mut res) = residual {
        // Fused epilogue, segment-streamed (DESIGN.md §12): apply
        // each reduced row-range into the residual the moment the
        // collective finalizes it, so segment k's epilogue hides
        // behind the wire time of segments k+1.. — then one ack
        // returns the finished tensor.
        debug_assert_eq!(res.len(), rows * cols, "residual shape");
        let mut epi_ms = 0.0f64;
        let b = {
            let mut epilogue = FusedEpilogue::residual_only(&mut res, cols);
            handle.try_allreduce_seg_with(
                &mut data,
                rows,
                cols,
                quant,
                segments.max(1),
                |row_start, row_end, vals| {
                    let te = Timer::start();
                    epilogue.apply(row_start, row_end, vals);
                    epi_ms += te.elapsed_ms();
                },
            )?
        };
        stats.fused_epilogue_ms += epi_ms;
        stats.fused_epilogue_rows += rows as u64;
        let ack = SegAck { row_start: 0, rows, data: res, fused: true, spent: Some(data) };
        *hung_up = acks.send(ack).is_err();
        Ok(b)
    } else if segments <= 1 {
        // Single segment: hand the whole payload over, no copy.
        let b = handle.try_allreduce_seg(&mut data, rows, cols, quant, 1)?;
        let ack = SegAck { row_start: 0, rows, data, fused: false, spent: None };
        *hung_up = acks.send(ack).is_err();
        Ok(b)
    } else {
        let acks_ref = &acks;
        let recycled_ref = &recycled;
        let ack_pool_ref = ack_pool;
        let hung_up_ref = hung_up;
        let b = handle.try_allreduce_seg_with(
            &mut data,
            rows,
            cols,
            quant,
            segments,
            |row_start, row_end, vals| {
                // Pool first, then buffers the compute thread has
                // already returned mid-collective, then allocate.
                let mut buf = ack_pool_ref
                    .pop()
                    .or_else(|| recycled_ref.try_recv().ok())
                    .unwrap_or_default();
                buf.clear();
                buf.extend_from_slice(vals);
                let ack = SegAck {
                    row_start,
                    rows: row_end - row_start,
                    data: buf,
                    fused: false,
                    spent: None,
                };
                if acks_ref.send(ack).is_err() {
                    *hung_up_ref = true;
                }
            },
        )?;
        // The job payload stays on this side; feed it to the wire pool.
        handle.recycle_f32(data);
        Ok(b)
    }
}

/// Comm-thread main loop: drain all-reduce jobs through the ring. Jobs
/// carrying a residual run the fused epilogue (DESIGN.md §12): each
/// reduced row-segment is applied into the residual inside the
/// collective's own segment callback, and one ack returns the finished
/// tensor (plus the spent partial for buffer reuse). Legacy jobs stream
/// per-segment acks so the compute thread starts on segment 0 without
/// waiting for the tail. Ack buffers come back through `recycled` and
/// wire buffers live in the ring handle's pool — steady state allocates
/// nothing.
///
/// Supervision (DESIGN.md §14): the fault injector is polled before
/// each job so a planned ring poison flags the next wire segment, and a
/// ring fault (dead peer, corrupt segment) posts a typed
/// [`SupervisionEvent`] and exits the loop — the dropped channel
/// endpoints then cascade the failure to the ring successor and this
/// rank's compute thread, so no peer blocks forever.
#[allow(clippy::too_many_arguments)]
fn comm_main(
    rank: usize,
    mut handle: RingHandle,
    jobs: Receiver<CommJob>,
    acks: Sender<SegAck>,
    recycled: Receiver<Vec<f32>>,
    injector: Arc<FaultInjector>,
    events: Sender<SupervisionEvent>,
) -> WorkerStats {
    let mut stats = WorkerStats { rank, ..Default::default() };
    // Buffers for streamed ack payloads, refilled by the compute thread.
    let mut ack_pool: Vec<Vec<f32>> = Vec::new();
    while let Ok(job) = jobs.recv() {
        while let Ok(buf) = recycled.try_recv() {
            if ack_pool.len() < 64 {
                ack_pool.push(buf);
            } else {
                handle.recycle_f32(buf);
            }
        }
        if injector.poll_wire(rank, false) {
            handle.poison_next_send();
        }
        let t = Timer::start();
        let mut hung_up = false;
        let rung = job.quant;
        let bytes = match comm_reduce(
            &mut handle,
            job,
            &mut stats,
            &acks,
            &recycled,
            &mut ack_pool,
            &mut hung_up,
        ) {
            Ok(b) => b,
            Err(error) => {
                events.send(SupervisionEvent { rank, error }).ok();
                break;
            }
        };
        stats.comm_ms += t.elapsed_ms();
        stats.wire_bytes += bytes;
        stats.wire_bytes_by_rung[rung.index()] += bytes;
        stats.allreduces += 1;
        if hung_up {
            break; // compute thread gone (shutdown)
        }
    }
    stats.wire_msgs = handle.sent_msgs;
    stats
}

/// Compute-thread main loop.
#[allow(clippy::too_many_arguments)]
/// Turn a caught panic payload into a human-readable detail string.
fn panic_detail(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Supervised compute-thread entry point (DESIGN.md §14): runs
/// [`compute_loop`] under `catch_unwind` so a worker panic or typed
/// fault becomes a [`SupervisionEvent`] for the leader instead of a
/// silently poisoned channel. The thread then exits; its dropped
/// channel endpoints cascade the failure to the comm thread and ring
/// peers so nobody blocks forever.
#[allow(clippy::too_many_arguments)]
fn compute_main(
    rank: usize,
    cfg: EngineConfig,
    manifest: Manifest,
    jobs: Receiver<Job>,
    reply: Option<Sender<Reply>>,
    port: StagePort,
    shard_ring: RingPass,
    to_comm: Sender<CommJob>,
    from_comm: Receiver<SegAck>,
    recycle_tx: Sender<Vec<f32>>,
    injector: Arc<FaultInjector>,
    events: Sender<SupervisionEvent>,
) -> Result<WorkerStats> {
    let outcome = catch_unwind(AssertUnwindSafe(|| {
        compute_loop(
            rank, cfg, manifest, jobs, reply, port, shard_ring, to_comm, from_comm, recycle_tx,
            injector,
        )
    }));
    match outcome {
        Ok(Ok(stats)) => Ok(stats),
        Ok(Err(e)) => {
            // Typed `EngineError`s were lifted into `anyhow::Error` on the
            // way up; their Display (e.g. "injected kill") survives in the
            // chain-formatted detail, which is what the leader logs.
            let error = EngineError::WorkerPanic { rank, detail: format!("{e:#}") };
            events.send(SupervisionEvent { rank, error }).ok();
            Err(e)
        }
        Err(payload) => {
            let detail = panic_detail(payload);
            let error = EngineError::WorkerPanic { rank, detail: detail.clone() };
            events.send(SupervisionEvent { rank, error }).ok();
            Err(anyhow!("worker {rank} panicked: {detail}"))
        }
    }
}

/// The un-supervised body of a compute thread: build the worker, then
/// drain jobs until shutdown or a channel peer dies.
#[allow(clippy::too_many_arguments)]
fn compute_loop(
    rank: usize,
    cfg: EngineConfig,
    manifest: Manifest,
    jobs: Receiver<Job>,
    reply: Option<Sender<Reply>>,
    port: StagePort,
    shard_ring: RingPass,
    to_comm: Sender<CommJob>,
    from_comm: Receiver<SegAck>,
    recycle_tx: Sender<Vec<f32>>,
    injector: Arc<FaultInjector>,
) -> Result<WorkerStats> {
    let mut w = ComputeWorker::build(
        rank, &cfg, manifest, port, shard_ring, to_comm, from_comm, recycle_tx, injector,
    )
    .with_context(|| format!("building worker {rank}"))?;
    while let Ok(job) = jobs.recv() {
        match job {
            Job::Step { prefill, decode, spec } => {
                let (prefill_logits, decode_logits) =
                    w.exec_step(prefill.as_deref(), &decode, &spec)?;
                if let Some(tx) = &reply {
                    tx.send(Reply::Step {
                        prefill: prefill_logits,
                        decode: decode_logits.unwrap_or_default(),
                    })
                    .ok();
                }
            }
            Job::Decode { slot, token, offset } => {
                let logits = w.decode(slot, token, offset)?;
                if let (Some(tx), Some(row)) = (&reply, logits) {
                    tx.send(Reply::Logits(row)).ok();
                }
            }
            Job::Release { slot } => {
                w.release(slot);
                if let Some(tx) = &reply {
                    tx.send(Reply::Released).ok();
                }
            }
            Job::Shutdown => break,
        }
    }
    w.stats.p2p_bytes = w.port.sent_bytes;
    w.stats.p2p_msgs = w.port.sent_msgs;
    w.stats.cp_shard_bytes = w.shard_ring.sent_bytes;
    w.stats.cp_shard_msgs = w.shard_ring.sent_msgs;
    Ok(w.stats)
}

// ---------------------------------------------------------------------------
// Mesh (one spawned generation of worker threads)
// ---------------------------------------------------------------------------

/// One spawned generation of the rank mesh: every compute/comm thread
/// pair, the leader-facing channels, and the supervision event queue.
/// Recovery (DESIGN.md §14) tears a generation down wholesale and
/// spawns a fresh one — rebuilding weight shards, KV slabs, ring
/// membership, and stage ports in one move — rather than surgically
/// splicing a replacement rank into a half-dead ring.
struct Mesh {
    job_txs: Vec<Sender<Job>>,
    reply_rx: Receiver<Reply>,
    event_rx: Receiver<SupervisionEvent>,
    compute_joins: Vec<JoinHandle<Result<WorkerStats>>>,
    comm_joins: Vec<JoinHandle<WorkerStats>>,
}

impl Mesh {
    /// Spawn `cp × pp × tp` compute/comm thread pairs: each CP group is
    /// a full `pp × tp` grid — one TP ring per stage, stages chained by
    /// p2p activation ports (stage s rank r → stage s+1 rank r) — and
    /// the groups are chained by per-(stage, tp-rank) KV shard rings
    /// (DESIGN.md §17). World rank is `c × (pp × tp) + s × tp + r`. The
    /// emulated link speed, when set, throttles all three fabrics.
    fn spawn(cfg: &EngineConfig, manifest: &Manifest, injector: &Arc<FaultInjector>) -> Mesh {
        let pp = cfg.pp_stages;
        let tp = cfg.tp;
        let cp = cfg.cp.max(1);
        let throttle = cfg.link_mbps.map(|mbps| crate::collective::Throttle {
            alpha_s: cfg.link_alpha_us * 1e-6,
            bytes_per_s: mbps * 1e6,
        });
        let (reply_tx, reply_rx) = channel();
        let (event_tx, event_rx) = channel();
        let mut job_txs = Vec::new();
        let mut compute_joins = Vec::new();
        let mut comm_joins = Vec::new();
        // One cyclic shard ring per (stage, tp-rank) pair, its ports
        // handed out to the CP groups in ascending group order.
        let mut shard_chains: Vec<std::vec::IntoIter<RingPass>> =
            (0..pp * tp).map(|_| cp_ring(cp).into_iter()).collect();
        for c in 0..cp {
            for (stage, ports_s) in stage_grid(pp, tp).into_iter().enumerate() {
                let rings = ring(tp);
                for (r, (mut ring_handle, mut port)) in rings.into_iter().zip(ports_s).enumerate()
                {
                    let rank = c * (pp * tp) + stage * tp + r;
                    let mut shard_ring = shard_chains[stage * tp + r]
                        .next()
                        .expect("invariant: one shard port per CP group");
                    let (job_tx, job_rx) = channel();
                    let (to_comm, comm_rx) = channel();
                    let (ack_tx, from_comm) = channel();
                    let (recycle_tx, recycle_rx) = channel();
                    if let Some(t) = throttle {
                        ring_handle.throttle = Some(t);
                        port.throttle = Some(t);
                        shard_ring.throttle = Some(t);
                    }
                    let inj_comm = Arc::clone(injector);
                    let ev_comm = event_tx.clone();
                    comm_joins.push(
                        std::thread::Builder::new()
                            .name(format!("iso-comm-{rank}"))
                            .spawn(move || {
                                comm_main(
                                    rank, ring_handle, comm_rx, ack_tx, recycle_rx, inj_comm,
                                    ev_comm,
                                )
                            })
                            .expect("spawn comm thread"),
                    );
                    let reply = if c == cp - 1 && stage == pp - 1 && r == 0 {
                        Some(reply_tx.clone())
                    } else {
                        None
                    };
                    let cfg_c = cfg.clone();
                    let manifest_c = manifest.clone();
                    let inj_compute = Arc::clone(injector);
                    let ev_compute = event_tx.clone();
                    compute_joins.push(
                        std::thread::Builder::new()
                            .name(format!("iso-compute-{rank}"))
                            .spawn(move || {
                                compute_main(
                                    rank, cfg_c, manifest_c, job_rx, reply, port, shard_ring,
                                    to_comm, from_comm, recycle_tx, inj_compute, ev_compute,
                                )
                            })
                            .expect("spawn compute thread"),
                    );
                    job_txs.push(job_tx);
                }
            }
        }
        Mesh { job_txs, reply_rx, event_rx, compute_joins, comm_joins }
    }

    /// Tear the generation down and collect every worker's stats. Drops
    /// all job senders first so each compute loop's `jobs.recv()` errors
    /// out, then joins. Termination argument (DESIGN.md §14): mpsc sends
    /// never block, so every loop either drains its finite buffered work
    /// or errors on a dead peer; a stalled rank bounds the join by its
    /// stall duration, it cannot extend it forever.
    fn join_all(mut self) -> (Vec<Result<WorkerStats>>, Vec<WorkerStats>) {
        self.job_txs.clear();
        drop(self.reply_rx);
        drop(self.event_rx);
        let computes: Vec<Result<WorkerStats>> = self
            .compute_joins
            .into_iter()
            .enumerate()
            .map(|(rank, j)| {
                j.join().unwrap_or_else(|p| {
                    Err(anyhow!("worker {rank} panicked: {}", panic_detail(p)))
                })
            })
            .collect();
        let comms: Vec<WorkerStats> =
            self.comm_joins.into_iter().map(|j| j.join().unwrap_or_default()).collect();
        (computes, comms)
    }
}

/// A live sequence's replay record for checkpoint-free recovery:
/// everything needed to rebuild its KV bit-identically on a fresh mesh
/// (DESIGN.md §14). `tokens` are the sequence's emissions so far; the
/// last one has not been fed back yet and is re-fed by the resumed
/// serving loop, not the replay.
struct ReplaySeq {
    slot: usize,
    prompt: Vec<i32>,
    tokens: Vec<i32>,
}

// ---------------------------------------------------------------------------
// Engine (leader)
// ---------------------------------------------------------------------------

/// The leader: owns the worker threads and the request-facing API.
pub struct Engine {
    cfg: EngineConfig,
    /// The loaded artifact manifest (model geometry, compiled sizes).
    pub manifest: Manifest,
    /// Current mesh generation; `None` only transiently inside
    /// recovery/shutdown (and permanently after shutdown consumed it).
    mesh: Option<Mesh>,
    /// Shared fault injector — the same plan survives mesh respawns so
    /// a multi-event plan keeps firing across recoveries.
    injector: Arc<FaultInjector>,
    /// EMA of observed iteration wall time, the base of the leader's
    /// detection deadline (DESIGN.md §14).
    iter_ema_ms: f64,
    /// True while recovery replays KV; suppresses request metrics so a
    /// recovered run reports the same counters as a fault-free one.
    replaying: bool,
    /// Worker stats folded out of dead mesh generations, absorbed into
    /// the final report at shutdown.
    prior_workers: Vec<WorkerStats>,
    /// Recoveries performed so far (bounded by `cfg.max_recoveries`).
    recoveries_used: usize,
    /// Live engine counters (folded with worker stats at shutdown).
    pub metrics: EngineMetrics,
    free_slots: Vec<usize>,
    smallest_chunk: usize,
    /// Prefill chunk sizes the artifacts compile (sorted, > 1).
    chunk_sizes: Vec<usize>,
    /// Calibrated context for `split::choose_split` (satellite: the
    /// engine's balanced split agrees with the simulator's bisection).
    split_ctx: SplitContext,
}

/// Result of one mixed iteration ([`Engine::step`]).
#[derive(Clone, Debug)]
pub struct StepOut {
    /// Prefill result, if the iteration carried one.
    pub prefill: Option<PrefillOut>,
    /// Greedy next token per decode lane entry, in lane order.
    pub decode_tokens: Vec<i32>,
    /// Full logits per decode lane entry, in lane order.
    pub decode_logits: Vec<Vec<f32>>,
}

/// Result of one speculative iteration ([`Engine::step_spec`]): per
/// verify window, the greedy row tokens, the accepted-draft count, and
/// the tokens the window actually emits (`accepted + 1` greedy tokens —
/// exactly what the non-speculative chain would have produced).
#[derive(Clone, Debug)]
pub struct SpecStepOut {
    /// Prefill result, if the iteration carried one.
    pub prefill: Option<PrefillOut>,
    /// Per window: the model's greedy token for every row.
    pub row_tokens: Vec<Vec<i32>>,
    /// Per window, per row: the full logits vector — what the
    /// equivalence tests pin bit-identical to a chain of single-token
    /// decodes over the same inputs.
    pub row_logits: Vec<Vec<Vec<f32>>>,
    /// Per window: accepted draft tokens (longest matching prefix).
    pub accepted: Vec<usize>,
    /// Per window: emitted tokens (`row_tokens[..=accepted]`).
    pub emitted: Vec<Vec<i32>>,
}

/// One iteration's worth of work for the canonical [`Engine::step`]
/// entry point: at most one prefill plus at most one fused lane —
/// one-token decode rows or speculative verify windows, never both.
/// [`Engine::step_decode`] and [`Engine::step_spec`] are thin wrappers
/// building the batch from the pre-topology two-argument signatures.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepBatch<'a> {
    /// At most one prefill: `(slot, prompt)`.
    pub prefill: Option<(usize, &'a [i32])>,
    /// Fused decode lane entries (one token each), empty for none.
    pub decode: &'a [DecodeSlot],
    /// Fused speculative verify windows, empty for none.
    pub spec: &'a [SpecSlot],
}

impl Engine {
    /// Start the engine: spawn the `cp × pp × tp` worker-pair grid,
    /// compile artifacts, load weights. Everything heavyweight happens
    /// here, once.
    pub fn start(cfg: EngineConfig) -> Result<Engine> {
        if cfg.comm_segments == 0 {
            bail!("comm_segments must be >= 1");
        }
        if cfg.decode_batch == 0 {
            bail!("decode_batch must be >= 1");
        }
        if cfg.spec_ngram == 0 {
            bail!("spec_ngram must be >= 1");
        }
        if cfg.pp_stages == 0 {
            bail!("pp_stages must be >= 1");
        }
        if cfg.cp == 0 {
            bail!("cp must be >= 1");
        }
        // Overload knobs are validated here too because benches and
        // tests construct EngineConfig directly, bypassing from_map.
        if cfg.tbt_budget_ms < 0.0 {
            bail!("tbt_budget_ms must be >= 0");
        }
        if cfg.cp > 1 && cfg.tbt_budget_ms > 0.0 {
            bail!("tbt_budget_ms requires cp = 1 (bounded chunked prefill is not sharded)");
        }
        if !(cfg.kv_high_water > 0.0 && cfg.kv_high_water <= 1.0) {
            bail!("kv_high_water must be in (0, 1]");
        }
        if cfg.ttft_deadline_ms < 0.0 {
            bail!("ttft_deadline_ms must be >= 0");
        }
        let manifest = Manifest::load(&cfg.artifacts_dir)?;
        if !manifest.tp_degrees.contains(&cfg.tp) {
            bail!("tp={} not in artifacts (have {:?})", cfg.tp, manifest.tp_degrees);
        }
        if cfg.pp_stages > manifest.config.n_layers {
            bail!(
                "pp_stages {} exceeds the model's {} layers (every stage needs >= 1)",
                cfg.pp_stages,
                manifest.config.n_layers
            );
        }
        let prefill_chunks: Vec<usize> = manifest
            .chunk_lens
            .iter()
            .copied()
            .filter(|&t| t > 1 && t <= cfg.max_chunk)
            .collect();
        if prefill_chunks.is_empty() {
            bail!("no prefill chunk sizes <= max_chunk {}", cfg.max_chunk);
        }
        let smallest_chunk =
            *prefill_chunks.iter().min().expect("invariant: non-empty (checked above)");

        let plan = match &cfg.fault_plan {
            Some(spec) => FaultPlan::parse(spec).map_err(|e| anyhow!("bad fault plan: {e}"))?,
            None => FaultPlan::empty(),
        };
        let injector = Arc::new(FaultInjector::new(plan));
        let mesh = Mesh::spawn(&cfg, &manifest, &injector);

        let free_slots = (0..cfg.max_batch).rev().collect();
        let split_ctx = SplitContext::engine(&cfg);
        Ok(Engine {
            cfg,
            manifest,
            mesh: Some(mesh),
            injector,
            iter_ema_ms: 0.0,
            replaying: false,
            prior_workers: Vec::new(),
            recoveries_used: 0,
            metrics: EngineMetrics::default(),
            free_slots,
            smallest_chunk,
            chunk_sizes: prefill_chunks,
            split_ctx,
        })
    }

    /// The current mesh generation (present outside recovery/shutdown).
    fn mesh(&self) -> &Mesh {
        self.mesh.as_ref().expect("engine mesh present outside recovery/shutdown")
    }

    /// Send one job to every rank. Bulky payloads are `Arc`-shared, so
    /// the per-rank clone is cheap. A dead rank's dropped receiver turns
    /// into a typed [`EngineError::RankDead`] instead of a panic.
    fn broadcast(&self, job: Job) -> Result<()> {
        for (i, tx) in self.mesh().job_txs.iter().enumerate() {
            tx.send(job.clone()).map_err(|_| EngineError::RankDead { rank: i, link: "job" })?;
        }
        Ok(())
    }

    /// Global rank of the reply-owning worker (last CP group, last
    /// stage, ring rank 0).
    fn reply_rank(&self) -> usize {
        let pp = self.cfg.pp_stages;
        let tp = self.cfg.tp;
        (self.cfg.cp.max(1) - 1) * pp * tp + (pp - 1) * tp
    }

    /// Leader detection deadline for one iteration (DESIGN.md §14):
    /// `fault_slack ×` the observed iteration EMA, floored so cold
    /// starts and compilation pauses don't trip false positives.
    fn deadline_ms(&self) -> f64 {
        self.cfg.fault_slack * self.iter_ema_ms.max(self.cfg.deadline_floor_ms)
    }

    /// Fold an observed iteration wall time into the deadline EMA.
    fn note_iteration_ms(&mut self, ms: f64) {
        if self.iter_ema_ms <= 0.0 {
            self.iter_ema_ms = ms;
        } else {
            self.iter_ema_ms = 0.8 * self.iter_ema_ms + 0.2 * ms;
        }
    }

    /// Await one reply under the detection deadline. On timeout or a
    /// dead reply channel, prefer the supervision queue's typed event
    /// for attribution (it names the faulting rank) over the generic
    /// link error, and count a detected fault.
    fn recv_reply(&mut self) -> Result<Reply, EngineError> {
        let deadline = self.deadline_ms();
        let mesh = self.mesh.as_ref().expect("engine mesh present outside recovery/shutdown");
        let err = match mesh.reply_rx.recv_timeout(Duration::from_secs_f64(deadline / 1e3)) {
            Ok(reply) => return Ok(reply),
            Err(RecvTimeoutError::Timeout) => EngineError::CollectiveTimeout {
                iteration: self.injector.current_iteration(),
                deadline_ms: deadline,
            },
            Err(RecvTimeoutError::Disconnected) => {
                EngineError::RankDead { rank: self.reply_rank(), link: "reply" }
            }
        };
        let err = match mesh.event_rx.try_recv() {
            Ok(ev) => ev.error,
            Err(_) => err,
        };
        self.metrics.faults_detected += 1;
        Err(err)
    }

    fn recv_logits(&mut self) -> Result<Vec<f32>> {
        match self.recv_reply()? {
            Reply::Logits(v) => Ok(v),
            other => bail!("unexpected reply {other:?}"),
        }
    }

    /// Pad a prompt to a tile-able length (appended tokens are masked out
    /// of the true-last-token logits by causality).
    fn pad(&self, prompt: &[i32]) -> Vec<i32> {
        let len = crate::workload::pad_to_chunk(prompt.len().max(2), self.smallest_chunk);
        let mut v = prompt.to_vec();
        v.resize(len, 0);
        v
    }

    /// Prefill one prompt; returns the first generated token and TTFT.
    pub fn prefill(&mut self, prompt: &[i32]) -> Result<PrefillOut> {
        let slot = self.alloc_slot()?;
        let out = self.prefill_in_slot(slot, prompt);
        self.free_slot(slot)?;
        out
    }

    /// Claim a sequence slot for iteration-level driving ([`Engine::step`]).
    pub fn alloc_slot(&mut self) -> Result<usize> {
        self.free_slots.pop().ok_or_else(|| anyhow!("no free sequence slots"))
    }

    /// Release a slot's KV caches on every rank and return it to the pool.
    pub fn free_slot(&mut self, slot: usize) -> Result<()> {
        self.broadcast(Job::Release { slot })?;
        match self.recv_reply()? {
            Reply::Released => {}
            other => bail!("bad release reply: {other:?}"),
        }
        self.free_slots.push(slot);
        Ok(())
    }

    /// Chunk count the prefill planner should aim for (DESIGN.md §11).
    /// The ISO stage schedule wavefronts chunk *pairs* between stages,
    /// so a `pp`-deep ISO pipeline needs `2 × pp` chunks — one pair per
    /// stage — to keep every stage fed; chunk-major strategies (the
    /// serial baseline) wavefront single chunks and need `pp`.
    /// Single-stage engines keep the pre-PP tiling (depth 1 = largest
    /// tiles).
    fn micro_batch_depth(&self) -> usize {
        let per_group = if self.cfg.pp_stages <= 1 {
            1
        } else if self.cfg.strategy == Strategy::Iso {
            2 * self.cfg.pp_stages
        } else {
            self.cfg.pp_stages
        };
        // Under context parallelism the tiling is sliced `cp` ways
        // (DESIGN.md §17), so scale the depth to keep every group's
        // pipeline as deep as the flat engine's.
        per_group * self.cfg.cp.max(1)
    }

    /// Plan the prefill half of a step: pad, validate, tile (via the
    /// calibrated split context), locate the true-last-token logits row.
    fn plan_step_prefill(&mut self, slot: usize, prompt: &[i32]) -> Result<StepPrefill> {
        if prompt.is_empty() {
            bail!("empty prompt");
        }
        let padded = self.pad(prompt);
        if padded.len() > self.manifest.config.max_seq {
            bail!("prompt {} exceeds max_seq {}", padded.len(), self.manifest.config.max_seq);
        }
        let chunks = plan_prefill_pp(
            slot as u64,
            padded.len(),
            self.cfg.strategy,
            self.cfg.split,
            &self.chunk_sizes,
            Some(&self.split_ctx),
            self.micro_batch_depth(),
        );
        let last =
            chunks.iter().find(|c| c.last).expect("invariant: planner marks one last chunk");
        let true_last = prompt.len() - 1;
        if true_last < last.offset {
            bail!("internal: true last token not in final chunk");
        }
        let logits_row = true_last - last.offset;
        Ok(StepPrefill { slot, tokens: padded, chunks, logits_row, completes: true })
    }

    /// One mixed iteration (DESIGN.md §9): at most one prefill plus at
    /// most one fused lane — one-token decode rows or speculative
    /// verify windows over engine-managed slots, never both. Lane
    /// entries advance independent sequences, sharing one B-row
    /// collective per layer-stage. This is the canonical entry point;
    /// [`Engine::step_decode`] and [`Engine::step_spec`] are thin
    /// wrappers over it keeping the pre-topology signatures alive.
    ///
    /// # Examples
    ///
    /// Driving the engine iteration by iteration (requires
    /// `make artifacts` and a real PJRT backend, hence `no_run`):
    ///
    /// ```no_run
    /// use iso::batch::DecodeSlot;
    /// use iso::config::EngineConfig;
    /// use iso::coordinator::{Engine, StepBatch};
    ///
    /// # fn main() -> anyhow::Result<()> {
    /// let mut engine = Engine::start(EngineConfig::default())?;
    /// let slot = engine.alloc_slot()?;
    /// // Iteration 1: prefill the prompt (no lane yet).
    /// let prompt = [1, 2, 3, 4];
    /// let batch = StepBatch { prefill: Some((slot, &prompt[..])), ..Default::default() };
    /// let out = engine.step(batch)?;
    /// let first = out.prefill.expect("prefill ran").first_token;
    /// // Iteration 2: the sequence joins the fused decode lane.
    /// let lane = [DecodeSlot { slot, token: first, offset: prompt.len() }];
    /// let out = engine.step(StepBatch { decode: &lane, ..Default::default() })?;
    /// println!("next token: {}", out.decode_tokens[0]);
    /// engine.free_slot(slot)?;
    /// engine.shutdown()?;
    /// # Ok(())
    /// # }
    /// ```
    pub fn step(&mut self, batch: StepBatch<'_>) -> Result<StepOut> {
        if !batch.decode.is_empty() && !batch.spec.is_empty() {
            bail!("a step cannot carry both a decode lane and a verify lane");
        }
        let planned = match batch.prefill {
            Some((slot, prompt)) => Some(Arc::new(self.plan_step_prefill(slot, prompt)?)),
            None => None,
        };
        if planned.is_none() && batch.decode.is_empty() && batch.spec.is_empty() {
            bail!("empty step: no prefill and no lane");
        }
        let max_seq = self.manifest.config.max_seq;
        if let Some(d) = batch.decode.iter().find(|d| d.offset >= max_seq) {
            bail!("lane slot {} offset {} exceeds max_seq {max_seq}", d.slot, d.offset);
        }
        for w in batch.spec {
            if w.tokens.is_empty() {
                bail!("slot {}: empty verify window", w.slot);
            }
            if w.offset + w.width() > max_seq {
                bail!(
                    "slot {}: verify window [{}, {}) exceeds max_seq {max_seq}",
                    w.slot,
                    w.offset,
                    w.offset + w.width()
                );
            }
        }
        let lanes =
            batch.decode.iter().map(|d| d.slot).chain(batch.spec.iter().map(|w| w.slot));
        self.check_lane_slots(planned.as_deref(), lanes)?;
        self.run_step(planned, batch.decode, batch.spec, true)
    }

    /// The pre-topology two-argument mixed step — at most one prefill
    /// plus a fused decode lane — kept as a thin wrapper over
    /// [`Engine::step`] so existing callers and the A/B baselines keep
    /// compiling unchanged.
    pub fn step_decode(
        &mut self,
        prefill: Option<(usize, &[i32])>,
        decode: &[DecodeSlot],
    ) -> Result<StepOut> {
        if prefill.is_none() && decode.is_empty() {
            bail!("empty step: no prefill and no decode lane");
        }
        self.step(StepBatch { prefill, decode, spec: &[] })
    }

    /// One speculative mixed iteration (DESIGN.md §10): at most one
    /// prefill plus a fused verify lane. Each [`SpecSlot`] window runs
    /// `tokens.len()` rows at consecutive KV offsets through one wide
    /// collective per layer-stage; the result reports, per window, the
    /// greedy row tokens, the accepted-draft count, and the emitted
    /// tokens. KV rollback of rejected rows is implicit in the engine's
    /// dense caches (later windows overwrite before reading); callers
    /// tracking a paged [`KvManager`](crate::kv::KvManager) mirror the acceptance with
    /// `truncate`, as `serve_trace` does.
    ///
    /// # Examples
    ///
    /// One verify window of two drafts (requires `make artifacts` and a
    /// real PJRT backend, hence `no_run`):
    ///
    /// ```no_run
    /// use iso::batch::SpecSlot;
    /// use iso::config::EngineConfig;
    /// use iso::coordinator::Engine;
    ///
    /// # fn main() -> anyhow::Result<()> {
    /// let mut engine = Engine::start(EngineConfig::default())?;
    /// let slot = engine.alloc_slot()?;
    /// let out = engine.step_decode(Some((slot, &[1, 2, 3, 4][..])), &[])?;
    /// let first = out.prefill.expect("prefill ran").first_token;
    /// // Verify window: last emitted token + two drafted candidates.
    /// let window = SpecSlot { slot, tokens: vec![first, 7, 9], offset: 4 };
    /// let out = engine.step_spec(None, &[window])?;
    /// println!("accepted {} drafts, emitted {:?}", out.accepted[0], out.emitted[0]);
    /// engine.free_slot(slot)?;
    /// engine.shutdown()?;
    /// # Ok(())
    /// # }
    /// ```
    pub fn step_spec(
        &mut self,
        prefill: Option<(usize, &[i32])>,
        spec: &[SpecSlot],
    ) -> Result<SpecStepOut> {
        if prefill.is_none() && spec.is_empty() {
            bail!("empty step: no prefill and no verify lane");
        }
        let out = self.step(StepBatch { prefill, decode: &[], spec })?;
        Ok(self.apply_spec_out(spec, out))
    }

    /// Slice a spec step's flat row results back into windows, apply
    /// greedy acceptance, and record the speculation metrics. Shared by
    /// [`Engine::step_spec`] and the serving loop.
    fn apply_spec_out(&mut self, spec: &[SpecSlot], out: StepOut) -> SpecStepOut {
        let mut row_tokens = Vec::with_capacity(spec.len());
        let mut row_logits = Vec::with_capacity(spec.len());
        let mut accepted = Vec::with_capacity(spec.len());
        let mut emitted = Vec::with_capacity(spec.len());
        let mut logits_iter = out.decode_logits.into_iter();
        let mut r = 0;
        for w in spec {
            let rows = &out.decode_tokens[r..r + w.width()];
            r += w.width();
            let a = accept_count(w.drafts(), rows);
            self.metrics.spec_windows += 1;
            self.metrics.spec_drafted += w.drafts().len() as u64;
            self.metrics.spec_accepted += a as u64;
            self.metrics.spec_accept_hist.record(a as f64);
            self.metrics.generated_tokens += (a + 1) as u64;
            row_tokens.push(rows.to_vec());
            row_logits.push(logits_iter.by_ref().take(w.width()).collect());
            accepted.push(a);
            emitted.push(rows[..a + 1].to_vec());
        }
        SpecStepOut { prefill: out.prefill, row_tokens, row_logits, accepted, emitted }
    }

    /// Shared slot validation for the decode/verify lanes: slots in
    /// range, no duplicates, and no slot both prefilling and in the lane.
    fn check_lane_slots(
        &self,
        prefill: Option<&StepPrefill>,
        lane: impl Iterator<Item = usize>,
    ) -> Result<()> {
        let slot_cap = self.cfg.max_batch;
        let mut slots: Vec<usize> = lane.collect();
        if let Some(p) = prefill {
            if p.slot >= slot_cap {
                bail!("slot {} outside the engine's slot range (max_batch {slot_cap})", p.slot);
            }
            if slots.contains(&p.slot) {
                bail!("slot {} cannot prefill and decode in one step", p.slot);
            }
        }
        if let Some(&s) = slots.iter().find(|&&s| s >= slot_cap) {
            bail!("slot {s} outside the engine's slot range (max_batch {slot_cap})");
        }
        slots.sort_unstable();
        if let Some(w) = slots.windows(2).find(|w| w[0] == w[1]) {
            bail!("slot {} appears twice in the decode lane", w[0]);
        }
        Ok(())
    }

    /// `count_iteration` separates genuine mixed iterations (the public
    /// `step` API and the mixed serving loop) from request-level callers
    /// routed through the same job (`prefill_in_slot`), so the
    /// `iterations`/`iter_occupancy` metrics stay meaningful in the
    /// sequential A/B baseline.
    fn run_step(
        &mut self,
        prefill: Option<Arc<StepPrefill>>,
        decode: &[DecodeSlot],
        spec: &[SpecSlot],
        count_iteration: bool,
    ) -> Result<StepOut> {
        let n_chunks = prefill.as_ref().map_or(0, |p| p.chunks.len());
        let spec_rows: usize = spec.iter().map(SpecSlot::width).sum();
        let timer = Timer::start();
        self.injector.begin_iteration();
        self.broadcast(Job::Step {
            prefill: prefill.clone(),
            decode: Arc::new(decode.to_vec()),
            spec: Arc::new(spec.to_vec()),
        })?;
        let (prefill_logits, decode_logits) = match self.recv_reply()? {
            Reply::Step { prefill, decode } => (prefill, decode),
            other => bail!("unexpected step reply {other:?}"),
        };
        let elapsed = timer.elapsed_ms();
        self.note_iteration_ms(elapsed);

        if count_iteration {
            self.metrics.iterations += 1;
            self.metrics
                .iter_occupancy
                .record((n_chunks + decode.len() + spec_rows) as f64);
        }
        // Plain lane rows are one emitted token each; verify-lane
        // emissions depend on acceptance and are counted by the caller
        // (`apply_spec_out`).
        self.metrics.generated_tokens += decode.len() as u64;
        self.metrics.fused_decode_tokens += decode.len() as u64;

        let prefill_out = match (prefill, prefill_logits) {
            (Some(p), Some(logits)) => {
                // Replayed prefills rebuild KV, they don't serve a new
                // request — keep them out of the request metrics so a
                // recovered run reports like a fault-free one. A partial
                // budget-bounded slice executed chunks but emitted no
                // token yet, so only a completing slice counts toward
                // TTFT and the token tally.
                if !self.replaying {
                    self.metrics.prefill_chunks += p.chunks.len() as u64;
                    if p.completes {
                        self.metrics.ttft_ms.record(elapsed);
                        self.metrics.generated_tokens += 1;
                    }
                }
                let first_token = argmax(&logits);
                Some(PrefillOut { first_token, ttft_ms: elapsed, logits })
            }
            (None, _) => None,
            (Some(_), None) => bail!("step carried a prefill but no logits came back"),
        };
        let expected_rows = decode.len() + spec_rows;
        if decode_logits.len() != expected_rows {
            bail!("lane logits {} != lane rows {expected_rows}", decode_logits.len());
        }
        let decode_tokens = decode_logits.iter().map(|l| argmax(l)).collect();
        Ok(StepOut { prefill: prefill_out, decode_tokens, decode_logits })
    }

    fn prefill_in_slot(&mut self, slot: usize, prompt: &[i32]) -> Result<PrefillOut> {
        let planned = Arc::new(self.plan_step_prefill(slot, prompt)?);
        let out = self.run_step(Some(planned), &[], &[], false)?;
        out.prefill.ok_or_else(|| anyhow!("prefill step returned no result"))
    }

    /// One legacy per-sequence decode step on an engine-managed slot —
    /// the un-fused baseline the decode lane is tested bit-identical to.
    pub fn decode_one(&mut self, slot: usize, token: i32, offset: usize) -> Result<Vec<f32>> {
        let timer = Timer::start();
        self.injector.begin_iteration();
        self.broadcast(Job::Decode { slot, token, offset })?;
        let logits = self.recv_logits()?;
        self.note_iteration_ms(timer.elapsed_ms());
        Ok(logits)
    }

    /// Fold a dead mesh generation's stats into `prior_workers` so the
    /// shutdown report covers the whole run. Ranks that died before
    /// returning stats contribute zeros (their partial iteration never
    /// landed anywhere observable).
    fn absorb_mesh(&mut self, mesh: Mesh) {
        let tp = self.cfg.tp.max(1);
        let pp = self.cfg.pp_stages.max(1);
        let (computes, comms) = mesh.join_all();
        let mut workers: Vec<WorkerStats> = computes
            .into_iter()
            .enumerate()
            .map(|(rank, r)| {
                // Stage within the rank's CP group (world layout
                // `c × (pp × tp) + s × tp + r`, DESIGN.md §17).
                let stage = rank % (pp * tp) / tp;
                r.unwrap_or(WorkerStats { rank, stage, ..Default::default() })
            })
            .collect();
        for (w, comm) in workers.iter_mut().zip(comms.iter()) {
            w.fold_comm(comm);
        }
        if self.prior_workers.is_empty() {
            self.prior_workers = workers;
        } else {
            for (acc, w) in self.prior_workers.iter_mut().zip(workers.iter()) {
                acc.absorb(w);
            }
        }
    }

    /// Rebuild every affected sequence's KV on the fresh mesh by
    /// re-prefilling its prompt and re-feeding its emitted tokens
    /// (checkpoint-free recompute). Bit-identical by the lane-equals-
    /// chain invariant: KV contents don't depend on how the prefill was
    /// chunked or how decodes were batched.
    fn replay_sequences(&mut self, live: &[ReplaySeq]) -> Result<()> {
        for seq in live {
            self.prefill_in_slot(seq.slot, &seq.prompt)?;
            for j in 0..seq.tokens.len().saturating_sub(1) {
                self.decode_one(seq.slot, seq.tokens[j], seq.prompt.len() + j)?;
            }
        }
        Ok(())
    }

    /// One recovery round (DESIGN.md §14): tear down the dead mesh
    /// generation, spawn a fresh one (weight shards, KV slabs, ring
    /// membership, stage ports all rebuilt), and replay every live
    /// sequence's KV. The failed iteration landed nothing on the leader,
    /// so resuming from the iteration boundary drops zero sequences.
    fn recover(&mut self, cause: &anyhow::Error, live: &[ReplaySeq]) -> Result<()> {
        if self.recoveries_used >= self.cfg.max_recoveries {
            bail!("fault recovery limit ({}) exhausted: {cause:#}", self.cfg.max_recoveries);
        }
        self.recoveries_used += 1;
        let timer = Timer::start();
        let dead = self.mesh.take().expect("engine mesh present outside recovery/shutdown");
        self.absorb_mesh(dead);
        self.mesh = Some(Mesh::spawn(&self.cfg, &self.manifest, &self.injector));
        self.replaying = true;
        let replayed = self.replay_sequences(live);
        self.replaying = false;
        replayed?;
        self.metrics.recoveries += 1;
        self.metrics.replayed_seqs += live.len() as u64;
        self.metrics.replayed_tokens += live
            .iter()
            .map(|s| (s.prompt.len() + s.tokens.len().saturating_sub(1)) as u64)
            .sum::<u64>();
        self.metrics.recovery_ms.record(timer.elapsed_ms());
        Ok(())
    }

    /// Recover, retrying if another planned fault fires mid-replay
    /// (multi-event plans keep firing across mesh generations). Bounded
    /// by `cfg.max_recoveries`, after which the last cause is returned.
    fn recover_with_retry(&mut self, cause: anyhow::Error, live: &[ReplaySeq]) -> Result<()> {
        let mut cause = cause;
        loop {
            match self.recover(&cause, live) {
                Ok(()) => return Ok(()),
                Err(e) => {
                    if self.recoveries_used >= self.cfg.max_recoveries {
                        return Err(e);
                    }
                    cause = e;
                }
            }
        }
    }

    /// Prefill + `steps` greedy decode steps.
    pub fn generate(&mut self, prompt: &[i32], steps: usize) -> Result<GenOut> {
        let slot = self.alloc_slot()?;
        let result = (|| {
            let pre = self.prefill_in_slot(slot, prompt)?;
            let mut tokens = vec![pre.first_token];
            let mut decode_ms = Vec::with_capacity(steps);
            let mut offset = prompt.len();
            for _ in 0..steps.min(self.manifest.config.max_seq - offset) {
                let t = Timer::start();
                let last = *tokens.last().expect("invariant: tokens seeded with first_token");
                let logits = self.decode_one(slot, last, offset)?;
                let ms = t.elapsed_ms();
                decode_ms.push(ms);
                self.metrics.decode_ms.record(ms);
                self.metrics.generated_tokens += 1;
                tokens.push(argmax(&logits));
                offset += 1;
            }
            Ok(GenOut { tokens, ttft_ms: pre.ttft_ms, decode_ms })
        })();
        self.free_slot(slot)?;
        result
    }

    /// Serve a full trace with continuous batching. Under
    /// `cfg.mixed_iterations` (the default) this is the iteration-level
    /// mixed scheduler (DESIGN.md §9): every iteration broadcasts one
    /// `Job::Step` composing the head-of-line prefill's ISO chunks with a
    /// fused decode lane of up to `decode_batch` live sequences, so
    /// decode collectives batch B× and decode compute hides behind
    /// prefill communication. With `cfg.spec_k > 0` the decode lane
    /// speculates (DESIGN.md §10): each lane sequence verifies `spec_k`
    /// self-drafted tokens per iteration and a paged
    /// [`KvManager`](crate::kv::KvManager)
    /// mirrors the accept/rollback motion. With mixed iterations off, the
    /// legacy per-request loop runs for A/B comparison. All modes emit
    /// identical tokens.
    pub fn serve_trace(&mut self, reqs: &[crate::workload::Request]) -> Result<TraceReport> {
        if !self.cfg.mixed_iterations {
            return self.serve_trace_sequential(reqs);
        }

        /// Leader bookkeeping per live request, around the planner's
        /// scheduler-visible [`LaneSeq`].
        struct Live {
            lane: LaneSeq,
            id: u64,
            prompt: Vec<i32>,
            tokens: Vec<i32>,
            arrival_s: f64,
            /// Engine-clock ms of the last emitted token (drives TBT).
            last_emit_ms: f64,
            /// Times this sequence has been preempted (anti-livelock
            /// cap, DESIGN.md §15).
            preemptions: usize,
        }

        /// A sequence evicted by KV pressure, waiting to re-enter via
        /// checkpoint-free re-prefill of prompt + committed tokens.
        struct Preempted {
            id: u64,
            prompt: Vec<i32>,
            tokens: Vec<i32>,
            prompt_len: usize,
            decode_left: usize,
            arrival_s: f64,
            preemptions: usize,
        }

        let mut pending = sort_by_arrival(reqs);
        let mut planner = MixedPlanner::new(
            self.cfg.strategy,
            self.cfg.split,
            self.chunk_sizes.clone(),
            self.cfg.decode_batch,
            self.manifest.config.max_seq,
        )
        .with_min_chunks(self.micro_batch_depth());
        if self.cfg.tbt_budget_ms > 0.0 {
            // Lower the wall-clock TBT budget onto a per-iteration
            // prefill token cap via the cost model (DESIGN.md §15):
            // largest multiple of the smallest compiled chunk whose
            // worst-case mixed iteration still fits the budget.
            let candidates: Vec<usize> = (1..=self.manifest.config.max_seq
                / self.smallest_chunk)
                .map(|i| i * self.smallest_chunk)
                .collect();
            let budget_tokens = crate::sched::budgeted_prefill_tokens(
                &self.split_ctx.node,
                &self.split_ctx.model,
                self.cfg.split,
                self.cfg.decode_batch,
                self.manifest.config.max_seq,
                self.cfg.comm_segments,
                // The TBT budget prices any quantized prefill rung at the
                // int8 wire factor — conservative for fp8/int4, which
                // move fewer bytes still (CommQuant::is_quantized).
                self.cfg.precision().prefill.is_quantized(),
                self.cfg.tbt_budget_ms / 1e3,
                &candidates,
            );
            planner = planner.with_prefill_budget(budget_tokens);
        }
        let spec_k = self.cfg.spec_k;
        let mut proposer = NGramProposer::new(self.cfg.spec_ngram);
        // Paged KV accounting mirroring the workers' dense caches: one
        // sequence per slot, logical (unpadded) lengths, verify windows
        // appended optimistically and truncated to the accepted prefix.
        // Sized per sequence: every sequence may need a partial last
        // block, so round max_seq up to a block multiple *before*
        // multiplying by the batch size.
        let kv_block = 16usize;
        let kv_cap =
            self.cfg.max_batch * self.manifest.config.max_seq.div_ceil(kv_block) * kv_block;
        // The paged mirror is tiered (DESIGN.md §17): with `kv_offload`
        // cold pages spill to the modeled host tier under the resident
        // cap; without it an over-cap sequence is a typed admission
        // error. Cap 0 keeps the tier inert (the pre-offload mirror).
        let mut kvm = TieredKv::new(
            kv_cap,
            kv_block,
            self.cfg.kv_resident_tokens,
            self.cfg.kv_prefetch_pages,
            self.cfg.kv_offload,
        );
        let mut live: Vec<Live> = Vec::new();
        let mut preempted: std::collections::VecDeque<Preempted> =
            std::collections::VecDeque::new();
        let mut report = TraceReport::default();
        let clock = Timer::start();

        while !pending.is_empty() || !live.is_empty() || !preempted.is_empty() {
            let now_s = clock.elapsed_ms() / 1e3;

            // Overload gate (DESIGN.md §15), applied to arrived-but-
            // unserved requests before admission. Shedding: the queue is
            // arrival-sorted, so waits decrease front-to-back and stale
            // requests pop from the front. Backpressure: arrivals beyond
            // the queue bound are rejected newest-first — the submit
            // that would have overflowed the bounded queue.
            if self.cfg.ttft_deadline_ms > 0.0 {
                let deadline_s = self.cfg.ttft_deadline_ms / 1e3;
                while let Some(front) = pending.front() {
                    if front.arrival_s <= now_s && now_s - front.arrival_s > deadline_s {
                        pending.pop_front();
                        report.shed += 1;
                        self.metrics.sheds += 1;
                    } else {
                        break;
                    }
                }
            }
            if self.cfg.queue_bound > 0 {
                let mut arrived =
                    pending.iter().take_while(|r| r.arrival_s <= now_s).count();
                while arrived > self.cfg.queue_bound {
                    pending.remove(arrived - 1);
                    arrived -= 1;
                    report.rejected += 1;
                    self.metrics.rejected += 1;
                }
            }

            // Re-admit preempted sequences before fresh arrivals: their
            // re-prefill is owed work, and starving them would turn
            // preemption into silent drop. The PR-6 replay path rebuilds
            // prompt + committed tokens bit-identically (KV contents
            // don't depend on how prefill was chunked or interrupted).
            while !preempted.is_empty() && !self.free_slots.is_empty() {
                let p = preempted.pop_front().expect("checked non-empty");
                let slot = self.alloc_slot()?;
                kvm.add_seq(slot as u64);
                let replay =
                    vec![ReplaySeq { slot, prompt: p.prompt.clone(), tokens: p.tokens.clone() }];
                self.replaying = true;
                let replayed = self.replay_sequences(&replay);
                self.replaying = false;
                if let Err(e) = replayed {
                    // Fault mid-restore: recover the whole mesh with the
                    // prefilled live set plus this sequence.
                    let mut all: Vec<ReplaySeq> = live
                        .iter()
                        .filter(|l| l.lane.prefilled)
                        .map(|l| ReplaySeq {
                            slot: l.lane.slot,
                            prompt: l.prompt.clone(),
                            tokens: l.tokens.clone(),
                        })
                        .collect();
                    all.extend(replay);
                    self.recover_with_retry(e, &all)?;
                    for l in live.iter_mut().filter(|l| !l.lane.prefilled) {
                        l.lane.prefill_done = 0; // partial worker KV lost
                    }
                }
                // Committed state re-enters the lane exactly where it
                // left: offset = prompt + emissions − 1 (the last token
                // is fed by the next decode step, same as live flow).
                let committed = p.prompt.len() + p.tokens.len() - 1;
                kvm.append(slot as u64, committed)?;
                let last =
                    *p.tokens.last().expect("preempted sequences hold >= 1 token");
                live.push(Live {
                    lane: LaneSeq {
                        slot,
                        prompt_len: p.prompt_len,
                        prefilled: true,
                        prefill_done: p.prompt_len,
                        last_token: last,
                        offset: committed,
                        decode_left: p.decode_left,
                    },
                    id: p.id,
                    prompt: p.prompt,
                    tokens: p.tokens,
                    arrival_s: p.arrival_s,
                    last_emit_ms: clock.elapsed_ms(),
                    preemptions: p.preemptions,
                });
            }

            // Admission: claim a slot per arrived request; the prefill
            // itself is scheduled into a later iteration.
            while let Some(next) = pending.front() {
                if next.arrival_s > now_s && !live.is_empty() {
                    break; // not arrived yet; keep the live set moving
                }
                if self.free_slots.is_empty() {
                    break;
                }
                if next.arrival_s > now_s {
                    // idle engine: sleep until the next arrival
                    std::thread::sleep(std::time::Duration::from_secs_f64(
                        next.arrival_s - now_s,
                    ));
                }
                let r = pending.pop_front().expect("invariant: front peeked above");
                let padded_len =
                    crate::workload::pad_to_chunk(r.prompt.len().max(2), self.smallest_chunk);
                if r.prompt.is_empty() || padded_len > self.manifest.config.max_seq {
                    bail!(
                        "request {}: prompt len {} unservable (max_seq {})",
                        r.id,
                        r.prompt.len(),
                        self.manifest.config.max_seq
                    );
                }
                let slot = self.alloc_slot()?;
                kvm.add_seq(slot as u64);
                live.push(Live {
                    lane: LaneSeq {
                        slot,
                        prompt_len: padded_len,
                        prefilled: false,
                        prefill_done: 0,
                        last_token: 0,
                        offset: 0,
                        decode_left: r.decode_steps,
                    },
                    id: r.id,
                    prompt: r.prompt.clone(),
                    tokens: Vec::new(),
                    arrival_s: r.arrival_s,
                    last_emit_ms: 0.0,
                    preemptions: 0,
                });
            }

            // Retire finished sequences before composing the iteration.
            // Order-preserving removal: `live` stays in admission order so
            // the head-of-line prefill really is the first-admitted
            // sequence (a swap_remove here would starve early arrivals).
            let max_seq = self.manifest.config.max_seq;
            let mut i = 0;
            while i < live.len() {
                let l = &live[i];
                if l.lane.prefilled && !l.lane.decoding(max_seq) {
                    let l = live.remove(i);
                    report.e2e_ms.record(clock.elapsed_ms() - l.arrival_s * 1e3);
                    report.completed += 1;
                    report.generated += l.tokens.len() as u64;
                    report.completions.push((l.id, l.tokens));
                    kvm.release(l.lane.slot as u64)?;
                    self.free_slot(l.lane.slot)?;
                    continue;
                }
                i += 1;
            }

            // KV-pressure preemption (DESIGN.md §15): past the
            // high-water mark, evict the youngest prefilled sequence —
            // it has the least committed work to recompute — and
            // re-enqueue it for checkpoint-free re-prefill. Anti-livelock
            // guards: never the last prefilled sequence (someone must
            // keep draining KV), and at most `max_preemptions` evictions
            // per sequence (a hot sequence eventually pins).
            if self.cfg.kv_high_water < 1.0 {
                let total_blocks = kvm.allocator().total_blocks();
                let high_water = (total_blocks as f64 * self.cfg.kv_high_water) as usize;
                while total_blocks - kvm.free_blocks() > high_water {
                    if live.iter().filter(|l| l.lane.prefilled).count() <= 1 {
                        break;
                    }
                    let Some(vi) = live.iter().rposition(|l| {
                        l.lane.prefilled && l.preemptions < self.cfg.max_preemptions
                    }) else {
                        break;
                    };
                    let v = live.remove(vi);
                    kvm.release(v.lane.slot as u64)?;
                    self.free_slot(v.lane.slot)?;
                    report.preemptions += 1;
                    self.metrics.preemptions += 1;
                    self.metrics.preempted_tokens +=
                        (v.prompt.len() + v.tokens.len().saturating_sub(1)) as u64;
                    preempted.push_back(Preempted {
                        id: v.id,
                        prompt: v.prompt,
                        tokens: v.tokens,
                        prompt_len: v.lane.prompt_len,
                        decode_left: v.lane.decode_left,
                        arrival_s: v.arrival_s,
                        preemptions: v.preemptions + 1,
                    });
                }
            }

            if live.is_empty() {
                continue; // next lap admits (and sleeps for) the next arrival
            }

            // Saturation sample (satellite), once per executed iteration:
            // arrived-but-unadmitted requests only — `pending` also holds
            // the trace's *future* arrivals, which are not queueing. Same
            // semantics as `batch::Admission::{queue_depth, oldest_wait_s}`.
            let sample_s = clock.elapsed_ms() / 1e3;
            let arrived =
                pending.iter().take_while(|r| r.arrival_s <= sample_s).count();
            self.metrics.queue_depth.record(arrived as f64);
            if let Some(front) = pending.front() {
                if front.arrival_s <= sample_s {
                    self.metrics.queue_wait_ms.record((sample_s - front.arrival_s) * 1e3);
                }
            }

            // Compose and execute one mixed iteration. The planner's
            // chunk set is used as-is; only padding and the logits row
            // are derived here — no second planning pass.
            let lane_view: Vec<LaneSeq> = live.iter().map(|l| l.lane.clone()).collect();
            let plan = if spec_k > 0 {
                // Self-draft from the sequence's own history (prompt +
                // emissions) — the proposer sees exactly what a separate
                // draft model would.
                let live_ref = &live;
                let mut draft = |slot: usize, k: usize| {
                    let l = live_ref
                        .iter()
                        .find(|l| l.lane.slot == slot)
                        .expect("drafting for a slot that is not live");
                    let mut history =
                        Vec::with_capacity(l.prompt.len() + l.tokens.len());
                    history.extend_from_slice(&l.prompt);
                    history.extend_from_slice(&l.tokens);
                    proposer.propose(&history, k)
                };
                planner.plan_spec(&lane_view, Some(&self.split_ctx), spec_k, &mut draft)
            } else {
                planner.plan(&lane_view, Some(&self.split_ctx))
            };
            let prefill_job = match &plan.prefill {
                Some(pf) => {
                    let l =
                        live.iter().find(|l| l.lane.slot == pf.slot).expect("planned slot");
                    let last = pf.chunks.iter().find(|c| c.last).expect("plan has last chunk");
                    let slice_end = last.offset + last.len;
                    let completes = slice_end >= pf.prompt_len;
                    let true_last = l.prompt.len() - 1;
                    // A partial slice stops before the prompt's true last
                    // token; the worker still needs *a* logits row (its
                    // step contract), so point at the slice tail and
                    // discard the result below.
                    let logits_row = if completes {
                        if true_last < last.offset {
                            bail!("internal: true last token not in final chunk");
                        }
                        true_last - last.offset
                    } else {
                        last.len - 1
                    };
                    let mut tokens = l.prompt.clone();
                    tokens.resize(pf.prompt_len, 0);
                    Some(Arc::new(StepPrefill {
                        slot: pf.slot,
                        tokens,
                        chunks: pf.chunks.clone(),
                        logits_row,
                        completes,
                    }))
                }
                None => None,
            };
            let mut out = match self.run_step(prefill_job, &plan.decode, &plan.spec, true) {
                Ok(out) => out,
                Err(e) => {
                    // Fault mid-iteration (DESIGN.md §14). The failed
                    // iteration landed nothing on the leader — lane
                    // state, the paged mirror, and the planner all still
                    // describe the last good iteration boundary — so
                    // replay every prefilled live sequence onto a fresh
                    // mesh and re-plan the iteration from scratch.
                    let replay: Vec<ReplaySeq> = live
                        .iter()
                        .filter(|l| l.lane.prefilled)
                        .map(|l| ReplaySeq {
                            slot: l.lane.slot,
                            prompt: l.prompt.clone(),
                            tokens: l.tokens.clone(),
                        })
                        .collect();
                    self.recover_with_retry(e, &replay)?;
                    // Partially-prefilled sequences lost their worker KV
                    // with the old mesh; their bounded prefill restarts
                    // from token 0 (nothing was committed to the paged
                    // mirror, so only the planner cursor rolls back).
                    for l in live.iter_mut().filter(|l| !l.lane.prefilled) {
                        l.lane.prefill_done = 0;
                    }
                    continue;
                }
            };
            let now_ms = clock.elapsed_ms();
            report.iterations += 1;
            let occupancy = plan.prefill.as_ref().map_or(0, |p| p.chunks.len())
                + plan.decode.len()
                + plan.spec.iter().map(SpecSlot::width).sum::<usize>();
            report.occupancy.record(occupancy as f64);

            let prefill_result = out.prefill.take();
            if let (Some(pf), Some(pre)) = (&plan.prefill, &prefill_result) {
                let l = live
                    .iter_mut()
                    .find(|l| l.lane.slot == pf.slot)
                    .expect("prefilled slot is live");
                let slice_end = pf
                    .chunks
                    .last()
                    .map(|c| c.offset + c.len)
                    .expect("plan carries >= 1 chunk");
                if slice_end >= pf.prompt_len {
                    l.lane.prefilled = true;
                    l.lane.prefill_done = pf.prompt_len;
                    l.lane.last_token = pre.first_token;
                    l.lane.offset = l.prompt.len();
                    l.tokens.push(pre.first_token);
                    l.last_emit_ms = now_ms;
                    // The paged mirror tracks logical (unpadded) lengths.
                    kvm.append(pf.slot as u64, l.prompt.len())?;
                    report.ttft_ms.record(now_ms - l.arrival_s * 1e3);
                } else {
                    // Bounded chunked prefill: the slice advanced the
                    // worker KV but emitted nothing; the next iteration
                    // resumes at `prefill_done`. The slice-tail logits
                    // row is discarded — only the true last token's row
                    // is an emission.
                    l.lane.prefill_done = slice_end;
                }
            }
            for (j, d) in plan.decode.iter().enumerate() {
                let l = live
                    .iter_mut()
                    .find(|l| l.lane.slot == d.slot)
                    .expect("lane slot is live");
                let token = out.decode_tokens[j];
                l.lane.last_token = token;
                l.lane.offset += 1;
                l.lane.decode_left -= 1;
                l.tokens.push(token);
                kvm.append(d.slot as u64, 1)?;
                if self.cfg.kv_offload {
                    // Keep the tail window resident ahead of the decode
                    // cursor (modeled H2D overlap, DESIGN.md §17).
                    kvm.prefetch(d.slot as u64)?;
                }
                let tbt = now_ms - l.last_emit_ms;
                l.last_emit_ms = now_ms;
                report.tbt_ms.record(tbt);
                self.metrics.tbt_ms.record(tbt);
            }
            if !plan.spec.is_empty() {
                // Verify lane: accept the longest matching greedy prefix
                // per window, advance the sequence by all accepted
                // emissions at once, and roll the paged mirror back to
                // the accepted length (append k+1, truncate to take).
                let sout = self.apply_spec_out(&plan.spec, out);
                for (w, em) in plan.spec.iter().zip(sout.emitted.iter()) {
                    let l = live
                        .iter_mut()
                        .find(|l| l.lane.slot == w.slot)
                        .expect("lane slot is live");
                    kvm.append(w.slot as u64, w.width())?;
                    let take = em.len().min(l.lane.decode_left);
                    kvm.truncate(w.slot as u64, w.offset + take)?;
                    if self.cfg.kv_offload {
                        kvm.prefetch(w.slot as u64)?;
                    }
                    for &tok in &em[..take] {
                        l.tokens.push(tok);
                    }
                    l.lane.last_token =
                        *l.tokens.last().expect("invariant: live lane holds >=1 token");
                    l.lane.offset += take;
                    l.lane.decode_left -= take;
                    // One iteration emitted `take` tokens for this
                    // sequence; spread the wall time across them so TBT
                    // stays comparable with the one-token lane.
                    let tbt = (now_ms - l.last_emit_ms) / take as f64;
                    for _ in 0..take {
                        report.tbt_ms.record(tbt);
                        self.metrics.tbt_ms.record(tbt);
                    }
                    l.last_emit_ms = now_ms;
                }
                debug_assert!(kvm.check_invariants().is_ok());
            }
        }
        report.wall_s = clock.elapsed_ms() / 1e3;
        // Tier traffic (DESIGN.md §17): zero unless the offload tier
        // actually moved pages, so resident-only runs report nothing.
        self.metrics.kv_spilled_pages += kvm.spilled_pages;
        self.metrics.kv_fetched_pages += kvm.fetched_pages;
        self.metrics.kv_prefetched_pages += kvm.prefetched_pages;
        Ok(report)
    }

    /// The pre-mixed-batching serving loop: inline prefill at admission,
    /// then one blocking `Job::Decode` per live sequence per round.
    /// Retained as the A/B baseline (`mixed_iterations = false`).
    fn serve_trace_sequential(
        &mut self,
        reqs: &[crate::workload::Request],
    ) -> Result<TraceReport> {
        struct Live {
            slot: usize,
            id: u64,
            prompt: Vec<i32>,
            tokens: Vec<i32>,
            prompt_len: usize,
            decode_left: usize,
            arrival_s: f64,
            last_emit_ms: f64,
        }

        /// Snapshot every live sequence for checkpoint-free replay.
        fn replay_set(live: &[Live]) -> Vec<ReplaySeq> {
            live.iter()
                .map(|l| ReplaySeq {
                    slot: l.slot,
                    prompt: l.prompt.clone(),
                    tokens: l.tokens.clone(),
                })
                .collect()
        }

        let mut pending = sort_by_arrival(reqs);
        let mut live: Vec<Live> = Vec::new();
        let mut report = TraceReport::default();
        let clock = Timer::start();

        while !pending.is_empty() || !live.is_empty() {
            let now_s = clock.elapsed_ms() / 1e3;

            // Admission: arrived requests while slots are free.
            while let Some(next) = pending.front() {
                if next.arrival_s > now_s && !live.is_empty() {
                    break; // not arrived yet; keep decoding the live set
                }
                if self.free_slots.is_empty() {
                    break;
                }
                if next.arrival_s > now_s {
                    // idle engine: sleep until the next arrival
                    std::thread::sleep(std::time::Duration::from_secs_f64(
                        next.arrival_s - now_s,
                    ));
                }
                let r = pending.pop_front().expect("invariant: front peeked above");
                let slot = self.alloc_slot()?;
                // A fault here landed nothing for the new sequence:
                // recover (replaying the already-live set) and re-run
                // the admission prefill on the fresh mesh.
                let out = loop {
                    match self.prefill_in_slot(slot, &r.prompt) {
                        Ok(out) => break out,
                        Err(e) => {
                            let replay = replay_set(&live);
                            self.recover_with_retry(e, &replay)?;
                        }
                    }
                };
                report
                    .ttft_ms
                    .record(clock.elapsed_ms() - r.arrival_s * 1e3);
                live.push(Live {
                    slot,
                    id: r.id,
                    prompt: r.prompt.clone(),
                    tokens: vec![out.first_token],
                    prompt_len: r.prompt.len(),
                    decode_left: r.decode_steps,
                    arrival_s: r.arrival_s,
                    last_emit_ms: clock.elapsed_ms(),
                });
            }

            // One round-robin decode step for every live sequence.
            let max_seq = self.manifest.config.max_seq;
            let mut i = 0;
            while i < live.len() {
                let l = &mut live[i];
                let offset = l.prompt_len + l.tokens.len() - 1;
                if l.decode_left == 0 || offset >= max_seq {
                    // finished: emit + free
                    let l = live.swap_remove(i);
                    report
                        .e2e_ms
                        .record(clock.elapsed_ms() - l.arrival_s * 1e3);
                    report.completed += 1;
                    report.generated += l.tokens.len() as u64;
                    report.completions.push((l.id, l.tokens));
                    self.free_slot(l.slot)?;
                    continue;
                }
                let token = *l.tokens.last().expect("invariant: live seq holds >=1 token");
                let slot = l.slot;
                // A fault here landed nothing: the live set (including
                // this sequence) still describes the last good boundary,
                // so replay it all and retry the same decode.
                let logits = loop {
                    match self.decode_one(slot, token, offset) {
                        Ok(v) => break v,
                        Err(e) => {
                            let replay = replay_set(&live);
                            self.recover_with_retry(e, &replay)?;
                        }
                    }
                };
                let now_ms = clock.elapsed_ms();
                let l = &mut live[i];
                l.tokens.push(argmax(&logits));
                l.decode_left -= 1;
                report.tbt_ms.record(now_ms - l.last_emit_ms);
                l.last_emit_ms = now_ms;
                self.metrics.generated_tokens += 1;
                i += 1;
            }
            report.iterations += 1;
        }
        report.wall_s = clock.elapsed_ms() / 1e3;
        Ok(report)
    }

    /// Graceful shutdown; returns metrics + per-worker stats. Always
    /// terminates, fault or no fault (DESIGN.md §14): shutdown sends are
    /// best-effort (a dead rank's closed channel is ignored), and
    /// [`Mesh::join_all`] drops every job sender before joining so no
    /// worker can block forever on a peer that already exited.
    pub fn shutdown(mut self) -> Result<EngineReport> {
        let mesh = self.mesh.take().expect("engine mesh present until shutdown");
        for tx in &mesh.job_txs {
            tx.send(Job::Shutdown).ok();
        }
        let (computes, comms) = mesh.join_all();
        let mut workers = Vec::new();
        for r in computes {
            workers.push(r?);
        }
        // Comm threads exit when their compute thread drops the sender.
        for (w, comm) in workers.iter_mut().zip(comms.iter()) {
            w.fold_comm(comm);
        }
        // Fold in the stats of mesh generations recovery tore down, so
        // the report covers the whole run, not just the last generation.
        for (w, prior) in workers.iter_mut().zip(std::mem::take(&mut self.prior_workers)) {
            w.absorb(&prior);
        }
        // Fold worker counters into the final metrics without cloning the
        // histograms (§Perf: `metrics` can hold thousands of samples).
        let mut metrics = std::mem::take(&mut self.metrics);
        metrics.allreduces = workers.iter().map(|w| w.allreduces).sum();
        metrics.comm_bytes = workers.iter().map(|w| w.wire_bytes).sum();
        metrics.comm_msgs = workers.iter().map(|w| w.wire_msgs).sum();
        for w in workers.iter() {
            for (tot, b) in metrics.comm_bytes_by_rung.iter_mut().zip(w.wire_bytes_by_rung) {
                *tot += b;
            }
        }
        metrics.seg_acks = workers.iter().map(|w| w.seg_acks).sum();
        metrics.fused_allreduces = workers.iter().map(|w| w.fused_allreduces).sum();
        let n_workers = workers.len().max(1) as f64;
        metrics.overlapped_ms =
            workers.iter().map(|w| w.overlapped_ms()).sum::<f64>() / n_workers;
        metrics.exposed_ms = workers.iter().map(|w| w.stall_ms).sum::<f64>() / n_workers;
        // Epilogue accounting (DESIGN.md §12): compute-side residual
        // applies are the exposed epilogue; comm-side applies ran inside
        // the collective and are hidden behind the in-flight segments.
        metrics.exposed_epilogue_ms =
            workers.iter().map(|w| w.epilogue_ms).sum::<f64>() / n_workers;
        metrics.fused_epilogue_rows = workers.iter().map(|w| w.fused_epilogue_rows).sum();
        // Pipeline accounting (DESIGN.md §11). Single-stage engines record
        // nothing here, keeping their reports byte-identical to pre-PP
        // output.
        metrics.p2p_bytes = workers.iter().map(|w| w.p2p_bytes).sum();
        metrics.p2p_msgs = workers.iter().map(|w| w.p2p_msgs).sum();
        // Context-parallel accounting (DESIGN.md §17). cp = 1 engines
        // record nothing here, keeping their reports byte-identical.
        metrics.cp_shard_bytes = workers.iter().map(|w| w.cp_shard_bytes).sum();
        metrics.cp_shard_msgs = workers.iter().map(|w| w.cp_shard_msgs).sum();
        metrics.cp_stall_ms = workers.iter().map(|w| w.cp_stall_ms).sum();
        if self.cfg.pp_stages > 1 {
            for w in &workers {
                metrics.pp_bubble_ms.record(w.p2p_stall_ms);
            }
            for s in 0..self.cfg.pp_stages {
                let stage_compute: f64 =
                    workers.iter().filter(|w| w.stage == s).map(|w| w.compute_ms).sum();
                metrics.stage_compute_ms.record(stage_compute);
            }
        }
        Ok(EngineReport {
            metrics,
            workers,
            pp_stages: self.cfg.pp_stages,
            tp: self.cfg.tp,
            cp: self.cfg.cp.max(1),
        })
    }
}

impl Drop for Engine {
    /// Last-resort teardown for engines dropped without `shutdown()`
    /// (early `?` returns, panicking tests): best-effort shutdown sends,
    /// then the same sender-drop drain as [`Mesh::join_all`], so dropping
    /// an engine can never hang even with a rank already dead
    /// (DESIGN.md §14). `shutdown()` consumed the mesh, so this is a
    /// no-op on the normal path.
    fn drop(&mut self) {
        if let Some(mesh) = self.mesh.take() {
            for tx in &mesh.job_txs {
                tx.send(Job::Shutdown).ok();
            }
            let _ = mesh.join_all();
        }
    }
}

/// Requests ordered by arrival time, ready for FIFO admission.
fn sort_by_arrival(reqs: &[crate::workload::Request]) -> VecDeque<&crate::workload::Request> {
    let mut v: Vec<&crate::workload::Request> = reqs.iter().collect();
    v.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
    v.into_iter().collect()
}

fn argmax(v: &[f32]) -> i32 {
    let mut best = 0;
    for (i, &x) in v.iter().enumerate() {
        if x > v[best] {
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_largest() {
        assert_eq!(argmax(&[0.1, 3.0, -2.0, 3.0]), 1); // first max wins
        assert_eq!(argmax(&[-5.0]), 0);
    }

    #[test]
    fn worker_stats_overlap_efficiency() {
        let s = WorkerStats { comm_ms: 10.0, stall_ms: 2.0, ..Default::default() };
        assert!((s.overlapped_ms() - 8.0).abs() < 1e-12);
        assert!((s.overlap_efficiency() - 0.8).abs() < 1e-12);
        let no_comm = WorkerStats::default();
        assert_eq!(no_comm.overlap_efficiency(), 1.0);
    }

    #[test]
    fn stage_layer_ranges_partition_the_model() {
        // The layer-to-stage assignment is contiguous, covers every layer
        // exactly once, and never starves a stage while pp <= n_layers.
        for n_layers in [4usize, 5, 60] {
            for pp in 1..=n_layers.min(6) {
                let mut covered = 0;
                for s in 0..pp {
                    let (lo, hi) = stage_layer_range(n_layers, pp, s);
                    assert_eq!(lo, covered, "layers={n_layers} pp={pp} s={s}");
                    assert!(hi > lo, "stage {s} owns no layers");
                    covered = hi;
                }
                assert_eq!(covered, n_layers);
            }
        }
        // The tiny engine model: 4 layers over 2 stages = 2 + 2.
        assert_eq!(stage_layer_range(4, 2, 0), (0, 2));
        assert_eq!(stage_layer_range(4, 2, 1), (2, 4));
    }

    #[test]
    fn worker_stats_pp_fields_default_zero() {
        let s = WorkerStats::default();
        assert_eq!((s.stage, s.p2p_bytes, s.p2p_msgs), (0, 0, 0));
        assert_eq!(s.p2p_stall_ms, 0.0);
    }

    #[test]
    fn worker_stats_epilogue_fields_default_zero() {
        // PR-5: epilogue accounting starts empty so a run that never
        // fuses reports zeros, not garbage.
        let s = WorkerStats::default();
        assert_eq!(s.fused_epilogue_rows, 0);
        assert_eq!(s.epilogue_ms, 0.0);
        assert_eq!(s.fused_epilogue_ms, 0.0);
    }

    #[test]
    fn broadcast_jobs_share_payloads() {
        // Arc payloads: cloning a Job must not copy the prefill or lane.
        let prefill = Arc::new(StepPrefill {
            slot: 0,
            tokens: (0..1024).collect(),
            chunks: Vec::new(),
            logits_row: 0,
            completes: true,
        });
        let decode = Arc::new(vec![DecodeSlot { slot: 1, token: 7, offset: 3 }; 8]);
        let spec = Arc::new(vec![
            SpecSlot { slot: 2, tokens: vec![7, 8, 9], offset: 3 };
            4
        ]);
        let job = Job::Step {
            prefill: Some(Arc::clone(&prefill)),
            decode: Arc::clone(&decode),
            spec: Arc::clone(&spec),
        };
        let copy = job.clone();
        match (&job, &copy) {
            (
                Job::Step { prefill: Some(a), decode: da, spec: sa },
                Job::Step { prefill: Some(b), decode: db, spec: sb },
            ) => {
                assert!(Arc::ptr_eq(a, b), "clone must share the prefill payload");
                assert!(Arc::ptr_eq(da, db), "clone must share the lane");
                assert!(Arc::ptr_eq(sa, sb), "clone must share the verify lane");
                assert_eq!(Arc::strong_count(&prefill), 3);
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn worker_stats_absorb_sums_generations() {
        // Recovery folds dead-generation stats via absorb(); the final
        // report must sum counters across mesh generations.
        let mut a = WorkerStats {
            compute_ms: 1.0,
            comm_ms: 2.0,
            wire_bytes: 10,
            wire_bytes_by_rung: [10, 0, 0, 0, 0],
            allreduces: 3,
            ..Default::default()
        };
        let b = WorkerStats {
            compute_ms: 4.0,
            comm_ms: 8.0,
            wire_bytes: 30,
            wire_bytes_by_rung: [20, 0, 0, 6, 4],
            allreduces: 5,
            ..Default::default()
        };
        a.absorb(&b);
        assert_eq!(a.compute_ms, 5.0);
        assert_eq!(a.comm_ms, 10.0);
        assert_eq!(a.wire_bytes, 40);
        assert_eq!(a.wire_bytes_by_rung, [30, 0, 0, 6, 4]);
        assert_eq!(a.allreduces, 8);
    }

    #[test]
    fn worker_stats_fold_comm_copies_wire_counters() {
        let mut w = WorkerStats::default();
        let comm = WorkerStats {
            comm_ms: 7.0,
            allreduces: 2,
            wire_bytes: 99,
            wire_bytes_by_rung: [0, 0, 90, 0, 9],
            wire_msgs: 4,
            ..Default::default()
        };
        w.fold_comm(&comm);
        assert_eq!(w.comm_ms, 7.0);
        assert_eq!(w.allreduces, 2);
        assert_eq!(w.wire_bytes, 99);
        assert_eq!(w.wire_bytes_by_rung, [0, 0, 90, 0, 9]);
        assert_eq!(w.wire_msgs, 4);
    }

    #[test]
    fn trace_report_new_fields_default_empty() {
        let t = TraceReport::default();
        assert_eq!(t.iterations, 0);
        assert!(t.tbt_ms.is_empty() && t.occupancy.is_empty());
        assert!(t.completions.is_empty());
        assert_eq!(t.throughput_tok_s(), 0.0);
    }
}
