//! Fault injection, failure taxonomy, and supervision (DESIGN.md §14).
//!
//! Three pieces:
//! * [`EngineError`] — the typed failure taxonomy carried by supervised
//!   links. Implements `std::error::Error`, so `?` lifts it into
//!   `anyhow::Result` at the leader boundary while match-based recovery
//!   code keeps the structured variants.
//! * [`FaultPlan`] — a deterministic, seedable schedule of injected
//!   faults (`kill` / `stall` / `poison`), parsed from the
//!   `engine.fault_plan` config key or the `--fault-plan` CLI flag.
//!   Same spec string → same event list, always; that determinism is
//!   what makes chaos runs reproducible and bit-identity checkable.
//! * [`FaultInjector`] — the runtime half: one `Arc`-shared injector
//!   threaded through compute workers, comm threads, and PP stage
//!   ports. The leader advances its iteration clock; workers poll it at
//!   layer boundaries (kill/stall) and before wire sends (poison).
//!
//! Injection is modeled, not violent: a "killed" rank returns
//! [`EngineError::InjectedKill`] from its compute loop, which takes the
//! exact exit path a real panic or device loss would (sender drop →
//! ring cascade → leader detection), so the recovery machinery under
//! test is the production path.

use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

/// Typed failure taxonomy for the supervised mesh (DESIGN.md §14).
///
/// Every supervised link (leader↔worker, compute↔comm, ring, stage
/// port) surfaces one of these instead of panicking. `link` names which
/// fabric failed: `"ring"` (TP all-reduce), `"stage"` (PP activation
/// port), `"comm"` (compute↔comm ack path), `"job"` (leader→worker
/// queue), or `"reply"` (worker→leader).
#[derive(Clone, Debug, PartialEq)]
pub enum EngineError {
    /// A peer's channel disconnected: the rank behind it is gone.
    RankDead {
        /// Rank (or, for leader-side detection, the closest known rank)
        /// whose link dropped.
        rank: usize,
        /// Which fabric the disconnect was observed on.
        link: &'static str,
    },
    /// The leader's per-iteration deadline expired with no reply.
    CollectiveTimeout {
        /// Leader iteration number (1-based) that timed out.
        iteration: u64,
        /// The deadline that expired, in milliseconds.
        deadline_ms: f64,
    },
    /// A wire segment arrived corrupted (modeled CRC failure).
    WireCorrupt {
        /// Rank that received the corrupt segment.
        rank: usize,
        /// Which fabric carried it (`"ring"` or `"stage"`).
        link: &'static str,
    },
    /// A worker thread panicked; the panic was caught and converted.
    WorkerPanic {
        /// Rank whose thread panicked.
        rank: usize,
        /// Stringified panic payload.
        detail: String,
    },
    /// A planned [`FaultKind::Kill`] fired on this rank.
    InjectedKill {
        /// Rank the plan killed.
        rank: usize,
        /// Leader iteration (1-based) the kill fired in.
        iteration: u64,
    },
    /// Admission rejected a request because the bounded queue is full
    /// (DESIGN.md §15). Backpressure, not failure: the caller should
    /// retry later or route elsewhere; nothing in the engine is broken.
    Overloaded {
        /// Requests already waiting when the rejection happened.
        queued: usize,
        /// The configured queue bound that was hit.
        bound: usize,
    },
}

impl fmt::Display for EngineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EngineError::RankDead { rank, link } => {
                write!(f, "rank {rank} dead ({link} link disconnected)")
            }
            EngineError::CollectiveTimeout { iteration, deadline_ms } => {
                write!(f, "iteration {iteration} missed its {deadline_ms:.1} ms deadline")
            }
            EngineError::WireCorrupt { rank, link } => {
                write!(f, "rank {rank} received a corrupt {link} segment")
            }
            EngineError::WorkerPanic { rank, detail } => {
                write!(f, "rank {rank} panicked: {detail}")
            }
            EngineError::InjectedKill { rank, iteration } => {
                write!(f, "rank {rank} killed by fault plan at iteration {iteration}")
            }
            EngineError::Overloaded { queued, bound } => {
                write!(f, "admission queue full ({queued} queued, bound {bound})")
            }
        }
    }
}

impl std::error::Error for EngineError {}

/// A supervision event: which rank failed, and how. Workers push these
/// onto the leader's event queue as they exit; the leader drains the
/// queue to attribute a detected fault before recovering.
#[derive(Clone, Debug)]
pub struct SupervisionEvent {
    /// Rank reporting the failure.
    pub rank: usize,
    /// The failure itself.
    pub error: EngineError,
}

/// What a planned fault does when it fires.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultKind {
    /// The rank's compute loop exits with [`EngineError::InjectedKill`].
    Kill,
    /// The rank sleeps for the given modeled duration, then continues.
    Stall {
        /// Stall duration in milliseconds.
        ms: f64,
    },
    /// The rank's next wire send is flagged corrupt; the receiver
    /// surfaces [`EngineError::WireCorrupt`]. `p2p` selects the stage
    /// port instead of the TP ring.
    Poison {
        /// Poison the PP stage port (`true`) or the TP ring (`false`).
        p2p: bool,
    },
}

/// One planned fault: fires once, on `rank`, in leader iteration
/// `iteration` (1-based), optionally gated to a specific local layer
/// index (kill/stall only; `None` fires at the rank's first poll of
/// that iteration).
#[derive(Clone, Debug, PartialEq)]
pub struct FaultEvent {
    /// Rank the fault targets (global rank = stage × tp + tp_rank).
    pub rank: usize,
    /// Leader iteration (1-based) the fault fires in.
    pub iteration: u64,
    /// Local layer index the fault is gated to, if any.
    pub layer: Option<usize>,
    /// What happens when it fires.
    pub kind: FaultKind,
}

/// A deterministic schedule of injected faults.
///
/// Spec grammar (events separated by `;`, fields by `:`):
///
/// * `kill:rank=R:iter=I[:layer=L]` — kill rank R in iteration I.
/// * `stall:rank=R:iter=I:ms=M[:layer=L]` — stall rank R for M ms.
/// * `poison:rank=R:iter=I[:p2p]` — corrupt rank R's next ring (or,
///   with `p2p`, stage-port) send in iteration I.
/// * `seed=S:ranks=R:iters=I[:n=N]` — N (default 1) pseudo-random
///   events over ranks `0..R` and iterations `1..=I`, derived from S
///   via the crate's SplitMix64 stream — same spec, same events.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    /// The planned events, in spec order.
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// Plan with no events (the fault-free default).
    pub fn empty() -> FaultPlan {
        FaultPlan::default()
    }

    /// True when the plan holds no events.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Parse a plan spec (see the type-level grammar). Errors name the
    /// offending token so config typos fail loudly at startup.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut events = Vec::new();
        for part in spec.split(';') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let toks: Vec<&str> = part.split(':').map(str::trim).collect();
            if toks[0].starts_with("seed=") {
                events.extend(Self::parse_seeded(&toks)?);
                continue;
            }
            let kind_tok = toks[0];
            let mut rank = None;
            let mut iter = None;
            let mut layer = None;
            let mut ms = None;
            let mut p2p = false;
            for t in &toks[1..] {
                match t.split_once('=') {
                    Some(("rank", v)) => rank = Some(parse_num::<usize>("rank", v)?),
                    Some(("iter", v)) => iter = Some(parse_num::<u64>("iter", v)?),
                    Some(("layer", v)) => layer = Some(parse_num::<usize>("layer", v)?),
                    Some(("ms", v)) => ms = Some(parse_num::<f64>("ms", v)?),
                    None if *t == "p2p" => p2p = true,
                    _ => return Err(format!("fault plan: unknown field {t:?} in {part:?}")),
                }
            }
            let rank = rank.ok_or_else(|| format!("fault plan: {part:?} needs rank="))?;
            let iteration = iter.ok_or_else(|| format!("fault plan: {part:?} needs iter="))?;
            if iteration == 0 {
                return Err(format!("fault plan: {part:?} iter is 1-based (got 0)"));
            }
            let kind = match kind_tok {
                "kill" => FaultKind::Kill,
                "stall" => FaultKind::Stall {
                    ms: ms.ok_or_else(|| format!("fault plan: {part:?} needs ms="))?,
                },
                "poison" => FaultKind::Poison { p2p },
                other => return Err(format!("fault plan: unknown kind {other:?}")),
            };
            events.push(FaultEvent { rank, iteration, layer, kind });
        }
        Ok(FaultPlan { events })
    }

    /// Expand a `seed=…` generator token list into concrete events.
    fn parse_seeded(toks: &[&str]) -> Result<Vec<FaultEvent>, String> {
        let mut seed = None;
        let mut n = 1usize;
        let mut ranks = None;
        let mut iters = None;
        for t in toks {
            match t.split_once('=') {
                Some(("seed", v)) => seed = Some(parse_num::<u64>("seed", v)?),
                Some(("n", v)) => n = parse_num::<usize>("n", v)?,
                Some(("ranks", v)) => ranks = Some(parse_num::<usize>("ranks", v)?),
                Some(("iters", v)) => iters = Some(parse_num::<u64>("iters", v)?),
                _ => return Err(format!("fault plan: unknown seeded field {t:?}")),
            }
        }
        let seed = seed.ok_or_else(|| "fault plan: seeded spec needs seed=".to_string())?;
        let ranks = ranks.ok_or_else(|| "fault plan: seeded spec needs ranks=".to_string())?;
        let iters = iters.ok_or_else(|| "fault plan: seeded spec needs iters=".to_string())?;
        if ranks == 0 || iters == 0 {
            return Err("fault plan: seeded spec needs ranks >= 1 and iters >= 1".to_string());
        }
        let mut rng = crate::util::Rng::new(seed);
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let rank = rng.below(ranks as u64) as usize;
            let iteration = 1 + rng.below(iters);
            let kind = match rng.below(3) {
                0 => FaultKind::Kill,
                1 => FaultKind::Stall { ms: 1.0 + rng.below(10) as f64 },
                _ => FaultKind::Poison { p2p: false },
            };
            out.push(FaultEvent { rank, iteration, layer: None, kind });
        }
        Ok(out)
    }
}

fn parse_num<T: std::str::FromStr>(key: &str, v: &str) -> Result<T, String> {
    v.parse().map_err(|_| format!("fault plan: bad {key} value {v:?}"))
}

/// Runtime fault injector: one per engine, `Arc`-shared with every
/// worker. The leader advances the iteration clock with
/// [`FaultInjector::begin_iteration`]; workers poll at layer boundaries
/// ([`FaultInjector::poll_compute`]) and before wire sends
/// ([`FaultInjector::poll_wire`]). Each planned event fires exactly
/// once (atomic claim), so a recovered mesh replaying the same
/// iteration numbers does not re-fire a consumed fault.
#[derive(Debug)]
pub struct FaultInjector {
    plan: FaultPlan,
    fired: Vec<AtomicBool>,
    iteration: AtomicU64,
}

impl FaultInjector {
    /// An injector over `plan`, with the iteration clock at 0 (no
    /// event fires before the first [`FaultInjector::begin_iteration`]).
    pub fn new(plan: FaultPlan) -> FaultInjector {
        let fired = plan.events.iter().map(|_| AtomicBool::new(false)).collect();
        FaultInjector { plan, fired, iteration: AtomicU64::new(0) }
    }

    /// Advance the iteration clock; returns the new (1-based) iteration
    /// number. The leader calls this once per broadcast step.
    pub fn begin_iteration(&self) -> u64 {
        self.iteration.fetch_add(1, Ordering::SeqCst) + 1
    }

    /// The current (1-based) iteration number; 0 before the first step.
    pub fn current_iteration(&self) -> u64 {
        self.iteration.load(Ordering::SeqCst)
    }

    /// Claim event `i` if it matches (rank, iteration, layer-gate,
    /// predicate); returns the kind on the one winning claim.
    fn claim(
        &self,
        rank: usize,
        layer: Option<usize>,
        want: impl Fn(&FaultKind) -> bool,
    ) -> Option<FaultKind> {
        let iter = self.iteration.load(Ordering::SeqCst);
        for (i, ev) in self.plan.events.iter().enumerate() {
            if ev.rank != rank || ev.iteration != iter || !want(&ev.kind) {
                continue;
            }
            if let (Some(gate), Some(at)) = (ev.layer, layer) {
                if gate != at {
                    continue;
                }
            }
            if self.fired[i]
                .compare_exchange(false, true, Ordering::SeqCst, Ordering::SeqCst)
                .is_ok()
            {
                return Some(ev.kind);
            }
        }
        None
    }

    /// Compute-side poll, called at each local layer boundary. A
    /// matching `Stall` sleeps here and continues; a matching `Kill`
    /// returns the error the worker exits with.
    pub fn poll_compute(&self, rank: usize, layer: usize) -> Result<(), EngineError> {
        if let Some(kind) = self.claim(rank, Some(layer), |k| {
            matches!(k, FaultKind::Kill | FaultKind::Stall { .. })
        }) {
            match kind {
                FaultKind::Kill => {
                    return Err(EngineError::InjectedKill {
                        rank,
                        iteration: self.current_iteration(),
                    });
                }
                FaultKind::Stall { ms } => {
                    std::thread::sleep(std::time::Duration::from_secs_f64(ms / 1e3));
                }
                FaultKind::Poison { .. } => unreachable!("claim filtered to kill/stall"),
            }
        }
        Ok(())
    }

    /// Wire-side poll, called before a send on the named fabric; true
    /// means "flag the next send corrupt". `p2p` selects the stage port
    /// fabric, `!p2p` the TP ring.
    pub fn poll_wire(&self, rank: usize, p2p: bool) -> bool {
        self.claim(rank, None, |k| matches!(k, FaultKind::Poison { p2p: wire } if *wire == p2p))
            .is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_explicit_events() {
        let plan = FaultPlan::parse("kill:rank=1:iter=3:layer=2; stall:rank=0:iter=2:ms=5")
            .expect("valid spec");
        assert_eq!(
            plan.events,
            vec![
                FaultEvent {
                    rank: 1,
                    iteration: 3,
                    layer: Some(2),
                    kind: FaultKind::Kill
                },
                FaultEvent {
                    rank: 0,
                    iteration: 2,
                    layer: None,
                    kind: FaultKind::Stall { ms: 5.0 }
                },
            ]
        );
    }

    #[test]
    fn parse_poison_p2p_flag() {
        let plan = FaultPlan::parse("poison:rank=2:iter=1:p2p;poison:rank=0:iter=4").unwrap();
        assert_eq!(plan.events[0].kind, FaultKind::Poison { p2p: true });
        assert_eq!(plan.events[1].kind, FaultKind::Poison { p2p: false });
    }

    #[test]
    fn parse_rejects_typos() {
        for bad in [
            "kill:rank=1",                  // missing iter
            "kill:iter=2",                  // missing rank
            "stall:rank=0:iter=1",          // missing ms
            "explode:rank=0:iter=1",        // unknown kind
            "kill:rank=0:iter=0",           // iter is 1-based
            "kill:rank=0:iter=1:color=red", // unknown field
            "seed=7:ranks=4",               // missing iters
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn seeded_plan_is_deterministic() {
        let a = FaultPlan::parse("seed=7:n=5:ranks=4:iters=10").unwrap();
        let b = FaultPlan::parse("seed=7:n=5:ranks=4:iters=10").unwrap();
        assert_eq!(a, b, "same seed must give the same event sequence");
        assert_eq!(a.events.len(), 5);
        for ev in &a.events {
            assert!(ev.rank < 4);
            assert!((1..=10).contains(&ev.iteration));
        }
        let c = FaultPlan::parse("seed=8:n=5:ranks=4:iters=10").unwrap();
        assert_ne!(a, c, "different seeds must diverge");
    }

    #[test]
    fn injector_fires_once_at_the_planned_point() {
        let plan = FaultPlan::parse("kill:rank=1:iter=2").unwrap();
        let inj = FaultInjector::new(plan);
        assert!(inj.poll_compute(1, 0).is_ok(), "clock at 0: nothing fires");
        assert_eq!(inj.begin_iteration(), 1);
        assert!(inj.poll_compute(1, 0).is_ok(), "iteration 1: not yet");
        assert_eq!(inj.begin_iteration(), 2);
        assert!(inj.poll_compute(0, 0).is_ok(), "wrong rank: no fire");
        let err = inj.poll_compute(1, 3).expect_err("planned kill fires");
        assert_eq!(err, EngineError::InjectedKill { rank: 1, iteration: 2 });
        assert!(inj.poll_compute(1, 4).is_ok(), "events fire exactly once");
    }

    #[test]
    fn injector_layer_gate() {
        let plan = FaultPlan::parse("kill:rank=0:iter=1:layer=2").unwrap();
        let inj = FaultInjector::new(plan);
        inj.begin_iteration();
        assert!(inj.poll_compute(0, 0).is_ok());
        assert!(inj.poll_compute(0, 1).is_ok());
        assert!(inj.poll_compute(0, 2).is_err(), "fires only at its layer");
    }

    #[test]
    fn injector_wire_poison_selects_fabric() {
        let plan = FaultPlan::parse("poison:rank=0:iter=1;poison:rank=0:iter=1:p2p").unwrap();
        let inj = FaultInjector::new(plan);
        inj.begin_iteration();
        assert!(!inj.poll_wire(1, false), "wrong rank");
        assert!(inj.poll_wire(0, false), "ring poison fires");
        assert!(!inj.poll_wire(0, false), "only once");
        assert!(inj.poll_wire(0, true), "p2p poison fires independently");
    }

    #[test]
    fn errors_display_and_convert() {
        let e = EngineError::RankDead { rank: 3, link: "ring" };
        assert_eq!(e.to_string(), "rank 3 dead (ring link disconnected)");
        // The taxonomy lifts into anyhow at the leader boundary via `?`.
        fn lift() -> anyhow::Result<()> {
            Err(EngineError::CollectiveTimeout { iteration: 7, deadline_ms: 250.0 })?;
            Ok(())
        }
        let msg = format!("{:#}", lift().unwrap_err());
        assert!(msg.contains("iteration 7"), "{msg}");
        let o = EngineError::Overloaded { queued: 12, bound: 8 };
        assert_eq!(o.to_string(), "admission queue full (12 queued, bound 8)");
    }
}
