//! Paged KV-cache manager (vLLM-style block allocator).
//!
//! The real engine keeps one cache per (rank, layer) as a dense
//! `[n_kv_heads/tp, max_seq, head_dim]` f32 buffer matching the AOT
//! attention stage's input; this module manages *which sequence owns which
//! slot range* — block allocation, per-sequence block tables, chunk
//! appends, and free-list invariants. Chunked prefill appends one chunk's
//! worth of positions at a time, which is exactly what ISO's intra-sequence
//! micro-batches do.
//!
//! Speculative decoding (DESIGN.md §10) adds the rollback motion: a verify
//! window *appends* `k + 1` positions optimistically, then *truncates* back
//! to the accepted prefix — [`KvManager::truncate`] returns the blocks of
//! the rejected suffix to the free list without disturbing the accepted
//! prefix's block table.

use std::collections::BTreeMap;
use std::fmt;

/// Allocation error.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum KvError {
    /// The free list cannot satisfy an allocation.
    OutOfBlocks {
        /// Blocks the request needed.
        need: usize,
        /// Blocks that were free.
        free: usize,
    },
    /// The sequence id is not registered.
    UnknownSeq(u64),
    /// An append would push the sequence past a fixed capacity.
    OverCapacity {
        /// Offending sequence id.
        seq: u64,
        /// Its current token length.
        len: usize,
        /// Tokens the append asked for.
        add: usize,
        /// The capacity that would be exceeded.
        cap: usize,
    },
    /// A truncate asked for a length beyond the current one.
    BadTruncate {
        /// Offending sequence id.
        seq: u64,
        /// Its current token length.
        len: usize,
        /// The (longer) length the caller asked to truncate to.
        to: usize,
    },
}

impl fmt::Display for KvError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            KvError::OutOfBlocks { need, free } => {
                write!(f, "out of KV blocks (need {need}, free {free})")
            }
            KvError::UnknownSeq(s) => write!(f, "unknown sequence {s}"),
            KvError::OverCapacity { seq, len, add, cap } => {
                write!(f, "sequence {seq} over capacity: {len} + {add} > {cap}")
            }
            KvError::BadTruncate { seq, len, to } => {
                write!(f, "sequence {seq}: cannot truncate len {len} up to {to}")
            }
        }
    }
}

impl std::error::Error for KvError {}

/// Block-granular KV allocator for a fixed-capacity cache region.
#[derive(Debug)]
pub struct KvManager {
    block_tokens: usize,
    n_blocks: usize,
    free: Vec<usize>,
    /// seq id → (block ids, token length)
    seqs: BTreeMap<u64, SeqEntry>,
}

#[derive(Debug, Clone)]
struct SeqEntry {
    blocks: Vec<usize>,
    len: usize,
}

impl KvManager {
    /// `capacity_tokens` total slots, managed in blocks of `block_tokens`.
    pub fn new(capacity_tokens: usize, block_tokens: usize) -> Self {
        assert!(block_tokens > 0 && capacity_tokens % block_tokens == 0);
        let n_blocks = capacity_tokens / block_tokens;
        KvManager {
            block_tokens,
            n_blocks,
            free: (0..n_blocks).rev().collect(),
            seqs: BTreeMap::new(),
        }
    }

    /// Blocks currently on the free list.
    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    /// Total blocks managed (free + owned).
    pub fn total_blocks(&self) -> usize {
        self.n_blocks
    }

    /// Total token capacity across all blocks.
    pub fn capacity_tokens(&self) -> usize {
        self.n_blocks * self.block_tokens
    }

    /// Current token length of `seq`, if registered.
    pub fn seq_len(&self, seq: u64) -> Option<usize> {
        self.seqs.get(&seq).map(|e| e.len)
    }

    /// Number of registered sequences.
    pub fn live_seqs(&self) -> usize {
        self.seqs.len()
    }

    /// Register a new empty sequence.
    pub fn add_seq(&mut self, seq: u64) {
        assert!(!self.seqs.contains_key(&seq), "seq {seq} already exists");
        self.seqs.insert(seq, SeqEntry { blocks: Vec::new(), len: 0 });
    }

    /// Can `tokens` more be appended to `seq` without failing?
    pub fn can_append(&self, seq: u64, tokens: usize) -> bool {
        match self.seqs.get(&seq) {
            None => false,
            Some(e) => {
                let have = e.blocks.len() * self.block_tokens - e.len;
                let need_tokens = tokens.saturating_sub(have);
                let need_blocks = need_tokens.div_ceil(self.block_tokens);
                need_blocks <= self.free.len()
            }
        }
    }

    /// Append a chunk of `tokens` to `seq`; returns the absolute start
    /// position of the chunk (== previous length).
    pub fn append(&mut self, seq: u64, tokens: usize) -> Result<usize, KvError> {
        let e = self.seqs.get(&seq).ok_or(KvError::UnknownSeq(seq))?;
        let have = e.blocks.len() * self.block_tokens - e.len;
        let need_tokens = tokens.saturating_sub(have);
        let need_blocks = need_tokens.div_ceil(self.block_tokens);
        if need_blocks > self.free.len() {
            return Err(KvError::OutOfBlocks { need: need_blocks, free: self.free.len() });
        }
        let e = self.seqs.get_mut(&seq).expect("invariant: seq present (checked above)");
        for _ in 0..need_blocks {
            e.blocks.push(self.free.pop().expect("invariant: free list sized by capacity check"));
        }
        let start = e.len;
        e.len += tokens;
        Ok(start)
    }

    /// Shrink `seq` to `new_len` tokens, returning the blocks of the cut
    /// suffix to the free list — the speculative-decode rollback
    /// (DESIGN.md §10): a verify window appends `k + 1` positions
    /// optimistically and truncates back to the accepted prefix. Growing
    /// (`new_len > len`) is a [`KvError::BadTruncate`]; use
    /// [`KvManager::append`].
    pub fn truncate(&mut self, seq: u64, new_len: usize) -> Result<(), KvError> {
        let e = self.seqs.get_mut(&seq).ok_or(KvError::UnknownSeq(seq))?;
        if new_len > e.len {
            return Err(KvError::BadTruncate { seq, len: e.len, to: new_len });
        }
        let keep_blocks = new_len.div_ceil(self.block_tokens);
        while e.blocks.len() > keep_blocks {
            self.free.push(e.blocks.pop().expect("invariant: block table covers len"));
        }
        e.len = new_len;
        Ok(())
    }

    /// Release a sequence's blocks back to the free list.
    pub fn release(&mut self, seq: u64) -> Result<(), KvError> {
        let e = self.seqs.remove(&seq).ok_or(KvError::UnknownSeq(seq))?;
        self.free.extend(e.blocks);
        Ok(())
    }

    /// The block table of a sequence (block ids in position order).
    pub fn block_table(&self, seq: u64) -> Option<&[usize]> {
        self.seqs.get(&seq).map(|e| e.blocks.as_slice())
    }

    /// Internal invariant: no block is both free and owned, and every
    /// block is accounted for exactly once.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut seen = vec![false; self.n_blocks];
        for &b in &self.free {
            if seen[b] {
                return Err(format!("block {b} double-listed in free list"));
            }
            seen[b] = true;
        }
        for (seq, e) in &self.seqs {
            if e.len > e.blocks.len() * self.block_tokens {
                return Err(format!("seq {seq} len {} exceeds its blocks", e.len));
            }
            for &b in &e.blocks {
                if seen[b] {
                    return Err(format!("block {b} owned twice (seq {seq})"));
                }
                seen[b] = true;
            }
        }
        if !seen.iter().all(|&s| s) {
            return Err("leaked blocks (neither free nor owned)".into());
        }
        Ok(())
    }
}

/// A dense per-(rank, layer) KV region matching the AOT attention stage
/// input: `[n_kv_heads, max_seq, head_dim]` f32, plus the write helper the
/// coordinator uses to scatter a chunk's K/V at its absolute offset.
#[derive(Clone, Debug)]
pub struct DenseKv {
    /// KV heads in this rank's shard.
    pub n_kv_heads: usize,
    /// Positions the region holds.
    pub max_seq: usize,
    /// Per-head feature dimension.
    pub head_dim: usize,
    /// Key buffer, `[n_kv_heads, max_seq, head_dim]` row-major.
    pub k: Vec<f32>,
    /// Value buffer, same layout as `k`.
    pub v: Vec<f32>,
}

impl DenseKv {
    /// A zero-filled region of the given geometry.
    pub fn new(n_kv_heads: usize, max_seq: usize, head_dim: usize) -> Self {
        let n = n_kv_heads * max_seq * head_dim;
        DenseKv { n_kv_heads, max_seq, head_dim, k: vec![0.0; n], v: vec![0.0; n] }
    }

    /// Overwrite from a full returned cache (the AOT attention stage
    /// returns the updated `[h, S, d]` cache tensors).
    pub fn store(&mut self, k: Vec<f32>, v: Vec<f32>) {
        debug_assert_eq!(k.len(), self.k.len());
        debug_assert_eq!(v.len(), self.v.len());
        self.k = k;
        self.v = v;
    }

    /// Zero positions `[from, to)` across all heads (sequence release).
    pub fn zero_range(&mut self, from: usize, to: usize) {
        for h in 0..self.n_kv_heads {
            let base = h * self.max_seq * self.head_dim;
            let a = base + from * self.head_dim;
            let b = base + to * self.head_dim;
            self.k[a..b].fill(0.0);
            self.v[a..b].fill(0.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{Prop, Rng};

    #[test]
    fn append_returns_absolute_offsets() {
        let mut kv = KvManager::new(256, 16);
        kv.add_seq(1);
        assert_eq!(kv.append(1, 64).unwrap(), 0);
        assert_eq!(kv.append(1, 64).unwrap(), 64); // ISO chunk 1 offset
        assert_eq!(kv.seq_len(1), Some(128));
    }

    #[test]
    fn blocks_allocated_lazily_and_exactly() {
        let mut kv = KvManager::new(256, 16);
        kv.add_seq(1);
        kv.append(1, 8).unwrap();
        assert_eq!(kv.block_table(1).unwrap().len(), 1);
        kv.append(1, 8).unwrap(); // fits the same block
        assert_eq!(kv.block_table(1).unwrap().len(), 1);
        kv.append(1, 1).unwrap();
        assert_eq!(kv.block_table(1).unwrap().len(), 2);
    }

    #[test]
    fn out_of_blocks_fails_cleanly() {
        let mut kv = KvManager::new(64, 16);
        kv.add_seq(1);
        assert!(matches!(
            kv.append(1, 100),
            Err(KvError::OutOfBlocks { .. })
        ));
        // failed append must not leak partial state
        assert_eq!(kv.seq_len(1), Some(0));
        assert_eq!(kv.free_blocks(), 4);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn release_returns_blocks() {
        let mut kv = KvManager::new(128, 16);
        kv.add_seq(1);
        kv.add_seq(2);
        kv.append(1, 48).unwrap();
        kv.append(2, 32).unwrap();
        kv.release(1).unwrap();
        assert_eq!(kv.free_blocks(), 8 - 2);
        assert!(kv.seq_len(1).is_none());
        kv.check_invariants().unwrap();
        assert_eq!(kv.release(1), Err(KvError::UnknownSeq(1)));
    }

    #[test]
    fn can_append_predicts_append() {
        let mut kv = KvManager::new(64, 16);
        kv.add_seq(1);
        assert!(kv.can_append(1, 64));
        assert!(!kv.can_append(1, 65));
        kv.append(1, 64).unwrap();
        assert!(!kv.can_append(1, 1));
        assert!(!kv.can_append(99, 1)); // unknown seq
    }

    #[test]
    fn prop_alloc_release_never_leaks() {
        Prop::new(31).cases(200).run("kv alloc/release invariants", |rng: &mut Rng| {
            let mut kv = KvManager::new(1024, 16);
            let mut live: Vec<u64> = Vec::new();
            let mut next_id = 0u64;
            for _ in 0..100 {
                match rng.range(0, 3) {
                    0 => {
                        kv.add_seq(next_id);
                        live.push(next_id);
                        next_id += 1;
                    }
                    1 if !live.is_empty() => {
                        let seq = live[rng.range(0, live.len())];
                        let n = rng.range(1, 100);
                        if kv.can_append(seq, n) {
                            kv.append(seq, n).map_err(|e| e.to_string())?;
                        } else {
                            // must fail without corrupting state
                            let before = kv.free_blocks();
                            let _ = kv.append(seq, n);
                            if kv.free_blocks() != before {
                                return Err("failed append leaked blocks".into());
                            }
                        }
                    }
                    _ if !live.is_empty() => {
                        let i = rng.range(0, live.len());
                        let seq = live.swap_remove(i);
                        kv.release(seq).map_err(|e| e.to_string())?;
                    }
                    _ => {}
                }
                kv.check_invariants()?;
            }
            Ok(())
        });
    }

    #[test]
    fn truncate_frees_suffix_blocks_exactly() {
        let mut kv = KvManager::new(256, 16);
        kv.add_seq(1);
        kv.append(1, 40).unwrap(); // 3 blocks (48 slots)
        assert_eq!(kv.block_table(1).unwrap().len(), 3);
        // Cut inside the second block: the third block frees, the second stays.
        kv.truncate(1, 20).unwrap();
        assert_eq!(kv.seq_len(1), Some(20));
        assert_eq!(kv.block_table(1).unwrap().len(), 2);
        assert_eq!(kv.free_blocks(), 16 - 2);
        kv.check_invariants().unwrap();
        // Truncate to a block boundary and to zero.
        kv.truncate(1, 16).unwrap();
        assert_eq!(kv.block_table(1).unwrap().len(), 1);
        kv.truncate(1, 0).unwrap();
        assert_eq!(kv.block_table(1).unwrap().len(), 0);
        assert_eq!(kv.free_blocks(), 16);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn truncate_rejects_growth_and_unknown_seq() {
        let mut kv = KvManager::new(64, 16);
        kv.add_seq(1);
        kv.append(1, 10).unwrap();
        assert_eq!(
            kv.truncate(1, 11),
            Err(KvError::BadTruncate { seq: 1, len: 10, to: 11 })
        );
        assert_eq!(kv.truncate(9, 0), Err(KvError::UnknownSeq(9)));
        // No-op truncate to the current length is fine.
        kv.truncate(1, 10).unwrap();
        assert_eq!(kv.seq_len(1), Some(10));
        kv.check_invariants().unwrap();
    }

    #[test]
    fn prop_speculative_append_truncate_conserves_blocks() {
        // Satellite (DESIGN.md §10): the verify-window motion — append
        // k+1 positions, accept a random prefix, truncate the rest —
        // never leaks or double-frees a block, and the block table always
        // covers exactly ceil(len / block_tokens) blocks.
        Prop::new(67).cases(200).run("kv speculative append/truncate", |rng: &mut Rng| {
            let block = 16;
            let mut kv = KvManager::new(1024, block);
            let n_seqs = rng.range(1, 5) as u64;
            for s in 0..n_seqs {
                kv.add_seq(s);
                // Random prefill.
                let prefill = rng.range(1, 80);
                if kv.can_append(s, prefill) {
                    kv.append(s, prefill).map_err(|e| e.to_string())?;
                }
            }
            for _ in 0..120 {
                let s = rng.below(n_seqs);
                let k = rng.range(0, 9); // drafts per window
                let window = k + 1;
                let len = kv.seq_len(s).unwrap();
                if !kv.can_append(s, window) {
                    continue;
                }
                let start = kv.append(s, window).map_err(|e| e.to_string())?;
                if start != len {
                    return Err(format!("append at {start}, expected {len}"));
                }
                // Random acceptance: keep 1..=window of the appended rows.
                let take = rng.range(1, window + 1);
                kv.truncate(s, len + take).map_err(|e| e.to_string())?;
                if kv.seq_len(s) != Some(len + take) {
                    return Err("truncate set the wrong length".into());
                }
                let blocks = kv.block_table(s).unwrap().len();
                let want = (len + take).div_ceil(block);
                if blocks != want {
                    return Err(format!(
                        "len {} held {blocks} blocks, want {want}",
                        len + take
                    ));
                }
                kv.check_invariants()?;
            }
            for s in 0..n_seqs {
                kv.release(s).map_err(|e| e.to_string())?;
            }
            if kv.free_blocks() != kv.total_blocks() {
                return Err("release after spec traffic leaked blocks".into());
            }
            kv.check_invariants()?;
            Ok(())
        });
    }

    #[test]
    fn prop_preempt_restore_matches_uninterrupted_twin() {
        // Satellite (DESIGN.md §15): KV-pressure preemption evicts a
        // sequence mid-decode (release) and later restores it by
        // re-registering and re-appending its committed prefix in one go —
        // the serve loop's `add_seq` + `append(slot, committed)` motion.
        // Drive twin managers with identical traffic, preempt/restore one
        // of them at random points, and require the allocator state they
        // expose (lengths, block-table sizes, free counts, and the start
        // offsets of every subsequent append) to stay identical.
        Prop::new(103).cases(200).run("kv preempt/restore equivalence", |rng: &mut Rng| {
            let block = 16;
            let mut a = KvManager::new(2048, block); // uninterrupted twin
            let mut b = KvManager::new(2048, block); // preempted twin
            let n_seqs = rng.range(2, 5) as u64;
            for s in 0..n_seqs {
                a.add_seq(s);
                b.add_seq(s);
                let prefill = rng.range(8, 96);
                a.append(s, prefill).map_err(|e| e.to_string())?;
                b.append(s, prefill).map_err(|e| e.to_string())?;
            }
            for _ in 0..80 {
                let s = rng.below(n_seqs);
                match rng.range(0, 4) {
                    // Decode step: both twins append one token.
                    0..=2 => {
                        if !a.can_append(s, 1) {
                            continue;
                        }
                        let oa = a.append(s, 1).map_err(|e| e.to_string())?;
                        let ob = b.append(s, 1).map_err(|e| e.to_string())?;
                        if oa != ob {
                            return Err(format!("append offsets diverged: {oa} vs {ob}"));
                        }
                    }
                    // Preempt + immediate restore on twin B only.
                    _ => {
                        let committed = b.seq_len(s).unwrap();
                        b.release(s).map_err(|e| e.to_string())?;
                        b.add_seq(s);
                        let start = b.append(s, committed).map_err(|e| e.to_string())?;
                        if start != 0 {
                            return Err(format!("restore append started at {start}"));
                        }
                    }
                }
                for s in 0..n_seqs {
                    if a.seq_len(s) != b.seq_len(s) {
                        return Err(format!("seq {s} lengths diverged"));
                    }
                    let (ba, bb) = (
                        a.block_table(s).unwrap().len(),
                        b.block_table(s).unwrap().len(),
                    );
                    if ba != bb {
                        return Err(format!("seq {s} block counts diverged: {ba} vs {bb}"));
                    }
                }
                if a.free_blocks() != b.free_blocks() {
                    return Err("free-block counts diverged".into());
                }
                a.check_invariants()?;
                b.check_invariants()?;
            }
            Ok(())
        });
    }

    #[test]
    fn dense_kv_store_and_zero() {
        let mut kv = DenseKv::new(2, 8, 4);
        let k: Vec<f32> = (0..2 * 8 * 4).map(|i| i as f32).collect();
        kv.store(k.clone(), k.clone());
        kv.zero_range(2, 4);
        for h in 0..2 {
            for pos in 2..4 {
                for d in 0..4 {
                    let idx = h * 32 + pos * 4 + d;
                    assert_eq!(kv.k[idx], 0.0);
                }
            }
            // outside range untouched
            let idx = h * 32 + 4 * 4;
            assert_eq!(kv.k[idx], k[idx]);
        }
    }
}
