//! Paged KV-cache manager (vLLM-style block allocator).
//!
//! The real engine keeps one cache per (rank, layer) as a dense
//! `[n_kv_heads/tp, max_seq, head_dim]` f32 buffer matching the AOT
//! attention stage's input; this module manages *which sequence owns which
//! slot range* — block allocation, per-sequence block tables, chunk
//! appends, and free-list invariants. Chunked prefill appends one chunk's
//! worth of positions at a time, which is exactly what ISO's intra-sequence
//! micro-batches do.
//!
//! Speculative decoding (DESIGN.md §10) adds the rollback motion: a verify
//! window *appends* `k + 1` positions optimistically, then *truncates* back
//! to the accepted prefix — [`KvManager::truncate`] returns the blocks of
//! the rejected suffix to the free list without disturbing the accepted
//! prefix's block table.
//!
//! Long-context serving (DESIGN.md §17) adds [`TieredKv`]: the same
//! allocator fronted by a capped *resident* pool over a modeled host tier —
//! cold pages spill farthest-behind-the-cursor first and prefetch back
//! ahead of the decode cursor, opening prompts the resident pool alone
//! cannot hold.

use std::collections::BTreeMap;
use std::fmt;

/// Allocation error.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum KvError {
    /// The free list cannot satisfy an allocation.
    OutOfBlocks {
        /// Blocks the request needed.
        need: usize,
        /// Blocks that were free.
        free: usize,
    },
    /// The sequence id is not registered.
    UnknownSeq(u64),
    /// An append would push the sequence past a fixed capacity.
    OverCapacity {
        /// Offending sequence id.
        seq: u64,
        /// Its current token length.
        len: usize,
        /// Tokens the append asked for.
        add: usize,
        /// The capacity that would be exceeded.
        cap: usize,
    },
    /// A truncate asked for a length beyond the current one.
    BadTruncate {
        /// Offending sequence id.
        seq: u64,
        /// Its current token length.
        len: usize,
        /// The (longer) length the caller asked to truncate to.
        to: usize,
    },
}

impl fmt::Display for KvError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            KvError::OutOfBlocks { need, free } => {
                write!(f, "out of KV blocks (need {need}, free {free})")
            }
            KvError::UnknownSeq(s) => write!(f, "unknown sequence {s}"),
            KvError::OverCapacity { seq, len, add, cap } => {
                write!(f, "sequence {seq} over capacity: {len} + {add} > {cap}")
            }
            KvError::BadTruncate { seq, len, to } => {
                write!(f, "sequence {seq}: cannot truncate len {len} up to {to}")
            }
        }
    }
}

impl std::error::Error for KvError {}

/// Block-granular KV allocator for a fixed-capacity cache region.
#[derive(Debug)]
pub struct KvManager {
    block_tokens: usize,
    n_blocks: usize,
    free: Vec<usize>,
    /// seq id → (block ids, token length)
    seqs: BTreeMap<u64, SeqEntry>,
}

#[derive(Debug, Clone)]
struct SeqEntry {
    blocks: Vec<usize>,
    len: usize,
}

impl KvManager {
    /// `capacity_tokens` total slots, managed in blocks of `block_tokens`.
    pub fn new(capacity_tokens: usize, block_tokens: usize) -> Self {
        assert!(block_tokens > 0 && capacity_tokens % block_tokens == 0);
        let n_blocks = capacity_tokens / block_tokens;
        KvManager {
            block_tokens,
            n_blocks,
            free: (0..n_blocks).rev().collect(),
            seqs: BTreeMap::new(),
        }
    }

    /// Blocks currently on the free list.
    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    /// Total blocks managed (free + owned).
    pub fn total_blocks(&self) -> usize {
        self.n_blocks
    }

    /// Total token capacity across all blocks.
    pub fn capacity_tokens(&self) -> usize {
        self.n_blocks * self.block_tokens
    }

    /// Current token length of `seq`, if registered.
    pub fn seq_len(&self, seq: u64) -> Option<usize> {
        self.seqs.get(&seq).map(|e| e.len)
    }

    /// Number of registered sequences.
    pub fn live_seqs(&self) -> usize {
        self.seqs.len()
    }

    /// Register a new empty sequence.
    pub fn add_seq(&mut self, seq: u64) {
        assert!(!self.seqs.contains_key(&seq), "seq {seq} already exists");
        self.seqs.insert(seq, SeqEntry { blocks: Vec::new(), len: 0 });
    }

    /// Can `tokens` more be appended to `seq` without failing?
    pub fn can_append(&self, seq: u64, tokens: usize) -> bool {
        match self.seqs.get(&seq) {
            None => false,
            Some(e) => {
                let have = e.blocks.len() * self.block_tokens - e.len;
                let need_tokens = tokens.saturating_sub(have);
                let need_blocks = need_tokens.div_ceil(self.block_tokens);
                need_blocks <= self.free.len()
            }
        }
    }

    /// Append a chunk of `tokens` to `seq`; returns the absolute start
    /// position of the chunk (== previous length).
    pub fn append(&mut self, seq: u64, tokens: usize) -> Result<usize, KvError> {
        let e = self.seqs.get(&seq).ok_or(KvError::UnknownSeq(seq))?;
        let have = e.blocks.len() * self.block_tokens - e.len;
        let need_tokens = tokens.saturating_sub(have);
        let need_blocks = need_tokens.div_ceil(self.block_tokens);
        if need_blocks > self.free.len() {
            return Err(KvError::OutOfBlocks { need: need_blocks, free: self.free.len() });
        }
        let e = self.seqs.get_mut(&seq).expect("invariant: seq present (checked above)");
        for _ in 0..need_blocks {
            e.blocks.push(self.free.pop().expect("invariant: free list sized by capacity check"));
        }
        let start = e.len;
        e.len += tokens;
        Ok(start)
    }

    /// Shrink `seq` to `new_len` tokens, returning the blocks of the cut
    /// suffix to the free list — the speculative-decode rollback
    /// (DESIGN.md §10): a verify window appends `k + 1` positions
    /// optimistically and truncates back to the accepted prefix. Growing
    /// (`new_len > len`) is a [`KvError::BadTruncate`]; use
    /// [`KvManager::append`].
    pub fn truncate(&mut self, seq: u64, new_len: usize) -> Result<(), KvError> {
        let e = self.seqs.get_mut(&seq).ok_or(KvError::UnknownSeq(seq))?;
        if new_len > e.len {
            return Err(KvError::BadTruncate { seq, len: e.len, to: new_len });
        }
        let keep_blocks = new_len.div_ceil(self.block_tokens);
        while e.blocks.len() > keep_blocks {
            self.free.push(e.blocks.pop().expect("invariant: block table covers len"));
        }
        e.len = new_len;
        Ok(())
    }

    /// Release a sequence's blocks back to the free list.
    pub fn release(&mut self, seq: u64) -> Result<(), KvError> {
        let e = self.seqs.remove(&seq).ok_or(KvError::UnknownSeq(seq))?;
        self.free.extend(e.blocks);
        Ok(())
    }

    /// The block table of a sequence (block ids in position order).
    pub fn block_table(&self, seq: u64) -> Option<&[usize]> {
        self.seqs.get(&seq).map(|e| e.blocks.as_slice())
    }

    /// Internal invariant: no block is both free and owned, and every
    /// block is accounted for exactly once.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut seen = vec![false; self.n_blocks];
        for &b in &self.free {
            if seen[b] {
                return Err(format!("block {b} double-listed in free list"));
            }
            seen[b] = true;
        }
        for (seq, e) in &self.seqs {
            if e.len > e.blocks.len() * self.block_tokens {
                return Err(format!("seq {seq} len {} exceeds its blocks", e.len));
            }
            for &b in &e.blocks {
                if seen[b] {
                    return Err(format!("block {b} owned twice (seq {seq})"));
                }
                seen[b] = true;
            }
        }
        if !seen.iter().all(|&s| s) {
            return Err("leaked blocks (neither free nor owned)".into());
        }
        Ok(())
    }
}

/// Error surface of the tiered (resident + host) KV model.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum KvTierError {
    /// The resident pool cannot hold the request and offload is off —
    /// the typed failure a too-long prompt hits without
    /// `slo.kv_offload` (DESIGN.md §17).
    ResidentPoolExceeded {
        /// Offending sequence id.
        seq: u64,
        /// Resident tokens the request would have needed.
        need: usize,
        /// The configured resident cap in tokens.
        cap: usize,
    },
    /// An underlying block-allocator error.
    Kv(KvError),
}

impl fmt::Display for KvTierError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            KvTierError::ResidentPoolExceeded { seq, need, cap } => write!(
                f,
                "sequence {seq} exceeds the resident KV pool (need {need} tokens, \
                 cap {cap}); enable slo.kv_offload to spill to the host tier"
            ),
            KvTierError::Kv(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for KvTierError {}

impl From<KvError> for KvTierError {
    fn from(e: KvError) -> Self {
        KvTierError::Kv(e)
    }
}

/// Per-sequence page residency for [`TieredKv`]: one flag per block of
/// the sequence's block table, plus a low-water hint so the coldest
/// resident page is found without rescanning from zero.
#[derive(Debug, Clone, Default)]
struct SeqResidency {
    /// `flags[p]` — is the sequence's `p`-th page resident?
    flags: Vec<bool>,
    /// No resident page exists below this index (monotone except when a
    /// fetch brings an older page back).
    low: usize,
}

impl SeqResidency {
    /// Lowest resident page at or above `from`, advancing the hint.
    fn first_resident(&mut self, from: usize) -> Option<usize> {
        while self.low < self.flags.len() && !self.flags[self.low] {
            self.low += 1;
        }
        let mut p = self.low.max(from);
        while p < self.flags.len() && !self.flags[p] {
            p += 1;
        }
        (p < self.flags.len()).then_some(p)
    }
}

/// Two-tier paged KV model (DESIGN.md §17): a capped *resident* pool in
/// front of an unbounded modeled *host* tier. The block allocator —
/// offsets, tables, free-list invariants — is the wrapped [`KvManager`]
/// over the whole logical space, so allocator-visible state is
/// **identical** to an all-resident run (pinned by the twin property
/// test); the tier only decides which pages are resident and counts the
/// modeled traffic (`spilled_pages` / `fetched_pages` /
/// `prefetched_pages`) the metrics report.
///
/// Spill policy is *least-recently-needed*: the decode cursor is the
/// sequence's write head, so the resident page farthest behind it (the
/// lowest page index, globally over all sequences) is the coldest and
/// spills first. The page under the write head and the pages of a
/// demanded range ([`TieredKv::ensure_resident`], [`TieredKv::prefetch`])
/// are pinned while they are hot; if the pinned window alone exceeds the
/// cap, residency overshoots rather than failing — the cap is a
/// pressure target, not a hard wall, exactly like a pinned-page budget.
///
/// With `resident_cap_tokens = 0` (uncapped) the tier never spills and
/// every operation is byte-identical to the bare [`KvManager`] — the
/// default-off contract of every knob in this repo.
#[derive(Debug)]
pub struct TieredKv {
    inner: KvManager,
    block_tokens: usize,
    resident_cap_tokens: usize,
    offload: bool,
    prefetch_pages: usize,
    residency: BTreeMap<u64, SeqResidency>,
    resident_blocks: usize,
    /// Pages spilled resident → host (modeled D2H traffic).
    pub spilled_pages: u64,
    /// Pages demand-fetched host → resident (modeled H2D stalls).
    pub fetched_pages: u64,
    /// Pages brought back ahead of the cursor (modeled H2D overlap).
    pub prefetched_pages: u64,
}

impl TieredKv {
    /// A tier over `capacity_tokens` of logical KV (the host tier backs
    /// all of it) with at most `resident_cap_tokens` resident
    /// (`0` = uncapped). `offload = false` keeps everything resident and
    /// turns a cap overflow into [`KvTierError::ResidentPoolExceeded`].
    pub fn new(
        capacity_tokens: usize,
        block_tokens: usize,
        resident_cap_tokens: usize,
        prefetch_pages: usize,
        offload: bool,
    ) -> Self {
        TieredKv {
            inner: KvManager::new(capacity_tokens, block_tokens),
            block_tokens,
            resident_cap_tokens,
            offload,
            prefetch_pages,
            residency: BTreeMap::new(),
            resident_blocks: 0,
            spilled_pages: 0,
            fetched_pages: 0,
            prefetched_pages: 0,
        }
    }

    /// The wrapped allocator (read-only: lengths, tables, free lists).
    pub fn allocator(&self) -> &KvManager {
        &self.inner
    }

    /// Tokens currently resident across all sequences.
    pub fn resident_tokens(&self) -> usize {
        self.resident_blocks * self.block_tokens
    }

    /// Whether the page holding `token_pos` of `seq` is resident.
    pub fn is_resident(&self, seq: u64, token_pos: usize) -> bool {
        let page = token_pos / self.block_tokens;
        self.residency.get(&seq).map(|r| r.flags.get(page) == Some(&true)).unwrap_or(false)
    }

    /// Current token length of `seq`, if registered.
    pub fn seq_len(&self, seq: u64) -> Option<usize> {
        self.inner.seq_len(seq)
    }

    /// Blocks currently on the free list of the logical space.
    pub fn free_blocks(&self) -> usize {
        self.inner.free_blocks()
    }

    /// Register a new empty sequence.
    pub fn add_seq(&mut self, seq: u64) {
        self.inner.add_seq(seq);
        self.residency.insert(seq, SeqResidency::default());
    }

    /// Can `tokens` more be appended to `seq` without failing? Mirrors
    /// [`TieredKv::append`], including the offload-off resident check.
    pub fn can_append(&self, seq: u64, tokens: usize) -> bool {
        if !self.inner.can_append(seq, tokens) {
            return false;
        }
        if !self.offload && self.resident_cap_tokens > 0 {
            let after = self.resident_blocks_after(seq, tokens) * self.block_tokens;
            if after > self.resident_cap_tokens {
                return false;
            }
        }
        true
    }

    /// Resident blocks after an append of `tokens` to `seq`, assuming
    /// nothing spills (the offload-off accounting).
    fn resident_blocks_after(&self, seq: u64, tokens: usize) -> usize {
        let len = self.inner.seq_len(seq).unwrap_or(0);
        let have = self.inner.block_table(seq).map(|t| t.len()).unwrap_or(0);
        let need = (len + tokens).div_ceil(self.block_tokens).saturating_sub(have);
        self.resident_blocks + need
    }

    /// Append a chunk of `tokens` to `seq`; returns the chunk's absolute
    /// start position. New pages land resident; under offload, residency
    /// past the cap spills the coldest pages (only the page under the
    /// write head is pinned — a streamed chunk is written, consumed, and
    /// its cold part spills). Without offload, a chunk that cannot fit
    /// the resident cap fails typed — the state is untouched.
    pub fn append(&mut self, seq: u64, tokens: usize) -> Result<usize, KvTierError> {
        if !self.offload && self.resident_cap_tokens > 0 {
            let after = self.resident_blocks_after(seq, tokens) * self.block_tokens;
            if after > self.resident_cap_tokens {
                return Err(KvTierError::ResidentPoolExceeded {
                    seq,
                    need: after,
                    cap: self.resident_cap_tokens,
                });
            }
        }
        let start = self.inner.append(seq, tokens)?;
        let pages = self.inner.block_table(seq).expect("appended seq exists").len();
        let r = self.residency.get_mut(&seq).expect("residency tracked per seq");
        while r.flags.len() < pages {
            r.flags.push(true);
            self.resident_blocks += 1;
        }
        if self.offload {
            self.enforce_cap(seq, pages.saturating_sub(1)..usize::MAX);
        }
        Ok(start)
    }

    /// Shrink `seq` to `new_len` tokens (speculative rollback); cut
    /// pages leave whichever tier held them.
    pub fn truncate(&mut self, seq: u64, new_len: usize) -> Result<(), KvTierError> {
        self.inner.truncate(seq, new_len)?;
        let pages = self.inner.block_table(seq).expect("truncated seq exists").len();
        let r = self.residency.get_mut(&seq).expect("residency tracked per seq");
        while r.flags.len() > pages {
            if r.flags.pop().expect("non-empty flags") {
                self.resident_blocks -= 1;
            }
        }
        Ok(())
    }

    /// Release a sequence entirely (both tiers).
    pub fn release(&mut self, seq: u64) -> Result<(), KvTierError> {
        self.inner.release(seq)?;
        let r = self.residency.remove(&seq).expect("residency tracked per seq");
        self.resident_blocks -= r.flags.iter().filter(|&&f| f).count();
        Ok(())
    }

    /// Demand-fetch: make every page of `seq` covering `[0, upto_tokens)`
    /// resident (counted in `fetched_pages`), then re-enforce the cap
    /// spilling only pages *outside* the demanded range. The replay /
    /// re-prefill motion uses this before touching a restored prefix.
    pub fn ensure_resident(&mut self, seq: u64, upto_tokens: usize) -> Result<(), KvTierError> {
        let len = self.inner.seq_len(seq).ok_or(KvError::UnknownSeq(seq))?;
        let pages = upto_tokens.min(len).div_ceil(self.block_tokens);
        let r = self.residency.get_mut(&seq).expect("residency tracked per seq");
        for p in 0..pages {
            if !r.flags[p] {
                r.flags[p] = true;
                r.low = r.low.min(p);
                self.resident_blocks += 1;
                self.fetched_pages += 1;
            }
        }
        if self.offload {
            self.enforce_cap(seq, 0..pages);
        }
        Ok(())
    }

    /// Prefetch ahead of the decode cursor: bring the last
    /// `prefetch_pages` pages of `seq` (the window the next decode steps
    /// read and extend) back resident before they stall a step, counted
    /// in `prefetched_pages`. No-op when the tail is already resident.
    pub fn prefetch(&mut self, seq: u64) -> Result<(), KvTierError> {
        let len = self.inner.seq_len(seq).ok_or(KvError::UnknownSeq(seq))?;
        let pages = len.div_ceil(self.block_tokens);
        let from = pages.saturating_sub(self.prefetch_pages);
        let r = self.residency.get_mut(&seq).expect("residency tracked per seq");
        for p in from..pages {
            if !r.flags[p] {
                r.flags[p] = true;
                r.low = r.low.min(p);
                self.resident_blocks += 1;
                self.prefetched_pages += 1;
            }
        }
        if self.offload {
            self.enforce_cap(seq, from..usize::MAX);
        }
        Ok(())
    }

    /// Spill coldest-first until residency fits the cap. Pages of
    /// `protect_seq` inside the `protect` page range are pinned; if only
    /// pinned pages remain, residency overshoots (see type docs).
    fn enforce_cap(&mut self, protect_seq: u64, protect: std::ops::Range<usize>) {
        if self.resident_cap_tokens == 0 {
            return;
        }
        while self.resident_blocks * self.block_tokens > self.resident_cap_tokens {
            // Coldest page: the resident page farthest behind its write
            // head, globally. Ties resolve to the lowest sequence id —
            // deterministic, like every scheduling decision here.
            let mut best: Option<(usize, u64, usize)> = None;
            for (&seq, r) in self.residency.iter_mut() {
                let Some(mut p) = r.first_resident(0) else { continue };
                if seq == protect_seq && protect.contains(&p) {
                    match r.first_resident(protect.end) {
                        Some(q) => p = q,
                        None => continue,
                    }
                }
                let dist = r.flags.len() - p;
                if best.map(|(d, _, _)| dist > d).unwrap_or(true) {
                    best = Some((dist, seq, p));
                }
            }
            let Some((_, seq, page)) = best else { return };
            let r = self.residency.get_mut(&seq).expect("candidate seq exists");
            r.flags[page] = false;
            self.resident_blocks -= 1;
            self.spilled_pages += 1;
        }
    }

    /// Internal invariants: the wrapped allocator's, plus residency
    /// flags exactly covering each block table and the resident count
    /// matching the flags.
    pub fn check_invariants(&self) -> Result<(), String> {
        self.inner.check_invariants()?;
        let mut resident = 0;
        for (&seq, r) in &self.residency {
            let table = self.inner.block_table(seq).ok_or(format!("seq {seq} untracked"))?;
            if r.flags.len() != table.len() {
                return Err(format!(
                    "seq {seq}: {} residency flags over {} blocks",
                    r.flags.len(),
                    table.len()
                ));
            }
            resident += r.flags.iter().filter(|&&f| f).count();
        }
        if resident != self.resident_blocks {
            return Err(format!(
                "resident count drifted: {} counted, {} cached",
                resident, self.resident_blocks
            ));
        }
        Ok(())
    }
}

/// A dense per-(rank, layer) KV region matching the AOT attention stage
/// input: `[n_kv_heads, max_seq, head_dim]` f32, plus the write helper the
/// coordinator uses to scatter a chunk's K/V at its absolute offset.
#[derive(Clone, Debug)]
pub struct DenseKv {
    /// KV heads in this rank's shard.
    pub n_kv_heads: usize,
    /// Positions the region holds.
    pub max_seq: usize,
    /// Per-head feature dimension.
    pub head_dim: usize,
    /// Key buffer, `[n_kv_heads, max_seq, head_dim]` row-major.
    pub k: Vec<f32>,
    /// Value buffer, same layout as `k`.
    pub v: Vec<f32>,
}

impl DenseKv {
    /// A zero-filled region of the given geometry.
    pub fn new(n_kv_heads: usize, max_seq: usize, head_dim: usize) -> Self {
        let n = n_kv_heads * max_seq * head_dim;
        DenseKv { n_kv_heads, max_seq, head_dim, k: vec![0.0; n], v: vec![0.0; n] }
    }

    /// Overwrite from a full returned cache (the AOT attention stage
    /// returns the updated `[h, S, d]` cache tensors).
    pub fn store(&mut self, k: Vec<f32>, v: Vec<f32>) {
        debug_assert_eq!(k.len(), self.k.len());
        debug_assert_eq!(v.len(), self.v.len());
        self.k = k;
        self.v = v;
    }

    /// Zero positions `[from, to)` across all heads (sequence release).
    pub fn zero_range(&mut self, from: usize, to: usize) {
        for h in 0..self.n_kv_heads {
            let base = h * self.max_seq * self.head_dim;
            let a = base + from * self.head_dim;
            let b = base + to * self.head_dim;
            self.k[a..b].fill(0.0);
            self.v[a..b].fill(0.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{Prop, Rng};

    #[test]
    fn append_returns_absolute_offsets() {
        let mut kv = KvManager::new(256, 16);
        kv.add_seq(1);
        assert_eq!(kv.append(1, 64).unwrap(), 0);
        assert_eq!(kv.append(1, 64).unwrap(), 64); // ISO chunk 1 offset
        assert_eq!(kv.seq_len(1), Some(128));
    }

    #[test]
    fn blocks_allocated_lazily_and_exactly() {
        let mut kv = KvManager::new(256, 16);
        kv.add_seq(1);
        kv.append(1, 8).unwrap();
        assert_eq!(kv.block_table(1).unwrap().len(), 1);
        kv.append(1, 8).unwrap(); // fits the same block
        assert_eq!(kv.block_table(1).unwrap().len(), 1);
        kv.append(1, 1).unwrap();
        assert_eq!(kv.block_table(1).unwrap().len(), 2);
    }

    #[test]
    fn out_of_blocks_fails_cleanly() {
        let mut kv = KvManager::new(64, 16);
        kv.add_seq(1);
        assert!(matches!(
            kv.append(1, 100),
            Err(KvError::OutOfBlocks { .. })
        ));
        // failed append must not leak partial state
        assert_eq!(kv.seq_len(1), Some(0));
        assert_eq!(kv.free_blocks(), 4);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn release_returns_blocks() {
        let mut kv = KvManager::new(128, 16);
        kv.add_seq(1);
        kv.add_seq(2);
        kv.append(1, 48).unwrap();
        kv.append(2, 32).unwrap();
        kv.release(1).unwrap();
        assert_eq!(kv.free_blocks(), 8 - 2);
        assert!(kv.seq_len(1).is_none());
        kv.check_invariants().unwrap();
        assert_eq!(kv.release(1), Err(KvError::UnknownSeq(1)));
    }

    #[test]
    fn can_append_predicts_append() {
        let mut kv = KvManager::new(64, 16);
        kv.add_seq(1);
        assert!(kv.can_append(1, 64));
        assert!(!kv.can_append(1, 65));
        kv.append(1, 64).unwrap();
        assert!(!kv.can_append(1, 1));
        assert!(!kv.can_append(99, 1)); // unknown seq
    }

    #[test]
    fn prop_alloc_release_never_leaks() {
        Prop::new(31).cases(200).run("kv alloc/release invariants", |rng: &mut Rng| {
            let mut kv = KvManager::new(1024, 16);
            let mut live: Vec<u64> = Vec::new();
            let mut next_id = 0u64;
            for _ in 0..100 {
                match rng.range(0, 3) {
                    0 => {
                        kv.add_seq(next_id);
                        live.push(next_id);
                        next_id += 1;
                    }
                    1 if !live.is_empty() => {
                        let seq = live[rng.range(0, live.len())];
                        let n = rng.range(1, 100);
                        if kv.can_append(seq, n) {
                            kv.append(seq, n).map_err(|e| e.to_string())?;
                        } else {
                            // must fail without corrupting state
                            let before = kv.free_blocks();
                            let _ = kv.append(seq, n);
                            if kv.free_blocks() != before {
                                return Err("failed append leaked blocks".into());
                            }
                        }
                    }
                    _ if !live.is_empty() => {
                        let i = rng.range(0, live.len());
                        let seq = live.swap_remove(i);
                        kv.release(seq).map_err(|e| e.to_string())?;
                    }
                    _ => {}
                }
                kv.check_invariants()?;
            }
            Ok(())
        });
    }

    #[test]
    fn truncate_frees_suffix_blocks_exactly() {
        let mut kv = KvManager::new(256, 16);
        kv.add_seq(1);
        kv.append(1, 40).unwrap(); // 3 blocks (48 slots)
        assert_eq!(kv.block_table(1).unwrap().len(), 3);
        // Cut inside the second block: the third block frees, the second stays.
        kv.truncate(1, 20).unwrap();
        assert_eq!(kv.seq_len(1), Some(20));
        assert_eq!(kv.block_table(1).unwrap().len(), 2);
        assert_eq!(kv.free_blocks(), 16 - 2);
        kv.check_invariants().unwrap();
        // Truncate to a block boundary and to zero.
        kv.truncate(1, 16).unwrap();
        assert_eq!(kv.block_table(1).unwrap().len(), 1);
        kv.truncate(1, 0).unwrap();
        assert_eq!(kv.block_table(1).unwrap().len(), 0);
        assert_eq!(kv.free_blocks(), 16);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn truncate_rejects_growth_and_unknown_seq() {
        let mut kv = KvManager::new(64, 16);
        kv.add_seq(1);
        kv.append(1, 10).unwrap();
        assert_eq!(
            kv.truncate(1, 11),
            Err(KvError::BadTruncate { seq: 1, len: 10, to: 11 })
        );
        assert_eq!(kv.truncate(9, 0), Err(KvError::UnknownSeq(9)));
        // No-op truncate to the current length is fine.
        kv.truncate(1, 10).unwrap();
        assert_eq!(kv.seq_len(1), Some(10));
        kv.check_invariants().unwrap();
    }

    #[test]
    fn prop_speculative_append_truncate_conserves_blocks() {
        // Satellite (DESIGN.md §10): the verify-window motion — append
        // k+1 positions, accept a random prefix, truncate the rest —
        // never leaks or double-frees a block, and the block table always
        // covers exactly ceil(len / block_tokens) blocks.
        Prop::new(67).cases(200).run("kv speculative append/truncate", |rng: &mut Rng| {
            let block = 16;
            let mut kv = KvManager::new(1024, block);
            let n_seqs = rng.range(1, 5) as u64;
            for s in 0..n_seqs {
                kv.add_seq(s);
                // Random prefill.
                let prefill = rng.range(1, 80);
                if kv.can_append(s, prefill) {
                    kv.append(s, prefill).map_err(|e| e.to_string())?;
                }
            }
            for _ in 0..120 {
                let s = rng.below(n_seqs);
                let k = rng.range(0, 9); // drafts per window
                let window = k + 1;
                let len = kv.seq_len(s).unwrap();
                if !kv.can_append(s, window) {
                    continue;
                }
                let start = kv.append(s, window).map_err(|e| e.to_string())?;
                if start != len {
                    return Err(format!("append at {start}, expected {len}"));
                }
                // Random acceptance: keep 1..=window of the appended rows.
                let take = rng.range(1, window + 1);
                kv.truncate(s, len + take).map_err(|e| e.to_string())?;
                if kv.seq_len(s) != Some(len + take) {
                    return Err("truncate set the wrong length".into());
                }
                let blocks = kv.block_table(s).unwrap().len();
                let want = (len + take).div_ceil(block);
                if blocks != want {
                    return Err(format!(
                        "len {} held {blocks} blocks, want {want}",
                        len + take
                    ));
                }
                kv.check_invariants()?;
            }
            for s in 0..n_seqs {
                kv.release(s).map_err(|e| e.to_string())?;
            }
            if kv.free_blocks() != kv.total_blocks() {
                return Err("release after spec traffic leaked blocks".into());
            }
            kv.check_invariants()?;
            Ok(())
        });
    }

    #[test]
    fn prop_preempt_restore_matches_uninterrupted_twin() {
        // Satellite (DESIGN.md §15): KV-pressure preemption evicts a
        // sequence mid-decode (release) and later restores it by
        // re-registering and re-appending its committed prefix in one go —
        // the serve loop's `add_seq` + `append(slot, committed)` motion.
        // Drive twin managers with identical traffic, preempt/restore one
        // of them at random points, and require the allocator state they
        // expose (lengths, block-table sizes, free counts, and the start
        // offsets of every subsequent append) to stay identical.
        Prop::new(103).cases(200).run("kv preempt/restore equivalence", |rng: &mut Rng| {
            let block = 16;
            let mut a = KvManager::new(2048, block); // uninterrupted twin
            let mut b = KvManager::new(2048, block); // preempted twin
            let n_seqs = rng.range(2, 5) as u64;
            for s in 0..n_seqs {
                a.add_seq(s);
                b.add_seq(s);
                let prefill = rng.range(8, 96);
                a.append(s, prefill).map_err(|e| e.to_string())?;
                b.append(s, prefill).map_err(|e| e.to_string())?;
            }
            for _ in 0..80 {
                let s = rng.below(n_seqs);
                match rng.range(0, 4) {
                    // Decode step: both twins append one token.
                    0..=2 => {
                        if !a.can_append(s, 1) {
                            continue;
                        }
                        let oa = a.append(s, 1).map_err(|e| e.to_string())?;
                        let ob = b.append(s, 1).map_err(|e| e.to_string())?;
                        if oa != ob {
                            return Err(format!("append offsets diverged: {oa} vs {ob}"));
                        }
                    }
                    // Preempt + immediate restore on twin B only.
                    _ => {
                        let committed = b.seq_len(s).unwrap();
                        b.release(s).map_err(|e| e.to_string())?;
                        b.add_seq(s);
                        let start = b.append(s, committed).map_err(|e| e.to_string())?;
                        if start != 0 {
                            return Err(format!("restore append started at {start}"));
                        }
                    }
                }
                for s in 0..n_seqs {
                    if a.seq_len(s) != b.seq_len(s) {
                        return Err(format!("seq {s} lengths diverged"));
                    }
                    let (ba, bb) = (
                        a.block_table(s).unwrap().len(),
                        b.block_table(s).unwrap().len(),
                    );
                    if ba != bb {
                        return Err(format!("seq {s} block counts diverged: {ba} vs {bb}"));
                    }
                }
                if a.free_blocks() != b.free_blocks() {
                    return Err("free-block counts diverged".into());
                }
                a.check_invariants()?;
                b.check_invariants()?;
            }
            Ok(())
        });
    }

    #[test]
    fn dense_kv_store_and_zero() {
        let mut kv = DenseKv::new(2, 8, 4);
        let k: Vec<f32> = (0..2 * 8 * 4).map(|i| i as f32).collect();
        kv.store(k.clone(), k.clone());
        kv.zero_range(2, 4);
        for h in 0..2 {
            for pos in 2..4 {
                for d in 0..4 {
                    let idx = h * 32 + pos * 4 + d;
                    assert_eq!(kv.k[idx], 0.0);
                }
            }
            // outside range untouched
            let idx = h * 32 + 4 * 4;
            assert_eq!(kv.k[idx], k[idx]);
        }
    }
}

#[cfg(test)]
mod tier_tests {
    use super::*;
    use crate::util::{Prop, Rng};

    #[test]
    fn uncapped_tier_matches_bare_manager() {
        // resident_cap_tokens = 0 is the default-off contract: the tier
        // is byte-identical to the bare allocator and never moves a page.
        let mut tier = TieredKv::new(256, 16, 0, 2, false);
        let mut bare = KvManager::new(256, 16);
        tier.add_seq(7);
        bare.add_seq(7);
        for chunk in [5, 16, 1, 40] {
            assert_eq!(tier.append(7, chunk).unwrap(), bare.append(7, chunk).unwrap());
            assert_eq!(tier.free_blocks(), bare.free_blocks());
        }
        tier.truncate(7, 20).unwrap();
        bare.truncate(7, 20).unwrap();
        assert_eq!(tier.free_blocks(), bare.free_blocks());
        assert_eq!(tier.seq_len(7), bare.seq_len(7));
        assert_eq!(tier.resident_tokens(), 2 * 16);
        assert_eq!(tier.spilled_pages + tier.fetched_pages + tier.prefetched_pages, 0);
        tier.check_invariants().unwrap();
        tier.release(7).unwrap();
        assert_eq!(tier.resident_tokens(), 0);
        tier.check_invariants().unwrap();
    }

    #[test]
    fn over_cap_without_offload_is_a_typed_error() {
        let mut tier = TieredKv::new(512, 16, 64, 2, false);
        tier.add_seq(1);
        assert_eq!(tier.append(1, 64).unwrap(), 0);
        assert!(!tier.can_append(1, 1));
        let err = tier.append(1, 1).unwrap_err();
        assert_eq!(err, KvTierError::ResidentPoolExceeded { seq: 1, need: 80, cap: 64 });
        // The failed append left no trace.
        assert_eq!(tier.seq_len(1), Some(64));
        assert_eq!(tier.resident_tokens(), 64);
        assert_eq!(tier.spilled_pages, 0);
        tier.check_invariants().unwrap();
    }

    #[test]
    fn offload_spills_coldest_pages_first() {
        let mut tier = TieredKv::new(1024, 16, 64, 2, true);
        tier.add_seq(1);
        for _ in 0..10 {
            tier.append(1, 16).unwrap();
        }
        // 10 pages written, 4 fit: the 6 farthest behind the cursor spill.
        assert_eq!(tier.resident_tokens(), 64);
        assert_eq!(tier.spilled_pages, 6);
        for page in 0..6 {
            assert!(!tier.is_resident(1, page * 16), "page {page} should be cold");
        }
        for page in 6..10 {
            assert!(tier.is_resident(1, page * 16), "page {page} should be hot");
        }
        tier.check_invariants().unwrap();
    }

    #[test]
    fn ensure_resident_demand_fetches_a_prefix() {
        let mut tier = TieredKv::new(1024, 16, 64, 2, true);
        tier.add_seq(1);
        tier.append(1, 160).unwrap();
        assert!(!tier.is_resident(1, 0));
        tier.ensure_resident(1, 48).unwrap();
        // The demanded prefix is pinned; the cap spilled tail pages instead.
        for page in 0..3 {
            assert!(tier.is_resident(1, page * 16), "page {page} should be fetched");
        }
        assert_eq!(tier.fetched_pages, 3);
        assert_eq!(tier.resident_tokens(), 64);
        tier.check_invariants().unwrap();
    }

    #[test]
    fn prefetch_restores_the_tail_window() {
        let mut tier = TieredKv::new(1024, 16, 64, 2, true);
        tier.add_seq(1);
        tier.append(1, 160).unwrap();
        // Drag the whole resident budget to the front of the sequence...
        tier.ensure_resident(1, 64).unwrap();
        assert!(!tier.is_resident(1, 159));
        // ...then prefetch brings the decode window back before a step.
        tier.prefetch(1).unwrap();
        assert!(tier.is_resident(1, 159));
        assert!(tier.is_resident(1, 128 + 1));
        assert_eq!(tier.prefetched_pages, 2);
        assert_eq!(tier.resident_tokens(), 64);
        tier.check_invariants().unwrap();
    }

    #[test]
    fn million_token_prompt_needs_offload() {
        // Acceptance (DESIGN.md §17): a 1M-token prompt fails typed on a
        // resident-only pool and completes once offload may spill.
        let cap = 1 << 14;
        let mut strict = TieredKv::new(1 << 20, 256, cap, 4, false);
        strict.add_seq(1);
        let mut failed = None;
        for _ in 0..256 {
            if let Err(e) = strict.append(1, 4096) {
                failed = Some(e);
                break;
            }
        }
        match failed {
            Some(KvTierError::ResidentPoolExceeded { seq: 1, cap: c, .. }) => {
                assert_eq!(c, cap);
            }
            other => panic!("expected ResidentPoolExceeded, got {other:?}"),
        }

        let mut tier = TieredKv::new(1 << 20, 256, cap, 4, true);
        tier.add_seq(1);
        for _ in 0..256 {
            tier.append(1, 4096).unwrap();
        }
        assert_eq!(tier.seq_len(1), Some(1 << 20));
        assert_eq!(tier.resident_tokens(), cap);
        assert_eq!(tier.spilled_pages as usize, (1 << 20) / 256 - cap / 256);
        tier.check_invariants().unwrap();
    }

    #[test]
    fn prop_offload_twin_matches_all_resident_run() {
        // Tentpole (DESIGN.md §17): spill/fetch/prefetch motion is pure
        // residency bookkeeping — the allocator state the scheduler sees
        // (lengths, offsets, block tables, free counts) must stay
        // identical to an uninterrupted all-resident twin under the same
        // traffic, mirroring the preempt/restore twin above.
        Prop::new(211).cases(150).run("kv offload twin equivalence", |rng: &mut Rng| {
            let block = 16;
            let cap = block * rng.range(3, 9);
            let mut tier = TieredKv::new(2048, block, cap, rng.range(1, 4), true);
            let mut bare = KvManager::new(2048, block);
            let n_seqs = rng.range(2, 5) as u64;
            for s in 0..n_seqs {
                tier.add_seq(s);
                bare.add_seq(s);
                let prefill = rng.range(8, 96);
                let ot = tier.append(s, prefill).map_err(|e| e.to_string())?;
                let ob = bare.append(s, prefill).map_err(|e| e.to_string())?;
                if ot != ob {
                    return Err(format!("prefill offsets diverged: {ot} vs {ob}"));
                }
            }
            for _ in 0..100 {
                let s = rng.below(n_seqs);
                match rng.range(0, 6) {
                    // Decode step on both twins.
                    0..=2 => {
                        if !bare.can_append(s, 1) {
                            continue;
                        }
                        let ot = tier.append(s, 1).map_err(|e| e.to_string())?;
                        let ob = bare.append(s, 1).map_err(|e| e.to_string())?;
                        if ot != ob {
                            return Err(format!("append offsets diverged: {ot} vs {ob}"));
                        }
                    }
                    // Speculative rollback on both twins.
                    3 => {
                        let len = bare.seq_len(s).unwrap();
                        let keep = rng.range(0, len + 1);
                        tier.truncate(s, keep).map_err(|e| e.to_string())?;
                        bare.truncate(s, keep).map_err(|e| e.to_string())?;
                    }
                    // Tier-only motion: demand fetch or prefetch. The bare
                    // twin has no counterpart — that is the point.
                    4 => {
                        let len = bare.seq_len(s).unwrap();
                        tier.ensure_resident(s, rng.range(0, len + 1))
                            .map_err(|e| e.to_string())?;
                    }
                    _ => tier.prefetch(s).map_err(|e| e.to_string())?,
                }
                for s in 0..n_seqs {
                    if tier.seq_len(s) != bare.seq_len(s) {
                        return Err(format!("seq {s} lengths diverged"));
                    }
                    let (bt, bb) = (
                        tier.allocator().block_table(s).unwrap().len(),
                        bare.block_table(s).unwrap().len(),
                    );
                    if bt != bb {
                        return Err(format!("seq {s} block counts diverged: {bt} vs {bb}"));
                    }
                }
                if tier.free_blocks() != bare.free_blocks() {
                    return Err("free-block counts diverged".into());
                }
                tier.check_invariants()?;
                bare.check_invariants()?;
            }
            for s in 0..n_seqs {
                tier.release(s).map_err(|e| e.to_string())?;
                bare.release(s).map_err(|e| e.to_string())?;
            }
            if tier.resident_tokens() != 0 {
                return Err("release left resident pages behind".into());
            }
            tier.check_invariants()?;
            Ok(())
        });
    }
}
