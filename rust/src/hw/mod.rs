//! Hardware profiles and cost models — the calibrated substitute for the
//! paper's 4090/A800 testbeds (DESIGN.md §2).
//!
//! Everything here is derived from public spec sheets and standard
//! collective cost models:
//!   * GEMM: `time = flops / (peak * eff(m)) + launch_overhead`, where the
//!     efficiency curve `eff(m) = peak_eff * m/(m + m_half)` captures the
//!     small-m (short-chunk) efficiency cliff that makes short prompts
//!     lose from splitting (paper §4.2);
//!   * ring all-reduce: `2(R-1) * (alpha + bytes/R / link_bw)`;
//!   * NCCL SM contention: compute issued while a collective is in flight
//!     is inflated by `contention_factor` (paper §3.2: 15–20% on A800,
//!     negligible on 4090).

/// Wire-size factor of int8 comm quantization relative to the fp16
/// activation payload: half the bytes plus ~2% of per-row scales
/// (paper §3.2). Shared by the simulator's collective cost models and
/// the benches so a recalibration is a single-point change.
pub const INT8_WIRE_FACTOR: f64 = 0.51;

/// Wire-size factor of fp8 (e5m2) relative to fp16: half the bytes and,
/// being elementwise, no scale vector at all (DESIGN.md §16).
pub const FP8_WIRE_FACTOR: f64 = 0.5;

/// Wire-size factor of packed int4 relative to fp16: a quarter of the
/// bytes plus the same ~2%-of-fp16 per-row scale overhead int8 carries.
pub const INT4_WIRE_FACTOR: f64 = 0.26;

/// Bytes each precision-ladder rung puts on the wire relative to the
/// fp16 activation payload (DESIGN.md §16). f32 doubles fp16; the
/// quantized rungs reuse the calibrated `*_WIRE_FACTOR` constants so a
/// recalibration stays a single-point change.
pub fn wire_factor(q: crate::config::CommQuant) -> f64 {
    use crate::config::CommQuant;
    match q {
        CommQuant::F32 => 2.0,
        CommQuant::Fp16 => 1.0,
        CommQuant::Int8 => INT8_WIRE_FACTOR,
        CommQuant::Fp8 => FP8_WIRE_FACTOR,
        CommQuant::Int4 => INT4_WIRE_FACTOR,
    }
}

/// Interconnect profile for a ring collective.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkProfile {
    /// Per-step latency in seconds (software + transport).
    pub alpha_s: f64,
    /// Per-direction per-link bandwidth in bytes/second.
    pub link_bytes_per_s: f64,
}

impl LinkProfile {
    /// Ring all-reduce wall time for `bytes` across `r` ranks.
    /// 2(R−1) steps, each moving bytes/R over one link.
    pub fn ring_allreduce_s(&self, bytes: f64, r: usize) -> f64 {
        if r <= 1 || bytes <= 0.0 {
            return 0.0;
        }
        let steps = 2.0 * (r as f64 - 1.0);
        steps * (self.alpha_s + (bytes / r as f64) / self.link_bytes_per_s)
    }

    /// One point-to-point transfer of `bytes` over the link — the α/β
    /// model shared by the pipeline stage hops (DESIGN.md §11) and the
    /// CP shard ring's per-layer prefix forward (DESIGN.md §17).
    pub fn p2p_s(&self, bytes: f64) -> f64 {
        if bytes <= 0.0 {
            return 0.0;
        }
        self.alpha_s + bytes / self.link_bytes_per_s
    }

    /// Bus bandwidth achieved by the ring (NCCL's "busbw") — diagnostic.
    pub fn busbw(&self, bytes: f64, r: usize) -> f64 {
        let t = self.ring_allreduce_s(bytes, r);
        if t == 0.0 {
            return 0.0;
        }
        bytes * 2.0 * (r as f64 - 1.0) / r as f64 / t
    }
}

/// One GPU model's compute profile.
#[derive(Clone, Debug, PartialEq)]
pub struct DeviceProfile {
    /// Preset name (`rtx4090`, `a800`, `cpu-engine`, or custom).
    pub name: String,
    /// Peak dense GEMM throughput in FLOP/s for the serving dtype
    /// (int8 tensor ops per the paper's quant setup).
    pub peak_flops: f64,
    /// Asymptotic fraction of peak a well-shaped GEMM reaches.
    pub peak_eff: f64,
    /// GEMM rows at which efficiency reaches half of `peak_eff`.
    pub m_half: f64,
    /// Per-kernel-launch overhead (s).
    pub launch_s: f64,
    /// Compute-time inflation while a collective shares the SMs
    /// (paper §3.2: A800 1.15–1.20, 4090 ≈ 1).
    pub contention: f64,
}

impl DeviceProfile {
    /// GEMM efficiency at m rows (0..peak_eff].
    pub fn eff(&self, m: usize) -> f64 {
        self.peak_eff * m as f64 / (m as f64 + self.m_half)
    }

    /// Wall time of a GEMM-shaped op with `flops` work and `m` rows.
    pub fn gemm_s(&self, flops: f64, m: usize) -> f64 {
        if flops <= 0.0 {
            return 0.0;
        }
        flops / (self.peak_flops * self.eff(m)) + self.launch_s
    }
}

/// A full node: device + interconnect + card count.
#[derive(Clone, Debug, PartialEq)]
pub struct NodeProfile {
    /// Per-card compute profile.
    pub device: DeviceProfile,
    /// Ring interconnect profile.
    pub link: LinkProfile,
    /// Cards in the TP group.
    pub cards: usize,
    /// Whether the wire supports the int8 comm-quant path (paper: used on
    /// 4090, not on A800).
    pub int8_wire_default: bool,
}

impl NodeProfile {
    /// RTX 4090 node: strong int8 compute, PCIe-only ring (no NVLink) —
    /// the paper's communication-dominated platform.
    pub fn rtx4090(cards: usize) -> Self {
        assert!(cards >= 1);
        // 8-card rings cross the host bridge more often → lower per-link
        // effective bandwidth and higher step latency.
        let (alpha, bw) = if cards <= 4 {
            (20e-6, 14.0e9)
        } else {
            (26e-6, 10.5e9)
        };
        NodeProfile {
            device: DeviceProfile {
                name: "rtx4090".into(),
                peak_flops: 330e12, // int8 dense tensor TOPS
                peak_eff: 0.72,
                m_half: 96.0,
                launch_s: 8e-6,
                contention: 1.02, // paper: negligible
            },
            link: LinkProfile { alpha_s: alpha, link_bytes_per_s: bw },
            cards,
            int8_wire_default: true,
        }
    }

    /// A800 node: A100-class compute, 400 GB/s NVLink — the paper's
    /// computation-dominated platform.
    pub fn a800(cards: usize) -> Self {
        assert!(cards >= 1);
        // 8-card rings: NVSwitch keeps per-link bandwidth, but NCCL uses
        // more channels → more SMs stolen from compute (higher contention).
        let (contention, bw) = if cards <= 4 { (1.17, 150.0e9) } else { (1.20, 165.0e9) };
        NodeProfile {
            device: DeviceProfile {
                name: "a800".into(),
                peak_flops: 624e12, // int8 dense tensor TOPS
                peak_eff: 0.78,
                m_half: 160.0,
                launch_s: 6e-6,
                contention, // paper: 15–20%
            },
            link: LinkProfile { alpha_s: 6e-6, link_bytes_per_s: bw },
            cards,
            int8_wire_default: false,
        }
    }

    /// Preset lookup (`4090` / `a800`).
    pub fn by_name(name: &str, cards: usize) -> Option<Self> {
        match name {
            "4090" | "rtx4090" => Some(Self::rtx4090(cards)),
            "a800" | "A800" => Some(Self::a800(cards)),
            _ => None,
        }
    }

    /// Build a custom profile from `[hardware]` config keys (see
    /// `config::parse_config_str`). Unknown keys are errors; omitted keys
    /// default to the A800 preset so a partial file tweaks one knob.
    ///
    /// ```text
    /// [hardware]
    /// name = h800ish
    /// cards = 8
    /// peak_tflops = 990        # int8 dense
    /// peak_eff = 0.8
    /// m_half = 200
    /// launch_us = 5
    /// contention = 1.12
    /// link_alpha_us = 5
    /// link_gbps = 200          # bytes/s = gbps * 1e9
    /// int8_wire = false
    /// ```
    pub fn from_map(map: &std::collections::BTreeMap<String, String>) -> Result<Self, String> {
        let mut p = Self::a800(4);
        for (k, v) in map {
            let Some(key) = k.strip_prefix("hardware.") else {
                continue; // other sections are someone else's business
            };
            let fval = || -> Result<f64, String> {
                v.parse().map_err(|_| format!("bad {key} value {v:?}"))
            };
            match key {
                "name" => p.device.name = v.clone(),
                "cards" => {
                    p.cards = v.parse().map_err(|_| format!("bad cards {v:?}"))?;
                    if p.cards == 0 {
                        return Err("cards must be >= 1".into());
                    }
                }
                "peak_tflops" => p.device.peak_flops = fval()? * 1e12,
                "peak_eff" => p.device.peak_eff = fval()?,
                "m_half" => p.device.m_half = fval()?,
                "launch_us" => p.device.launch_s = fval()? * 1e-6,
                "contention" => {
                    p.device.contention = fval()?;
                    if p.device.contention < 1.0 {
                        return Err("contention must be >= 1.0".into());
                    }
                }
                "link_alpha_us" => p.link.alpha_s = fval()? * 1e-6,
                "link_gbps" => p.link.link_bytes_per_s = fval()? * 1e9,
                "int8_wire" => {
                    p.int8_wire_default = match v.as_str() {
                        "true" => true,
                        "false" => false,
                        _ => return Err(format!("bad int8_wire {v:?}")),
                    }
                }
                other => return Err(format!("unknown hardware key {other:?}")),
            }
        }
        Ok(p)
    }

    /// The `[hardware]` config keys describing this profile — the exact
    /// inverse of [`NodeProfile::from_map`], so a calibrated
    /// `MeasuredProfile` (`tune::calibrate`) can be exported where the
    /// hand-coded constants sit today and fed back through `--hw-file`.
    /// Scaled keys (`peak_tflops`, `launch_us`, …) round-trip through
    /// Rust's shortest float formatting; re-parsing reproduces the
    /// profile to within float re-scaling (≤ 1 ulp per field).
    pub fn to_map(&self) -> std::collections::BTreeMap<String, String> {
        let mut m = std::collections::BTreeMap::new();
        let mut put = |k: &str, v: String| {
            m.insert(format!("hardware.{k}"), v);
        };
        put("name", self.device.name.clone());
        put("cards", self.cards.to_string());
        put("peak_tflops", (self.device.peak_flops / 1e12).to_string());
        put("peak_eff", self.device.peak_eff.to_string());
        put("m_half", self.device.m_half.to_string());
        put("launch_us", (self.device.launch_s * 1e6).to_string());
        put("contention", self.device.contention.to_string());
        put("link_alpha_us", (self.link.alpha_s * 1e6).to_string());
        put("link_gbps", (self.link.link_bytes_per_s / 1e9).to_string());
        put("int8_wire", self.int8_wire_default.to_string());
        m
    }

    /// The CPU engine testbed itself (DESIGN.md §2): XLA-CPU f32 GEMM
    /// throughput with its (much earlier) small-m efficiency knee, and the
    /// ring's throttled α/β when the engine emulates a PCIe-class link.
    /// This is what threads `split::choose_split` into
    /// `batch::plan_prefill`, so the engine's balanced ISO split comes
    /// from the same calibrated bisection the simulator and benches use
    /// instead of a hardcoded ratio.
    pub fn cpu_engine(threads: usize, link_mbps: Option<f64>, link_alpha_us: f64) -> Self {
        assert!(threads >= 1);
        NodeProfile {
            device: DeviceProfile {
                name: "cpu-engine".into(),
                peak_flops: 8e9, // per-worker f32 XLA-CPU GEMM on the tiny model
                peak_eff: 0.6,
                m_half: 12.0,
                launch_s: 25e-6,
                // Comm runs on a separate OS thread, not on shared SMs.
                contention: 1.0,
            },
            link: LinkProfile {
                alpha_s: link_alpha_us * 1e-6,
                // Unthrottled shared-memory channels move ~GB/s.
                link_bytes_per_s: link_mbps.map_or(2.0e9, |m| m * 1e6),
            },
            cards: threads,
            int8_wire_default: false,
        }
    }

    /// All-reduce wall time for `bytes` of fp16 activations, with optional
    /// int8 wire quantization (halves payload, adds per-row scales ≈ +2%).
    pub fn allreduce_s(&self, fp16_bytes: usize, int8_wire: bool) -> f64 {
        let wire = if int8_wire {
            fp16_bytes as f64 * INT8_WIRE_FACTOR // int8 payload + scales
        } else {
            fp16_bytes as f64
        };
        self.link.ring_allreduce_s(wire, self.cards)
    }

    /// All-reduce wall time for `fp16_bytes` of activations at precision
    /// rung `q` — the ladder generalization of
    /// [`NodeProfile::allreduce_s`] (whose `int8_wire = true/false` is
    /// exactly the `Int8`/`Fp16` rung).
    pub fn allreduce_rung_s(&self, fp16_bytes: usize, q: crate::config::CommQuant) -> f64 {
        self.link.ring_allreduce_s(fp16_bytes as f64 * wire_factor(q), self.cards)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_allreduce_scales_with_ranks_and_bytes() {
        let l = LinkProfile { alpha_s: 10e-6, link_bytes_per_s: 10e9 };
        let t4 = l.ring_allreduce_s(100e6, 4);
        let t8 = l.ring_allreduce_s(100e6, 8);
        assert!(t8 > t4); // 2(R-1)/R grows with R
        assert!(l.ring_allreduce_s(200e6, 4) > 1.9 * t4);
        assert_eq!(l.ring_allreduce_s(100e6, 1), 0.0);
    }

    #[test]
    fn p2p_is_alpha_beta() {
        let l = LinkProfile { alpha_s: 10e-6, link_bytes_per_s: 10e9 };
        assert_eq!(l.p2p_s(0.0), 0.0);
        assert!((l.p2p_s(1e9) - (10e-6 + 0.1)).abs() < 1e-12);
        // Matches the pp stage-hop arithmetic it factors out.
        assert_eq!(l.p2p_s(4096.0), l.alpha_s + 4096.0 / l.link_bytes_per_s);
    }

    #[test]
    fn busbw_approaches_link_bw_for_big_messages() {
        let l = LinkProfile { alpha_s: 10e-6, link_bytes_per_s: 10e9 };
        let bus = l.busbw(1e9, 8);
        assert!(bus > 0.9 * 10e9, "busbw {bus}");
        // tiny messages are latency-bound
        assert!(l.busbw(1e3, 8) < 0.1 * 10e9);
    }

    #[test]
    fn efficiency_curve_monotone_saturating() {
        let d = NodeProfile::a800(4).device;
        assert!(d.eff(128) < d.eff(1024));
        assert!(d.eff(16384) <= d.peak_eff);
        assert!(d.eff(16384) > 0.95 * d.peak_eff);
    }

    #[test]
    fn gemm_time_includes_launch_overhead() {
        let d = NodeProfile::rtx4090(4).device;
        let tiny = d.gemm_s(1.0, 1);
        assert!(tiny >= d.launch_s);
        assert_eq!(d.gemm_s(0.0, 1), 0.0);
    }

    #[test]
    fn paper_regime_4090_comm_dominates() {
        // Paper §3.2/Fig 2a: on 4090, fp16 comm ≈ 75% of a layer; int8
        // wire brings it to ≈ 50%.
        use crate::model::ModelSpec;
        let node = NodeProfile::rtx4090(4);
        let m = ModelSpec::mha_30b();
        let t = 4096;
        let c = m.layer_chunk_cost(t, 0);
        let flops = (c.gemm_flops_attn + c.gemm_flops_mlp + c.attn_flops) / 4.0;
        let compute = node.device.gemm_s(flops, t);
        let comm_fp16 = 2.0 * node.allreduce_s(c.ar_bytes, false);
        let comm_int8 = 2.0 * node.allreduce_s(c.ar_bytes, true);
        let share_fp16 = comm_fp16 / (comm_fp16 + compute);
        let share_int8 = comm_int8 / (comm_int8 + compute);
        assert!((0.65..0.85).contains(&share_fp16), "fp16 comm share {share_fp16}");
        assert!((0.42..0.62).contains(&share_int8), "int8 comm share {share_int8}");
    }

    #[test]
    fn paper_regime_a800_compute_dominates() {
        // Paper §3.2: on A/H-series the computation share exceeds ~75%.
        use crate::model::ModelSpec;
        let node = NodeProfile::a800(4);
        let m = ModelSpec::gqa_70b();
        let t = 8192;
        let c = m.layer_chunk_cost(t, 0);
        let flops = (c.gemm_flops_attn + c.gemm_flops_mlp + c.attn_flops) / 4.0;
        let compute = node.device.gemm_s(flops, t);
        let comm = 2.0 * node.allreduce_s(c.ar_bytes, false);
        let share = compute / (comm + compute);
        assert!(share > 0.70, "compute share {share}");
    }

    #[test]
    fn int8_wire_halves_comm() {
        let node = NodeProfile::rtx4090(4);
        let fp16 = node.allreduce_s(100 << 20, false);
        let int8 = node.allreduce_s(100 << 20, true);
        assert!((0.45..0.60).contains(&(int8 / fp16)));
    }

    #[test]
    fn wire_factor_ladder_monotone_and_anchored() {
        use crate::config::CommQuant;
        // Walking down the ladder strictly shrinks the wire.
        let f: Vec<f64> = CommQuant::LADDER.iter().map(|&q| wire_factor(q)).collect();
        for w in f.windows(2) {
            assert!(w[1] < w[0], "ladder factor not decreasing: {f:?}");
        }
        // The bool API is exactly the Fp16/Int8 rungs of the rung API.
        let node = NodeProfile::rtx4090(4);
        let b = 100 << 20;
        assert_eq!(node.allreduce_s(b, false), node.allreduce_rung_s(b, CommQuant::Fp16));
        assert_eq!(node.allreduce_s(b, true), node.allreduce_rung_s(b, CommQuant::Int8));
        // fp8 halves fp16; int4 is int8 minus half the payload share.
        let fp16 = node.allreduce_rung_s(b, CommQuant::Fp16);
        let fp8 = node.allreduce_rung_s(b, CommQuant::Fp8);
        assert!((0.45..0.60).contains(&(fp8 / fp16)), "{}", fp8 / fp16);
        let int4 = node.allreduce_rung_s(b, CommQuant::Int4);
        assert!(int4 < node.allreduce_rung_s(b, CommQuant::Int8));
    }

    #[test]
    fn presets_by_name() {
        assert_eq!(NodeProfile::by_name("4090", 8).unwrap().cards, 8);
        assert_eq!(NodeProfile::by_name("a800", 4).unwrap().device.name, "a800");
        assert!(NodeProfile::by_name("h100", 4).is_none());
    }

    #[test]
    fn custom_profile_from_config() {
        let text = r#"
            [hardware]
            name = h800ish
            cards = 8
            peak_tflops = 990
            contention = 1.12
            link_gbps = 200
            int8_wire = false
        "#;
        let map = crate::config::parse_config_str(text).unwrap();
        let p = NodeProfile::from_map(&map).unwrap();
        assert_eq!(p.device.name, "h800ish");
        assert_eq!(p.cards, 8);
        assert_eq!(p.device.peak_flops, 990e12);
        assert_eq!(p.link.link_bytes_per_s, 200e9);
        assert!(!p.int8_wire_default);
        // untouched knobs keep the a800 defaults
        assert_eq!(p.device.m_half, 160.0);
    }

    #[test]
    fn custom_profile_rejects_bad_keys_and_values() {
        let bad_key = crate::config::parse_config_str("[hardware]\nwatts = 300").unwrap();
        assert!(NodeProfile::from_map(&bad_key).is_err());
        let bad_val =
            crate::config::parse_config_str("[hardware]\ncontention = 0.5").unwrap();
        assert!(NodeProfile::from_map(&bad_val).is_err());
        let zero_cards = crate::config::parse_config_str("[hardware]\ncards = 0").unwrap();
        assert!(NodeProfile::from_map(&zero_cards).is_err());
    }

    #[test]
    fn to_map_round_trips_through_from_map() {
        for node in [
            NodeProfile::rtx4090(4),
            NodeProfile::a800(8),
            NodeProfile::cpu_engine(2, Some(64.0), 120.0),
        ] {
            let back = NodeProfile::from_map(&node.to_map()).unwrap();
            assert_eq!(back.device.name, node.device.name);
            assert_eq!(back.cards, node.cards);
            assert_eq!(back.int8_wire_default, node.int8_wire_default);
            // Scaled float keys re-scale on parse; allow 1-ulp wobble.
            let close = |a: f64, b: f64| (a - b).abs() <= 1e-12 * b.abs().max(1.0);
            assert!(close(back.device.peak_flops, node.device.peak_flops));
            assert!(close(back.device.peak_eff, node.device.peak_eff));
            assert!(close(back.device.m_half, node.device.m_half));
            assert!(close(back.device.launch_s, node.device.launch_s));
            assert!(close(back.device.contention, node.device.contention));
            assert!(close(back.link.alpha_s, node.link.alpha_s));
            assert!(close(back.link.link_bytes_per_s, node.link.link_bytes_per_s));
        }
    }

    #[test]
    fn contention_in_paper_band() {
        assert!((1.15..=1.20).contains(&NodeProfile::a800(4).device.contention));
        assert!(NodeProfile::rtx4090(4).device.contention < 1.05);
    }
}
