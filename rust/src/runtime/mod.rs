//! PJRT runtime: load AOT artifacts (HLO text + weights + manifest) and
//! execute them on the CPU client.
//!
//! The interchange contract with `python/compile/aot.py`:
//! * HLO **text**, parsed with `HloModuleProto::from_text_file` (the text
//!   parser reassigns instruction ids, sidestepping xla_extension 0.5.1's
//!   rejection of jax≥0.5 64-bit-id protos);
//! * every module lowered with `return_tuple=True` → outputs are a tuple;
//! * weights as raw little-endian f32 files indexed by `manifest.json`.
//!
//! `PjRtClient` wraps thread-affine raw pointers, so each TP worker thread
//! constructs its own client and compiles its own executables
//! (`WorkerRuntime`); compilation happens once at engine start, never on
//! the request path.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::Json;

/// Parsed `manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Artifact directory the manifest was loaded from.
    pub dir: PathBuf,
    /// Geometry of the tiny model the artifacts implement.
    pub config: ModelGeometry,
    /// Chunk lengths with compiled stages.
    pub chunk_lens: Vec<usize>,
    /// TP degrees with sharded weights/stages.
    pub tp_degrees: Vec<usize>,
    /// Every compiled HLO module.
    pub modules: Vec<ModuleSpec>,
    /// tp degree → weight entries.
    pub weights: BTreeMap<usize, Vec<WeightSpec>>,
    /// Golden-reference files for end-to-end tests.
    pub golden: GoldenSpec,
}

/// Tiny-model geometry (mirrors python `TinyConfig`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ModelGeometry {
    /// Vocabulary size.
    pub vocab: usize,
    /// Residual width.
    pub d_model: usize,
    /// Transformer layers.
    pub n_layers: usize,
    /// Query heads.
    pub n_heads: usize,
    /// KV heads (GQA).
    pub n_kv_heads: usize,
    /// Per-head feature dimension.
    pub head_dim: usize,
    /// MLP hidden width.
    pub d_ff: usize,
    /// KV-cache capacity in tokens.
    pub max_seq: usize,
}

/// One compiled HLO module's manifest entry.
#[derive(Clone, Debug)]
pub struct ModuleSpec {
    /// Manifest key, e.g. `attn_tp2_t64`.
    pub name: String,
    /// HLO text file relative to the artifact dir.
    pub file: String,
    /// Stage kind (`embed` / `attn` / `mlp` / `logits`).
    pub stage: String,
    /// TP degree the module was lowered for.
    pub tp: usize,
    /// Chunk length the module was lowered for.
    pub t: usize,
    /// Positional input specs.
    pub inputs: Vec<TensorSpec>,
    /// Tuple output specs.
    pub outputs: Vec<TensorSpec>,
}

/// Shape + dtype of one stage operand.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    /// Dimensions, row-major.
    pub shape: Vec<usize>,
    /// Element type (`f32` or `i32`).
    pub dtype: String,
}

impl TensorSpec {
    /// Total element count.
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One exported weight tensor's manifest entry.
#[derive(Clone, Debug)]
pub struct WeightSpec {
    /// Manifest key, e.g. `layer0.rank1.wq`.
    pub name: String,
    /// Dimensions, row-major.
    pub shape: Vec<usize>,
    /// Raw little-endian f32 file relative to the artifact dir.
    pub file: String,
}

/// Golden-reference pointers for the end-to-end tests.
#[derive(Clone, Debug)]
pub struct GoldenSpec {
    /// Prompt token file (raw i32).
    pub tokens_file: String,
    /// Full-model reference logits file (raw f32).
    pub logits_file: String,
    /// Length of the golden prompt.
    pub prompt_len: usize,
    /// Shape of the reference logits.
    pub logits_shape: Vec<usize>,
}

fn tensor_specs(j: &Json) -> Result<Vec<TensorSpec>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("expected array of tensor specs"))?
        .iter()
        .map(|s| {
            Ok(TensorSpec {
                shape: s
                    .get("shape")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("missing shape"))?
                    .iter()
                    .map(|d| d.as_usize().unwrap_or(0))
                    .collect(),
                dtype: s
                    .get("dtype")
                    .and_then(Json::as_str)
                    .unwrap_or("f32")
                    .to_string(),
            })
        })
        .collect()
}

impl Manifest {
    /// Parse `manifest.json` under `dir` (the `make artifacts` output).
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("parsing {path:?}: {e}"))?;

        let c = j.get("config").ok_or_else(|| anyhow!("manifest missing config"))?;
        let geo = ModelGeometry {
            vocab: c.get("vocab").and_then(Json::as_usize).unwrap_or(0),
            d_model: c.get("d_model").and_then(Json::as_usize).unwrap_or(0),
            n_layers: c.get("n_layers").and_then(Json::as_usize).unwrap_or(0),
            n_heads: c.get("n_heads").and_then(Json::as_usize).unwrap_or(0),
            n_kv_heads: c.get("n_kv_heads").and_then(Json::as_usize).unwrap_or(0),
            head_dim: c.get("head_dim").and_then(Json::as_usize).unwrap_or(0),
            d_ff: c.get("d_ff").and_then(Json::as_usize).unwrap_or(0),
            max_seq: c.get("max_seq").and_then(Json::as_usize).unwrap_or(0),
        };
        if geo.d_model == 0 || geo.n_layers == 0 {
            bail!("manifest config incomplete: {geo:?}");
        }

        let modules = j
            .get("modules")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing modules"))?
            .iter()
            .map(|m| {
                Ok(ModuleSpec {
                    name: m.get("name").and_then(Json::as_str).unwrap_or("").into(),
                    file: m.get("file").and_then(Json::as_str).unwrap_or("").into(),
                    stage: m.get("stage").and_then(Json::as_str).unwrap_or("").into(),
                    tp: m.get("tp").and_then(Json::as_usize).unwrap_or(0),
                    t: m.get("t").and_then(Json::as_usize).unwrap_or(0),
                    inputs: tensor_specs(m.get("inputs").ok_or_else(|| anyhow!("inputs"))?)?,
                    outputs: tensor_specs(m.get("outputs").ok_or_else(|| anyhow!("outputs"))?)?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let mut weights = BTreeMap::new();
        if let Some(Json::Obj(w)) = j.get("weights") {
            for (k, entries) in w {
                let tp: usize = k
                    .strip_prefix("tp")
                    .and_then(|s| s.parse().ok())
                    .ok_or_else(|| anyhow!("bad weights key {k:?}"))?;
                let list = entries
                    .as_arr()
                    .ok_or_else(|| anyhow!("weights[{k}] not an array"))?
                    .iter()
                    .map(|e| {
                        Ok(WeightSpec {
                            name: e.get("name").and_then(Json::as_str).unwrap_or("").into(),
                            shape: e
                                .get("shape")
                                .and_then(Json::as_arr)
                                .unwrap_or(&[])
                                .iter()
                                .map(|d| d.as_usize().unwrap_or(0))
                                .collect(),
                            file: e.get("file").and_then(Json::as_str).unwrap_or("").into(),
                        })
                    })
                    .collect::<Result<Vec<_>>>()?;
                weights.insert(tp, list);
            }
        }

        let g = j.get("golden").ok_or_else(|| anyhow!("manifest missing golden"))?;
        let golden = GoldenSpec {
            tokens_file: g.get("tokens_file").and_then(Json::as_str).unwrap_or("").into(),
            logits_file: g.get("logits_file").and_then(Json::as_str).unwrap_or("").into(),
            prompt_len: g.get("prompt_len").and_then(Json::as_usize).unwrap_or(0),
            logits_shape: g
                .get("logits_shape")
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .map(|d| d.as_usize().unwrap_or(0))
                .collect(),
        };

        let chunk_lens = j
            .get("chunk_lens")
            .and_then(Json::as_arr)
            .unwrap_or(&[])
            .iter()
            .filter_map(Json::as_usize)
            .collect();
        let tp_degrees = j
            .get("tp_degrees")
            .and_then(Json::as_arr)
            .unwrap_or(&[])
            .iter()
            .filter_map(Json::as_usize)
            .collect();

        Ok(Manifest { dir, config: geo, chunk_lens, tp_degrees, modules, weights, golden })
    }

    /// Look up a module entry by manifest name.
    pub fn module(&self, name: &str) -> Result<&ModuleSpec> {
        self.modules
            .iter()
            .find(|m| m.name == name)
            .ok_or_else(|| anyhow!("module {name:?} not in manifest"))
    }

    /// Read a raw little-endian f32 file relative to the artifact dir.
    pub fn read_f32(&self, rel: &str) -> Result<Vec<f32>> {
        let path = self.dir.join(rel);
        let bytes = std::fs::read(&path).with_context(|| format!("reading {path:?}"))?;
        if bytes.len() % 4 != 0 {
            bail!("{path:?}: length {} not a multiple of 4", bytes.len());
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }

    /// Read a raw little-endian i32 file.
    pub fn read_i32(&self, rel: &str) -> Result<Vec<i32>> {
        let path = self.dir.join(rel);
        let bytes = std::fs::read(&path).with_context(|| format!("reading {path:?}"))?;
        Ok(bytes
            .chunks_exact(4)
            .map(|b| i32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }

    /// Golden reference (tokens, logits row-major, shape).
    pub fn golden_data(&self) -> Result<(Vec<i32>, Vec<f32>, Vec<usize>)> {
        let tokens = self.read_i32(&self.golden.tokens_file)?;
        let logits = self.read_f32(&self.golden.logits_file)?;
        Ok((tokens, logits, self.golden.logits_shape.clone()))
    }
}

/// Host-side tensor (f32, row-major) moving in/out of PJRT.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    /// Dimensions, row-major.
    pub shape: Vec<usize>,
    /// Elements, row-major.
    pub data: Vec<f32>,
}

/// The default tensor is an **unallocated placeholder** (empty shape,
/// empty data) used by `std::mem::take` when moving caches in and out of
/// stages (§Perf: no per-call `Tensor::zeros` allocation). It is not a
/// valid operand; it only ever exists between a take and the put-back.
impl Default for Tensor {
    fn default() -> Tensor {
        Tensor { shape: Vec::new(), data: Vec::new() }
    }
}

impl Tensor {
    /// A tensor from parts; panics if `shape` does not cover `data`.
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape, data }
    }

    /// A zero-filled tensor of the given shape.
    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(&self.data).reshape(&dims)?)
    }
}

/// A tensor pre-converted to an XLA literal — weights are converted ONCE
/// at engine start instead of on every stage call (§Perf: the conversion
/// was ~500 KB of copies per layer call before this cache).
pub struct DevTensor {
    /// Dimensions, row-major.
    pub shape: Vec<usize>,
    lit: xla::Literal,
}

impl DevTensor {
    /// Convert a host tensor once, for reuse across stage calls.
    pub fn from_tensor(t: &Tensor) -> Result<DevTensor> {
        Ok(DevTensor { shape: t.shape.clone(), lit: t.to_literal()? })
    }
}

/// One compiled stage on one worker's client.
pub struct Executable {
    /// The manifest entry the executable was compiled from.
    pub spec: ModuleSpec,
    exe: xla::PjRtLoadedExecutable,
}

/// Inputs a stage can take.
pub enum Arg<'a> {
    /// Host f32 tensor (activations).
    F32(&'a Tensor),
    /// Pre-converted literal (cached weights) — zero conversion cost.
    Dev(&'a DevTensor),
    /// Host i32 vector (token ids).
    I32(&'a [i32]),
    /// Scalar i32 (offsets).
    Scalar(i32),
}

impl Executable {
    /// Execute with positional args matching the manifest input specs.
    /// Returns the tuple outputs as host tensors.
    pub fn run(&self, args: &[Arg<'_>]) -> Result<Vec<Tensor>> {
        if args.len() != self.spec.inputs.len() {
            bail!(
                "{}: got {} args, manifest says {}",
                self.spec.name,
                args.len(),
                self.spec.inputs.len()
            );
        }
        // Owned literals (activations, scalars) live here; cached weight
        // literals are borrowed straight from the DevTensor.
        let mut owned: Vec<Option<xla::Literal>> = Vec::with_capacity(args.len());
        for (arg, spec) in args.iter().zip(&self.spec.inputs) {
            let lit = match arg {
                Arg::F32(t) => {
                    if t.shape != spec.shape {
                        bail!("{}: shape {:?} != spec {:?}", self.spec.name, t.shape, spec.shape);
                    }
                    Some(t.to_literal()?)
                }
                Arg::Dev(d) => {
                    if d.shape != spec.shape {
                        bail!("{}: shape {:?} != spec {:?}", self.spec.name, d.shape, spec.shape);
                    }
                    None
                }
                Arg::I32(v) => {
                    let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
                    Some(xla::Literal::vec1(v).reshape(&dims)?)
                }
                Arg::Scalar(x) => Some(xla::Literal::scalar(*x)),
            };
            owned.push(lit);
        }
        let refs: Vec<&xla::Literal> = args
            .iter()
            .zip(&owned)
            .map(|(arg, own)| match (arg, own) {
                (Arg::Dev(d), _) => &d.lit,
                (_, Some(lit)) => lit,
                _ => unreachable!(),
            })
            .collect();
        let result = self.exe.execute::<&xla::Literal>(&refs)?;
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{}: got {} outputs, manifest says {}",
                self.spec.name,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        parts
            .into_iter()
            .zip(&self.spec.outputs)
            .map(|(lit, spec)| {
                let data = lit.to_vec::<f32>()?;
                Ok(Tensor::new(spec.shape.clone(), data))
            })
            .collect()
    }

    /// Execute once with all-zero inputs — primes XLA's lazy first-run
    /// initialization so the first real request doesn't pay it (§Perf).
    pub fn warmup(&self) -> Result<()> {
        let zero_i32: Vec<Vec<i32>> = self
            .spec
            .inputs
            .iter()
            .map(|s| if s.dtype == "i32" { vec![0i32; s.elems()] } else { Vec::new() })
            .collect();
        let zero_f32: Vec<Tensor> = self
            .spec
            .inputs
            .iter()
            .map(|s| Tensor::zeros(s.shape.clone()))
            .collect();
        let args: Vec<Arg<'_>> = self
            .spec
            .inputs
            .iter()
            .enumerate()
            .map(|(i, s)| {
                if s.dtype == "i32" {
                    if s.shape.is_empty() {
                        Arg::Scalar(0)
                    } else {
                        Arg::I32(&zero_i32[i])
                    }
                } else {
                    Arg::F32(&zero_f32[i])
                }
            })
            .collect();
        self.run(&args)?;
        Ok(())
    }
}

/// Per-worker-thread runtime: its own PJRT client + compiled stages.
/// Construct *inside* the worker thread (the client is thread-affine).
pub struct WorkerRuntime {
    client: xla::PjRtClient,
    /// The manifest the runtime compiles from.
    pub manifest: Manifest,
}

impl WorkerRuntime {
    /// A runtime with a fresh CPU PJRT client (call on the worker thread).
    pub fn new(manifest: Manifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu()?;
        Ok(WorkerRuntime { client, manifest })
    }

    /// Compile one module by manifest name.
    pub fn compile(&self, name: &str) -> Result<Executable> {
        let spec = self.manifest.module(name)?.clone();
        let path = self.manifest.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path {path:?}"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(Executable { spec, exe })
    }

    /// Load one weight tensor (by manifest entry name) for a tp degree.
    pub fn load_weight(&self, tp: usize, name: &str) -> Result<Tensor> {
        let entries = self
            .manifest
            .weights
            .get(&tp)
            .ok_or_else(|| anyhow!("no weights for tp={tp}"))?;
        let e = entries
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| anyhow!("weight {name:?} not in manifest (tp={tp})"))?;
        let data = self.manifest.read_f32(&e.file)?;
        Ok(Tensor::new(e.shape.clone(), data))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Integration tests that need built artifacts live in
    // rust/tests/; these cover the pure parts.

    #[test]
    fn tensor_shape_checked() {
        let t = Tensor::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.shape, vec![2, 3]);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn tensor_shape_mismatch_panics() {
        Tensor::new(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn zeros_has_right_size() {
        let t = Tensor::zeros(vec![4, 8, 2]);
        assert_eq!(t.data.len(), 64);
        assert!(t.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn manifest_missing_dir_errors() {
        let err = Manifest::load("/nonexistent/artifacts").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn tensor_spec_elems() {
        let s = TensorSpec { shape: vec![2, 128, 16], dtype: "f32".into() };
        assert_eq!(s.elems(), 4096);
    }
}
