//! Paper-artifact rendering: Table 1 grids, Figure-1 ASCII Gantt charts,
//! and CSV/JSON dumps for downstream plotting.

use crate::config::{SimExperiment, Strategy};
use crate::coordinator::WorkerStats;
use crate::hw::NodeProfile;
use crate::model::ModelSpec;
use crate::sched;
use crate::sim::{OpKind, Timeline};
use crate::util::Json;

/// One row of the Table-1 grid.
#[derive(Clone, Debug)]
pub struct Table1Row {
    /// GPU preset name.
    pub gpu: String,
    /// Cards in the TP group.
    pub cards: usize,
    /// Model spec name.
    pub model: String,
    /// (prompt_len, reduction) pairs; reduction is the fractional decrease
    /// of prefill duration vs the serial baseline (paper's percentages).
    pub cells: Vec<(usize, f64)>,
}

/// Prompt lengths per platform, matching Table 1's populated cells
/// ("-" cells are lengths the authors could not fit in memory).
pub fn table1_lens(gpu: &str, cards: usize) -> Vec<usize> {
    let all: Vec<usize> = (0..8).map(|i| 1024 << i).collect(); // 1k..128k
    match (gpu, cards) {
        ("4090", 4) => all[..6].to_vec(),  // 1k..32k
        ("4090", 8) => all[..7].to_vec(),  // 1k..64k
        _ => all,                          // a800: 1k..128k
    }
}

/// Compute the full Table-1 grid for a strategy (Iso reproduces the
/// paper's table; other strategies give the §4.2 comparison rows).
pub fn table1(strategy: Strategy) -> Vec<Table1Row> {
    let mut rows = Vec::new();
    for (gpu, cards) in [("4090", 4), ("4090", 8), ("a800", 4), ("a800", 8)] {
        for model_name in ["30b", "70b"] {
            let model = ModelSpec::by_name(model_name).unwrap();
            let node = NodeProfile::by_name(gpu, cards).unwrap();
            let mut cells = Vec::new();
            for len in table1_lens(gpu, cards) {
                let mut e = SimExperiment::new(node.clone(), model.clone(), len, strategy);
                // Paper setup: segmented GEMMs on the compute-bound A800,
                // monolithic launches on the 4090.
                e.gemm_segments = if gpu == "a800" { 4 } else { 1 };
                cells.push((len, sched::reduction_vs_serial(&e)));
            }
            rows.push(Table1Row {
                gpu: gpu.into(),
                cards,
                model: model_name.into(),
                cells,
            });
        }
    }
    rows
}

/// Render the grid in the paper's layout.
pub fn render_table1(rows: &[Table1Row], title: &str) -> String {
    let lens: Vec<usize> = (0..8).map(|i| 1024 << i).collect();
    let mut s = String::new();
    s.push_str(&format!("{title}\n"));
    s.push_str(&format!("{:<14} {:<6}", "GPU", "model"));
    for l in &lens {
        s.push_str(&format!(" {:>6}", format_len(*l)));
    }
    s.push('\n');
    for r in rows {
        s.push_str(&format!("{:<14} {:<6}", format!("{} {}c", r.gpu, r.cards), r.model));
        for l in &lens {
            match r.cells.iter().find(|(len, _)| len == l) {
                Some((_, red)) => s.push_str(&format!(" {:>5.0}%", red * 100.0)),
                None => s.push_str(&format!(" {:>6}", "-")),
            }
        }
        s.push('\n');
    }
    s
}

fn format_len(l: usize) -> String {
    format!("{}k", l / 1024)
}

/// CSV dump (gpu,cards,model,len,reduction).
pub fn table1_csv(rows: &[Table1Row]) -> String {
    let mut s = String::from("gpu,cards,model,prompt_len,reduction\n");
    for r in rows {
        for (len, red) in &r.cells {
            s.push_str(&format!("{},{},{},{},{:.4}\n", r.gpu, r.cards, r.model, len, red));
        }
    }
    s
}

/// JSON dump of a timeline (for external plotting of Figure 1).
pub fn timeline_json(tl: &Timeline) -> Json {
    let mut spans = Vec::new();
    for s in &tl.spans {
        let mut o = Json::obj();
        o.set("label", s.label.as_str())
            .set("kind", if s.kind == OpKind::Compute { "compute" } else { "comm" })
            .set("chunk", s.chunk)
            .set("start_us", s.start_s * 1e6)
            .set("end_us", s.end_s * 1e6)
            .set("contended", s.contended);
        spans.push(o);
    }
    let mut root = Json::obj();
    root.set("makespan_us", tl.makespan_s * 1e6).set("spans", Json::Arr(spans));
    root
}

/// Topology-aware rendering of the per-worker counters (PR-4 satellite).
///
/// The engine's workers form a `pp_stages × tp` grid. The flat single-
/// stage rollup (`pp_stages = 1`) prints one `rank …` line per worker —
/// **byte-identical** to the pre-pipeline report, pinned by test — while
/// multi-stage engines group the ranks by stage first, each stage headed
/// by its summed compute and pipeline-bubble wait, so imbalanced layer
/// assignments and starved stages are visible at a glance.
///
/// Every emitted field (`compute`, `stall`, `comm`, `overlap_eff`, and
/// the stage headers' `bubble_wait`/`p2p_sent`) is defined in the
/// metrics glossary, DESIGN.md §13.
pub fn worker_rollup(workers: &[WorkerStats], pp_stages: usize, tp: usize) -> String {
    let mut s = String::new();
    let rank_line = |w: &WorkerStats| {
        format!(
            "rank {}: compute={:.0}ms stall={:.0}ms comm={:.0}ms overlap_eff={:.2}\n",
            w.rank,
            w.compute_ms,
            w.stall_ms,
            w.comm_ms,
            w.overlap_efficiency()
        )
    };
    if pp_stages <= 1 {
        for w in workers {
            s.push_str(&rank_line(w));
        }
        return s;
    }
    for stage in 0..pp_stages {
        let ranks: Vec<&WorkerStats> =
            workers.iter().filter(|w| w.stage == stage).collect();
        let compute: f64 = ranks.iter().map(|w| w.compute_ms).sum();
        let bubble: f64 = ranks.iter().map(|w| w.p2p_stall_ms).sum();
        let p2p: u64 = ranks.iter().map(|w| w.p2p_bytes).sum();
        s.push_str(&format!(
            "stage {stage} (tp={tp}): compute={compute:.0}ms bubble_wait={bubble:.0}ms \
             p2p_sent={p2p}B\n"
        ));
        for w in ranks {
            s.push_str("  ");
            s.push_str(&rank_line(w));
        }
    }
    s
}

/// [`worker_rollup`] extended with the ring context-parallel axis
/// (DESIGN.md §17). `cp <= 1` delegates to the two-axis rollup —
/// byte-identical output, pinned by test — while `cp > 1` engines print
/// one `group c` header per CP group (its summed compute, shard-ring
/// traffic, and shard-ring stall) and nest that group's stage rollup
/// beneath it, so an imbalanced shard assignment or a slow shard hop is
/// visible per group. Workers are expected in global-rank order
/// (`c × (pp × tp) + s × tp + r`).
pub fn worker_rollup_cp(
    workers: &[WorkerStats],
    pp_stages: usize,
    tp: usize,
    cp: usize,
) -> String {
    if cp <= 1 {
        return worker_rollup(workers, pp_stages, tp);
    }
    let group_sz = pp_stages.max(1) * tp.max(1);
    let mut s = String::new();
    for c in 0..cp {
        let lo = (c * group_sz).min(workers.len());
        let hi = ((c + 1) * group_sz).min(workers.len());
        let ranks = &workers[lo..hi];
        let compute: f64 = ranks.iter().map(|w| w.compute_ms).sum();
        let shard: u64 = ranks.iter().map(|w| w.cp_shard_bytes).sum();
        let stall: f64 = ranks.iter().map(|w| w.cp_stall_ms).sum();
        s.push_str(&format!(
            "group {c} (pp={pp_stages} tp={tp}): compute={compute:.0}ms \
             cp_shard_sent={shard}B cp_stall={stall:.0}ms\n"
        ));
        for line in worker_rollup(ranks, pp_stages, tp).lines() {
            s.push_str("  ");
            s.push_str(line);
            s.push('\n');
        }
    }
    s
}

/// One measured case for the machine-readable perf snapshot
/// (`BENCH_PR1.json` and successors) that seeds the perf trajectory
/// across PRs (EXPERIMENTS.md).
#[derive(Clone, Debug)]
pub struct PerfRecord {
    /// Case label (unique within a section).
    pub case: String,
    /// Mean wall time (ms).
    pub mean_ms: f64,
    /// Median wall time (ms).
    pub p50_ms: f64,
    /// 95th-percentile wall time (ms).
    pub p95_ms: f64,
    /// Free-form numeric annotations (segments, exposed_ms, wire bytes…).
    pub extra: Vec<(String, f64)>,
}

impl PerfRecord {
    /// A record from the three timing aggregates.
    pub fn new(case: &str, mean_ms: f64, p50_ms: f64, p95_ms: f64) -> PerfRecord {
        PerfRecord { case: case.to_string(), mean_ms, p50_ms, p95_ms, extra: Vec::new() }
    }

    /// Attach a numeric annotation (builder style).
    pub fn with(mut self, key: &str, value: f64) -> PerfRecord {
        self.extra.push((key.to_string(), value));
        self
    }

    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("case", self.case.as_str())
            .set("mean_ms", self.mean_ms)
            .set("p50_ms", self.p50_ms)
            .set("p95_ms", self.p95_ms);
        for (k, v) in &self.extra {
            o.set(k, *v);
        }
        o
    }
}

/// Merge `records` into the JSON snapshot at `path` under section
/// `bench`, creating or extending the file. Each bench target owns one
/// section, so the collective and e2e benches share one `BENCH_PR1.json`.
pub fn append_perf_records(
    path: &str,
    bench: &str,
    records: &[PerfRecord],
) -> std::io::Result<()> {
    let mut root = std::fs::read_to_string(path)
        .ok()
        .and_then(|t| Json::parse(&t).ok())
        .filter(|j| matches!(j, Json::Obj(_)))
        .unwrap_or_else(Json::obj);
    let arr: Vec<Json> = records.iter().map(|r| r.to_json()).collect();
    root.set(bench, Json::Arr(arr));
    std::fs::write(path, root.to_string())
}

/// ASCII Gantt of the first `layers` layers of a timeline — the Figure-1
/// schematic, regenerated from the simulator.
pub fn gantt(tl: &Timeline, width: usize, until_s: f64) -> String {
    let until = if until_s > 0.0 { until_s } else { tl.makespan_s };
    let scale = width as f64 / until;
    let mut out = String::new();
    for (kind, name) in [(OpKind::Compute, "COMPUTE"), (OpKind::Comm, "COMM   ")] {
        let mut row = vec![' '; width];
        for s in tl.spans.iter().filter(|s| s.kind == kind && s.start_s < until) {
            let a = (s.start_s * scale) as usize;
            let b = (((s.end_s.min(until)) * scale) as usize).max(a + 1).min(width);
            let ch = match (kind, s.chunk % 2, s.contended) {
                (OpKind::Compute, 0, false) => '0',
                (OpKind::Compute, 1, false) => '1',
                (OpKind::Compute, 0, true) => 'o',
                (OpKind::Compute, 1, true) => 'i',
                (OpKind::Comm, 0, _) => '#',
                _ => '%',
            };
            for c in row.iter_mut().take(b).skip(a) {
                *c = ch;
            }
        }
        out.push_str(name);
        out.push(' ');
        out.push('|');
        out.extend(row);
        out.push('|');
        out.push('\n');
    }
    out.push_str(
        "        0/1: chunk compute  o/i: contended compute  #/%: chunk all-reduce\n",
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimExperiment;

    #[test]
    fn lens_match_paper_populated_cells() {
        assert_eq!(table1_lens("4090", 4).len(), 6);
        assert_eq!(table1_lens("4090", 8).len(), 7);
        assert_eq!(table1_lens("a800", 4).len(), 8);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let rows = vec![Table1Row {
            gpu: "4090".into(),
            cards: 4,
            model: "30b".into(),
            cells: vec![(1024, 0.38), (2048, 0.42)],
        }];
        let csv = table1_csv(&rows);
        assert!(csv.starts_with("gpu,cards,model"));
        assert!(csv.contains("4090,4,30b,1024,0.38"));
    }

    #[test]
    fn render_marks_missing_cells() {
        let rows = vec![Table1Row {
            gpu: "4090".into(),
            cards: 4,
            model: "30b".into(),
            cells: vec![(1024, 0.5)],
        }];
        let s = render_table1(&rows, "t");
        assert!(s.contains("50%"));
        assert!(s.contains(" -"));
    }

    #[test]
    fn gantt_renders_both_streams() {
        let e = SimExperiment::new(
            NodeProfile::rtx4090(4),
            ModelSpec::mha_30b(),
            4096,
            Strategy::Iso,
        );
        let tl = sched::run(&e);
        let g = gantt(&tl, 100, tl.makespan_s / 20.0);
        assert!(g.contains("COMPUTE"));
        assert!(g.contains("COMM"));
        assert!(g.contains('#') || g.contains('%'));
    }

    #[test]
    fn single_stage_rollup_is_byte_identical_to_legacy() {
        // Satellite (PR 4): the flat-TP rollup must not change by a byte
        // versus the pre-pipeline per-rank lines.
        let workers: Vec<WorkerStats> = (0..2)
            .map(|rank| WorkerStats {
                rank,
                compute_ms: 12.4 + rank as f64,
                stall_ms: 3.6,
                comm_ms: 10.0,
                ..Default::default()
            })
            .collect();
        let legacy: String = workers
            .iter()
            .map(|w| {
                format!(
                    "rank {}: compute={:.0}ms stall={:.0}ms comm={:.0}ms overlap_eff={:.2}\n",
                    w.rank,
                    w.compute_ms,
                    w.stall_ms,
                    w.comm_ms,
                    w.overlap_efficiency()
                )
            })
            .collect();
        assert_eq!(worker_rollup(&workers, 1, 2), legacy);
    }

    #[test]
    fn multi_stage_rollup_groups_by_stage_then_rank() {
        let mk = |rank: usize, stage: usize| WorkerStats {
            rank,
            stage,
            compute_ms: 10.0,
            p2p_stall_ms: 2.0,
            p2p_bytes: 100,
            ..Default::default()
        };
        let workers = vec![mk(0, 0), mk(1, 0), mk(2, 1), mk(3, 1)];
        let s = worker_rollup(&workers, 2, 2);
        let stage0 = s.find("stage 0").unwrap();
        let stage1 = s.find("stage 1").unwrap();
        let rank2 = s.find("rank 2").unwrap();
        assert!(stage0 < rank2 && rank2 > stage1, "ranks must nest under stages");
        assert!(s.contains("compute=20ms"), "stage compute must sum its ranks");
        assert!(s.contains("bubble_wait=4ms"));
        assert!(s.contains("p2p_sent=200B"));
        assert!(s.contains("(tp=2)"));
        assert_eq!(s.matches("rank ").count(), 4);
    }

    #[test]
    fn cp_rollup_delegates_at_cp1_and_groups_at_cp2() {
        // Tentpole (PR 9): cp = 1 must not change the rollup by a byte;
        // cp = 2 nests the per-group stage rollup under `group` headers
        // that sum compute and shard-ring traffic.
        let mk = |rank: usize, stage: usize| WorkerStats {
            rank,
            stage,
            compute_ms: 10.0,
            cp_shard_bytes: 64,
            cp_stall_ms: 1.5,
            ..Default::default()
        };
        let flat = vec![mk(0, 0), mk(1, 0)];
        assert_eq!(worker_rollup_cp(&flat, 1, 2, 1), worker_rollup(&flat, 1, 2));
        let workers = vec![mk(0, 0), mk(1, 0), mk(2, 0), mk(3, 0)];
        let s = worker_rollup_cp(&workers, 1, 2, 2);
        let g0 = s.find("group 0").unwrap();
        let g1 = s.find("group 1").unwrap();
        let r2 = s.find("rank 2").unwrap();
        assert!(g0 < g1 && g0 < r2 && r2 > g1, "ranks must nest under groups");
        assert!(s.contains("compute=20ms"), "group compute must sum its ranks");
        assert!(s.contains("cp_shard_sent=128B"));
        assert!(s.contains("cp_stall=3ms"));
        assert_eq!(s.matches("rank ").count(), 4);
    }

    #[test]
    fn perf_snapshot_merges_sections() {
        let dir = std::env::temp_dir().join("iso_perf_snapshot_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("snap.json");
        let path = path.to_str().unwrap();
        let _ = std::fs::remove_file(path);

        let a = vec![PerfRecord::new("tp2 seg1", 10.0, 9.5, 12.0).with("segments", 1.0)];
        append_perf_records(path, "e2e_engine", &a).unwrap();
        let b = vec![
            PerfRecord::new("4r f32 seg4", 1.0, 1.0, 1.2).with("segments", 4.0),
            PerfRecord::new("4r f32 seg8", 0.9, 0.9, 1.1).with("segments", 8.0),
        ];
        append_perf_records(path, "collective", &b).unwrap();

        let parsed = Json::parse(&std::fs::read_to_string(path).unwrap()).unwrap();
        let e2e = parsed.get("e2e_engine").and_then(Json::as_arr).unwrap();
        assert_eq!(e2e.len(), 1);
        assert_eq!(e2e[0].get("case").and_then(Json::as_str), Some("tp2 seg1"));
        assert_eq!(e2e[0].get("segments").and_then(Json::as_f64), Some(1.0));
        let col = parsed.get("collective").and_then(Json::as_arr).unwrap();
        assert_eq!(col.len(), 2);
        assert_eq!(col[1].get("mean_ms").and_then(Json::as_f64), Some(0.9));
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn timeline_json_roundtrips() {
        let e = SimExperiment::new(
            NodeProfile::rtx4090(4),
            ModelSpec::mha_30b(),
            1024,
            Strategy::Serial,
        );
        let tl = sched::run(&e);
        let j = timeline_json(&tl);
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert!(parsed.get("makespan_us").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(
            parsed.get("spans").unwrap().as_arr().unwrap().len(),
            tl.spans.len()
        );
    }
}
