//! Serving metrics: monotonic timers, streaming histograms, and the
//! latency/throughput summaries the examples and benches report.

use std::time::{Duration, Instant};

/// Wall-clock stopwatch.
#[derive(Clone, Copy, Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e3
    }
}

/// Reservoir-free exact histogram: keeps all samples (our runs are small
/// enough), gives exact percentiles. Values are in arbitrary units; the
/// engine records milliseconds.
#[derive(Clone, Debug, Default)]
pub struct Histogram {
    samples: Vec<f64>,
    sorted: bool,
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, v: f64) {
        self.samples.push(v);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Exact percentile by nearest-rank (q in [0,1]).
    pub fn percentile(&mut self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        if !self.sorted {
            self.samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
        let rank = ((q * self.samples.len() as f64).ceil() as usize).clamp(1, self.samples.len());
        self.samples[rank - 1]
    }

    pub fn p50(&mut self) -> f64 {
        self.percentile(0.50)
    }
    pub fn p95(&mut self) -> f64 {
        self.percentile(0.95)
    }
    pub fn p99(&mut self) -> f64 {
        self.percentile(0.99)
    }

    pub fn summary(&mut self, label: &str) -> String {
        format!(
            "{label}: n={} mean={:.3} p50={:.3} p95={:.3} p99={:.3} min={:.3} max={:.3}",
            self.len(),
            self.mean(),
            self.p50(),
            self.p95(),
            self.p99(),
            self.min(),
            self.max()
        )
    }
}

/// Engine-level counters reported by the coordinator.
#[derive(Clone, Debug, Default)]
pub struct EngineMetrics {
    /// Time-to-first-token per request (ms) — the paper's headline metric.
    pub ttft_ms: Histogram,
    /// Per-decode-step latency (ms).
    pub decode_ms: Histogram,
    /// Time between consecutive tokens of a sequence under the mixed
    /// scheduler (ms per decoded token) — the serving-side latency the
    /// decode lane trades against batching.
    pub tbt_ms: Histogram,
    /// Per-iteration batch occupancy: prefill chunks + decode lane rows
    /// composed into each `Job::Step`.
    pub iter_occupancy: Histogram,
    /// Mixed iterations the leader executed.
    pub iterations: u64,
    /// Tokens decoded through the fused lane (vs. legacy per-sequence
    /// `Job::Decode` steps, which record into `decode_ms`).
    pub fused_decode_tokens: u64,
    /// Fused B-row lane collectives (one per layer-stage per iteration
    /// with a non-empty lane).
    pub fused_allreduces: u64,
    /// Prefill chunks executed.
    pub prefill_chunks: u64,
    /// All-reduce invocations.
    pub allreduces: u64,
    /// Bytes moved by collectives (post-quantization wire bytes).
    pub comm_bytes: u64,
    /// Wire messages sent by the rings; grows with `comm_segments`
    /// (per-segment wire accounting: bytes/messages ≈ segment size).
    pub comm_msgs: u64,
    /// Per-segment acks streamed from comm to compute threads.
    pub seg_acks: u64,
    /// Total generated tokens.
    pub generated_tokens: u64,
    /// Wall time the comm stream overlapped with compute (ms, ISO only).
    pub overlapped_ms: f64,
    /// Comm time *not* hidden behind compute (mean per-rank stall, ms) —
    /// the quantity segmented streaming drives down.
    pub exposed_ms: f64,
}

impl EngineMetrics {
    /// Exposed (un-hidden) communication per generated token (ms/tok) —
    /// the quantity decode-collective fusion drives down as the lane
    /// widens.
    pub fn exposed_ms_per_token(&self) -> f64 {
        if self.generated_tokens == 0 {
            return 0.0;
        }
        self.exposed_ms / self.generated_tokens as f64
    }

    pub fn report(&mut self) -> String {
        let mut s = String::new();
        s.push_str(&self.ttft_ms.summary("ttft_ms"));
        s.push('\n');
        if !self.decode_ms.is_empty() {
            s.push_str(&self.decode_ms.summary("decode_ms"));
            s.push('\n');
        }
        if !self.tbt_ms.is_empty() {
            s.push_str(&self.tbt_ms.summary("tbt_ms"));
            s.push('\n');
        }
        if !self.iter_occupancy.is_empty() {
            s.push_str(&self.iter_occupancy.summary("iter_occupancy"));
            s.push('\n');
        }
        s.push_str(&format!(
            "prefill_chunks={} allreduces={} comm_bytes={} comm_msgs={} seg_acks={} \
             generated={} overlapped_ms={:.2} exposed_ms={:.2}",
            self.prefill_chunks,
            self.allreduces,
            self.comm_bytes,
            self.comm_msgs,
            self.seg_acks,
            self.generated_tokens,
            self.overlapped_ms,
            self.exposed_ms
        ));
        s.push_str(&format!(
            "\niterations={} fused_decode_tokens={} fused_allreduces={} \
             exposed_ms_per_tok={:.4}",
            self.iterations,
            self.fused_decode_tokens,
            self.fused_allreduces,
            self.exposed_ms_per_token()
        ));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_exact_nearest_rank() {
        let mut h = Histogram::new();
        for v in 1..=100 {
            h.record(v as f64);
        }
        assert_eq!(h.p50(), 50.0);
        assert_eq!(h.p95(), 95.0);
        assert_eq!(h.p99(), 99.0);
        assert_eq!(h.percentile(1.0), 100.0);
        assert_eq!(h.percentile(0.0), 1.0); // clamped to rank 1
    }

    #[test]
    fn mean_min_max() {
        let mut h = Histogram::new();
        for v in [2.0, 4.0, 6.0] {
            h.record(v);
        }
        assert_eq!(h.mean(), 4.0);
        assert_eq!(h.min(), 2.0);
        assert_eq!(h.max(), 6.0);
        assert_eq!(h.len(), 3);
    }

    #[test]
    fn empty_histogram_is_nan() {
        let mut h = Histogram::new();
        assert!(h.mean().is_nan());
        assert!(h.p50().is_nan());
    }

    #[test]
    fn record_after_percentile_resorts() {
        let mut h = Histogram::new();
        h.record(10.0);
        assert_eq!(h.p50(), 10.0);
        h.record(1.0);
        assert_eq!(h.percentile(0.5), 1.0);
    }

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(t.elapsed_ms() >= 1.0);
    }

    #[test]
    fn engine_metrics_report_contains_counts() {
        let mut m = EngineMetrics::default();
        m.ttft_ms.record(12.5);
        m.prefill_chunks = 4;
        m.allreduces = 16;
        let r = m.report();
        assert!(r.contains("prefill_chunks=4"));
        assert!(r.contains("allreduces=16"));
        assert!(r.contains("iterations=0"));
    }

    #[test]
    fn exposed_per_token_and_mixed_counters() {
        let mut m = EngineMetrics::default();
        assert_eq!(m.exposed_ms_per_token(), 0.0); // no tokens, no NaN
        m.generated_tokens = 40;
        m.exposed_ms = 10.0;
        assert!((m.exposed_ms_per_token() - 0.25).abs() < 1e-12);
        m.tbt_ms.record(3.0);
        m.iter_occupancy.record(9.0);
        m.iterations = 7;
        m.fused_decode_tokens = 32;
        m.fused_allreduces = 56;
        let r = m.report();
        assert!(r.contains("tbt_ms"));
        assert!(r.contains("iter_occupancy"));
        assert!(r.contains("fused_decode_tokens=32"));
        assert!(r.contains("exposed_ms_per_tok=0.25"));
    }
}
