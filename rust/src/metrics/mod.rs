//! Serving metrics: monotonic timers, streaming histograms, and the
//! latency/throughput summaries the examples and benches report.

use std::time::{Duration, Instant};

/// Wall-clock stopwatch.
#[derive(Clone, Copy, Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start timing now.
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    /// Time since [`Timer::start`].
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Time since [`Timer::start`] in milliseconds.
    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e3
    }
}

/// Reservoir-free exact histogram: keeps all samples (our runs are small
/// enough), gives exact percentiles. Values are in arbitrary units; the
/// engine records milliseconds.
#[derive(Clone, Debug, Default)]
pub struct Histogram {
    samples: Vec<f64>,
    sorted: bool,
}

impl Histogram {
    /// An empty histogram.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add one sample.
    pub fn record(&mut self, v: f64) {
        self.samples.push(v);
        self.sorted = false;
    }

    /// Samples recorded so far.
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    /// True when no samples have been recorded.
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Arithmetic mean (NaN when empty).
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Smallest sample (+∞ when empty).
    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// Largest sample (−∞ when empty).
    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Exact percentile by nearest-rank (q in [0,1]).
    pub fn percentile(&mut self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        if !self.sorted {
            self.samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
        let rank = ((q * self.samples.len() as f64).ceil() as usize).clamp(1, self.samples.len());
        self.samples[rank - 1]
    }

    /// Median.
    pub fn p50(&mut self) -> f64 {
        self.percentile(0.50)
    }
    /// 95th percentile.
    pub fn p95(&mut self) -> f64 {
        self.percentile(0.95)
    }
    /// 99th percentile.
    pub fn p99(&mut self) -> f64 {
        self.percentile(0.99)
    }

    /// One-line `n/mean/p50/p95/p99/min/max` summary for reports.
    pub fn summary(&mut self, label: &str) -> String {
        format!(
            "{label}: n={} mean={:.3} p50={:.3} p95={:.3} p99={:.3} min={:.3} max={:.3}",
            self.len(),
            self.mean(),
            self.p50(),
            self.p95(),
            self.p99(),
            self.min(),
            self.max()
        )
    }
}

/// Engine-level counters reported by the coordinator. Every field that
/// [`EngineMetrics::report`] prints is cataloged in the metrics
/// glossary, DESIGN.md §13, alongside the `report::worker_rollup`
/// per-rank fields; `TUNING.md` maps each counter to the knob that
/// moves it.
#[derive(Clone, Debug, Default)]
pub struct EngineMetrics {
    /// Time-to-first-token per request (ms) — the paper's headline metric.
    pub ttft_ms: Histogram,
    /// Per-decode-step latency (ms).
    pub decode_ms: Histogram,
    /// Time between consecutive tokens of a sequence under the mixed
    /// scheduler (ms per decoded token) — the serving-side latency the
    /// decode lane trades against batching.
    pub tbt_ms: Histogram,
    /// Per-iteration batch occupancy: prefill chunks + decode lane rows
    /// composed into each `Job::Step`.
    pub iter_occupancy: Histogram,
    /// Mixed iterations the leader executed.
    pub iterations: u64,
    /// Tokens decoded through the fused lane (vs. legacy per-sequence
    /// `Job::Decode` steps, which record into `decode_ms`).
    pub fused_decode_tokens: u64,
    /// Fused B-row lane collectives (one per layer-stage per iteration
    /// with a non-empty lane).
    pub fused_allreduces: u64,
    /// Prefill chunks executed.
    pub prefill_chunks: u64,
    /// All-reduce invocations.
    pub allreduces: u64,
    /// Bytes moved by collectives (post-quantization wire bytes).
    pub comm_bytes: u64,
    /// Wire messages sent by the rings; grows with `comm_segments`
    /// (per-segment wire accounting: bytes/messages ≈ segment size).
    pub comm_msgs: u64,
    /// `comm_bytes` split by wire rung, indexed by
    /// [`crate::config::CommQuant::index`] (f32, fp16, int8, fp8, int4).
    /// The per-phase precision policy (DESIGN.md §16) can put prefill
    /// and decode collectives on different rungs, so the single total
    /// no longer says where the bytes went.
    pub comm_bytes_by_rung: [u64; 5],
    /// Per-segment acks streamed from comm to compute threads: one per
    /// collective for residual-carrying jobs under the fused epilogue
    /// (DESIGN.md §12), per-segment otherwise (`fused_epilogue = false`,
    /// or the `ladder_residual` loops, whose collectives keep the tensor
    /// compute-side).
    pub seg_acks: u64,
    /// Compute-thread time applying reduced rows into the residual (mean
    /// per-rank, ms) — the *exposed* post-collective epilogue. Near zero
    /// under `fused_epilogue` (the comm thread applies each segment
    /// while the collective's tail is still on the ring, DESIGN.md §12)
    /// unless `ladder_residual` routes collectives around the fusion.
    pub exposed_epilogue_ms: f64,
    /// Rows whose residual epilogue ran comm-side, fused into the
    /// collective's segment callbacks (DESIGN.md §12).
    pub fused_epilogue_rows: u64,
    /// Total generated tokens.
    pub generated_tokens: u64,
    /// Wall time the comm stream overlapped with compute (ms, ISO only).
    pub overlapped_ms: f64,
    /// Comm time *not* hidden behind compute (mean per-rank stall, ms) —
    /// the quantity segmented streaming drives down.
    pub exposed_ms: f64,
    /// Speculative verify windows executed (DESIGN.md §10).
    pub spec_windows: u64,
    /// Draft tokens proposed into verify windows.
    pub spec_drafted: u64,
    /// Draft tokens accepted by greedy verification (never counts past a
    /// sequence's decode budget).
    pub spec_accepted: u64,
    /// Accepted drafts per verify window — the paper-§6 acceptance curve
    /// the k-sweep bench snapshots.
    pub spec_accept_hist: Histogram,
    /// Arrived-but-unadmitted requests, sampled once per serving
    /// iteration — the saturation signal. The serving loop samples its
    /// own pending queue; `batch::Admission::queue_depth` exposes the
    /// same signal for queue-fed deployments.
    pub queue_depth: Histogram,
    /// Head-of-line queue wait (ms), sampled once per serving iteration;
    /// the `batch::Admission::oldest_wait_s` signal.
    pub queue_wait_ms: Histogram,
    /// Activation bytes moved over the inter-stage p2p links
    /// (DESIGN.md §11); 0 when `pp_stages = 1`.
    pub p2p_bytes: u64,
    /// Activation messages over the inter-stage p2p links.
    pub p2p_msgs: u64,
    /// Per-rank time blocked waiting on the previous stage's activations
    /// (one sample per rank at shutdown) — the pipeline-bubble histogram.
    /// Empty when `pp_stages = 1`.
    pub pp_bubble_ms: Histogram,
    /// Per-stage summed compute time (one sample per stage at shutdown) —
    /// the stage-occupancy histogram; its min/max spread shows layer-
    /// assignment imbalance. Empty when `pp_stages = 1`.
    pub stage_compute_ms: Histogram,
    /// Faults the leader detected (deadline expiry or a dead link),
    /// including injected ones (DESIGN.md §14). 0 on fault-free runs.
    pub faults_detected: u64,
    /// Successful mesh respawn + replay rounds.
    pub recoveries: u64,
    /// Live sequences whose KV was rebuilt by recovery replay.
    pub replayed_seqs: u64,
    /// Tokens recomputed by recovery replay (prompt + emitted so far).
    pub replayed_tokens: u64,
    /// Wall time of each recovery round (teardown → respawn → replay).
    pub recovery_ms: Histogram,
    /// Sequences evicted by KV-pressure preemption (DESIGN.md §15). A
    /// sequence preempted twice counts twice.
    pub preemptions: u64,
    /// Tokens (prompt + committed emissions) queued for checkpoint-free
    /// re-prefill by preemption.
    pub preempted_tokens: u64,
    /// Queued requests shed for a blown TTFT deadline.
    pub sheds: u64,
    /// Submits rejected with `Overloaded` backpressure at the bounded
    /// admission queue.
    pub rejected: u64,
    /// KV-shard bytes forwarded around the context-parallel ring
    /// (DESIGN.md §17); 0 when `cp = 1`.
    pub cp_shard_bytes: u64,
    /// KV-shard messages forwarded around the CP ring.
    pub cp_shard_msgs: u64,
    /// Compute time blocked waiting on the previous CP group's KV
    /// prefix (summed across ranks, ms).
    pub cp_stall_ms: f64,
    /// Cold KV pages the tiered mirror spilled resident → host
    /// (DESIGN.md §17); 0 unless `kv_offload` ran under a resident cap.
    pub kv_spilled_pages: u64,
    /// KV pages demand-fetched host → resident (modeled H2D stalls).
    pub kv_fetched_pages: u64,
    /// KV pages brought back ahead of the decode cursor (modeled H2D
    /// overlap).
    pub kv_prefetched_pages: u64,
}

impl EngineMetrics {
    /// Exposed (un-hidden) communication per generated token (ms/tok) —
    /// the quantity decode-collective fusion drives down as the lane
    /// widens.
    pub fn exposed_ms_per_token(&self) -> f64 {
        if self.generated_tokens == 0 {
            return 0.0;
        }
        self.exposed_ms / self.generated_tokens as f64
    }

    /// Fraction of drafted tokens accepted by greedy verification
    /// (0.0 when no speculation ran).
    pub fn acceptance_rate(&self) -> f64 {
        if self.spec_drafted == 0 {
            return 0.0;
        }
        self.spec_accepted as f64 / self.spec_drafted as f64
    }

    /// Multi-line human-readable dump of every populated counter.
    pub fn report(&mut self) -> String {
        let mut s = String::new();
        s.push_str(&self.ttft_ms.summary("ttft_ms"));
        s.push('\n');
        if !self.decode_ms.is_empty() {
            s.push_str(&self.decode_ms.summary("decode_ms"));
            s.push('\n');
        }
        if !self.tbt_ms.is_empty() {
            s.push_str(&self.tbt_ms.summary("tbt_ms"));
            s.push('\n');
        }
        if !self.iter_occupancy.is_empty() {
            s.push_str(&self.iter_occupancy.summary("iter_occupancy"));
            s.push('\n');
        }
        s.push_str(&format!(
            "prefill_chunks={} allreduces={} comm_bytes={} comm_msgs={} seg_acks={} \
             generated={} overlapped_ms={:.2} exposed_ms={:.2}",
            self.prefill_chunks,
            self.allreduces,
            self.comm_bytes,
            self.comm_msgs,
            self.seg_acks,
            self.generated_tokens,
            self.overlapped_ms,
            self.exposed_ms
        ));
        s.push_str(&format!(
            "\niterations={} fused_decode_tokens={} fused_allreduces={} \
             exposed_ms_per_tok={:.4} exposed_epilogue_ms={:.2} fused_epilogue_rows={}",
            self.iterations,
            self.fused_decode_tokens,
            self.fused_allreduces,
            self.exposed_ms_per_token(),
            self.exposed_epilogue_ms,
            self.fused_epilogue_rows
        ));
        if self.spec_windows > 0 {
            s.push_str(&format!(
                "\nspec_windows={} spec_drafted={} spec_accepted={} accept_rate={:.3}",
                self.spec_windows,
                self.spec_drafted,
                self.spec_accepted,
                self.acceptance_rate()
            ));
            s.push('\n');
            s.push_str(&self.spec_accept_hist.summary("spec_accept_per_window"));
        }
        if !self.queue_depth.is_empty() {
            s.push('\n');
            s.push_str(&self.queue_depth.summary("queue_depth"));
            s.push('\n');
            s.push_str(&self.queue_wait_ms.summary("queue_wait_ms"));
        }
        // The per-rung wire split appears only when the ladder is in
        // play — two rungs live at once (per-phase policy) or a
        // sub-int8 rung on the wire. Uniform legacy configs (all bytes
        // on one of f32/fp16/int8) keep byte-identical reports.
        let rungs_live = self.comm_bytes_by_rung.iter().filter(|&&b| b > 0).count();
        if rungs_live > 1
            || self.comm_bytes_by_rung[crate::config::CommQuant::Fp8.index()] > 0
            || self.comm_bytes_by_rung[crate::config::CommQuant::Int4.index()] > 0
        {
            s.push_str("\nwire_rungs:");
            for q in crate::config::CommQuant::LADDER {
                let b = self.comm_bytes_by_rung[q.index()];
                if b > 0 {
                    s.push_str(&format!(" {}={b}", q.label()));
                }
            }
        }
        // Pipeline counters appear only when stages actually ran, so
        // single-stage reports stay byte-identical to the pre-PP output.
        if self.p2p_msgs > 0 || !self.pp_bubble_ms.is_empty() {
            s.push_str(&format!(
                "\np2p_bytes={} p2p_msgs={}",
                self.p2p_bytes, self.p2p_msgs
            ));
            s.push('\n');
            s.push_str(&self.pp_bubble_ms.summary("pp_bubble_ms"));
            s.push('\n');
            s.push_str(&self.stage_compute_ms.summary("stage_compute_ms"));
        }
        // Fault counters appear only when a fault was actually detected,
        // so fault-free reports stay byte-identical to pre-fault output.
        if self.faults_detected > 0 || self.recoveries > 0 {
            s.push_str(&format!(
                "\nfaults_detected={} recoveries={} replayed_seqs={} replayed_tokens={}",
                self.faults_detected, self.recoveries, self.replayed_seqs, self.replayed_tokens
            ));
            s.push('\n');
            s.push_str(&self.recovery_ms.summary("recovery_ms"));
        }
        // Overload counters appear only when the overload machinery
        // actually fired, so unloaded reports stay byte-identical.
        if self.preemptions > 0 || self.sheds > 0 || self.rejected > 0 {
            s.push_str(&format!(
                "\npreemptions={} preempted_tokens={} sheds={} rejected={}",
                self.preemptions, self.preempted_tokens, self.sheds, self.rejected
            ));
        }
        // Context-parallel counters appear only when shards actually
        // moved on the ring, so cp = 1 reports stay byte-identical.
        if self.cp_shard_msgs > 0 {
            s.push_str(&format!(
                "\ncp_shard_bytes={} cp_shard_msgs={} cp_stall_ms={:.2}",
                self.cp_shard_bytes, self.cp_shard_msgs, self.cp_stall_ms
            ));
        }
        // Offload counters appear only when the tier actually moved
        // pages, so resident-only reports stay byte-identical.
        if self.kv_spilled_pages > 0 || self.kv_fetched_pages > 0 || self.kv_prefetched_pages > 0
        {
            s.push_str(&format!(
                "\nkv_spilled_pages={} kv_fetched_pages={} kv_prefetched_pages={}",
                self.kv_spilled_pages, self.kv_fetched_pages, self.kv_prefetched_pages
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_exact_nearest_rank() {
        let mut h = Histogram::new();
        for v in 1..=100 {
            h.record(v as f64);
        }
        assert_eq!(h.p50(), 50.0);
        assert_eq!(h.p95(), 95.0);
        assert_eq!(h.p99(), 99.0);
        assert_eq!(h.percentile(1.0), 100.0);
        assert_eq!(h.percentile(0.0), 1.0); // clamped to rank 1
    }

    #[test]
    fn mean_min_max() {
        let mut h = Histogram::new();
        for v in [2.0, 4.0, 6.0] {
            h.record(v);
        }
        assert_eq!(h.mean(), 4.0);
        assert_eq!(h.min(), 2.0);
        assert_eq!(h.max(), 6.0);
        assert_eq!(h.len(), 3);
    }

    #[test]
    fn empty_histogram_is_nan() {
        let mut h = Histogram::new();
        assert!(h.mean().is_nan());
        assert!(h.p50().is_nan());
    }

    #[test]
    fn record_after_percentile_resorts() {
        let mut h = Histogram::new();
        h.record(10.0);
        assert_eq!(h.p50(), 10.0);
        h.record(1.0);
        assert_eq!(h.percentile(0.5), 1.0);
    }

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(t.elapsed_ms() >= 1.0);
    }

    #[test]
    fn engine_metrics_report_contains_counts() {
        let mut m = EngineMetrics::default();
        m.ttft_ms.record(12.5);
        m.prefill_chunks = 4;
        m.allreduces = 16;
        let r = m.report();
        assert!(r.contains("prefill_chunks=4"));
        assert!(r.contains("allreduces=16"));
        assert!(r.contains("iterations=0"));
    }

    #[test]
    fn exposed_per_token_and_mixed_counters() {
        let mut m = EngineMetrics::default();
        assert_eq!(m.exposed_ms_per_token(), 0.0); // no tokens, no NaN
        m.generated_tokens = 40;
        m.exposed_ms = 10.0;
        assert!((m.exposed_ms_per_token() - 0.25).abs() < 1e-12);
        m.tbt_ms.record(3.0);
        m.iter_occupancy.record(9.0);
        m.iterations = 7;
        m.fused_decode_tokens = 32;
        m.fused_allreduces = 56;
        m.exposed_epilogue_ms = 1.5;
        m.fused_epilogue_rows = 96;
        let r = m.report();
        assert!(r.contains("tbt_ms"));
        assert!(r.contains("iter_occupancy"));
        assert!(r.contains("fused_decode_tokens=32"));
        assert!(r.contains("exposed_ms_per_tok=0.25"));
        assert!(r.contains("exposed_epilogue_ms=1.50"));
        assert!(r.contains("fused_epilogue_rows=96"));
    }

    #[test]
    fn pp_counters_absent_until_stages_run() {
        // Satellite (PR 4): the single-stage report is byte-identical to
        // the pre-PP format — pipeline lines appear only once p2p moved.
        let mut m = EngineMetrics::default();
        let before = m.report();
        assert!(!before.contains("p2p_bytes"), "pp lines must be opt-in");
        m.p2p_bytes = 4096;
        m.p2p_msgs = 8;
        m.pp_bubble_ms.record(1.5);
        m.stage_compute_ms.record(10.0);
        m.stage_compute_ms.record(12.0);
        let after = m.report();
        assert!(after.contains("p2p_bytes=4096 p2p_msgs=8"));
        assert!(after.contains("pp_bubble_ms"));
        assert!(after.contains("stage_compute_ms"));
        assert!(after.starts_with(&before), "pp lines must only append");
    }

    #[test]
    fn fault_counters_absent_until_faults() {
        // Satellite (PR 6): fault-free reports stay byte-identical to
        // the pre-fault format — fault lines appear only on detection.
        let mut m = EngineMetrics::default();
        let before = m.report();
        assert!(!before.contains("faults_detected"), "fault lines must be opt-in");
        m.faults_detected = 2;
        m.recoveries = 1;
        m.replayed_seqs = 3;
        m.replayed_tokens = 120;
        m.recovery_ms.record(42.0);
        let after = m.report();
        assert!(after.contains("faults_detected=2 recoveries=1 replayed_seqs=3"));
        assert!(after.contains("replayed_tokens=120"));
        assert!(after.contains("recovery_ms"));
        assert!(after.starts_with(&before), "fault lines must only append");
    }

    #[test]
    fn overload_counters_absent_until_overload() {
        // Satellite (PR 7): unloaded reports stay byte-identical to the
        // pre-overload format — the line appears only under pressure.
        let mut m = EngineMetrics::default();
        let before = m.report();
        assert!(!before.contains("preemptions"), "overload lines must be opt-in");
        m.preemptions = 2;
        m.preempted_tokens = 160;
        m.sheds = 3;
        m.rejected = 5;
        let after = m.report();
        assert!(after.contains("preemptions=2 preempted_tokens=160 sheds=3 rejected=5"));
        assert!(after.starts_with(&before), "overload lines must only append");
    }

    #[test]
    fn wire_rungs_absent_until_ladder_in_play() {
        // Satellite (PR 8): a uniform legacy wire (all bytes on one of
        // f32/fp16/int8) keeps the report byte-identical — the per-rung
        // split appears only with a mixed policy or a sub-int8 rung.
        let mut m = EngineMetrics::default();
        m.comm_bytes_by_rung[0] = 4096; // uniform f32: legacy shape
        let before = m.report();
        assert!(!before.contains("wire_rungs"), "rung line must be opt-in");
        let mut int8 = EngineMetrics::default();
        int8.comm_bytes_by_rung[2] = 4096; // uniform int8: also legacy
        assert!(!int8.report().contains("wire_rungs"));
        m.comm_bytes_by_rung[4] = 512; // decode lane dropped to int4
        let after = m.report();
        assert!(after.contains("wire_rungs: f32=4096 int4=512"));
        assert!(after.starts_with(&before), "rung line must only append");
        let mut solo = EngineMetrics::default();
        solo.comm_bytes_by_rung[3] = 64; // fp8 alone is still non-legacy
        assert!(solo.report().contains("wire_rungs: fp8=64"));
    }

    #[test]
    fn spec_and_queue_counters_report() {
        let mut m = EngineMetrics::default();
        assert_eq!(m.acceptance_rate(), 0.0); // no drafts, no NaN
        assert!(!m.report().contains("spec_windows"), "absent until used");
        m.spec_windows = 5;
        m.spec_drafted = 20;
        m.spec_accepted = 12;
        m.spec_accept_hist.record(3.0);
        m.queue_depth.record(4.0);
        m.queue_wait_ms.record(7.5);
        assert!((m.acceptance_rate() - 0.6).abs() < 1e-12);
        let r = m.report();
        assert!(r.contains("spec_windows=5"));
        assert!(r.contains("accept_rate=0.600"));
        assert!(r.contains("spec_accept_per_window"));
        assert!(r.contains("queue_depth"));
        assert!(r.contains("queue_wait_ms"));
    }
}
