//! Discrete-event simulator for one tensor-parallel device's two streams.
//!
//! Tensor parallelism is symmetric: every rank runs the identical schedule
//! and the collectives synchronize them, so simulating one representative
//! device (a COMPUTE stream + a COMM stream, like the paper's Figure 1
//! lanes) reproduces the whole node's makespan.
//!
//! Contention model (paper §3.2, "computation dominates"): NCCL collectives
//! occupy SMs. A compute kernel *launched while a collective is in flight*
//! runs at `1/contention` speed for its whole lifetime (occupancy is fixed
//! at launch); a collective starting mid-kernel slows the remainder of that
//! kernel. Kernels launched after the collective completes run at full
//! speed — which is exactly why the paper segments large GEMMs into
//! multiple launches (reproduced by `sched`'s `gemm_segments`).

use std::collections::BinaryHeap;

/// Which stream executes the op.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// Runs on the compute stream (kernels).
    Compute,
    /// Runs on the communication stream (collectives).
    Comm,
}

/// Node in the op DAG.
#[derive(Clone, Debug)]
pub struct Op {
    /// Stable id == index in `OpGraph::ops`.
    pub id: usize,
    /// Human-readable label (drives the Gantt renderer).
    pub label: String,
    /// Which stream executes the op.
    pub kind: OpKind,
    /// Uncontended duration in seconds.
    pub duration_s: f64,
    /// Ids of ops that must complete before this op may start.
    pub deps: Vec<usize>,
    /// Micro-batch / chunk tag (0 or 1 for ISO; request id for
    /// request-overlap; 0 for serial) — used by the Gantt renderer.
    pub chunk: usize,
}

/// A complete schedule lowered from one prefill (sched::*).
#[derive(Clone, Debug, Default)]
pub struct OpGraph {
    /// Ops in insertion (id) order.
    pub ops: Vec<Op>,
}

impl OpGraph {
    /// An empty graph.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add an op; returns its id.
    pub fn push(
        &mut self,
        label: impl Into<String>,
        kind: OpKind,
        duration_s: f64,
        deps: &[usize],
        chunk: usize,
    ) -> usize {
        let id = self.ops.len();
        for &d in deps {
            assert!(d < id, "dep {d} of op {id} not yet defined (cycle?)");
        }
        assert!(duration_s >= 0.0, "negative duration for {id}");
        self.ops.push(Op { id, label: label.into(), kind, duration_s, deps: deps.to_vec(), chunk });
        id
    }

    /// Sum of uncontended durations on one stream.
    pub fn total_work(&self, kind: OpKind) -> f64 {
        self.ops.iter().filter(|o| o.kind == kind).map(|o| o.duration_s).sum()
    }
}

/// One executed span on a stream.
#[derive(Clone, Debug, PartialEq)]
pub struct Span {
    /// The executed op's id.
    pub op_id: usize,
    /// The op's label.
    pub label: String,
    /// Stream the span ran on.
    pub kind: OpKind,
    /// The op's micro-batch / chunk tag.
    pub chunk: usize,
    /// Start time (seconds).
    pub start_s: f64,
    /// End time (seconds).
    pub end_s: f64,
    /// True if this compute span paid the SM-contention tax.
    pub contended: bool,
}

/// Simulation result.
#[derive(Clone, Debug, Default)]
pub struct Timeline {
    /// Executed spans, in completion order.
    pub spans: Vec<Span>,
    /// Wall time of the whole schedule (seconds).
    pub makespan_s: f64,
}

impl Timeline {
    /// Total busy time of a stream.
    pub fn busy_s(&self, kind: OpKind) -> f64 {
        self.spans.iter().filter(|s| s.kind == kind).map(|s| s.end_s - s.start_s).sum()
    }

    /// Wall time during which both streams were simultaneously busy —
    /// the achieved overlap.
    pub fn overlap_s(&self) -> f64 {
        // Sweep span edges; both-busy intervals.
        let mut edges: Vec<(f64, OpKind, i32)> = Vec::new();
        for s in &self.spans {
            edges.push((s.start_s, s.kind, 1));
            edges.push((s.end_s, s.kind, -1));
        }
        edges.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let (mut nc, mut nm) = (0i32, 0i32);
        let mut last = 0.0;
        let mut both = 0.0;
        for (t, kind, d) in edges {
            if nc > 0 && nm > 0 {
                both += t - last;
            }
            match kind {
                OpKind::Compute => nc += d,
                OpKind::Comm => nm += d,
            }
            last = t;
        }
        both
    }
}

/// Exact makespan of a linear pipeline (DESIGN.md §11): `chunks`
/// micro-batches flow through `stage_s.len()` stages in order, stage `s`
/// taking `stage_s[s]` seconds per chunk, with `hop_s` seconds of
/// point-to-point transfer between consecutive stages. Each stage
/// processes one chunk at a time (chunks FIFO), and a hop overlaps with
/// both neighbors' compute (the async-DMA link model). This is the
/// wavefront recurrence
///
/// ```text
/// finish[s][i] = max(finish[s][i-1], finish[s-1][i] + hop) + stage[s]
/// ```
///
/// whose uniform-stage closed form is `(stages + chunks - 1)·T +
/// (stages - 1)·hop` — the classic fill/drain bubble of
/// `(stages - 1) / (chunks + stages - 1)`.
pub fn pipeline_makespan(stage_s: &[f64], hop_s: f64, chunks: usize) -> f64 {
    assert!(!stage_s.is_empty(), "no stages");
    assert!(chunks >= 1, "no chunks");
    assert!(hop_s >= 0.0 && stage_s.iter().all(|&t| t >= 0.0));
    let mut finish = vec![0.0f64; stage_s.len()];
    for _ in 0..chunks {
        let mut arrive = 0.0f64; // chunk ready at stage 0 at t = 0
        for (s, &t) in stage_s.iter().enumerate() {
            let start = finish[s].max(arrive);
            finish[s] = start + t;
            arrive = finish[s] + hop_s;
        }
    }
    finish[stage_s.len() - 1]
}

/// Exposed tail of a segment-streamed epilogue (DESIGN.md §12): segment
/// `k`'s reduced rows arrive `cover_s[k]` seconds after segment `k−1`'s
/// (the collective's wire pacing), and a single epilogue worker spends
/// `work_s[k]` on each the moment it is both arrived and free — the
/// TokenWeave-style fusion the engine's comm threads run. Returns how
/// long the epilogue runs **past the last arrival** — the only part the
/// collective cannot hide:
///
/// ```text
/// arrive[k] = Σ cover_s[..=k]
/// finish    = max(finish, arrive[k]) + work_s[k]
/// exposed   = finish − arrive[last]
/// ```
///
/// One segment degenerates to the serial epilogue (`work_s[0]` fully
/// exposed); with wire-dominated segments (`work ≤ cover` per segment)
/// only the last segment's slice is exposed.
pub fn streamed_epilogue_exposed_s(cover_s: &[f64], work_s: &[f64]) -> f64 {
    assert_eq!(cover_s.len(), work_s.len(), "one cover per work segment");
    assert!(!cover_s.is_empty(), "no segments");
    assert!(cover_s.iter().chain(work_s).all(|&x| x >= 0.0));
    let mut arrive = 0.0f64;
    let mut finish = 0.0f64;
    for (&c, &w) in cover_s.iter().zip(work_s) {
        arrive += c;
        finish = finish.max(arrive) + w;
    }
    (finish - arrive).max(0.0)
}

struct Running {
    op: usize,
    start: f64,
    end: f64,
    contended: bool,
}

/// Execute the DAG on the two streams; deterministic (FIFO by op id among
/// ready ops).
pub fn simulate(graph: &OpGraph, contention: f64) -> Timeline {
    assert!(contention >= 1.0, "contention must be >= 1");
    let n = graph.ops.len();
    let mut indeg: Vec<usize> = vec![0; n];
    let mut rdeps: Vec<Vec<usize>> = vec![Vec::new(); n];
    for op in &graph.ops {
        indeg[op.id] = op.deps.len();
        for &d in &op.deps {
            rdeps[d].push(op.id);
        }
    }

    // Ready queues (BinaryHeap as min-heap over op id via Reverse).
    use std::cmp::Reverse;
    let mut ready_c: BinaryHeap<Reverse<usize>> = BinaryHeap::new();
    let mut ready_m: BinaryHeap<Reverse<usize>> = BinaryHeap::new();
    for op in &graph.ops {
        if op.deps.is_empty() {
            match op.kind {
                OpKind::Compute => ready_c.push(Reverse(op.id)),
                OpKind::Comm => ready_m.push(Reverse(op.id)),
            }
        }
    }

    let mut running_c: Option<Running> = None;
    let mut running_m: Option<Running> = None;
    let mut spans: Vec<Span> = Vec::with_capacity(n);
    let mut done = 0usize;
    let mut now = 0.0f64;

    // Start ops if streams idle; returns true if anything started.
    fn try_start(
        now: f64,
        graph: &OpGraph,
        contention: f64,
        ready_c: &mut BinaryHeap<std::cmp::Reverse<usize>>,
        ready_m: &mut BinaryHeap<std::cmp::Reverse<usize>>,
        running_c: &mut Option<Running>,
        running_m: &mut Option<Running>,
    ) -> bool {
        let mut started = false;
        // Start comm first so a simultaneously-ready compute op sees the
        // in-flight collective (conservative, matches NCCL stream order).
        if running_m.is_none() {
            if let Some(std::cmp::Reverse(id)) = ready_m.pop() {
                let dur = graph.ops[id].duration_s;
                *running_m = Some(Running { op: id, start: now, end: now + dur, contended: false });
                // A collective starting now slows the remainder of a
                // running, not-yet-contended compute kernel.
                if let Some(rc) = running_c.as_mut() {
                    if !rc.contended && rc.end > now {
                        let remaining = rc.end - now;
                        rc.end = now + remaining * contention;
                        rc.contended = true;
                    }
                }
                started = true;
            }
        }
        if running_c.is_none() {
            if let Some(std::cmp::Reverse(id)) = ready_c.pop() {
                let comm_busy = running_m.is_some();
                let factor = if comm_busy { contention } else { 1.0 };
                let dur = graph.ops[id].duration_s * factor;
                *running_c = Some(Running {
                    op: id,
                    start: now,
                    end: now + dur,
                    contended: comm_busy,
                });
                started = true;
            }
        }
        started
    }

    while done < n {
        // Greedily start whatever can start at `now`.
        while try_start(now, graph, contention, &mut ready_c, &mut ready_m, &mut running_c, &mut running_m) {}

        // Advance to the earliest completion.
        let next_end = [
            running_c.as_ref().map(|r| r.end),
            running_m.as_ref().map(|r| r.end),
        ]
        .into_iter()
        .flatten()
        .fold(f64::INFINITY, f64::min);
        assert!(
            next_end.is_finite(),
            "deadlock: {done}/{n} ops done, nothing running — cyclic or cross-kind dep starvation"
        );
        now = next_end;

        // Complete every op ending at `now`.
        for running in [&mut running_c, &mut running_m] {
            if running.as_ref().map(|r| r.end <= now + 1e-15).unwrap_or(false) {
                let r = running.take().unwrap();
                let op = &graph.ops[r.op];
                spans.push(Span {
                    op_id: r.op,
                    label: op.label.clone(),
                    kind: op.kind,
                    chunk: op.chunk,
                    start_s: r.start,
                    end_s: r.end,
                    contended: r.contended,
                });
                done += 1;
                for &succ in &rdeps[r.op] {
                    indeg[succ] -= 1;
                    if indeg[succ] == 0 {
                        match graph.ops[succ].kind {
                            OpKind::Compute => ready_c.push(Reverse(succ)),
                            OpKind::Comm => ready_m.push(Reverse(succ)),
                        }
                    }
                }
            }
        }
    }

    spans.sort_by(|a, b| a.start_s.partial_cmp(&b.start_s).unwrap().then(a.op_id.cmp(&b.op_id)));
    let makespan = spans.iter().map(|s| s.end_s).fold(0.0, f64::max);
    Timeline { spans, makespan_s: makespan }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn g() -> OpGraph {
        OpGraph::new()
    }

    #[test]
    fn serial_chain_sums_durations() {
        let mut graph = g();
        let a = graph.push("c0", OpKind::Compute, 1.0, &[], 0);
        let b = graph.push("m0", OpKind::Comm, 2.0, &[a], 0);
        let c = graph.push("c1", OpKind::Compute, 3.0, &[b], 0);
        let _ = graph.push("m1", OpKind::Comm, 1.0, &[c], 0);
        let tl = simulate(&graph, 1.0);
        assert!((tl.makespan_s - 7.0).abs() < 1e-12);
        assert_eq!(tl.spans.len(), 4);
        assert!(tl.overlap_s() < 1e-12);
    }

    #[test]
    fn independent_ops_overlap_fully() {
        let mut graph = g();
        graph.push("c", OpKind::Compute, 4.0, &[], 0);
        graph.push("m", OpKind::Comm, 4.0, &[], 1);
        let tl = simulate(&graph, 1.0);
        assert!((tl.makespan_s - 4.0).abs() < 1e-12);
        assert!((tl.overlap_s() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn compute_stream_is_exclusive() {
        let mut graph = g();
        graph.push("c0", OpKind::Compute, 2.0, &[], 0);
        graph.push("c1", OpKind::Compute, 2.0, &[], 1);
        let tl = simulate(&graph, 1.0);
        assert!((tl.makespan_s - 4.0).abs() < 1e-12); // one stream, serialized
    }

    #[test]
    fn contention_applies_to_kernel_launched_during_comm() {
        let mut graph = g();
        graph.push("m", OpKind::Comm, 10.0, &[], 0);
        graph.push("c", OpKind::Compute, 4.0, &[], 0);
        let tl = simulate(&graph, 1.5);
        let c = tl.spans.iter().find(|s| s.kind == OpKind::Compute).unwrap();
        assert!(c.contended);
        assert!((c.end_s - c.start_s - 6.0).abs() < 1e-12); // 4 * 1.5
    }

    #[test]
    fn contention_slows_remainder_when_comm_starts_midway() {
        let mut graph = g();
        let c0 = graph.push("pre", OpKind::Compute, 0.0, &[], 0);
        graph.push("c", OpKind::Compute, 4.0, &[c0], 0);
        graph.push("m", OpKind::Comm, 10.0, &[c0], 0);
        // both start ~0; comm starts first in try_start order, so compute
        // launches during comm → fully contended. Instead gate comm later:
        let mut graph2 = g();
        let _c = graph2.push("c", OpKind::Compute, 4.0, &[], 0);
        let gate = graph2.push("gate", OpKind::Compute, 0.0, &[], 1);
        let _ = gate;
        // no clean way to delay comm without a timed dep; emulate with a
        // compute pre-op feeding comm: comm starts when pre-op (2s) ends.
        let mut graph3 = g();
        let pre = graph3.push("pre", OpKind::Compute, 2.0, &[], 0);
        graph3.push("big", OpKind::Compute, 4.0, &[pre], 0); // runs 2..6 uncontended
        graph3.push("m", OpKind::Comm, 5.0, &[pre], 0);      // starts at 2
        let tl3 = simulate(&graph3, 2.0);
        // "big" starts at 2 with comm also starting at 2 (comm first) → contended whole: 8s.
        let big = tl3.spans.iter().find(|s| s.label == "big").unwrap();
        assert!(big.contended);
        assert!((big.end_s - big.start_s - 8.0).abs() < 1e-9);
        let _ = simulate(&graph, 1.5);
        let _ = simulate(&graph2, 1.5);
    }

    #[test]
    fn midflight_comm_scales_remaining_compute() {
        // compute runs 0..4; comm becomes ready at t=2 via a comm pre-dep.
        let mut graph = g();
        let pre_m = graph.push("pre_m", OpKind::Comm, 2.0, &[], 0);
        graph.push("c", OpKind::Compute, 4.0, &[], 0);
        graph.push("m", OpKind::Comm, 5.0, &[pre_m], 0);
        let tl = simulate(&graph, 2.0);
        let c = tl.spans.iter().find(|s| s.label == "c").unwrap();
        // c starts at 0 *during* pre_m (comm busy) → contended from launch.
        assert!(c.contended);
        assert!((c.end_s - 8.0).abs() < 1e-9);
    }

    #[test]
    fn kernel_launched_after_comm_ends_is_full_speed() {
        let mut graph = g();
        let m = graph.push("m", OpKind::Comm, 1.0, &[], 0);
        graph.push("c", OpKind::Compute, 4.0, &[m], 0);
        let tl = simulate(&graph, 2.0);
        let c = tl.spans.iter().find(|s| s.kind == OpKind::Compute).unwrap();
        assert!(!c.contended);
        assert!((c.end_s - c.start_s - 4.0).abs() < 1e-12);
    }

    #[test]
    fn deterministic_fifo_order() {
        let mut graph = g();
        for i in 0..5 {
            graph.push(format!("c{i}"), OpKind::Compute, 1.0, &[], i);
        }
        let tl = simulate(&graph, 1.0);
        let order: Vec<usize> = tl.spans.iter().map(|s| s.op_id).collect();
        assert_eq!(order, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    #[should_panic(expected = "dep")]
    fn forward_deps_rejected() {
        let mut graph = g();
        graph.push("bad", OpKind::Compute, 1.0, &[3], 0);
    }

    #[test]
    fn busy_and_total_work_agree_without_contention() {
        let mut graph = g();
        let a = graph.push("c", OpKind::Compute, 1.5, &[], 0);
        graph.push("m", OpKind::Comm, 2.5, &[a], 0);
        let tl = simulate(&graph, 1.0);
        assert!((tl.busy_s(OpKind::Compute) - 1.5).abs() < 1e-12);
        assert!((tl.busy_s(OpKind::Comm) - 2.5).abs() < 1e-12);
        assert!((graph.total_work(OpKind::Comm) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn prop_makespan_bounds_on_random_dags() {
        // For ANY dag: max(stream work) <= makespan <= contention * total work.
        use crate::util::{Prop, Rng};
        Prop::new(71).cases(150).run("sim makespan bounds", |rng: &mut Rng| {
            let n = rng.range(1, 40);
            let contention = 1.0 + rng.f64() * 0.5;
            let mut graph = OpGraph::new();
            for i in 0..n {
                // random deps among earlier ops (keeps it acyclic)
                let n_deps = rng.range(0, (i + 1).min(4));
                let mut deps = Vec::new();
                for _ in 0..n_deps {
                    deps.push(rng.range(0, i.max(1)).min(i.saturating_sub(1)));
                }
                deps.sort_unstable();
                deps.dedup();
                let kind = if rng.f64() < 0.5 { OpKind::Compute } else { OpKind::Comm };
                graph.push(format!("op{i}"), kind, rng.f64() * 3.0, &deps, i % 2);
            }
            let tl = simulate(&graph, contention);
            let work_c = graph.total_work(OpKind::Compute);
            let work_m = graph.total_work(OpKind::Comm);
            let lower = work_c.max(work_m);
            let upper = (work_c + work_m) * contention + 1e-9;
            if tl.makespan_s + 1e-9 < lower {
                return Err(format!("makespan {} < stream bound {lower}", tl.makespan_s));
            }
            if tl.makespan_s > upper {
                return Err(format!("makespan {} > serial bound {upper}", tl.makespan_s));
            }
            if tl.spans.len() != graph.ops.len() {
                return Err("some op never executed".into());
            }
            // dependencies respected
            for s in &tl.spans {
                for &d in &graph.ops[s.op_id].deps {
                    let dep_end = tl.spans.iter().find(|x| x.op_id == d).unwrap().end_s;
                    if s.start_s + 1e-12 < dep_end {
                        return Err(format!("op {} started before dep {d}", s.op_id));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_overlap_bounded_by_stream_busy() {
        use crate::util::{Prop, Rng};
        Prop::new(73).cases(100).run("overlap <= min busy", |rng: &mut Rng| {
            let n = rng.range(2, 30);
            let mut graph = OpGraph::new();
            for i in 0..n {
                let deps: Vec<usize> =
                    if i > 0 && rng.f64() < 0.4 { vec![rng.range(0, i)] } else { vec![] };
                let kind = if i % 2 == 0 { OpKind::Compute } else { OpKind::Comm };
                graph.push(format!("op{i}"), kind, 0.1 + rng.f64(), &deps, 0);
            }
            let tl = simulate(&graph, 1.0);
            let overlap = tl.overlap_s();
            let min_busy = tl.busy_s(OpKind::Compute).min(tl.busy_s(OpKind::Comm));
            if overlap > min_busy + 1e-9 {
                return Err(format!("overlap {overlap} > min busy {min_busy}"));
            }
            Ok(())
        });
    }

    #[test]
    fn pipeline_makespan_single_stage_is_serial() {
        // One stage = no pipeline: chunks run back to back.
        assert!((pipeline_makespan(&[2.0], 0.5, 4) - 8.0).abs() < 1e-12);
        assert!((pipeline_makespan(&[3.0], 0.0, 1) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn pipeline_makespan_uniform_closed_form() {
        // (stages + chunks - 1)·T + (stages - 1)·hop, checked by hand:
        // 2 stages, T=2, hop=0.5, 3 chunks → (2+3-1)·2 + 1·0.5 = 8.5.
        assert!((pipeline_makespan(&[2.0, 2.0], 0.5, 3) - 8.5).abs() < 1e-12);
        // 3 stages, T=1, hop=0, 5 chunks → 7.
        assert!((pipeline_makespan(&[1.0, 1.0, 1.0], 0.0, 5) - 7.0).abs() < 1e-12);
    }

    #[test]
    fn pipeline_makespan_bottleneck_stage_dominates() {
        // The slow stage sets the steady-state rate: k chunks through
        // stages [1, 3] cost 1 + hop + 3k at large k (hand recurrence:
        // finish1[i] = max(finish1[i-1], i+1+hop) + 3 → 1 + hop + 3k
        // once the bottleneck saturates).
        let t = pipeline_makespan(&[1.0, 3.0], 0.0, 10);
        assert!((t - (1.0 + 30.0)).abs() < 1e-12, "got {t}");
        // More chunks amortize the fill bubble: per-chunk time falls.
        let per = |k: usize| pipeline_makespan(&[2.0, 2.0], 0.25, k) / k as f64;
        assert!(per(8) < per(2));
        assert!(per(32) < per(8));
    }

    #[test]
    fn streamed_epilogue_hand_arithmetic() {
        // One segment: the whole epilogue is exposed.
        assert!((streamed_epilogue_exposed_s(&[1.0], &[0.5]) - 0.5).abs() < 1e-12);
        // Wire-dominated (work <= cover per segment): only the last
        // segment's slice is exposed — arrivals 1,2,3,4 each processed in
        // 0.25 before the next lands.
        let e = streamed_epilogue_exposed_s(&[1.0; 4], &[0.25; 4]);
        assert!((e - 0.25).abs() < 1e-12, "{e}");
        // Work-dominated: the worker queues — arrivals at 0.1, 0.2;
        // finish = 0.1 + 1.0 + 1.0 = 2.1; exposed = 2.1 − 0.2 = 1.9.
        let e = streamed_epilogue_exposed_s(&[0.1; 2], &[1.0; 2]);
        assert!((e - 1.9).abs() < 1e-12, "{e}");
        // More segments never increase exposure (same totals).
        let total_cover = 1.0;
        let total_work = 0.8;
        let mut prev = f64::INFINITY;
        for s in [1usize, 2, 4, 8] {
            let e = streamed_epilogue_exposed_s(
                &vec![total_cover / s as f64; s],
                &vec![total_work / s as f64; s],
            );
            assert!(e <= prev + 1e-12, "s={s}: {e} > {prev}");
            prev = e;
        }
    }

    #[test]
    fn diamond_dependencies_respected() {
        let mut graph = g();
        let a = graph.push("a", OpKind::Compute, 1.0, &[], 0);
        let b = graph.push("b", OpKind::Comm, 1.0, &[a], 0);
        let c = graph.push("c", OpKind::Compute, 1.0, &[a], 0);
        graph.push("d", OpKind::Comm, 1.0, &[b, c], 0);
        let tl = simulate(&graph, 1.0);
        let find = |l: &str| tl.spans.iter().find(|s| s.label == l).unwrap().clone();
        assert!(find("b").start_s >= find("a").end_s - 1e-12);
        assert!(find("d").start_s >= find("c").end_s - 1e-12);
        assert!(find("d").start_s >= find("b").end_s - 1e-12);
    }
}
